"""CNF density estimation + 2-NFE HyperHeun sampling (paper Sec. 4.2).

Trains a FFJORD CNF on a chosen 2-D density, fits a HyperHeun with a
single K=1 residual, and prints sample-quality metrics at 2 NFEs vs
dopri5 (Fig. 7 quantified). ASCII density render included.

    PYTHONPATH=src python examples/cnf_density.py --density pinwheel
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_cnf import _g_apply, fit_hyperheun, train_cnf
from repro.core import FixedGrid, HyperSolver, get_tableau, odeint_dopri5
from repro.data import density_sampler
from repro.nn.cnf import exact_trace_dynamics


def ascii_density(x, bins=28, lo=-4.0, hi=4.0):
    h, _, _ = np.histogram2d(x[:, 1], x[:, 0], bins=bins,
                             range=[[lo, hi], [lo, hi]])
    h = h / max(h.max(), 1)
    chars = " .:-=+*#%@"
    return "\n".join(
        "".join(chars[min(int(v * 9.99), 9)] for v in row)
        for row in h[::-1])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--density", default="pinwheel",
                    choices=["pinwheel", "rings", "checkerboard", "circles"])
    ap.add_argument("--iters", type=int, default=600)
    args = ap.parse_args()

    print(f"training CNF on {args.density} ...")
    p = train_cnf(args.density, iters=args.iters)
    print("fitting HyperHeun (K=1 residual, paper Sec. 4.2) ...")
    gp = fit_hyperheun(p, args.density, iters=500)

    aug = exact_trace_dynamics(p)
    z0 = jax.random.normal(jax.random.PRNGKey(0), (4096, 2))
    state0 = (z0, jnp.zeros(z0.shape[0]))

    ref, nfe = odeint_dopri5(aug, state0, FixedGrid.over(0, 1, 1),
                             atol=1e-5, rtol=1e-5)
    x_ref = np.asarray(ref[0][-1])

    hs = HyperSolver(tableau=get_tableau("heun"),
                     g=lambda e, s, z, dz: _g_apply(gp, e, s, None, z, dz))
    x_hyper = np.asarray(hs.odeint(aug, state0, FixedGrid.over(0, 1, 1),
                                   return_traj=False)[0])
    heun = HyperSolver(tableau=get_tableau("heun"), g=None)
    x_heun = np.asarray(heun.odeint(aug, state0, FixedGrid.over(0, 1, 1),
                                    return_traj=False)[0])

    d_hyper = float(np.mean(np.linalg.norm(x_hyper - x_ref, -1)))
    d_heun = float(np.mean(np.linalg.norm(x_heun - x_ref, -1)))
    print(f"\ndopri5 used {int(nfe)} NFEs; fixed methods use 2 NFEs")
    print(f"mean sample displacement vs dopri5:  "
          f"HyperHeun {d_hyper:.4f}   plain Heun {d_heun:.4f}   "
          f"({d_heun / max(d_hyper, 1e-9):.1f}x worse)")

    data = np.asarray(next(density_sampler(args.density, 4096, seed=3)))
    print("\n-- data --")
    print(ascii_density(data))
    print("\n-- HyperHeun samples @ 2 NFE --")
    print(ascii_density(x_hyper))


if __name__ == "__main__":
    main()
