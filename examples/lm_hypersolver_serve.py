"""End-to-end serving driver: batched requests against a small LM with a
selectable depth solver — the paper's technique as a serving feature
(DESIGN.md §4). Trains a reduced qwen3-family model on the synthetic token
stream for a few hundred steps, then serves batched greedy generation and
compares full-depth vs hypersolved continuous-depth scoring.

    PYTHONPATH=src python examples/lm_hypersolver_serve.py --steps 200
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
import argparse
import time

import jax
import jax.numpy as jnp

from benchmarks.bench_cdepth_lm import train_small_lm
from repro.data import token_batches
from repro.launch.engine import greedy_generate
from repro.models.cdepth import (
    cdepth_residual_loss, lm_forward_cdepth, lm_g_init,
)
from repro.models.lm import group_layout, lm_forward
from repro.optim import adamw, apply_updates, clip_by_global_norm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg, params = train_small_lm(steps=args.steps)
    _, n_groups, _ = group_layout(cfg)
    print(f"model: {cfg.name} (reduced), {cfg.n_layers} layers "
          f"({n_groups} depth groups), vocab {cfg.vocab}")

    # --- batched generation (discrete full-depth path)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, 8), 0, cfg.vocab)
    t0 = time.time()
    toks = greedy_generate(params, cfg, prompt, args.gen)
    dt = time.time() - t0
    print(f"[serve] generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s, full depth)")

    # --- hypersolved continuous-depth scoring at half NFE
    K = n_groups // 2
    gp = lm_g_init(jax.random.PRNGKey(2), cfg, rank=32,
                   param_dtype=jnp.float32)
    opt = adamw(3e-3)
    st = opt.init(gp)

    @jax.jit
    def fit(gp, st, i, batch):
        l, g = jax.value_and_grad(
            lambda gg: cdepth_residual_loss(params, gg, cfg, batch, K))(gp)
        g, _ = clip_by_global_norm(g, 1.0)
        u, st = opt.update(g, st, gp, i)
        return apply_updates(gp, u), st, l

    it = token_batches(cfg.vocab, 4, 32, seed=9)
    batch, _ = next(it)
    for i in range(120):
        if i % 10 == 0:
            batch, _ = next(it)
        gp, st, loss = fit(gp, st, i, batch)
    print(f"[hypersolver] residual loss after fit: {float(loss):.4f}")

    eval_toks, _ = next(token_batches(cfg.vocab, 8, 48, seed=33))
    full, _ = lm_forward(params, cfg, eval_toks)
    for label, g_used in (("euler (layer-skip)", None),
                          ("HYPER-euler", gp)):
        out = lm_forward_cdepth(params, cfg, eval_toks, K=K,
                                solver="euler", g_params=g_used)
        lp_full = jax.nn.log_softmax(full, -1)
        lp_out = jax.nn.log_softmax(out, -1)
        kl = float(jnp.mean(jnp.sum(jnp.exp(lp_full)
                                    * (lp_full - lp_out), -1)))
        print(f"[score @ NFE {K}/{n_groups}] {label:20s} "
              f"KL vs full depth = {kl:.4f}")


if __name__ == "__main__":
    main()
