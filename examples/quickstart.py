"""Quickstart: the hypersolver paradigm in ~80 lines.

Train a small Neural ODE classifier on two-moons, generate dopri5
ground-truth trajectories, fit a HyperEuler by residual fitting, and print
the NFE/error pareto (paper Secs. 3-4 in miniature).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import EULER, FixedGrid, NeuralODE, get_tableau, odeint_fixed
from repro.core.train import (
    HypersolverTrainConfig, make_hypersolver, train_hypersolver,
)
from repro.nn.module import mlp_apply, mlp_init
from repro.optim import adamw, apply_updates


def two_moons(key, n):
    k1, k2, k3 = jax.random.split(key, 3)
    t = jax.random.uniform(k1, (n,)) * jnp.pi
    lab = jax.random.bernoulli(k2, 0.5, (n,)).astype(jnp.int32)
    x = jnp.stack([jnp.cos(t) * (1 - 2 * lab) + lab,
                   jnp.sin(t) * (1 - 2 * lab) + lab * 0.3], -1)
    return x + 0.05 * jax.random.normal(k3, x.shape), lab


def main():
    key = jax.random.PRNGKey(0)
    nz = 8

    params = {
        "f": mlp_init(jax.random.PRNGKey(1), (nz + 1, 64, nz)),
        "hx": mlp_init(jax.random.PRNGKey(2), (2, nz)),
        "hy": mlp_init(jax.random.PRNGKey(3), (nz, 2)),
    }

    def f_apply(p, s, x, z):
        s_col = jnp.broadcast_to(jnp.asarray(s, z.dtype), z[..., :1].shape)
        return mlp_apply(p["f"], jnp.concatenate([z, s_col], -1))

    node = NeuralODE(
        f_apply=f_apply,
        hx_apply=lambda p, x: mlp_apply(p["hx"], x),
        hy_apply=lambda p, z: mlp_apply(p["hy"], z),
    )

    # 1) task training (RK4, K=32 — ground-truth-quality forward)
    xs, ys = two_moons(key, 512)
    opt = adamw(3e-3)
    st = opt.init(params)

    @jax.jit
    def step(p, st, i):
        def loss(p):
            logits = node.forward_fixed(p, xs, get_tableau("rk4"), 32)
            return -jnp.mean(jax.nn.log_softmax(logits)[
                jnp.arange(xs.shape[0]), ys])
        l, g = jax.value_and_grad(loss)(p)
        u, st = opt.update(g, st, p, i)
        return apply_updates(p, u), st, l

    for i in range(200):
        params, st, loss = step(params, st, i)
    print(f"task loss after 200 steps: {loss:.4f}")

    # 2) hypersolver: residual fitting on dopri5 trajectories (K=4 mesh)
    gp = mlp_init(jax.random.PRNGKey(4), (2 * nz + 1, 64, nz),
                  final_zero=True)

    def g_apply(g, eps, s, x, z, dz):
        s_col = jnp.broadcast_to(jnp.asarray(s, z.dtype), z[..., :1].shape)
        return mlp_apply(g, jnp.concatenate([z, dz, s_col], -1))

    def batches():
        k = jax.random.PRNGKey(5)
        while True:
            k, sub = jax.random.split(k)
            yield two_moons(sub, 128)[0]

    cfg = HypersolverTrainConfig(base_solver="euler", K=4, iters=300,
                                 atol=1e-6, rtol=1e-6)
    gp, losses = train_hypersolver(node, params, g_apply, gp, batches(), cfg)
    print(f"residual loss: {losses[0]:.4f} -> {losses[-1]:.4f}")

    # 3) pareto: K-step Euler vs HyperEuler against dopri5 truth
    xt, _ = two_moons(jax.random.PRNGKey(6), 1024)
    print(f"{'K':>3} {'NFE':>4} {'euler_err':>10} {'hyper_err':>10}")
    for K in (2, 4, 8, 16):
        ref, _, _ = node.reference_trajectory(params, xt, K, atol=1e-8,
                                              rtol=1e-8)
        f = node.field(params, xt)
        z0 = node.hx_apply(params, xt)
        grid = FixedGrid.over(0.0, 1.0, K)
        base = odeint_fixed(f, z0, grid, EULER, return_traj=False)
        hs = make_hypersolver("euler", g_apply, gp, xt)
        hyper = hs.odeint(f, z0, grid, return_traj=False)
        e_b = float(jnp.mean(jnp.abs(base - ref[-1])))
        e_h = float(jnp.mean(jnp.abs(hyper - ref[-1])))
        print(f"{K:>3} {K:>4} {e_b:>10.5f} {e_h:>10.5f}"
              + ("   <- hypersolver wins" if e_h < e_b else ""))


if __name__ == "__main__":
    main()
