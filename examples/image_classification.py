"""Image-classification Neural ODE + HyperEuler (paper Sec. 4.1).

Trains the paper's MNIST-family conv Neural ODE on the synthetic image
set, fits a conv HyperEuler by residual fitting, and prints the solver
pareto (MAPE + accuracy drop vs NFE/GMACs).

    PYTHONPATH=src python examples/image_classification.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from benchmarks.bench_pareto import main as pareto_main


def main():
    rows = pareto_main(budget="small")
    print(f"{'solver':>12} {'K':>3} {'NFE':>4} {'GMAC':>8} "
          f"{'MAPE%':>8} {'acc_drop%':>9}")
    for r in rows:
        print(f"{r['solver']:>12} {r['K']:>3} {r['nfe']:>4} "
              f"{r['gmac']:>8.3f} {r['mape']:>8.3f} "
              f"{r['acc_loss_pct']:>9.3f}")
    # headline: equal-NFE comparison (the paper's axis)
    at_nfe = [r for r in rows if r["nfe"] == 4]
    best = min(at_nfe, key=lambda r: r["mape"])
    print(f"\nat 4 NFE the best solver is: {best['solver']} "
          f"(MAPE {best['mape']:.3f}%) — paper Fig. 3's low-NFE regime")


if __name__ == "__main__":
    main()
