"""In-flight depth-continuous batching: the resumable segment solve
(core/integrate.py::solve_segment), the slot-pool scheduler
(launch/scheduler.py), arrival-trace workloads + replay accounting
(launch/workload.py), and the BENCH schema gate (benchmarks/run.py
--check).

The acceptance pins:
  * segment-by-segment == one ``solve_multirate`` call (fp32 allclose),
    mixed-K, with and without a hypersolver correction;
  * ONE fused-kernel trace per (shape, seg) cell across every
    occupancy/refill pattern a streaming trace produces;
  * the slot-axis-sharded pool (``solve_segment(mesh=)`` /
    ``InflightScheduler(mesh=)``) reproduces the single-device results
    bit-for-bit on a forced 4-device CPU mesh, still one kernel trace
    per (shape, seg, mesh) cell (subprocess — the main test process
    keeps one device).
"""
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FixedGrid, Integrator, SegmentCarry, get_tableau, make_segment_carry,
)
from repro.kernels.hyper_step.ops import TRACE_COUNTS
from repro.launch.engine import DepthModel, EngineConfig, MultiRateEngine
from repro.launch.oracle import RooflineOracle, SequentialEvalOracle
from repro.launch.scheduler import InflightScheduler
from repro.launch.workload import (
    TraceReport, bursty_trace, heterogeneous_requests, latency_stats,
    poisson_trace, replay_engine, replay_scheduler,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _field(s, z):
    return -z * jax.nn.softplus(jnp.mean(z, axis=-1, keepdims=True))


G = lambda eps, s, z, dz: 0.25 * z + 0.1 * dz


def _toy_model(fused=False, g=None):
    def field_of(x):
        k = jax.nn.softplus(jnp.mean(x, axis=-1, keepdims=True))
        return lambda s, z: -z * k

    return DepthModel(
        embed=lambda x: x + 0.0,
        field_of=field_of,
        readout=lambda x, zT: zT,
        integ=Integrator(tableau=get_tableau("euler"), g=g, fused=fused),
    )


# ------------------------------------------------- solve_segment parity ----

@pytest.mark.parametrize("fused", [False, True])
@pytest.mark.parametrize("with_g", [False, True])
@pytest.mark.parametrize("seg", [1, 2, 3, 8])
def test_solve_segment_parity_with_solve_multirate(fused, with_g, seg):
    """ACCEPTANCE: driving a mixed-K batch to completion segment-by-
    segment is allclose (fp32) to ONE solve_multirate call — with and
    without a hypersolver correction, fused and unfused, for seg both
    dividing and not dividing the mesh lengths."""
    g = G if with_g else None
    integ = Integrator(get_tableau("heun"), g=g, fused=fused)
    z0 = jax.random.normal(jax.random.PRNGKey(0), (5, 17))
    Ks = jnp.asarray([1, 2, 5, 8, 3], jnp.int32)
    fs = _field(0.0, z0)
    ref = integ.solve_multirate(_field, z0, (0.0, 1.0), Ks, 8,
                                first_stage=fs)
    carry = make_segment_carry(z0, Ks, (0.0, 1.0), first_stage=fs)
    fin = None
    for _ in range(-(-8 // seg)):
        carry, fin = integ.solve_segment(_field, carry, seg)
    assert bool(jnp.all(fin))
    np.testing.assert_allclose(np.asarray(carry.z), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_solve_segment_refill_midflight_matches_fresh_solve():
    """A slot retired and refilled mid-flight (new z row, k=0, new K)
    integrates its own mesh exactly as a fresh solve would — the
    resumability the scheduler's admit-between-segments relies on."""
    integ = Integrator(get_tableau("euler"), fused=True)
    z0 = jax.random.normal(jax.random.PRNGKey(1), (3, 9))
    carry = make_segment_carry(z0, jnp.asarray([2, 6, 0]), (0.0, 1.0))
    carry, fin = integ.solve_segment(_field, carry, 2)
    assert np.asarray(fin).tolist() == [True, False, True]
    # refill slots 0 (finished) and 2 (was empty) with new requests
    z_new = jax.random.normal(jax.random.PRNGKey(2), (2, 9))
    idx = jnp.asarray([0, 2])
    carry = SegmentCarry(
        z=carry.z.at[idx].set(z_new),
        k=carry.k.at[idx].set(0),
        Ks=carry.Ks.at[idx].set(jnp.asarray([4, 3])),
        eps=carry.eps.at[idx].set(jnp.asarray([0.25, 1.0 / 3.0])),
        first_stage=None)
    for _ in range(3):
        carry, fin = integ.solve_segment(_field, carry, 2)
    assert bool(jnp.all(fin))
    for j, (i, K) in enumerate(((0, 4), (2, 3))):
        ref = integ.solve(_field, z_new[j][None],
                          FixedGrid.over(0.0, 1.0, K), return_traj=False)
        np.testing.assert_allclose(np.asarray(carry.z[i]),
                                   np.asarray(ref[0]), rtol=1e-6, atol=1e-6)


def test_make_segment_carry_empty_slots_stay_inert():
    """Ks == 0 marks an empty slot: frozen state, counter pinned at 0, no
    NaN/inf leaking from the padded eps."""
    integ = Integrator(get_tableau("euler"), fused=True)
    z0 = jnp.ones((3, 4))
    carry = make_segment_carry(z0, jnp.asarray([2, 0, 3]), (0.0, 1.0))
    assert np.all(np.isfinite(np.asarray(carry.eps)))
    carry, fin = integ.solve_segment(_field, carry, 4)
    assert np.asarray(fin).tolist() == [True, True, True]
    np.testing.assert_array_equal(np.asarray(carry.z[1]), np.ones(4))
    assert np.asarray(carry.k).tolist() == [2, 0, 3]


# ---------------------------------------------------- compile accounting ----

def test_one_kernel_trace_per_shape_seg_cell_across_refills():
    """ACCEPTANCE: a full streaming replay — admissions, retirements,
    partial occupancy, every refill pattern the trace produces — traces
    the fused kernel exactly ONCE per (shape, seg) cell."""
    xs = heterogeneous_requests(24, 8, seed=2)
    trace = poisson_trace(xs, rate=0.3, seed=4)
    ecfg = EngineConfig(buckets=(2, 4, 8, 16), tol=5e-3, fused=True)
    sched = InflightScheduler(_toy_model(fused=True), ecfg, slots=4, seg=2)
    before = TRACE_COUNTS["fused_rk_update"]
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        rep = replay_scheduler(sched, trace)
    assert len(rep.records) == 24
    assert TRACE_COUNTS["fused_rk_update"] == before + 1, (
        "occupancy/refill pattern leaked into the segment jit cell")
    # a second shape opens a second (shape, seg) cell — exactly one more
    sched.run(np.zeros((3, 5), np.float32) - 2.0)
    assert TRACE_COUNTS["fused_rk_update"] == before + 2


# -------------------------------------------------- scheduler vs engine ----

def test_scheduler_outputs_and_nfe_match_engine():
    """Same controller + buckets through both loops: request-for-request
    equal K, equal NFE accounting, numerically matching outputs."""
    xs = heterogeneous_requests(18, 8, seed=1)
    ecfg = EngineConfig(buckets=(2, 4, 8, 16), tol=5e-3, max_batch=6)
    res_e = MultiRateEngine(_toy_model(), ecfg).run(xs)
    res_s = InflightScheduler(_toy_model(), ecfg, slots=6, seg=2).run(xs)
    assert [r.uid for r in res_s] == [r.uid for r in res_e]
    for a, b in zip(res_e, res_s):
        assert (a.K, a.nfe) == (b.K, b.nfe)
        np.testing.assert_allclose(a.outputs, b.outputs, rtol=1e-6,
                                   atol=1e-6)


def test_scheduler_fixed_controller_and_hyper_solver_paths():
    ecfg = EngineConfig(buckets=(4,), controller="fixed", fixed_K=4)
    res = InflightScheduler(_toy_model(), ecfg, slots=3, seg=2).run(
        heterogeneous_requests(5, 6, seed=3))
    assert all(r.K == 4 and r.nfe == 4 for r in res)

    hyper = EngineConfig(buckets=(2, 4, 8), tol=1e-1, solver="hyper_euler")
    sched = InflightScheduler(_toy_model(g=lambda e, s, z, dz: 0.3 * z),
                              hyper, slots=4, seg=2)
    res = sched.run(heterogeneous_requests(6, 6, seed=4))
    assert type(sched.controller).__name__ == "HypersolverResidualController"
    assert all(r.nfe == r.K for r in res)  # probe fully reused

    with pytest.raises(ValueError):
        InflightScheduler(_toy_model(), hyper)  # hyper solver needs g


def test_easy_request_escapes_a_busy_pool_early():
    """THE motivating property: a K=2 request admitted while a K=16
    request is mid-flight exits after its own segments instead of
    waiting out the long request (the drain engine cannot do this when
    both land in one batch)."""
    ecfg = EngineConfig(buckets=(2, 16), tol=1e-2, max_batch=2)
    sched = InflightScheduler(_toy_model(), ecfg, slots=2, seg=2)
    hard = np.full((6,), 3.0, np.float32)
    easy = np.full((6,), -2.0, np.float32)
    uid_hard = sched.submit(hard)
    done = sched.step()           # hard admitted, in flight
    assert not done
    uid_easy = sched.submit(easy)
    finished = {}
    while sched.pending:
        for c in sched.step():
            finished[c.uid] = c
    assert finished[uid_hard].K == 16 and finished[uid_easy].K == 2
    assert finished[uid_easy].t_done < finished[uid_hard].t_done
    # and the drain engine, forced to pack them together, cannot:
    eng = MultiRateEngine(_toy_model(), ecfg)
    eng.submit(hard), eng.submit(easy)
    eng.step()
    assert eng.last_report.batches == 1
    assert eng.last_report.finish_offset[1] == eng.last_report.finish_offset[2]


def test_submit_future_t_refused_while_busy_allowed_when_idle():
    """A future-t submit idle-jumps the clock only when nothing is in
    flight; with work pending it is refused — jumping mid-flight would
    bill in-flight requests for time no segment ran."""
    ecfg = EngineConfig(buckets=(2, 4), tol=1e-2)
    sched = InflightScheduler(_toy_model(), ecfg, slots=2, seg=1)
    sched.submit(np.full((4,), 3.0, np.float32), t=5.0)  # idle: jump
    assert sched.now == 5.0
    sched.step()
    assert sched.pending  # K=4 hard request still mid-flight at seg=1
    with pytest.raises(ValueError, match="misattribute"):
        sched.submit(np.full((4,), -2.0, np.float32), t=sched.now + 100.0)
    while sched.pending:
        sched.step()
    assert sched.now < 100.0


def test_scheduler_handles_mixed_shapes_and_queue_overflow():
    """More requests than slots queue up and drain FIFO per shape; a
    second shape gets its own pool without blocking the first."""
    ecfg = EngineConfig(buckets=(2, 4), tol=1e-2)
    sched = InflightScheduler(_toy_model(), ecfg, slots=2, seg=2)
    uids_a = [sched.submit(np.full((3,), -2.0, np.float32))
              for _ in range(5)]
    uid_b = sched.submit(np.full((7,), -2.0, np.float32))
    results = {}
    while sched.pending:
        for c in sched.step():
            results[c.uid] = c
    assert sorted(results) == sorted(uids_a + [uid_b])
    assert results[uid_b].outputs.shape == (7,)
    # FIFO within a shape: earlier submissions never finish after later ones
    admits = [results[u].t_admit for u in uids_a]
    assert admits == sorted(admits)


def test_scheduler_same_shape_mixed_dtypes_get_separate_pools():
    """Same-shape requests of a different dtype open their own pool
    instead of silently casting into the first admission's storage —
    the scheduler's explicit version of jit's dtype retrace boundary."""
    ecfg = EngineConfig(buckets=(2, 4), tol=1e-2)
    sched = InflightScheduler(_toy_model(), ecfg, slots=2, seg=2)
    u32 = sched.submit(np.full((4,), -2.0, np.float32))
    u64 = sched.submit(np.full((4,), -2.25, np.float64))
    results = {}
    while sched.pending:
        for c in sched.step():
            results[c.uid] = c
    assert len(sched._pools) == 2
    # fractional float64 value survived (no truncation through a latched
    # pool dtype); outputs match the engine's on the same lone request
    res_e = MultiRateEngine(_toy_model(), ecfg).run(
        np.full((1, 4), -2.25, np.float64))
    np.testing.assert_allclose(np.asarray(results[u64].outputs, np.float64),
                               np.asarray(res_e[0].outputs, np.float64),
                               rtol=1e-6, atol=1e-6)


# ------------------------------------------------------- hot-swapped g ----

@pytest.mark.parametrize("overlap", [False, True],
                         ids=["sync", "overlap"])
def test_hot_swap_g_mid_flight_no_retrace(overlap):
    """ACCEPTANCE (PR 9): swapping correction params into a BUSY pool —
    sync and overlap loops — compiles nothing (TRACE_COUNTS frozen: the
    params are traced cell inputs), drains nothing, and is LIVE
    (post-swap completions differ from a never-swapped replay)."""
    from repro.launch.workload import toy_refinable_classifier

    ecfg = EngineConfig(controller="fixed", fixed_K=4, buckets=(4,))
    xs = heterogeneous_requests(16, 8, seed=5)
    trace = poisson_trace(xs, rate=0.25, seed=6)
    new_gp = jax.tree_util.tree_map(
        lambda l: l + 0.5, toy_refinable_classifier(d=8).g_params)

    def run(swap):
        sched = InflightScheduler(toy_refinable_classifier(d=8), ecfg,
                                  slots=4, seg=1, overlap=overlap)
        state = {"tick": 0, "before": None}

        def on_tick(s):
            state["tick"] += 1
            if swap and state["tick"] == 3:
                assert s.pending, "swap must land on a busy pool"
                state["before"] = TRACE_COUNTS["fused_rk_update"]
                s.hot_swap_g(new_gp)

        rep = replay_scheduler(sched, trace, on_tick=on_tick)
        if swap:
            assert state["before"] is not None
            assert TRACE_COUNTS["fused_rk_update"] == state["before"], (
                "hot_swap_g retraced a pool cell — params stopped being "
                "traced inputs")
        assert len(rep.records) == 16
        return {r.uid: r.outputs for r in rep.records}

    plain, swapped = run(False), run(True)
    assert set(plain) == set(swapped)
    assert any(not np.array_equal(plain[u], swapped[u]) for u in plain), (
        "swapped params never reached the pool cells")


# ------------------------------------------------- sharded slot pools ----

_SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import warnings
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import Integrator, get_tableau, make_segment_carry
    from repro.kernels.hyper_step.ops import TRACE_COUNTS
    from repro.launch.engine import DepthModel, EngineConfig
    from repro.launch.mesh import make_serving_mesh
    from repro.launch.scheduler import InflightScheduler
    from repro.launch.workload import (
        heterogeneous_requests, latency_stats, poisson_trace,
        replay_scheduler,
    )

    assert jax.device_count() == 4, jax.device_count()
    mesh = make_serving_mesh(4)
    f = lambda s, z: -z * jax.nn.softplus(jnp.mean(z, -1, keepdims=True))
    G = lambda eps, s, z, dz: 0.25 * z + 0.1 * dz

    # ACCEPTANCE: solve_segment(mesh=) segment-by-segment == one
    # solve_multirate call, fp32, with and without a correction
    for g in (None, G):
        integ = Integrator(get_tableau("heun"), g=g, fused=True)
        z0 = jax.random.normal(jax.random.PRNGKey(0), (8, 17))
        Ks = jnp.asarray([1, 2, 5, 8, 3, 4, 8, 2], jnp.int32)
        fs = f(0.0, z0)
        ref = integ.solve_multirate(f, z0, (0.0, 1.0), Ks, 8,
                                    first_stage=fs)
        carry = make_segment_carry(z0, Ks, (0.0, 1.0), first_stage=fs)
        fin = None
        for _ in range(4):
            carry, fin = integ.solve_segment(f, carry, 2, mesh=mesh)
        assert bool(jnp.all(fin))
        np.testing.assert_allclose(np.asarray(carry.z), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)
    print("SHARDED_SEGMENT_PARITY_OK")

    # a slot count the mesh axis cannot split raises the clear error
    bad = make_segment_carry(jnp.ones((6, 4)), jnp.asarray([2] * 6),
                             (0.0, 1.0))
    try:
        Integrator(get_tableau("euler")).solve_segment(f, bad, 2,
                                                       mesh=mesh)
    except ValueError as e:
        assert "does not divide" in str(e), e
        print("SHARDED_SEGMENT_DIVISIBILITY_OK")

    # sharded pool replay == single-device pool replay, request for
    # request, and ONE fused-kernel trace for the (shape, seg, mesh)
    # cell across every refill pattern the trace produces
    def field_of(x):
        k = jax.nn.softplus(jnp.mean(x, axis=-1, keepdims=True))
        return lambda s, z: -z * k

    def model():
        return DepthModel(
            embed=lambda x: x + 0.0, field_of=field_of,
            readout=lambda x, zT: zT,
            integ=Integrator(get_tableau("euler"), fused=True))

    ecfg = EngineConfig(buckets=(2, 4, 8, 16), tol=5e-3, fused=True)
    xs = heterogeneous_requests(24, 8, seed=2)
    trace = poisson_trace(xs, rate=0.5, seed=4)
    rep_1 = replay_scheduler(
        InflightScheduler(model(), ecfg, slots=8, seg=2), trace)
    before = TRACE_COUNTS["fused_rk_update"]
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        rep_4 = replay_scheduler(
            InflightScheduler(model(), ecfg, slots=8, seg=2, mesh=mesh),
            trace)
    assert TRACE_COUNTS["fused_rk_update"] == before + 1, (
        "refill pattern leaked into the sharded (shape, seg, mesh) cell")
    assert len(rep_4.records) == 24
    out_1 = {r.uid: r for r in rep_1.records}
    for r in rep_4.records:
        assert r.K == out_1[r.uid].K
        assert r.nfe == out_1[r.uid].nfe
        np.testing.assert_allclose(r.outputs, out_1[r.uid].outputs,
                                   rtol=1e-6, atol=1e-6)
    # equal global slots -> the virtual clock ticks identically
    s1, s4 = latency_stats(rep_1), latency_stats(rep_4)
    assert s1 == s4, (s1, s4)
    print("SHARDED_POOL_REPLAY_OK")

    # the pipelined overlap loop on the SAME forced mesh: completions
    # uid-for-uid bitwise identical to the sync sharded replay
    rep_o = replay_scheduler(
        InflightScheduler(model(), ecfg, slots=8, seg=2, mesh=mesh,
                          overlap=True), trace)
    assert len(rep_o.records) == 24
    out_4 = {r.uid: r for r in rep_4.records}
    for r in rep_o.records:
        ref = out_4[r.uid]
        assert r.K == ref.K and r.nfe == ref.nfe
        assert (r.t_submit, r.t_admit, r.t_done) == (
            ref.t_submit, ref.t_admit, ref.t_done)
        assert np.array_equal(np.asarray(r.outputs),
                              np.asarray(ref.outputs))
    assert latency_stats(rep_o) == s4
    print("SHARDED_OVERLAP_PARITY_OK")

    # hot-swapping correction params into a BUSY sharded pool (sync and
    # overlap) compiles nothing and is live — the params-are-inputs
    # invariant holds per (shape, seg, mesh) cell too
    from repro.launch.workload import toy_refinable_classifier

    pecfg = EngineConfig(controller="fixed", fixed_K=4, buckets=(4,))
    pxs = heterogeneous_requests(16, 8, seed=5)
    ptrace = poisson_trace(pxs, rate=0.25, seed=6)
    new_gp = jax.tree_util.tree_map(
        lambda l: l + 0.5, toy_refinable_classifier(d=8).g_params)

    def hot_run(swap, overlap):
        sched = InflightScheduler(toy_refinable_classifier(d=8), pecfg,
                                  slots=8, seg=1, mesh=mesh,
                                  overlap=overlap)
        state = {"tick": 0, "before": None}

        def on_tick(s):
            state["tick"] += 1
            if swap and state["tick"] == 3:
                assert s.pending
                state["before"] = TRACE_COUNTS["fused_rk_update"]
                s.hot_swap_g(new_gp)

        rep = replay_scheduler(sched, ptrace, on_tick=on_tick)
        if swap:
            assert state["before"] is not None
            assert TRACE_COUNTS["fused_rk_update"] == state["before"], (
                "hot_swap_g retraced a sharded pool cell")
        assert len(rep.records) == 16
        return {r.uid: r.outputs for r in rep.records}

    for overlap in (False, True):
        plain = hot_run(False, overlap)
        swapped = hot_run(True, overlap)
        assert set(plain) == set(swapped)
        assert any(not np.array_equal(plain[u], swapped[u])
                   for u in plain)
    print("SHARDED_HOTSWAP_NO_RETRACE_OK")
""")


def test_sharded_slot_pool_debug_mesh_subprocess():
    """ACCEPTANCE: on a forced 4-device CPU mesh, ``solve_segment(mesh=)``
    keeps exact parity with ``solve_multirate``, the sharded scheduler
    reproduces the single-device replay request-for-request, one kernel
    trace serves the (shape, seg, mesh) cell across refills, and the
    indivisible slot count raises the clear error (subprocess — the main
    test process keeps one device)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600,
                          cwd=REPO_ROOT)
    out = proc.stdout + proc.stderr
    assert proc.returncode == 0, out[-4000:]
    for marker in ("SHARDED_SEGMENT_PARITY_OK",
                   "SHARDED_SEGMENT_DIVISIBILITY_OK",
                   "SHARDED_POOL_REPLAY_OK",
                   "SHARDED_OVERLAP_PARITY_OK",
                   "SHARDED_HOTSWAP_NO_RETRACE_OK"):
        assert marker in out, (marker, out[-4000:])


# ---------------------------------------------------------- workloads ----

def test_trace_generators_are_seeded_and_ordered():
    xs = heterogeneous_requests(12, 4, seed=0)
    t1 = poisson_trace(xs, rate=0.5, seed=7)
    t2 = poisson_trace(xs, rate=0.5, seed=7)
    assert [a.t for a in t1] == [a.t for a in t2]
    assert all(a.t <= b.t for a, b in zip(t1, t1[1:]))
    tb = bursty_trace(xs, burst=4, gap=10.0, seed=7)
    assert len(tb) == 12
    assert len({round(a.t, 6) for a in tb}) == 3  # 3 bursts, zero `within`
    with pytest.raises(ValueError):
        poisson_trace(xs, rate=0.0)


def test_heterogeneous_requests_difficulty_split():
    xs = heterogeneous_requests(10, 4, seed=0, interleave=False)
    assert xs.shape == (10, 4) and xs.dtype == np.float32
    assert xs[:5].mean() < -1.5 < 1.5 < xs[5:].mean()


def test_replay_accounting_invariants():
    """Both replays conserve requests and keep a sane time ordering:
    submit <= admit <= done per record; waste = total - useful >= 0."""
    xs = heterogeneous_requests(16, 6, seed=5)
    trace = poisson_trace(xs, rate=0.3, seed=6)
    ecfg = EngineConfig(buckets=(2, 4, 8), tol=5e-3, max_batch=4)
    rep_e = replay_engine(MultiRateEngine(_toy_model(), ecfg), trace)
    rep_s = replay_scheduler(
        InflightScheduler(_toy_model(), ecfg, slots=4, seg=2), trace)
    for rep in (rep_e, rep_s):
        assert len(rep.records) == 16
        for r in rep.records:
            assert r.t_submit <= r.t_admit <= r.t_done
        assert rep.waste_steps >= 0
        assert rep.useful_steps == sum(r.K for r in rep.records)
        stats = latency_stats(rep)
        for key in ("p50_latency", "p99_latency", "p99_queue_wait",
                    "throughput", "waste_steps", "waste_frac"):
            assert key in stats
        assert stats["p50_latency"] <= stats["p99_latency"]
    # identical traffic + policy -> identical outputs across the loops
    out_e = {r.uid: r.outputs for r in rep_e.records}
    for r in rep_s.records:
        np.testing.assert_allclose(r.outputs, out_e[r.uid], rtol=1e-6,
                                   atol=1e-6)


# --------------------------------------------------------- cost oracle ----

def test_pool_completions_stamped_with_own_cost_only():
    """BUGFIX pin: pools are concurrent cells, so a completion is stamped
    with only ITS pool's probe + segment cost — per-request latency must
    not depend on (shape, dtype) key insertion order. The pre-fix clock
    accumulated segment cost across pools in dict-iteration order,
    billing the second-iterated pool's completion 2x the first's."""
    ecfg = EngineConfig(buckets=(2,), controller="fixed", fixed_K=2)
    for order in ((3, 5), (5, 3)):
        sched = InflightScheduler(_toy_model(), ecfg, slots=2, seg=2)
        for d in order:
            sched.submit(np.full((d,), -2.0, np.float32))
        done = sched.step()        # K=2 completes within one seg=2 tick
        assert len(done) == 2
        # euler stages=1, seg=2, no probe under the fixed controller:
        # each pool's own cumulative cost this tick is exactly 2.0
        assert [c.t_done for c in done] == [2.0, 2.0], (order, done)
        # the tick's resource ledger still sums BOTH pools' segments
        assert sched.total_cost == 4.0


def test_drain_occupancy_invariant():
    """BUGFIX pin: drain-engine occupancy is 1.0 by construction — both
    for replay_engine's reports and for a TraceReport built WITHOUT
    occupied_steps (the old default of 0 reported 0.0)."""
    xs = heterogeneous_requests(8, 6, seed=5)
    rep = replay_engine(
        MultiRateEngine(_toy_model(), EngineConfig(buckets=(2, 4, 8),
                                                   tol=5e-3)),
        poisson_trace(xs, rate=0.3, seed=6))
    assert rep.total_steps > 0 and rep.occupancy == 1.0
    bare = TraceReport(records=rep.records, total_cost=1.0, probe_cost=0.0,
                       useful_steps=3, total_steps=4, makespan=1.0)
    assert bare.occupancy == 1.0
    assert latency_stats(bare)["occupancy"] == 1.0
    # an explicitly-counted pool report still reports its true fraction
    part = TraceReport(records=rep.records, total_cost=1.0, probe_cost=0.0,
                       useful_steps=3, total_steps=4, makespan=1.0,
                       occupied_steps=2)
    assert part.occupancy == 0.5


def test_sequential_oracle_is_a_pure_relabel():
    """Explicitly passing SequentialEvalOracle reproduces the default
    clock bit-for-bit through BOTH replay drivers (the oracle refactor
    did not change the default path's numbers)."""
    xs = heterogeneous_requests(20, 6, seed=5)
    trace = poisson_trace(xs, rate=0.3, seed=6)
    ecfg = EngineConfig(buckets=(2, 4, 8), tol=5e-3, max_batch=4)
    base = latency_stats(replay_scheduler(
        InflightScheduler(_toy_model(), ecfg, slots=4, seg=2), trace))
    expl = latency_stats(replay_scheduler(
        InflightScheduler(_toy_model(), ecfg, slots=4, seg=2,
                          oracle=SequentialEvalOracle()), trace))
    assert base == expl
    base_e = latency_stats(replay_engine(
        MultiRateEngine(_toy_model(), ecfg), trace))
    expl_e = latency_stats(replay_engine(
        MultiRateEngine(_toy_model(), ecfg,
                        oracle=SequentialEvalOracle()), trace))
    assert base_e == expl_e
    assert base["cost_unit"] == base_e["cost_unit"] == "sequential_evals"


def test_sequential_oracle_reproduces_bench_scheduler_numbers():
    """ACCEPTANCE: replaying bench_scheduler's seeded poisson_seed3 trace
    under an explicit SequentialEvalOracle reproduces the committed
    BENCH_scheduler.json inflight row bit-for-bit — the committed
    sequential section is exactly what the default clock produces."""
    import json
    if REPO_ROOT not in sys.path:
        sys.path.insert(0, REPO_ROOT)
    from benchmarks.bench_scheduler import D_FEAT, toy_classifier
    with open(os.path.join(REPO_ROOT, "BENCH_scheduler.json")) as fh:
        rows = json.load(fh)
    row = next(r for r in rows if r.get("mode") == "inflight"
               and r.get("trace") == "poisson_seed3")
    xs = heterogeneous_requests(int(row["requests"]), D_FEAT, seed=3)
    trace = poisson_trace(xs, rate=0.25, seed=103)
    ecfg = EngineConfig(buckets=(2, 4, 8, 16), tol=5e-3, max_batch=8,
                        solver="euler", fused=True)
    sched = InflightScheduler(toy_classifier("euler"), ecfg,
                              slots=int(row["slots"]), seg=int(row["seg"]),
                              oracle=SequentialEvalOracle())
    stats = latency_stats(replay_scheduler(sched, trace))
    for key, val in stats.items():
        assert row[key] == val, (key, row[key], val)


def test_roofline_oracle_prices_seg_and_width():
    """The roofline oracle prices seg=2*s strictly above seg=s for a busy
    pool, and pool width is PRICED (sublinearly — weight reads amortize
    across rows) where the sequential clock gives it away for free."""
    from repro.configs import get
    o = RooflineOracle(get("qwen3_8b"), ctx=4096)
    shape = (32,)
    for s in (1, 2, 4):
        assert o.segment_cost(shape, 2 * s, 8, 1) \
            > o.segment_cost(shape, s, 8, 1)
    t8, t16 = o.step_time(8), o.step_time(16)
    assert t8 < t16 < 2 * t8        # priced, but sublinear
    assert o.probe_cost(shape, 8, 2) == 2 * t8
    seq = SequentialEvalOracle()
    assert seq.segment_cost(shape, 2, 8, 1) \
        == seq.segment_cost(shape, 2, 9999, 1)   # width-free by design


def test_roofline_oracle_replay_stamps_device_us():
    """An end-to-end replay on the roofline clock: same policy decisions
    as the sequential clock (K/NFE are clock-independent), ledgers and
    stats tagged device_us."""
    from repro.configs import get
    o = RooflineOracle(get("qwen3_8b"), ctx=4096)
    ecfg = EngineConfig(buckets=(2, 4, 8), tol=5e-3, max_batch=4)
    xs = heterogeneous_requests(12, 6, seed=5)
    # same relative load as the sequential replay: rate converts by the
    # pool's per-step price, so admission dynamics are congruent
    t_seq = poisson_trace(xs, rate=0.3, seed=6)
    t_us = poisson_trace(xs, rate=0.3 / o.step_time(4), seed=6)
    rep_seq = replay_scheduler(
        InflightScheduler(_toy_model(), ecfg, slots=4, seg=2), t_seq)
    rep_us = replay_scheduler(
        InflightScheduler(_toy_model(), ecfg, slots=4, seg=2, oracle=o),
        t_us)
    assert rep_us.cost_unit == "device_us"
    assert latency_stats(rep_us)["cost_unit"] == "device_us"
    # policy (which K each request gets) does not depend on the clock
    k_seq = {r.uid: r.K for r in rep_seq.records}
    assert {r.uid: r.K for r in rep_us.records} == k_seq
    # step COUNTS are clock-independent; COSTS scale with the step price
    assert rep_us.useful_steps == rep_seq.useful_steps
    assert rep_us.total_cost > rep_seq.total_cost


def test_autotune_cell_structure_and_hillclimb_keeps_best():
    """The knob autotuner returns a persisted-shape verdict: chosen knobs
    are JSON-clean, the hillclimb log carries verdicts, and the tuned
    score never regresses the baseline (hypothesis_loop keeps only
    CONFIRMED changes)."""
    from repro.launch.autotune import autotune_cell
    res = autotune_cell({"cell": "t4k", "arch": "qwen3_8b", "ctx": 4096},
                        budget="tiny",
                        steps=[("slots 8->16", "wider pool under load",
                                {"slots": 16})])
    assert res["mode"] == "tuner" and res["cost_unit"] == "device_us"
    assert set(res["chosen"]) == {"seg", "slots", "buckets"}
    assert res["p99_tuned"] <= res["p99_base"]
    verdicts = [r["verdict"] for r in res["log"][1:]]
    assert all(v == "CONFIRMED" or v.startswith("REFUTED")
               for v in verdicts)
    confirmed = [r["change"] for r in res["log"][1:]
                 if r["verdict"] == "CONFIRMED"]
    assert res["confirmed"] == confirmed
    assert (res["chosen"]["slots"] == 16) == ("slots 8->16" in confirmed)


# --------------------------------------------------------- BENCH schema ----

def _load_bench_run():
    if REPO_ROOT not in sys.path:
        sys.path.insert(0, REPO_ROOT)
    import benchmarks.run as bench_run
    return bench_run


def test_bench_schema_check_passes_on_committed_files():
    """benchmarks/run.py --check (the tier-1 CI gate) passes on the
    committed BENCH_*.json trajectory files."""
    bench_run = _load_bench_run()
    assert bench_run.check_bench_files(REPO_ROOT) == []


def test_bench_schema_check_catches_malformed_files(tmp_path):
    bench_run = _load_bench_run()
    errs = bench_run.check_bench_files(str(tmp_path))
    assert len(errs) == len(bench_run.BENCH_REQUIRED)  # all missing
    (tmp_path / "BENCH_kernels.json").write_text("{not json")
    (tmp_path / "BENCH_serve.json").write_text("[]")
    (tmp_path / "BENCH_scheduler.json").write_text(
        '[{"bench": "scheduler", "p99_latency": 1, "waste_steps": 0}]')
    errs = bench_run.check_bench_files(str(tmp_path))
    assert any("malformed" in e for e in errs)
    assert any("non-empty" in e for e in errs)
    assert any("verdict" in e for e in errs)
    # a corrupted scheduler file is an error STRING, not a crash
    (tmp_path / "BENCH_scheduler.json").write_text("{not json")
    errs = bench_run.check_bench_files(str(tmp_path))
    assert any("BENCH_scheduler.json" in e and "malformed" in e
               for e in errs)


# ------------------------------------------- overlap + donated carries ----

def test_overlap_replay_uid_for_uid_identical_to_sync():
    """ACCEPTANCE: the pipelined ``overlap=True`` loop replays a seeded
    Poisson trace uid-for-uid identical to the synchronous loop —
    bitwise-equal outputs, same K/nfe/segments, same virtual-clock
    stamps, same latency summary. The sync path is the oracle the
    pipeline is pinned against."""
    ecfg = EngineConfig(buckets=(2, 4, 8, 16), tol=5e-3, max_batch=8,
                        fused=True)
    xs = heterogeneous_requests(24, 8, seed=2)
    trace = poisson_trace(xs, rate=0.3, seed=4)
    rep_s = replay_scheduler(
        InflightScheduler(_toy_model(fused=True), ecfg, slots=4, seg=2),
        trace)
    rep_o = replay_scheduler(
        InflightScheduler(_toy_model(fused=True), ecfg, slots=4, seg=2,
                          overlap=True), trace)
    assert len(rep_o.records) == len(rep_s.records) == 24
    sync = {r.uid: r for r in rep_s.records}
    for r in rep_o.records:
        ref = sync[r.uid]
        assert (r.K, r.nfe) == (ref.K, ref.nfe)
        assert (r.t_submit, r.t_admit, r.t_done) == (
            ref.t_submit, ref.t_admit, ref.t_done)
        assert np.array_equal(np.asarray(r.outputs),
                              np.asarray(ref.outputs))
    assert latency_stats(rep_o) == latency_stats(rep_s)


def test_overlap_one_segment_retire_lag_and_cost_parity():
    """The overlap tick retires one segment LATE: completions finishing
    in segment N surface from step N+1 (launch first, read flags next
    tick), with the same per-pool cost stamps and ledger totals as the
    sync multi-pool pin above."""
    ecfg = EngineConfig(buckets=(2,), controller="fixed", fixed_K=2)
    sched = InflightScheduler(_toy_model(), ecfg, slots=2, seg=2,
                              overlap=True)
    for d in (3, 5):
        sched.submit(np.full((d,), -2.0, np.float32))
    assert sched.step() == []         # segments in flight, flags unread
    done = sched.step()               # lagged retire surfaces both
    assert len(done) == 2
    assert [c.t_done for c in done] == [2.0, 2.0]
    assert sched.total_cost == 4.0
    assert not sched.pending


def test_overlap_requires_multicore_host_is_documented():
    """The donate auto-default is platform-aware: off on the CPU client
    (where a donating call dispatches synchronously and would serialize
    the pipeline at launch), forced values win either way."""
    ecfg = EngineConfig(buckets=(2,), controller="fixed", fixed_K=2)
    assert InflightScheduler(_toy_model(), ecfg).donate is (
        jax.default_backend() != "cpu")
    assert InflightScheduler(_toy_model(), ecfg, donate=True).donate
    assert not InflightScheduler(_toy_model(), ecfg, donate=False).donate


def test_segment_cell_donates_carry_buffers():
    """ACCEPTANCE: the compiled segment cell reports the pool-sized
    carry buffers (z, first_stage) as donated — input/output aliasing
    in the compiled memory analysis, donated inputs deleted after the
    call, conditioning rows untouched."""
    m = _toy_model(fused=True)
    cell = m.integ.segment_cell(m.field_of, seg=2, donate=True)
    B, d = 4, 16
    xs = jnp.zeros((B, d), jnp.float32)
    z = jnp.ones((B, d), jnp.float32)
    fs = jnp.zeros((B, d), jnp.float32)
    k = jnp.zeros((B,), jnp.int32)
    Ks = jnp.full((B,), 4, jnp.int32)
    eps = jnp.full((B,), 0.25, jnp.float32)
    compiled = cell.lower(xs, z, k, Ks, eps, fs).compile()
    assert "input_output_alias" in compiled.as_text()
    mem = compiled.memory_analysis()
    assert mem.alias_size_in_bytes >= z.nbytes + fs.nbytes, (
        mem.alias_size_in_bytes)
    z2, fs2, meta = cell(xs, z, k, Ks, eps, fs)
    assert z.is_deleted() and fs.is_deleted()
    assert not xs.is_deleted()
    meta = np.array(meta)
    assert meta.shape == (3, B) and meta.dtype == np.int32
    np.testing.assert_array_equal(meta[0], [2, 2, 2, 2])   # k' after seg=2
    np.testing.assert_array_equal(meta[1], [0, 0, 0, 0])   # K=4 unfinished
    np.testing.assert_array_equal(meta[2], [0, 0, 0, 0])   # all finite


def test_retire_readout_gated_to_finished_rows():
    """BUGFIX pin: retirement reads out only the FINISHED rows (padded
    to a pow2 cell), never the full pool — the readout jit is traced at
    sub-pool widths and the pool records exactly those widths."""
    traced = []

    def readout(x, zT):
        traced.append(zT.shape[0])    # runs at TRACE time only
        return zT

    base = _toy_model(fused=True)
    model = DepthModel(embed=base.embed, field_of=base.field_of,
                       readout=readout, integ=base.integ)
    ecfg = EngineConfig(buckets=(2, 4, 8, 16), tol=5e-3, max_batch=8,
                        fused=True)
    xs = heterogeneous_requests(24, 8, seed=2)
    sched = InflightScheduler(model, ecfg, slots=8, seg=2)
    rep = replay_scheduler(sched, poisson_trace(xs, rate=0.5, seed=4))
    assert len(rep.records) == 24
    pool = next(iter(sched._pools.values()))
    assert traced, "readout never traced"
    assert set(traced) == pool._readout_widths
    # a streaming trace retires stragglers in sub-pool batches: the
    # pre-fix full-pool readout would have traced ONLY width 8
    assert min(traced) < sched.slots, traced
    assert all(w <= sched.slots and w & (w - 1) == 0 for w in traced)


# ------------------------------------------------------- tier-2 sweep ----

@pytest.mark.slow
def test_scheduler_seg_slots_sweep_parity_and_latency():
    """Tier-2: across (seg, slots) configs on a longer Poisson trace, the
    scheduler keeps exact policy parity with the engine and its mean
    queue wait stays at-or-below the drain loop's."""
    xs = heterogeneous_requests(64, 8, seed=8)
    trace = poisson_trace(xs, rate=0.25, seed=9)
    ecfg = EngineConfig(buckets=(2, 4, 8, 16), tol=5e-3, max_batch=8,
                        fused=True)
    rep_e = replay_engine(MultiRateEngine(_toy_model(fused=True), ecfg),
                          trace)
    out_e = {r.uid: r for r in rep_e.records}
    wait_e = np.mean([r.queue_wait for r in rep_e.records])
    for seg in (1, 2, 4):
        for slots in (4, 8):
            sched = InflightScheduler(_toy_model(fused=True), ecfg,
                                      slots=slots, seg=seg)
            rep_s = replay_scheduler(sched, trace)
            assert len(rep_s.records) == 64
            for r in rep_s.records:
                assert r.K == out_e[r.uid].K
                np.testing.assert_allclose(r.outputs, out_e[r.uid].outputs,
                                           rtol=1e-6, atol=1e-6)
            if slots == 8:
                wait_s = np.mean([r.queue_wait for r in rep_s.records])
                assert wait_s <= wait_e, (seg, slots, wait_s, wait_e)
