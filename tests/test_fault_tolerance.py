"""End-to-end fault tolerance: a training run with injected failures must
resume from checkpoints and converge to the same trajectory as an
uninterrupted run (bitwise-identical data stream via skip-ahead)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get
from repro.data import token_batches
from repro.distributed.fault import FailureInjector, StepWatchdog, WatchdogConfig
from repro.launch.steps import StepSettings
from repro.launch.train import train_loop


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def _batches(cfg, n=None):
    def gen():
        it = token_batches(cfg.vocab, 4, 32, seed=5)
        for t, y in it:
            yield {"tokens": t, "targets": y}
    return gen()


class _Replayable:
    """iterable whose iter() restarts the deterministic stream."""

    def __init__(self, cfg):
        self.cfg = cfg

    def __iter__(self):
        it = token_batches(self.cfg.vocab, 4, 32, seed=5)
        return ({"tokens": t, "targets": y} for t, y in it)


@pytest.fixture(scope="module")
def setup():
    cfg = get("qwen3_4b").reduced()
    settings = StepSettings(microbatches=1, remat="none", zero_opt=False,
                            lr=1e-3)
    return cfg, settings


@pytest.mark.slow  # three full train loops with restarts: long-JIT
def test_recovers_from_injected_failures(tmp_path, setup):
    cfg, settings = setup
    mesh = _mesh()
    # uninterrupted baseline
    _, _, hist_ref = train_loop(cfg, settings, mesh, steps=12,
                                batch_iter=_Replayable(cfg), ckpt=None)
    # failure-injected run with checkpoints every 4 steps
    ckpt = CheckpointManager(str(tmp_path / "ft"), keep=3)
    inj = FailureInjector(fail_at=(6, 9))
    wd = StepWatchdog(WatchdogConfig(max_restarts=5))
    _, _, hist = train_loop(cfg, settings, mesh, steps=12,
                            batch_iter=_Replayable(cfg), ckpt=ckpt,
                            ckpt_every=4, injector=inj, watchdog=wd)
    assert wd.restarts == 2
    ref = {h["step"]: h["loss"] for h in hist_ref}
    got = {h["step"]: h["loss"] for h in hist}
    assert set(got) == set(ref)
    for s in ref:
        np.testing.assert_allclose(got[s], ref[s], rtol=1e-4), s


def test_restart_budget_exhausted_raises(tmp_path, setup):
    cfg, settings = setup
    from repro.distributed.fault import StepFailure
    ckpt = CheckpointManager(str(tmp_path / "budget"), keep=2)
    inj = FailureInjector(fail_at=(2,))

    class AlwaysFail(FailureInjector):
        def maybe_fail(self, step):
            if step == 2:
                raise StepFailure("permanent")

    wd = StepWatchdog(WatchdogConfig(max_restarts=2))
    with pytest.raises(StepFailure):
        train_loop(cfg, settings, _mesh(), steps=5,
                   batch_iter=_Replayable(cfg), ckpt=ckpt, ckpt_every=1,
                   injector=AlwaysFail(), watchdog=wd)


def test_elastic_restore_across_mesh_shapes(tmp_path, setup):
    """Checkpoint written under one mesh restores under another (here 1x1
    CPU both ways, exercising the device_put-per-leaf path; on hardware the
    same call re-shards 512->256)."""
    cfg, settings = setup
    mesh = _mesh()
    ckpt = CheckpointManager(str(tmp_path / "elastic"), keep=2)
    _, _, h1 = train_loop(cfg, settings, mesh, steps=4,
                          batch_iter=_Replayable(cfg), ckpt=ckpt,
                          ckpt_every=2)
    # "new cluster": fresh mesh + loop resuming from the checkpoint
    mesh2 = _mesh()
    _, _, h2 = train_loop(cfg, settings, mesh2, steps=8,
                          batch_iter=_Replayable(cfg), ckpt=ckpt,
                          ckpt_every=4)
    assert h2[0]["step"] == 4  # resumed, not restarted
    assert len(h2) == 4
