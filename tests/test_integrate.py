"""Unified Integrator engine: convergence orders, equivalence with the
legacy odeint paths, batched per-sample step sizes, vmap/jit/checkpoint
composition, and the fused Pallas update path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    EULER, HEUN, MIDPOINT, RK4, FixedGrid, HyperSolver, Integrator,
    as_integrator, depth_like, get_tableau, odeint_fixed,
)

# x64 enabled per-module via tests/conftest.py

# numpy constant: module import happens with x64 OFF (conftest.py)
A = np.array([[-0.5, -2.0], [2.0, -0.5]], dtype=np.float64)


def _expm(M):
    w, V = np.linalg.eig(np.asarray(M))
    return (V @ np.diag(np.exp(w)) @ np.linalg.inv(V)).real


def linear_field(s, z):
    return z @ A.T


# ------------------------------------------------------ convergence order ----

@pytest.mark.parametrize(
    "tab,expected_order",
    [(EULER, 1), (MIDPOINT, 2), (HEUN, 2), (RK4, 4)],
)
def test_engine_global_order(tab, expected_order):
    """Global error of Integrator.solve scales ~ eps^p on an analytic field:
    Euler O(eps), Midpoint/Heun O(eps^2), RK4 O(eps^4)."""
    z0 = jnp.array([[1.0, 0.5]], dtype=jnp.float64)
    exact = jnp.asarray(z0 @ _expm(A).T)
    integ = Integrator(tableau=tab)
    Ks = [8, 16, 32, 64]
    errs = []
    for K in Ks:
        zT = integ.solve(linear_field, z0, FixedGrid.over(0.0, 1.0, K),
                         return_traj=False)
        errs.append(float(jnp.linalg.norm(zT - exact)))
    slopes = np.diff(np.log(errs)) / np.diff(np.log([1.0 / k for k in Ks]))
    assert np.mean(slopes) > expected_order - 0.35, (errs, slopes)


# ------------------------------------------------- legacy-path equivalence ----

def test_matches_python_loop():
    """The scan walk == an explicit python-loop RK walk, bitwise-ish."""
    f = lambda s, z: -0.7 * z + jnp.sin(s)
    z0 = jnp.asarray([1.0, -2.0], jnp.float64)
    grid = FixedGrid.over(0.0, 1.0, 9)
    integ = Integrator(tableau=HEUN)
    traj = integ.solve(f, z0, grid, return_traj=True)
    z = z0
    for k in range(grid.K):
        s = grid.s0 + k * grid.eps
        z, _, _ = integ.step(f, s, grid.eps, z)
    np.testing.assert_allclose(np.asarray(traj[-1]), np.asarray(z),
                               rtol=1e-12)


def test_equivalence_odeint_fixed_pytree():
    """Integrator.solve == odeint_fixed on a tuple (CNF-style) state."""
    def f(s, state):
        z, logp = state
        return (-z, -jnp.sum(z, axis=-1))

    state0 = (jnp.ones((3, 2), jnp.float64), jnp.zeros((3,), jnp.float64))
    grid = FixedGrid.over(0.0, 1.0, 6)
    a = odeint_fixed(f, state0, grid, RK4, return_traj=True)
    b = Integrator(tableau=RK4).solve(f, state0, grid, return_traj=True)
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # analytic check: z decays to e^{-1}
    np.testing.assert_allclose(np.asarray(b[0][-1]),
                               np.exp(-1.0) * np.ones((3, 2)), rtol=1e-5)


def test_equivalence_hypersolver_odeint():
    """HyperSolver.odeint (the legacy entry point) == Integrator.solve with
    the same correction, on a pytree state."""
    def f(s, state):
        z, aux = state
        return (jnp.tanh(z), -aux)

    g = lambda eps, s, state, dstate: (0.2 * state[0], 0.1 * state[1])
    state0 = (jnp.array([[0.3, -1.1]], jnp.float64),
              jnp.ones((1,), jnp.float64))
    grid = FixedGrid.over(0.0, 1.0, 5)
    hs = HyperSolver(tableau=MIDPOINT, g=g)
    a = hs.odeint(f, state0, grid, return_traj=False)
    b = Integrator(tableau=MIDPOINT, g=g).solve(f, state0, grid,
                                                return_traj=False)
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_terminal_matches_trajectory_tail():
    f = lambda s, z: -z
    z0 = jnp.ones((4, 3), jnp.float64)
    grid = FixedGrid.over(0.0, 1.0, 7)
    integ = Integrator(tableau=RK4)
    traj = integ.solve(f, z0, grid, return_traj=True)
    zT = integ.solve(f, z0, grid, return_traj=False)
    assert traj.shape == (8, 4, 3)
    np.testing.assert_array_equal(np.asarray(traj[-1]), np.asarray(zT))
    np.testing.assert_array_equal(np.asarray(traj[0]), np.asarray(z0))


# ------------------------------------------------------ batched step sizes ----

def test_batched_eps_matches_per_sample_solves():
    """grid.eps with a leading batch axis == stacking per-sample scalar-eps
    solves (multi-rate serving: each row integrates its own mesh)."""
    f = lambda s, z: -z + depth_like(jnp.sin(s), z)
    z0 = jnp.asarray(np.random.RandomState(0).randn(4, 3))
    eps = jnp.asarray([0.1, 0.125, 0.2, 0.05], jnp.float64)
    integ = Integrator(tableau=RK4)
    zb = integ.solve(f, z0, FixedGrid(0.0, eps, 8), return_traj=False)
    for i in range(4):
        zi = integ.solve(f, z0[i:i + 1],
                         FixedGrid(0.0, float(eps[i]), 8),
                         return_traj=False)
        np.testing.assert_allclose(np.asarray(zb[i]), np.asarray(zi[0]),
                                   rtol=1e-10)


def test_vmap_over_state_and_eps():
    """The engine composes with vmap over (z0, eps) — the fully general
    per-sample path — and agrees with the native batched-eps path."""
    f = lambda s, z: -z + depth_like(jnp.sin(s), z)
    z0 = jnp.asarray(np.random.RandomState(1).randn(4, 3))
    eps = jnp.asarray([0.1, 0.125, 0.2, 0.05], jnp.float64)
    integ = Integrator(tableau=HEUN)
    native = integ.solve(f, z0, FixedGrid(0.0, eps, 6), return_traj=False)
    vmapped = jax.vmap(
        lambda z, e: integ.solve(f, z, FixedGrid(0.0, e, 6),
                                 return_traj=False))(z0, eps)
    np.testing.assert_allclose(np.asarray(vmapped), np.asarray(native),
                               rtol=1e-10)


def test_batched_eps_hypersolver_correction_scaling():
    """The eps^{p+1} correction weight is applied per-sample too."""
    g = lambda eps, s, z, dz: jnp.ones_like(z)
    f = lambda s, z: jnp.zeros_like(z)
    z0 = jnp.zeros((3, 2), jnp.float64)
    eps = jnp.asarray([0.1, 0.2, 0.4], jnp.float64)
    integ = Integrator(tableau=EULER, g=g)
    zT = integ.solve(f, z0, FixedGrid(0.0, eps, 1), return_traj=False)
    np.testing.assert_allclose(
        np.asarray(zT), np.asarray(eps[:, None] ** 2 * np.ones((3, 2))),
        rtol=1e-12)


# ------------------------------------------------------- jit / checkpoint ----

def test_jit_and_grad_with_checkpoint():
    f = lambda s, z: jnp.tanh(z)
    z0 = jnp.asarray([[0.5, -0.25]], jnp.float64)
    grid = FixedGrid.over(0.0, 1.0, 16)
    integ = Integrator(tableau=HEUN)

    def loss(z, ckpt):
        out = integ.solve(f, z, grid, return_traj=False, checkpoint=ckpt)
        return jnp.sum(out ** 2)

    l0, g0 = jax.value_and_grad(loss)(z0, False)
    l1, g1 = jax.jit(jax.value_and_grad(loss), static_argnums=1)(z0, True)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1), rtol=1e-10)


# ------------------------------------------------------------- fused path ----

@pytest.mark.parametrize("base", ["euler", "heun", "midpoint", "rk4"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_solve_matches_unfused(base, dtype):
    """fused=True (Pallas fused_rk_update, interpret on CPU) == the jnp
    leaf-algebra path for every base tableau, with and without g."""
    f = lambda s, z: jnp.sin(z)
    g = lambda eps, s, z, dz: 0.3 * z + 0.1 * dz
    z0 = jax.random.normal(jax.random.PRNGKey(0), (4, 37)).astype(dtype)
    grid = FixedGrid.over(0.0, 1.0, 3)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-6)
    for corr in (None, g):
        a = Integrator(get_tableau(base), g=corr).solve(
            f, z0, grid, return_traj=False)
        b = Integrator(get_tableau(base), g=corr, fused=True).solve(
            f, z0, grid, return_traj=False)
        assert b.dtype == z0.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **tol)


def test_fused_handles_batched_eps_in_kernel():
    """Per-sample (B,) eps is a RUNTIME kernel operand now: the fused path
    stays on the Pallas kernel (no fallback warning), matches the jnp
    leaf-algebra path, and ``fused_available`` reports the kernel in play
    for every step-size pattern."""
    import warnings

    f = lambda s, z: -z
    z0 = jnp.ones((2, 5), jnp.float32)
    eps = jnp.asarray([0.1, 0.2], jnp.float32)
    a = Integrator(RK4).solve(f, z0, FixedGrid(0.0, eps, 4),
                              return_traj=False)
    fused = Integrator(RK4, fused=True)
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        b = fused.solve(f, z0, FixedGrid(0.0, eps, 4), return_traj=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                               atol=1e-6)
    assert fused.fused_available(eps)
    assert fused.fused_available(0.1)
    assert fused.fused_available(eps, z=z0)


def test_fused_falls_back_on_odd_dtype_resettably():
    """The one surviving fallback: state dtypes the kernel does not store
    (complex here). The warning is one-time but RESETTABLE, so it is not
    test-order-dependent (tests/conftest.py re-arms it per test)."""
    from repro.core.integrate import reset_fused_fallback_warning

    f = lambda s, z: -z
    z0 = jnp.ones((2, 3), jnp.complex64)
    grid = FixedGrid.over(0.0, 1.0, 2)
    fused = Integrator(HEUN, fused=True)
    a = Integrator(HEUN).solve(f, z0, grid, return_traj=False)
    with pytest.warns(RuntimeWarning, match="falling back"):
        b = fused.solve(f, z0, grid, return_traj=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
    assert not fused.fused_available(z=z0)
    # latch: silent on the next solve, re-armed after an explicit reset
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        fused.solve(f, z0, grid, return_traj=False)
    reset_fused_fallback_warning()
    with pytest.warns(RuntimeWarning, match="falling back"):
        fused.solve(f, z0, grid, return_traj=False)


# ------------------------------------------------------------ coercion ----

def test_as_integrator_coercions():
    assert as_integrator("rk4").tableau is RK4
    assert as_integrator(HEUN).tableau is HEUN
    integ = Integrator(tableau=EULER)
    assert as_integrator(integ) is integ
    hs = HyperSolver(tableau=MIDPOINT, g=None)
    assert as_integrator(hs).tableau is MIDPOINT
    assert as_integrator(integ.with_tableau("heun")).tableau.name == "heun"
    with pytest.raises(TypeError):
        as_integrator(123)
