"""Test-suite wide configuration.

x64 containment: the numerics tests (solver order fits) need fp64, but
pytest imports every module at collection time — a module-level
``jax.config.update("jax_enable_x64", True)`` would leak into the whole
suite and change integer/float promotion everywhere (it broke the int32
arithmetic inside Pallas kernels). This autouse fixture scopes x64 to
exactly the modules that need it.
"""
import jax
import pytest

X64_MODULES = {
    "test_solvers.py",
    "test_hypersolver.py",
    "test_core_properties.py",
    "test_integrate.py",
    "test_adaptive.py",
    "test_controllers.py",
}


@pytest.fixture(autouse=True)
def _x64_scope(request):
    need = request.node.path.name in X64_MODULES
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", need)
    yield
    jax.config.update("jax_enable_x64", prev)


@pytest.fixture(autouse=True)
def _rearm_one_time_warnings():
    """One-time warning latches (fused fallback, bucket-overflow snap) are
    re-armed per test so warning assertions are not test-order-dependent
    (they used to be process-global bools that whichever test tripped
    first would consume for the whole session)."""
    from repro.core.integrate import reset_fused_fallback_warning
    from repro.launch.engine import (reset_probe_nonfinite_warning,
                                     reset_snap_overflow_warning)

    reset_fused_fallback_warning()
    reset_snap_overflow_warning()
    reset_probe_nonfinite_warning()
    yield
