"""Unit tests for the sharding rule engine (pure spec logic — no mesh
devices needed; divisibility checks use a mock mesh shape)."""
from types import SimpleNamespace

import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    param_pspec, sanitize_spec, set_ep_axis, zero_pspec,
)

MESH = SimpleNamespace(shape={"data": 16, "model": 16})


def test_attention_projections():
    assert param_pspec("groups/b0/attn/wq/kernel", 3) == P(None, None, "model")
    assert param_pspec("groups/b0/attn/wo/kernel", 3) == P(None, "model", None)
    assert param_pspec("tail/t0/attn/wk/kernel", 2) == P(None, "model")


def test_embed_vocab_sharding_and_sanitize():
    assert param_pspec("embed/table", 2) == P("model", None)
    # whisper vocab 51865 not divisible by 16 -> replicate dim 0
    assert sanitize_spec(MESH, P("model", None), (51865, 512)) == P(None, None)
    assert sanitize_spec(MESH, P("model", None), (256000, 512)) == \
        P("model", None)


def test_moe_expert_parallel_axis_flip():
    assert param_pspec("groups/b1/moe/wi", 4) == P(None, "model", None, None)
    set_ep_axis("data")
    try:
        assert param_pspec("groups/b1/moe/wi", 4) == \
            P(None, "data", None, "model")
        assert param_pspec("groups/b1/moe/wd", 4) == \
            P(None, "data", "model", None)
    finally:
        set_ep_axis("model")
    assert param_pspec("groups/b1/moe/wd", 4) == P(None, "model", None, None)


def test_zero_pspec_skips_scanned_stack_axis():
    # stacked ffn weight (96, 18432, 73728): data goes on dim1, NOT the
    # scanned dim0 (which would force a pre-loop all-gather)
    spec = zero_pspec("groups/b0/ffn/wi/kernel", (96, 18432, 73728), 16)
    assert spec == P(None, "data", "model")
    # unstacked weight: data may take dim 0
    spec2 = zero_pspec("head/kernel", (4096, 151936), 16)
    assert spec2 == P("data", "model")


def test_zero_pspec_no_duplicate_data_axis():
    set_ep_axis("data")
    try:
        spec = zero_pspec("groups/b1/moe/wi", (24, 128, 5120, 8192), 16)
        flat = [a for ax in spec for a in
                ([ax] if isinstance(ax, str) else list(ax or ()))]
        assert flat.count("data") <= 1, spec
    finally:
        set_ep_axis("model")


def test_unknown_params_replicate():
    assert param_pspec("something/new/weird", 3) == P(None, None, None)


def test_norms_replicated():
    assert param_pspec("groups/b0/ln1/scale", 2) == P(None, None)
    assert param_pspec("ln_f/scale", 1) == P(None)


def test_rwkv_and_griffin_rules():
    assert param_pspec("groups/b0/tmix/wr/kernel", 3) == \
        P(None, None, "model")
    assert param_pspec("groups/b0/tmix/wo/kernel", 3) == \
        P(None, "model", None)
    assert param_pspec("groups/b0/griffin/rglru/lam", 2) == P(None, "model")
    assert param_pspec("groups/b0/griffin/conv/w", 3) == \
        P(None, None, "model")
