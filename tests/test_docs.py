"""Docs link-and-freshness gate (tier-1): docs rot fails CI.

Three kinds of pin over README.md + docs/*.md:

  * python snippets actually run / their ``repro`` imports resolve —
    the README quickstart is executed, not pattern-matched;
  * every path-like cross-reference (``launch/scheduler.py``,
    ``docs/serving.md``, ``BENCH_*.json``) names a file that exists;
  * the flag tables in docs/serving.md and the argparse surface of
    ``launch/serve.py`` agree in BOTH directions — a flag added to the
    CLI without docs, or documented without existing, is a failure.
"""
import importlib
import os
import re
import sys

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
README = os.path.join(REPO_ROOT, "README.md")
DOCS_DIR = os.path.join(REPO_ROOT, "docs")
SERVE_PY = os.path.join(REPO_ROOT, "src", "repro", "launch", "serve.py")


def _doc_files():
    docs = [README] + sorted(
        os.path.join(DOCS_DIR, f) for f in os.listdir(DOCS_DIR)
        if f.endswith(".md"))
    assert len(docs) >= 3, "README.md + docs/{architecture,serving}.md"
    return docs


def _read(path):
    with open(path) as fh:
        return fh.read()


def _fenced(text, lang):
    """Fenced code blocks tagged ``lang``."""
    return re.findall(rf"```{lang}\n(.*?)```", text, re.DOTALL)


# ------------------------------------------------------ snippets run ----

def test_readme_quickstart_executes():
    """Every ```python block in the README is a RUNNABLE snippet —
    executed here in one shared namespace, so a renamed symbol or a
    changed signature fails CI, not a reader."""
    blocks = _fenced(_read(README), "python")
    assert blocks, "README lost its python quickstart"
    ns = {}
    for block in blocks:
        exec(compile(block, README, "exec"), ns)  # noqa: S102


@pytest.mark.parametrize("path", _doc_files(),
                         ids=[os.path.basename(p) for p in _doc_files()])
def test_snippet_imports_resolve(path):
    """``from repro.x import y`` / ``import repro.x`` lines inside any
    python snippet must resolve against the live package."""
    for block in _fenced(_read(path), "python"):
        for line in block.splitlines():
            m = re.match(r"\s*from\s+(repro[\w.]*)\s+import\s+(.+)", line)
            if m:
                mod = importlib.import_module(m.group(1))
                for name in m.group(2).split(","):
                    name = name.strip().split(" as ")[0].strip("()")
                    if name:
                        assert hasattr(mod, name), (path, line)
                continue
            m = re.match(r"\s*import\s+(repro[\w.]*)", line)
            if m:
                importlib.import_module(m.group(1))


def test_dotted_module_references_import():
    """Backticked/CLI module paths (``repro.launch.serve``,
    ``benchmarks.run``) must import — a moved module invalidates every
    command line that names it."""
    if REPO_ROOT not in sys.path:
        sys.path.insert(0, REPO_ROOT)
    for path in _doc_files():
        text = _read(path)
        for mod in set(re.findall(r"\b(repro(?:\.[a-z_0-9]+)+)\b", text)):
            importlib.import_module(mod)
        for mod in set(re.findall(r"\b(benchmarks\.[a-z_0-9]+)\b", text)):
            importlib.import_module(mod)


# ----------------------------------------------------- path freshness ----

# path-like tokens are checked when they contain a separator (bare file
# names like ``ops.py`` carry no unambiguous location); resolution tries
# the repo root, src/, and src/repro/ prefixes
_PATH_RE = re.compile(r"[A-Za-z0-9_][A-Za-z0-9_./-]*\.(?:py|md|json)\b")


def _resolves(ref):
    for base in ("", "src", os.path.join("src", "repro")):
        if os.path.exists(os.path.join(REPO_ROOT, base, ref)):
            return True
    return False


@pytest.mark.parametrize("path", _doc_files(),
                         ids=[os.path.basename(p) for p in _doc_files()])
def test_path_references_exist(path):
    text = _read(path)
    missing = []
    for ref in set(_PATH_RE.findall(text)):
        ref = ref.split("::")[0]
        if "/" not in ref or "*" in ref:
            continue
        if not _resolves(ref):
            missing.append(ref)
    assert not missing, (
        f"{os.path.basename(path)} references files that do not exist "
        f"(moved/renamed without a docs update?): {sorted(missing)}")


def test_bench_wildcard_targets_exist():
    """``BENCH_*.json`` in the docs is a real glob at the repo root."""
    import glob
    assert glob.glob(os.path.join(REPO_ROOT, "BENCH_*.json"))


# ------------------------------------------------------ flag freshness ----

# flags that are legitimately not launch/serve.py's (other CLIs, env)
_FLAG_ALLOW = {
    "--check", "--budget", "--only",           # benchmarks/run.py
    "--out",                                   # bench_* scripts
    "--xla_force_host_platform_device_count",  # XLA_FLAGS env
}


def _serve_flags():
    flags = set(re.findall(r'add_argument\(\s*"(--[a-z][a-z0-9-]*)"',
                           _read(SERVE_PY)))
    assert flags, "could not parse launch/serve.py's argparse surface"
    return flags


def test_documented_flags_exist():
    """Every ``--flag`` token in README/docs names a real CLI flag."""
    declared = _serve_flags() | _FLAG_ALLOW
    for path in _doc_files():
        used = set(re.findall(r"--[a-z][a-z0-9_-]*", _read(path)))
        unknown = {u for u in used
                   if not any(u == d or u.startswith(d + "=")
                              for d in declared)}
        assert not unknown, (
            f"{os.path.basename(path)} documents flags that no CLI "
            f"declares: {sorted(unknown)}")


def test_serve_flags_are_documented():
    """The reverse direction: every flag launch/serve.py declares must
    appear in docs/serving.md (the operator guide is complete)."""
    serving = _read(os.path.join(DOCS_DIR, "serving.md"))
    undocumented = {f for f in _serve_flags() if f"`{f}`" not in serving}
    assert not undocumented, (
        "launch/serve.py flags missing from docs/serving.md: "
        f"{sorted(undocumented)}")


# --------------------------------------------------- cost accounting ----

def test_tick_accounting_prose_matches_live_oracle():
    """The Layer-4 tick-accounting prose in docs/architecture.md and the
    'Cost accounting' section in docs/serving.md describe the LIVE
    oracle: the documented `s*K` segment price and `probe_nfe` probe
    price are asserted against SequentialEvalOracle itself, and both
    unit strings the docs name must be the ones the implementations
    report."""
    from repro.launch.oracle import RooflineOracle, SequentialEvalOracle

    arch = _read(os.path.join(DOCS_DIR, "architecture.md"))
    serving = _read(os.path.join(DOCS_DIR, "serving.md"))

    # the prose names the oracle module and the `s*K` pricing rule
    assert "launch/oracle.py" in arch
    assert "`s*K`" in arch and "probe_nfe" in arch
    seq = SequentialEvalOracle()
    assert seq.segment_cost((8,), 5, 4, 3) == 15.0        # s=3, K=5
    assert seq.solve_cost((8,), 5, 4, 3) == 15.0
    assert seq.probe_cost((8,), 4, 2) == 2.0
    # batch-width free, as both docs claim
    assert seq.segment_cost((8,), 5, 4096, 3) == 15.0

    # unit strings in the docs are the ones the oracles report
    for doc in (arch, serving):
        assert "SequentialEvalOracle" in doc
        assert "RooflineOracle" in doc
    assert seq.unit == "sequential_evals"
    assert RooflineOracle.unit == "device_us"
    assert f"`{seq.unit}`" in serving
    assert f"`{RooflineOracle.unit}`" in serving

    # serving.md documents the tuned-config contract and the two fixed
    # accounting bugs
    assert "artifacts/tuned" in serving
    assert "occupied_steps" in serving
    assert "Cross-pool" in serving or "cross-pool" in serving


def test_wallclock_unit_prose_matches_live_tag():
    """The real-clock unit the BENCH_wallclock glossary names is the one
    the bench stamps (`launch/oracle.py::WALLCLOCK_UNIT`), and the
    overlap timeline the architecture doc draws names the live knobs."""
    from repro.launch.oracle import WALLCLOCK_UNIT

    serving = _read(os.path.join(DOCS_DIR, "serving.md"))
    arch = _read(os.path.join(DOCS_DIR, "architecture.md"))
    assert WALLCLOCK_UNIT == "wall_us"
    assert f"`{WALLCLOCK_UNIT}`" in serving
    assert "WALLCLOCK_UNIT" in serving
    # the documented pipeline phases exist as code
    from repro.launch.scheduler import InflightScheduler, _SlotPool
    assert "overlap=True" in arch
    for method in ("launch_segment", "retire_pending", "finalize_retired"):
        assert hasattr(_SlotPool, method)
    assert "_step_overlap" in arch or "one-segment" in arch.lower()
    assert "donate" in arch
    import inspect
    assert "donate" in inspect.signature(
        InflightScheduler.__init__).parameters


def test_kernel_pass_count_prose_matches_traffic_model():
    """Layer-1 prose claims the unfused update costs `stages + 3` jnp
    passes vs ONE fused memory pass — asserted against the live traffic
    model the kernel bench ships (benchmarks/bench_kernels.py), not
    against a copy of the arithmetic."""
    if REPO_ROOT not in sys.path:
        sys.path.insert(0, REPO_ROOT)
    from benchmarks.bench_kernels import _traffic_model

    arch = _read(os.path.join(DOCS_DIR, "architecture.md"))
    assert "`stages + 3`" in arch
    for stages in (1, 2, 4):
        model = _traffic_model(stages, True, 1024)
        assert model["memory_passes_unfused"] == stages + 3
        assert model["memory_passes_fused"] == 1
        assert model["traffic_ratio"] > 1.0


def test_trace_counts_prose_matches_live_counter():
    """The docs lean on `TRACE_COUNTS` as the compile-count witness:
    verify it is live — a second same-shape fused solve must NOT add a
    kernel trace, and a new shape must add exactly one."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import Integrator, get_tableau
    from repro.kernels.hyper_step.ops import TRACE_COUNTS

    arch = _read(os.path.join(DOCS_DIR, "architecture.md"))
    assert "TRACE_COUNTS" in arch
    integ = Integrator(get_tableau("euler"), fused=True)
    f = lambda s, z: -z                                    # noqa: E731
    z0 = jnp.asarray(np.ones((4, 7), np.float32))
    Ks_a = jnp.asarray([1, 2, 3, 4], jnp.int32)
    Ks_b = jnp.asarray([4, 4, 2, 1], jnp.int32)
    integ.solve_multirate(f, z0, (0.0, 1.0), Ks_a, 4)
    before = TRACE_COUNTS["fused_rk_update"]
    integ.solve_multirate(f, z0, (0.0, 1.0), Ks_b, 4)      # same shape
    assert TRACE_COUNTS["fused_rk_update"] == before, (
        "same-shape solve retraced the fused kernel")
    z1 = jnp.asarray(np.ones((4, 9), np.float32))          # new shape
    integ.solve_multirate(f, z1, (0.0, 1.0), Ks_a, 4)
    assert TRACE_COUNTS["fused_rk_update"] > before


def test_refinery_prose_matches_live_api():
    """The Layer-6 refinery prose (docs/architecture.md) and the
    'Online refinement' flag table + BENCH_refinery glossary
    (docs/serving.md) describe the LIVE loop: the named classes, hooks,
    and verdict keys are asserted against launch/refinery.py, both
    serving loops' swap surface, and benchmarks/run.py's check gate."""
    import inspect

    from repro.launch.engine import MultiRateEngine, validate_g_swap
    from repro.launch.refinery import (
        Refinery, RefineryConfig, ResidualLedger,
    )
    from repro.launch.scheduler import InflightScheduler

    arch = _read(os.path.join(DOCS_DIR, "architecture.md"))
    serving = _read(os.path.join(DOCS_DIR, "serving.md"))

    # the architecture doc names the refinery layer and its invariant
    assert "launch/refinery.py" in arch
    assert "ResidualLedger" in arch and "params-are-inputs" in arch
    assert "hot_swap_g" in arch and "TRACE_COUNTS" in arch

    # the documented swap surface is live on BOTH loops + the refinery
    for cls in (InflightScheduler, MultiRateEngine):
        assert hasattr(cls, "hot_swap_g")
        assert "ledger" in inspect.signature(cls.__init__).parameters
    for method in ("train_tick", "shadow_score", "maybe_promote",
                   "check_promoted", "tick", "flush", "status"):
        assert hasattr(Refinery, method)
    for attr in ("capture", "capture_pool", "sample_batch",
                 "holdout_batch", "flush"):
        assert hasattr(ResidualLedger, attr)
    assert validate_g_swap is not None

    # the flag table documents the knobs the refinery actually has
    cfg = RefineryConfig()
    assert cfg.steps_per_tick == 2 and cfg.shadow_every == 100
    for flag in ("--refine", "--capture-rate", "--ledger-cap",
                 "--refine-steps", "--shadow-every", "--ledger-out",
                 "--progress-every", "--refine-dir"):
        assert f"`{flag}`" in serving, f"{flag} missing from serving.md"

    # the BENCH_refinery glossary names the verdict keys --check gates
    if REPO_ROOT not in sys.path:
        sys.path.insert(0, REPO_ROOT)
    from benchmarks.run import BENCH_REQUIRED, _check_refinery_section
    assert "BENCH_refinery.json" in BENCH_REQUIRED
    assert "BENCH_refinery.json" in serving
    for key in ("refined_beats_frozen", "equal_nfe", "capture_parity",
                "shadow_gate_clean"):
        assert f"`{key}`" in serving, f"verdict key {key} undocumented"
    # the gate function rejects an empty file shape (it is live)
    assert _check_refinery_section("BENCH_refinery.json", [])


def test_flow_tier_prose_matches_live_router():
    """The K=0 flow-tier flag table + BENCH_flow glossary (serving.md)
    and the three-tier ladder diagram (architecture.md) describe the
    LIVE router: the documented thresholds are TierRouter's actual
    defaults, the tier is actually off by default on EngineConfig, the
    named swap surface exists on both loops, and the verdict keys are
    the ones benchmarks/run.py gates."""
    import inspect

    from repro.core.controllers import TierRouter
    from repro.core.train import FlowTrainConfig, train_flowhead
    from repro.launch.engine import EngineConfig, MultiRateEngine
    from repro.launch.scheduler import InflightScheduler

    arch = _read(os.path.join(DOCS_DIR, "architecture.md"))
    serving = _read(os.path.join(DOCS_DIR, "serving.md"))

    # the documented default thresholds are the live ones
    router = TierRouter()
    assert router.flow_threshold == 0.25 and router.hyper_k_max == 4
    assert "`0.25`" in serving and "TierRouter" in serving
    assert "hyper_k_max" in serving
    # ...and the tier really is off unless asked for
    assert EngineConfig().flow_threshold == 0.0

    # architecture.md draws the ladder with the live pieces
    for token in ("TierRouter", "core/flowhead.py", "FLOW TIER",
                  "escalated", "min(buckets)"):
        assert token in arch, f"{token!r} missing from architecture.md"

    # the documented swap/accounting surface is live on BOTH loops
    for cls in (InflightScheduler, MultiRateEngine):
        assert hasattr(cls, "hot_swap_flow")
        assert "nfe_flow" in inspect.getsource(
            sys.modules[cls.__module__])
    assert train_flowhead is not None
    assert FlowTrainConfig().relative is True

    # the flag table documents the real CLI surface
    for flag in ("--flow-ckpt", "--flow-rank", "--flow-threshold"):
        assert f"`{flag}`" in serving, f"{flag} missing from serving.md"

    # the BENCH_flow glossary names the verdict keys --check gates
    if REPO_ROOT not in sys.path:
        sys.path.insert(0, REPO_ROOT)
    from benchmarks.run import BENCH_REQUIRED, _check_flow_section
    assert "BENCH_flow.json" in BENCH_REQUIRED
    assert "BENCH_flow.json" in serving
    for key in ("three_tier_dominates", "flow_disabled_parity",
                "escalation_accounted", "zero_hang"):
        assert f"`{key}`" in serving, f"verdict key {key} undocumented"
    # the gate function rejects an empty file shape (it is live)
    assert _check_flow_section("BENCH_flow.json", [])


def test_failure_semantics_prose_matches_live_enum():
    """The 'Failure semantics' status glossary in docs/serving.md is
    asserted against the LIVE terminal-status enum and retry defaults —
    a new status or a changed retry budget must update the docs."""
    from repro.distributed.fault import FaultInjector, RetryPolicy
    from repro.launch.engine import STATUSES, QueueFull  # noqa: F401

    serving = _read(os.path.join(DOCS_DIR, "serving.md"))
    arch = _read(os.path.join(DOCS_DIR, "architecture.md"))
    assert "Failure semantics" in serving

    # every live status appears backticked in the glossary, and the
    # glossary table has exactly one row per status (no stale rows)
    section = serving.split("Failure semantics", 1)[1]
    for status in STATUSES:
        assert f"`{status}`" in section, f"status {status!r} undocumented"
    table_rows = re.findall(r"^\| `(\w+)` \|", section, re.MULTILINE)
    assert sorted(table_rows) == sorted(STATUSES), table_rows

    # the documented retry defaults are the live ones
    policy = RetryPolicy()
    assert policy.max_retries == 1
    assert policy.retry_statuses == ("diverged",)
    assert "max_retries=1" in section
    assert '("diverged",)' in section or "(\"diverged\",)" in section

    # the chaos-source sites the docs name exist and are disarmed by
    # default (a bare injector must be a no-op — fault-free parity)
    inj = FaultInjector()
    assert inj.nan_uid_frac == 0.0 and inj.drop_flag_p == 0.0 \
        and inj.straggle_tick_frac == 0.0
    for site in ("corrupt_admission", "drop_retire_flags",
                 "inflate_segment_cost"):
        assert hasattr(inj, site)

    # architecture.md's meta-layer note matches the live 3-row layout
    assert "3×B" in arch and "nonfinite" in arch
    assert "3×B" in section or "3×B" in serving
