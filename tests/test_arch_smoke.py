"""Per-architecture smoke tests: REDUCED same-family configs, one forward +
one train-grad step + one decode step on CPU; asserts shapes and finiteness.

The FULL configs are exercised only via the dry-run (AOT, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get
from repro.models.encdec import (
    encdec_decode_step, encdec_loss, encode, init_dec_cache, init_encdec,
)
from repro.models.lm import (
    count_params, init_lm, init_lm_cache, lm_decode_step, lm_forward, lm_loss,
)

LM_ARCHS = [a for a in ARCH_IDS if a != "whisper_base"]


def _data(cfg, B=2, S=16, key=0):
    k = jax.random.PRNGKey(key)
    toks = jax.random.randint(k, (B, S), 0, cfg.vocab)
    tgts = jnp.roll(toks, -1, axis=1)
    fe = None
    if cfg.frontend == "patches":
        fe = jax.random.normal(jax.random.PRNGKey(7),
                               (B, cfg.n_frontend_tokens, cfg.d_model))
    return toks, tgts, fe


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_reduced_forward_and_grad(arch):
    cfg = get(arch).reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks, tgts, fe = _data(cfg)
    logits, aux = lm_forward(params, cfg, toks, frontend=fe)
    S_total = toks.shape[1] + (cfg.n_frontend_tokens if fe is not None else 0)
    assert logits.shape == (2, S_total, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm_loss(p, cfg, toks, tgts, frontend=fe), has_aux=True
    )(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g)))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0.0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_reduced_decode_step(arch):
    cfg = get(arch).reduced()
    params = init_lm(jax.random.PRNGKey(0), cfg)
    B, S_max = 2, 16
    caches = init_lm_cache(cfg, B, S_max)
    tok = jnp.zeros((B,), jnp.int32)
    logits, caches = lm_decode_step(params, cfg, tok, caches, jnp.asarray(0))
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    logits2, caches = lm_decode_step(
        params, cfg, jnp.argmax(logits, -1).astype(jnp.int32), caches,
        jnp.asarray(1))
    assert np.all(np.isfinite(np.asarray(logits2)))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_decode_matches_forward(arch):
    """Token-by-token decode logits == teacher-forced forward logits."""
    cfg = get(arch).reduced()
    params = init_lm(jax.random.PRNGKey(1), cfg)
    B, S = 1, 6
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    full_logits, _ = lm_forward(params, cfg, toks)
    caches = init_lm_cache(cfg, B, S)
    outs = []
    for t in range(S):
        lg, caches = lm_decode_step(params, cfg, toks[:, t], caches,
                                    jnp.asarray(t))
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    if cfg.n_experts:
        # capacity-based drops can differ between batched prefill and
        # token-level decode; compare argmax agreement instead
        agree = np.mean(np.asarray(jnp.argmax(full_logits, -1)
                                   == jnp.argmax(dec_logits, -1)))
        assert agree > 0.65, agree
    else:
        np.testing.assert_allclose(np.asarray(full_logits),
                                   np.asarray(dec_logits), rtol=5e-3,
                                   atol=5e-4)


def test_whisper_smoke():
    cfg = get("whisper_base").reduced()
    params = init_encdec(jax.random.PRNGKey(0), cfg)
    B, T, L = 2, 12, 8
    frames = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, L), 0, cfg.vocab)
    tgts = jnp.roll(toks, -1, 1)
    (loss, m), grads = jax.value_and_grad(
        lambda p: encdec_loss(p, cfg, frames, toks, tgts), has_aux=True
    )(params)
    assert np.isfinite(float(loss))

    enc = encode(params, cfg, frames)
    caches = init_dec_cache(params, cfg, enc, B, L)
    lg, caches = encdec_decode_step(params, cfg, toks[:, 0], caches,
                                    jnp.asarray(0))
    assert lg.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(lg)))


def test_whisper_decode_matches_teacher_forced():
    cfg = get("whisper_base").reduced()
    params = init_encdec(jax.random.PRNGKey(4), cfg)
    B, T, L = 1, 10, 5
    frames = jax.random.normal(jax.random.PRNGKey(5), (B, T, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(6), (B, L), 0, cfg.vocab)
    enc = encode(params, cfg, frames)
    from repro.models.encdec import decode_train
    full = decode_train(params, cfg, enc, toks)
    caches = init_dec_cache(params, cfg, enc, B, L)
    outs = []
    for t in range(L):
        lg, caches = encdec_decode_step(params, cfg, toks[:, t], caches,
                                        jnp.asarray(t))
        outs.append(lg)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.stack(outs, 1)), rtol=5e-3,
                               atol=5e-4)


def test_param_counts_full_configs_match_public_sizes():
    """Analytic parameter counts of the FULL configs are in the right
    ballpark (no allocation — pure arithmetic from config)."""
    from repro.roofline.params import analytic_param_count
    expected = {
        "llama4_maverick_400b_a17b": (350e9, 460e9),
        "olmoe_1b_7b": (6.0e9, 8.0e9),
        "nemotron_4_340b": (320e9, 360e9),
        "qwen3_4b": (3.2e9, 4.8e9),
        "qwen3_8b": (7.0e9, 9.0e9),
        "mistral_nemo_12b": (11.0e9, 13.5e9),
        "paligemma_3b": (2.2e9, 3.5e9),
        "rwkv6_1p6b": (1.3e9, 2.2e9),
        "recurrentgemma_2b": (2.0e9, 3.3e9),
        "whisper_base": (6e7, 1.1e8),
    }
    for arch, (lo, hi) in expected.items():
        n = analytic_param_count(get(arch))
        assert lo <= n <= hi, (arch, f"{n:.3e}", lo, hi)
