"""Hypersolver correctness: Theorem 1 scaling, pareto vs base solver,
alpha-family base-solver swap, training harness round-trip."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    EULER, HEUN, FixedGrid, HyperSolver, NeuralODE, alpha_family,
    get_tableau, odeint_fixed, solver_residual,
)
from repro.core.train import (
    HypersolverTrainConfig, bind_g, make_hypersolver, train_hypersolver,
)

# x64 enabled per-module via tests/conftest.py

# numpy constant: module import happens with x64 OFF (conftest.py)
A = np.array([[-0.4, -1.6], [1.6, -0.4]], dtype=np.float64)


def f_apply(params, s, x, z):
    del params, x
    return z @ A.T


NODE = NeuralODE(
    f_apply=f_apply,
    hx_apply=lambda p, x: x,
    hy_apply=lambda p, z: z,
    s_span=(0.0, 1.0),
)


def g_apply(gp, eps, s, x, z, dz):
    """Linear correction g = z W1 + dz W2 (exact residual for a linear field
    is representable: R ~ A^2 z / 2 = A dz / 2)."""
    return z @ gp["w1"].T + dz @ gp["w2"].T


def batches(seed=0, n=64):
    key = jax.random.PRNGKey(seed)
    while True:
        key, sub = jax.random.split(key)
        yield jax.random.normal(sub, (n, 2), dtype=jnp.float64)


def _train(base="euler", iters=250, K=10):
    gp = {
        "w1": jnp.zeros((2, 2), jnp.float64),
        "w2": jnp.zeros((2, 2), jnp.float64),
    }
    cfg = HypersolverTrainConfig(
        base_solver=base, K=K, iters=iters, pretrain_iters=10, swap_every=10,
        lr=5e-2, lr_min=1e-3, atol=1e-9, rtol=1e-9,
    )
    gp, losses = train_hypersolver(NODE, None, g_apply, gp, batches(), cfg)
    return gp, losses


def test_zero_correction_reduces_to_base_solver():
    z0 = jnp.array([[1.0, -0.5]])
    grid = FixedGrid.over(0.0, 1.0, 5)
    base = odeint_fixed(lambda s, z: z @ A.T, z0, grid, EULER, return_traj=False)
    hs = HyperSolver(tableau=EULER, g=None)
    hyper = hs.odeint(lambda s, z: z @ A.T, z0, grid, return_traj=False)
    np.testing.assert_allclose(np.asarray(base), np.asarray(hyper))


def test_residual_fit_learns_true_residual():
    """For a linear field, R -> A/2 * dz as eps -> 0; trained W2 ~ A/2."""
    gp, losses = _train(iters=300)
    assert losses[-1] < losses[0] * 0.05, (losses[0], losses[-1])
    # The learned combination should act like A^2/2 on z (up to O(eps) terms).
    eff = np.asarray(gp["w1"] + gp["w2"] @ A)
    target = np.asarray(A @ A) / 2.0
    assert np.linalg.norm(eff - target) / np.linalg.norm(target) < 0.15, eff


def _expm(M):
    # eigendecomposition exponential for the 2x2 normal-ish test matrix
    w, V = np.linalg.eig(np.asarray(M))
    return (V @ np.diag(np.exp(w)) @ np.linalg.inv(V)).real


def test_theorem1_local_error_scaling():
    """Hypersolver local error should be << base local error at the training
    eps, and keep scaling ~ eps^{p+1} (Theorem 1: e_k = O(delta eps^{p+1}))."""
    gp, _ = _train(iters=300, K=10)
    z = jnp.array([[0.7, -0.3]], dtype=jnp.float64)
    f = lambda s, zz: zz @ A.T
    hs = make_hypersolver("euler", g_apply, gp, None)
    base_errs, hyper_errs, epss = [], [], [0.1, 0.05, 0.025]
    for eps in epss:
        z_next_true = jnp.asarray(np.asarray(z) @ _expm(np.asarray(A) * eps).T)
        # base Euler local error
        base_pred = z + eps * f(0.0, z)
        base_errs.append(float(jnp.linalg.norm(z_next_true - base_pred)))
        hyper_pred, _, _ = hs.step(f, 0.0, eps, z)
        hyper_errs.append(float(jnp.linalg.norm(z_next_true - hyper_pred)))
    # delta << 1 at the training step size (trained at eps = 0.1)
    assert hyper_errs[0] < 0.1 * base_errs[0], (base_errs, hyper_errs)
    # Theorem 1: e_k <= delta * eps^{p+1} with delta << base residual constant
    # (for Euler p=1 the base constant is e_base/eps^2 ~ ||A^2 z||/2).
    for eps, be, he in zip(epss, base_errs, hyper_errs):
        delta = he / eps ** 2
        base_const = be / eps ** 2
        assert delta < 0.12 * base_const, (eps, delta, base_const)


def test_hypersolver_beats_base_at_equal_nfe():
    """Terminal solution error at K=10 steps: hyper-Euler << Euler (Fig. 3)."""
    gp, _ = _train(iters=300, K=10)
    z0 = jnp.array([[1.0, 0.5], [-0.2, 0.9]])
    grid = FixedGrid.over(0.0, 1.0, 10)
    f = lambda s, z: z @ A.T
    ref, _ = NODE.reference_trajectory(None, z0, 10, atol=1e-10, rtol=1e-10)[:2]
    exact = ref[-1]
    base = odeint_fixed(f, z0, grid, EULER, return_traj=False)
    hs = make_hypersolver("euler", g_apply, gp, None)
    hyper = hs.odeint(f, z0, grid, return_traj=False)
    err_base = float(jnp.linalg.norm(base - exact))
    err_hyper = float(jnp.linalg.norm(hyper - exact))
    assert err_hyper < err_base * 0.2, (err_base, err_hyper)


def test_step_size_generalization():
    """Paper Sec. 4.1: trained at K=10, evaluated at unseen K (8, 20)."""
    gp, _ = _train(iters=300, K=10)
    f = lambda s, z: z @ A.T
    z0 = jnp.array([[0.3, -1.1]])
    for K in [8, 20]:
        grid = FixedGrid.over(0.0, 1.0, K)
        ref, _ = NODE.reference_trajectory(None, z0, K, atol=1e-10, rtol=1e-10)[:2]
        exact = ref[-1]
        base = odeint_fixed(f, z0, grid, EULER, return_traj=False)
        hs = make_hypersolver("euler", g_apply, gp, None)
        hyper = hs.odeint(f, z0, grid, return_traj=False)
        assert float(jnp.linalg.norm(hyper - exact)) < float(
            jnp.linalg.norm(base - exact)
        ), K


def test_alpha_family_base_swap():
    """HyperMidpoint evaluated under other alpha-family members without
    finetuning stays ahead of the plain member (paper Fig. 6)."""
    gp, _ = _train(base="midpoint", iters=300, K=10)
    z0 = jnp.array([[1.0, 0.5]])
    f = lambda s, z: z @ A.T
    grid = FixedGrid.over(0.0, 1.0, 10)
    ref, _ = NODE.reference_trajectory(None, z0, 10, atol=1e-10, rtol=1e-10)[:2]
    exact = ref[-1]
    hs_mid = make_hypersolver("midpoint", g_apply, gp, None)
    for alpha in [0.4, 0.5, 2.0 / 3.0, 1.0]:
        tab = alpha_family(alpha)
        plain = odeint_fixed(f, z0, grid, tab, return_traj=False)
        swapped = hs_mid.with_tableau(tab)
        hyper = swapped.odeint(f, z0, grid, return_traj=False)
        err_plain = float(jnp.linalg.norm(plain - exact))
        err_hyper = float(jnp.linalg.norm(hyper - exact))
        assert err_hyper < err_plain, (alpha, err_plain, err_hyper)
