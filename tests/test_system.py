"""End-to-end behaviour tests for the hypersolver system.

A miniature version of the paper's full pipeline: train a Neural ODE on a
task, generate dopri5 ground truth, fit a hypersolver by residual fitting,
and verify the hypersolved model preserves task accuracy at a fraction of
the NFE (paper Figs. 3-4 in microcosm).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    EULER, FixedGrid, NeuralODE, get_tableau, odeint_fixed,
)
from repro.core.train import (
    HypersolverTrainConfig, make_hypersolver, train_hypersolver,
)
from repro.optim import adamw, apply_updates


def _make_node(key, nz=8):
    """Tiny MLP Neural ODE f(s, z) = W2 tanh(W1 [z, s])."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "w1": jax.random.normal(k1, (nz + 1, 32)) * 0.4,
        "w2": jax.random.normal(k2, (32, nz)) * 0.4,
        "hx": jax.random.normal(k3, (2, nz)) * 0.7,
        "hy": jax.random.normal(k4, (nz, 2)) * 0.7,
    }

    def f_apply(p, s, x, z):
        s_col = jnp.broadcast_to(jnp.asarray(s, z.dtype), z[..., :1].shape)
        h = jnp.concatenate([z, s_col], axis=-1)
        return jnp.tanh(h @ p["w1"]) @ p["w2"]

    node = NeuralODE(
        f_apply=f_apply,
        hx_apply=lambda p, x: x @ p["hx"],
        hy_apply=lambda p, z: z @ p["hy"],
    )
    return node, params


def _two_moons(key, n):
    k1, k2, k3 = jax.random.split(key, 3)
    t = jax.random.uniform(k1, (n,)) * jnp.pi
    lab = jax.random.bernoulli(k2, 0.5, (n,)).astype(jnp.int32)
    x = jnp.stack(
        [jnp.cos(t) * (1 - 2 * lab) + lab * 1.0,
         jnp.sin(t) * (1 - 2 * lab) + lab * 0.3],
        axis=-1,
    )
    x = x + 0.05 * jax.random.normal(k3, x.shape)
    return x, lab


def test_full_pipeline_hypersolver_preserves_accuracy():
    key = jax.random.PRNGKey(0)
    node, params = _make_node(key)

    # --- phase 0: train the Neural ODE on the task (dopri5-quality fwd: RK4 K=32)
    opt = adamw(3e-3)
    opt_state = opt.init(params)
    xs, ys = _two_moons(jax.random.PRNGKey(1), 256)

    def loss_fn(p):
        logits = node.forward_fixed(p, xs, get_tableau("rk4"), 32)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(logp[jnp.arange(xs.shape[0]), ys])

    @jax.jit
    def train_step(p, st, i):
        l, g = jax.value_and_grad(loss_fn)(p)
        upd, st = opt.update(g, st, p, i)
        return apply_updates(p, upd), st, l

    for i in range(150):
        params, opt_state, _ = train_step(params, opt_state, i)

    def acc(logits):
        return float(jnp.mean(jnp.argmax(logits, -1) == ys))

    ref_logits = node.forward_fixed(params, xs, get_tableau("rk4"), 32)
    acc_ref = acc(ref_logits)
    assert acc_ref > 0.9, acc_ref

    # --- phase 1: fit HyperEuler by residual fitting on dopri5 trajectories
    nz = 8
    kg = jax.random.PRNGKey(2)
    gp = {
        "w1": jax.random.normal(kg, (2 * nz + 1, 32)) * 0.05,
        "w2": jnp.zeros((32, nz)),
    }

    def g_apply(g, eps, s, x, z, dz):
        s_col = jnp.broadcast_to(jnp.asarray(s, z.dtype), z[..., :1].shape)
        h = jnp.concatenate([z, dz, s_col], axis=-1)
        return jnp.tanh(h @ g["w1"]) @ g["w2"]

    def batches():
        k = jax.random.PRNGKey(3)
        while True:
            k, sub = jax.random.split(k)
            yield _two_moons(sub, 128)[0]

    cfg = HypersolverTrainConfig(
        base_solver="euler", K=4, iters=220, pretrain_iters=10, swap_every=10,
        lr=1e-2, lr_min=5e-4, atol=1e-6, rtol=1e-6,
    )
    gp, losses = train_hypersolver(node, params, g_apply, gp, batches(), cfg)
    assert losses[-1] < losses[0], (losses[0], losses[-1])

    # --- phase 2: K=4 HyperEuler vs K=4 Euler on held-out data
    xt, yt = _two_moons(jax.random.PRNGKey(9), 512)
    ref, _, _ = node.reference_trajectory(params, xt, 4, atol=1e-8, rtol=1e-8)
    zT_true = ref[-1]
    grid = FixedGrid.over(0.0, 1.0, 4)
    f = node.field(params, xt)
    z0 = node.hx_apply(params, xt)
    zT_euler = odeint_fixed(f, z0, grid, EULER, return_traj=False)
    hs = make_hypersolver("euler", g_apply, gp, xt)
    zT_hyper = hs.odeint(f, z0, grid, return_traj=False)

    err_euler = float(jnp.mean(jnp.abs(zT_euler - zT_true)))
    err_hyper = float(jnp.mean(jnp.abs(zT_hyper - zT_true)))
    assert err_hyper < err_euler, (err_euler, err_hyper)

    # task metric: hypersolver accuracy drop vs dopri5-quality reference <= Euler's
    logits_true = node.hy_apply(params, zT_true)
    logits_e = node.hy_apply(params, zT_euler)
    logits_h = node.hy_apply(params, zT_hyper)
    yt_ref = jnp.argmax(logits_true, -1)
    agree_e = float(jnp.mean(jnp.argmax(logits_e, -1) == yt_ref))
    agree_h = float(jnp.mean(jnp.argmax(logits_h, -1) == yt_ref))
    assert agree_h >= agree_e, (agree_e, agree_h)
    assert not np.any(np.isnan(np.asarray(zT_hyper)))
