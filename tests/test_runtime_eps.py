"""Runtime-eps fused serving path, end to end.

The kernel takes step sizes as runtime scalar-prefetch operands
(kernels/hyper_step), so ONE compilation serves every eps pattern —
scalar, traced, per-sample multi-rate — and the controller-driven masked
solve stays fused. This module pins:

  * compile-count: serving many distinct eps values / buckets traces the
    kernel exactly once (the recompile-churn fix);
  * controller-driven fused == unfused, leaf-wise, fp32 and bf16, with and
    without g, with NO fallback warning;
  * the engine packs mixed-K batches into a single fused multi-rate solve
    (one jit cell, outputs matching direct per-K solves);
  * ``Integrator.solve(mesh=...)`` on the CPU debug mesh (subprocess —
    the main test process keeps a single device per the dry-run contract).
"""
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FixedGrid, Integrator, get_tableau
from repro.core.controllers import (
    EmbeddedErrorController, HypersolverResidualController,
)
from repro.kernels.hyper_step.ops import TRACE_COUNTS, fused_rk_update


def _field(s, z):
    return -z * jax.nn.softplus(jnp.mean(z, axis=-1, keepdims=True))


G = lambda eps, s, z, dz: 0.25 * z + 0.1 * dz


# --------------------------------------------------------- compile count ----

def test_kernel_traces_once_across_eps_values():
    """4 different eps buckets through the fused entry point must trace the
    kernel once: eps is a runtime operand, not a specialization key."""
    z = jax.random.normal(jax.random.PRNGKey(0), (4, 40))
    r = jax.random.normal(jax.random.PRNGKey(1), (4, 40))
    fused_rk_update(z, (r,), None, 0.5, (1.0,), 1)  # warm the cache
    before = TRACE_COUNTS["fused_rk_update"]
    for eps in (0.1, 0.125, 0.25, 0.5):
        fused_rk_update(z, (r,), None, eps, (1.0,), 1)
    assert TRACE_COUNTS["fused_rk_update"] == before, (
        "kernel retraced for a new eps value — eps leaked back into the "
        "compilation key")


def test_kernel_traces_once_across_bucket_solves():
    """Serving 4 eps buckets (4 distinct mesh lengths K) through fused
    Integrator solves compiles the kernel once: the scan length changes,
    the kernel shape does not."""
    integ = Integrator(get_tableau("heun"), g=G, fused=True)
    z0 = jax.random.normal(jax.random.PRNGKey(2), (4, 24))
    integ.solve(_field, z0, FixedGrid.over(0.0, 1.0, 3), return_traj=False)
    before = TRACE_COUNTS["fused_rk_update"]
    for K in (2, 4, 8, 16):  # 4 buckets -> 4 distinct scalar eps = 1/K
        integ.solve(_field, z0, FixedGrid.over(0.0, 1.0, K),
                    return_traj=False)
    assert TRACE_COUNTS["fused_rk_update"] == before, (
        "kernel retraced across eps buckets")


# ---------------------------------------- controller-driven fused solve ----

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("with_g", [True, False])
def test_controlled_solve_fused_matches_unfused(dtype, with_g):
    """The controller's masked multi-rate solve (per-sample eps rows) takes
    the kernel path with NO fallback warning and matches the jnp path
    leaf-wise — fp32 atol 1e-6, bf16 at storage precision."""
    g = G if with_g else None
    ctrl = (HypersolverResidualController(tol=1e-3, k_min=1, k_max=8)
            if with_g else EmbeddedErrorController(tol=1e-3, k_min=1,
                                                   k_max=8))
    z0 = jax.random.normal(jax.random.PRNGKey(3), (6, 33)).astype(dtype)
    grid = FixedGrid.over(0.0, 1.0, 8)
    res_u, st_u = Integrator(get_tableau("heun"), g=g).solve(
        _field, z0, grid, return_traj=False, controller=ctrl)
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        res_f, st_f = Integrator(get_tableau("heun"), g=g, fused=True).solve(
            _field, z0, grid, return_traj=False, controller=ctrl)
    assert res_f.dtype == z0.dtype
    np.testing.assert_array_equal(np.asarray(st_u.K), np.asarray(st_f.K))
    np.testing.assert_array_equal(np.asarray(st_u.nfe), np.asarray(st_f.nfe))
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(res_f, np.float32),
                               np.asarray(res_u, np.float32), **tol)
    # multi-rate actually happened: controller spread the mesh lengths
    assert len(np.unique(np.asarray(st_f.K))) >= 1


@pytest.mark.parametrize("with_g", [True, False])
def test_solve_multirate_fused_matches_per_sample_solves(with_g):
    """solve_multirate with an explicit mixed-K row == stacking per-sample
    fixed-grid solves at each K_i, on the fused path, for a pytree state."""
    g = (lambda eps, s, z, dz: jax.tree_util.tree_map(
        lambda l: 0.2 * l, z)) if with_g else None

    def f(s, state):
        z, aux = state
        k = jax.nn.softplus(jnp.mean(aux, axis=-1))[:, None, None]
        return (-z * k, -0.5 * aux)

    B = 4
    z0 = (jax.random.normal(jax.random.PRNGKey(4), (B, 3, 7)),
          jax.random.normal(jax.random.PRNGKey(5), (B, 2)))
    Ks = jnp.asarray([1, 2, 5, 8], jnp.int32)
    integ = Integrator(get_tableau("midpoint"), g=g, fused=True)
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        out = integ.solve_multirate(f, z0, (0.0, 1.0), Ks, 8)
    for i in range(B):
        zi = jax.tree_util.tree_map(lambda l: l[i:i + 1], z0)
        ref = integ.solve(f, zi, FixedGrid.over(0.0, 1.0, int(Ks[i])),
                          return_traj=False)
        for lo, lr in zip(jax.tree_util.tree_leaves(out),
                          jax.tree_util.tree_leaves(ref)):
            np.testing.assert_allclose(np.asarray(lo[i]), np.asarray(lr[0]),
                                       rtol=1e-6, atol=1e-6)


def test_solve_multirate_rejects_truncating_k_max():
    """A concrete Ks row exceeding k_max would silently stop mid-span —
    the entry point refuses it (traced callers own the invariant)."""
    integ = Integrator(get_tableau("euler"), fused=True)
    z0 = jnp.ones((2, 4))
    with pytest.raises(ValueError, match="truncates"):
        integ.solve_multirate(_field, z0, (0.0, 1.0),
                              jnp.asarray([4, 16]), 8)


# ------------------------------------------------------- engine mixed-K ----

def _toy_model(fused=False):
    from repro.launch.engine import DepthModel

    def field_of(x):
        k = jax.nn.softplus(jnp.mean(x, axis=-1, keepdims=True))
        return lambda s, z: -z * k

    return DepthModel(
        embed=lambda x: x + 0.0,
        field_of=field_of,
        readout=lambda x, zT: zT,
        integ=Integrator(tableau=get_tableau("euler"), fused=fused),
    )


def test_engine_packs_mixed_K_into_one_fused_cell():
    """Requests landing in different buckets pack into ONE batch and ONE
    jit cell (mixed-K masked multi-rate solve), fused, with outputs equal
    to direct per-K solves."""
    from repro.launch.engine import EngineConfig, MultiRateEngine

    rng = np.random.RandomState(0)
    easy = rng.randn(3, 4).astype(np.float32) * 0.05 - 2.0
    hard = rng.randn(3, 4).astype(np.float32) * 0.05 + 3.0
    xs = np.concatenate([easy, hard])
    eng = MultiRateEngine(_toy_model(fused=True),
                          EngineConfig(buckets=(2, 4, 8, 16), tol=5e-3,
                                       max_batch=8, fused=True))
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        res = eng.run(xs)
    assert len({r.K for r in res}) > 1, "bucket mix expected"
    assert len(eng._solve_fns) == 1, (
        "mixed-K batch should be served by a single (shape, k_max) cell, "
        f"got {list(eng._solve_fns)}")
    assert all(r.fused_kernel for r in res)
    model = _toy_model()
    for i, r in enumerate(res):
        x = jnp.asarray(xs[i:i + 1])
        direct = model.integ.solve(model.field_of(x), model.embed(x),
                                   FixedGrid.over(0.0, 1.0, r.K),
                                   return_traj=False)
        np.testing.assert_allclose(np.asarray(r.outputs),
                                   np.asarray(direct[0]), rtol=1e-6,
                                   atol=1e-6)


# ------------------------------------------------------- sharded solve ----

_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import FixedGrid, Integrator, get_tableau
    from repro.core.controllers import EmbeddedErrorController
    from repro.launch.mesh import make_debug_mesh, sharded_solve

    assert jax.device_count() == 4, jax.device_count()
    mesh = make_debug_mesh(n_data=2, n_model=2)
    f = lambda s, z: -z * jnp.tanh(jnp.mean(z, -1, keepdims=True) + 2.0)
    z0 = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
    integ = Integrator(get_tableau("heun"), fused=True)

    grid = FixedGrid.over(0.0, 1.0, 4)
    ref = integ.solve(f, z0, grid, return_traj=False)
    out = sharded_solve(integ, f, z0, grid, mesh=mesh, return_traj=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    print("SHARDED_PLAIN_OK")

    eps = jnp.linspace(0.1, 0.25, 8)
    gb = FixedGrid(0.0, eps, 4)
    out_b = sharded_solve(integ, f, z0, gb, mesh=mesh, return_traj=False)
    ref_b = integ.solve(f, z0, gb, return_traj=False)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(ref_b),
                               rtol=1e-6, atol=1e-6)
    print("SHARDED_BATCHED_EPS_OK")

    ctrl = EmbeddedErrorController(tol=1e-3, k_min=1, k_max=8)
    res, st = integ.solve(f, z0, FixedGrid.over(0.0, 1.0, 8),
                          return_traj=False, controller=ctrl, mesh=mesh)
    res_r, st_r = integ.solve(f, z0, FixedGrid.over(0.0, 1.0, 8),
                              return_traj=False, controller=ctrl)
    np.testing.assert_allclose(np.asarray(res), np.asarray(res_r),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(st.K), np.asarray(st_r.K))
    np.testing.assert_array_equal(np.asarray(st.nfe), np.asarray(st_r.nfe))
    assert st.probe_nfe == st_r.probe_nfe
    print("SHARDED_CONTROLLER_OK")

    try:
        sharded_solve(integ, f, z0[:3], grid, mesh=mesh, return_traj=False)
    except ValueError as e:
        assert "does not divide" in str(e), e
        print("SHARDED_DIVISIBILITY_OK")
""")


def test_sharded_solve_debug_mesh_subprocess():
    """Integrator.solve(mesh=) on a forced 4-device CPU mesh: plain,
    batched-eps, and controller-driven solves all match the single-device
    results shard-for-shard (subprocess — the main test process must keep
    one device)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    out = proc.stdout + proc.stderr
    assert proc.returncode == 0, out[-4000:]
    for marker in ("SHARDED_PLAIN_OK", "SHARDED_BATCHED_EPS_OK",
                   "SHARDED_CONTROLLER_OK", "SHARDED_DIVISIBILITY_OK"):
        assert marker in out, (marker, out[-4000:])
