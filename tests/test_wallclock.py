"""Tier-1 smoke of the wall-clock serving benchmark
(benchmarks/bench_wallclock.py::serving_rows): two tiny seeded traces
through both serving loops. Pins the SCHEMA and the CORRECTNESS gates —
agreement 1.0 (overlap is observationally the sync loop) and the
async-dispatch mechanism — but NOT the wall-clock race outcome: on a
shared 1-core CI box the loops are work-conserving and req/s is noise
(the committed BENCH_wallclock.json's verdict row records the race; the
tier-2 nightly regenerates it at full budget).
"""
import os
import sys

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def rows():
    if REPO_ROOT not in sys.path:
        sys.path.insert(0, REPO_ROOT)
    from benchmarks.bench_wallclock import serving_rows
    return serving_rows("smoke")


def test_serving_rows_cover_both_loops_at_agreement_one(rows):
    serving = [r for r in rows if r.get("section") == "serving"]
    traces = {r["trace"] for r in serving}
    assert len(traces) == 2                      # smoke = 2 seeded traces
    for trace in traces:
        lanes = {r["loop"] for r in serving if r["trace"] == trace}
        assert lanes == {"sync", "overlap"}
    for r in serving:
        assert r["agreement"] == 1.0, r
        assert r["req_per_s"] > 0 and r["wall_s_min"] > 0
        assert r["time_unit"] == "wall_us"
        assert r["ticks"] > 0


def test_mechanism_row_measures_the_async_window(rows):
    (mech,) = [r for r in rows if r.get("section") == "mechanism"]
    assert mech["async_dispatch_ok"], mech       # 11-rep median, ~25x margin
    assert mech["overlap_window_us"] > 0
    assert mech["dispatch_us"] < mech["execute_us"]
    assert isinstance(mech["donation_serializes_dispatch"], bool)


def test_predicted_vs_measured_rows_keep_their_units(rows):
    pvm = [r for r in rows if r.get("section") == "predicted_vs_measured"]
    assert pvm
    for r in pvm:
        assert r["predicted_unit"] == "device_us"
        assert r["measured_unit"] == "wall_us"
        assert r["predicted_device_us_per_segment"] > 0
        assert r["measured_over_predicted"] > 0


def test_verdict_row_and_schema_gate_agree(rows):
    (verdict,) = [r for r in rows if r.get("mode") == "verdict"]
    assert verdict["agreement_all"] == 1.0
    assert verdict["async_dispatch_ok"]
    assert isinstance(verdict["overlap_wins_wallclock"], bool)
    assert verdict["host_cpus"] >= 1
    # the live rows pass the same gate --check applies to the committed
    # BENCH_wallclock.json (benchmarks/run.py)
    from benchmarks.run import _check_wallclock_section
    assert _check_wallclock_section("live", rows) == []
