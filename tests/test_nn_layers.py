"""Unit tests for the NN substrate: attention (incl. cache parity), MoE
dispatch vs loop oracle, RWKV6 recurrence, RG-LRU scans, FFN variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.attention import (
    attention_init, init_cache, mha, mha_decode, precompute_cross_kv,
)
from repro.nn.ffn import ffn_apply, ffn_init, rwkv_channel_mix, rwkv_channel_mix_init
from repro.nn.moe import moe_apply, moe_apply_reference, moe_init
from repro.nn.module import rmsnorm, rmsnorm_init
from repro.nn.rglru import (
    griffin_recurrent_apply, griffin_recurrent_init, rglru_apply, rglru_init,
    rglru_decode_step, rglru_scan_ref, causal_conv1d, causal_conv1d_init,
)
from repro.nn.rwkv6 import rwkv6_decode_step, rwkv6_init, rwkv6_time_mix, wkv6_scan_ref

KEY = jax.random.PRNGKey(0)


# ----------------------------------------------------------- attention ----

def test_gqa_matches_mha_when_kv_equals_heads():
    d, H, hd = 32, 4, 8
    p = attention_init(KEY, d, H, H, hd)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, d))
    out = mha(p, x, n_heads=H, n_kv=H, d_head=hd)
    assert out.shape == (2, 6, d)
    assert not np.any(np.isnan(np.asarray(out)))


def test_causal_mask_blocks_future():
    """Changing a future token must not change past outputs."""
    d, H, KV, hd = 16, 4, 2, 4
    p = attention_init(KEY, d, H, KV, hd)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, d))
    out1 = mha(p, x, n_heads=H, n_kv=KV, d_head=hd)
    x2 = x.at[0, 7].set(99.0)
    out2 = mha(p, x2, n_heads=H, n_kv=KV, d_head=hd)
    np.testing.assert_allclose(np.asarray(out1[0, :7]), np.asarray(out2[0, :7]),
                               rtol=1e-5)
    assert not np.allclose(np.asarray(out1[0, 7]), np.asarray(out2[0, 7]))


def test_sliding_window_limits_receptive_field():
    d, H, KV, hd = 16, 2, 2, 8
    p = attention_init(KEY, d, H, KV, hd)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 10, d))
    out1 = mha(p, x, n_heads=H, n_kv=KV, d_head=hd, window=3)
    x2 = x.at[0, 0].set(50.0)  # token 0 outside window of token 9
    out2 = mha(p, x2, n_heads=H, n_kv=KV, d_head=hd, window=3)
    np.testing.assert_allclose(np.asarray(out1[0, 9]), np.asarray(out2[0, 9]),
                               rtol=1e-5)


def test_decode_matches_prefill():
    """Token-by-token decode must reproduce the full-sequence forward."""
    d, H, KV, hd, S = 24, 6, 2, 4, 7
    p = attention_init(KEY, d, H, KV, hd, qk_norm=True)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, S, d))
    full = mha(p, x, n_heads=H, n_kv=KV, d_head=hd, qk_norm=True)
    cache = init_cache(2, S, KV, hd, dtype=jnp.float32)
    outs = []
    for t in range(S):
        o, cache = mha_decode(p, x[:, t:t + 1], cache, jnp.asarray(t),
                              n_heads=H, n_kv=KV, d_head=hd, qk_norm=True)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), rtol=2e-4,
                               atol=2e-5)


def test_cross_attention_decode():
    d, H, KV, hd = 16, 4, 4, 4
    p = attention_init(KEY, d, H, KV, hd)
    enc = jax.random.normal(jax.random.PRNGKey(4), (2, 5, d))
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 3, d))
    full = mha(p, x, n_heads=H, n_kv=KV, d_head=hd, kv_x=enc, causal=False,
               use_rope=False)
    ckv = precompute_cross_kv(p, enc, n_kv=KV, d_head=hd)
    o0, _ = mha_decode(p, x[:, 1:2], {}, jnp.asarray(1), n_heads=H, n_kv=KV,
                       d_head=hd, cross_kv=ckv, use_rope=False)
    np.testing.assert_allclose(np.asarray(full[:, 1:2]), np.asarray(o0),
                               rtol=1e-4, atol=1e-5)


# ----------------------------------------------------------------- MoE ----

@pytest.mark.parametrize("top_k,E", [(1, 4), (2, 8), (4, 8)])
def test_moe_matches_loop_oracle_with_big_capacity(top_k, E):
    d, d_ff = 16, 32
    p = moe_init(KEY, d, d_ff, E)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 10, d))
    out = moe_apply(p, x, n_experts=E, top_k=top_k, capacity_factor=float(E))
    ref = moe_apply_reference(p, x, n_experts=E, top_k=top_k)
    assert float(out.fraction_dropped) == 0.0
    np.testing.assert_allclose(np.asarray(out.y), np.asarray(ref), rtol=2e-3,
                               atol=2e-4)


def test_moe_capacity_drops_tokens():
    d, d_ff, E = 8, 16, 2
    p = moe_init(KEY, d, d_ff, E)
    x = jax.random.normal(jax.random.PRNGKey(8), (1, 16, d))
    out = moe_apply(p, x, n_experts=E, top_k=1, capacity_factor=0.25)
    assert float(out.fraction_dropped) > 0.0
    assert not np.any(np.isnan(np.asarray(out.y)))
    assert float(out.aux_loss) > 0.0


# --------------------------------------------------------------- RWKV6 ----

def test_wkv6_scan_reference_properties():
    B, T, H, D = 2, 5, 2, 4
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    r = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, H, D))
    v = jax.random.normal(ks[2], (B, T, H, D))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, D)))  # in (0,1)
    u = jnp.full((H, D), 0.5)
    o, S = wkv6_scan_ref(r, k, v, w, u)
    assert o.shape == (B, T, H, D)
    assert S.shape == (B, H, D, D)
    # first output only sees first token: o_0 = r_0 (u * k_0) v_0
    expected0 = jnp.einsum("bhi,bhi,bhj->bhj", r[:, 0], u[None] * k[:, 0],
                           v[:, 0])
    np.testing.assert_allclose(np.asarray(o[:, 0]), np.asarray(expected0),
                               rtol=1e-5)


def test_rwkv6_time_mix_streaming_parity():
    """Processing a sequence in two halves with carried state == full pass."""
    d, H = 32, 4
    p = rwkv6_init(KEY, d, H, lora_rank=8)
    x = jax.random.normal(jax.random.PRNGKey(10), (2, 8, d))
    full, _ = rwkv6_time_mix(p, x, H)
    h1, st = rwkv6_time_mix(p, x[:, :4], H)
    h2, _ = rwkv6_time_mix(p, x[:, 4:], H, state=st)
    merged = jnp.concatenate([h1, h2], axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(merged), rtol=2e-4,
                               atol=2e-5)


def test_rwkv6_decode_step_matches_full():
    d, H = 16, 2
    p = rwkv6_init(KEY, d, H, lora_rank=4)
    x = jax.random.normal(jax.random.PRNGKey(11), (1, 6, d))
    full, _ = rwkv6_time_mix(p, x, H)
    state = None
    outs = []
    for t in range(6):
        o, state = rwkv6_decode_step(p, x[:, t], state, H)
        outs.append(o)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), rtol=2e-4,
                               atol=2e-5)


# -------------------------------------------------------------- RG-LRU ----

def test_rglru_assoc_scan_matches_sequential():
    W = 24
    p = rglru_init(KEY, W)
    x = jax.random.normal(jax.random.PRNGKey(12), (3, 16, W))
    y1, h1 = rglru_apply(p, x)
    y2, h2 = rglru_scan_ref(p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-4,
                               atol=1e-5)


def test_rglru_decode_matches_scan():
    W = 8
    p = rglru_init(KEY, W)
    x = jax.random.normal(jax.random.PRNGKey(13), (2, 5, W))
    y_full, _ = rglru_scan_ref(p, x)
    h = jnp.zeros((2, W), jnp.float32)
    outs = []
    for t in range(5):
        y_t, h = rglru_decode_step(p, x[:, t], h)
        outs.append(y_t)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.stack(outs, 1)), rtol=2e-4,
                               atol=1e-5)


def test_rglru_stability_long_sequence():
    """|h| stays bounded over long sequences (a < 1)."""
    W = 8
    p = rglru_init(KEY, W)
    x = jax.random.normal(jax.random.PRNGKey(14), (1, 512, W))
    y, hT = rglru_apply(p, x)
    assert float(jnp.max(jnp.abs(y))) < 50.0
    assert not np.any(np.isnan(np.asarray(y)))


def test_griffin_block_streaming_parity():
    d, W = 16, 24
    p = griffin_recurrent_init(KEY, d, W)
    x = jax.random.normal(jax.random.PRNGKey(15), (2, 10, d))
    full, _ = griffin_recurrent_apply(p, x)
    y1, st = griffin_recurrent_apply(p, x[:, :5])
    y2, _ = griffin_recurrent_apply(p, x[:, 5:], state=st)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate([y1, y2], 1)),
                               rtol=3e-4, atol=2e-5)


def test_causal_conv1d_shift_invariance():
    W = 6
    p = causal_conv1d_init(KEY, W, 4)
    x = jax.random.normal(jax.random.PRNGKey(16), (1, 12, W))
    y_full, _ = causal_conv1d(p, x)
    y1, carry = causal_conv1d(p, x[:, :7])
    y2, _ = causal_conv1d(p, x[:, 7:], carry)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate([y1, y2], 1)),
                               rtol=1e-5, atol=1e-6)


# ----------------------------------------------------------------- FFN ----

def test_ffn_variants():
    d, f = 8, 16
    x = jax.random.normal(jax.random.PRNGKey(17), (2, 3, d))
    for gated, act in [(True, "silu"), (True, "gelu"), (False, "relu2"),
                       (False, "gelu")]:
        p = ffn_init(KEY, d, f, gated=gated)
        y = ffn_apply(p, x, act=act)
        assert y.shape == x.shape
        assert not np.any(np.isnan(np.asarray(y)))


def test_rwkv_channel_mix_runs():
    d, f = 8, 16
    p = rwkv_channel_mix_init(KEY, d, f)
    x = jax.random.normal(jax.random.PRNGKey(18), (2, 4, d))
    x_prev = jnp.roll(x, 1, axis=1).at[:, 0].set(0.0)
    y = rwkv_channel_mix(p, x, x_prev)
    assert y.shape == x.shape


def test_rmsnorm_scale_invariance_direction():
    p = rmsnorm_init(8)
    x = jax.random.normal(jax.random.PRNGKey(19), (3, 8))
    y1 = rmsnorm(p, x)
    y2 = rmsnorm(p, 10.0 * x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4)


@pytest.mark.parametrize("top_k,E", [(1, 4), (2, 8), (8, 8)])
def test_moe_sorted_matches_loop_oracle(top_k, E):
    from repro.nn.moe import moe_apply_sorted
    d, d_ff = 16, 32
    p = moe_init(KEY, d, d_ff, E)
    x = jax.random.normal(jax.random.PRNGKey(21), (2, 12, d))
    out = moe_apply_sorted(p, x, n_experts=E, top_k=top_k,
                           capacity_factor=float(E))
    ref = moe_apply_reference(p, x, n_experts=E, top_k=top_k)
    assert float(out.fraction_dropped) == 0.0
    np.testing.assert_allclose(np.asarray(out.y), np.asarray(ref), rtol=2e-3,
                               atol=2e-4)


def test_moe_sorted_matches_einsum_dispatch_incl_drops():
    """Same capacity => same kept-token semantics as the einsum dispatch
    (slot-major priority order)."""
    from repro.nn.moe import moe_apply_sorted
    d, d_ff, E = 8, 16, 4
    p = moe_init(KEY, d, d_ff, E)
    x = jax.random.normal(jax.random.PRNGKey(22), (1, 32, d))
    o1 = moe_apply(p, x, n_experts=E, top_k=1, capacity_factor=0.5)
    o2 = moe_apply_sorted(p, x, n_experts=E, top_k=1, capacity_factor=0.5)
    np.testing.assert_allclose(float(o1.fraction_dropped),
                               float(o2.fraction_dropped), atol=1e-6)
    np.testing.assert_allclose(np.asarray(o1.y), np.asarray(o2.y), rtol=2e-3,
                               atol=2e-4)


def test_moe_sorted_grads_flow():
    from repro.nn.moe import moe_apply_sorted
    d, d_ff, E = 8, 16, 4
    p = moe_init(KEY, d, d_ff, E)
    x = jax.random.normal(jax.random.PRNGKey(23), (1, 16, d))

    def loss(pp):
        return jnp.sum(moe_apply_sorted(pp, x, n_experts=E, top_k=2).y ** 2)

    g = jax.grad(loss)(p)
    total = sum(float(jnp.sum(jnp.abs(l)))
                for l in jax.tree_util.tree_leaves(g))
    assert np.isfinite(total) and total > 0


def test_chunked_attention_matches_dense():
    from repro.nn.attention import set_attention_chunking
    d, H, KV, hd, S = 16, 4, 2, 4, 32
    p = attention_init(KEY, d, H, KV, hd)
    x = jax.random.normal(jax.random.PRNGKey(30), (2, S, d))
    for causal, window in [(True, None), (True, 5), (False, None)]:
        ref = mha(p, x, n_heads=H, n_kv=KV, d_head=hd, causal=causal,
                  window=window)
        set_attention_chunking(8)
        try:
            out = mha(p, x, n_heads=H, n_kv=KV, d_head=hd, causal=causal,
                      window=window)
        finally:
            set_attention_chunking(None)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-5,
                                   err_msg=f"{causal},{window}")


def test_moe_int8_dispatch_close_to_fp():
    from repro.nn.moe import moe_apply_sorted
    d, d_ff, E = 16, 32, 4
    p = moe_init(KEY, d, d_ff, E)
    x = jax.random.normal(jax.random.PRNGKey(41), (2, 16, d))
    fp = moe_apply_sorted(p, x, n_experts=E, top_k=2, capacity_factor=4.0)
    q = moe_apply_sorted(p, x, n_experts=E, top_k=2, capacity_factor=4.0,
                         int8_dispatch=True)
    err = float(jnp.mean(jnp.abs(fp.y - q.y)))
    ref = float(jnp.mean(jnp.abs(fp.y))) + 1e-9
    assert err / ref < 0.05, (err, ref)


def test_int8_kv_cache_decode_close_to_bf16():
    d, H, KV, hd, S = 32, 4, 2, 8, 12
    p = attention_init(KEY, d, H, KV, hd)
    x = jax.random.normal(jax.random.PRNGKey(42), (2, S, d))
    full = mha(p, x, n_heads=H, n_kv=KV, d_head=hd)
    cache = init_cache(2, S, KV, hd, kv_int8=True)
    outs = []
    for t in range(S):
        o, cache = mha_decode(p, x[:, t:t + 1], cache, jnp.asarray(t),
                              n_heads=H, n_kv=KV, d_head=hd)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    err = float(jnp.mean(jnp.abs(full - dec)))
    mag = float(jnp.mean(jnp.abs(full))) + 1e-9
    assert err / mag < 0.02, (err, mag)
