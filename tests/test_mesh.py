"""launch/mesh.py policy layer — the request-facing checks that run
BEFORE any device mesh is touched, so they are testable on the
single-device CPU test process (the full sharded-solve semantics run in
the forced-4-device subprocess in test_runtime_eps.py).

Pinned decision: a batch that does not divide the mesh's data-axis size
is a CLEAR ERROR naming the remedy, not silent pad-and-trim — padding
would fabricate requests whose NFE/latency accounting the serving layer
then misreports."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FixedGrid, Integrator, get_tableau
from repro.launch.mesh import batch_axes, sharded_solve


class _StubMesh:
    """Shape/axis metadata double for the pre-dispatch policy checks
    (sharded_solve reads nothing else before raising)."""

    def __init__(self, n_data=3):
        self.shape = {"data": n_data, "model": 2}
        self.axis_names = ("data", "model")


def _field(s, z):
    return -z


def test_sharded_solve_rejects_indivisible_batch():
    """PINNED: batch % data-axis != 0 raises the clear error (with the
    re-bucket remedy) before any shard_map/device work happens."""
    integ = Integrator(get_tableau("euler"))
    z0 = jnp.ones((8, 4))  # 8 % 3 != 0
    with pytest.raises(ValueError, match="does not divide"):
        sharded_solve(integ, _field, z0, FixedGrid.over(0.0, 1.0, 2),
                      mesh=_StubMesh(n_data=3))


def test_sharded_solve_rejects_indivisible_pytree_batch():
    """The divisibility check keys on the leading axis of the FIRST leaf
    — a pytree state hits the same clear error."""
    integ = Integrator(get_tableau("euler"))
    z0 = (jnp.ones((5, 3)), jnp.ones((5, 2)))
    with pytest.raises(ValueError, match="does not divide"):
        sharded_solve(integ, lambda s, z: z, z0,
                      FixedGrid.over(0.0, 1.0, 2), mesh=_StubMesh(n_data=2))


def test_sharded_solve_rejects_bad_eps_rank():
    """grid.eps beyond (B,) is a policy error too, caught pre-dispatch."""
    integ = Integrator(get_tableau("euler"))
    z0 = jnp.ones((6, 4))
    bad = FixedGrid(0.0, jnp.ones((6, 2)), 2)  # eps ndim == 2
    with pytest.raises(ValueError, match="scalar or"):
        sharded_solve(integ, _field, z0, bad, mesh=_StubMesh(n_data=3))


def test_batch_axes_policy():
    assert batch_axes(_StubMesh()) == ("data",)

    class _PodMesh(_StubMesh):
        def __init__(self):
            super().__init__()
            self.axis_names = ("pod", "data", "model")

    assert batch_axes(_PodMesh()) == ("pod", "data")
