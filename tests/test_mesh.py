"""launch/mesh.py policy layer — the request-facing checks that run
BEFORE any device mesh is touched, so they are testable on the
single-device CPU test process (the full sharded-solve semantics run in
the forced-4-device subprocess in test_runtime_eps.py).

Pinned decision: a batch that does not divide the mesh's data-axis size
is a CLEAR ERROR naming the remedy, not silent pad-and-trim — padding
would fabricate requests whose NFE/latency accounting the serving layer
then misreports."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FixedGrid, Integrator, get_tableau
from repro.launch.mesh import batch_axes, sharded_solve


class _StubMesh:
    """Shape/axis metadata double for the pre-dispatch policy checks
    (sharded_solve reads nothing else before raising)."""

    def __init__(self, n_data=3):
        self.shape = {"data": n_data, "model": 2}
        self.axis_names = ("data", "model")


def _field(s, z):
    return -z


def test_sharded_solve_rejects_indivisible_batch():
    """PINNED: batch % data-axis != 0 raises the clear error (with the
    re-bucket remedy) before any shard_map/device work happens."""
    integ = Integrator(get_tableau("euler"))
    z0 = jnp.ones((8, 4))  # 8 % 3 != 0
    with pytest.raises(ValueError, match="does not divide"):
        sharded_solve(integ, _field, z0, FixedGrid.over(0.0, 1.0, 2),
                      mesh=_StubMesh(n_data=3))


def test_sharded_solve_rejects_indivisible_pytree_batch():
    """The divisibility check keys on the leading axis of the FIRST leaf
    — a pytree state hits the same clear error."""
    integ = Integrator(get_tableau("euler"))
    z0 = (jnp.ones((5, 3)), jnp.ones((5, 2)))
    with pytest.raises(ValueError, match="does not divide"):
        sharded_solve(integ, lambda s, z: z, z0,
                      FixedGrid.over(0.0, 1.0, 2), mesh=_StubMesh(n_data=2))


def test_sharded_solve_rejects_bad_eps_rank():
    """grid.eps beyond (B,) is a policy error too, caught pre-dispatch."""
    integ = Integrator(get_tableau("euler"))
    z0 = jnp.ones((6, 4))
    bad = FixedGrid(0.0, jnp.ones((6, 2)), 2)  # eps ndim == 2
    with pytest.raises(ValueError, match="scalar or"):
        sharded_solve(integ, _field, z0, bad, mesh=_StubMesh(n_data=3))


def test_solve_segment_rejects_indivisible_slot_count():
    """PINNED (mirroring the batch-axis decision): a slot pool the mesh
    axis cannot split row-wise is a CLEAR ERROR naming the remedy, raised
    before any shard_map/device work happens."""
    from repro.core import make_segment_carry

    integ = Integrator(get_tableau("euler"))
    carry = make_segment_carry(jnp.ones((8, 4)), jnp.full((8,), 2),
                               (0.0, 1.0))  # 8 slots % 3 != 0
    with pytest.raises(ValueError, match="does not divide"):
        integ.solve_segment(_field, carry, 2, mesh=_StubMesh(n_data=3))


def test_sharded_segment_rejects_indivisible_slot_count():
    """The conditioning-threading helper hits the same pre-dispatch check."""
    from repro.core import make_segment_carry
    from repro.launch.mesh import sharded_segment

    integ = Integrator(get_tableau("euler"))
    carry = make_segment_carry(jnp.ones((5, 4)), jnp.full((5,), 2),
                               (0.0, 1.0))
    with pytest.raises(ValueError, match="does not divide"):
        sharded_segment(integ, lambda x: _field, jnp.ones((5, 4)), carry,
                        2, mesh=_StubMesh(n_data=2))


def test_inflight_scheduler_rejects_indivisible_slots():
    """InflightScheduler(mesh=) validates the GLOBAL pool width against
    the slot axis at construction — before any queue state exists."""
    from repro.launch.engine import DepthModel, EngineConfig
    from repro.launch.scheduler import InflightScheduler

    model = DepthModel(embed=lambda x: x, field_of=lambda x: _field,
                       readout=lambda x, zT: zT,
                       integ=Integrator(get_tableau("euler")))
    with pytest.raises(ValueError, match="does not divide"):
        InflightScheduler(model, EngineConfig(), slots=5, seg=2,
                          mesh=_StubMesh(n_data=3))


def test_make_serving_mesh_rejects_oversubscription():
    """--mesh N beyond the visible device count is a clear error naming
    the XLA_FLAGS remedy, not an opaque make_mesh failure."""
    import jax

    from repro.launch.mesh import make_serving_mesh

    with pytest.raises(ValueError, match="XLA_FLAGS"):
        make_serving_mesh(jax.device_count() + 1)
    with pytest.raises(ValueError, match=">= 1"):
        make_serving_mesh(0)


def test_batch_axes_policy():
    assert batch_axes(_StubMesh()) == ("data",)

    class _PodMesh(_StubMesh):
        def __init__(self):
            super().__init__()
            self.axis_names = ("pod", "data", "model")

    assert batch_axes(_PodMesh()) == ("pod", "data")
