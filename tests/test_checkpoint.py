"""Checkpoint manager: roundtrip, atomicity (partial writes invisible),
keep-N GC, async save, restore into different structure-alike trees."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (16, 8)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jnp.ones((3,), jnp.bfloat16)}}


def test_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    cm.save(3, t)
    assert cm.latest_step() == 3
    out = cm.restore(3, jax.eval_shape(lambda: t))
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_keep_n_gc(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, _tree(s))
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4]


def test_partial_write_is_invisible(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=3)
    cm.save(1, _tree())
    # simulate a crash mid-write: tmp dir exists, no manifest rename
    staging = tmp_path / ".tmp_step_2"
    staging.mkdir()
    (staging / "0.npy.zst").write_bytes(b"garbage")
    # and a torn final dir without manifest
    torn = tmp_path / "step_5"
    torn.mkdir()
    assert cm.latest_step() == 1


def test_async_save_then_restore(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    t = _tree(7)
    cm.save(10, t)
    cm.wait()
    out = cm.restore(10, jax.eval_shape(lambda: t))
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(t["a"]))


def test_restore_latest_none(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    step, state = cm.restore_latest(None)
    assert step is None and state is None


def test_save_while_restore_latest_race(tmp_path):
    """REGRESSION (PR 9): with ``keep=1`` + ``async_save=True``, the
    async writer's publish+GC could delete the very step a concurrent
    ``restore_latest`` had just picked, crashing the reader with
    FileNotFoundError mid-read. The per-directory lock makes publish+GC
    and pick+read atomic against each other (plus a bounded rescan for
    cross-process deleters) — a second manager instance on the SAME
    directory shares the lock, so this hammers writer and reader from
    two threads and requires zero read failures."""
    import threading

    cm_w = CheckpointManager(str(tmp_path), keep=1, async_save=True)
    cm_r = CheckpointManager(str(tmp_path), keep=1)   # shared dir lock
    t = _tree()
    like = jax.eval_shape(lambda: t)
    cm_w.save(0, t)
    cm_w.wait()

    errors = []
    seen_steps = []
    stop = threading.Event()

    def reader():
        while not stop.is_set():
            try:
                step, state = cm_r.restore_latest(like)
                assert step is not None and state is not None
                seen_steps.append(step)
            except Exception as e:      # noqa: BLE001 — the regression
                errors.append(e)
                return

    th = threading.Thread(target=reader)
    th.start()
    for s in range(1, 40):
        cm_w.save(s, _tree(s))
    cm_w.wait()
    stop.set()
    th.join(timeout=60)
    assert not th.is_alive()
    assert not errors, errors
    assert seen_steps and seen_steps == sorted(seen_steps), (
        "restore_latest went back in time")


def test_shape_mismatch_rejected(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, {"a": jnp.ones((4,))})
    with pytest.raises(AssertionError):
        cm.restore(1, {"a": jax.ShapeDtypeStruct((5,), jnp.float32)})
