"""Property-based tests (hypothesis) for solver/hypersolver invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the hypothesis dev-dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    EULER, HEUN, MIDPOINT, RK4, FixedGrid, HyperSolver, alpha_family,
    get_tableau, odeint_fixed, rk_psi, solver_residual, tree_lincomb,
)

# x64 enabled per-module via tests/conftest.py

TABS = [EULER, MIDPOINT, HEUN, RK4]

finite_f = st.floats(
    min_value=-2.0, max_value=2.0, allow_nan=False, allow_infinity=False
)


@st.composite
def small_vec(draw, n=3):
    return jnp.asarray([draw(finite_f) for _ in range(n)], dtype=jnp.float64)


@settings(max_examples=25, deadline=None)
@given(z=small_vec(), c=small_vec())
def test_constant_field_consistency(z, c):
    """For f == const, any consistent RK method gives psi == const exactly
    (sum of b weights is 1)."""
    f = lambda s, _z: c
    for tab in TABS:
        psi, _ = rk_psi(f, tab, 0.0, 0.1, z)
        np.testing.assert_allclose(np.asarray(psi), np.asarray(c), rtol=1e-12,
                                   atol=1e-300)


@settings(max_examples=25, deadline=None)
@given(z=small_vec(), a=finite_f, b=finite_f)
def test_psi_linearity_in_field(z, a, b):
    """psi is linear in f for linear fields sharing the same trajectory ops:
    rk_psi(alpha*f) == alpha * rk_psi(f) for Euler (single-stage)."""
    f = lambda s, zz: a * zz + b
    psi1, _ = rk_psi(f, EULER, 0.0, 0.05, z)
    psi2, _ = rk_psi(lambda s, zz: 2.0 * f(s, zz), EULER, 0.0, 0.05, z)
    np.testing.assert_allclose(np.asarray(psi2), 2 * np.asarray(psi1), rtol=1e-12)


@settings(max_examples=25, deadline=None)
@given(
    z=small_vec(),
    r=small_vec(),
    eps=st.floats(min_value=0.05, max_value=0.5),
    tab_idx=st.integers(min_value=0, max_value=3),
)
def test_residual_roundtrip(z, r, eps, tab_idx):
    """If z_{k+1} := z_k + eps psi + eps^{p+1} r then the residual is r.

    (eps bounded below: dividing by eps^{p+1} amplifies fp64 rounding of the
    O(1) state — the roundtrip is ill-conditioned for tiny eps.)
    """
    tab = TABS[tab_idx]
    f = lambda s, zz: jnp.tanh(zz)
    psi, _ = rk_psi(f, tab, 0.0, eps, z)
    z_next = z + eps * psi + eps ** (tab.order + 1) * r
    resid, dz = solver_residual(f, tab, 0.0, eps, z, z_next)
    np.testing.assert_allclose(np.asarray(resid), np.asarray(r), rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(dz), np.asarray(f(0.0, z)))


@settings(max_examples=15, deadline=None)
@given(z=small_vec(), eps=st.floats(min_value=1e-3, max_value=0.3))
def test_hypersolver_step_decomposition(z, eps):
    """hyper step == base step + eps^{p+1} g, exactly."""
    f = lambda s, zz: jnp.sin(zz)
    g_val = jnp.asarray([0.3, -0.2, 0.1], jnp.float64)
    for tab in TABS:
        hs0 = HyperSolver(tableau=tab, g=None)
        hs1 = HyperSolver(tableau=tab, g=lambda e, s, zz, dz: g_val)
        base, _, _ = hs0.step(f, 0.0, eps, z)
        hyper, _, _ = hs1.step(f, 0.0, eps, z)
        np.testing.assert_allclose(
            np.asarray(hyper - base),
            eps ** (tab.order + 1) * np.asarray(g_val),
            rtol=1e-10, atol=1e-12,
        )


@settings(max_examples=10, deadline=None)
@given(K=st.integers(min_value=1, max_value=12))
def test_scan_matches_python_loop(K):
    f = lambda s, z: -0.7 * z + jnp.sin(s)
    z0 = jnp.asarray([1.0, -2.0], jnp.float64)
    grid = FixedGrid.over(0.0, 1.0, K)
    traj = odeint_fixed(f, z0, grid, HEUN, return_traj=True)
    z = z0
    for k in range(K):
        s = grid.s0 + k * grid.eps
        psi, _ = rk_psi(f, HEUN, s, grid.eps, z)
        z = z + grid.eps * psi
    np.testing.assert_allclose(np.asarray(traj[-1]), np.asarray(z), rtol=1e-12)


@settings(max_examples=20, deadline=None)
@given(alpha=st.floats(min_value=0.05, max_value=2.0))
def test_alpha_family_consistency(alpha):
    tab = alpha_family(alpha)
    tab.validate()
    assert abs(sum(tab.b) - 1.0) < 1e-12


def test_lincomb_skips_zeros():
    trees = [jnp.ones(3), jnp.full(3, 2.0)]
    out = tree_lincomb((0.0, 0.5), trees)
    np.testing.assert_allclose(np.asarray(out), 1.0)
    out0 = tree_lincomb((0.0, 0.0), trees)
    np.testing.assert_allclose(np.asarray(out0), 0.0)


# ------------------------------------------- controller-policy properties ----
# (core/controllers.py + the engine's bucket snap — the selection rules
# the serving ladder routes on)

from repro.core.controllers import (  # noqa: E402
    MAX_FACTOR, MIN_FACTOR, error_ratio, mesh_for_tolerance, step_factor,
)
from repro.launch.engine import snap_to_buckets  # noqa: E402

pos_f = st.floats(min_value=1e-8, max_value=1e8,
                  allow_nan=False, allow_infinity=False)


@settings(max_examples=25, deadline=None)
@given(z=small_vec(), err=small_vec(), scale=st.floats(min_value=1.0,
                                                       max_value=16.0))
def test_error_ratio_monotone_in_err(z, err, scale):
    """Uniformly inflating the error estimate never shrinks the ratio
    (the accept/reject decision is monotone in the evidence)."""
    r1 = float(error_ratio(z, z, err, 1e-6, 1e-3))
    r2 = float(error_ratio(z, z, jnp.asarray(err) * scale, 1e-6, 1e-3))
    assert r2 >= r1 - 1e-12
    assert r1 >= 0.0


@settings(max_examples=50, deadline=None)
@given(r1=pos_f, r2=pos_f, order=st.integers(min_value=1, max_value=4))
def test_step_factor_monotone_and_clamped(r1, r2, order):
    """A worse (larger) error ratio never asks for a LARGER next step,
    and the multiplier always lands inside the classical safety clamp —
    for every ratio, including the extremes."""
    f1, f2 = float(step_factor(r1, order)), float(step_factor(r2, order))
    if r1 <= r2:
        assert f1 >= f2 - 1e-12
    for f in (f1, f2):
        assert MIN_FACTOR - 1e-12 <= f <= MAX_FACTOR + 1e-12


@settings(max_examples=50, deadline=None)
@given(
    err=st.lists(st.floats(min_value=0.0, max_value=1e6), min_size=1,
                 max_size=6),
    tol=st.floats(min_value=1e-6, max_value=10.0),
    q=st.integers(min_value=1, max_value=4),
    k_max=st.integers(min_value=1, max_value=64),
)
def test_mesh_for_tolerance_bounds_and_nan(err, tol, q, k_max):
    """Selected K always lands in [k_min, k_max]; a non-finite probe
    error must select k_max (the probe blowing up means maximally hard),
    never the smallest mesh via an int cast of NaN."""
    e = jnp.asarray(err, jnp.float32)
    Ks = np.asarray(mesh_for_tolerance(e, tol, q, 1, k_max))
    assert ((Ks >= 1) & (Ks <= k_max)).all()
    bad = jnp.asarray([np.nan, np.inf] + list(err), jnp.float32)
    Kb = np.asarray(mesh_for_tolerance(bad, tol, q, 1, k_max))
    assert Kb[0] == k_max and Kb[1] == k_max


@settings(max_examples=50, deadline=None)
@given(
    buckets=st.lists(st.integers(min_value=1, max_value=64), min_size=1,
                     max_size=6, unique=True),
    Ks=st.lists(st.integers(min_value=1, max_value=128), min_size=1,
                max_size=8),
)
def test_snap_to_buckets_idempotent_and_clamped(buckets, Ks):
    """Snapping is idempotent (a snapped mesh is a fixed point), lands
    on a configured bucket, never integrates coarser than asked below
    the top bucket, and clamps overshooting K down to the largest
    bucket."""
    Ks = np.asarray(Ks, np.int32)
    snapped = snap_to_buckets(Ks, buckets)
    assert np.array_equal(snap_to_buckets(snapped, buckets), snapped)
    assert np.isin(snapped, np.asarray(buckets, np.int32)).all()
    top = max(buckets)
    under = Ks <= top
    assert (snapped[under] >= Ks[under]).all()
    assert (snapped[~under] == top).all()
