"""Request-hardening layer (PR 8): non-finite quarantine, bounded retry
ladder, per-request deadlines, overload policies, and the seeded chaos
source — through BOTH serving loops (drain MultiRateEngine + in-flight
InflightScheduler, sync and overlap), plus the watchdog's NaN screen and
the probe-clamp observability fix.

The acceptance pins:
  * zero-hang — every submitted uid reaches exactly one terminal record
    under every fault mix (quarantine, dropped flags, deadlines,
    overload);
  * the terminal-status enum is exhaustive and live
    (``engine.STATUSES``);
  * sync and overlap resolve identical fault schedules to bitwise-
    identical records (the injector hashes (seed, site, uid/tick),
    never call order);
  * the sharded (forced 4-device) pool quarantines and evicts exactly
    like the single-device pool (subprocess).
"""
import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

from repro.distributed.fault import (
    FaultInjector, RetryPolicy, StepFailure, StepWatchdog, WatchdogConfig,
)
from repro.launch.engine import (
    STATUSES, EngineConfig, MultiRateEngine, QueueFull, next_bucket_above,
    screen_probe_errors,
)
from repro.launch.scheduler import InflightScheduler
from repro.launch.workload import (
    Arrival, heterogeneous_requests, latency_stats, ok_records,
    poisson_trace, replay_engine, replay_scheduler, status_counts,
    toy_classifier, toy_flow_classifier,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ECFG = EngineConfig(buckets=(2, 4, 8, 16), tol=5e-3, max_batch=8,
                    solver="euler", fused=True)
# NOT d=8: the fused segment kernel is built once per signature
# (globally cached, TRACE_COUNTS-pinned), and test_scheduler.py's
# one-trace-per-cell acceptance test asserts ITS d=8 replay compiles the
# cell fresh — a distinct width here keeps the suites independent
D = 10


def _sched(inj=None, overlap=False, **kw):
    return InflightScheduler(toy_classifier(d=D), ECFG, slots=4, seg=2,
                             overlap=overlap, fault_injector=inj, **kw)


def _engine(inj=None, **kw):
    return MultiRateEngine(toy_classifier(d=D), ECFG, fault_injector=inj,
                           **kw)


def _trace(n=16, seed=3, rate=0.05, **kw):
    xs = heterogeneous_requests(n, D, seed=seed)
    return poisson_trace(xs, rate=rate, seed=seed + 100, **kw)


def _zero_hang(rep, n):
    uids = [r.uid for r in rep.records]
    assert len(uids) == n and len(set(uids)) == n, (
        f"expected {n} terminal records, got {len(uids)} "
        f"({len(set(uids))} unique)")


# ------------------------------------------------------- watchdog screen ----

def test_watchdog_owns_nan_screen():
    """BUGFIX pin: ``nan_is_failure`` now acts inside ``run()`` via
    ``loss_of`` — callers no longer re-implement the check ad hoc."""
    wd = StepWatchdog(WatchdogConfig(nan_is_failure=True))
    assert wd.run(lambda: {"loss": 1.0}, loss_of=lambda o: o["loss"]) \
        == {"loss": 1.0}
    with pytest.raises(StepFailure, match="non-finite loss"):
        wd.run(lambda: {"loss": float("nan")}, loss_of=lambda o: o["loss"])
    with pytest.raises(StepFailure, match="non-finite loss"):
        wd.run(lambda: {"loss": float("inf")}, loss_of=lambda o: o["loss"])
    # the config switch disables the screen; no loss_of -> no screen
    wd2 = StepWatchdog(WatchdogConfig(nan_is_failure=False))
    wd2.run(lambda: {"loss": float("nan")}, loss_of=lambda o: o["loss"])
    wd.run(lambda: {"loss": float("nan")})


def test_watchdog_reset_on_success_closes_incident_window():
    """``reset_on_success=True`` makes the restart budget bound
    CONSECUTIVE failures; the default (False) keeps the historical
    lifetime accounting that test_fault_tolerance.py pins."""
    cfg = WatchdogConfig(max_restarts=2, reset_on_success=True)
    wd = StepWatchdog(cfg)
    assert wd.record_failure() and wd.record_failure()
    assert wd.restarts == 2
    wd.run(lambda: {"loss": 0.5}, loss_of=lambda o: o["loss"])
    assert wd.restarts == 0          # clean step closed the window
    assert wd.record_failure()       # budget is fresh again
    # default: restarts accumulate across clean steps
    wd_legacy = StepWatchdog(WatchdogConfig(max_restarts=2))
    assert wd_legacy.record_failure()
    wd_legacy.run(lambda: {"loss": 0.5}, loss_of=lambda o: o["loss"])
    assert wd_legacy.restarts == 1


# ------------------------------------------------- retry policy + hashes ----

def test_retry_policy_bounds_and_statuses():
    p = RetryPolicy()
    assert p.should_retry("diverged", 0)
    assert not p.should_retry("diverged", 1)    # max_retries=1
    assert not p.should_retry("deadline", 0)    # not retried by default
    assert not p.should_retry("ok", 0)
    opt = RetryPolicy(max_retries=2, retry_statuses=("diverged",
                                                     "deadline"))
    assert opt.should_retry("deadline", 1)
    assert not opt.should_retry("deadline", 2)


def test_next_bucket_above_is_the_escalation_rule():
    assert next_bucket_above(2, (2, 4, 8)) == 4
    assert next_bucket_above(5, (2, 4, 8)) == 8
    assert next_bucket_above(8, (2, 4, 8)) is None
    assert next_bucket_above(0, (8, 2, 4)) == 2   # unsorted buckets ok


def test_fault_injector_decisions_are_call_order_free():
    """Every decision re-draws identically for the same keys — the root
    of sync/overlap fault-schedule parity."""
    inj = FaultInjector(seed=7, nan_uid_frac=0.5, drop_flag_p=0.5,
                        straggle_tick_frac=0.5)
    x = np.ones((4,), np.float32)
    a = [np.isnan(inj.corrupt_admission(u, 0, x)).any()
         for u in range(20)]
    b = [np.isnan(inj.corrupt_admission(u, 0, x)).any()
         for u in reversed(range(20))]
    assert a == b[::-1] and any(a) and not all(a)
    assert inj.corrupt_admission(3, 0, x) is not x or not a[3]
    # transient: attempts > 0 re-admit clean
    poisoned = [u for u in range(20) if a[u]]
    assert not np.isnan(
        inj.corrupt_admission(poisoned[0], 1, x)).any()
    costs = [inj.inflate_segment_cost(t, 1.0) for t in range(20)]
    assert costs == [inj.inflate_segment_cost(t, 1.0) for t in range(20)]
    assert any(c > 1.0 for c in costs) and not all(c > 1.0 for c in costs)
    uids = np.arange(6)
    segs = np.zeros(6, np.int32)
    fin = np.ones(6, bool)
    out1 = inj.drop_retire_flags(uids, segs, fin)
    assert (out1 == inj.drop_retire_flags(uids, segs, fin)).all()
    # keyed on the segment count: a dropped flag is re-drawn next segment
    later = inj.drop_retire_flags(uids, segs + 1, fin)
    assert not (out1 == later).all() or out1.all()


# ------------------------------------------------ probe-clamp visibility ----

def test_probe_nonfinite_screen_warns_once_and_counts():
    """BUGFIX pin: ``mesh_for_tolerance`` silently clamps a non-finite
    probe k to k_max inside jit; the host-side screen makes that
    observable (one-time warning + counter) for both loops."""
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert screen_probe_errors(np.asarray([1.0, np.nan, np.inf])) == 2
        assert screen_probe_errors(np.asarray([np.nan])) == 1  # latched
    assert len(w) == 1 and "k_max" in str(w[0].message)
    assert screen_probe_errors(np.asarray([0.5, 2.0])) == 0


def test_probe_nonfinite_counter_reaches_both_reports():
    """A NaN-poisoned admission surfaces in StepReport.probe_nonfinite
    (engine) and TickReport.probe_nonfinite (scheduler)."""
    inj = FaultInjector(seed=1, nan_uid_frac=1.0, nan_transient=False)
    xs = heterogeneous_requests(3, D, seed=0)
    eng = _engine(inj, retry=RetryPolicy(max_retries=0))
    for x in xs:
        eng.submit(x)
    done = eng.step()
    assert eng.last_report.probe_nonfinite == 3
    assert all(c.status == "diverged" for c in done)
    sched = _sched(inj, retry=RetryPolicy(max_retries=0))
    for x in xs:
        sched.submit(x)
    sched.step()
    assert sched.last_report.probe_nonfinite == 3


# --------------------------------------------------- quarantine + retry ----

@pytest.mark.parametrize("overlap", [False, True])
def test_scheduler_quarantine_retries_then_diverges(overlap):
    """Transient poison -> one quarantine + requeue at an escalated
    K_floor -> clean re-run retires ``retried`` with finite outputs and
    the failed attempt's work billed into nfe. Persistent poison ->
    best-effort ``diverged`` with the non-finite partial readout."""
    n = 12
    trace = _trace(n)
    inj_t = FaultInjector(seed=1, nan_uid_frac=0.3, nan_transient=True)
    rep = replay_scheduler(_sched(inj_t, overlap=overlap), trace)
    _zero_hang(rep, n)
    counts = status_counts(rep)
    assert counts["retried"] >= 1 and counts["diverged"] == 0
    assert set(counts) == set(STATUSES)
    clean = {r.uid: r for r in
             replay_scheduler(_sched(None, overlap=overlap),
                              _trace(n)).records}
    for r in rep.records:
        if r.status == "retried":
            assert np.isfinite(r.outputs).all()
            assert r.nfe > clean[r.uid].nfe   # failed attempt is billed
        else:
            assert r.status == "ok"
            # untouched requests keep their accounting; outputs may move
            # by an ulp (quarantined slots change batch composition, and
            # with it the compiled kernel's reduction order) — BITWISE
            # parity is only promised fault-run-to-fault-run, which
            # test_overlap_parity_under_faults pins
            assert r.nfe == clean[r.uid].nfe
            assert np.allclose(r.outputs, clean[r.uid].outputs,
                               rtol=1e-5, atol=1e-6)

    inj_p = FaultInjector(seed=1, nan_uid_frac=0.3, nan_transient=False)
    rep_p = replay_scheduler(_sched(inj_p, overlap=overlap), _trace(n))
    _zero_hang(rep_p, n)
    diverged = [r for r in rep_p.records if r.status == "diverged"]
    assert diverged
    for r in diverged:
        assert r.outputs is not None
        assert not np.isfinite(r.outputs).all()   # best-effort partial
    assert len(ok_records(rep_p).records) == n - len(diverged)


def test_scheduler_dropped_retire_flags_still_terminate():
    """A lost completion signal is re-drawn per segment (keyed on the
    slot's segment count), so every request still terminates ``ok`` —
    just later. Zero-hang for p < 1."""
    n = 12
    inj = FaultInjector(seed=2, drop_flag_p=0.5)
    rep = replay_scheduler(_sched(inj), _trace(n))
    _zero_hang(rep, n)
    assert all(r.status == "ok" for r in rep.records)
    clean = replay_scheduler(_sched(None), _trace(n))
    # dropped flags cost extra segments somewhere on the trace
    assert rep.total_cost >= clean.total_cost


# -------------------------------------------------------------- deadlines ----

@pytest.mark.parametrize("overlap", [False, True])
def test_deadline_eviction_and_queue_drop(overlap):
    """Stragglers (inflated segment cost) push requests past their
    deadline: in-slot rows evict with the partial readout, queued rows
    drop with no outputs — each uid exactly once (no double-retire under
    overlap's lagged retire)."""
    n = 16
    inj = FaultInjector(seed=5, straggle_tick_frac=0.4,
                        straggle_factor=8.0)
    trace = _trace(n, deadline_slack=60.0)
    rep = replay_scheduler(_sched(inj, overlap=overlap), trace)
    _zero_hang(rep, n)
    counts = status_counts(rep)
    assert counts["deadline"] >= 1, counts
    for r in rep.records:
        if r.status == "deadline":
            assert r.t_done - r.t_submit >= 0
        else:
            assert r.status in ("ok", "retried")
    # a finished request is never evicted: every ok row has outputs
    assert all(r.outputs is not None for r in rep.records
               if r.status == "ok")


def test_deadline_expired_in_queue_drops_without_probe():
    """A request whose deadline passed while it queued drops terminally
    at admission — no slot, no probe cost, outputs None."""
    sched = _sched(None)
    xs = heterogeneous_requests(6, D, seed=0)
    # fill the pool, then queue one request with a deadline that will
    # expire while it waits
    for x in xs[:4]:
        sched.submit(x)
    late = sched.submit(xs[4], deadline=sched.now + 1e-9)
    done = {}
    while sched.pending:
        for c in sched.step():
            done[c.uid] = c
    assert done[late].status == "deadline"
    assert done[late].outputs is None and done[late].segments == 0
    assert all(c.status == "ok" for u, c in done.items() if u != late)


def test_deadline_retry_opt_in():
    """``retry_statuses=("diverged", "deadline")`` opts deadline
    evictions into the ladder — bounded, so they still terminate."""
    n = 12
    inj = FaultInjector(seed=5, straggle_tick_frac=0.4,
                        straggle_factor=8.0)
    rep = replay_scheduler(
        _sched(inj, retry=RetryPolicy(retry_statuses=("diverged",
                                                      "deadline"))),
        _trace(n, deadline_slack=60.0))
    _zero_hang(rep, n)


# --------------------------------------------------------------- overload ----

def test_overload_shed_refuses_terminally():
    sched = _sched(None, queue_cap=2, overload_policy="shed")
    xs = heterogeneous_requests(10, D, seed=0)
    uids = [sched.submit(x) for x in xs]
    done = {}
    while sched.pending:
        for c in sched.step():
            done[c.uid] = c
    assert set(done) == set(uids)
    counts = {}
    for c in done.values():
        counts[c.status] = counts.get(c.status, 0) + 1
    # the admission queue is the capacity boundary: 2 queue, 8 shed at
    # submit time (slots only fill at the next tick)
    assert counts == {"ok": 2, "shed": 8}, counts
    assert all(done[u].outputs is None for u in uids
               if done[u].status == "shed")


def test_overload_block_raises_and_can_submit_gates():
    sched = _sched(None, queue_cap=1, overload_policy="block")
    xs = heterogeneous_requests(3, D, seed=0)
    assert sched.can_submit()
    sched.submit(xs[0])
    assert not sched.can_submit()
    with pytest.raises(QueueFull):
        sched.submit(xs[1])
    sched.step()                      # admits into slots, queue frees
    assert sched.can_submit()
    sched.submit(xs[1])
    while sched.pending:
        sched.step()


def test_overload_degrade_caps_k_under_pressure():
    """Over-pressure admissions serve one bucket coarser — nothing is
    refused, agreement degrades instead of availability."""
    xs = np.full((10, D), 3.0, np.float32)   # hard rows -> fine buckets
    burst = [Arrival(t=0.0, x=x) for x in xs]
    rep_free = replay_scheduler(_sched(None), list(burst))
    rep_deg = replay_scheduler(
        _sched(None, queue_cap=2, overload_policy="degrade"), list(burst))
    _zero_hang(rep_deg, 10)
    assert all(r.status == "ok" for r in rep_deg.records)
    k_free = {r.uid: r.K for r in rep_free.records}
    assert any(r.K < k_free[r.uid] for r in rep_deg.records)


def test_engine_overload_and_retry_paths():
    """The drain engine honors the same contracts: shed/block caps,
    transient-NaN retry (``retried``), persistent-NaN best-effort
    (``diverged``)."""
    xs = heterogeneous_requests(8, D, seed=0)
    eng = _engine(None, queue_cap=2, overload_policy="shed")
    uids = [eng.submit(x) for x in xs]
    done = {}
    while len(eng):
        for c in eng.step():
            done[c.uid] = c
    assert set(done) == set(uids)
    assert sum(1 for c in done.values() if c.status == "shed") == 6
    eng_b = _engine(None, queue_cap=1, overload_policy="block")
    eng_b.submit(xs[0])
    assert not eng_b.can_submit()
    with pytest.raises(QueueFull):
        eng_b.submit(xs[1])
    inj = FaultInjector(seed=1, nan_uid_frac=0.4, nan_transient=True)
    rep = replay_engine(_engine(inj), _trace(12))
    _zero_hang(rep, 12)
    assert status_counts(rep)["retried"] >= 1
    inj_p = FaultInjector(seed=1, nan_uid_frac=0.4, nan_transient=False)
    rep_p = replay_engine(_engine(inj_p), _trace(12))
    _zero_hang(rep_p, 12)
    assert status_counts(rep_p)["diverged"] >= 1


# -------------------------------------------------------- pool exhaustion ----

def test_pool_survives_total_quarantine():
    """EDGE: every slot quarantined in one tick — the pool frees all
    rows, the retry ladder requeues them, and the very next tick
    re-admits; nothing deadlocks, everything terminates."""
    inj = FaultInjector(seed=0, nan_uid_frac=1.0, nan_transient=True)
    sched = _sched(inj)
    xs = heterogeneous_requests(4, D, seed=0)   # exactly the pool width
    uids = [sched.submit(x) for x in xs]
    done = {}
    guard = 0
    while sched.pending:
        guard += 1
        assert guard < 200, "pool deadlocked after total quarantine"
        for c in sched.step():
            done[c.uid] = c
    assert set(done) == set(uids)
    assert all(c.status == "retried" for c in done.values())
    assert sched.last_report is not None
    # and with retries exhausted: terminal diverged, still no hang
    inj_p = FaultInjector(seed=0, nan_uid_frac=1.0, nan_transient=False)
    sched_p = _sched(inj_p, retry=RetryPolicy(max_retries=0))
    uids_p = [sched_p.submit(x) for x in xs]
    done_p = {}
    while sched_p.pending:
        for c in sched_p.step():
            done_p[c.uid] = c
    assert set(done_p) == set(uids_p)
    assert all(c.status == "diverged" for c in done_p.values())


# ------------------------------------------------ sync/overlap fault parity ----

def test_overlap_parity_under_faults():
    """ACCEPTANCE: the pipelined loop resolves the SAME fault schedule
    to bitwise-identical terminal records — statuses, stamps, nfe,
    outputs — because injector decisions hash keys, not call order."""
    n = 14
    mixes = [
        FaultInjector(seed=1, nan_uid_frac=0.3, nan_transient=True),
        FaultInjector(seed=2, drop_flag_p=0.4),
        FaultInjector(seed=5, straggle_tick_frac=0.4,
                      straggle_factor=8.0),
    ]
    for inj in mixes:
        kw = {"deadline": 80.0} if inj.straggle_tick_frac else {}
        a = {r.uid: r for r in replay_scheduler(
            _sched(inj, **kw), _trace(n)).records}
        b = {r.uid: r for r in replay_scheduler(
            _sched(inj, overlap=True, **kw), _trace(n)).records}
        assert set(a) == set(b)
        for u in a:
            ra, rb = a[u], b[u]
            assert (ra.status, ra.K, ra.nfe, ra.t_submit, ra.t_admit,
                    ra.t_done) == (rb.status, rb.K, rb.nfe, rb.t_submit,
                                   rb.t_admit, rb.t_done), (ra, rb)
            if ra.outputs is None:
                assert rb.outputs is None
            else:
                assert np.array_equal(ra.outputs, rb.outputs,
                                      equal_nan=True)


# --------------------------------------------- flow-tier escalation path ----
# (PR 10: a NaN-poisoned K=0 flow eval must quarantine and requeue into
# the K-bucket ladder — never hang, never serve the poison)

import dataclasses as _dc  # noqa: E402

FLOW_ECFG = _dc.replace(ECFG, solver="hyper_euler",
                        flow_threshold=0.25)


def _flow_sched(inj=None, overlap=False, **kw):
    return InflightScheduler(toy_flow_classifier(d=D), FLOW_ECFG,
                             slots=4, seg=2, overlap=overlap,
                             fault_injector=inj, **kw)


def _flow_engine(inj=None, **kw):
    return MultiRateEngine(toy_flow_classifier(d=D), FLOW_ECFG,
                           fault_injector=inj, **kw)


@pytest.mark.parametrize("loop", ["engine", "inflight",
                                  "inflight_overlap"])
def test_flow_nan_escalates_into_ladder(loop):
    """Transient flow-eval poison -> quarantine + requeue at a K_floor
    (status 'escalated'), real finite outputs from the ladder, and the
    wasted flow attempt billed into nfe. Zero-init g routes EVERY
    request to the flow tier, so the injected fraction is exact."""
    n = 12
    trace = _trace(n)
    inj = FaultInjector(seed=4, flow_nan_frac=0.5, nan_transient=True)
    if loop == "engine":
        rep = replay_engine(_flow_engine(inj), trace)
        clean = replay_engine(_flow_engine(None), _trace(n))
        nfe_flow = _flow_engine(None).nfe_flow
    else:
        ov = loop == "inflight_overlap"
        rep = replay_scheduler(_flow_sched(inj, overlap=ov), trace)
        clean = replay_scheduler(_flow_sched(None, overlap=ov), _trace(n))
        nfe_flow = _flow_sched(None).nfe_flow
    _zero_hang(rep, n)
    counts = status_counts(rep)
    assert counts["escalated"] >= 1 and counts["diverged"] == 0, counts
    ok = {r.uid: r for r in clean.records}
    for r in rep.records:
        if r.status == "escalated":
            assert np.isfinite(r.outputs).all()
            assert r.K > 0                      # served by the ladder
            assert r.nfe > ok[r.uid].nfe        # flow attempt billed
            assert r.nfe >= nfe_flow + 1
        else:
            assert r.status == "ok" and r.K == 0
            assert r.nfe == ok[r.uid].nfe


def test_flow_nan_persistent_diverges_when_retries_exhausted():
    """max_retries=0 makes the poisoned flow eval terminal: best-effort
    'diverged' with the non-finite flow row — still zero-hang."""
    n = 8
    inj = FaultInjector(seed=4, flow_nan_frac=0.5, nan_transient=False)
    for make in (lambda: _flow_engine(inj,
                                      retry=RetryPolicy(max_retries=0)),
                 lambda: _flow_sched(inj,
                                     retry=RetryPolicy(max_retries=0))):
        rep = (replay_engine if make().__class__ is MultiRateEngine
               else replay_scheduler)(make(), _trace(n))
        _zero_hang(rep, n)
        counts = status_counts(rep)
        assert counts["diverged"] >= 1 and counts["escalated"] == 0
        for r in rep.records:
            if r.status == "diverged":
                assert not np.isfinite(r.outputs).all()


def test_flow_escalation_zero_hang_under_chaos_mixes():
    """Flow poison composed with the PR-8 chaos sources: every mix
    terminates every uid, and sync == overlap bitwise on the identical
    schedule."""
    n = 14
    mixes = [
        FaultInjector(seed=4, flow_nan_frac=0.6, nan_transient=True),
        FaultInjector(seed=6, flow_nan_frac=0.4, drop_flag_p=0.3,
                      nan_transient=True),
        FaultInjector(seed=8, flow_nan_frac=0.4, straggle_tick_frac=0.3,
                      straggle_factor=4.0, nan_transient=True),
    ]
    for inj in mixes:
        a = {r.uid: r for r in replay_scheduler(
            _flow_sched(inj), _trace(n)).records}
        b = {r.uid: r for r in replay_scheduler(
            _flow_sched(inj, overlap=True), _trace(n)).records}
        assert len(a) == n and set(a) == set(b)
        for u in a:
            ra, rb = a[u], b[u]
            assert (ra.status, ra.K, ra.nfe, ra.t_done) == \
                (rb.status, rb.K, rb.nfe, rb.t_done), (ra, rb)
            assert np.array_equal(ra.outputs, rb.outputs,
                                  equal_nan=True)


def test_flow_injector_skips_admission_poisoned_rows():
    """corrupt_flow_eval and corrupt_admission are SEPARATE sites: an
    admission-poisoned request fails the probe's finite screen, never
    reaches the flow tier, and resolves through the PR-8 quarantine
    ('retried'), not the escalation path."""
    n = 10
    inj = FaultInjector(seed=3, nan_uid_frac=0.4, nan_transient=True)
    rep = replay_scheduler(_flow_sched(inj), _trace(n))
    _zero_hang(rep, n)
    counts = status_counts(rep)
    assert counts["retried"] >= 1 and counts["escalated"] == 0, counts


# --------------------------------------------- status-key frozen contract ----

def test_status_counts_and_latency_stats_frozen_keys():
    """REGRESSION (PR 10): growing ``engine.STATUSES`` with 'escalated'
    must flow through ``status_counts`` automatically and must NOT
    change ``latency_stats``' frozen summary-key set (dashboards key on
    it)."""
    assert "escalated" in STATUSES
    n = 10
    inj = FaultInjector(seed=4, flow_nan_frac=0.5, nan_transient=True)
    rep = replay_scheduler(_flow_sched(inj), _trace(n))
    counts = status_counts(rep)
    assert set(counts) == set(STATUSES)
    assert sum(counts.values()) == n
    frozen = {"requests", "p50_latency", "p99_latency", "mean_latency",
              "p50_queue_wait", "p99_queue_wait", "mean_nfe",
              "throughput", "total_cost", "probe_cost", "useful_steps",
              "waste_steps", "waste_frac", "occupancy", "cost_unit"}
    assert set(latency_stats(rep)) == frozen
    # the empty-replay branch reports the identical key set
    empty = replay_scheduler(_flow_sched(None), [])
    assert set(latency_stats(empty)) == frozen
    # escalated completions are kept by ok_records (they finished with
    # real ladder outputs), alongside ok and retried
    kept = ok_records(rep)
    assert {r.status for r in kept.records} <= {"ok", "retried",
                                                "escalated"}
    assert any(r.status == "escalated" for r in kept.records)


# ----------------------------------------------------- bench check gate ----

def test_bench_faults_check_gate():
    """``run.py --check``'s faults section passes the committed rows and
    fails fast on a hung mix, broken accounting, or a failed parity."""
    if REPO_ROOT not in sys.path:
        sys.path.insert(0, REPO_ROOT)
    from benchmarks.run import _check_faults_section

    good = [
        {"bench": "faults", "mode": "inflight", "mix": "clean",
         "devices": 1, "zero_hang": True, "status_ok": True},
        {"bench": "faults", "mode": "inflight", "mix": "nan_transient",
         "devices": 4, "zero_hang": True, "status_ok": True},
        {"bench": "faults", "mode": "verdict", "zero_hang_all": True,
         "fault_free_parity": True, "status_accounting_ok": True,
         "overlap_parity_all": True},
    ]
    assert _check_faults_section("BENCH_faults.json", good) == []
    hung = [dict(good[0], zero_hang=False), good[1], good[2]]
    assert any("lost requests" in e for e in
               _check_faults_section("BENCH_faults.json", hung))
    bad_verdict = [good[0], good[1],
                   dict(good[2], fault_free_parity=False)]
    assert any("fault_free_parity" in e for e in
               _check_faults_section("BENCH_faults.json", bad_verdict))
    no_mesh = [good[0], good[2]]
    assert any("devices > 1" in e for e in
               _check_faults_section("BENCH_faults.json", no_mesh))


# ------------------------------------------------- sharded pool (4 dev) ----

_SHARDED_FAULTS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax

    from repro.distributed.fault import FaultInjector
    from repro.launch.engine import EngineConfig
    from repro.launch.mesh import make_serving_mesh
    from repro.launch.scheduler import InflightScheduler
    from repro.launch.workload import (
        heterogeneous_requests, poisson_trace, replay_scheduler,
        status_counts, toy_classifier,
    )

    assert jax.device_count() == 4, jax.device_count()
    mesh = make_serving_mesh(4)
    ecfg = EngineConfig(buckets=(2, 4, 8, 16), tol=5e-3, fused=True)

    def sched(inj, overlap=False, **kw):
        return InflightScheduler(toy_classifier(d=8), ecfg, slots=8,
                                 seg=2, mesh=mesh, overlap=overlap,
                                 fault_injector=inj, **kw)

    xs = heterogeneous_requests(16, 8, seed=3)
    trace = poisson_trace(xs, rate=0.25, seed=103)

    # quarantine on the sharded pool: the nonfinite meta row is computed
    # on the global (gathered) state, so a poisoned slot on any device
    # quarantines exactly as on one device
    inj = FaultInjector(seed=1, nan_uid_frac=0.3, nan_transient=True)
    rep = replay_scheduler(sched(inj), trace)
    counts = status_counts(rep)
    assert len(rep.records) == 16, counts
    assert counts["retried"] >= 1, counts
    print("SHARDED_QUARANTINE_OK")

    # deadline eviction under overlap=True on the mesh: each uid exactly
    # once (no double-retire through the lagged retire), bitwise equal
    # to the sync mesh replay
    inj_d = FaultInjector(seed=5, straggle_tick_frac=0.4,
                          straggle_factor=8.0)
    trace_d = poisson_trace(xs, rate=0.25, seed=103, deadline_slack=60.0)
    rep_s = replay_scheduler(sched(inj_d), trace_d)
    rep_o = replay_scheduler(sched(inj_d, overlap=True), trace_d)
    for rep_x in (rep_s, rep_o):
        uids = [r.uid for r in rep_x.records]
        assert len(uids) == 16 and len(set(uids)) == 16
    assert status_counts(rep_s)["deadline"] >= 1
    a = {r.uid: r for r in rep_s.records}
    for r in rep_o.records:
        ref = a[r.uid]
        assert (r.status, r.K, r.nfe, r.t_done) == (
            ref.status, ref.K, ref.nfe, ref.t_done)
        if r.outputs is None:
            assert ref.outputs is None
        else:
            assert np.array_equal(r.outputs, ref.outputs, equal_nan=True)
    print("SHARDED_DEADLINE_OVERLAP_OK")
""")


@pytest.mark.slow
def test_sharded_pool_faults_subprocess():
    """EDGE (tier-2): quarantine and deadline eviction on a forced
    4-device mesh behave exactly as single-device — including under
    ``overlap=True`` — in a subprocess (device topology is frozen at
    first jax init)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SHARDED_FAULTS_SCRIPT],
                          env=env, capture_output=True, text=True,
                          timeout=600, cwd=REPO_ROOT)
    out = proc.stdout + proc.stderr
    assert proc.returncode == 0, out[-4000:]
    for marker in ("SHARDED_QUARANTINE_OK",
                   "SHARDED_DEADLINE_OVERLAP_OK"):
        assert marker in out, out[-4000:]
