"""Continuous-depth LM mode: exact equivalence at K = n_groups, NFE/error
pareto with the hypersolver at K < n_groups."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.models.cdepth import (
    cdepth_residual_loss, discrete_depth_trajectory, lm_forward_cdepth,
    lm_g_init,
)
from repro.models.lm import group_layout, init_lm, lm_forward
from repro.optim import adamw, apply_updates, clip_by_global_norm

ARCH = "qwen3_4b"  # dense, homogeneous pattern


def _setup(n_layers=8):
    import dataclasses
    cfg = dataclasses.replace(get(ARCH).reduced(), n_layers=n_layers)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    return cfg, params, toks


def test_euler_full_K_equals_discrete_forward():
    """Euler at K = n_groups must reproduce the discrete network exactly."""
    cfg, params, toks = _setup()
    _, n_groups, _ = group_layout(cfg)
    ref, _ = lm_forward(params, cfg, toks)
    ode = lm_forward_cdepth(params, cfg, toks, K=n_groups, solver="euler")
    np.testing.assert_allclose(np.asarray(ref), np.asarray(ode), rtol=1e-5,
                               atol=1e-5)


def test_reduced_K_degrades_then_hypersolver_recovers():
    cfg, params, toks = _setup(n_layers=8)
    _, n_groups, _ = group_layout(cfg)
    K = n_groups // 2

    ref, _ = lm_forward(params, cfg, toks)
    base = lm_forward_cdepth(params, cfg, toks, K=K, solver="euler")
    err_base = float(jnp.mean(jnp.abs(ref - base)))
    assert err_base > 0  # skipping layers must change the output

    gp = lm_g_init(jax.random.PRNGKey(2), cfg, rank=32,
                   param_dtype=jnp.float32)
    opt = adamw(3e-3)
    st = opt.init(gp)

    @jax.jit
    def fit(gp, st, i, toks):
        loss, grads = jax.value_and_grad(
            lambda g: cdepth_residual_loss(params, g, cfg, toks, K))(gp)
        grads, _ = clip_by_global_norm(grads, 1.0)
        upd, st = opt.update(grads, st, gp, i)
        return apply_updates(gp, upd), st, loss

    key = jax.random.PRNGKey(3)
    losses = []
    for i in range(120):
        if i % 10 == 0:
            key, sub = jax.random.split(key)
            batch = jax.random.randint(sub, (2, 8), 0, cfg.vocab)
        gp, st, loss = fit(gp, st, i, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0], (losses[0], losses[-1])

    hyper = lm_forward_cdepth(params, cfg, toks, K=K, solver="euler",
                              g_params=gp)
    err_hyper = float(jnp.mean(jnp.abs(ref - hyper)))
    assert err_hyper < err_base, (err_base, err_hyper)


def test_trajectory_shapes():
    cfg, params, toks = _setup(n_layers=4)
    _, n_groups, _ = group_layout(cfg)
    traj = discrete_depth_trajectory(params, cfg, toks)
    assert traj.shape[0] == n_groups + 1
    assert np.all(np.isfinite(np.asarray(traj)))
