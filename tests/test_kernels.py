"""Per-kernel shape/dtype sweeps: pallas interpret=True vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hyper_step.ops import fused_rk_update, hyper_step
from repro.kernels.hyper_step.ref import fused_rk_update_ref, hyper_step_ref
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.rwkv6_scan.ops import wkv6
from repro.kernels.rwkv6_scan.ref import wkv6_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------- hyper_step ----

@pytest.mark.parametrize("shape", [(7,), (33, 5), (4, 130), (2, 3, 257),
                                   (256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("eps,order", [(0.1, 1), (0.25, 2)])
def test_hyper_step_sweep(shape, dtype, eps, order):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    z = jax.random.normal(ks[0], shape, dtype)
    f = jax.random.normal(ks[1], shape, dtype)
    g = jax.random.normal(ks[2], shape, dtype)
    out = hyper_step(z, f, g, eps, order, interpret=True)
    ref = hyper_step_ref(z, f, g, eps, order)
    assert out.dtype == z.dtype and out.shape == z.shape
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("shape", [(7,), (33, 5), (2, 3, 257), (256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("tab_name,eps", [
    ("euler", 0.1), ("heun", 0.25), ("midpoint", 0.5), ("rk4", 0.125),
])
@pytest.mark.parametrize("with_g", [True, False])
def test_fused_rk_update_sweep(shape, dtype, tab_name, eps, with_g):
    """Generalized kernel: b-weighted stage combine + correction in one
    pass, vs the jnp oracle, across tableaus/orders/dtypes."""
    from repro.core import get_tableau
    tab = get_tableau(tab_name)
    ks = jax.random.split(jax.random.PRNGKey(3), tab.stages + 2)
    z = jax.random.normal(ks[0], shape, dtype)
    stages = tuple(jax.random.normal(k, shape, dtype)
                   for k in ks[1:1 + tab.stages])
    g = jax.random.normal(ks[-1], shape, dtype) if with_g else None
    out = fused_rk_update(z, stages, g, eps, tab.b, tab.order,
                          interpret=True)
    ref = fused_rk_update_ref(z, stages, g, eps, tab.b, tab.order)
    assert out.dtype == z.dtype and out.shape == z.shape
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("shape", [(4,), (4, 37), (4, 3, 57), (8, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("tab_name", ["euler", "heun", "rk4"])
@pytest.mark.parametrize("with_g", [True, False])
def test_fused_rk_update_per_sample_eps_sweep(shape, dtype, tab_name,
                                              with_g):
    """Runtime-eps path: per-sample (B,) eps row + active freeze mask ride
    the scalar-prefetch SMEM operands — one masked multi-rate update in a
    single kernel pass, vs the jnp oracle."""
    from repro.core import get_tableau
    tab = get_tableau(tab_name)
    B = shape[0]
    ks = jax.random.split(jax.random.PRNGKey(7), tab.stages + 2)
    z = jax.random.normal(ks[0], shape, dtype)
    stages = tuple(jax.random.normal(k, shape, dtype)
                   for k in ks[1:1 + tab.stages])
    g = jax.random.normal(ks[-1], shape, dtype) if with_g else None
    eps = jnp.linspace(0.05, 0.5, B)
    active = (jnp.arange(B) % 2).astype(jnp.int32)
    out = fused_rk_update(z, stages, g, eps, tab.b, tab.order,
                          active=active, interpret=True)
    ref = fused_rk_update_ref(z, stages, g, eps, tab.b, tab.order,
                              active=active)
    assert out.dtype == z.dtype and out.shape == z.shape
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))
    # frozen rows are bitwise the input state
    np.testing.assert_array_equal(
        np.asarray(out, np.float32)[::2], np.asarray(z, np.float32)[::2])


def test_fused_rk_update_traced_scalar_eps():
    """A traced 0-d eps (the controller's span/K) takes the same kernel —
    no concretization, no fallback."""
    z = jnp.ones((3, 5))
    r = jnp.full((3, 5), 2.0)

    out = jax.jit(lambda e: fused_rk_update(z, (r,), None, e, (1.0,), 1,
                                            interpret=True))(jnp.asarray(0.25))
    np.testing.assert_allclose(np.asarray(out), 1.5)


# ------------------------------------------------------ flash_attention ----

@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 64),      # MHA, single block
    (2, 256, 8, 2, 64),      # GQA 4:1, two blocks
    (1, 384, 4, 1, 128),     # MQA, 3 blocks, wide head
    (1, 200, 4, 2, 64),      # padded (S not block multiple)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal_sweep(B, S, H, KV, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)

    out = flash_attention(q, k, v, causal=True, interpret=True)
    ref = attention_ref(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                        jnp.moveaxis(v, 1, 2), causal=True)
    ref = jnp.moveaxis(ref, 1, 2)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_noncausal_and_window():
    B, S, H, KV, hd = 1, 256, 2, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    for causal, window in [(False, None), (True, 64), (True, 130)]:
        out = flash_attention(q, k, v, causal=causal, window=window,
                              interpret=True)
        ref = jnp.moveaxis(attention_ref(
            jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
            jnp.moveaxis(v, 1, 2), causal=causal, window=window), 1, 2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"{causal} {window}")


def test_flash_attention_matches_model_attention():
    """Kernel path == nn.attention einsum path (same math, same layout)."""
    from repro.nn.attention import attention_init, mha
    d, H, KV, hd, S = 32, 4, 2, 8, 64
    p = attention_init(jax.random.PRNGKey(3), d, H, KV, hd)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, S, d))
    ref = mha(p, x, n_heads=H, n_kv=KV, d_head=hd, use_rope=False)

    from repro.nn.attention import _proj
    q = _proj(p["wq"], x, H, hd)
    k = _proj(p["wk"], x, KV, hd)
    v = _proj(p["wv"], x, KV, hd)
    o = flash_attention(q, k, v, causal=True, bq=32, bk=32, interpret=True)
    o = o.reshape(2, S, H * hd)
    out = o @ p["wo"]["kernel"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-5)


# ----------------------------------------------------------- rwkv6_scan ----

@pytest.mark.parametrize("B,T,H,D,chunk", [
    (1, 8, 1, 8, 8),         # single chunk
    (2, 16, 2, 8, 8),        # two chunks: state carry across chunks
    (1, 20, 2, 8, 8),        # padded T
    (1, 32, 1, 16, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_kernel_sweep(B, T, H, D, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    r = jax.random.normal(ks[0], (B, T, H, D), dtype)
    k = jax.random.normal(ks[1], (B, T, H, D), dtype)
    v = jax.random.normal(ks[2], (B, T, H, D), dtype)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, T, H, D))).astype(dtype)
    u = jnp.full((H, D), 0.3, dtype)
    out = wkv6(r, k, v, w, u, chunk=chunk, interpret=True)
    ref = wkv6_ref(r, k, v, w, u)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **tol)


def test_wkv6_kernel_in_model_layer():
    """rwkv6_time_mix(wkv_fn=kernel) == default scan path."""
    from repro.nn.rwkv6 import rwkv6_init, rwkv6_time_mix
    d, H = 32, 4
    p = rwkv6_init(jax.random.PRNGKey(6), d, H, lora_rank=4)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 16, d))
    ref, _ = rwkv6_time_mix(p, x, H)

    def kernel_wkv(r, k, v, w, u, S0):
        o = wkv6(r, k, v, w, u, chunk=8, interpret=True)
        return o, S0  # state not needed for the parity check

    out, _ = rwkv6_time_mix(p, x, H, wkv_fn=kernel_wkv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-5)


# ----------------------------------------------------------- rglru_scan ----

@pytest.mark.parametrize("B,T,W,chunk,bw", [
    (1, 16, 8, 8, 8),
    (2, 32, 16, 8, 8),       # multiple chunks + width blocks
    (1, 20, 12, 8, 8),       # padded both axes
    (3, 64, 128, 16, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_kernel_sweep(B, T, W, chunk, bw, dtype):
    ks = jax.random.split(jax.random.PRNGKey(8), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, T, W))).astype(dtype)
    b = jax.random.normal(ks[1], (B, T, W), dtype)
    out = rglru_scan(a, b, chunk=chunk, bw=bw, interpret=True)
    ref = rglru_scan_ref(a, b)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **tol)


def test_rglru_kernel_matches_module():
    """Kernel scan == nn.rglru associative scan on real gate values."""
    from repro.nn.rglru import _gates, rglru_apply, rglru_init
    W = 16
    p = rglru_init(jax.random.PRNGKey(9), W)
    x = jax.random.normal(jax.random.PRNGKey(10), (2, 24, W))
    ref, _ = rglru_apply(p, x)
    a, b = _gates(p, x)
    out = rglru_scan(a, b, chunk=8, bw=8, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref, np.float32),
                               rtol=2e-4, atol=1e-5)


def test_fused_hypersolver_step_matches_unfused():
    """HyperSolver(fused=True) routes through the Pallas hyper_step kernel
    and must match the tree-arithmetic path exactly."""
    import dataclasses
    from repro.core import HyperSolver, get_tableau
    f = lambda s, z: jnp.sin(z)
    g = lambda eps, s, z, dz: 0.3 * z + 0.1 * dz
    z0 = jax.random.normal(jax.random.PRNGKey(11), (4, 37))
    hs = HyperSolver(tableau=get_tableau("heun"), g=g)
    hs_fused = dataclasses.replace(hs, fused=True)
    a, _, _ = hs.step(f, 0.2, 0.125, z0)
    b, _, _ = hs_fused.step(f, 0.2, 0.125, z0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                               atol=1e-6)
