"""Distributed semantics on a forced 8-device CPU mesh (subprocess — the
main test process must keep a single device per the dry-run contract).

Checks: (1) sharded train step == single-device train step, (2) sharding
rules actually shard (per-device bytes < total), (3) compressed all-reduce
== arithmetic mean, (4) decode step matches under sharding.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get
    from repro.distributed import sharding as shd
    from repro.launch.mesh import mesh_context
    from repro.launch.steps import StepSettings, make_train_step, make_serve_step
    from repro.models.lm import init_lm, init_lm_cache, lm_decode_step
    from repro.data import token_batches

    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get("qwen3_4b").reduced()
    settings = StepSettings(microbatches=2, remat="none", zero_opt=True,
                            lr=1e-3)

    # ---- single-device baseline
    cpu1 = jax.devices()[0]
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks, tgts = next(token_batches(cfg.vocab, 8, 32, seed=3))
    batch = {"tokens": toks, "targets": tgts}

    from repro.optim import apply_updates, clip_by_global_norm
    from repro.launch.steps import make_optimizer
    opt = make_optimizer(settings)
    from repro.models.lm import lm_loss

    def ref_step(params, opt_state, batch):
        mbs = jax.tree_util.tree_map(
            lambda x: x.reshape((2, x.shape[0] // 2) + x.shape[1:]), batch)
        g_acc = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        loss_acc = 0.0
        for i in range(2):
            mb = jax.tree_util.tree_map(lambda x: x[i], mbs)
            (l, m), g = jax.value_and_grad(
                lambda p: lm_loss(p, cfg, mb["tokens"], mb["targets"]),
                has_aux=True)(params)
            g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
            loss_acc += l
        grads = jax.tree_util.tree_map(lambda g: g / 2, g_acc)
        grads, _ = clip_by_global_norm(grads, settings.grad_clip)
        upd, opt_state = opt.update(grads, opt_state, params, 0)
        return apply_updates(params, upd), opt_state, loss_acc / 2

    opt_state0 = opt.init(params)
    p_ref, _, loss_ref = ref_step(params, opt_state0, batch)

    # pristine host copy (device buffers below get donated/aliased)
    params_host = jax.tree_util.tree_map(np.asarray, params)

    # ---- sharded step
    with mesh_context(mesh):
        shd.set_activation_sharding(("data",))
        step, _, (a_p, a_o, p_sh, o_sh) = make_train_step(cfg, settings, mesh)
        params_sh = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), params, p_sh)
        opt_sh = jax.jit(opt.init, out_shardings=o_sh)(params_sh)
        p_new, o_new, metrics = step(params_sh, opt_sh,
                                     jnp.asarray(0, jnp.int32), batch)
        shd.clear_activation_sharding()

    np.testing.assert_allclose(float(metrics["loss"]), float(loss_ref),
                               rtol=2e-4)
    for (path, a), b in zip(
            jax.tree_util.tree_flatten_with_path(p_new)[0],
            jax.tree_util.tree_leaves(p_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-3,
                                   atol=3e-4, err_msg=str(path))
    print("TRAIN_STEP_PARITY_OK")

    # ---- sharding actually shards: per-shard bytes < full bytes
    # (params_sh was donated to the step; inspect the step OUTPUT)
    emb = p_new["embed"]["table"]
    shard_bytes = emb.addressable_shards[0].data.nbytes
    assert shard_bytes * 4 == emb.nbytes, (shard_bytes, emb.nbytes)
    # re-place the ORIGINAL params for the decode comparison (the first
    # placement was donated to the train step)
    params_sh = jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), params_host, p_sh)
    params = jax.tree_util.tree_map(jnp.asarray, params_host)
    print("PARAM_SHARDED_OK")

    # ---- compressed all-reduce == mean
    from repro.optim.grad_compress import compressed_allreduce_mean
    x = jax.random.normal(jax.random.PRNGKey(5), (64, 32))
    xs = jax.device_put(x, NamedSharding(mesh, P()))
    got = compressed_allreduce_mean(xs, mesh, axis="data")
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), rtol=0.03,
                               atol=0.03)
    print("COMPRESSED_ALLREDUCE_OK")

    # ---- sharded decode parity
    with mesh_context(mesh):
        serve, (a_p2, p_sh2) = make_serve_step(cfg, mesh)
        caches = init_lm_cache(cfg, 8, 16)
        tok = toks[:, 0]
        lg_sh, _ = serve(params_sh, tok, caches, jnp.asarray(0, jnp.int32))
    lg_ref, _ = lm_decode_step(params, cfg, tok,
                               init_lm_cache(cfg, 8, 16), jnp.asarray(0))
    np.testing.assert_allclose(np.asarray(lg_sh), np.asarray(lg_ref),
                               rtol=3e-3, atol=3e-3)
    print("DECODE_PARITY_OK")
""")


@pytest.mark.slow
def test_distributed_semantics_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    out = proc.stdout + proc.stderr
    assert proc.returncode == 0, out[-4000:]
    for marker in ("TRAIN_STEP_PARITY_OK", "PARAM_SHARDED_OK",
                   "COMPRESSED_ALLREDUCE_OK", "DECODE_PARITY_OK"):
        assert marker in out, (marker, out[-4000:])
