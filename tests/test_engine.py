"""Multi-rate serving engine (launch/engine.py): bucket assignment,
request-queue packing, per-request NFE accounting, fixed-vs-multirate
consistency, and the LM adapter end-to-end."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FixedGrid, Integrator, get_tableau
from repro.launch.engine import (
    DepthModel, EngineConfig, MultiRateEngine, lm_depth_model,
    snap_to_buckets,
)


# ----------------------------------------------------------- bucket policy ----

def test_snap_to_buckets():
    Ks = np.array([1, 2, 3, 4, 5, 8])
    np.testing.assert_array_equal(snap_to_buckets(Ks, (2, 4, 8)),
                                  [2, 2, 4, 4, 8, 8])
    np.testing.assert_array_equal(snap_to_buckets(Ks, (16,)), [16] * 6)
    with pytest.warns(RuntimeWarning):  # overshoot clamps down, warned
        np.testing.assert_array_equal(
            snap_to_buckets(np.array([9, 40]), (2, 4, 8)), [8, 8])


def test_snap_to_buckets_overflow_clamps_with_one_time_warning():
    """A probed K above the largest configured bucket clamps to
    buckets[-1] — integrating COARSER than asked — and says so once (the
    latch is re-armed per test by conftest)."""
    with pytest.warns(RuntimeWarning, match="exceeds the largest"):
        out = snap_to_buckets(np.array([3, 40]), (2, 4, 8))
    np.testing.assert_array_equal(out, [4, 8])
    # one-time: the second overflow in the same process stays silent
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        np.testing.assert_array_equal(
            snap_to_buckets(np.array([99]), (2, 4, 8)), [8])
    # in-range snapping never warns
    from repro.launch.engine import reset_snap_overflow_warning

    reset_snap_overflow_warning()
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        snap_to_buckets(np.array([1, 8]), (2, 4, 8))


def test_engine_config_requires_sorted_buckets():
    with pytest.raises(AssertionError):
        EngineConfig(buckets=(8, 2, 4))


# -------------------------------------------------------- synthetic model ----

def _toy_model(g_scale=None, solver="euler"):
    """A tiny servable 'model': z' = -z * softplus(mean(x)); request
    difficulty is controlled directly by the input magnitude."""
    tab = get_tableau(solver[len("hyper_"):] if solver.startswith("hyper_")
                      else solver)
    g = None
    if g_scale is not None:
        g = lambda eps, s, z, dz: g_scale * z
    stiff = lambda x: jax.nn.softplus(jnp.mean(x, axis=-1, keepdims=True))

    def field_of(x):
        k = stiff(x)
        return lambda s, z: -z * k

    return DepthModel(
        embed=lambda x: x + 0.0,
        field_of=field_of,
        readout=lambda x, zT: zT,
        integ=Integrator(tableau=tab, g=g),
    )


def _requests(n=10, d=4, seed=0):
    # the shared difficulty-mix generator, un-interleaved so the first
    # half is the easy (softplus(-2) small) slice the assertions key on
    from repro.launch.workload import heterogeneous_requests

    return heterogeneous_requests(n, d, seed=seed, interleave=False)


# ------------------------------------------------------------------ engine ----

def test_engine_orders_results_and_accounts_nfe():
    model = _toy_model()
    eng = MultiRateEngine(model, EngineConfig(buckets=(2, 4, 8), tol=1e-2,
                                              max_batch=3))
    xs = _requests(9)
    res = eng.run(xs)
    assert [r.uid for r in res] == sorted(r.uid for r in res)
    assert len(res) == 9 and len(eng) == 0
    for r in res:
        assert r.K in (2, 4, 8)
        # embedded HEUN probe (2 evals, 1 reused) + euler K evals
        assert r.nfe == eng.probe_nfe + r.K
        assert r.err_probe > 0.0
    assert eng.probe_nfe == 1  # 2-stage probe minus the reused dz


def test_engine_routes_hard_requests_to_finer_buckets():
    model = _toy_model()
    eng = MultiRateEngine(model, EngineConfig(buckets=(2, 4, 8, 16),
                                              tol=5e-3, max_batch=8))
    xs = _requests(12)
    res = eng.run(xs)
    k_easy = [r.K for r in res[:6]]
    k_hard = [r.K for r in res[6:]]
    assert max(k_easy) <= min(k_hard), (k_easy, k_hard)
    assert min(k_easy) < max(k_hard), "buckets should actually differ"


def test_engine_outputs_match_direct_solve():
    """Engine-served outputs == a direct fixed-grid solve at the same K
    (packing and probe reuse change nothing numerically)."""
    model = _toy_model()
    eng = MultiRateEngine(model, EngineConfig(buckets=(2, 4, 8), tol=1e-2,
                                              max_batch=4))
    xs = _requests(6)
    res = eng.run(xs)
    for i, r in enumerate(res):
        x = jnp.asarray(xs[i:i + 1])
        direct = model.integ.solve(model.field_of(x), model.embed(x),
                                   FixedGrid.over(0.0, 1.0, r.K),
                                   return_traj=False)
        np.testing.assert_allclose(np.asarray(r.outputs),
                                   np.asarray(direct[0]), rtol=1e-6)


def test_engine_fixed_controller_is_fixed_k():
    model = _toy_model()
    eng = MultiRateEngine(model, EngineConfig(buckets=(4,),
                                              controller="fixed", fixed_K=4))
    res = eng.run(_requests(5))
    assert all(r.K == 4 for r in res)
    assert all(r.nfe == 4 for r in res)       # no probe on the fixed path
    assert all(r.err_probe == 0.0 for r in res)
    assert eng.probe_nfe == 0


def test_engine_residual_controller_with_g():
    model = _toy_model(g_scale=0.3, solver="hyper_euler")
    eng = MultiRateEngine(model, EngineConfig(buckets=(2, 4, 8), tol=1e-1,
                                              solver="hyper_euler"))
    res = eng.run(_requests(6))
    assert type(eng.controller).__name__ == "HypersolverResidualController"
    assert eng.probe_nfe == 0                 # 1-eval probe, fully reused
    assert all(r.nfe == r.K for r in res)     # probe is free for HyperEuler


def test_engine_hyper_solver_requires_g():
    model = _toy_model()                       # no correction bound
    with pytest.raises(ValueError):
        MultiRateEngine(model, EngineConfig(solver="hyper_euler"))


def test_engine_groups_mixed_shapes():
    model = _toy_model()
    eng = MultiRateEngine(model, EngineConfig(buckets=(2, 4), tol=1e-2))
    uid_a = eng.submit(np.zeros(3, np.float32) - 2.0)
    uid_b = eng.submit(np.zeros(5, np.float32) - 2.0)
    done = eng.step()
    assert sorted(c.uid for c in done) == [uid_a, uid_b]
    shapes = {c.uid: c.outputs.shape for c in done}
    assert shapes[uid_a] == (3,) and shapes[uid_b] == (5,)


# -------------------------------------------------------------- LM adapter ----

def test_lm_engine_end_to_end():
    """The LM depth model serves through the engine; a fixed-K engine run
    reproduces lm_forward_cdepth at the same K, and per-request stats are
    threaded through (models/cdepth.py SolveStats counterpart)."""
    from repro.configs import get
    from repro.models.cdepth import lm_forward_cdepth
    from repro.models.lm import init_lm

    cfg = dataclasses.replace(get("qwen3_4b").reduced(), n_layers=4,
                              vocab=64)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (3, 6), 0,
                                         cfg.vocab), np.int32)

    model = lm_depth_model(params, cfg, solver="euler")
    eng = MultiRateEngine(model, EngineConfig(buckets=(2,),
                                              controller="fixed", fixed_K=2,
                                              max_batch=2))
    res = eng.run(toks)
    ref, stats = lm_forward_cdepth(params, cfg, jnp.asarray(toks), K=2,
                                   solver="euler", with_stats=True)
    np.testing.assert_array_equal(np.asarray(stats.nfe), [2, 2, 2])
    for i, r in enumerate(res):
        assert r.nfe == int(stats.nfe[i])
        np.testing.assert_allclose(np.asarray(r.outputs),
                                   np.asarray(ref[i]), rtol=2e-4, atol=2e-4)

    # multi-rate path with the embedded probe stays in the bucket set
    eng2 = MultiRateEngine(model, EngineConfig(buckets=(1, 2, 4), tol=1e3))
    res2 = eng2.run(toks)
    assert all(r.K in (1, 2, 4) for r in res2)

    # the models-layer probe API is the same selection the engine runs
    from repro.models.cdepth import depth_probe

    probe = depth_probe(params, cfg, jnp.asarray(toks), eng2.controller,
                        solver="euler")
    raw_k, raw_err = eng2.probe(toks)
    np.testing.assert_array_equal(np.asarray(probe.K), raw_k)
    np.testing.assert_allclose(np.asarray(probe.err), raw_err, rtol=1e-6)
