"""Direct coverage for core/adaptive.py: convergence to tolerance, NFE
monotonicity in rtol, vmap-batched solves, and a regression test pinning
the refactored embedded-error path (shared with core/controllers.py) to
the original per-segment while_loop implementation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FixedGrid, odeint_dopri5, odeint_dopri5_batched
from repro.core.solvers import tree_axpy, tree_lincomb
from repro.core.tableaus import DOPRI5

# x64 enabled per-module via tests/conftest.py

A = np.array([[-0.5, -2.0], [2.0, -0.5]], dtype=np.float64)


def _expm(M):
    w, V = np.linalg.eig(np.asarray(M))
    return (V @ np.diag(np.exp(w)) @ np.linalg.inv(V)).real


def linear_field(s, z):
    return z @ A.T


# ------------------------------------------------- convergence to tolerance ----

@pytest.mark.parametrize("tol", [1e-4, 1e-6, 1e-8])
def test_converges_to_tolerance(tol):
    """Terminal error against the analytic solution tracks the requested
    tolerance (within a safety margin — tolerances control LOCAL error)."""
    z0 = jnp.array([[1.0, 0.5]], dtype=jnp.float64)
    exact = np.asarray(z0) @ _expm(A).T
    traj, nfe = odeint_dopri5(linear_field, z0, FixedGrid.over(0.0, 1.0, 4),
                              atol=tol, rtol=tol)
    err = float(np.linalg.norm(np.asarray(traj[-1]) - exact))
    assert err < 100 * tol, (err, tol)
    assert int(nfe) > 0


# -------------------------------------------------------- NFE monotonicity ----

def test_nfe_monotone_in_rtol():
    """Tighter tolerances never take fewer vector-field evaluations."""
    z0 = jnp.array([[1.0, -0.3]], dtype=jnp.float64)
    grid = FixedGrid.over(0.0, 1.0, 4)
    nfes = []
    for tol in (1e-3, 1e-5, 1e-7, 1e-9):
        _, nfe = odeint_dopri5(linear_field, z0, grid, atol=tol, rtol=tol)
        nfes.append(int(nfe))
    assert nfes == sorted(nfes), nfes
    assert nfes[-1] > nfes[0], nfes


# --------------------------------------------------- legacy-path regression ----
# The original implementation (pre-refactor) with its own private embedded
# stage math, kept verbatim: the refactored odeint_dopri5 routes through
# controllers.embedded_step / error_ratio / step_factor and must reproduce
# these results exactly.

_SAFETY, _MIN_FACTOR, _MAX_FACTOR = 0.9, 0.2, 5.0


def _legacy_dopri5_stages(f, s, eps, z):
    tab = DOPRI5
    stages = []
    for i in range(tab.stages):
        if i == 0:
            zi = z
        else:
            zi = tree_axpy(eps, tree_lincomb(tab.a[i], stages), z)
        stages.append(f(s + tab.c[i] * eps, zi))
    z5 = tree_axpy(eps, tree_lincomb(tab.b, stages), z)
    err_w = tuple(b - be for b, be in zip(tab.b, tab.b_err))
    err = jax.tree_util.tree_map(lambda l: eps * l, tree_lincomb(err_w, stages))
    return z5, err


def _legacy_error_ratio(z, z_new, err, atol, rtol):
    def leafwise(zl, znl, el):
        tol = atol + rtol * jnp.maximum(jnp.abs(zl), jnp.abs(znl))
        return jnp.mean((el.astype(jnp.float32) / tol.astype(jnp.float32)) ** 2)

    parts = jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(leafwise, z, z_new, err))
    return jnp.sqrt(sum(parts) / len(parts))


def _legacy_segment(f, z0, s0, s1, eps0, atol, rtol, max_steps):
    def cond(st):
        s, z, eps, nfe = st
        return (s < s1 - 1e-12) & (nfe < max_steps * 6)

    def body(st):
        s, z, eps0_, nfe = st
        eps = jnp.minimum(eps0_, s1 - s)
        z_new, err = _legacy_dopri5_stages(f, s, eps, z)
        ratio = _legacy_error_ratio(z, z_new, err, atol, rtol)
        accept = ratio <= 1.0
        factor = jnp.clip(
            _SAFETY * (jnp.maximum(ratio, 1e-10) ** -0.2),
            _MIN_FACTOR, _MAX_FACTOR)
        new_eps = jnp.clip(eps * factor, 1e-8, s1 - s0)
        z_out = jax.tree_util.tree_map(
            lambda a, b: jnp.where(accept, a, b), z_new, z)
        s_out = jnp.where(accept, s + eps, s)
        return (s_out, z_out, new_eps, nfe + 6)

    init = (jnp.asarray(s0, jnp.float32), z0,
            jnp.asarray(eps0, jnp.float32), jnp.asarray(0, jnp.int32))
    s, z, eps, nfe = jax.lax.while_loop(cond, body, init)
    return z, eps, nfe


def _legacy_odeint_dopri5(f, z0, grid, atol=1e-5, rtol=1e-5, max_steps=1000):
    def seg(carry, s_pair):
        z, eps = carry
        s_a, s_b = s_pair
        z_b, eps_out, nfe = _legacy_segment(f, z, s_a, s_b, eps, atol, rtol,
                                            max_steps)
        return (z_b, eps_out), (z_b, nfe)

    s_span = grid.s_span
    pairs = jnp.stack([s_span[:-1], s_span[1:]], axis=1)
    (_, _), (traj, nfes) = jax.lax.scan(
        seg, (z0, jnp.asarray(grid.eps, jnp.float32)), pairs)
    full = jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a[None], b], axis=0), z0, traj)
    return full, jnp.sum(nfes)


@pytest.mark.parametrize("tol", [1e-4, 1e-7])
def test_refactored_matches_legacy_while_loop(tol):
    """The shared embedded-error code path reproduces the original
    per-segment while_loop results (trajectory AND NFE count)."""
    f = lambda s, z: jnp.stack([-z[..., 1], z[..., 0]], axis=-1) \
        + 0.1 * jnp.sin(3.0 * s) * jnp.ones_like(z)
    z0 = jnp.array([[0.7, -0.2], [1.5, 0.4]], dtype=jnp.float64)
    grid = FixedGrid.over(0.0, 1.0, 5)
    new_traj, new_nfe = odeint_dopri5(f, z0, grid, atol=tol, rtol=tol)
    old_traj, old_nfe = _legacy_odeint_dopri5(f, z0, grid, atol=tol, rtol=tol)
    assert int(new_nfe) == int(old_nfe)
    np.testing.assert_array_equal(np.asarray(new_traj), np.asarray(old_traj))


# ------------------------------------------------------------- batched vmap ----

def test_batched_matches_per_sample():
    """odeint_dopri5_batched == a python loop of per-sample solves, with a
    per-sample NFE vector (the multi-rate difficulty signal)."""
    f = lambda s, z: -z * (1.0 + 0.5 * jnp.tanh(z))
    z0 = jnp.asarray(np.random.RandomState(0).randn(3, 4))
    grid = FixedGrid.over(0.0, 1.0, 3)
    traj_b, nfe_b = odeint_dopri5_batched(f, z0, grid, atol=1e-6, rtol=1e-6)
    assert traj_b.shape == (3, 4, 4)
    assert nfe_b.shape == (3,)
    for i in range(3):
        traj_i, nfe_i = odeint_dopri5(f, z0[i], grid, atol=1e-6, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(traj_b[i]), np.asarray(traj_i),
                                   rtol=1e-6, atol=1e-9)


def test_batched_nfe_tracks_stiffness():
    """A stiffer sample spends at least as many NFEs as an easy one."""
    f = lambda s, z: -z ** 3
    z0 = jnp.asarray([[0.1], [8.0]], dtype=jnp.float64)  # easy, stiff
    _, nfe = odeint_dopri5_batched(f, z0, FixedGrid.over(0.0, 1.0, 2),
                                   atol=1e-7, rtol=1e-7)
    assert int(nfe[1]) > int(nfe[0]), np.asarray(nfe)
