"""Optimizer substrate: AdamW vs numpy reference, schedules, 8-bit state,
gradient compression with error feedback."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, apply_updates, clip_by_global_norm, sgd
from repro.optim.grad_compress import (
    compress_with_feedback, init_error_feedback,
)
from repro.optim.quantized_state import (
    adamw8bit, dequantize_blockwise, quantize_blockwise,
)
from repro.optim.schedules import cosine_annealing, linear_warmup_cosine


def test_adamw_matches_numpy_reference():
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    g = {"w": jnp.asarray([[0.1, -0.3], [0.2, 0.05]])}
    opt = adamw(1e-2, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01)
    st = opt.init(p)
    params = p
    m = np.zeros((2, 2))
    v = np.zeros((2, 2))
    w = np.asarray(p["w"])
    gn = np.asarray(g["w"])
    for step in range(5):
        upd, st = opt.update(g, st, params, step)
        params = apply_updates(params, upd)
        m = 0.9 * m + 0.1 * gn
        v = 0.999 * v + 0.001 * gn * gn
        mh = m / (1 - 0.9 ** (step + 1))
        vh = v / (1 - 0.999 ** (step + 1))
        w = w - 1e-2 * (mh / (np.sqrt(vh) + 1e-8) + 0.01 * w)
    np.testing.assert_allclose(np.asarray(params["w"]), w, rtol=1e-5)


def test_adamw_converges_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    p = {"x": jnp.zeros(3)}
    opt = adamw(0.1)
    st = opt.init(p)
    for i in range(300):
        g = jax.grad(lambda pp: jnp.sum((pp["x"] - target) ** 2))(p)
        upd, st = opt.update(g, st, p, i)
        p = apply_updates(p, upd)
    np.testing.assert_allclose(np.asarray(p["x"]), np.asarray(target),
                               atol=1e-2)


def test_schedules():
    s = cosine_annealing(1.0, 0.1, 100)
    assert abs(float(s(0)) - 1.0) < 1e-6
    assert abs(float(s(100)) - 0.1) < 1e-6
    w = linear_warmup_cosine(1.0, 0.0, 10, 100)
    assert float(w(5)) == 0.5
    assert abs(float(w(100))) < 1e-6


def test_clip_by_global_norm():
    g = {"a": jnp.full(4, 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-4


def test_blockwise_quant_roundtrip_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    qt = quantize_blockwise(x)
    y = dequantize_blockwise(qt, x.shape)
    rel = float(jnp.max(jnp.abs(x - y)) / jnp.max(jnp.abs(x)))
    assert rel < 1.5 / 127


def test_adam8bit_tracks_fp32_adam():
    target = jnp.asarray([0.5, -1.5, 2.5, 0.1])
    loss = lambda pp: jnp.sum((pp["x"] - target) ** 2)
    p32 = {"x": jnp.zeros(4)}
    p8 = {"x": jnp.zeros(4)}
    o32, o8 = adamw(0.05), adamw8bit(0.05)
    s32, s8 = o32.init(p32), o8.init(p8)
    for i in range(200):
        g32 = jax.grad(loss)(p32)
        g8 = jax.grad(loss)(p8)
        u32, s32 = o32.update(g32, s32, p32, i)
        u8, s8 = o8.update(g8, s8, p8, i)
        p32 = apply_updates(p32, u32)
        p8 = apply_updates(p8, u8)
    np.testing.assert_allclose(np.asarray(p8["x"]), np.asarray(p32["x"]),
                               atol=5e-2)
    np.testing.assert_allclose(np.asarray(p8["x"]), np.asarray(target),
                               atol=5e-2)


def test_grad_compression_error_feedback_unbiased():
    """SGD on a quadratic with int8-compressed grads + error feedback must
    still converge to the optimum (the residual re-enters next step)."""
    target = jnp.asarray([1.0, -1.0, 0.5])
    p = {"x": jnp.zeros(3)}
    opt = sgd(0.05)
    st = opt.init(p)
    ef = init_error_feedback(p)
    for i in range(400):
        g = jax.grad(lambda pp: jnp.sum((pp["x"] - target) ** 2))(p)
        g_hat, ef = compress_with_feedback(g, ef)
        upd, st = opt.update(g_hat, st, p, i)
        p = apply_updates(p, upd)
    np.testing.assert_allclose(np.asarray(p["x"]), np.asarray(target),
                               atol=1e-2)


def test_moment_dtype_bf16():
    opt = adamw(1e-2, moment_dtype=jnp.bfloat16)
    p = {"w": jnp.ones(8)}
    st = opt.init(p)
    assert st.mu["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full(8, 0.5)}
    upd, st = opt.update(g, st, p, 0)
    assert np.all(np.isfinite(np.asarray(upd["w"])))
