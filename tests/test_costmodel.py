"""Roofline cost-model sanity: analytic FLOPs must track 6*N_active*D for
LM training within the expected envelope (attention + readout overhead),
and the roofline terms must be internally consistent — plus regression
pins on the hillclimb verdict logic (roofline/hillclimb.py)."""
import pytest

from repro.configs import SHAPES, get
from repro.roofline.costmodel import (
    MULTI_POD, SINGLE_POD, RooflineTerms, cell_cost, decode_step_flops,
    forward_flops, train_step_flops,
)
from repro.roofline.hillclimb import _iterate, hypothesis_loop
from repro.roofline.params import analytic_active_param_count


@pytest.mark.parametrize("arch", ["qwen3_8b", "mistral_nemo_12b",
                                  "nemotron_4_340b"])
def test_train_flops_track_6nd(arch):
    cfg = get(arch)
    B, S = 256, 4096
    got = train_step_flops(cfg, B, S, remat="none")
    n = analytic_active_param_count(cfg)
    model = 6.0 * n * B * S
    # fwd+bwd = 3x fwd; attention core + embeds push it above 6ND
    assert 0.9 * model <= got <= 2.2 * model, (got / model)


def test_moe_flops_use_active_params():
    cfg = get("olmoe_1b_7b")
    B, S = 256, 4096
    fwd = forward_flops(cfg, B, S)
    n_active = analytic_active_param_count(cfg)
    model_fwd = 2.0 * n_active * B * S
    assert 0.8 * model_fwd <= fwd <= 2.5 * model_fwd, (fwd / model_fwd)


def test_decode_flops_scale_with_batch_not_ctx_for_ssm():
    cfg = get("rwkv6_1p6b")
    f1 = decode_step_flops(cfg, 128, 32768)
    f2 = decode_step_flops(cfg, 128, 524288)
    assert abs(f1 - f2) / f1 < 1e-6  # attention-free: ctx-independent
    f3 = decode_step_flops(cfg, 256, 32768)
    assert abs(f3 - 2 * f1) / f1 < 0.01


def test_decode_flops_grow_with_ctx_for_attention():
    cfg = get("qwen3_8b")
    f1 = decode_step_flops(cfg, 128, 32768)
    f2 = decode_step_flops(cfg, 128, 65536)
    assert f2 > f1 * 1.2


def test_window_caps_attention_cost():
    cfg = get("recurrentgemma_2b")
    f1 = decode_step_flops(cfg, 1, 32768)
    f2 = decode_step_flops(cfg, 1, 524288)
    assert abs(f1 - f2) / f1 < 1e-6  # local window + recurrence: O(1) decode


@pytest.mark.parametrize("shape", list(SHAPES))
def test_cell_cost_terms_positive(shape):
    cfg = get("qwen3_8b")
    t = cell_cost(cfg, SHAPES[shape], SINGLE_POD)
    assert t.t_compute > 0 and t.t_memory > 0 and t.t_collective > 0
    assert t.dominant in ("compute", "memory", "collective")
    assert 0 < t.roofline_fraction <= 1.0
    assert 0 < t.useful_ratio < 2.0


def test_multipod_halves_compute_term():
    cfg = get("qwen3_8b")
    t1 = cell_cost(cfg, SHAPES["train_4k"], SINGLE_POD)
    t2 = cell_cost(cfg, SHAPES["train_4k"], MULTI_POD)
    assert abs(t2.t_compute - t1.t_compute / 2) / t1.t_compute < 0.01


def test_decode_is_memory_bound():
    """The canonical result: single-token decode sits on the HBM roof."""
    for arch in ("qwen3_8b", "mistral_nemo_12b"):
        t = cell_cost(get(arch), SHAPES["decode_32k"], SINGLE_POD)
        assert t.t_memory > t.t_compute, (arch, t)


# ------------------------------------------------- hillclimb verdicts ----

def _terms(c, m, l):
    return RooflineTerms(flops_total=1.0, hbm_bytes_dev=1.0,
                         coll_bytes_dev=1.0, model_flops=1.0,
                         t_compute=c, t_memory=m, t_collective=l)


def _table_cost(table):
    """cost_fn stub for _iterate: look the (c, m, l) row up by kw."""
    def fn(cfg, shape, mesh, **kw):
        return _terms(*table[frozenset(kw.items())])
    return fn


def test_hillclimb_dominance_flip_scores_new_bottleneck():
    """BUGFIX pin: a change that flips the bottleneck must be scored on
    the NEW dominant term. Baseline is collective-bound (coll=10.0,
    mem=9.9); the change kills the collective term to 1.0 — the step is
    now memory-bound at 9.9, a ~1% true gain. The pre-fix code read the
    post-change value at the OLD dominant key and reported a bogus 90%
    CONFIRMED."""
    cost = _table_cost({
        frozenset(): (1.0, 9.9, 10.0),
        frozenset({("int8_a2a", True)}): (1.0, 9.9, 1.0),
    })
    log = _iterate("synthetic", None, None, {},
                   [("int8_a2a", "halve a2a payload",
                     {"int8_a2a": True}, None)], cost_fn=cost)
    row = log[1]
    assert row["dominant_before"] == "collective"
    assert row["dominant_after"] == "memory"
    assert row["dominant_term_after_s"] == 9.9
    # the stale term's collapse is still visible in the log...
    assert row["prev_dominant_term_after_s"] == 1.0
    # ...but it no longer drives the verdict: 1 - 9.9/10.0 = 1% < 2%
    assert row["verdict"].startswith("REFUTED")
    assert row["gain_on_dominant"] == "1.0%"


def test_hillclimb_genuine_gain_with_flip_still_confirms():
    """A flip with a real gain on the new bottleneck stays CONFIRMED and
    the accepted state carries forward to the next step's baseline."""
    cost = _table_cost({
        frozenset(): (1.0, 4.0, 10.0),
        frozenset({("a", True)}): (1.0, 4.0, 2.0),       # coll->mem, -60%
        frozenset({("a", True), ("b", True)}): (1.0, 3.0, 2.0),
    })
    log = _iterate("synthetic", None, None, {},
                   [("a", "", {"a": True}, None),
                    ("b", "", {"b": True}, None)], cost_fn=cost)
    assert log[1]["verdict"] == "CONFIRMED"
    assert log[1]["dominant_after"] == "memory"
    assert log[1]["dominant_term_after_s"] == 4.0       # new bottleneck
    # step 2 baselines on the ACCEPTED step-1 state (mem=4.0 -> 3.0)
    assert log[2]["dominant_term_before_s"] == 4.0
    assert log[2]["verdict"] == "CONFIRMED"


def test_hypothesis_loop_keeps_only_confirmed():
    """The generic loop (shared with launch/autotune.py): lower-is-better
    scores, steps applied on top of the best kw so far, refuted steps
    rolled back."""
    scores = {frozenset(): 100.0,
              frozenset({("x", 2)}): 50.0,           # confirmed
              frozenset({("x", 2), ("y", 1)}): 49.5,  # <2% -> refuted
              frozenset({("x", 2), ("z", 0)}): 25.0}  # confirmed

    def evaluate(kw):
        return scores[frozenset(kw.items())], {"probe": len(kw)}

    best_kw, best, log = hypothesis_loop(
        evaluate,
        [("x", "", {"x": 2}), ("y", "", {"y": 1}), ("z", "", {"z": 0})],
        {})
    assert best_kw == {"x": 2, "z": 0} and best == 25.0
    assert log[0]["score"] == 100.0
    assert [r["verdict"] for r in log[1:]] == \
        ["CONFIRMED", "REFUTED (<2%)", "CONFIRMED"]
    assert log[2]["score_before"] == 50.0   # refuted step baselines on best
    assert all("probe" in r for r in log)
