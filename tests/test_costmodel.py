"""Roofline cost-model sanity: analytic FLOPs must track 6*N_active*D for
LM training within the expected envelope (attention + readout overhead),
and the roofline terms must be internally consistent."""
import pytest

from repro.configs import SHAPES, get
from repro.roofline.costmodel import (
    MULTI_POD, SINGLE_POD, cell_cost, decode_step_flops, forward_flops,
    train_step_flops,
)
from repro.roofline.params import analytic_active_param_count


@pytest.mark.parametrize("arch", ["qwen3_8b", "mistral_nemo_12b",
                                  "nemotron_4_340b"])
def test_train_flops_track_6nd(arch):
    cfg = get(arch)
    B, S = 256, 4096
    got = train_step_flops(cfg, B, S, remat="none")
    n = analytic_active_param_count(cfg)
    model = 6.0 * n * B * S
    # fwd+bwd = 3x fwd; attention core + embeds push it above 6ND
    assert 0.9 * model <= got <= 2.2 * model, (got / model)


def test_moe_flops_use_active_params():
    cfg = get("olmoe_1b_7b")
    B, S = 256, 4096
    fwd = forward_flops(cfg, B, S)
    n_active = analytic_active_param_count(cfg)
    model_fwd = 2.0 * n_active * B * S
    assert 0.8 * model_fwd <= fwd <= 2.5 * model_fwd, (fwd / model_fwd)


def test_decode_flops_scale_with_batch_not_ctx_for_ssm():
    cfg = get("rwkv6_1p6b")
    f1 = decode_step_flops(cfg, 128, 32768)
    f2 = decode_step_flops(cfg, 128, 524288)
    assert abs(f1 - f2) / f1 < 1e-6  # attention-free: ctx-independent
    f3 = decode_step_flops(cfg, 256, 32768)
    assert abs(f3 - 2 * f1) / f1 < 0.01


def test_decode_flops_grow_with_ctx_for_attention():
    cfg = get("qwen3_8b")
    f1 = decode_step_flops(cfg, 128, 32768)
    f2 = decode_step_flops(cfg, 128, 65536)
    assert f2 > f1 * 1.2


def test_window_caps_attention_cost():
    cfg = get("recurrentgemma_2b")
    f1 = decode_step_flops(cfg, 1, 32768)
    f2 = decode_step_flops(cfg, 1, 524288)
    assert abs(f1 - f2) / f1 < 1e-6  # local window + recurrence: O(1) decode


@pytest.mark.parametrize("shape", list(SHAPES))
def test_cell_cost_terms_positive(shape):
    cfg = get("qwen3_8b")
    t = cell_cost(cfg, SHAPES[shape], SINGLE_POD)
    assert t.t_compute > 0 and t.t_memory > 0 and t.t_collective > 0
    assert t.dominant in ("compute", "memory", "collective")
    assert 0 < t.roofline_fraction <= 1.0
    assert 0 < t.useful_ratio < 2.0


def test_multipod_halves_compute_term():
    cfg = get("qwen3_8b")
    t1 = cell_cost(cfg, SHAPES["train_4k"], SINGLE_POD)
    t2 = cell_cost(cfg, SHAPES["train_4k"], MULTI_POD)
    assert abs(t2.t_compute - t1.t_compute / 2) / t1.t_compute < 0.01


def test_decode_is_memory_bound():
    """The canonical result: single-token decode sits on the HBM roof."""
    for arch in ("qwen3_8b", "mistral_nemo_12b"):
        t = cell_cost(get(arch), SHAPES["decode_32k"], SINGLE_POD)
        assert t.t_memory > t.t_compute, (arch, t)
