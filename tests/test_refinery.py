"""Online refinery (launch/refinery.py): ledger determinism + bounds,
capture bitwise parity on every serving loop, trainer convergence over
captured residuals, hot-swap zero-retrace + liveness, the shadow
promotion gate (promote / reject / rollback), and the graceful-drain
hooks (``should_admit`` admission stop, ledger flush roundtrip)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.hyper_step.ops import TRACE_COUNTS
from repro.launch.engine import EngineConfig, MultiRateEngine
from repro.launch.refinery import Refinery, RefineryConfig, ResidualLedger
from repro.launch.scheduler import InflightScheduler
from repro.launch.workload import (
    drifting_requests, heterogeneous_requests, poisson_trace,
    replay_engine, replay_scheduler, toy_refinable_classifier,
)

D = 16


def _ecfg(**kw):
    # fixed K=2 + seg=1 below: every request crosses one interior
    # segment boundary, so the scheduler's retire hook has healthy
    # interior rows to capture
    kw.setdefault("controller", "fixed")
    kw.setdefault("fixed_K", 2)
    kw.setdefault("buckets", (2,))
    return EngineConfig(**kw)


def _sched(model, ledger=None, overlap=False, slots=8):
    return InflightScheduler(model, _ecfg(), slots=slots, seg=1,
                             overlap=overlap, ledger=ledger)


def _fill_ledger(model, **led_kw):
    led_kw.setdefault("capacity", 256)
    led_kw.setdefault("seed", 0)
    led = ResidualLedger(model, **led_kw)
    sched = _sched(model, ledger=led)
    xs = heterogeneous_requests(32, D, seed=3)
    replay_scheduler(sched, poisson_trace(xs, rate=1.0, seed=7))
    return led


# -------------------------------------------------------------- ledger ----

def test_ledger_validation_errors():
    model = toy_refinable_classifier(d=D)
    with pytest.raises(ValueError, match="capacity"):
        ResidualLedger(model, capacity=0)
    with pytest.raises(ValueError, match="capture_rate"):
        ResidualLedger(model, capture_rate=1.5)
    with pytest.raises(ValueError, match="capture_rate"):
        ResidualLedger(model, capture_rate=-0.1)


def test_ledger_reservoir_is_bounded_and_seeded():
    model = toy_refinable_classifier(d=D)
    leds = [_fill_ledger(model, capacity=8, seed=5) for _ in range(2)]
    for led in leds:
        assert led.fill <= 8 and led.holdout_fill <= 8
        assert led.seen > 8          # the reservoir actually overflowed
    # same seed, same traffic -> identical reservoir contents
    a, b = leds
    assert a.seen == b.seen
    for ta, tb in zip(a._samples, b._samples):
        assert ta[0] == tb[0] and ta[1] == tb[1]
        for la, lb in zip(jax.tree_util.tree_leaves(ta[2]),
                          jax.tree_util.tree_leaves(tb[2])):
            np.testing.assert_array_equal(la, lb)


def test_capture_rate_zero_captures_nothing():
    model = toy_refinable_classifier(d=D)
    led = _fill_ledger(model, capture_rate=0.0)
    assert led.fill == 0 and led.seen == 0 and led.captures == 0


def test_scheduler_captures_interior_rows_only():
    """Captured depths are interior mesh points (0 < s < 1): the retire
    hook reads live rows mid-flight, never admission or finished state.
    """
    model = toy_refinable_classifier(d=D)
    led = _fill_ledger(model)
    assert led.fill > 0
    s_vals = np.asarray([t[0] for t in led._samples + led._holdout])
    assert np.all((s_vals > 0.0) & (s_vals < 1.0)), np.unique(s_vals)


def test_engine_captures_under_fixed_controller():
    """The drain engine has no probe under controller='fixed' — capture
    must still fire (it embeds its own state copy)."""
    model = toy_refinable_classifier(d=D)
    led = ResidualLedger(model, capacity=64, seed=0)
    eng = MultiRateEngine(model, _ecfg(), ledger=led)
    eng.run(heterogeneous_requests(16, D, seed=3))
    assert led.fill > 0


def test_capture_parity_bitwise_all_loops():
    """ACCEPTANCE: capture on (rate=1.0) vs off — completions uid-for-uid
    bitwise identical on the sync scheduler, the overlap scheduler, and
    the drain engine (capture only READS resident state and is never
    priced by the cost oracle)."""
    from benchmarks.bench_faults import records_bitwise_equal
    xs = heterogeneous_requests(24, D, seed=11)
    trace = poisson_trace(xs, rate=0.5, seed=13)

    def pair(mk_loop, replay):
        m_off, m_on = (toy_refinable_classifier(d=D) for _ in range(2))
        rep_off = replay(mk_loop(m_off, None), trace)
        rep_on = replay(
            mk_loop(m_on, ResidualLedger(m_on, capacity=64, seed=0)),
            trace)
        return records_bitwise_equal(rep_off, rep_on)

    assert pair(lambda m, led: _sched(m, ledger=led), replay_scheduler)
    assert pair(lambda m, led: _sched(m, ledger=led, overlap=True),
                replay_scheduler)
    assert pair(lambda m, led: MultiRateEngine(m, _ecfg(), ledger=led),
                replay_engine)


def test_ledger_flush_roundtrip(tmp_path):
    model = toy_refinable_classifier(d=D)
    led = _fill_ledger(model)
    path = os.path.join(str(tmp_path), "ledger.npz")
    n = led.flush(path)
    assert n == led.fill + led.holdout_fill
    data = np.load(path)
    assert int(data["n_train"]) == led.fill
    assert data["s"].shape == (n,) and data["eps"].shape == (n,)
    assert data["z_0"].shape[0] == n and data["R_0"].shape[0] == n
    # an empty ledger still writes a readable file
    led2 = ResidualLedger(model, capacity=4, capture_rate=0.0)
    p2 = os.path.join(str(tmp_path), "empty.npz")
    assert led2.flush(p2) == 0
    assert int(np.load(p2)["n_train"]) == 0


# ------------------------------------------------------------- trainer ----

def test_trainer_converges_on_captured_residuals():
    model = toy_refinable_classifier(d=D, hidden=16)
    led = _fill_ledger(model, capacity=256)
    refin = Refinery(model, led,
                     RefineryConfig(steps_per_tick=60, batch_size=32,
                                    min_fill=8, lr=5e-3, total_steps=600))
    b = led.sample_batch(64, np.random.RandomState(0))
    loss0 = float(refin._eval_loss(refin.candidate, b["s"], b["eps"],
                                   b["z"], b["dz"], b["R"]))
    for _ in range(10):
        last = refin.train_tick()
    assert refin.steps == 600
    loss1 = float(refin._eval_loss(refin.candidate, b["s"], b["eps"],
                                   b["z"], b["dz"], b["R"]))
    assert loss1 < 0.5 * loss0, (loss0, loss1)
    assert last is not None
    # holdout residual-norm score drops too (generalizes off-batch)
    fr = refin.shadow_score(
        jax.tree_util.tree_map(jnp.asarray, model.g_params))
    ca = refin.shadow_score(refin.candidate)
    assert ca["resid"] < fr["resid"]


def test_trainer_noop_below_min_fill():
    model = toy_refinable_classifier(d=D)
    led = ResidualLedger(model, capacity=64)
    refin = Refinery(model, led, RefineryConfig(min_fill=8))
    assert refin.train_tick() is None and refin.steps == 0


def test_refinery_requires_parametric_model():
    from repro.launch.workload import toy_classifier
    model = toy_classifier("euler")
    led = ResidualLedger(toy_refinable_classifier(d=D), capacity=4)
    with pytest.raises(ValueError, match="parametric"):
        Refinery(model, led)


def test_refinery_async_checkpoints_candidate(tmp_path):
    model = toy_refinable_classifier(d=D)
    led = _fill_ledger(model)
    refin = Refinery(model, led,
                     RefineryConfig(steps_per_tick=4, min_fill=8,
                                    ckpt_every=2),
                     ckpt_dir=str(tmp_path))
    refin.train_tick()
    refin.flush()
    from repro.checkpoint import CheckpointManager
    cm = CheckpointManager(str(tmp_path))
    step, state = cm.restore_latest(
        jax.eval_shape(lambda: refin.candidate))
    assert step == 4
    np.testing.assert_array_equal(np.asarray(state["w1"]),
                                  np.asarray(refin.candidate["w1"]))


# ------------------------------------------------------------ hot swap ----

def test_hot_swap_mid_flight_no_retrace_and_live():
    """ACCEPTANCE: swapping g mid-replay (pool busy, between segments)
    compiles NOTHING — TRACE_COUNTS frozen — and the swapped params are
    LIVE: completions after the swap differ from a never-swapped run."""
    xs = heterogeneous_requests(24, D, seed=21)
    trace = poisson_trace(xs, rate=0.25, seed=23)
    new_gp = jax.tree_util.tree_map(
        lambda l: l + 0.5, toy_refinable_classifier(d=D).g_params)

    def run(swap):
        sched = _sched(toy_refinable_classifier(d=D))
        state = {"tick": 0, "before": None}

        def on_tick(s):
            state["tick"] += 1
            if swap and state["tick"] == 3:
                assert s.pending, "swap must land on a busy pool"
                state["before"] = TRACE_COUNTS["fused_rk_update"]
                s.hot_swap_g(new_gp)

        rep = replay_scheduler(sched, trace, on_tick=on_tick)
        if swap:
            assert state["before"] is not None
            assert TRACE_COUNTS["fused_rk_update"] == state["before"], \
                "hot_swap_g retraced a pool cell"
        return {r.uid: r.outputs for r in rep.records}

    plain, swapped = run(False), run(True)
    assert set(plain) == set(swapped)
    assert any(not np.array_equal(plain[u], swapped[u]) for u in plain), \
        "swapped params never reached the pool cells"


def test_engine_hot_swap_no_retrace_and_live():
    model = toy_refinable_classifier(d=D)
    eng = MultiRateEngine(model, _ecfg())
    xs = heterogeneous_requests(8, D, seed=31)
    out_a = {c.uid: c.outputs for c in eng.run(xs)}
    before = TRACE_COUNTS["fused_rk_update"]
    eng.hot_swap_g(jax.tree_util.tree_map(lambda l: l + 0.5,
                                          model.g_params))
    out_b = {c.uid: c.outputs for c in eng.run(xs)}
    assert TRACE_COUNTS["fused_rk_update"] == before
    # uids keep counting across runs; requests resubmit in order, so
    # uid u in run B served the same x as uid u - 8 in run A
    assert len(out_a) == len(out_b) == 8
    assert any(not np.array_equal(out_a[u - 8], out_b[u])
               for u in out_b)


def test_hot_swap_validation_errors():
    model = toy_refinable_classifier(d=D)
    sched = _sched(model)
    gp = sched.g_params
    with pytest.raises(ValueError):                     # shape mismatch
        sched.hot_swap_g(jax.tree_util.tree_map(
            lambda l: jnp.zeros(l.shape + (1,), l.dtype), gp))
    with pytest.raises(ValueError):                     # dtype mismatch
        sched.hot_swap_g(jax.tree_util.tree_map(
            lambda l: jnp.zeros(l.shape, jnp.int32), gp))
    with pytest.raises(ValueError):                     # treedef mismatch
        sched.hot_swap_g({"nope": jnp.zeros(())})
    from repro.launch.workload import toy_classifier
    with pytest.raises(ValueError, match="parametric"):
        _sched(toy_classifier("euler")).hot_swap_g(gp)


# --------------------------------------------------------- shadow gate ----

def _refinery(model, led, **cfg_kw):
    cfg_kw.setdefault("min_fill", 8)
    cfg_kw.setdefault("ref_K", 32)
    return Refinery(model, led, RefineryConfig(**cfg_kw), ecfg=_ecfg(),
                    shadow_xs=heterogeneous_requests(8, D, seed=99))


def test_gate_promotes_trained_candidate_into_targets():
    model = toy_refinable_classifier(d=D)
    led = _fill_ledger(model, capacity=256)
    sched = _sched(model)
    refin = _refinery(model, led, steps_per_tick=30, lr=5e-3,
                      total_steps=300)
    for _ in range(10):
        refin.train_tick()
    old = sched.g_params
    verdict = refin.maybe_promote([sched])
    assert verdict["promoted"] and refin.promotions == 1
    assert refin.last_promotion == refin.steps
    # the target now serves the promoted params
    assert all(np.array_equal(a, b) for a, b in zip(
        jax.tree_util.tree_leaves(sched.g_params),
        jax.tree_util.tree_leaves(refin.current)))
    assert any(not np.array_equal(a, b) for a, b in zip(
        jax.tree_util.tree_leaves(old),
        jax.tree_util.tree_leaves(sched.g_params)))


def test_gate_rejects_corrupted_candidate():
    model = toy_refinable_classifier(d=D)
    led = _fill_ledger(model)
    sched = _sched(model)
    refin = _refinery(model, led)
    rng = np.random.RandomState(0)
    refin.candidate = jax.tree_util.tree_map(
        lambda l: l + 100.0 * rng.standard_normal(l.shape).astype(l.dtype),
        refin.candidate)
    old = sched.g_params
    verdict = refin.maybe_promote([sched])
    assert not verdict["promoted"] and refin.rejections == 1
    # serving params untouched by the rejected candidate
    assert all(np.array_equal(a, b) for a, b in zip(
        jax.tree_util.tree_leaves(old),
        jax.tree_util.tree_leaves(sched.g_params)))


def test_check_promoted_rolls_back_regressed_params():
    model = toy_refinable_classifier(d=D)
    led = _fill_ledger(model, capacity=256)
    sched = _sched(model)
    refin = _refinery(model, led, steps_per_tick=30, lr=5e-3,
                      total_steps=300)
    for _ in range(10):
        refin.train_tick()
    assert refin.maybe_promote([sched])["promoted"]
    good = refin.current
    # the promoted params rot in place (checkpoint corruption, a bad
    # in-place update...): the post-promotion guard must restore prev
    rng = np.random.RandomState(1)
    refin.current = jax.tree_util.tree_map(
        lambda l: l + 100.0 * rng.standard_normal(l.shape).astype(l.dtype),
        good)
    assert refin.check_promoted([sched]) is True
    assert refin.rollbacks == 1
    prev = refin.current                   # rolled back to pre-promotion
    assert all(np.array_equal(a, b) for a, b in zip(
        jax.tree_util.tree_leaves(sched.g_params),
        jax.tree_util.tree_leaves(prev)))
    assert refin.check_promoted([sched]) is None   # handle consumed


def test_status_keys_for_progress_line():
    model = toy_refinable_classifier(d=D)
    refin = _refinery(model, ResidualLedger(model, capacity=8))
    st = refin.status()
    for key in ("ledger_fill", "ledger_seen", "candidate_step",
                "last_loss", "last_promotion", "promotions",
                "rejections", "rollbacks"):
        assert key in st


# ------------------------------------------------------- graceful drain ----

def test_should_admit_false_drains_inflight_and_stops_admission():
    """The graceful-shutdown contract: once ``should_admit`` goes False,
    no further arrivals are admitted, every in-flight request still
    reaches a terminal record, and the replay loop exits."""
    model = toy_refinable_classifier(d=D)
    sched = _sched(model, slots=4)
    xs = heterogeneous_requests(24, D, seed=41)
    trace = poisson_trace(xs, rate=0.25, seed=43)
    ticks = [0]

    def on_tick(s):
        ticks[0] += 1

    rep = replay_scheduler(sched, trace, on_tick=on_tick,
                           should_admit=lambda: ticks[0] < 3)
    assert 0 < len(rep.records) < len(trace)
    assert sched.pending == 0
    assert all(r.status in ("ok", "retried") for r in rep.records)


def test_drifting_requests_seeded_and_nonstationary():
    a = drifting_requests(48, D, seed=3)
    b = drifting_requests(48, D, seed=3)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (48, D)
    # the phases drift: the late third is harder (larger mean norm) than
    # the early third
    n = len(a) // 3
    early = np.linalg.norm(a[:n], axis=1).mean()
    late = np.linalg.norm(a[-n:], axis=1).mean()
    assert late > early
