"""The K=0 flow tier (PR 10): FlowHead solution operator, the shared
residual-ledger fitting path, the three-way tier router, and the
flow-disabled bitwise-parity acceptance — engine, in-flight sync,
in-flight overlap, and the forced-4-device sharded pool (subprocess).

The acceptance pins:
  * a ZERO-INIT flow head is EXACTLY one full-span base Euler step —
    so a cold flow tier can never silently change numerics, and every
    later gain is attributable to the ledger fit;
  * ``flow_fitting_loss`` of the structured head reduces EXACTLY to
    ``ledger_fitting_loss`` of its inner net — the flow tier and the
    hypersolver g fit the same target off the same reservoir;
  * with the flow tier disabled (``flow_threshold=0``) or with a
    threshold that routes zero requests, completions are uid-for-uid
    BITWISE identical to a serve with no flow head attached — the tier
    is pure packing policy;
  * ``hot_swap_flow`` validates like ``hot_swap_g`` (params-are-inputs:
    a structural mismatch would silently retrace every cell).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TierRouter, flow_combine, make_flow_apply
from repro.core.residual import flow_fitting_loss, ledger_fitting_loss
from repro.launch.engine import (
    EngineConfig, MultiRateEngine, prepare_model,
)
from repro.launch.scheduler import InflightScheduler
from repro.launch.workload import (
    heterogeneous_requests, poisson_trace, replay_engine, replay_scheduler,
    toy_flow_classifier, toy_refinable_classifier,
)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

D = 12   # distinct from test_faults (10) / test_scheduler (8): the fused
#          segment cell is globally cached per signature


def _ecfg(flow_threshold=0.0, **kw):
    kw.setdefault("buckets", (2, 4, 8, 16))
    kw.setdefault("tol", 5e-3)
    kw.setdefault("max_batch", 8)
    kw.setdefault("solver", "hyper_euler")
    kw.setdefault("fused", True)
    return EngineConfig(flow_threshold=flow_threshold, **kw)


# ------------------------------------------------------- flow head unit ----

def test_zero_init_flow_is_exactly_one_euler_step():
    """F(fp0, eps, s, z, dz) == z + eps*dz bitwise for a zero-output-init
    net — the cold flow tier IS the base solver's full-span step."""
    model = toy_flow_classifier(d=D)
    z = jnp.asarray(np.random.RandomState(0).randn(5, D), jnp.float32)
    dz = jnp.asarray(np.random.RandomState(1).randn(5, D), jnp.float32)
    eps = jnp.float32(1.0)
    out = model.flow_apply(model.flow_params, eps, jnp.float32(0.0), z, dz)
    euler = z + eps * dz
    assert np.array_equal(np.asarray(out), np.asarray(euler))


def test_flow_combine_order_scaling():
    """The correction enters at eps^{p+1} — the same scaling the
    hypersolver update uses (paper Eq. 5)."""
    z = jnp.ones((3,)); dz = jnp.full((3,), 2.0); corr = jnp.full((3,), 5.0)
    for order in (1, 2, 4):
        got = flow_combine(jnp.float32(0.5), z, dz, corr, order=order)
        want = z + 0.5 * dz + 0.5 ** (order + 1) * corr
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6)


def test_flow_fitting_loss_reduces_to_ledger_fitting_loss():
    """For the structured head the Euler part cancels: fitting F equals
    fitting its inner net on the raw residual rows — one ledger, two
    tiers. (Default relative=False; the relative variant reweights per
    sample and is pinned separately.)"""
    rs = np.random.RandomState(7)
    n = 16
    s = jnp.asarray(rs.rand(n), jnp.float32)
    eps = jnp.asarray(0.1 + rs.rand(n), jnp.float32)
    z = jnp.asarray(rs.randn(n, D), jnp.float32)
    dz = jnp.asarray(rs.randn(n, D), jnp.float32)
    R = jnp.asarray(rs.randn(n, D), jnp.float32)

    def net(fp, e, si, zi, dzi):
        return fp["w"] * zi + dzi * e

    fp = {"w": jnp.float32(0.3)}
    fa = make_flow_apply(net, order=1)
    flow = lambda e, si, zi, dzi: fa(fp, e, si, zi, dzi)
    g = lambda e, si, zi, dzi: net(fp, e, si, zi, dzi)
    lf = float(flow_fitting_loss(flow, s, eps, z, dz, R, order=1))
    lg = float(ledger_fitting_loss(g, s, eps, z, dz, R))
    np.testing.assert_allclose(lf, lg, rtol=1e-4)
    # relative=True downweights each sample by 1 + ||R||: strictly
    # smaller on any batch with nonzero residuals, and still positive
    lr = float(flow_fitting_loss(flow, s, eps, z, dz, R, order=1,
                                 relative=True))
    assert 0.0 < lr < lf


# ----------------------------------------------------------- tier router ----

def test_tier_router_masks_and_bounds():
    r = TierRouter(flow_threshold=0.5, hyper_k_max=4)
    err = jnp.asarray([0.001, 0.004, 0.01, np.nan, np.inf, 0.0])
    tol = 0.01
    k_floor = jnp.asarray([0, 0, 0, 0, 0, 3])
    mask = np.asarray(r.flow_mask(err, tol, k_floor))
    # 0.001/0.004 pass (<= 0.005); 0.01 exceeds the gate; non-finite
    # and escalated (k_floor > 0) rows are excluded unconditionally
    assert mask.tolist() == [True, True, False, False, False, False]
    tiers = np.asarray(r.tier_of(jnp.asarray([2, 4, 8, 16])))
    assert tiers.tolist() == [1, 1, 2, 2]
    with pytest.raises(ValueError, match="confidence fraction"):
        TierRouter(flow_threshold=1.5)
    with pytest.raises(ValueError, match="confidence fraction"):
        TierRouter(flow_threshold=-0.1)


def test_engine_config_flow_validation():
    """flow_threshold > 0 demands a flow-capable model and a probing
    controller — fail at prepare time, not mid-serve."""
    with pytest.raises(ValueError, match="flow_threshold"):
        EngineConfig(flow_threshold=1.5)
    flowless = toy_refinable_classifier(d=D)
    with pytest.raises(ValueError, match="flow"):
        prepare_model(flowless, _ecfg(0.25))
    with pytest.raises(ValueError, match="controller"):
        prepare_model(toy_flow_classifier(d=D),
                      _ecfg(0.25, controller="fixed", fixed_K=4))


# ----------------------------------------------------- flow-tier serving ----

def test_engine_serves_flow_tier_with_k0_accounting():
    """Zero-init g makes every probe error 0, so every request is
    confidently easy: all complete on the flow tier with K=0, status
    'ok', and nfe == the engine's nfe_flow (probe + combine)."""
    eng = MultiRateEngine(toy_flow_classifier(d=D), _ecfg(0.25))
    xs = heterogeneous_requests(12, D, seed=0)
    uids = [eng.submit(x) for x in xs]
    done = {}
    while len(eng):
        for c in eng.step():
            done[c.uid] = c
    assert set(done) == set(uids)
    for c in done.values():
        assert c.K == 0 and c.status == "ok"
        assert c.nfe == eng.nfe_flow
        assert np.isfinite(c.outputs).all()
    assert eng.last_report.flow_served == 12


@pytest.mark.parametrize("overlap", [False, True])
def test_scheduler_serves_flow_tier(overlap):
    sched = InflightScheduler(toy_flow_classifier(d=D), _ecfg(0.25),
                              slots=4, seg=2, overlap=overlap)
    xs = heterogeneous_requests(10, D, seed=1)
    uids = [sched.submit(x) for x in xs]
    done = {}
    while sched.pending:
        for c in sched.step():
            done[c.uid] = c
    assert set(done) == set(uids)
    assert all(c.K == 0 and c.status == "ok" for c in done.values())
    assert sched.total_flow_served == 10
    assert sched.total_escalated == 0


def test_flow_sync_overlap_bitwise_parity():
    """Sync and overlap resolve the same flow-routed trace to bitwise
    identical completions (same jit cell, same staging drain)."""
    xs = heterogeneous_requests(12, D, seed=5)
    trace = poisson_trace(xs, rate=0.25, seed=105)
    reps = {}
    for ov in (False, True):
        sched = InflightScheduler(toy_flow_classifier(d=D), _ecfg(0.25),
                                  slots=4, seg=2, overlap=ov)
        reps[ov] = {r.uid: r for r in replay_scheduler(sched, trace).records}
    assert set(reps[False]) == set(reps[True])
    for u, ra in reps[False].items():
        rb = reps[True][u]
        assert (ra.status, ra.K, ra.nfe, ra.t_done) == \
            (rb.status, rb.K, rb.nfe, rb.t_done)
        assert np.array_equal(ra.outputs, rb.outputs)


# ------------------------------------- flow-disabled bitwise parity (e2e) ----

def _bitwise_records_equal(a, b):
    ra = {r.uid: r for r in a.records}
    rb = {r.uid: r for r in b.records}
    if set(ra) != set(rb):
        return False
    for u in ra:
        x, y = ra[u], rb[u]
        if (x.status, x.K, x.nfe, x.t_submit, x.t_done) != \
                (y.status, y.K, y.nfe, y.t_submit, y.t_done):
            return False
        if (x.outputs is None) != (y.outputs is None):
            return False
        if x.outputs is not None and not np.array_equal(
                x.outputs, y.outputs, equal_nan=True):
            return False
    return True


@pytest.mark.parametrize("threshold", [0.0, 1e-6])
def test_flow_disabled_parity_all_loops(threshold):
    """ACCEPTANCE: flow_threshold=0 (tier off) — and a threshold so
    tight ZERO requests qualify — serve uid-for-uid bitwise identical to
    a model with no flow head attached, on all three loops. The embedded
    controller gives every row a real positive probe error, so the 1e-6
    gate routes nothing while exercising the live router."""
    kw = {"controller": "embedded"}
    ecfg_flow = _ecfg(threshold, **kw)
    ecfg_off = _ecfg(0.0, **kw)
    xs = heterogeneous_requests(14, D, seed=9)
    trace = poisson_trace(xs, rate=0.25, seed=109)

    def serve(model, ecfg):
        eng = replay_engine(MultiRateEngine(model, ecfg), trace)
        sy = replay_scheduler(
            InflightScheduler(model, ecfg, slots=4, seg=2), trace)
        ov = replay_scheduler(
            InflightScheduler(model, ecfg, slots=4, seg=2, overlap=True),
            trace)
        return eng, sy, ov

    with_flow = serve(toy_flow_classifier(d=D), ecfg_flow)
    without = serve(toy_refinable_classifier(d=D), ecfg_off)
    for a, b in zip(with_flow, without):
        assert _bitwise_records_equal(a, b)
        assert all(r.K > 0 for r in a.records)   # nothing flow-routed


def test_hot_swap_flow_validates_structure():
    """hot_swap_flow is zero-retrace ONLY for structurally identical
    params; a mismatched pytree or dtype must refuse (engine and
    scheduler share validate_g_swap)."""
    eng = MultiRateEngine(toy_flow_classifier(d=D), _ecfg(0.25))
    good = jax.tree_util.tree_map(lambda l: l + 1.0, eng.flow_params)
    eng.hot_swap_flow(good)
    with pytest.raises(ValueError, match="hot_swap_flow"):
        eng.hot_swap_flow({"wrong": jnp.zeros(3)})
    sched = InflightScheduler(toy_flow_classifier(d=D), _ecfg(0.25),
                              slots=4, seg=2)
    sched.hot_swap_flow(good)
    with pytest.raises(ValueError, match="hot_swap_flow"):
        sched.hot_swap_flow({"wrong": jnp.zeros(3)})


# ------------------------------------------------------ bench check gate ----

def test_bench_flow_check_gate():
    """``run.py --check``'s flow section passes well-formed rows and
    fails fast on a missing variant, a lost pareto win, a broken parity,
    or a regressed verdict."""
    if REPO_ROOT not in sys.path:
        sys.path.insert(0, REPO_ROOT)
    from benchmarks.run import _check_flow_section

    good = [
        {"bench": "flow", "section": "pareto", "variant": "hyper_multirate",
         "agreement": 0.93, "mean_nfe": 9.0, "flow_served": 0},
        {"bench": "flow", "section": "pareto", "variant": "three_tier",
         "agreement": 0.99, "mean_nfe": 8.5, "flow_served": 40},
        {"bench": "flow", "section": "flow_disabled_parity",
         "mode": "engine", "parity": True},
        {"bench": "flow", "section": "flow_disabled_parity",
         "mode": "inflight", "parity": True},
        {"bench": "flow", "section": "flow_disabled_parity",
         "mode": "inflight_overlap", "parity": True},
        {"bench": "flow", "section": "escalation", "mode": "inflight",
         "escalated": 5, "zero_hang": True},
        {"bench": "flow", "mode": "verdict", "three_tier_dominates": True,
         "flow_disabled_parity": True, "escalation_accounted": True,
         "zero_hang": True},
    ]
    assert _check_flow_section("BENCH_flow.json", good) == []
    slow = [dict(good[1], mean_nfe=9.5)] + good[:1] + good[2:]
    assert any("strictly below" in e for e in
               _check_flow_section("BENCH_flow.json", slow))
    vac = [good[0], dict(good[1], flow_served=0)] + good[2:]
    assert any("vacuous" in e for e in
               _check_flow_section("BENCH_flow.json", vac))
    broken = good[:3] + [dict(good[3], parity=False)] + good[4:]
    assert any("not at parity" in e for e in
               _check_flow_section("BENCH_flow.json", broken))
    noesc = good[:5] + [dict(good[5], escalated=0)] + good[6:]
    assert any("requeued" in e for e in
               _check_flow_section("BENCH_flow.json", noesc))
    regressed = good[:6] + [dict(good[6], three_tier_dominates=False)]
    assert any("three_tier_dominates" in e for e in
               _check_flow_section("BENCH_flow.json", regressed))


# ------------------------------------------------- sharded pool (4 dev) ----

_SHARDED_FLOW_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax

    from repro.launch.engine import EngineConfig
    from repro.launch.mesh import make_serving_mesh
    from repro.launch.scheduler import InflightScheduler
    from repro.launch.workload import (
        heterogeneous_requests, poisson_trace, replay_scheduler,
        toy_flow_classifier, toy_refinable_classifier,
    )

    assert jax.device_count() == 4, jax.device_count()
    mesh = make_serving_mesh(4)
    D = 12

    def serve(model, ft, overlap=False):
        ecfg = EngineConfig(buckets=(2, 4, 8, 16), tol=5e-3, fused=True,
                            solver="hyper_euler", controller="embedded",
                            flow_threshold=ft)
        sched = InflightScheduler(model, ecfg, slots=8, seg=2,
                                  mesh=mesh, overlap=overlap)
        return replay_scheduler(sched, trace)

    xs = heterogeneous_requests(16, D, seed=9)
    trace = poisson_trace(xs, rate=0.25, seed=109)

    # flow-disabled parity on the sharded pool: a threshold routing zero
    # requests serves bitwise like a flowless model, sync and overlap
    for overlap in (False, True):
        a = {r.uid: r for r in serve(
            toy_flow_classifier(d=D), 1e-6, overlap).records}
        b = {r.uid: r for r in serve(
            toy_refinable_classifier(d=D), 0.0, overlap).records}
        assert set(a) == set(b)
        for u in a:
            ra, rb = a[u], b[u]
            assert (ra.status, ra.K, ra.nfe, ra.t_done) == (
                rb.status, rb.K, rb.nfe, rb.t_done)
            assert np.array_equal(ra.outputs, rb.outputs)
            assert ra.K > 0
    print("SHARDED_FLOW_PARITY_OK")

    # and the flow tier itself serves on the mesh (zero-init g routes
    # everything under the residual controller)
    ecfg_f = EngineConfig(buckets=(2, 4, 8, 16), tol=5e-3, fused=True,
                          solver="hyper_euler", flow_threshold=0.25)
    sched = InflightScheduler(toy_flow_classifier(d=D), ecfg_f, slots=8,
                              seg=2, mesh=mesh)
    rep = replay_scheduler(sched, trace)
    assert len(rep.records) == 16
    assert all(r.K == 0 and r.status == "ok" for r in rep.records)
    assert sched.total_flow_served == 16
    print("SHARDED_FLOW_SERVE_OK")
""")


@pytest.mark.slow
def test_sharded_flow_parity_subprocess():
    """EDGE (tier-2): the flow-disabled parity acceptance and the flow
    tier itself on a forced 4-device mesh (device topology is frozen at
    first jax init, hence subprocess)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SHARDED_FLOW_SCRIPT],
                          env=env, capture_output=True, text=True,
                          timeout=600, cwd=REPO_ROOT)
    out = proc.stdout + proc.stderr
    assert proc.returncode == 0, out[-4000:]
    for marker in ("SHARDED_FLOW_PARITY_OK", "SHARDED_FLOW_SERVE_OK"):
        assert marker in out, out[-4000:]
