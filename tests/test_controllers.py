"""Step controllers (core/controllers.py) and the controller-driven
multi-rate solve path of the Integrator: selection monotonicity, shared
embedded-error machinery, per-sample NFE accounting, probe-stage reuse."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    EmbeddedErrorController, FixedController, FixedGrid, HEUN,
    HypersolverResidualController, Integrator, depth_like, embedded_step,
    error_ratio, get_tableau, per_sample_norm, step_factor,
)

# x64 enabled per-module via tests/conftest.py


def field(s, z):
    return -z + depth_like(jnp.sin(3.0 * jnp.asarray(s)), z) * jnp.ones_like(z)


# -------------------------------------------------- shared embedded machinery ----

def test_embedded_step_error_order():
    """The Heun-Euler pair's error estimate scales ~ h^2 (embedded order 1),
    the same machinery odeint_dopri5 runs with DOPRI5 weights."""
    z0 = jnp.asarray([[1.0, -0.4]])
    f = lambda s, z: jnp.sin(z) + z ** 2 * 0.1
    errs = []
    for h in (0.2, 0.1, 0.05):
        _, err, stages = embedded_step(f, HEUN, 0.0, h, z0)
        assert len(stages) == HEUN.stages
        errs.append(float(jnp.linalg.norm(err)))
    slopes = np.diff(np.log(errs)) / np.diff(np.log([0.2, 0.1, 0.05]))
    assert np.all(slopes > 1.6), (errs, slopes)


def test_embedded_step_requires_b_err():
    with pytest.raises(ValueError):
        embedded_step(field, get_tableau("rk4"), 0.0, 0.1, jnp.ones((1, 2)))


def test_step_factor_clamped():
    assert float(step_factor(jnp.asarray(1e9), 5)) == pytest.approx(0.2)
    assert float(step_factor(jnp.asarray(1e-12), 5)) == pytest.approx(5.0)
    # dopri5 instance: ratio^{-1/5} * safety, the original exponent
    assert float(step_factor(jnp.asarray(1.0), 5)) == pytest.approx(0.9)


def test_error_ratio_accept_boundary():
    z = jnp.zeros((2, 2))
    err_ok = jnp.full((2, 2), 0.5e-3)
    err_bad = jnp.full((2, 2), 2e-3)
    assert float(error_ratio(z, z, err_ok, 1e-3, 0.0)) < 1.0
    assert float(error_ratio(z, z, err_bad, 1e-3, 0.0)) > 1.0


def test_per_sample_norm_reduces_to_leading_axis():
    t = {"a": jnp.ones((3, 4, 5)) * 2.0, "b": jnp.zeros((3, 7))}
    out = per_sample_norm(t)
    assert out.shape == (3,)
    np.testing.assert_allclose(np.asarray(out),
                               np.sqrt((4.0 + 0.0) / 2) * np.ones(3))


# -------------------------------------------------------- controller selection ----

def test_fixed_controller_constant():
    probe = FixedController(K=5).select(Integrator(HEUN), field,
                                        jnp.ones((4, 3)), (0.0, 1.0))
    np.testing.assert_array_equal(np.asarray(probe.K), [5, 5, 5, 5])
    assert probe.nfe == 0 and probe.dz0 is None


def test_embedded_controller_tol_monotone():
    """Tighter tolerance never selects a coarser mesh."""
    z0 = jnp.asarray(np.random.RandomState(0).randn(4, 3))
    prev = None
    for tol in (1e-1, 1e-2, 1e-3):
        c = EmbeddedErrorController(tol=tol, k_min=1, k_max=64)
        probe = c.select(Integrator(HEUN), field, z0, (0.0, 1.0))
        if prev is not None:
            assert np.all(np.asarray(probe.K) >= np.asarray(prev))
        prev = probe.K
        assert probe.nfe == HEUN.stages and probe.dz0 is not None


def test_embedded_controller_harder_sample_gets_finer_mesh():
    """A sample with larger local error gets at least as large a K."""
    z0 = jnp.stack([0.01 * jnp.ones((3,)), 5.0 * jnp.ones((3,))])
    f = lambda s, z: -z ** 2  # curvature grows with |z|
    c = EmbeddedErrorController(tol=1e-3, k_min=1, k_max=128)
    probe = c.select(Integrator(HEUN), f, z0, (0.0, 1.0))
    assert int(probe.K[1]) > int(probe.K[0]), np.asarray(probe.K)
    assert float(probe.err[1]) > float(probe.err[0])


def test_residual_controller_uses_g_magnitude():
    g_small = lambda eps, s, z, dz: 1e-4 * jnp.ones_like(z)
    g_big = lambda eps, s, z, dz: 10.0 * jnp.ones_like(z)
    z0 = jnp.ones((2, 3))
    c = HypersolverResidualController(tol=1e-2, k_min=1, k_max=64)
    k_small = c.select(Integrator(get_tableau("euler"), g=g_small), field,
                       z0, (0.0, 1.0)).K
    k_big = c.select(Integrator(get_tableau("euler"), g=g_big), field,
                     z0, (0.0, 1.0)).K
    assert np.all(np.asarray(k_big) > np.asarray(k_small))


def test_residual_controller_requires_g():
    c = HypersolverResidualController()
    with pytest.raises(ValueError):
        c.select(Integrator(HEUN), field, jnp.ones((1, 2)), (0.0, 1.0))


# ------------------------------------------------- controller-driven solve ----

def test_controlled_solve_matches_per_sample_fixed_solves():
    """The masked multi-rate scan == separate scalar-eps solves at each
    sample's selected K (the correctness core of multi-rate serving)."""
    scales = jnp.asarray([0.05, 0.3, 1.0, 2.5, 6.0])[:, None]
    z0 = scales * jnp.ones((5, 3))  # per-row stiffness spread
    f = lambda s, z: -z ** 2
    integ = Integrator(HEUN)
    ctrl = EmbeddedErrorController(tol=1e-1, k_min=1, k_max=64)
    out, stats = integ.solve(f, z0, FixedGrid.over(0.0, 1.0, 8),
                             return_traj=False, controller=ctrl)
    Ks = np.asarray(stats.K)
    assert len(set(Ks.tolist())) > 1, "workload should be heterogeneous"
    for i in range(z0.shape[0]):
        zi = integ.solve(f, z0[i:i + 1],
                         FixedGrid.over(0.0, 1.0, int(Ks[i])),
                         return_traj=False)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(zi[0]),
                                   rtol=1e-9, atol=1e-12)


def test_controlled_solve_nfe_accounting():
    """Per-sample NFE = probe evals - reused stage + stages * K."""
    z0 = jnp.asarray(np.random.RandomState(2).randn(4, 2))
    integ = Integrator(HEUN)
    ctrl = EmbeddedErrorController(tol=1e-2, k_min=2, k_max=16)
    _, stats = integ.solve(field, z0, FixedGrid.over(0.0, 1.0, 4),
                           return_traj=False, controller=ctrl)
    expect = ctrl.probe_nfe - 1 + HEUN.stages * np.asarray(stats.K)
    np.testing.assert_array_equal(np.asarray(stats.nfe), expect)
    assert stats.probe_nfe == HEUN.stages


def test_controlled_solve_fixed_controller_matches_plain_solve():
    z0 = jnp.asarray(np.random.RandomState(3).randn(3, 4))
    integ = Integrator(get_tableau("rk4"))
    grid = FixedGrid.over(0.0, 1.0, 6)
    ref = integ.solve(field, z0, grid, return_traj=False)
    out, stats = integ.solve(field, z0, grid, return_traj=False,
                             controller=FixedController(K=6))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-12)
    np.testing.assert_array_equal(np.asarray(stats.nfe), [24, 24, 24])


def test_controlled_solve_rejects_batched_eps_grid():
    integ = Integrator(HEUN)
    grid = FixedGrid(0.0, jnp.asarray([0.1, 0.2]), 4)
    with pytest.raises(AssertionError):
        integ.solve(field, jnp.ones((2, 3)), grid,
                    controller=FixedController(K=4))


# ------------------------------------------------------- probe-stage reuse ----

def test_first_stage_reuse_exact():
    """solve(first_stage=f(s0, z0)) == solve() bitwise: stage 0 is simply
    not recomputed."""
    z0 = jnp.asarray(np.random.RandomState(4).randn(3, 2))
    integ = Integrator(get_tableau("midpoint"))
    grid = FixedGrid.over(0.0, 1.0, 5)
    dz0 = field(0.0, z0)
    a = integ.solve(field, z0, grid, return_traj=True)
    b = integ.solve(field, z0, grid, return_traj=True, first_stage=dz0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    aT = integ.solve(field, z0, grid, return_traj=False)
    bT = integ.solve(field, z0, grid, return_traj=False, first_stage=dz0)
    np.testing.assert_array_equal(np.asarray(aT), np.asarray(bT))


def test_first_stage_reuse_single_step():
    z0 = jnp.ones((2, 3))
    integ = Integrator(HEUN)
    grid = FixedGrid.over(0.0, 1.0, 1)
    a = integ.solve(field, z0, grid, return_traj=True)
    b = integ.solve(field, z0, grid, return_traj=True,
                    first_stage=field(0.0, z0))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------- lazy psi ----

def test_step_psi_lazy_on_fused_path():
    """The fused serving path skips the redundant b-weighted recombination:
    psi is None; the unfused path still returns it."""
    z0 = jnp.ones((2, 8), jnp.float32)
    f = lambda s, z: -z
    _, psi_unfused, _ = Integrator(HEUN).step(f, 0.0, 0.25, z0)
    assert psi_unfused is not None
    _, psi_fused, _ = Integrator(HEUN, fused=True).step(f, 0.0, 0.25, z0)
    assert psi_fused is None
