"""Data pipeline: determinism, learnability signal, loader prefetch,
density samplers, synthetic images."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import DENSITIES, ShardedLoader, density_sampler, \
    synthetic_images, token_batches


@pytest.mark.slow  # Markov token stream generation is minutes-scale on CPU
def test_token_stream_deterministic():
    a1, b1 = next(token_batches(1000, 4, 16, seed=7))
    a2, b2 = next(token_batches(1000, 4, 16, seed=7))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    a3, _ = next(token_batches(1000, 4, 16, seed=8))
    assert not np.array_equal(np.asarray(a1), np.asarray(a3))


@pytest.mark.slow  # Markov token stream generation is minutes-scale on CPU
def test_token_targets_are_shifted_inputs():
    t, y = next(token_batches(500, 2, 10, seed=0))
    np.testing.assert_array_equal(np.asarray(t[:, 1:]), np.asarray(y[:, :-1]))


@pytest.mark.slow  # Markov token stream generation is minutes-scale on CPU
def test_token_stream_is_learnable():
    """Order-2 Markov stream: bigram statistics are far from uniform."""
    t, y = next(token_batches(50000, 64, 256, seed=1))
    toks = np.asarray(t).reshape(-1)
    counts = np.bincount(toks, minlength=512)
    p = counts / counts.sum()
    ent = -(p[p > 0] * np.log(p[p > 0])).sum()
    assert ent < np.log(512) * 0.999


def test_densities_shapes_and_spread():
    for name in DENSITIES:
        x = next(density_sampler(name, 512, seed=3))
        assert x.shape == (512, 2)
        assert np.all(np.isfinite(np.asarray(x)))
        assert float(jnp.std(x)) > 0.3, name


def test_synthetic_images_classes_distinguishable():
    imgs, ys = synthetic_images("mnist28", 200, seed=0)
    assert imgs.shape == (200, 28, 28, 1)
    assert float(imgs.min()) >= 0.0 and float(imgs.max()) <= 1.0
    # class means must differ (simple separability proxy)
    m = np.stack([np.asarray(imgs[np.asarray(ys) == c]).mean(0)
                  for c in range(10)])
    dists = np.linalg.norm((m[:, None] - m[None]).reshape(100, -1), axis=-1)
    assert np.median(dists[dists > 0]) > 0.5
    imgs2, _ = synthetic_images("cifar32", 8, seed=0)
    assert imgs2.shape == (8, 32, 32, 3)


def test_sharded_loader_prefetch_and_order():
    src = iter([{"x": jnp.full((2,), i)} for i in range(5)])
    loader = ShardedLoader(src, sharding=None, prefetch=2)
    got = [int(b["x"][0]) for b in loader]
    assert got == [0, 1, 2, 3, 4]


def test_sharded_loader_propagates_errors():
    def gen():
        yield {"x": jnp.zeros(2)}
        raise ValueError("boom")
    loader = ShardedLoader(gen(), prefetch=1)
    next(loader)
    try:
        next(loader)
        raised = False
    except ValueError:
        raised = True
    assert raised
