"""Numerical-order and correctness tests for the solver substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DOPRI5, EULER, HEUN, MIDPOINT, RK4, RK38, RK3_KUTTA,
    FixedGrid, alpha_family, get_tableau, odeint_dopri5, odeint_fixed,
    local_error,
)

# x64 enabled per-module via tests/conftest.py


# z' = A z with known matrix exponential solution.
# (numpy constant: module import happens with x64 OFF — see conftest.py)
A = np.array([[-0.5, -2.0], [2.0, -0.5]], dtype=np.float64)


def linear_field(s, z):
    return z @ A.T


def exact_linear(z0, t):
    import scipy.linalg as sla  # noqa: F401 — not available; use eig decomposition
    raise NotImplementedError


def expm(M):
    """Matrix exponential via scaling-and-squaring on Pade(6) — small fixed impl."""
    n = M.shape[0]
    norm = np.linalg.norm(M, 1)
    j = max(0, int(np.ceil(np.log2(max(norm, 1e-30)))) + 1)
    Ms = np.asarray(M) / (2 ** j)
    X = Ms
    c = 0.5
    N = np.eye(n) + c * Ms
    D = np.eye(n) - c * Ms
    q = 6
    p = True
    for k in range(2, q + 1):
        c = c * (q - k + 1) / (k * (2 * q - k + 1))
        X = Ms @ X
        N = N + c * X
        D = D + (c if p else -c) * X
        p = not p
    F = np.linalg.solve(D, N)
    for _ in range(j):
        F = F @ F
    return F


@pytest.mark.parametrize(
    "tab,expected_order",
    [(EULER, 1), (MIDPOINT, 2), (HEUN, 2), (RK3_KUTTA, 3), (RK4, 4), (RK38, 4)],
)
def test_global_order_of_convergence(tab, expected_order):
    """Global error should scale ~ eps^p: fitted log-log slope close to p."""
    z0 = jnp.array([[1.0, 0.5]], dtype=jnp.float64)
    T = 1.0
    exact = jnp.asarray(z0 @ expm(np.asarray(A * T)).T)
    Ks = [8, 16, 32, 64]
    errs = []
    for K in Ks:
        grid = FixedGrid.over(0.0, T, K)
        zT = odeint_fixed(linear_field, z0, grid, tab, return_traj=False)
        errs.append(float(jnp.linalg.norm(zT - exact)))
    slopes = np.diff(np.log(errs)) / np.diff(np.log([1.0 / k for k in Ks]))
    assert np.mean(slopes) > expected_order - 0.35, (errs, slopes)


@pytest.mark.parametrize("alpha", [0.3, 0.5, 2.0 / 3.0, 1.0])
def test_alpha_family_is_second_order(alpha):
    tab = alpha_family(alpha)
    tab.validate()
    z0 = jnp.array([[1.0, 0.5]], dtype=jnp.float64)
    exact = jnp.asarray(z0 @ expm(np.asarray(A)).T)
    errs = []
    for K in [16, 32, 64]:
        zT = odeint_fixed(linear_field, z0, FixedGrid.over(0, 1, K), tab,
                          return_traj=False)
        errs.append(float(jnp.linalg.norm(zT - exact)))
    slopes = np.diff(np.log(errs)) / np.diff(np.log([1 / 16, 1 / 32, 1 / 64]))
    assert np.mean(slopes) > 1.7, (errs, slopes)


def test_alpha_family_recovers_midpoint_and_heun():
    assert np.allclose(alpha_family(0.5).b, MIDPOINT.b)
    assert np.allclose(alpha_family(1.0).b, HEUN.b)
    assert np.allclose(alpha_family(0.5).a[1], MIDPOINT.a[1])


def test_local_error_order():
    """Local truncation error e_k = O(eps^{p+1}) (paper Sec. 2)."""
    z0 = jnp.array([[1.0, 0.5]], dtype=jnp.float64)
    for tab, p in [(EULER, 1), (HEUN, 2), (RK4, 4)]:
        errs = []
        epss = [0.2, 0.1, 0.05]
        for eps in epss:
            z_next = jnp.asarray(z0 @ expm(np.asarray(A * eps)).T)
            errs.append(float(local_error(linear_field, tab, 0.0, eps, z0, z_next)))
        slopes = np.diff(np.log(errs)) / np.diff(np.log(epss))
        assert np.mean(slopes) > p + 1 - 0.3, (tab.name, errs, slopes)


def test_dopri5_matches_exact_solution():
    z0 = jnp.array([[1.0, 0.5], [-2.0, 0.25]], dtype=jnp.float64)
    grid = FixedGrid.over(0.0, 1.0, 4)
    traj, nfe = odeint_dopri5(linear_field, z0, grid, atol=1e-9, rtol=1e-9)
    for k, s in enumerate(np.asarray(grid.s_span)):
        exact = np.asarray(z0) @ expm(np.asarray(A) * s).T
        np.testing.assert_allclose(np.asarray(traj[k]), exact, rtol=1e-6, atol=1e-8)
    assert int(nfe) > 0


def test_dopri5_pytree_state():
    """Adaptive solver must handle tuple states (e.g. CNF (z, logp))."""
    z0 = (jnp.ones((3, 2)), jnp.zeros((3,)))

    def f(s, state):
        z, logp = state
        return (-z, -jnp.sum(z, axis=-1))

    grid = FixedGrid.over(0.0, 1.0, 2)
    traj, _ = odeint_dopri5(f, z0, grid, atol=1e-8, rtol=1e-8)
    np.testing.assert_allclose(
        np.asarray(traj[0][-1]), np.exp(-1.0) * np.ones((3, 2)), rtol=1e-5
    )


def test_fixed_solver_trajectory_shape():
    z0 = jnp.ones((4, 3))
    grid = FixedGrid.over(0.0, 1.0, 7)
    traj = odeint_fixed(lambda s, z: -z, z0, grid, RK4, return_traj=True)
    assert traj.shape == (8, 4, 3)
    np.testing.assert_allclose(np.asarray(traj[0]), np.asarray(z0))


def test_tableau_registry_lookup():
    assert get_tableau("euler") is EULER
    assert get_tableau("alpha_0.75").order == 2
    with pytest.raises(KeyError):
        get_tableau("nope")
