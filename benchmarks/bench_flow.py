"""K=0 flow-tier bench (PR 10: the Neural-Flows fast tier).

    PYTHONPATH=src python benchmarks/bench_flow.py [--budget small]

Trains BOTH learned heads in-bench off one residual ledger — the
hypersolver correction g (ledger_fitting_loss) and the K=0 flow head F
(flow_fitting_loss; for the structured F = z + eps*dz + eps^{p+1}*net
the two losses are the SAME fitting problem) — then serves a held-out
heterogeneous mix and writes BENCH_flow.json with three sections:

  * **pareto** — the headline: the three-tier router (flow / hyper /
    high-K, ``EngineConfig.flow_threshold`` at the TierRouter default)
    must reach EQUAL-OR-BETTER argmax agreement against a fine frozen
    reference at STRICTLY LOWER mean NFE than hypersolver-only
    multi-rate on the same mix — probe-easy requests collapse to one
    net eval instead of the smallest bucket's solve.
  * **flow_disabled_parity** — ACCEPTANCE: with the flow tier disabled
    (flow_threshold=0) a flow-capable model's completions are
    uid-for-uid bitwise identical to a model with no flow head at all —
    engine, in-flight sync, and in-flight overlap (the tier is pure
    packing policy; attaching it must not perturb the ladder).
  * **escalation** — ACCEPTANCE: under seeded flow-eval NaN poisoning
    (``FaultInjector.flow_nan_frac``) every poisoned request escalates
    into the K-bucket ladder and completes with real outputs
    (status='escalated', the flow attempt's nfe billed), the status
    histogram sums to the submitted count, no request hangs, and the
    sync and overlap loops agree bitwise under the identical fault
    schedule.

The verdict row is the tracked scoreboard: ``three_tier_dominates``,
``flow_disabled_parity``, ``escalation_accounted``, ``zero_hang``.
``benchmarks/run.py --check`` enforces all four.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import json
import sys

REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
if __name__ == "__main__":  # runnable as a script from anywhere
    sys.path.insert(0, REPO_ROOT)

import numpy as np

from benchmarks.bench_faults import records_bitwise_equal
from repro.distributed.fault import FaultInjector
from repro.launch.engine import EngineConfig, MultiRateEngine, STATUSES
from repro.launch.refinery import Refinery, RefineryConfig, ResidualLedger
from repro.launch.scheduler import InflightScheduler
from repro.launch.workload import (
    heterogeneous_requests, poisson_trace, replay_engine, replay_scheduler,
    status_counts, toy_flow_classifier, toy_refinable_classifier,
)

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_flow.json")

D_FEAT = 32
BUCKETS = (2, 4, 8, 16)
# Probe errors here are L2 norms over the d=32 embedding state, and the
# toy mix is bimodal: trained-g estimates settle near ~0.07 for the easy
# class and ~7 for the stiff class. tol=0.35 puts the router's flow
# gate (0.25 * tol ~ 0.0875) comfortably above the easy mode and far
# below the hard one, so the three tiers all see traffic — the same
# calibration serve.py owners do against their own error scale
# (docs/serving.md).
TOL = 0.35
REF_K = 64
HIDDEN = 32


def _budget(budget: str):
    return {
        "tiny": dict(n_train=128, n_eval=96, g_steps=1500, f_iters=1500),
        "small": dict(n_train=256, n_eval=192, g_steps=4000, f_iters=4000),
        "full": dict(n_train=512, n_eval=384, g_steps=6000, f_iters=6000),
    }.get(budget, None) or _budget("small")


def _ecfg(flow_threshold: float = 0.0):
    return EngineConfig(buckets=BUCKETS, tol=TOL, max_batch=16,
                        solver="hyper_euler", fused=True,
                        flow_threshold=flow_threshold)


def _reference(model, xs: np.ndarray) -> np.ndarray:
    """Fine frozen reference: the BASE tableau at REF_K steps — the same
    ground-truth proxy the refinery's shadow scorer uses."""
    import jax
    import jax.numpy as jnp

    from repro.core import Integrator

    ref = Integrator(tableau=model.integ.tableau)

    @jax.jit
    def run(x):
        z0 = model.embed(x)
        Ks = jnp.full((x.shape[0],), REF_K, jnp.int32)
        zT = ref.solve_multirate(model.field_of(x), z0, model.span, Ks,
                                 REF_K)
        return model.readout(x, zT)

    return np.asarray(run(jnp.asarray(xs)))


def _trained_model(budget: str):
    """One ledger, two heads: capture residual rows from a training mix
    at the serving step sizes (bucket eps AND the flow tier's full
    span), fit g on them with the refinery trainer, fit F on the SAME
    ledger with core.train.train_flowhead, and return the model carrying
    both trained heads."""
    import jax.numpy as jnp

    from repro.core.train import FlowTrainConfig, train_flowhead

    b = _budget(budget)
    model = toy_flow_classifier(d=D_FEAT, hidden=HIDDEN)
    ledger = ResidualLedger(model, capacity=4096, capture_rate=1.0,
                            seed=0, holdout_every=0)

    xs = heterogeneous_requests(b["n_train"], D_FEAT, seed=1)
    h = model.span[1] - model.span[0]
    z0 = model.embed(jnp.asarray(xs))
    dz0 = model.field_of(jnp.asarray(xs))(model.span[0], z0)
    z_mid = z0 + (h / 2) * dz0          # a half-span Euler interior state
    n = len(xs)
    for K in (1,) + BUCKETS:            # K=1 is the flow tier's eps = h
        eps = np.full(n, h / K, np.float32)
        ledger.capture(xs, z0, np.zeros(n, np.float32), eps)
        ledger.capture(xs, z_mid, np.full(n, h / 2, np.float32), eps)

    # g: the refinery's trainer over ledger_fitting_loss
    refin = Refinery(model, ledger,
                     RefineryConfig(steps_per_tick=b["g_steps"],
                                    batch_size=128, min_fill=64, lr=5e-3,
                                    total_steps=b["g_steps"],
                                    ckpt_every=10 ** 9, seed=0),
                     param_site="g")
    refin.train_tick()

    # F: same ledger rows through core/train.py::train_flowhead
    fp, losses = train_flowhead(
        model.flow_apply, model.flow_params, ledger,
        FlowTrainConfig(iters=b["f_iters"], batch_size=128, lr=5e-3,
                        order=model.integ.order, seed=0))
    return dataclasses.replace(model, g_params=refin.candidate,
                               flow_params=fp), float(losses[-1])


# --------------------------------------------------------------- pareto ----

def pareto_rows(budget: str = "small"):
    """Three-tier router vs hypersolver-only multi-rate on a held-out
    heterogeneous mix: equal-or-better agreement at strictly lower mean
    NFE, or the tier is not paying for its routing."""
    b = _budget(budget)
    model, final_loss = _trained_model(budget)
    xs = heterogeneous_requests(b["n_eval"], D_FEAT, seed=7)
    ref_top = np.argmax(_reference(model, xs), -1)

    rows, stats = [], {}
    for variant, ft in (("hyper_multirate", 0.0), ("three_tier", None)):
        if ft is None:
            from repro.core.controllers import TierRouter
            ft = TierRouter().flow_threshold   # the live router default
        eng = MultiRateEngine(model, _ecfg(ft))
        recs = sorted(eng.run(xs), key=lambda c: c.uid)
        outs = np.stack([c.outputs for c in recs])
        agree = float((np.argmax(outs, -1) == ref_top).mean())
        mean_nfe = float(np.mean([c.nfe for c in recs]))
        flow_served = sum(1 for c in recs if c.K == 0)
        stats[variant] = (agree, mean_nfe)
        rows.append({"bench": "flow", "section": "pareto",
                     "variant": variant, "flow_threshold": ft,
                     "agreement": agree, "mean_nfe": mean_nfe,
                     "flow_served": flow_served,
                     "requests": len(xs), "buckets": list(BUCKETS),
                     "tol": TOL, "ref_K": REF_K,
                     "flow_final_loss": final_loss})
    (ag_h, nfe_h), (ag_f, nfe_f) = stats["hyper_multirate"], \
        stats["three_tier"]
    dominates = bool(ag_f >= ag_h and nfe_f < nfe_h)
    served_flow = any(r["variant"] == "three_tier" and r["flow_served"] > 0
                      for r in rows)
    return rows, dominates and served_flow, model


# ------------------------------------------------- flow-disabled parity ----

def parity_rows(budget: str = "small"):
    """flow_threshold=0 on a flow-capable model must be bitwise the
    flowless model's serve — all three loops, uid for uid."""
    n = {"tiny": 24, "small": 48, "full": 96}.get(budget, 48)
    xs = heterogeneous_requests(n, D_FEAT, seed=17)
    trace = poisson_trace(xs, rate=0.5, seed=211)
    ecfg = _ecfg(0.0)

    def loops(make_model):
        eng = replay_engine(MultiRateEngine(make_model(), ecfg), trace)
        sy = replay_scheduler(InflightScheduler(make_model(), ecfg,
                                                slots=8, seg=2), trace)
        ov = replay_scheduler(InflightScheduler(make_model(), ecfg,
                                                slots=8, seg=2,
                                                overlap=True), trace)
        return {"engine": eng, "inflight": sy, "inflight_overlap": ov}

    with_flow = loops(lambda: toy_flow_classifier(d=D_FEAT))
    without = loops(lambda: toy_refinable_classifier(d=D_FEAT))
    rows, ok = [], True
    for loop in ("engine", "inflight", "inflight_overlap"):
        parity = records_bitwise_equal(with_flow[loop], without[loop])
        ok &= parity
        rows.append({"bench": "flow", "section": "flow_disabled_parity",
                     "mode": loop, "submitted": n,
                     "parity": bool(parity)})
    return rows, bool(ok)


# ------------------------------------------------------------ escalation ----

def escalation_rows(budget: str, model):
    """Seeded flow-eval NaN chaos: poisoned flow rows must escalate into
    the ladder and complete with real outputs; accounting must close;
    sync and overlap must agree bitwise under the identical schedule."""
    n = {"tiny": 32, "small": 64, "full": 128}.get(budget, 64)
    xs = heterogeneous_requests(n, D_FEAT, seed=23)
    trace = poisson_trace(xs, rate=0.5, seed=311)
    ecfg = _ecfg(0.25)

    def injector():
        return FaultInjector(seed=5, flow_nan_frac=0.7)

    reports = {}
    reports["engine"] = replay_engine(
        MultiRateEngine(model, ecfg, fault_injector=injector()), trace)
    scheds = {}
    for loop, ov in (("inflight", False), ("inflight_overlap", True)):
        s = InflightScheduler(model, ecfg, slots=8, seg=2, overlap=ov,
                              fault_injector=injector())
        reports[loop] = replay_scheduler(s, trace)
        scheds[loop] = s

    rows, esc_total, accounted, zero_hang = [], 0, True, True
    for loop, rep in reports.items():
        sc = status_counts(rep)
        esc = sc["escalated"]
        esc_total += esc
        closes = sum(sc.values()) == n and len(rep.records) == n
        real = all(r.outputs is not None and np.isfinite(r.outputs).all()
                   for r in rep.records if r.status == "escalated")
        accounted &= closes and real
        zero_hang &= closes
        rows.append({"bench": "flow", "section": "escalation",
                     "mode": loop, "mix": "flow_nan", "submitted": n,
                     "status": sc, "escalated": esc,
                     "zero_hang": bool(closes),
                     "escalated_outputs_real": bool(real)})
    overlap_parity = records_bitwise_equal(reports["inflight"],
                                           reports["inflight_overlap"])
    rows.append({"bench": "flow", "section": "escalation",
                 "mode": "sync_vs_overlap", "mix": "flow_nan",
                 "parity": bool(overlap_parity),
                 "flow_served": scheds["inflight"].total_flow_served,
                 "escalated": scheds["inflight"].total_escalated})
    ok = bool(esc_total > 0 and accounted and overlap_parity)
    return rows, ok, bool(zero_hang)


def main(budget: str = "small", out_path: str = OUT_PATH):
    par_rows, dominates, model = pareto_rows(budget)
    dis_rows, parity_ok = parity_rows(budget)
    esc_rows, esc_ok, zero_hang = escalation_rows(budget, model)
    rows = par_rows + dis_rows + esc_rows
    rows.append({
        "bench": "flow", "mode": "verdict",
        "three_tier_dominates": bool(dominates),
        "flow_disabled_parity": bool(parity_ok),
        "escalation_accounted": bool(esc_ok),
        "zero_hang": bool(zero_hang),
        "statuses": list(STATUSES),
    })
    with open(out_path, "w") as fh:
        json.dump(rows, fh, indent=1, default=str)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", default="small",
                    choices=["tiny", "small", "full"])
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    for r in main(args.budget, args.out):
        print(r)
