"""Paper Fig. 5-6: base-solver generalization. A single HyperMidpoint
(trained with the alpha=0.5 base) is evaluated, WITHOUT finetuning, under
other members of the 2nd-order alpha-family; it should stay pareto-ahead
of each plain alpha solver."""
from __future__ import annotations

from benchmarks.common import (
    eval_solver, fit_image_hypersolver, train_image_node,
)
from repro.core import alpha_family
from repro.data import synthetic_images


def main(budget: str = "small"):
    node, params = train_image_node()
    gp = fit_image_hypersolver(node, params, "midpoint", K=10,
                               iters=150 if budget == "small" else 1500)
    xt, _ = synthetic_images("mnist28", 64, seed=13)
    rows = []
    for alpha in (0.3, 0.4, 0.5, 2.0 / 3.0, 0.8, 1.0):
        tab = alpha_family(alpha)
        plain = eval_solver(node, params, "midpoint", 10, xt, alpha_tab=tab)
        hyper = eval_solver(node, params, "hyper_midpoint", 10, xt, gp=gp,
                            alpha_tab=tab)
        rows.append({
            "bench": "alpha_family", "alpha": round(alpha, 3),
            "mape_plain": round(plain["mape"], 4),
            "mape_hyper": round(hyper["mape"], 4),
            "hyper_wins": hyper["mape"] < plain["mape"],
        })
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
