"""Beyond-paper: the hypersolver technique applied to LM serving
(continuous-depth mode, DESIGN.md §4). A reduced qwen3-family model is
trained briefly on the synthetic token stream; a HyperEuler g_omega is fit
by residual fitting against the full-depth trajectory; scoring quality
(argmax agreement + logit MAE vs full depth) is swept over NFE."""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CACHE
from repro.checkpoint import CheckpointManager
from repro.configs import get
from repro.data import token_batches
from repro.models.cdepth import (
    cdepth_residual_loss, lm_forward_cdepth, lm_g_init,
)
from repro.models.lm import group_layout, init_lm, lm_forward, lm_loss
from repro.optim import adamw, apply_updates, clip_by_global_norm


def _cfg():
    return dataclasses.replace(get("qwen3_4b").reduced(), n_layers=8)


def train_small_lm(steps=150):
    cfg = _cfg()
    cm = CheckpointManager(os.path.join(CACHE, "cdepth_lm"), keep=1)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    latest = cm.latest_step()
    if latest is not None and latest >= steps:
        return cfg, cm.restore(latest, jax.eval_shape(lambda: params))
    opt = adamw(1e-3)
    st = opt.init(params)

    @jax.jit
    def step(p, st, i, toks, tgts):
        (l, m), g = jax.value_and_grad(
            lambda pp: lm_loss(pp, cfg, toks, tgts), has_aux=True)(p)
        g, _ = clip_by_global_norm(g, 1.0)
        u, st = opt.update(g, st, p, i)
        return apply_updates(p, u), st, l

    it = token_batches(cfg.vocab, 8, 64, seed=3)
    for i in range(steps):
        toks, tgts = next(it)
        params, st, l = step(params, st, i, toks, tgts)
    cm.save(steps, params)
    return cfg, params


def main(budget: str = "small"):
    cfg, params = train_small_lm(150 if budget == "small" else 600)
    _, n_groups, _ = group_layout(cfg)
    it = token_batches(cfg.vocab, 4, 32, seed=11)
    toks, _ = next(it)
    full, _ = lm_forward(params, cfg, toks)

    rows = []
    for K in [k for k in (1, 2, 4, 8) if n_groups % k == 0]:
        # fit a hypersolver for this K
        gp = lm_g_init(jax.random.PRNGKey(2), cfg, rank=32,
                       param_dtype=jnp.float32)
        opt = adamw(3e-3)
        st = opt.init(gp)

        @jax.jit
        def fit(gp, st, i, batch):
            l, g = jax.value_and_grad(
                lambda gg: cdepth_residual_loss(params, gg, cfg, batch, K)
            )(gp)
            g, _ = clip_by_global_norm(g, 1.0)
            u, st = opt.update(g, st, gp, i)
            return apply_updates(gp, u), st, l

        fit_it = token_batches(cfg.vocab, 4, 32, seed=13)
        batch, _ = next(fit_it)
        iters = 80 if budget == "small" else 300
        for i in range(iters):
            if i % 10 == 0:
                batch, _ = next(fit_it)
            gp, st, _ = fit(gp, st, i, batch)

        for solver, g_used in (("euler", None), ("euler", gp)):
            out = lm_forward_cdepth(params, cfg, toks, K=K, solver=solver,
                                    g_params=g_used)
            agree = float(jnp.mean(jnp.argmax(full, -1)
                                   == jnp.argmax(out, -1)))
            mae = float(jnp.mean(jnp.abs(full - out)))
            # KL(full || approx): smooth serving-quality metric
            lp_full = jax.nn.log_softmax(full, -1)
            lp_out = jax.nn.log_softmax(out, -1)
            kl = float(jnp.mean(jnp.sum(
                jnp.exp(lp_full) * (lp_full - lp_out), -1)))
            rows.append({
                "bench": "cdepth_lm",
                "solver": "hyper_euler" if g_used is not None else "euler",
                "K": K, "full_depth_groups": n_groups,
                "nfe_fraction": round(K / n_groups, 3),
                "argmax_agreement": round(agree, 4),
                "logit_mae": round(mae, 4),
                "kl_vs_full_depth": round(kl, 4),
            })
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
