"""Paper Fig. 4: wall-clock solve time of fixed-step methods vs dopri5 at
iso-accuracy (each method runs the minimum K keeping accuracy loss vs
dopri5 under 0.1% -> paper's protocol). CPU timings (documented); the
paper's metric of record, NFE/MACs, is hardware-neutral and also reported.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (
    accuracy_drop, eval_solver, fit_image_hypersolver, timed,
    train_image_node,
)
from repro.core import FixedGrid, get_tableau
from repro.data import synthetic_images
from repro.models.conv_node import mnist_integrator


def _min_K_for_accuracy(node, params, name, xt, gp, threshold=0.1,
                        K_grid=(1, 2, 4, 8, 16, 32)):
    for K in K_grid:
        out = eval_solver(node, params, name, K, xt,
                          gp=gp if name.startswith("hyper") else None)
        if accuracy_drop(node, params, out["zT"], out["z_ref"]) <= threshold:
            return K, out["nfe"]
    return K_grid[-1], out["nfe"]


def main(budget: str = "small"):
    node, params = train_image_node()
    gp = fit_image_hypersolver(node, params, "euler", K=10)
    xt, _ = synthetic_images("mnist28", 32, seed=11)

    f = node.field(params, xt)
    z0 = node.hx_apply(params, xt)

    rows = []
    # dopri5 reference timing
    ref_fn = jax.jit(lambda z: node.reference_trajectory(
        params, xt, K=1, atol=1e-4, rtol=1e-4)[0][-1])
    t_ref, _ = timed(ref_fn, z0)
    rows.append({"bench": "wallclock_mnist", "solver": "dopri5", "K": "-",
                 "nfe": "adaptive", "ms": round(t_ref * 1e3, 2),
                 "speedup_vs_dopri5": 1.0})

    for name in ("euler", "hyper_euler", "midpoint", "rk4"):
        K, nfe = _min_K_for_accuracy(node, params, name, xt, gp)
        grid = FixedGrid.over(0.0, 1.0, K)
        if name.startswith("hyper"):
            integ = mnist_integrator(gp, xt, base="euler")
        else:
            integ = mnist_integrator(base=get_tableau(name))
        fn = jax.jit(lambda z, it=integ, gr=grid: it.solve(
            f, z, gr, return_traj=False))
        t, _ = timed(fn, z0)
        rows.append({"bench": "wallclock_mnist", "solver": name, "K": K,
                     "nfe": nfe, "ms": round(t * 1e3, 2),
                     "speedup_vs_dopri5": round(t_ref / t, 2)})
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
