"""Wall-clock benchmarks: paper Fig. 4 (solver race) + the serving loop.

Two sections, one REAL clock (``time.perf_counter``):

1. ``fig4_rows`` — the paper's Fig. 4: wall-clock solve time of
   fixed-step methods vs dopri5 at iso-accuracy (each method runs the
   minimum K keeping accuracy loss vs dopri5 under 0.1%). CPU timings
   (documented); the paper's metric of record, NFE/MACs, is
   hardware-neutral and also reported.
2. ``serving_rows`` — the in-flight serving runtime head-to-head: the
   pipelined ``--overlap`` loop vs the synchronous loop replaying the
   SAME seeded Poisson traces, measured end-to-end under
   ``time.perf_counter``. Emits ``BENCH_wallclock.json`` (repo root)
   with four row kinds:

     * ``section="serving"`` — one row per (trace, loop): wall seconds
       (min + median over interleaved warm repeats), requests/s, ticks,
       mean per-tick wall-us, and the loops' output ``agreement``
       (uid-for-uid identical completions, checked on a cold replay).
     * ``section="mechanism"`` — the async-dispatch measurement the
       overlap design rests on: time for the segment cell's ``jit``
       call to RETURN (dispatch) vs time to actually finish (execute).
       Dispatch must be a small fraction of execute, or there is
       nothing for the host to overlap into. Measured in both donate
       modes — on the CPU client a donating call dispatches
       synchronously, the reason ``InflightScheduler``'s ``donate``
       auto-default is platform-aware.
     * ``section="predicted_vs_measured"`` — joins the measured mean
       per-tick wall-us against the ``RooflineOracle`` device-us price
       of the same (seg, slots) segment. The units differ on purpose
       (``wall_us`` measured on a host-CPU toy pool vs ``device_us``
       predicted for a qwen3_8b decode pool on accelerator HBM
       bandwidth) — the join validates the per-tick accounting
       plumbing and the scaling shape, not absolute calibration; rows
       carry both unit tags so downstream analysis can never sum them.
     * ``mode="verdict"`` — scoreboard: per-trace overlap speedups,
       ``overlap_wins_wallclock``, ``agreement_all``,
       ``async_dispatch_ok``, and ``host_cpus``. On a single-core host
       (CI containers) the two loops are work-conserving — wall time
       is total CPU work, which identical schedules make identical —
       so speedups sit at ~1.0 +/- scheduler noise and the verdict
       records that honestly; the overlap win needs ``host_cpus >= 2``
       so XLA's worker threads run under the host-side admit/retire.

Timing protocol (``serving_rows``): the cold replay per loop pays
compilation and pins agreement; timed repeats then re-replay the SAME
scheduler instance with a time-shifted copy of the trace (virtual
clocks are translation-invariant; a fresh scheduler would recompile its
jit cells). Repeats interleave the two loops in alternating order with
GC disabled, and the reported req/s uses the MIN wall time — on a
shared/noisy host the minimum is the closest observable to the
structural cost (same reasoning as ``timeit``).
"""
from __future__ import annotations

import gc
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    accuracy_drop, eval_solver, fit_image_hypersolver, timed,
    train_image_node,
)
from repro.core import FixedGrid, get_tableau
from repro.data import synthetic_images
from repro.models.conv_node import mnist_integrator

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_wallclock.json")

#: Serving-section budgets: (seeds, n_requests, d, slots, seg, rate,
#: timed repeats). "smoke" is the tier-1 variant — 2 tiny traces, small
#: pool, enough to pin agreement and schema but not to win a race.
SERVING_BUDGETS = {
    "smoke": ((3, 11), 12, 64, 4, 2, 0.4, 3),
    "small": ((3, 11, 21), 48, 256, 8, 4, 0.4, 9),
    "full": ((3, 11, 21), 96, 256, 8, 4, 0.4, 15),
}


def _min_K_for_accuracy(node, params, name, xt, gp, threshold=0.1,
                        K_grid=(1, 2, 4, 8, 16, 32)):
    for K in K_grid:
        out = eval_solver(node, params, name, K, xt,
                          gp=gp if name.startswith("hyper") else None)
        if accuracy_drop(node, params, out["zT"], out["z_ref"]) <= threshold:
            return K, out["nfe"]
    return K_grid[-1], out["nfe"]


def fig4_rows(budget: str = "small"):
    node, params = train_image_node()
    gp = fit_image_hypersolver(node, params, "euler", K=10)
    xt, _ = synthetic_images("mnist28", 32, seed=11)

    f = node.field(params, xt)
    z0 = node.hx_apply(params, xt)

    rows = []
    # dopri5 reference timing
    ref_fn = jax.jit(lambda z: node.reference_trajectory(
        params, xt, K=1, atol=1e-4, rtol=1e-4)[0][-1])
    t_ref, _ = timed(ref_fn, z0)
    rows.append({"bench": "wallclock_mnist", "solver": "dopri5", "K": "-",
                 "nfe": "adaptive", "ms": round(t_ref * 1e3, 2),
                 "speedup_vs_dopri5": 1.0})

    for name in ("euler", "hyper_euler", "midpoint", "rk4"):
        K, nfe = _min_K_for_accuracy(node, params, name, xt, gp)
        grid = FixedGrid.over(0.0, 1.0, K)
        if name.startswith("hyper"):
            integ = mnist_integrator(gp, xt, base="euler")
        else:
            integ = mnist_integrator(base=get_tableau(name))
        fn = jax.jit(lambda z, it=integ, gr=grid: it.solve(
            f, z, gr, return_traj=False))
        t, _ = timed(fn, z0)
        rows.append({"bench": "wallclock_mnist", "solver": name, "K": K,
                     "nfe": nfe, "ms": round(t * 1e3, 2),
                     "speedup_vs_dopri5": round(t_ref / t, 2)})
    return rows


# ------------------------------------------------------------- serving ----

def _shifted(trace, dt):
    from repro.launch.workload import Arrival
    return [Arrival(t=a.t + dt, x=a.x) for a in trace]


def _agreement(rep_a, rep_b) -> float:
    """Fraction of uid-matched completions identical across two replays:
    same K, nfe, and timestamps, bitwise-equal outputs. 1.0 = the
    pipelined loop is observationally the synchronous loop."""
    recs_a = {r.uid: r for r in rep_a.records}
    recs_b = {r.uid: r for r in rep_b.records}
    if set(recs_a) != set(recs_b):
        return 0.0
    same = 0
    for uid, ra in recs_a.items():
        rb = recs_b[uid]
        if (ra.K == rb.K and ra.nfe == rb.nfe
                and ra.t_submit == rb.t_submit
                and ra.t_admit == rb.t_admit
                and ra.t_done == rb.t_done
                and np.array_equal(np.asarray(ra.outputs),
                                   np.asarray(rb.outputs))):
            same += 1
    return same / max(len(recs_a), 1)


def _mechanism_row(d: int = 4096, slots: int = 8, seg: int = 8):
    """Measure async dispatch directly on the segment cell: wall time
    for the jit call to RETURN (dispatch) vs wall time for the retire
    meta to materialize (execute). The gap is the window the overlap
    loop fills with host-side admit/probe/retire work. Measured in both
    donate modes: on the CPU client (jaxlib 0.4.x) a DONATING call runs
    synchronously — dispatch collapses onto execute — which is why the
    scheduler's ``donate`` auto-default keeps donation off on CPU
    (``InflightScheduler.__init__``)."""
    from repro.launch.workload import heterogeneous_requests, toy_classifier

    m = toy_classifier("euler", d=d)
    xs = jnp.asarray(np.asarray(heterogeneous_requests(slots, d, seed=0)))
    k = jnp.zeros((slots,), jnp.int32)
    Ks = jnp.full((slots,), 64, jnp.int32)
    eps = jnp.full((slots,), 0.01, jnp.float32)
    times = {}
    for donate in (False, True):
        cell = m.integ.segment_cell(m.field_of, seg, donate=donate)
        z = jnp.zeros((slots, d), jnp.float32)
        fs = jnp.zeros((slots, d), jnp.float32)
        z, fs, meta = cell(xs, z, k, Ks, eps, fs)   # compile
        np.array(meta)
        dispatch, execute = [], []
        for _ in range(11):
            t0 = time.perf_counter()
            z, fs, meta = cell(xs, z, k, Ks, eps, fs)
            t1 = time.perf_counter()
            np.array(meta)              # block until the segment finishes
            t2 = time.perf_counter()
            dispatch.append(t1 - t0)
            execute.append(t2 - t1)
        times[donate] = (float(np.median(dispatch) * 1e6),
                         float(np.median(execute) * 1e6))
    dispatch_us, block_us = times[False]
    execute_us = dispatch_us + block_us             # full segment wall
    donated_dispatch_us = times[True][0]
    return {"bench": "wallclock_serving", "section": "mechanism",
            "backend": jax.default_backend(),
            "d": d, "slots": slots, "seg": seg,
            "dispatch_us": round(dispatch_us, 1),
            "execute_us": round(execute_us, 1),
            "overlap_window_us": round(execute_us - dispatch_us, 1),
            "async_dispatch_ok": bool(dispatch_us < execute_us / 5),
            "donated_dispatch_us": round(donated_dispatch_us, 1),
            "donation_serializes_dispatch": bool(
                donated_dispatch_us > execute_us / 2),
            "time_unit": "wall_us"}


def serving_rows(budget: str = "small"):
    """The overlap-vs-sync wall-clock head-to-head (see module docstring
    for the protocol). Returns serving + mechanism + predicted-vs-
    measured + verdict rows; pure function of the budget and the host."""
    from repro.configs import get
    from repro.launch.engine import EngineConfig
    from repro.launch.oracle import WALLCLOCK_UNIT, RooflineOracle
    from repro.launch.scheduler import InflightScheduler
    from repro.launch.workload import (heterogeneous_requests,
                                       latency_stats, poisson_trace,
                                       replay_scheduler, toy_classifier)

    seeds, n, d, slots, seg, rate, reps = SERVING_BUDGETS[budget]
    host_cpus = os.cpu_count() or 1
    rows = []
    speedups = {}
    agreements = {}
    oracle = RooflineOracle(get("qwen3_8b"), ctx=4096)
    predicted_us = oracle.segment_cost((d,), seg, slots, stages=1)

    for seed in seeds:
        trace_name = f"poisson_seed{seed}"
        ecfg = EngineConfig(buckets=(2, 4, 8, 16), tol=5e-3, max_batch=8,
                            solver="euler", fused=True)
        xs = heterogeneous_requests(n, d, seed=seed)
        trace = poisson_trace(xs, rate=rate, seed=seed + 100)

        # cold replays: pay compilation, pin uid-for-uid agreement
        scheds, cold = {}, {}
        for loop, overlap in (("sync", False), ("overlap", True)):
            s = InflightScheduler(toy_classifier("euler", d=d), ecfg,
                                  slots=slots, seg=seg, overlap=overlap)
            cold[loop] = replay_scheduler(s, trace)
            scheds[loop] = s
        agreement = _agreement(cold["sync"], cold["overlap"])
        agreements[trace_name] = agreement

        # warm timed repeats: interleaved, alternating order, GC off
        times = {"sync": [], "overlap": []}
        ticks = {"sync": 0, "overlap": 0}
        gc.disable()
        try:
            for rep in range(reps):
                order = (("sync", "overlap") if rep % 2 == 0
                         else ("overlap", "sync"))
                for loop in order:
                    s = scheds[loop]
                    tr = _shifted(trace, s.now + 1.0)
                    t_before = s.ticks
                    t0 = time.perf_counter()
                    replay_scheduler(s, tr)
                    times[loop].append(time.perf_counter() - t0)
                    ticks[loop] = s.ticks - t_before
        finally:
            gc.enable()

        stats = latency_stats(cold["sync"])
        for loop in ("sync", "overlap"):
            wall_min = min(times[loop])
            wall_med = float(np.median(times[loop]))
            rows.append({
                "bench": "wallclock_serving", "section": "serving",
                "loop": loop, "trace": trace_name, "requests": n,
                "d": d, "slots": slots, "seg": seg, "rate": rate,
                "reps": reps,
                "wall_s_min": round(wall_min, 4),
                "wall_s_median": round(wall_med, 4),
                "req_per_s": round(n / wall_min, 2),
                "ticks": ticks[loop],
                "mean_tick_wall_us": round(
                    wall_min * 1e6 / max(ticks[loop], 1), 1),
                "time_unit": WALLCLOCK_UNIT,
                "agreement": agreement,
                "mean_nfe": stats["mean_nfe"],
                "host_cpus": host_cpus,
            })
        sync_row, overlap_row = rows[-2], rows[-1]
        speedups[trace_name] = round(
            overlap_row["req_per_s"] / sync_row["req_per_s"], 3)
        rows.append({
            "bench": "wallclock_serving",
            "section": "predicted_vs_measured", "trace": trace_name,
            "seg": seg, "slots": slots,
            "predicted_device_us_per_segment": round(predicted_us, 1),
            "predicted_unit": oracle.unit,
            "measured_wall_us_per_tick":
                overlap_row["mean_tick_wall_us"],
            "measured_unit": WALLCLOCK_UNIT,
            "measured_over_predicted": round(
                overlap_row["mean_tick_wall_us"] / predicted_us, 3),
            "note": ("predicted prices a qwen3_8b decode pool on "
                     "accelerator HBM; measured is a toy host-CPU pool "
                     "— join validates per-tick accounting, not "
                     "absolute calibration"),
        })

    mech = _mechanism_row()
    rows.append(mech)
    rows.append({
        "bench": "wallclock_serving", "mode": "verdict",
        "overlap_wins_wallclock": bool(
            all(s >= 1.0 for s in speedups.values())),
        "overlap_speedups": speedups,
        "agreement_all": float(min(agreements.values())),
        "async_dispatch_ok": mech["async_dispatch_ok"],
        "host_cpus": host_cpus,
        "note": ("identical schedules make the two loops work-"
                 "conserving: on a 1-core host wall time is total CPU "
                 "work and speedups sit at ~1.0 +/- noise; the overlap "
                 "win requires host_cpus >= 2 so the XLA worker runs "
                 "under host-side admit/probe/retire (the mechanism "
                 "row measures that window directly)"),
    })
    return rows


def main(budget: str = "small"):
    import json
    if budget == "smoke":
        return serving_rows("smoke")    # tier-1: no training, no JSON
    rows = fig4_rows(budget) + serving_rows(budget)
    with open(OUT_PATH, "w") as fh:
        json.dump([r for r in rows if r["bench"] == "wallclock_serving"],
                  fh, indent=1)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", default="small",
                    choices=sorted(SERVING_BUDGETS))
    for r in main(budget=ap.parse_args().budget):
        print(r)
