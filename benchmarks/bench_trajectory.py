"""Paper App. C.1 / Fig. 8: trajectory-fitting hypersolver on a periodic
tracking task. A Neural ODE is trained with an integral loss to track
beta(s) = [sin 2 pi s, cos 2 pi s]; a 3-layer (64,64,64) HyperEuler is then
fit with TRAJECTORY fitting and compared on global truncation error E(k)
against Euler / midpoint / RK4 across NFE."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CACHE
from repro.checkpoint import CheckpointManager
from repro.core import FixedGrid
from repro.core.neural_ode import NeuralODE
from repro.core.train import (
    HypersolverTrainConfig, make_integrator, train_hypersolver,
)
from repro.nn.module import mlp_apply, mlp_init
from repro.optim import adamw, apply_updates, clip_by_global_norm

DIM = 2


def _beta(s):
    return jnp.stack([jnp.sin(2 * jnp.pi * s), jnp.cos(2 * jnp.pi * s)], -1)


def _make_node():
    def f_apply(p, s, x, z):
        s_col = jnp.broadcast_to(jnp.asarray(s, z.dtype), z[..., :1].shape)
        return mlp_apply(p, jnp.concatenate([z, s_col], -1), act=jnp.tanh)

    return NeuralODE(f_apply=f_apply, hx_apply=lambda p, x: x,
                     hy_apply=lambda p, z: z)


def train_tracker(iters: int = 400, seed=0):
    cm = CheckpointManager(os.path.join(CACHE, "tracker"), keep=1)
    params = mlp_init(jax.random.PRNGKey(seed), (DIM + 1, 64, 64, DIM))
    latest = cm.latest_step()
    node = _make_node()
    if latest is not None and latest >= iters:
        return node, cm.restore(latest, jax.eval_shape(lambda: params))
    opt = adamw(3e-3)
    st = opt.init(params)
    K = 32
    s_knots = FixedGrid.over(0, 1, K).s_span

    def loss_fn(p, z0):
        traj = make_integrator("rk4").solve(node.field(p, None), z0,
                                            FixedGrid.over(0, 1, K))
        target = _beta(s_knots)[:, None, :]
        return jnp.mean((traj - target) ** 2)

    @jax.jit
    def step(p, st, i, z0):
        l, g = jax.value_and_grad(loss_fn)(p, z0)
        g, _ = clip_by_global_norm(g, 1.0)
        u, st = opt.update(g, st, p, i)
        return apply_updates(p, u), st, l

    key = jax.random.PRNGKey(1)
    for i in range(iters):
        key, sub = jax.random.split(key)
        z0 = _beta(jnp.zeros(8)) + 0.05 * jax.random.normal(sub, (8, DIM))
        params, st, _ = step(params, st, i, z0)
    cm.save(iters, params)
    return node, params


def _g_apply(gp, eps, s, x, z, dz):
    s_col = jnp.broadcast_to(jnp.asarray(s, z.dtype), z[..., :1].shape)
    return mlp_apply(gp, jnp.concatenate([z, dz, s_col], -1), act=jnp.tanh)


def fit_tracker_hypersolver(node, params, iters: int = 400, K: int = 16):
    cm = CheckpointManager(os.path.join(CACHE, "tracker_hyper"), keep=1)
    gp = mlp_init(jax.random.PRNGKey(5), (2 * DIM + 1, 64, 64, 64, DIM),
                  final_zero=True)
    latest = cm.latest_step()
    if latest is not None and latest >= iters:
        return cm.restore(latest, jax.eval_shape(lambda: gp))

    def batches():
        key = jax.random.PRNGKey(6)
        while True:
            key, sub = jax.random.split(key)
            yield _beta(jnp.zeros(16)) + 0.05 * jax.random.normal(sub,
                                                                  (16, DIM))

    cfg = HypersolverTrainConfig(
        base_solver="euler", K=K, iters=iters, lr=3e-3, lr_min=1e-4,
        atol=1e-7, rtol=1e-7,
        residual_weight=0.0, trajectory_weight=1.0,  # paper: trajectory fit
    )
    gp, _ = train_hypersolver(node, params, _g_apply, gp, batches(), cfg)
    cm.save(iters, gp)
    return gp


def main(budget: str = "small"):
    node, params = train_tracker(400 if budget == "small" else 1500)
    gp = fit_tracker_hypersolver(node, params,
                                 400 if budget == "small" else 2000)
    z0 = _beta(jnp.zeros(64)) + 0.05 * jax.random.normal(
        jax.random.PRNGKey(9), (64, DIM))
    ref, _, _ = node.reference_trajectory(params, z0, K=16, atol=1e-8,
                                          rtol=1e-8)
    rows = []
    for K in (4, 8, 16, 25):
        stride = 16 // min(K, 16)
        for name in ("euler", "hyper_euler", "midpoint", "rk4"):
            grid = FixedGrid.over(0.0, 1.0, K)
            f = node.field(params, z0)
            if name == "hyper_euler":
                integ = make_integrator("euler", _g_apply, gp, z0)
            else:
                integ = make_integrator(name)
            zT = integ.solve(f, z0, grid, return_traj=False)
            nfe = integ.nfe(K)
            err = float(jnp.mean(jnp.linalg.norm(zT - ref[-1], axis=-1)))
            rows.append({"bench": "trajectory_tracking", "solver": name,
                         "K": K, "nfe": nfe,
                         "global_err": round(err, 6)})
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
