"""Paper Fig. 3 / Fig. 9: MAPE-NFE and accuracy-loss-GMAC pareto fronts on
the image-classification Neural ODE.

HyperEuler (trained by residual fitting at K=10) vs Euler / midpoint / RK4
across step counts; MACs account for the g_omega overhead (0.02 vs 0.04
GMAC per NFE in the paper's arch; here computed from the actual convs).
"""
from __future__ import annotations

import jax

from benchmarks.common import (
    accuracy_drop, eval_solver, fit_image_hypersolver, train_image_node,
)
from repro.data import synthetic_images
from repro.models.conv_node import mnist_f_macs, mnist_g_macs


def main(budget: str = "small"):
    steps = 60 if budget == "small" else 1000
    iters = 120 if budget == "small" else 1500
    node, params = train_image_node(steps=steps)
    gp = fit_image_hypersolver(node, params, "euler", K=10, iters=iters)
    xt, _ = synthetic_images("mnist28", 64, seed=9)

    macs_f = mnist_f_macs() / 1e9
    macs_g = mnist_g_macs() / 1e9
    rows = []
    for K in (2, 4, 8, 10, 20):
        for name in ("euler", "hyper_euler", "midpoint", "rk4"):
            out = eval_solver(node, params, name, K, xt,
                              gp=gp if name.startswith("hyper") else None)
            acc_loss = accuracy_drop(node, params, out["zT"], out["z_ref"])
            gmac = out["nfe"] * macs_f + (K * macs_g
                                          if name.startswith("hyper") else 0)
            rows.append({
                "bench": "pareto_mnist", "solver": name, "K": K,
                "nfe": out["nfe"], "gmac": round(gmac, 4),
                "mape": round(out["mape"], 4),
                "acc_loss_pct": round(acc_loss, 3),
            })
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
