"""Paper Sec. 6 (Fig. 2 complement): hypersolver relative overhead
O_r = 1 + MAC_g / (p * MAC_f) -> 1 as the base-solver order p grows, plus
the asymptotic-complexity table (empirical local-error order fits)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EULER, HEUN, MIDPOINT, RK4, rk_psi
from repro.models.conv_node import mnist_f_macs, mnist_g_macs


def main(budget: str = "small"):
    rows = []
    macs_f = mnist_f_macs()
    macs_g = mnist_g_macs()
    for tab in (EULER, MIDPOINT, HEUN, RK4):
        p = tab.order
        o_r = 1.0 + macs_g / (tab.stages * macs_f)
        rows.append({
            "bench": "overhead", "base": tab.name, "order": p,
            "stages": tab.stages,
            "mac_g_over_mac_f": round(macs_g / macs_f, 4),
            "relative_overhead_O_r": round(o_r, 4),
        })

    # Fig. 2 table: empirical local-error order e_k ~ eps^{p+1}
    A = jnp.array([[-0.4, -1.6], [1.6, -0.4]])
    f = lambda s, z: z @ A.T
    w, V = np.linalg.eig(np.asarray(A))

    def expm(t):
        return (V @ np.diag(np.exp(w * t)) @ np.linalg.inv(V)).real

    z = jnp.array([[0.7, -0.3]])
    for tab in (EULER, MIDPOINT, HEUN, RK4):
        # eps large enough that even RK4's eps^5 local error clears the
        # fp32 noise floor (the fp64 fit lives in tests/test_solvers.py)
        errs, epss = [], [0.8, 0.6, 0.45, 0.33]
        for eps in epss:
            z_true = jnp.asarray(np.asarray(z) @ expm(eps).T)
            psi, _ = rk_psi(f, tab, 0.0, eps, z)
            errs.append(float(jnp.linalg.norm(z_true - (z + eps * psi))))
        slope = float(np.polyfit(np.log(epss), np.log(errs), 1)[0])
        rows.append({
            "bench": "complexity_table", "solver": tab.name,
            "nfe_per_step": tab.stages,
            "theory_local_order": tab.order + 1,
            "empirical_local_order": round(slope, 2),
        })
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
