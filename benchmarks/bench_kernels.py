"""Kernel micro-bench: Pallas (interpret on CPU — correctness-grade
timing) vs the pure-jnp reference vs the Integrator's unfused jnp-fallback
sequence, plus the analytic HBM-traffic model the fusion is about.

The hyper_step section sweeps tableaus through the runtime-eps MASKED
MULTI-RATE update (per-sample eps row + active mask — the serving hot
path): the fused kernel does it in ONE memory pass per leaf, the unfused
leaf-wise path in ``stages + 3`` passes (b-lincomb, eps-axpy, correction
axpy, freeze where). Interpret-mode wall times on CPU do not measure TPU
HBM; the traffic model is the perf trajectory, timings pin relative
regressions. Writes BENCH_kernels.json at the repo root (CI uploads all
BENCH_*.json as artifacts per run — the serving counterpart is
BENCH_serve.json).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.core import get_tableau
from repro.core.integrate import tree_axpy, tree_lincomb
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hyper_step.ops import fused_rk_update
from repro.kernels.hyper_step.ref import fused_rk_update_ref
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.rwkv6_scan.ops import wkv6
from repro.kernels.rwkv6_scan.ref import wkv6_ref

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_kernels.json")

TABLEAUS = ("euler", "heun", "rk3", "rk4")


def _unfused_update(z, stages, g, eps, b, order, active):
    """The exact jnp sequence Integrator.step runs when the kernel is not
    in play: stages + 3 leaf-wise memory passes."""
    psi = tree_lincomb(b, stages)
    out = tree_axpy(eps, psi, z)
    if g is not None:
        out = tree_axpy(jnp.asarray(eps) ** (order + 1), g, out)
    mask = active.reshape(active.shape + (1,) * (z.ndim - 1)) != 0
    return jnp.where(mask, out, z).astype(z.dtype)


def _traffic_model(stages: int, with_g: bool, nbytes: int):
    """Bytes over the HBM bus for one masked multi-rate state update.

    fused: every operand streams exactly once — z + S stages (+ g) read,
    z_next written; the (B,) eps/mask rows ride in SMEM (negligible).
    unfused: ``stages + 3`` read-modify-write passes over state-sized
    arrays (b-lincomb accumulation, eps-axpy, correction axpy, freeze
    where), each re-reading its accumulator."""
    reads_fused = (1 + stages + (1 if with_g else 0)) * nbytes
    writes_fused = nbytes
    passes_unfused = stages + (3 if with_g else 2)
    # lincomb: S passes (r_j + accumulator re-read after the first),
    # each later pipeline stage: 2 reads + 1 write of state size.
    reads_unfused = (2 * stages - 1 + 2 * (passes_unfused - stages)) * nbytes
    writes_unfused = passes_unfused * nbytes
    return {
        "memory_passes_fused": 1,
        "memory_passes_unfused": passes_unfused,
        "hbm_bytes_fused": reads_fused + writes_fused,
        "hbm_bytes_unfused": reads_unfused + writes_unfused,
        "traffic_ratio": round(
            (reads_unfused + writes_unfused)
            / (reads_fused + writes_fused), 2),
    }


def main(budget: str = "small"):
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 12)
    B, D = (8, 4096) if budget == "small" else (64, 16384)

    # ---- hyper_step: runtime-eps masked multi-rate update per tableau ----
    z = jax.random.normal(ks[0], (B, D))
    g = jax.random.normal(ks[1], (B, D))
    eps = jnp.linspace(0.05, 0.5, B)
    active = (jnp.arange(B) % 2).astype(jnp.int32)
    for name in TABLEAUS:
        tab = get_tableau(name)
        stages = tuple(jax.random.normal(k, (B, D))
                       for k in jax.random.split(ks[2], tab.stages))
        t_ref, _ = timed(
            jax.jit(lambda z_, s_, g_, e_, a_, b=tab.b, o=tab.order:
                    fused_rk_update_ref(z_, s_, g_, e_, b, o, active=a_)),
            z, stages, g, eps, active)
        t_unf, _ = timed(
            jax.jit(lambda z_, s_, g_, e_, a_, b=tab.b, o=tab.order:
                    _unfused_update(z_, s_, g_, e_, b, o, a_)),
            z, stages, g, eps, active)
        t_pal, _ = timed(
            lambda z_, s_, g_, e_, a_, b=tab.b, o=tab.order:
            fused_rk_update(z_, s_, g_, e_, b, o, active=a_),
            z, stages, g, eps, active)
        nbytes = z.size * z.dtype.itemsize
        rows.append({
            "bench": "kernels", "kernel": "hyper_step", "tableau": name,
            "stages": tab.stages, "shape": f"{B}x{D}",
            "update": "masked multi-rate (per-sample eps row + active "
                      "mask, runtime scalar-prefetch operands)",
            "ref_us": round(t_ref * 1e6, 1),
            "jnp_fallback_us": round(t_unf * 1e6, 1),
            "pallas_interpret_us": round(t_pal * 1e6, 1),
            **_traffic_model(tab.stages, True, nbytes),
            "tpu_note": "mem-bound: one HBM pass replaces the unfused "
                        "lincomb/axpy/axpy/where pipeline; eps in SMEM "
                        "so one compile serves every step-size mix",
        })

    # ---- flash attention ----
    Bq, S, H, KV, hd = 1, 256, 4, 2, 64
    q = jax.random.normal(ks[3], (Bq, S, H, hd))
    k = jax.random.normal(ks[4], (Bq, S, KV, hd))
    v = jax.random.normal(ks[5], (Bq, S, KV, hd))
    ref_fn = jax.jit(lambda q, k, v: attention_ref(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2)))
    t_ref, _ = timed(ref_fn, q, k, v)
    t_pal, _ = timed(lambda q, k, v: flash_attention(q, k, v), q, k, v)
    rows.append({"bench": "kernels", "kernel": "flash_attention",
                 "shape": f"{Bq}x{S}x{H}x{hd}",
                 "ref_us": round(t_ref * 1e6, 1),
                 "pallas_interpret_us": round(t_pal * 1e6, 1),
                 "tpu_note": "128x128 MXU blocks; causal skips upper "
                             "triangle via loop bound"})

    # ---- wkv6 ----
    Bt, T, Hh, Dh = 1, 256, 2, 16
    r = jax.random.normal(ks[6], (Bt, T, Hh, Dh))
    kk = jax.random.normal(ks[7], (Bt, T, Hh, Dh))
    vv = jax.random.normal(ks[8], (Bt, T, Hh, Dh))
    w = jax.nn.sigmoid(jax.random.normal(ks[9], (Bt, T, Hh, Dh)))
    u = jnp.full((Hh, Dh), 0.3)
    t_ref, _ = timed(jax.jit(wkv6_ref), r, kk, vv, w, u)
    t_pal, _ = timed(lambda *a: wkv6(*a, chunk=64), r, kk, vv, w, u)
    rows.append({"bench": "kernels", "kernel": "rwkv6_scan",
                 "shape": f"{Bt}x{T}x{Hh}x{Dh}",
                 "ref_us": round(t_ref * 1e6, 1),
                 "pallas_interpret_us": round(t_pal * 1e6, 1),
                 "tpu_note": "chunked VMEM-resident (D,D) state; "
                             "O(T D) HBM traffic"})

    # ---- rglru ----
    a = jax.nn.sigmoid(jax.random.normal(ks[10], (2, 512, 128)))
    b = jax.random.normal(ks[11], (2, 512, 128))
    t_ref, _ = timed(jax.jit(rglru_scan_ref), a, b)
    t_pal, _ = timed(lambda x, y: rglru_scan(x, y, chunk=128, bw=128), a, b)
    rows.append({"bench": "kernels", "kernel": "rglru_scan",
                 "shape": "2x512x128",
                 "ref_us": round(t_ref * 1e6, 1),
                 "pallas_interpret_us": round(t_pal * 1e6, 1),
                 "tpu_note": "lane-parallel VPU scan, fp32 carry"})

    with open(OUT_PATH, "w") as fh:
        json.dump(rows, fh, indent=1, default=str)
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
