"""Kernel micro-bench: Pallas (interpret on CPU — correctness-grade
timing) vs the pure-jnp reference, plus analytic VMEM/MXU utilization
notes per kernel for the TPU target."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timed
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.hyper_step.ops import hyper_step
from repro.kernels.hyper_step.ref import hyper_step_ref
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.rwkv6_scan.ops import wkv6
from repro.kernels.rwkv6_scan.ref import wkv6_ref


def main(budget: str = "small"):
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    # hyper_step
    z, f, g = (jax.random.normal(ks[i], (64, 2048)) for i in range(3))
    t_ref, _ = timed(jax.jit(lambda a, b, c: hyper_step_ref(a, b, c, 0.1, 1)),
                     z, f, g)
    t_pal, _ = timed(lambda a, b, c: hyper_step(a, b, c, 0.1, 1), z, f, g)
    rows.append({"bench": "kernels", "kernel": "hyper_step",
                 "shape": "64x2048",
                 "ref_us": round(t_ref * 1e6, 1),
                 "pallas_interpret_us": round(t_pal * 1e6, 1),
                 "tpu_note": "mem-bound fusion: 4 HBM streams vs 8 unfused"})

    # flash attention
    B, S, H, KV, hd = 1, 256, 4, 2, 64
    q = jax.random.normal(ks[3], (B, S, H, hd))
    k = jax.random.normal(ks[4], (B, S, KV, hd))
    v = jax.random.normal(ks[5], (B, S, KV, hd))
    ref_fn = jax.jit(lambda q, k, v: attention_ref(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2)))
    t_ref, _ = timed(ref_fn, q, k, v)
    t_pal, _ = timed(lambda q, k, v: flash_attention(q, k, v), q, k, v)
    rows.append({"bench": "kernels", "kernel": "flash_attention",
                 "shape": f"{B}x{S}x{H}x{hd}",
                 "ref_us": round(t_ref * 1e6, 1),
                 "pallas_interpret_us": round(t_pal * 1e6, 1),
                 "tpu_note": "128x128 MXU blocks; causal skips upper "
                             "triangle via loop bound"})

    # wkv6
    Bt, T, Hh, D = 1, 256, 2, 16
    r = jax.random.normal(ks[6], (Bt, T, Hh, D))
    kk = jax.random.normal(ks[7], (Bt, T, Hh, D))
    vv = jax.random.normal(ks[0], (Bt, T, Hh, D))
    w = jax.nn.sigmoid(jax.random.normal(ks[1], (Bt, T, Hh, D)))
    u = jnp.full((Hh, D), 0.3)
    t_ref, _ = timed(jax.jit(wkv6_ref), r, kk, vv, w, u)
    t_pal, _ = timed(lambda *a: wkv6(*a, chunk=64), r, kk, vv, w, u)
    rows.append({"bench": "kernels", "kernel": "rwkv6_scan",
                 "shape": f"{Bt}x{T}x{Hh}x{D}",
                 "ref_us": round(t_ref * 1e6, 1),
                 "pallas_interpret_us": round(t_pal * 1e6, 1),
                 "tpu_note": "chunked VMEM-resident (D,D) state; "
                             "O(T D) HBM traffic"})

    # rglru
    a = jax.nn.sigmoid(jax.random.normal(ks[2], (2, 512, 128)))
    b = jax.random.normal(ks[3], (2, 512, 128))
    t_ref, _ = timed(jax.jit(rglru_scan_ref), a, b)
    t_pal, _ = timed(lambda x, y: rglru_scan(x, y, chunk=128, bw=128), a, b)
    rows.append({"bench": "kernels", "kernel": "rglru_scan",
                 "shape": "2x512x128",
                 "ref_us": round(t_ref * 1e6, 1),
                 "pallas_interpret_us": round(t_pal * 1e6, 1),
                 "tpu_note": "lane-parallel VPU scan, fp32 carry"})
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
