"""Benchmark harness — one module per paper table/figure (+ beyond-paper).

    PYTHONPATH=src python -m benchmarks.run [--budget small|full] [--only X]

Prints one CSV-ish line per result row: ``name,us_per_call,derived``.
Figure mapping: bench_pareto (Fig 3/9), bench_wallclock (Fig 4),
bench_alpha_family (Fig 5-6), bench_cnf (Fig 1/7), bench_trajectory
(Fig 8), bench_overhead (Fig 2 + Sec 6), bench_kernels (kernel layer),
bench_cdepth_lm (beyond paper: the technique on LM serving).

Perf trajectory files at the repo root (uploaded as CI artifacts on every
tier-1 run): BENCH_kernels.json (bench_kernels — fused hyper_step traffic
model + timings per tableau) and BENCH_serve.json (bench_serve — the
multi-rate NFE/agreement pareto).
"""
from __future__ import annotations

import argparse
import json
import os
import time
import traceback

MODULES = [
    "bench_overhead",
    "bench_pareto",
    "bench_wallclock",
    "bench_alpha_family",
    "bench_trajectory",
    "bench_cnf",
    "bench_kernels",
    "bench_cdepth_lm",
    "bench_serve",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", default="small", choices=["small", "full"])
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    out_dir = os.path.join(os.path.dirname(__file__), "..", "artifacts")
    os.makedirs(out_dir, exist_ok=True)
    all_rows = []
    failures = []
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["main"])
            rows = mod.main(budget=args.budget)
            dt = time.time() - t0
            for r in rows:
                derived = {k: v for k, v in r.items() if k != "bench"}
                print(f"{r['bench']},{dt / max(len(rows), 1) * 1e6:.0f},"
                      f"{json.dumps(derived, default=str)}")
            all_rows.extend(rows)
            print(f"# {mod_name}: {len(rows)} rows in {dt:.1f}s")
        except Exception as e:
            traceback.print_exc()
            failures.append((mod_name, str(e)))
    with open(os.path.join(out_dir, "bench_results.json"), "w") as f:
        json.dump(all_rows, f, indent=1, default=str)
    if failures:
        print(f"# FAILURES: {failures}")
        raise SystemExit(1)
    print(f"# total rows: {len(all_rows)} -> artifacts/bench_results.json")


if __name__ == "__main__":
    main()
