"""Benchmark harness — one module per paper table/figure (+ beyond-paper).

    PYTHONPATH=src python -m benchmarks.run [--budget small|full] [--only X]
    PYTHONPATH=src python -m benchmarks.run --check

Prints one CSV-ish line per result row: ``name,us_per_call,derived``.
Figure mapping: bench_pareto (Fig 3/9), bench_wallclock (Fig 4),
bench_alpha_family (Fig 5-6), bench_cnf (Fig 1/7), bench_trajectory
(Fig 8), bench_overhead (Fig 2 + Sec 6), bench_kernels (kernel layer),
bench_cdepth_lm (beyond paper: the technique on LM serving),
bench_scheduler (in-flight continuous batching vs the drain engine).

Perf trajectory files at the repo root (uploaded as CI artifacts on every
tier-1 run): BENCH_kernels.json (bench_kernels — fused hyper_step traffic
model + timings per tableau), BENCH_serve.json (bench_serve — the
multi-rate NFE/agreement pareto), BENCH_scheduler.json
(bench_scheduler — serving-latency head-to-head, p50/p99/waste),
BENCH_wallclock.json (bench_wallclock — the real-clock overlap-vs-sync
serving race + async-dispatch mechanism + predicted-vs-measured join),
BENCH_faults.json (bench_faults — the chaos harness: zero-hang,
status accounting, and fault-free parity under seeded fault injection),
BENCH_refinery.json (bench_refinery — the closed refinement loop:
refined-vs-frozen agreement at equal NFE, capture bitwise parity, and
shadow-gate rejection cleanliness), and BENCH_flow.json (bench_flow —
the K=0 flow tier: three-tier-router vs hypersolver-only pareto,
flow-disabled bitwise parity, and escalation-path accounting).

``--check`` is the BENCH-schema smoke gate (tier-1 CI): it validates
every committed BENCH_*.json — parseable, non-empty list of rows, every
row tagged with its bench — plus per-file invariants (the scheduler
verdict row must exist; kernels rows must carry the traffic model), so a
malformed perf-trajectory file fails fast instead of at analysis time.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import time
import traceback

MODULES = [
    "bench_overhead",
    "bench_pareto",
    "bench_wallclock",
    "bench_alpha_family",
    "bench_trajectory",
    "bench_cnf",
    "bench_kernels",
    "bench_cdepth_lm",
    "bench_serve",
    "bench_scheduler",
    "bench_faults",
    "bench_refinery",
    "bench_flow",
]

REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))

# BENCH_*.json contract: every tracked perf-trajectory file must exist at
# the repo root, and each file's rows must contain the listed keys in at
# least one row (the row-level invariant the analysis scripts key on).
BENCH_REQUIRED = {
    "BENCH_kernels.json": ("memory_passes_fused", "hbm_bytes_fused"),
    "BENCH_serve.json": ("mean_nfe", "mode"),
    # 'devices' pins the multi-device slot-pool section (single- vs
    # sharded-pool rows, bench_scheduler.sharded_rows); 'cost_unit' pins
    # the clock tag every replay row must carry since the oracle refactor
    "BENCH_scheduler.json": ("p99_latency", "waste_steps", "devices",
                             "cost_unit"),
    # the wall-clock serving race (bench_wallclock.serving_rows):
    # 'req_per_s' pins the real-clock serving rows, 'agreement' the
    # uid-for-uid overlap-vs-sync parity every timing row must carry
    "BENCH_wallclock.json": ("req_per_s", "agreement"),
    # the chaos harness (bench_faults): 'zero_hang' pins the liveness
    # ledger every fault-mix row carries, 'mix' the fault taxonomy
    "BENCH_faults.json": ("zero_hang", "mix"),
    # the closed-loop refinery (bench_refinery): 'agreement' pins the
    # frozen-vs-refined scoring rows, 'section' the three-part layout
    "BENCH_refinery.json": ("agreement", "section"),
    # the K=0 flow tier (bench_flow): 'mean_nfe' pins the pareto rows
    # (three-tier vs hypersolver-only), 'escalated' the fault-path
    # accounting rows, 'section' the three-part layout
    "BENCH_flow.json": ("mean_nfe", "escalated", "section"),
}


def check_bench_files(root: str = REPO_ROOT) -> list:
    """Validate BENCH_*.json at the repo root; returns a list of error
    strings (empty = all good). Shared by ``--check`` and the tier-1
    test (tests/test_scheduler.py)."""
    errors = []
    found = {os.path.basename(p) for p in
             glob.glob(os.path.join(root, "BENCH_*.json"))}
    for name in BENCH_REQUIRED:
        if name not in found:
            errors.append(f"{name}: missing from repo root")
    for name in sorted(found):
        path = os.path.join(root, name)
        try:
            with open(path) as fh:
                rows = json.load(fh)
        except (OSError, json.JSONDecodeError) as e:
            errors.append(f"{name}: unreadable/malformed JSON ({e})")
            continue
        if not isinstance(rows, list) or not rows:
            errors.append(f"{name}: expected a non-empty list of rows")
            continue
        bad = [i for i, r in enumerate(rows)
               if not isinstance(r, dict) or not isinstance(
                   r.get("bench"), str)]
        if bad:
            errors.append(f"{name}: rows {bad[:5]} lack a 'bench' tag")
        for key in BENCH_REQUIRED.get(name, ()):
            if not any(isinstance(r, dict) and key in r for r in rows):
                errors.append(f"{name}: no row carries required key "
                              f"{key!r}")
        if name == "BENCH_scheduler.json":
            verdicts = [r for r in rows if isinstance(r, dict)
                        and r.get("mode") == "verdict"]
            if not verdicts:
                errors.append(f"{name}: missing the verdict row "
                              "(inflight_wins_p99 scoreboard)")
            else:
                for key in ("inflight_wins_p99", "sharded_pool_ok"):
                    if key not in verdicts[0]:
                        errors.append(f"{name}: verdict row lacks "
                                      f"{key!r}")
            if not any(isinstance(r, dict) and r.get("devices", 0) > 1
                       for r in rows):
                errors.append(f"{name}: no multi-device slot-pool row "
                              "(devices > 1) — bench_scheduler's sharded "
                              "section is missing")
            errors.extend(_check_oracle_section(name, rows, root))
        if name == "BENCH_wallclock.json":
            errors.extend(_check_wallclock_section(name, rows))
        if name == "BENCH_faults.json":
            errors.extend(_check_faults_section(name, rows))
        if name == "BENCH_refinery.json":
            errors.extend(_check_refinery_section(name, rows))
        if name == "BENCH_flow.json":
            errors.extend(_check_flow_section(name, rows))
    return errors


def _check_flow_section(name: str, rows: list) -> list:
    """K=0 flow-tier invariants: pareto rows for BOTH router variants
    with the three-tier side at equal-or-better agreement and STRICTLY
    lower mean NFE (and actually serving flow traffic — a zero-traffic
    'win' is vacuous), flow-disabled parity rows for all three serving
    loops each bitwise at parity, escalation rows whose poisoned flow
    evals were requeued into the K-ladder with accounting that closes,
    and the verdict scoreboard all True."""
    errors = []
    par = {r.get("variant"): r for r in rows if isinstance(r, dict)
           and r.get("section") == "pareto"}
    for variant in ("hyper_multirate", "three_tier"):
        if variant not in par:
            errors.append(f"{name}: no pareto row for the {variant!r} "
                          "variant — the head-to-head needs both sides")
    if len(par) == 2:
        h, f = par["hyper_multirate"], par["three_tier"]
        if not (f.get("agreement", 0) >= h.get("agreement", 1)):
            errors.append(f"{name}: three-tier agreement "
                          f"{f.get('agreement')} fell below the "
                          f"hypersolver-only {h.get('agreement')}")
        if not (f.get("mean_nfe", 1e9) < h.get("mean_nfe", 0)):
            errors.append(f"{name}: three-tier mean NFE "
                          f"{f.get('mean_nfe')} is not strictly below "
                          f"the hypersolver-only {h.get('mean_nfe')}")
        if not f.get("flow_served", 0) > 0:
            errors.append(f"{name}: the three-tier pareto row served "
                          "zero flow-tier requests — the comparison is "
                          "vacuous")
    dis = {r.get("mode"): r for r in rows if isinstance(r, dict)
           and r.get("section") == "flow_disabled_parity"}
    for loop in ("engine", "inflight", "inflight_overlap"):
        if loop not in dis:
            errors.append(f"{name}: no flow-disabled parity row for "
                          f"the {loop!r} loop")
        elif dis[loop].get("parity") is not True:
            errors.append(f"{name}: flow-disabled parity row for "
                          f"{loop!r} is not at parity — attaching a "
                          "disabled flow head perturbed the ladder")
    esc = [r for r in rows if isinstance(r, dict)
           and r.get("section") == "escalation" and "escalated" in r]
    if not esc:
        errors.append(f"{name}: no escalation rows (flow fault path)")
    elif not any(r.get("escalated", 0) > 0 for r in esc):
        errors.append(f"{name}: no escalation row recorded a poisoned "
                      "flow eval requeued into the K-ladder")
    verdicts = [r for r in rows if isinstance(r, dict)
                and r.get("mode") == "verdict"]
    if not verdicts:
        errors.append(f"{name}: missing the verdict row "
                      "(three_tier_dominates scoreboard)")
    else:
        for key in ("three_tier_dominates", "flow_disabled_parity",
                    "escalation_accounted", "zero_hang"):
            if verdicts[0].get(key) is not True:
                errors.append(f"{name}: verdict {key} is not True — "
                              "the flow-tier contract regressed")
    return errors


def _check_refinery_section(name: str, rows: list) -> list:
    """Closed-loop-refinery invariants: frozen AND refined scoring rows
    (the head-to-head needs both), capture-parity rows for all three
    serving loops each at parity, a shadow-gate row whose corrupted
    candidate was rejected, and the verdict scoreboard — the refined g
    must beat the frozen g on agreement at EQUAL mean NFE, capture must
    be bitwise free, and a rejected candidate must never be observable
    in serving outputs."""
    errors = []
    scoring = [r for r in rows if isinstance(r, dict)
               and r.get("section") == "refinement"
               and r.get("variant") in ("frozen", "refined")]
    for variant in ("frozen", "refined"):
        if not any(r.get("variant") == variant for r in scoring):
            errors.append(f"{name}: no refinement scoring row for the "
                          f"{variant!r} variant — the head-to-head "
                          "needs both sides")
    cap = {r.get("mode"): r for r in rows if isinstance(r, dict)
           and r.get("section") == "capture_parity"}
    for loop in ("inflight", "inflight_overlap", "engine"):
        if loop not in cap:
            errors.append(f"{name}: no capture-parity row for the "
                          f"{loop!r} loop")
        elif cap[loop].get("parity") is not True:
            errors.append(f"{name}: capture-parity row for {loop!r} is "
                          "not at parity — capture perturbed serving "
                          "completions")
    gates = [r for r in rows if isinstance(r, dict)
             and r.get("section") == "shadow_gate"]
    if not gates:
        errors.append(f"{name}: missing the shadow-gate rejection row")
    elif not (gates[0].get("candidate_rejected")
              and gates[0].get("parity")):
        errors.append(f"{name}: shadow-gate row shows the corrupted "
                      "candidate leaked into serving (rejected="
                      f"{gates[0].get('candidate_rejected')}, parity="
                      f"{gates[0].get('parity')})")
    verdicts = [r for r in rows if isinstance(r, dict)
                and r.get("mode") == "verdict"]
    if not verdicts:
        errors.append(f"{name}: missing the verdict row "
                      "(refined_beats_frozen scoreboard)")
    else:
        for key in ("refined_beats_frozen", "equal_nfe",
                    "capture_parity", "shadow_gate_clean"):
            if verdicts[0].get(key) is not True:
                errors.append(f"{name}: verdict {key} is not True — "
                              "the closed-loop contract regressed")
    return errors


def _check_faults_section(name: str, rows: list) -> list:
    """Chaos-bench invariants: every fault-mix row terminal-accounted
    and hang-free, a fault-free-parity check that PASSED (the hardened
    loops are bitwise the old loops when nothing is injected), sync ==
    overlap under identical fault schedules, and a multi-device chaos
    row (the quarantine works on the sharded pool too)."""
    errors = []
    fault_rows = [r for r in rows if isinstance(r, dict)
                  and "zero_hang" in r]
    if not fault_rows:
        errors.append(f"{name}: no fault-mix rows (zero_hang ledger)")
    hung = [f"{r.get('mode')}/{r.get('mix')}" for r in fault_rows
            if not r.get("zero_hang")]
    if hung:
        errors.append(f"{name}: rows {hung} lost requests — a submitted "
                      "uid never reached a terminal record")
    bad_acct = [f"{r.get('mode')}/{r.get('mix')}" for r in fault_rows
                if not r.get("status_ok")]
    if bad_acct:
        errors.append(f"{name}: rows {bad_acct} have a status histogram "
                      "that does not sum to the submitted count")
    if not any(isinstance(r, dict) and r.get("devices", 0) > 1
               for r in rows):
        errors.append(f"{name}: no multi-device chaos row (devices > 1) "
                      "— bench_faults' sharded section is missing")
    verdicts = [r for r in rows if isinstance(r, dict)
                and r.get("mode") == "verdict"]
    if not verdicts:
        errors.append(f"{name}: missing the verdict row (zero_hang_all "
                      "scoreboard)")
    else:
        for key in ("zero_hang_all", "fault_free_parity",
                    "status_accounting_ok", "overlap_parity_all"):
            if verdicts[0].get(key) is not True:
                errors.append(f"{name}: verdict {key} is not True — "
                              "the hardening contract regressed")
    return errors


def _check_wallclock_section(name: str, rows: list) -> list:
    """Wall-clock-bench invariants: a sync AND an overlap serving row
    (the race needs both lanes), every serving row at agreement 1.0
    (a timing row for loops that diverged is meaningless — the overlap
    loop must be observationally the sync loop before its clock
    counts), a predicted-vs-measured row carrying BOTH unit tags (the
    device_us/wall_us join must stay ratio-able, never summable), and
    the verdict scoreboard with its async-dispatch mechanism check."""
    errors = []
    serving = [r for r in rows if isinstance(r, dict)
               and r.get("section") == "serving"]
    for loop in ("sync", "overlap"):
        if not any(r.get("loop") == loop for r in serving):
            errors.append(f"{name}: no serving row for the {loop!r} "
                          "loop — the wall-clock race needs both lanes")
    bad = [r.get("trace") for r in serving if r.get("agreement") != 1.0]
    if bad:
        errors.append(f"{name}: serving rows with agreement != 1.0 on "
                      f"traces {bad} — overlap diverged from sync, the "
                      "timings are void")
    pvm = [r for r in rows if isinstance(r, dict)
           and r.get("section") == "predicted_vs_measured"]
    if not pvm:
        errors.append(f"{name}: missing the predicted-vs-measured "
                      "section (roofline device_us vs measured wall_us)")
    elif not all(r.get("predicted_unit") == "device_us"
                 and r.get("measured_unit") == "wall_us" for r in pvm):
        errors.append(f"{name}: predicted-vs-measured rows must tag "
                      "predicted_unit='device_us' and "
                      "measured_unit='wall_us'")
    verdicts = [r for r in rows if isinstance(r, dict)
                and r.get("mode") == "verdict"]
    if not verdicts:
        errors.append(f"{name}: missing the verdict row "
                      "(overlap_wins_wallclock scoreboard)")
    else:
        for key in ("overlap_wins_wallclock", "agreement_all",
                    "async_dispatch_ok", "host_cpus"):
            if key not in verdicts[0]:
                errors.append(f"{name}: verdict row lacks {key!r}")
        if verdicts[0].get("agreement_all") != 1.0:
            errors.append(f"{name}: verdict agreement_all != 1.0 — "
                          "the overlap loop diverged from the sync "
                          "oracle on some trace")
    return errors


def _check_oracle_section(name: str, rows: list, root: str) -> list:
    """Scheduler-bench oracle-section invariants: a roofline-clock row
    must exist (cost_unit='device_us'), tuner verdict rows must exist,
    and each tuner row's chosen knobs must match the persisted config in
    artifacts/tuned/<cell>.json — a stale tuned config (someone re-ran
    the autotuner without regenerating the bench, or vice versa) fails
    here instead of silently shipping two disagreeing verdicts."""
    errors = []
    if not any(isinstance(r, dict) and r.get("cost_unit") == "device_us"
               for r in rows):
        errors.append(f"{name}: no roofline-oracle row "
                      "(cost_unit='device_us') — bench_scheduler's "
                      "oracle section is missing")
    tuner_rows = [r for r in rows if isinstance(r, dict)
                  and r.get("mode") == "tuner"]
    if not tuner_rows:
        errors.append(f"{name}: no tuner verdict rows (mode='tuner') — "
                      "run python -m repro.launch.autotune or regenerate "
                      "the bench")
    for r in tuner_rows:
        cell = r.get("cell", "?")
        path = os.path.join(root, "artifacts", "tuned", f"{cell}.json")
        if not os.path.exists(path):
            errors.append(f"{name}: tuner row {cell!r} has no persisted "
                          f"config at artifacts/tuned/{cell}.json")
            continue
        try:
            with open(path) as fh:
                tuned = json.load(fh)
        except (OSError, json.JSONDecodeError) as e:
            errors.append(f"artifacts/tuned/{cell}.json: unreadable/"
                          f"malformed JSON ({e})")
            continue
        if tuned.get("chosen") != r.get("chosen"):
            errors.append(
                f"artifacts/tuned/{cell}.json is stale relative to the "
                f"tuner verdict in {name}: chosen {tuned.get('chosen')} "
                f"vs {r.get('chosen')} — re-run the autotune sweep and "
                "regenerate the bench together")
    return errors


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", default="small", choices=["small", "full"])
    ap.add_argument("--only", default=None)
    ap.add_argument("--check", action="store_true",
                    help="validate committed BENCH_*.json schemas and exit "
                         "(the tier-1 fail-fast gate; runs no benchmarks)")
    args = ap.parse_args()

    if args.check:
        errors = check_bench_files()
        for e in errors:
            print(f"# BENCH-CHECK FAIL: {e}")
        if errors:
            raise SystemExit(1)
        print(f"# BENCH-CHECK OK: {sorted(BENCH_REQUIRED)}")
        return

    out_dir = os.path.join(os.path.dirname(__file__), "..", "artifacts")
    os.makedirs(out_dir, exist_ok=True)
    all_rows = []
    failures = []
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["main"])
            rows = mod.main(budget=args.budget)
            dt = time.time() - t0
            for r in rows:
                derived = {k: v for k, v in r.items() if k != "bench"}
                print(f"{r['bench']},{dt / max(len(rows), 1) * 1e6:.0f},"
                      f"{json.dumps(derived, default=str)}")
            all_rows.extend(rows)
            print(f"# {mod_name}: {len(rows)} rows in {dt:.1f}s")
        except Exception as e:
            traceback.print_exc()
            failures.append((mod_name, str(e)))
    with open(os.path.join(out_dir, "bench_results.json"), "w") as f:
        json.dump(all_rows, f, indent=1, default=str)
    if failures:
        print(f"# FAILURES: {failures}")
        raise SystemExit(1)
    print(f"# total rows: {len(all_rows)} -> artifacts/bench_results.json")


if __name__ == "__main__":
    main()
