"""Closed-loop refinery bench (PR 9: online hypersolver refinement).

    PYTHONPATH=src python benchmarks/bench_refinery.py [--budget small]

Serves a DRIFTING seeded workload mix through the in-flight scheduler
with the full refinery loop live — residual-ledger capture from interior
healthy slot rows, cooperative fit steps between scheduler ticks, shadow
scoring, and promotion hot-swaps into the RUNNING scheduler — and writes
BENCH_refinery.json with three sections:

  * **refinement** — the headline: after serving the drifting mix with
    the loop closed, the promoted (refined) g beats the frozen
    (zero-init) g on agreement against a fine frozen reference at EQUAL
    mean NFE, on a held-out drifting request set neither side trained
    on. One row per variant (frozen / refined) + one loop-accounting row
    (promotions, rejections, rollbacks, ledger fill, fit steps).
  * **capture_parity** — ACCEPTANCE: with capture enabled
    (capture_rate=1.0) but NO promotion, completions are uid-for-uid
    bitwise identical to capture-disabled runs — engine, in-flight
    sync, and in-flight overlap. Capture only reads resident state, is
    never priced by the cost oracle, and draws from its own RNG.
  * **shadow_gate** — ACCEPTANCE: a corrupted candidate offered to the
    promotion gate mid-serving is rejected by the shadow scorer, and
    the serving outputs are bitwise identical to a run where no
    refinery was attached at all — a rejected candidate is NEVER
    observable in serving outputs.

The verdict row is the tracked scoreboard: ``refined_beats_frozen``,
``equal_nfe``, ``capture_parity``, ``shadow_gate_clean``.
``benchmarks/run.py --check`` enforces all four.
"""
from __future__ import annotations

import argparse
import os
import json
import sys

REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
if __name__ == "__main__":  # runnable as a script from anywhere
    sys.path.insert(0, REPO_ROOT)

import numpy as np

from benchmarks.bench_faults import records_bitwise_equal
from repro.launch.engine import EngineConfig, MultiRateEngine
from repro.launch.refinery import Refinery, RefineryConfig, ResidualLedger
from repro.launch.scheduler import InflightScheduler
from repro.launch.workload import (
    drifting_requests, poisson_trace, replay_engine, replay_scheduler,
    toy_refinable_classifier,
)

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_refinery.json")

D_FEAT = 32
SLOTS = 32
# seg=1 under fixed K=2 so every request has an interior segment
# boundary — the scheduler's retire hook captures interior rows only
SEG = 1


def _ecfg():
    # fixed-K serving: both variants run the SAME mesh (K=2 for every
    # request), so agreement differences are purely the correction's —
    # the refined g must win at equal NFE, not by buying steps
    return EngineConfig(controller="fixed", fixed_K=2, buckets=(2,),
                        max_batch=SLOTS, solver="euler")


def _budget(budget: str):
    return {
        "tiny": dict(n=96, epochs=2, steps_per_tick=20, total=1500),
        "small": dict(n=256, epochs=4, steps_per_tick=40, total=6000),
        "full": dict(n=512, epochs=6, steps_per_tick=60, total=20000),
    }.get(budget, None) or _budget("small")


# ------------------------------------------------------- the closed loop ----

def refinement_rows(budget: str = "small"):
    """Serve the drifting mix with the loop closed; score frozen vs the
    promoted params on a held-out drifting set. Returns (rows, ok_flags).
    """
    b = _budget(budget)
    model = toy_refinable_classifier(d=D_FEAT, hidden=16)
    ecfg = _ecfg()
    ledger = ResidualLedger(model, capacity=2048, capture_rate=1.0,
                            seed=0, holdout_every=8)
    sched = InflightScheduler(model, ecfg, slots=SLOTS, seg=SEG,
                              ledger=ledger)
    shadow = drifting_requests(32, D_FEAT, seed=999)
    refin = Refinery(
        model, ledger,
        RefineryConfig(steps_per_tick=b["steps_per_tick"], batch_size=64,
                       min_fill=64, lr=5e-3, total_steps=b["total"],
                       shadow_every=400, ckpt_every=10 ** 9, ref_K=64,
                       seed=0),
        ecfg=ecfg, shadow_xs=shadow)
    frozen = refin.current

    xs = drifting_requests(b["n"], D_FEAT, seed=3)
    ticks = [0]

    def on_tick(s):
        refin.tick([s])
        ticks[0] += 1

    for ep in range(b["epochs"]):
        replay_scheduler(sched, poisson_trace(xs, rate=2.0, seed=100 + ep),
                         on_tick=on_tick)

    fr = refin.shadow_score(frozen)
    re = refin.shadow_score(refin.current)
    rows = [
        {"bench": "refinery", "section": "refinement", "variant": "frozen",
         "agreement": fr["agreement"], "mean_nfe": fr["mean_nfe"],
         "holdout_resid": fr.get("resid")},
        {"bench": "refinery", "section": "refinement", "variant": "refined",
         "agreement": re["agreement"], "mean_nfe": re["mean_nfe"],
         "holdout_resid": re.get("resid")},
        {"bench": "refinery", "section": "refinement", "variant": "loop",
         "ticks": ticks[0], "fit_steps": refin.steps,
         "ledger_fill": ledger.fill, "ledger_seen": ledger.seen,
         "holdout_fill": ledger.holdout_fill,
         "promotions": refin.promotions, "rejections": refin.rejections,
         "rollbacks": refin.rollbacks, "last_loss": refin.last_loss},
    ]
    beats = bool(refin.promotions > 0
                 and re["agreement"] > fr["agreement"]
                 and re.get("resid", 0.0) < fr.get("resid", float("inf")))
    equal_nfe = bool(re["mean_nfe"] == fr["mean_nfe"])
    return rows, beats, equal_nfe


# ------------------------------------------------------- capture parity ----

def capture_parity_rows(budget: str = "small"):
    """Capture on (rate=1.0, no refinery -> no promotion) vs capture off
    must be bitwise identical, uid for uid — all three serving loops."""
    n = {"tiny": 24, "small": 48, "full": 96}.get(budget, 48)
    ecfg = _ecfg()
    xs = drifting_requests(n, D_FEAT, seed=17)
    trace = poisson_trace(xs, rate=0.5, seed=211)

    def led(model):
        return ResidualLedger(model, capacity=512, capture_rate=1.0,
                              seed=0)

    def sched(ledger=None, overlap=False):
        m = toy_refinable_classifier(d=D_FEAT)
        return InflightScheduler(
            m, ecfg, slots=8, seg=SEG, overlap=overlap,
            ledger=None if ledger is None else led(m))

    checks = []
    rep_off = replay_scheduler(sched(), trace)
    rep_on = replay_scheduler(sched(ledger=True), trace)
    checks.append(("inflight", records_bitwise_equal(rep_off, rep_on)))
    rep_off_o = replay_scheduler(sched(overlap=True), trace)
    rep_on_o = replay_scheduler(sched(ledger=True, overlap=True), trace)
    checks.append(("inflight_overlap",
                   records_bitwise_equal(rep_off_o, rep_on_o)))
    m_e = toy_refinable_classifier(d=D_FEAT)
    rep_e_off = replay_engine(MultiRateEngine(m_e, ecfg), trace)
    rep_e_on = replay_engine(
        MultiRateEngine(m_e, ecfg, ledger=led(m_e)), trace)
    checks.append(("engine", records_bitwise_equal(rep_e_off, rep_e_on)))

    rows = [{"bench": "refinery", "section": "capture_parity",
             "mode": loop, "submitted": n, "parity": bool(ok)}
            for loop, ok in checks]
    return rows, all(ok for _, ok in checks)


# ---------------------------------------------------------- shadow gate ----

def shadow_gate_rows(budget: str = "small"):
    """A corrupted candidate offered mid-serving must be REJECTED by the
    shadow gate, and the serving records must be bitwise identical to a
    run with no refinery attached at all."""
    n = {"tiny": 24, "small": 48, "full": 96}.get(budget, 48)
    ecfg = _ecfg()
    xs = drifting_requests(n, D_FEAT, seed=29)
    trace = poisson_trace(xs, rate=0.5, seed=307)
    shadow = drifting_requests(16, D_FEAT, seed=999)

    # baseline: capture on, no refinery
    m_a = toy_refinable_classifier(d=D_FEAT)
    sched_a = InflightScheduler(
        m_a, ecfg, slots=8, seg=SEG,
        ledger=ResidualLedger(m_a, capacity=512, seed=0))
    rep_a = replay_scheduler(sched_a, trace)

    # gated run: same trace; at tick 5 a corrupted candidate hits the
    # promotion gate against the live scheduler
    m_b = toy_refinable_classifier(d=D_FEAT)
    led_b = ResidualLedger(m_b, capacity=512, seed=0)
    sched_b = InflightScheduler(m_b, ecfg, slots=8, seg=SEG, ledger=led_b)
    refin = Refinery(m_b, led_b, RefineryConfig(ref_K=64, seed=0),
                     ecfg=ecfg, shadow_xs=shadow)
    rng = np.random.RandomState(0)
    import jax
    refin.candidate = jax.tree_util.tree_map(
        lambda l: l + 100.0 * rng.standard_normal(l.shape).astype(l.dtype),
        refin.candidate)
    state = {"tick": 0, "verdict": None}

    def on_tick(s):
        state["tick"] += 1
        if state["tick"] == 5:
            state["verdict"] = refin.maybe_promote([s])

    rep_b = replay_scheduler(sched_b, trace, on_tick=on_tick)

    v = state["verdict"] or {}
    rejected = bool(v and not v.get("promoted", True))
    parity = records_bitwise_equal(rep_a, rep_b)
    rows = [{"bench": "refinery", "section": "shadow_gate",
             "submitted": n, "gate_fired": bool(v),
             "candidate_rejected": rejected, "parity": bool(parity),
             "candidate_agreement":
                 (v.get("candidate") or {}).get("agreement"),
             "current_agreement":
                 (v.get("current") or {}).get("agreement")}]
    return rows, bool(rejected and parity)


def main(budget: str = "small", out_path: str = OUT_PATH):
    ref_rows, beats, equal_nfe = refinement_rows(budget)
    cap_rows, cap_ok = capture_parity_rows(budget)
    gate_rows, gate_ok = shadow_gate_rows(budget)
    rows = ref_rows + cap_rows + gate_rows
    rows.append({
        "bench": "refinery", "mode": "verdict",
        "refined_beats_frozen": bool(beats),
        "equal_nfe": bool(equal_nfe),
        "capture_parity": bool(cap_ok),
        "shadow_gate_clean": bool(gate_ok),
    })
    with open(out_path, "w") as fh:
        json.dump(rows, fh, indent=1, default=str)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", default="small",
                    choices=["tiny", "small", "full"])
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    for r in main(args.budget, args.out):
        print(r)
