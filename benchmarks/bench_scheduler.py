"""In-flight scheduler vs drain-the-queue engine, identical arrival traces.

    PYTHONPATH=src python benchmarks/bench_scheduler.py [--budget small]

The serving-latency head-to-head the ROADMAP's async-serving item calls
for: seeded Poisson and bursty arrival traces over a heterogeneous
difficulty mix replay through BOTH loops (launch/workload.py drivers) on
the same virtual clock (sequential vector-field evaluations):

  * engine   — ``MultiRateEngine``: drain everything queued, probe, pack
    by bucket, solve each batch to completion (launch/engine.py);
  * inflight — ``InflightScheduler``: slot pool over the resumable
    segment solve; finished slots retire and refill between segments
    (launch/scheduler.py).

Both use the SAME controller, buckets, and solver, so every request gets
the same K and numerically matching outputs — agreement against the
fine-mesh reference is equal BY CONSTRUCTION (asserted per trace), and
the comparison isolates scheduling: queue wait, p50/p99 latency,
throughput, slot occupancy, masked-step waste.

A second section (``sharded_rows``, run under 4 forced host devices in a
subprocess) pits the single-device slot pool against the slot-axis-
sharded pool (``InflightScheduler(mesh=)``) on hot Poisson traces past
one pool's capacity: same policy, same agreement, n-fold the slots at
the same sequential cost per segment.

A third section (``oracle_rows``) replays the same workload on the
roofline cost oracle (``launch/oracle.py``): completions priced in
predicted device-us of a qwen3_8b decode cell instead of sequential
field evals, plus the scheduler-knob autotune verdicts
(``launch/autotune.py``, persisted to ``artifacts/tuned/``). Every row
carries ``cost_unit`` so the two clocks are never cross-compared.

The JSON written to BENCH_scheduler.json carries one row per
(loop, trace, config) plus a ``verdict`` row: ``inflight_wins_p99`` is
True when the scheduler beats the engine's p99 latency at equal agreement
on at least one seeded Poisson trace, and ``sharded_pool_ok`` is True
when the multi-device pool holds throughput at-or-above the single-device
pool at equal agreement on every hot trace — the tracked serving
scoreboards (benchmarks/run.py --check enforces both).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
if __name__ == "__main__":  # runnable as a script from anywhere
    sys.path.insert(0, REPO_ROOT)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FixedGrid, Integrator
from repro.launch.engine import DepthModel, EngineConfig, MultiRateEngine
from repro.launch.scheduler import InflightScheduler
from repro.launch.workload import (
    bursty_trace, heterogeneous_requests, latency_stats, poisson_trace,
    replay_engine, replay_scheduler, toy_classifier,
)

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_scheduler.json")

D_FEAT = 32          # toy_classifier's input width (launch/workload.py)
N_CLASSES = 10


def reference_argmax(model: DepthModel, xs: np.ndarray) -> np.ndarray:
    """Fine-mesh ground truth (K=64 base-tableau solve, no correction)."""
    integ = Integrator(tableau=model.integ.tableau)
    x = jnp.asarray(xs)
    zT = integ.solve(model.field_of(x), model.embed(x),
                     FixedGrid.over(0.0, 1.0, 64), return_traj=False)
    return np.asarray(jnp.argmax(model.readout(x, zT), -1))


def _agreement(records, ref_top) -> float:
    by_uid = sorted(records, key=lambda r: r.uid)  # uid == arrival order
    top = np.asarray([np.argmax(r.outputs, -1) for r in by_uid])
    return float(np.mean(top == ref_top))


def run_trace(trace, xs, ecfg, solver, slots, seg, workload):
    """One trace through both loops; returns the two stat rows."""
    ref_top = reference_argmax(toy_classifier(solver), xs)

    eng = MultiRateEngine(toy_classifier(solver), ecfg)
    rep_e = replay_engine(eng, trace)
    row_e = latency_stats(rep_e)
    row_e.update(bench="scheduler", mode="engine", trace=workload,
                 solver=solver, max_batch=ecfg.max_batch,
                 agreement=round(_agreement(rep_e.records, ref_top), 4))

    sched = InflightScheduler(toy_classifier(solver), ecfg, slots=slots,
                              seg=seg)
    rep_s = replay_scheduler(sched, trace)
    row_s = latency_stats(rep_s)   # occupancy rides in the shared summary
    row_s.update(bench="scheduler", mode="inflight", trace=workload,
                 solver=solver, slots=slots, seg=seg, devices=1,
                 agreement=round(_agreement(rep_s.records, ref_top), 4))

    # equal-K, numerically matching outputs: agreement must tie exactly
    assert row_e["agreement"] == row_s["agreement"], (row_e, row_s)
    return row_e, row_s


# ------------------------------------------------- multi-device section ----

def sharded_rows(budget: str = "small", n_devices: int = 4):
    """Single- vs multi-device slot pool on identical hot Poisson traces.

    Requires ``n_devices`` visible jax devices — ``main()`` runs this in a
    subprocess with a forced host device count (the same pattern as the
    debug-mesh tests), never in the importing process. The comparison the
    ROADMAP's slot-sharding item calls for: a pool capped at what one chip
    holds (``slots_per_dev``) vs the sharded pool holding
    ``slots_per_dev * n_devices`` rows at the SAME sequential cost per
    segment (the slot axis is the hardware-parallel one) — under load the
    single pool queues and the sharded pool keeps admitting."""
    import jax

    from repro.launch.mesh import make_serving_mesh

    assert jax.device_count() >= n_devices, (
        f"sharded_rows needs {n_devices} devices, found "
        f"{jax.device_count()} — run under "
        "XLA_FLAGS=--xla_force_host_platform_device_count=4")
    n = {"tiny": 24, "small": 64, "full": 192}.get(budget, 64)
    mesh = make_serving_mesh(n_devices)
    ecfg = EngineConfig(buckets=(2, 4, 8, 16), tol=5e-3, max_batch=8,
                        solver="euler", fused=True)
    slots_per_dev, seg = 8, 2
    pairs = []
    # hot Poisson traces: rate chosen past the single pool's capacity
    # (~slots_per_dev * seg steps per stages*seg cost, discounted by
    # probes + masked waste) so queueing separates the two pools
    for seed in (3, 11):
        xs = heterogeneous_requests(n, D_FEAT, seed=seed)
        trace = poisson_trace(xs, rate=1.5, seed=seed + 100)
        ref_top = reference_argmax(toy_classifier("euler"), xs)
        pair = []
        for devices, m in ((1, None), (n_devices, mesh)):
            sched = InflightScheduler(
                toy_classifier("euler"), ecfg,
                slots=slots_per_dev * devices, seg=seg, mesh=m)
            rep = replay_scheduler(sched, trace)
            row = latency_stats(rep)
            row.update(bench="scheduler", mode="inflight",
                       trace=f"poisson_hot_seed{seed}", solver="euler",
                       slots=slots_per_dev * devices, seg=seg,
                       devices=devices,
                       agreement=round(_agreement(rep.records, ref_top),
                                       4))
            pair.append(row)
        # same controller + buckets through both pools: equal agreement
        # is BY CONSTRUCTION, so the comparison isolates pool capacity
        assert pair[0]["agreement"] == pair[1]["agreement"], pair
        pairs.append(pair)
    return pairs   # explicit (single, sharded) pairs — never re-zipped


# ------------------------------------------------ roofline-oracle section ----

def oracle_rows(budget: str = "small"):
    """The roofline-oracle clock section: the same toy workload replayed
    through BOTH loops with completions stamped in predicted device-us
    (``launch/oracle.py::RooflineOracle`` pricing a qwen3_8b decode
    cell), plus the per-cell scheduler-knob autotune verdicts
    (``launch/autotune.py``) whose chosen configs persist to
    ``artifacts/tuned/`` — ``benchmarks/run.py --check`` fails if those
    files drift from the verdict rows here."""
    from repro.configs import get
    from repro.launch.autotune import TUNE_CELLS, autotune_cell, save_tuned
    from repro.launch.oracle import RooflineOracle

    n = {"tiny": 16, "small": 48, "full": 128}.get(budget, 48)
    solver = "euler"
    ecfg = EngineConfig(buckets=(2, 4, 8, 16), tol=5e-3, max_batch=8,
                        solver=solver, fused=True)
    slots, seg = 8, 2
    oracle = RooflineOracle(get("qwen3_8b"), ctx=4096)
    # same relative load as the sequential poisson_seed3 trace: the rate
    # converts from per-field-eval to per-device-us via the pool's step
    # price, so the two sections stress the loops identically and only
    # the clock differs
    rate = 0.25 / oracle.step_time(slots)
    xs = heterogeneous_requests(n, D_FEAT, seed=3)
    trace = poisson_trace(xs, rate=rate, seed=103)
    ref_top = reference_argmax(toy_classifier(solver), xs)

    eng = MultiRateEngine(toy_classifier(solver), ecfg, oracle=oracle)
    rep_e = replay_engine(eng, trace)
    row_e = latency_stats(rep_e)
    row_e.update(bench="scheduler", mode="engine", trace="poisson_oracle",
                 clock="roofline", solver=solver, max_batch=ecfg.max_batch,
                 agreement=round(_agreement(rep_e.records, ref_top), 4))

    sched = InflightScheduler(toy_classifier(solver), ecfg, slots=slots,
                              seg=seg, oracle=oracle)
    rep_s = replay_scheduler(sched, trace)
    row_s = latency_stats(rep_s)
    row_s.update(bench="scheduler", mode="inflight", trace="poisson_oracle",
                 clock="roofline", solver=solver, slots=slots, seg=seg,
                 devices=1,
                 agreement=round(_agreement(rep_s.records, ref_top), 4))
    assert row_e["agreement"] == row_s["agreement"], (row_e, row_s)

    # knob autotune per serving cell; the full hillclimb log lives in
    # artifacts/tuned/<cell>.json, the BENCH row carries the verdict
    tuner_rows = []
    for spec in TUNE_CELLS:
        res = autotune_cell(spec, budget=budget)
        save_tuned(res, os.path.join(REPO_ROOT, "artifacts", "tuned"))
        tuner_rows.append({k: v for k, v in res.items() if k != "log"})
    return [row_e, row_s] + tuner_rows


def _start_sharded_section(budget: str):
    """Launch ``sharded_rows`` under a forced 4-device CPU host in a
    subprocess (jax device topology is frozen at first init, so the
    importing process cannot grow devices itself). Started BEFORE the
    in-process trace loop — the two share nothing — and joined by
    ``_join_sharded_section``."""
    script = (
        "import os, json, sys\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=4'\n"
        f"sys.path.insert(0, {REPO_ROOT!r})\n"
        "from benchmarks.bench_scheduler import sharded_rows\n"
        f"print('SHARDED_ROWS=' + json.dumps(sharded_rows({budget!r}), "
        "default=str))\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.Popen([sys.executable, "-c", script], env=env,
                            stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True,
                            cwd=REPO_ROOT)


def _join_sharded_section(proc):
    stdout, stderr = proc.communicate(timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError("sharded bench subprocess failed:\n"
                           + (stdout + stderr)[-4000:])
    line = [l for l in stdout.splitlines()
            if l.startswith("SHARDED_ROWS=")][-1]
    return json.loads(line[len("SHARDED_ROWS="):])


def main(budget: str = "small", out_path: str = OUT_PATH):
    # the multi-device section (4 forced host devices, subprocess) shares
    # nothing with the in-process loops — overlap it with them
    sh_proc = _start_sharded_section(budget)
    n = {"tiny": 32, "small": 96, "full": 256}.get(budget, 96)
    solver = "euler"
    ecfg = EngineConfig(buckets=(2, 4, 8, 16), tol=5e-3, max_batch=8,
                        solver=solver, fused=True)
    slots, seg = 8, 2

    pairs = []
    # Poisson at moderate load (the regime where drain latency compounds):
    # rate is in requests per sequential field eval; mean service ~9 steps
    # over 8 parallel slots puts capacity near 0.45 req/unit.
    for seed in (3, 11):
        xs = heterogeneous_requests(n, D_FEAT, seed=seed)
        trace = poisson_trace(xs, rate=0.25, seed=seed + 100)
        pairs.append(run_trace(trace, xs, ecfg, solver, slots, seg,
                               f"poisson_seed{seed}"))

    # bursty arrivals: bursts of 2x the slot pool, spaced one mean
    # service-time apart — the drain loop's worst case
    xs = heterogeneous_requests(n, D_FEAT, seed=5)
    trace = bursty_trace(xs, burst=16, gap=60.0, seed=7)
    pairs.append(run_trace(trace, xs, ecfg, solver, slots, seg, "bursty"))

    # hypersolver serving config: residual controller (free probe) through
    # both loops — the paper's correction survives in-flight batching
    hyper_ecfg = EngineConfig(buckets=(2, 4, 8, 16), tol=5e-3, max_batch=8,
                              solver="hyper_euler", fused=True)
    xs = heterogeneous_requests(n, D_FEAT, seed=9)
    trace = poisson_trace(xs, rate=0.25, seed=13)
    pairs.append(run_trace(trace, xs, hyper_ecfg, "hyper_euler", slots,
                           seg, "poisson_hyper"))

    # roofline-oracle clock section + scheduler-knob autotune verdicts
    o_rows = oracle_rows(budget)

    # multi-device slot pool vs one chip, identical hot traces (4 forced
    # host devices in a subprocess — see sharded_rows)
    sh_pairs = _join_sharded_section(sh_proc)

    # verdict: does in-flight beat drain p99 at equal agreement on some
    # seeded Poisson trace? (explicit pairs — no positional row coupling)
    wins = []
    for row_e, row_s in pairs:
        if not row_s["trace"].startswith("poisson"):
            continue
        if (row_s["agreement"] >= row_e["agreement"]
                and row_s["p99_latency"] < row_e["p99_latency"]):
            wins.append({
                "trace": row_s["trace"], "solver": row_s["solver"],
                "p99_engine": row_e["p99_latency"],
                "p99_inflight": row_s["p99_latency"],
                "agreement": row_s["agreement"],
            })
    # sharded verdict: the multi-device pool must keep throughput at or
    # above the single-device pool at equal agreement on EVERY hot trace
    sh_wins, sh_ok = [], True
    for single, multi in sh_pairs:
        ok = (multi["agreement"] >= single["agreement"]
              and multi["throughput"] >= single["throughput"])
        sh_ok = sh_ok and ok
        sh_wins.append({
            "trace": multi["trace"], "devices": multi["devices"],
            "throughput_single": single["throughput"],
            "throughput_sharded": multi["throughput"],
            "p99_single": single["p99_latency"],
            "p99_sharded": multi["p99_latency"],
            "agreement": multi["agreement"], "ok": ok,
        })
    rows = [r for pair in pairs for r in pair] \
        + o_rows \
        + [r for pair in sh_pairs for r in pair]
    rows.append({
        "bench": "scheduler", "mode": "verdict",
        "inflight_wins_p99": bool(wins), "witnesses": wins[:4],
        "sharded_pool_ok": bool(sh_ok), "sharded_witnesses": sh_wins[:4],
    })
    with open(out_path, "w") as fh:
        json.dump(rows, fh, indent=1, default=str)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", default="small",
                    choices=["tiny", "small", "full"])
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    for r in main(args.budget, args.out):
        print(r)
