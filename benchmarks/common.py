"""Shared experiment substrate for the paper benchmarks: train the image
Neural ODE + HyperEuler once, cache to artifacts/, expose solver sweeps.

Data substitution (offline container): synthetic class-conditional images
(data/synthetic.py) stand in for MNIST/CIFAR; solver pareto metrics are
measured against dopri5 trajectories of the SAME trained model, so the
comparison semantics match the paper exactly (DESIGN.md §7).
"""
from __future__ import annotations

import os
import time
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import FixedGrid, get_tableau
from repro.core.train import HypersolverTrainConfig, train_hypersolver
from repro.data import synthetic_images
from repro.models.conv_node import (
    init_mnist_hyper, mnist_g_apply, mnist_node,
)
from repro.optim import adamw, apply_updates, clip_by_global_norm

CACHE = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                     "bench_cache")


def train_image_node(steps: int = 60, batch: int = 8, seed: int = 0):
    """Train the MNIST-family conv Neural ODE on synthetic images with an
    RK4/K=8 forward (ground-truth-quality fixed solver; paper uses
    dopri5; budget scaled for the CPU container — DESIGN.md §7)."""
    cm = CheckpointManager(os.path.join(CACHE, "mnist_node"), keep=1)
    node, params = mnist_node(jax.random.PRNGKey(seed))
    latest = cm.latest_step()
    if latest is not None and latest >= steps:
        params = cm.restore(latest, jax.eval_shape(lambda: params))
        return node, params
    xs, ys = synthetic_images("mnist28", 256, seed=1)
    opt = adamw(2e-3)
    st = opt.init(params)
    rk4 = get_tableau("rk4")

    def loss_fn(p, xb, yb):
        logits = node.forward_fixed(p, xb, rk4, 8)
        lp = jax.nn.log_softmax(logits)
        return -jnp.mean(lp[jnp.arange(xb.shape[0]), yb])

    @jax.jit
    def step(p, st, i, xb, yb):
        l, g = jax.value_and_grad(loss_fn)(p, xb, yb)
        g, _ = clip_by_global_norm(g, 1.0)
        u, st = opt.update(g, st, p, i)
        return apply_updates(p, u), st, l

    key = jax.random.PRNGKey(2)
    for i in range(steps):
        key, sub = jax.random.split(key)
        idx = jax.random.randint(sub, (batch,), 0, xs.shape[0])
        params, st, l = step(params, st, i, xs[idx], ys[idx])
    cm.save(steps, params)
    return node, params


def fit_image_hypersolver(node, params, base: str = "euler", K: int = 10,
                          iters: int = 120, seed: int = 3):
    tag = f"mnist_hyper_{base}_K{K}"
    cm = CheckpointManager(os.path.join(CACHE, tag), keep=1)
    gp = init_mnist_hyper(jax.random.PRNGKey(seed))
    latest = cm.latest_step()
    if latest is not None and latest >= iters:
        return cm.restore(latest, jax.eval_shape(lambda: gp))
    xs, _ = synthetic_images("mnist28", 256, seed=4)

    def batches():
        key = jax.random.PRNGKey(5)
        while True:
            key, sub = jax.random.split(key)
            idx = jax.random.randint(sub, (16,), 0, xs.shape[0])
            yield xs[idx]

    cfg = HypersolverTrainConfig(
        base_solver=base, K=K, iters=iters, pretrain_iters=10, swap_every=20,
        lr=1e-2, lr_min=5e-4, weight_decay=1e-6, atol=1e-4, rtol=1e-4,
    )
    gp, losses = train_hypersolver(node, params, mnist_g_apply, gp,
                                   batches(), cfg)
    cm.save(iters, gp)
    return gp


_REF_CACHE: Dict = {}


def reference_state(node, params, x, tol: float = 1e-5):
    """Tight-dopri5 terminal state, cached per input buffer (the reference
    is by far the most expensive part of a solver sweep on one core)."""
    key = (id(node), x.shape, float(jnp.sum(x)))
    if key not in _REF_CACHE:
        ref, _, _ = node.reference_trajectory(params, x, K=1, atol=tol,
                                              rtol=tol)
        _REF_CACHE[key] = jax.block_until_ready(ref[-1])
    return _REF_CACHE[key]


def eval_solver(node, params, solver_name: str, K: int, x, gp=None,
                alpha_tab=None):
    """Returns dict(mape, nfe, zT) vs a (cached) tight-dopri5 reference."""
    z_ref = reference_state(node, params, x)
    grid = FixedGrid.over(0.0, 1.0, K)
    f = node.field(params, x)
    z0 = node.hx_apply(params, x)
    from repro.models.conv_node import mnist_integrator
    if solver_name.startswith("hyper_"):
        base = solver_name.split("_", 1)[1]
        integ = mnist_integrator(gp, x, base=alpha_tab or base)
    else:
        integ = mnist_integrator(base=alpha_tab or get_tableau(solver_name))
    zT = integ.solve(f, z0, grid, return_traj=False)
    nfe = integ.nfe(K)
    mape = float(jnp.mean(jnp.abs(zT - z_ref)
                          / (jnp.abs(z_ref) + 1e-3))) * 100
    return {"mape": mape, "nfe": nfe, "zT": zT, "z_ref": z_ref}


def accuracy_drop(node, params, zT, z_ref):
    """Task metric: disagreement with the dopri5-quality prediction (%)."""
    logit_a = node.hy_apply(params, zT)
    logit_r = node.hy_apply(params, z_ref)
    agree = float(jnp.mean(jnp.argmax(logit_a, -1) == jnp.argmax(logit_r, -1)))
    return (1.0 - agree) * 100


def timed(fn, *args, repeats: int = 3):
    fn(*args)  # compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return min(ts), out


def _noop():  # keep module import side-effect free
    pass
