"""Chaos harness for the hardened serving loops (PR 8).

    PYTHONPATH=src python benchmarks/bench_faults.py [--budget small]

Replays seeded arrival traces through BOTH serving loops — the drain
``MultiRateEngine`` and the in-flight ``InflightScheduler`` (sync and
``overlap=True``) — under seeded fault injection
(``distributed/fault.py::FaultInjector``) and overload, and writes
BENCH_faults.json with one row per (loop, fault mix):

  * **zero-hang** — every submitted uid reaches a terminal record,
    exactly once, under every mix (the hard liveness contract);
  * **status accounting** — the terminal-status histogram
    (``ok | retried | diverged | deadline | shed``) sums to the
    submitted count;
  * **completion rate + p99** — p99 latency computed over the requests
    that produced real outputs (``ok``/``retried``), never flattered by
    shed or evicted entries;
  * **fault-free parity** — a DISARMED injector (all rates zero) leaves
    both loops bitwise identical to running with no injector at all
    (uid-for-uid: outputs, nfe, clock stamps, status), sync and overlap,
    single-device and 4-device-mesh (subprocess, forced host devices) —
    the fault path costs nothing when nothing is injected.

Fault mixes: transient NaN poisoning (exercises the bounded retry
ladder -> ``retried``), persistent NaN (``diverged`` best-effort),
dropped retire flags (lost completion signals; re-drawn per segment so
p < 1 still terminates), virtual stragglers + per-request deadlines
(``deadline`` evictions), and queue overload under each policy
(``shed`` / ``degrade`` / ``block``).

The verdict row is the tracked scoreboard: ``zero_hang_all``,
``fault_free_parity``, ``status_accounting_ok``, ``overlap_parity_all``
(sync and overlap see identical fault schedules — every decision hashes
(seed, site, uid-or-tick), never call order). ``benchmarks/run.py
--check`` enforces all four.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
if __name__ == "__main__":  # runnable as a script from anywhere
    sys.path.insert(0, REPO_ROOT)

import numpy as np

from repro.distributed.fault import FaultInjector
from repro.launch.engine import EngineConfig, MultiRateEngine
from repro.launch.scheduler import InflightScheduler
from repro.launch.workload import (
    bursty_trace, heterogeneous_requests, latency_stats, ok_records,
    poisson_trace, replay_engine, replay_scheduler, status_counts,
    toy_classifier,
)

OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_faults.json")

D_FEAT = 32
SLOTS, SEG = 8, 2


def _ecfg():
    return EngineConfig(buckets=(2, 4, 8, 16), tol=5e-3, max_batch=8,
                        solver="euler", fused=True)


def _sched(inj=None, overlap=False, mesh=None, **hard):
    return InflightScheduler(toy_classifier("euler"), _ecfg(), slots=SLOTS,
                             seg=SEG, overlap=overlap, mesh=mesh,
                             fault_injector=inj, **hard)


def _engine(inj=None, **hard):
    return MultiRateEngine(toy_classifier("euler"), _ecfg(),
                           fault_injector=inj, **hard)


def records_bitwise_equal(rep_a, rep_b) -> bool:
    """uid-for-uid bitwise comparison of two replays: outputs, nfe, K,
    clock stamps, status. NaN outputs compare equal positionally (a
    diverged best-effort readout must still be deterministic)."""
    a = {r.uid: r for r in rep_a.records}
    b = {r.uid: r for r in rep_b.records}
    if set(a) != set(b):
        return False
    for u, ra in a.items():
        rb = b[u]
        if (ra.t_submit, ra.t_admit, ra.t_done, ra.K, ra.nfe,
                ra.status) != (rb.t_submit, rb.t_admit, rb.t_done, rb.K,
                               rb.nfe, rb.status):
            return False
        if (ra.outputs is None) != (rb.outputs is None):
            return False
        if ra.outputs is not None and not np.array_equal(
                ra.outputs, rb.outputs, equal_nan=True):
            return False
    return True


def fault_row(rep, n_submitted: int, mode: str, mix: str,
              devices: int = 1) -> dict:
    """One (loop, mix) accounting row: latency stats over the served
    requests + the liveness/status ledgers over ALL of them."""
    uids = [r.uid for r in rep.records]
    counts = status_counts(rep)
    row = latency_stats(ok_records(rep))
    row.update(
        bench="faults", mode=mode, mix=mix, devices=devices,
        submitted=n_submitted,
        zero_hang=bool(len(uids) == n_submitted
                       and len(set(uids)) == n_submitted),
        status_ok=bool(sum(counts.values()) == n_submitted),
        completion_rate=round(
            (counts["ok"] + counts["retried"]) / max(n_submitted, 1), 4),
        **{f"n_{k}": v for k, v in counts.items()})
    return row


# ----------------------------------------------------------- fault mixes ----

def mix_rows(budget: str = "small"):
    """Every fault mix through engine + in-flight sync + in-flight
    overlap. Returns (rows, overlap_parity_all): parity asserts the sync
    and overlap loops saw identical fault schedules AND resolved them to
    identical terminal records."""
    n = {"tiny": 24, "small": 48, "full": 128}.get(budget, 48)
    xs = heterogeneous_requests(n, D_FEAT, seed=3)
    base = poisson_trace(xs, rate=0.25, seed=103)
    dl_trace = poisson_trace(xs, rate=0.25, seed=103, deadline_slack=60.0)
    burst = bursty_trace(xs, burst=SLOTS * 3, gap=30.0, seed=7)

    mixes = [
        ("clean", base, FaultInjector(), {}),
        ("nan_transient", base,
         FaultInjector(seed=1, nan_uid_frac=0.25, nan_transient=True), {}),
        ("nan_persistent", base,
         FaultInjector(seed=1, nan_uid_frac=0.25, nan_transient=False), {}),
        ("drop_flags", base, FaultInjector(seed=2, drop_flag_p=0.5), {}),
        ("straggle_deadline", dl_trace,
         FaultInjector(seed=5, straggle_tick_frac=0.4, straggle_factor=8.0),
         {}),
        ("overload_shed", burst, None,
         {"queue_cap": SLOTS, "overload_policy": "shed"}),
        ("overload_degrade", burst, None,
         {"queue_cap": SLOTS, "overload_policy": "degrade"}),
        ("overload_block", burst, None,
         {"queue_cap": SLOTS, "overload_policy": "block"}),
    ]
    rows = []
    overlap_parity = True
    for mix, trace, inj, hard in mixes:
        rep_e = replay_engine(_engine(inj, **hard), trace)
        rows.append(fault_row(rep_e, n, "engine", mix))
        rep_s = replay_scheduler(_sched(inj, **hard), trace)
        rows.append(fault_row(rep_s, n, "inflight", mix))
        rep_o = replay_scheduler(_sched(inj, overlap=True, **hard), trace)
        rows.append(fault_row(rep_o, n, "inflight_overlap", mix))
        overlap_parity = overlap_parity \
            and records_bitwise_equal(rep_s, rep_o)
    return rows, overlap_parity


# ---------------------------------------------------- fault-free parity ----

def parity_rows(budget: str = "small", mesh=None, devices: int = 1):
    """ACCEPTANCE: on a fault-free trace, the hardened loops with a
    DISARMED injector are bitwise identical to the loops with no
    injector wired at all — uid for uid, both loop variants. Returns
    (rows, all_parity_ok)."""
    n = {"tiny": 24, "small": 48, "full": 128}.get(budget, 48)
    xs = heterogeneous_requests(n, D_FEAT, seed=9)
    trace = poisson_trace(xs, rate=0.25, seed=113)
    disarmed = FaultInjector()   # every rate zero: armed code, no faults

    checks = []
    rep_none = replay_scheduler(_sched(None, mesh=mesh), trace)
    rep_dis = replay_scheduler(_sched(disarmed, mesh=mesh), trace)
    checks.append(("inflight", records_bitwise_equal(rep_none, rep_dis)))
    rep_none_o = replay_scheduler(
        _sched(None, overlap=True, mesh=mesh), trace)
    rep_dis_o = replay_scheduler(
        _sched(disarmed, overlap=True, mesh=mesh), trace)
    checks.append(("inflight_overlap",
                   records_bitwise_equal(rep_none_o, rep_dis_o)))
    checks.append(("sync_vs_overlap",
                   records_bitwise_equal(rep_none, rep_none_o)))
    if mesh is None:
        rep_e_none = replay_engine(_engine(None), trace)
        rep_e_dis = replay_engine(_engine(disarmed), trace)
        checks.append(("engine",
                       records_bitwise_equal(rep_e_none, rep_e_dis)))
    rows = [{"bench": "faults", "mode": loop, "mix": "fault_free_parity",
             "devices": devices, "submitted": n, "parity": bool(ok)}
            for loop, ok in checks]
    return rows, all(ok for _, ok in checks)


# ------------------------------------------------- multi-device section ----

def sharded_chaos_rows(budget: str = "small", n_devices: int = 4):
    """The same contracts on the slot-axis-sharded pool: fault-free
    parity (disarmed == absent, sync == overlap) plus zero-hang under
    the NaN quarantine mix, with the pool sharded over ``n_devices``
    forced host devices. Run in a subprocess by ``main()`` — jax device
    topology is frozen at first init."""
    import jax

    from repro.launch.mesh import make_serving_mesh

    assert jax.device_count() >= n_devices, (
        f"sharded_chaos_rows needs {n_devices} devices, found "
        f"{jax.device_count()} — run under "
        "XLA_FLAGS=--xla_force_host_platform_device_count=4")
    mesh = make_serving_mesh(n_devices)
    rows, parity_ok = parity_rows(budget, mesh=mesh, devices=n_devices)

    n = {"tiny": 24, "small": 48, "full": 128}.get(budget, 48)
    xs = heterogeneous_requests(n, D_FEAT, seed=3)
    trace = poisson_trace(xs, rate=0.25, seed=103)
    inj = FaultInjector(seed=1, nan_uid_frac=0.25, nan_transient=True)
    rep_s = replay_scheduler(_sched(inj, mesh=mesh), trace)
    rows.append(fault_row(rep_s, n, "inflight", "nan_transient",
                          devices=n_devices))
    rep_o = replay_scheduler(_sched(inj, overlap=True, mesh=mesh), trace)
    rows.append(fault_row(rep_o, n, "inflight_overlap", "nan_transient",
                          devices=n_devices))
    parity_ok = parity_ok and records_bitwise_equal(rep_s, rep_o)
    return rows, parity_ok


def _start_sharded_section(budget: str):
    script = (
        "import os, json, sys\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=4'\n"
        f"sys.path.insert(0, {REPO_ROOT!r})\n"
        "from benchmarks.bench_faults import sharded_chaos_rows\n"
        f"rows, ok = sharded_chaos_rows({budget!r})\n"
        "print('SHARDED_FAULTS=' + json.dumps([rows, ok], default=str))\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.Popen([sys.executable, "-c", script], env=env,
                            stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True,
                            cwd=REPO_ROOT)


def _join_sharded_section(proc):
    stdout, stderr = proc.communicate(timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError("sharded chaos subprocess failed:\n"
                           + (stdout + stderr)[-4000:])
    line = [l for l in stdout.splitlines()
            if l.startswith("SHARDED_FAULTS=")][-1]
    rows, ok = json.loads(line[len("SHARDED_FAULTS="):])
    return rows, ok


def main(budget: str = "small", out_path: str = OUT_PATH):
    sh_proc = _start_sharded_section(budget)
    p_rows, parity_ok = parity_rows(budget)
    m_rows, overlap_parity = mix_rows(budget)
    sh_rows, sh_parity = _join_sharded_section(sh_proc)

    rows = p_rows + m_rows + sh_rows
    fault_rows = [r for r in rows if "zero_hang" in r]
    rows.append({
        "bench": "faults", "mode": "verdict",
        "zero_hang_all": all(r["zero_hang"] for r in fault_rows),
        "status_accounting_ok": all(r["status_ok"] for r in fault_rows),
        "fault_free_parity": bool(parity_ok and sh_parity),
        "overlap_parity_all": bool(overlap_parity and sh_parity),
        "mixes": sorted({r["mix"] for r in fault_rows}),
    })
    with open(out_path, "w") as fh:
        json.dump(rows, fh, indent=1, default=str)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", default="small",
                    choices=["tiny", "small", "full"])
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    for r in main(args.budget, args.out):
        print(r)
