"""Multi-rate serving pareto: error-controlled per-request step sizes
(launch/engine.py) vs fixed-K serving, on the NFE-vs-agreement axis.

    PYTHONPATH=src python benchmarks/bench_serve.py --solver hyper_euler

Workloads:
  * node — the paper's MNIST-family Neural ODE (trained + HyperEuler
    fitted once, cached in artifacts/ by benchmarks/common.py) serving a
    heterogeneous request mix: nominal synthetic images plus a stiff slice
    (higher contrast -> genuinely harder dynamics). Reference = dopri5 at
    tight tolerances, the paper's ground-truth semantics.
  * lm — the continuous-depth LM (models/cdepth.py): same engine, same
    accounting, reference = dopri5 solve of the depth ODE.

Quality metrics per request, against the reference prediction:
  * argmax_agreement — predicted class/token match;
  * soft_agreement   — softmax overlap sum_c min(p_c, p_ref_c)
    (= 1 - total variation; smooth in integration error, so the pareto is
    visible even where argmax saturates).

The fixed-K baseline runs through the SAME engine (FixedController), so
the comparison isolates the policy, not the plumbing. The JSON written to
BENCH_serve.json includes a ``verdict`` row: multirate_wins is True when
some multi-rate point matches a fixed point's agreement at strictly fewer
mean NFEs (or beats it at equal NFEs) — the tracked pareto scoreboard.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

if __name__ == "__main__":  # runnable as a script from anywhere
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CACHE, fit_image_hypersolver, train_image_node
from repro.core import FixedGrid, odeint_dopri5
from repro.data import synthetic_images
from repro.launch.engine import (
    EngineConfig, MultiRateEngine, lm_depth_model, node_depth_model,
)
from repro.models.conv_node import mnist_g_apply

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")


# ---------------------------------------------------------------- metrics ----

def _soft_agree(logits: np.ndarray, ref_p: np.ndarray) -> float:
    """Softmax overlap with the reference distribution, in [0, 1]."""
    p = np.asarray(jax.nn.softmax(jnp.asarray(logits), -1))
    return float(np.minimum(p, ref_p).sum(-1).mean())


def _evaluate(results, ref_p, ref_top):
    soft, top, nfes, Ks = [], [], [], []
    for i, r in enumerate(results):
        soft.append(_soft_agree(r.outputs, ref_p[i]))
        p_top = np.asarray(r.outputs).argmax(-1)
        top.append(float(np.mean(p_top == ref_top[i])))
        nfes.append(r.nfe)
        Ks.append(r.K)
    return {
        "mean_nfe": round(float(np.mean(nfes)), 3),
        "argmax_agreement": round(float(np.mean(top)), 4),
        "soft_agreement": round(float(np.mean(soft)), 4),
        "k_histogram": {int(k): int(c) for k, c in
                        zip(*np.unique(Ks, return_counts=True))},
    }


def pareto_verdict(fixed_rows, mr_rows):
    """Does some multi-rate point weakly dominate a fixed-K point?

    Win = same-or-better agreement at strictly fewer mean NFEs, or better
    agreement at the same NFEs, on either agreement metric."""
    wins = []
    for metric in ("argmax_agreement", "soft_agreement"):
        for m in mr_rows:
            for fx in fixed_rows:
                same_quality = m[metric] >= fx[metric] - 1e-9
                cheaper = m["mean_nfe"] < fx["mean_nfe"] - 1e-9
                better = (m[metric] > fx[metric] + 1e-9
                          and m["mean_nfe"] <= fx["mean_nfe"] + 1e-9)
                if (same_quality and cheaper) or better:
                    wins.append({
                        "metric": metric,
                        "multirate": {"tol": m["tol"],
                                      "mean_nfe": m["mean_nfe"],
                                      metric: m[metric]},
                        "fixed": {"K": fx["K"], "mean_nfe": fx["mean_nfe"],
                                  metric: fx[metric]},
                    })
    return {"multirate_wins": bool(wins), "witnesses": wins[:4]}


def _sweep(model, ref_p, ref_top, xs, buckets, tol_grid, max_batch,
           workload, solver):
    """Fixed-K and multi-rate tolerance sweeps through the same engine."""
    fixed_rows = []
    for K in buckets:
        eng = MultiRateEngine(model, EngineConfig(
            buckets=(K,), controller="fixed", fixed_K=K,
            max_batch=max_batch, solver=solver))
        row = _evaluate(eng.run(xs), ref_p, ref_top)
        row.update(bench="serve", workload=workload, solver=solver,
                   mode="fixed", K=K)
        fixed_rows.append(row)

    mr_rows = []
    for tol in tol_grid:
        eng = MultiRateEngine(model, EngineConfig(
            buckets=buckets, tol=float(tol), max_batch=max_batch,
            solver=solver))
        row = _evaluate(eng.run(xs), ref_p, ref_top)
        row.update(bench="serve", workload=workload, solver=solver,
                   mode="multirate", tol=round(float(tol), 4),
                   probe_nfe=eng.probe_nfe,
                   controller=type(eng.controller).__name__)
        mr_rows.append(row)

    verdict = pareto_verdict(fixed_rows, mr_rows)
    verdict.update(bench="serve", workload=workload, solver=solver,
                   mode="verdict")
    return fixed_rows + mr_rows + [verdict]


def _tol_grid(model, xs, buckets, max_batch):
    """Anchor the tolerance sweep on the measured probe-error scale, so the
    sweep lands on the interesting part of the pareto for any workload.
    Probe-only — no bucket solves are spent on calibration."""
    eng = MultiRateEngine(model, EngineConfig(buckets=buckets, tol=1.0,
                                              max_batch=max_batch))
    _, errs = eng.probe(xs)
    med = float(np.median(errs))
    return [med * f for f in (1.3, 1.1, 0.9, 0.7, 0.5, 0.35, 0.2)]


# -------------------------------------------------------------- workloads ----

def node_workload(budget: str, solver: str):
    """Heterogeneous image-classification traffic on the paper's MNIST-
    family Neural ODE: nominal requests plus a stiff (2.5x contrast)
    slice."""
    node, params = train_image_node()
    gp = None
    if solver.startswith("hyper_"):
        gp = fit_image_hypersolver(node, params,
                                   base=solver[len("hyper_"):], K=10)
    n_nom, n_stiff = (96, 32) if budget != "tiny" else (24, 8)
    xa, _ = synthetic_images("mnist28", n_nom, seed=42)
    xb, _ = synthetic_images("mnist28", n_stiff, seed=43)
    xs = np.concatenate([np.asarray(xa), 2.5 * np.asarray(xb)], axis=0)

    z0 = node.hx_apply(params, jnp.asarray(xs))
    f = node.field(params, jnp.asarray(xs))
    ref_traj, ref_nfe = odeint_dopri5(f, z0, FixedGrid.over(0.0, 1.0, 1),
                                      atol=1e-6, rtol=1e-6)
    ref_logits = node.hy_apply(params, ref_traj[-1])
    ref_p = np.asarray(jax.nn.softmax(ref_logits, -1))

    model = node_depth_model(node, params, solver=solver,
                             g_apply=mnist_g_apply if gp is not None else None,
                             g_params=gp)
    buckets = (1, 2, 3, 4, 6, 8)
    tols = _tol_grid(model, xs, buckets, 32)
    rows = _sweep(model, ref_p, ref_p.argmax(-1), xs, buckets, tols, 32,
                  "node", solver)
    for r in rows:
        r["reference_nfe"] = int(ref_nfe)
    return rows


def lm_workload(budget: str, solver: str):
    """The continuous-depth LM through the same engine: mixed prompt
    difficulty, reference = dopri5 solve of the depth ODE."""
    from benchmarks.bench_cdepth_lm import train_small_lm
    from repro.checkpoint import CheckpointManager
    from repro.data import token_batches
    from repro.models.cdepth import (
        apply_tail, cdepth_residual_loss, depth_field, lm_g_init,
    )
    from repro.models.lm import _embed, group_layout
    from repro.optim import adamw, apply_updates, clip_by_global_norm

    cfg, params = train_small_lm(150 if budget != "full" else 600)
    _, n_groups, _ = group_layout(cfg)

    g_params = None
    if solver.startswith("hyper_"):
        # one correction shared across serving buckets: mixed-K residual fit
        cm = CheckpointManager(os.path.join(CACHE, "lm_g_serve"), keep=1)
        g_params = lm_g_init(jax.random.PRNGKey(2), cfg, rank=32,
                             param_dtype=jnp.float32)
        iters = 150
        latest = cm.latest_step()
        if latest is not None and latest >= iters:
            g_params = cm.restore(latest, jax.eval_shape(lambda: g_params))
        else:
            opt = adamw(3e-3)
            st = opt.init(g_params)

            @jax.jit
            def fit(gp, st, i, batch):
                def loss(gg):
                    return sum(cdepth_residual_loss(params, gg, cfg, batch, K)
                               for K in (2, 4, 8)) / 3.0
                l, g = jax.value_and_grad(loss)(gp)
                g, _ = clip_by_global_norm(g, 1.0)
                u, st = opt.update(g, st, gp, i)
                return apply_updates(gp, u), st, l

            it = token_batches(cfg.vocab, 4, 32, seed=13)
            batch, _ = next(it)
            for i in range(iters):
                if i % 10 == 0:
                    batch, _ = next(it)
                g_params, st, _ = fit(g_params, st, i, batch)
            cm.save(iters, g_params)

    B, S = (16, 24) if budget != "tiny" else (6, 16)
    rng = np.random.RandomState(0)
    easy = np.repeat(rng.randint(0, cfg.vocab, (B // 2, 1)), S, axis=1)
    hard = rng.randint(0, cfg.vocab, (B - B // 2, S))
    toks = np.concatenate([easy, hard], axis=0).astype(np.int32)

    h0 = _embed(params, cfg, jnp.asarray(toks))
    f = depth_field(params, cfg)
    ref_traj, ref_nfe = odeint_dopri5(f, h0, FixedGrid.over(0.0, 1.0, 1),
                                      atol=1e-3, rtol=1e-3)
    ref_logits = apply_tail(params, cfg, ref_traj[-1])
    ref_p = np.asarray(jax.nn.softmax(ref_logits, -1))

    model = lm_depth_model(params, cfg, solver=solver, g_params=g_params)
    buckets = (2, 4, 8, 16)
    tols = _tol_grid(model, toks, buckets, 16)
    rows = _sweep(model, ref_p, ref_p.argmax(-1), toks, buckets, tols, 16,
                  "lm", solver)
    for r in rows:
        r["reference_nfe"] = int(ref_nfe)
        r["full_depth_groups"] = n_groups
    return rows


# ------------------------------------------------------------------- main ----

def main(budget: str = "small", solver: str = "hyper_euler",
         workload: str = "both", out_path: str = OUT_PATH):
    rows = []
    if workload in ("node", "both"):
        rows += node_workload(budget, solver)
    if workload in ("lm", "both"):
        rows += lm_workload(budget, solver)
    with open(out_path, "w") as fh:
        json.dump(rows, fh, indent=1, default=str)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", default="small",
                    choices=["tiny", "small", "full"])
    ap.add_argument("--solver", default="hyper_euler")
    ap.add_argument("--workload", default="both",
                    choices=["node", "lm", "both"])
    ap.add_argument("--out", default=OUT_PATH)
    args = ap.parse_args()
    for r in main(args.budget, args.solver, args.workload, args.out):
        print(r)
