"""Paper Fig. 1 + Fig. 7 (+ Sec. 4.2): lightweight density estimation.

Train FFJORD CNFs on the paper's 2-D densities (pinwheel / rings /
checkerboard / circles), then fit a second-order HyperHeun with K=1
residual (paper: 30k iters, tol 1e-5 dopri5 targets; scaled to container
budget) and sample with TWO NFEs. Metrics: per-sample displacement vs the
dopri5 trajectory endpoint from the same base draws, and histogram L1 to
the data distribution — quantifying the paper's visual result that
Hyper-Heun @ 2 NFE ~ dopri5 while plain Heun @ 2 NFE fails.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CACHE
from repro.checkpoint import CheckpointManager
from repro.core import FixedGrid, Integrator, get_tableau, odeint_dopri5
from repro.core.residual import residual_fitting_loss
from repro.data import density_sampler
from repro.nn.cnf import (
    cnf_log_prob, cnf_mlp_init, cnf_sample, exact_trace_dynamics,
)
from repro.nn.module import mlp_apply, mlp_init
from repro.optim import adamw, apply_updates, clip_by_global_norm


def train_cnf(density: str, iters: int = 400, batch: int = 128, seed=0):
    cm = CheckpointManager(os.path.join(CACHE, f"cnf_{density}"), keep=1)
    params = cnf_mlp_init(jax.random.PRNGKey(seed))
    latest = cm.latest_step()
    if latest is not None and latest >= iters:
        return cm.restore(latest, jax.eval_shape(lambda: params))
    opt = adamw(1e-3)          # paper C.3: Adam, lr 1e-3
    st = opt.init(params)
    sampler = density_sampler(density, batch, seed=seed + 1)

    def nll(p, x):
        return -jnp.mean(cnf_log_prob(p, x, K=8, solver="rk4"))

    @jax.jit
    def step(p, st, i, x):
        l, g = jax.value_and_grad(nll)(p, x)
        g, _ = clip_by_global_norm(g, 10.0)
        u, st = opt.update(g, st, p, i)
        return apply_updates(p, u), st, l

    for i in range(iters):
        params, st, loss = step(params, st, i, next(sampler))
    cm.save(iters, params)
    return params


def _g_init(key):
    # two-layer hypersolver net over [z, dz, dlogp, s] -> (dz_corr, dlogp_corr)
    return mlp_init(key, (2 + 2 + 1 + 1, 64, 3), final_zero=True)


def _g_apply(gp, eps, s, x, state, dstate):
    z, logp = state
    dz, dlogp = dstate
    s_col = jnp.broadcast_to(jnp.asarray(s, z.dtype), z[..., :1].shape)
    h = jnp.concatenate([z, dz, dlogp[..., None], s_col], axis=-1)
    out = mlp_apply(gp, h, act=jnp.tanh)
    return (out[..., :2], out[..., 2])


def fit_hyperheun(cnf_params, density: str, iters: int = 500, K: int = 1,
                  seed=7):
    cm = CheckpointManager(os.path.join(CACHE, f"cnf_hyper_{density}"),
                           keep=1)
    gp = _g_init(jax.random.PRNGKey(seed))
    latest = cm.latest_step()
    if latest is not None and latest >= iters:
        return cm.restore(latest, jax.eval_shape(lambda: gp))
    aug = exact_trace_dynamics(cnf_params)  # sampling direction base->data
    heun = get_tableau("heun")
    grid = FixedGrid.over(0.0, 1.0, K)
    opt = adamw(5e-3, weight_decay=1e-6)    # paper C.3: AdamW 5e-3, wd 1e-6
    st = opt.init(gp)

    @jax.jit
    def ref_traj(z0):
        state0 = (z0, jnp.zeros(z0.shape[0]))
        traj, _ = odeint_dopri5(aug, state0, grid, atol=1e-5, rtol=1e-5)
        return traj

    def loss_fn(g, traj):
        integ = Integrator(tableau=heun,
                           g=lambda e, s, z, dz: _g_apply(g, e, s, None, z, dz))
        return residual_fitting_loss(integ, aug, traj, grid)

    @jax.jit
    def fit(g, st, i, traj):
        l, grads = jax.value_and_grad(loss_fn)(g, traj)
        grads, _ = clip_by_global_norm(grads, 10.0)
        u, st = opt.update(grads, st, g, i)
        return apply_updates(g, u), st, l

    key = jax.random.PRNGKey(seed + 1)
    traj = None
    for i in range(iters):
        if i % 100 == 0 or traj is None:   # paper: swap every 100 iters
            key, sub = jax.random.split(key)
            traj = ref_traj(jax.random.normal(sub, (256, 2)))
        gp, st, l = fit(gp, st, i, traj)
    cm.save(iters, gp)
    return gp


def _hist_l1(a, b, bins=24, lo=-4.5, hi=4.5):
    ha, _, _ = np.histogram2d(a[:, 0], a[:, 1], bins=bins,
                              range=[[lo, hi], [lo, hi]], density=True)
    hb, _, _ = np.histogram2d(b[:, 0], b[:, 1], bins=bins,
                              range=[[lo, hi], [lo, hi]], density=True)
    return float(np.abs(ha - hb).mean())


def main(budget: str = "small"):
    iters = 400 if budget == "small" else 3000
    fit_iters = 300 if budget == "small" else 3000
    densities = ["pinwheel", "rings"] if budget == "small" else \
        ["pinwheel", "rings", "checkerboard", "circles"]
    rows = []
    for density in densities:
        p = train_cnf(density, iters=iters)
        gp = fit_hyperheun(p, density, iters=fit_iters)
        aug = exact_trace_dynamics(p)
        key = jax.random.PRNGKey(42)
        z0 = jax.random.normal(key, (1024, 2))
        state0 = (z0, jnp.zeros(z0.shape[0]))
        # dopri5 reference samples from the same base draws
        ref, nfe_ref = odeint_dopri5(aug, state0, FixedGrid.over(0, 1, 1),
                                     atol=1e-5, rtol=1e-5)
        x_ref = np.asarray(ref[0][-1])
        data = np.asarray(next(density_sampler(density, 1024, seed=77)))

        candidates = {
            "hyper_heun@2nfe": (Integrator(
                tableau=get_tableau("heun"),
                g=lambda e, s, z, dz: _g_apply(gp, e, s, None, z, dz)), 1),
            "heun@2nfe": (Integrator(tableau=get_tableau("heun")), 1),
            "euler@2nfe": (Integrator(tableau=get_tableau("euler")), 2),
        }
        for name, (integ, K) in candidates.items():
            zT = cnf_sample(p, z0, K=K, solver=integ)
            x = np.asarray(zT[0])
            rows.append({
                "bench": "cnf", "density": density, "method": name,
                "nfe": 2,
                "disp_vs_dopri5": round(float(np.mean(
                    np.linalg.norm(x - x_ref, axis=-1))), 4),
                "hist_l1_vs_data": round(_hist_l1(x, data), 4),
                "hist_l1_dopri5_vs_data": round(_hist_l1(x_ref, data), 4),
                "dopri5_nfe": int(nfe_ref),
            })
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
