"""RWKV-6 "Finch" time-mix (Peng et al., arXiv:2404.05892).

Data-dependent token-shift (low-rank) + data-dependent per-channel decay
w_t, with the per-head WKV recurrence

    S_t = diag(w_t) S_{t-1} + k_t^T v_t           (state: (hd_k, hd_v) per head)
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

Reference path: exact ``lax.scan`` over time (used for decode and as the
oracle for the chunked Pallas kernel in kernels/rwkv6_scan).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.nn.module import dense, dense_init, truncated_normal_init

MIXES = ("w", "k", "v", "r", "g")


def rwkv6_init(key, d_model: int, n_heads: int, lora_rank: int = 32,
               param_dtype=jnp.float32):
    d_head = d_model // n_heads
    ks = jax.random.split(key, 12)
    p = {
        "wr": dense_init(ks[0], d_model, d_model, param_dtype),
        "wk": dense_init(ks[1], d_model, d_model, param_dtype),
        "wv": dense_init(ks[2], d_model, d_model, param_dtype),
        "wg": dense_init(ks[3], d_model, d_model, param_dtype),
        "wo": dense_init(ks[4], d_model, d_model, param_dtype),
        # static token-shift interpolants
        "mu_x": jnp.full((d_model,), 0.5, param_dtype),
        "mu": truncated_normal_init(ks[5], (len(MIXES), d_model), 0.02,
                                    param_dtype),
        # low-rank data-dependent shift:  tanh(xx A1) A2 -> 5 mixes
        "lora_a1": truncated_normal_init(ks[6], (d_model, len(MIXES) * lora_rank),
                                         0.02, param_dtype),
        "lora_a2": truncated_normal_init(
            ks[7], (len(MIXES), lora_rank, d_model), 0.02, param_dtype),
        # decay: w = exp(-exp(w0 + tanh(xw W1) W2))
        "w0": jnp.linspace(-6.0, -1.0, d_model).astype(param_dtype),
        "w_lora1": truncated_normal_init(ks[8], (d_model, lora_rank), 0.02,
                                         param_dtype),
        "w_lora2": truncated_normal_init(ks[9], (lora_rank, d_model), 0.02,
                                         param_dtype),
        # per-channel bonus u (reshaped to heads)
        "u": truncated_normal_init(ks[10], (d_model,), 0.3, param_dtype),
        # per-head output group-norm
        "gn_scale": jnp.ones((n_heads, d_head), param_dtype),
        "gn_bias": jnp.zeros((n_heads, d_head), param_dtype),
    }
    return p


def _token_shift(x: jnp.ndarray, x_prev_last: jnp.ndarray) -> jnp.ndarray:
    """Shift sequence right by one; first position uses carry (B, d)."""
    return jnp.concatenate([x_prev_last[:, None, :], x[:, :-1, :]], axis=1)


def _mix_inputs(p, x: jnp.ndarray, x_shift: jnp.ndarray):
    xx = x_shift - x
    xxx = x + xx * p["mu_x"].astype(x.dtype)
    m = jnp.tanh(xxx @ p["lora_a1"].astype(x.dtype))  # (B,S,5r)
    B, S, _ = m.shape
    r = p["lora_a2"].shape[1]
    m = m.reshape(B, S, len(MIXES), r)
    delta = jnp.einsum("bsnr,nrd->nbsd", m, p["lora_a2"].astype(x.dtype))
    out = {}
    for i, name in enumerate(MIXES):
        mu = p["mu"][i].astype(x.dtype) + delta[i]
        out[name] = x + xx * mu
    return out


def _decay(p, xw: jnp.ndarray) -> jnp.ndarray:
    """Per-channel decay w_t in (0, 1): exp(-exp(w0 + lora(xw)))."""
    lo = jnp.tanh(xw @ p["w_lora1"].astype(xw.dtype)) @ p["w_lora2"].astype(xw.dtype)
    logw = p["w0"].astype(jnp.float32) + lo.astype(jnp.float32)
    return jnp.exp(-jnp.exp(logw))


def wkv6_scan_ref(r, k, v, w, u):
    """Exact recurrence. r,k,v,w: (B, T, H, hd); u: (H, hd).

    Returns o: (B, T, H, hd) and final state (B, H, hd, hd), fp32.
    """
    B, T, H, D = r.shape
    r, k, v, w = (t.astype(jnp.float32) for t in (r, k, v, w))
    u = u.astype(jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,D)
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,D,D)
        o = jnp.einsum("bhi,bhij->bhj", r_t, S + u[None, :, :, None] * kv)
        S_new = w_t[..., :, None] * S + kv
        return S_new, o

    S0 = jnp.zeros((B, H, D, D), jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    S_fin, o = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(o, 0, 1), S_fin


def _group_norm(p, o: jnp.ndarray, eps: float = 64e-5) -> jnp.ndarray:
    """Per-head layer norm over head dim (RWKV's GroupNorm(H))."""
    mean = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.var(o, axis=-1, keepdims=True)
    y = (o - mean) * jax.lax.rsqrt(var + eps)
    return y * p["gn_scale"].astype(o.dtype) + p["gn_bias"].astype(o.dtype)


def rwkv6_time_mix(p, x: jnp.ndarray, n_heads: int,
                   state: Any = None, wkv_fn=None):
    """Full-sequence time-mix. x: (B, S, d). state carries (x_last, S_wkv)
    for streaming; None = zeros. Returns (out, new_state)."""
    B, S, d = x.shape
    D = d // n_heads
    if state is None:
        x_last = jnp.zeros((B, d), x.dtype)
        S_wkv = jnp.zeros((B, n_heads, D, D), jnp.float32)
    else:
        x_last, S_wkv = state
    x_shift = _token_shift(x, x_last)
    mixed = _mix_inputs(p, x, x_shift)
    r = dense(p["wr"], mixed["r"]).reshape(B, S, n_heads, D)
    k = dense(p["wk"], mixed["k"]).reshape(B, S, n_heads, D)
    v = dense(p["wv"], mixed["v"]).reshape(B, S, n_heads, D)
    g = jax.nn.silu(dense(p["wg"], mixed["g"]))
    w = _decay(p, mixed["w"]).reshape(B, S, n_heads, D)
    u = p["u"].reshape(n_heads, D)

    if wkv_fn is None:
        o, S_new = _wkv_with_initial_state(r, k, v, w, u, S_wkv)
    else:
        o, S_new = wkv_fn(r, k, v, w, u, S_wkv)
    o = _group_norm(p, o.astype(x.dtype))
    o = (o.reshape(B, S, d) * g)
    out = dense(p["wo"], o)
    return out, (x[:, -1, :], S_new)


def _wkv_with_initial_state(r, k, v, w, u, S0):
    B, T, H, D = r.shape
    r, k, v, w = (t.astype(jnp.float32) for t in (r, k, v, w))
    u = u.astype(jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        o = jnp.einsum("bhi,bhij->bhj", r_t, S + u[None, :, :, None] * kv)
        S_new = w_t[..., :, None] * S + kv
        return S_new, o

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    S_fin, o = jax.lax.scan(step, S0.astype(jnp.float32), xs)
    return jnp.moveaxis(o, 0, 1), S_fin


def rwkv6_decode_step(p, x_t: jnp.ndarray, state, n_heads: int):
    """Single-token step. x_t: (B, d); state = (x_last, S_wkv)."""
    out, new_state = rwkv6_time_mix(p, x_t[:, None, :], n_heads, state=state)
    return out[:, 0, :], new_state
