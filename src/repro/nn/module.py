"""Primitive layers: dense, norms, embeddings, small MLPs.

Conventions:
  * params are nested dicts of jnp arrays; keys are stable names that the
    sharding rules in ``repro.distributed.sharding`` pattern-match on.
  * ``param_dtype`` controls storage; matmuls upcast accumulation via
    ``preferred_element_type=float32`` when inputs are low-precision.
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

Params = Any


def truncated_normal_init(key, shape, scale, dtype):
    """He/fan-in style truncated normal initializer."""
    x = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
    return (x * scale).astype(dtype)


def dense_init(key, in_dim: int, out_dim: int, param_dtype=jnp.float32,
               scale: float | None = None):
    scale = (in_dim ** -0.5) if scale is None else scale
    return {"kernel": truncated_normal_init(key, (in_dim, out_dim), scale,
                                            param_dtype)}


def dense(params, x: jnp.ndarray) -> jnp.ndarray:
    k = params["kernel"]
    return jnp.matmul(x, k.astype(x.dtype),
                      preferred_element_type=jnp.float32).astype(x.dtype)


def rmsnorm_init(dim: int, param_dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), param_dtype)}


def rmsnorm(params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(dim: int, param_dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), param_dtype),
            "bias": jnp.zeros((dim,), param_dtype)}


def layernorm(params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mean) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(x.dtype)


def embedding_init(key, vocab: int, dim: int, param_dtype=jnp.float32):
    return {"table": truncated_normal_init(key, (vocab, dim), 1.0, param_dtype)}


def embedding_lookup(params, ids: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.take(params["table"], ids, axis=0).astype(dtype)


def embedding_logits(params, x: jnp.ndarray) -> jnp.ndarray:
    """Tied-embedding readout: x @ table^T (fp32 accumulation)."""
    t = params["table"]
    return jnp.matmul(x, t.astype(x.dtype).T,
                      preferred_element_type=jnp.float32)


def mlp_init(key, dims: Sequence[int], param_dtype=jnp.float32,
             final_zero: bool = False):
    """Simple MLP used for hypersolver g_omega nets. ``final_zero`` zeroes
    the last layer so the correction starts at exactly g == 0."""
    keys = jax.random.split(key, len(dims) - 1)
    layers = []
    for i, k in enumerate(keys):
        p = dense_init(k, dims[i], dims[i + 1], param_dtype)
        if final_zero and i == len(keys) - 1:
            p = {"kernel": jnp.zeros_like(p["kernel"])}
        layers.append(p)
    return {"layers": layers}


def mlp_apply(params, x: jnp.ndarray,
              act: Callable = jax.nn.tanh) -> jnp.ndarray:
    layers = params["layers"]
    h = x
    for i, lp in enumerate(layers):
        h = dense(lp, h)
        if i < len(layers) - 1:
            h = act(h)
    return h
