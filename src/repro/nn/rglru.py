"""Real-Gated Linear Recurrent Unit + Griffin recurrent block
(De, Smith et al., arXiv:2402.19427 — RecurrentGemma backbone).

    r_t = sigmoid(x_t W_a + b_a)                 (recurrence gate)
    i_t = sigmoid(x_t W_x + b_x)                 (input gate)
    log a_t = -c * softplus(Lambda) * r_t        (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training path uses ``jax.lax.associative_scan`` (parallel prefix over the
elementwise linear recurrence — the TPU-native schedule); decode is a
single-step update. The Pallas kernel in kernels/rglru_scan implements the
chunked sequential sweep for long sequences.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.nn.module import dense, dense_init, truncated_normal_init

_C = 8.0


def rglru_init(key, width: int, param_dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    # Lambda init so a ~ U[0.9, 0.999] at r = 1 (paper App. A)
    u = jax.random.uniform(k3, (width,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log u / c)
    return {
        "wa": dense_init(k1, width, width, param_dtype),
        "ba": jnp.zeros((width,), param_dtype),
        "wx": dense_init(k2, width, width, param_dtype),
        "bx": jnp.zeros((width,), param_dtype),
        "lam": lam.astype(param_dtype),
    }


def _gates(p, x: jnp.ndarray):
    r = jax.nn.sigmoid(dense(p["wa"], x).astype(jnp.float32)
                       + p["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(dense(p["wx"], x).astype(jnp.float32)
                       + p["bx"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    # multiplier sqrt(1 - a^2) in stable form
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * (i * x.astype(jnp.float32))
    return a, b


def rglru_apply(p, x: jnp.ndarray, h0: Optional[jnp.ndarray] = None):
    """x: (B, T, width) -> (y, h_T). Parallel associative scan over T."""
    a, b = _gates(p, x)
    if h0 is not None:
        # fold the carry into the first step: b_0 += a_0 * h0
        b = b.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1, :]


def rglru_scan_ref(p, x: jnp.ndarray, h0: Optional[jnp.ndarray] = None):
    """Sequential oracle (lax.scan) for tests and decode parity."""
    a, b = _gates(p, x)
    B, T, W = x.shape
    h_init = jnp.zeros((B, W), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        a_t, b_t = inp
        h_new = a_t * h + b_t
        return h_new, h_new

    hT, hs = jax.lax.scan(step, h_init,
                          (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype), hT


def rglru_decode_step(p, x_t: jnp.ndarray, h: jnp.ndarray):
    """x_t: (B, width); h: (B, width) fp32 carry."""
    a, b = _gates(p, x_t[:, None, :])
    h_new = a[:, 0, :] * h + b[:, 0, :]
    return h_new.astype(x_t.dtype), h_new


# ---------------------------------------------------------------- conv1d ----

def causal_conv1d_init(key, width: int, kernel_size: int = 4,
                       param_dtype=jnp.float32):
    return {
        "w": truncated_normal_init(key, (kernel_size, width),
                                   kernel_size ** -0.5, param_dtype),
        "b": jnp.zeros((width,), param_dtype),
    }


def causal_conv1d(p, x: jnp.ndarray, carry: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x: (B, T, W); carry: (B, k-1, W) history.
    Returns (y, new_carry)."""
    k = p["w"].shape[0]
    B, T, W = x.shape
    if carry is None:
        carry = jnp.zeros((B, k - 1, W), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)  # (B, T+k-1, W)
    y = jnp.zeros((B, T, W), jnp.float32)
    for i in range(k):
        y = y + xp[:, i:i + T, :].astype(jnp.float32) * p["w"][i].astype(jnp.float32)
    y = y + p["b"].astype(jnp.float32)
    return y.astype(x.dtype), xp[:, -(k - 1):, :]


# ------------------------------------------------------- recurrent block ----

def griffin_recurrent_init(key, d_model: int, width: int,
                           param_dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    return {
        "in_rec": dense_init(ks[0], d_model, width, param_dtype),
        "in_gate": dense_init(ks[1], d_model, width, param_dtype),
        "conv": causal_conv1d_init(ks[2], width, 4, param_dtype),
        "rglru": rglru_init(ks[3], width, param_dtype),
        "out": dense_init(ks[4], width, d_model, param_dtype),
    }


def griffin_recurrent_apply(p, x: jnp.ndarray, state: Any = None,
                            use_assoc_scan: bool = True):
    """Griffin recurrent branch: [linear->conv->RG-LRU] * gelu(linear).
    state = (conv_carry, h) or None. Returns (y, new_state)."""
    if state is None:
        conv_carry, h0 = None, None
    else:
        conv_carry, h0 = state
    u = dense(p["in_rec"], x)
    g = jax.nn.gelu(dense(p["in_gate"], x), approximate=True)
    u, conv_carry = causal_conv1d(p["conv"], u, conv_carry)
    if use_assoc_scan:
        h, hT = rglru_apply(p["rglru"], u, h0)
    else:
        h, hT = rglru_scan_ref(p["rglru"], u, h0)
    y = dense(p["out"], h * g)
    return y, (conv_carry, hT)
