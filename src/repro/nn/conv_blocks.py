"""Convolutional building blocks for the paper's image-classification
Neural ODEs (App. C.2): DepthCat conv vector fields, conv HyperEuler nets,
channel Augmenter, PReLU, GroupNorm."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.nn.module import truncated_normal_init


def conv2d_init(key, in_ch: int, out_ch: int, ksize: int,
                param_dtype=jnp.float32):
    fan_in = in_ch * ksize * ksize
    return {
        "w": truncated_normal_init(key, (ksize, ksize, in_ch, out_ch),
                                   fan_in ** -0.5, param_dtype),
        "b": jnp.zeros((out_ch,), param_dtype),
    }


def conv2d(p, x: jnp.ndarray) -> jnp.ndarray:
    """NHWC 'SAME' conv."""
    y = jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype), window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.float32)
    return (y + p["b"].astype(jnp.float32)).astype(x.dtype)


def prelu_init(ch: int, param_dtype=jnp.float32):
    return {"alpha": jnp.full((ch,), 0.25, param_dtype)}


def prelu(p, x: jnp.ndarray) -> jnp.ndarray:
    a = p["alpha"].astype(x.dtype)
    return jnp.where(x >= 0, x, a * x)


def groupnorm_init(ch: int, param_dtype=jnp.float32):
    return {"scale": jnp.ones((ch,), param_dtype),
            "bias": jnp.zeros((ch,), param_dtype)}


def groupnorm(p, x: jnp.ndarray, groups: int = 8, eps: float = 1e-5):
    """NHWC group norm (BatchNorm stand-in inside ODE fields; a running-stat
    BN is ill-defined along continuous depth — documented in DESIGN.md)."""
    B, H, W, C = x.shape
    g = min(groups, C)
    while C % g:
        g -= 1
    xg = x.reshape(B, H, W, g, C // g).astype(jnp.float32)
    mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    y = ((xg - mean) * jax.lax.rsqrt(var + eps)).reshape(B, H, W, C)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def depth_cat(x: jnp.ndarray, s) -> jnp.ndarray:
    """Concatenate the depth variable s as a constant channel (DepthCat)."""
    s_chan = jnp.broadcast_to(jnp.asarray(s, x.dtype), x[..., :1].shape)
    return jnp.concatenate([x, s_chan], axis=-1)
