"""Grouped-query attention with RoPE, optional qk-norm, sliding window,
cross-attention, and a decode KV cache.

Layouts (chosen for TP shardability — head axes shard over 'model'):
    q proj : (d_model, n_heads * d_head)      "wq"
    k/v    : (d_model, n_kv   * d_head)       "wk"/"wv"
    out    : (n_heads * d_head, d_model)      "wo"
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.nn.module import dense_init, rmsnorm, rmsnorm_init, truncated_normal_init

NEG_INF = -1e30

# Exact q-chunked attention (lax.scan over query blocks): bounds the score
# buffer to (B, H, chunk, S) instead of (B, H, S, S). The XLA-level
# analogue of the flash kernel — used for long-S prefill/train where the
# Pallas TPU kernel can't be lowered (CPU dry-run) or isn't enabled.
_CHUNK = {"q_chunk": None}


def set_attention_chunking(q_chunk: Optional[int]) -> None:
    _CHUNK["q_chunk"] = q_chunk


def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, n, d_head); positions: (..., S) or (S,)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)  # (d_head/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def attention_init(
    key,
    d_model: int,
    n_heads: int,
    n_kv: int,
    d_head: int,
    qk_norm: bool = False,
    param_dtype=jnp.float32,
):
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, d_model, n_heads * d_head, param_dtype),
        "wk": dense_init(kk, d_model, n_kv * d_head, param_dtype),
        "wv": dense_init(kv, d_model, n_kv * d_head, param_dtype),
        "wo": {"kernel": truncated_normal_init(
            ko, (n_heads * d_head, d_model), (n_heads * d_head) ** -0.5,
            param_dtype)},
    }
    if qk_norm:
        p["q_norm"] = rmsnorm_init(d_head, param_dtype)
        p["k_norm"] = rmsnorm_init(d_head, param_dtype)
    return p


def _proj(w, x, n, d_head):
    y = jnp.matmul(x, w["kernel"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y.reshape(*x.shape[:-1], n, d_head)


def _mask_bias(q_pos, k_pos, causal: bool, window: Optional[int],
               k_valid=None) -> jnp.ndarray:
    """(..., S_q, S_k) additive bias in fp32."""
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if causal:
        ok = ok & (kp <= qp)
    if window is not None:
        ok = ok & (qp - kp < window)
    if k_valid is not None:
        ok = ok & k_valid[..., None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def mha(
    params,
    x: jnp.ndarray,
    *,
    n_heads: int,
    n_kv: int,
    d_head: int,
    rope_theta: float = 1e4,
    positions: Optional[jnp.ndarray] = None,
    causal: bool = True,
    window: Optional[int] = None,
    kv_x: Optional[jnp.ndarray] = None,
    use_rope: bool = True,
    qk_norm: bool = False,
) -> jnp.ndarray:
    """Full-sequence attention (training / prefill).

    x: (B, S, d). kv_x (B, T, d) switches to cross-attention (no causal
    mask, no rope on k). Returns (B, S, d).
    """
    B, S, _ = x.shape
    src = x if kv_x is None else kv_x
    T = src.shape[1]
    q = _proj(params["wq"], x, n_heads, d_head)     # (B,S,H,hd)
    k = _proj(params["wk"], src, n_kv, d_head)      # (B,T,KV,hd)
    v = _proj(params["wv"], src, n_kv, d_head)
    if qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    if positions is None:
        positions = jnp.arange(S)
    if use_rope and kv_x is None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    group = n_heads // n_kv
    qg = q.reshape(B, S, n_kv, group, d_head)
    qc = _CHUNK["q_chunk"]
    if qc is not None and kv_x is None and S > qc and S % qc == 0:
        ctx = _chunked_self_attention(qg, k, v, causal, window, qc)
    else:
        scores = jnp.einsum("bsngh,btnh->bngst", qg, k,
                            preferred_element_type=jnp.float32)
        scores = scores * (d_head ** -0.5)
        if kv_x is None:
            bias = _mask_bias(jnp.arange(S), jnp.arange(T), causal, window)
            scores = scores + bias
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        ctx = jnp.einsum("bngst,btnh->bsngh", probs, v,
                         preferred_element_type=jnp.float32).astype(x.dtype)
    ctx = ctx.reshape(B, S, n_heads * d_head)
    out = jnp.matmul(ctx, params["wo"]["kernel"].astype(x.dtype),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out


def _chunked_self_attention(qg, k, v, causal, window, qc: int):
    """qg: (B, S, KV, G, hd); k, v: (B, S, KV, hd). Exact attention with a
    lax.scan over q chunks. Returns ctx (B, S, KV, G, hd)-reshaped view."""
    B, S, KV, G, hd = qg.shape
    nc = S // qc
    q_chunks = jnp.moveaxis(qg.reshape(B, nc, qc, KV, G, hd), 1, 0)
    k_pos = jnp.arange(S)

    def one(ci):
        qi = q_chunks[ci]
        scores = jnp.einsum("bsngh,btnh->bngst", qi, k,
                            preferred_element_type=jnp.float32)
        scores = scores * (hd ** -0.5)
        q_pos = ci * qc + jnp.arange(qc)
        ok = jnp.ones((qc, S), bool)
        if causal:
            ok = ok & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            ok = ok & (q_pos[:, None] - k_pos[None, :] < window)
        scores = scores + jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
        probs = jax.nn.softmax(scores, axis=-1).astype(qi.dtype)
        return jnp.einsum("bngst,btnh->bsngh", probs, v,
                          preferred_element_type=jnp.float32).astype(qi.dtype)

    ctx = jax.lax.map(one, jnp.arange(nc))          # (nc, B, qc, KV, G, hd)
    return jnp.moveaxis(ctx, 0, 1).reshape(B, S, KV, G, hd)


def init_cache(batch: int, max_len: int, n_kv: int, d_head: int,
               dtype=jnp.bfloat16, kv_int8: bool = False):
    if kv_int8:
        # §Perf: int8 KV cache with per-(token, head) scales — halves the
        # decode-dominant KV HBM traffic vs bf16 at ~0.4% attention error.
        return {
            "k": jnp.zeros((batch, max_len, n_kv, d_head), jnp.int8),
            "v": jnp.zeros((batch, max_len, n_kv, d_head), jnp.int8),
            "k_scale": jnp.zeros((batch, max_len, n_kv), jnp.float32),
            "v_scale": jnp.zeros((batch, max_len, n_kv), jnp.float32),
        }
    return {
        "k": jnp.zeros((batch, max_len, n_kv, d_head), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, d_head), dtype),
    }


def _quantize_kv(x: jnp.ndarray):
    """x: (B, 1, KV, hd) -> int8 payload + (B, 1, KV) scale."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def mha_decode(
    params,
    x: jnp.ndarray,
    cache: Any,
    cur_index: jnp.ndarray,
    *,
    n_heads: int,
    n_kv: int,
    d_head: int,
    rope_theta: float = 1e4,
    window: Optional[int] = None,
    use_rope: bool = True,
    qk_norm: bool = False,
    cross_kv: Optional[Any] = None,
):
    """Single-token decode. x: (B, 1, d); cache k/v: (B, Smax, KV, hd).

    ``cur_index``: scalar int32 — the position being generated. Returns
    (out (B,1,d), new_cache). With ``cross_kv`` (precomputed encoder K/V)
    the self cache is ignored.
    """
    B = x.shape[0]
    q = _proj(params["wq"], x, n_heads, d_head)  # (B,1,H,hd)
    if cross_kv is None:
        k_new = _proj(params["wk"], x, n_kv, d_head)  # (B,1,KV,hd)
        v_new = _proj(params["wv"], x, n_kv, d_head)
        if qk_norm:
            q = rmsnorm(params["q_norm"], q)
            k_new = rmsnorm(params["k_norm"], k_new)
        pos = jnp.asarray(cur_index)[None]
        if use_rope:
            q = apply_rope(q, pos, rope_theta)
            k_new = apply_rope(k_new, pos, rope_theta)
        kv_int8 = cache["k"].dtype == jnp.int8
        if kv_int8:
            kq, ks = _quantize_kv(k_new)
            vq, vs = _quantize_kv(v_new)
            new_cache = {
                "k": jax.lax.dynamic_update_slice(
                    cache["k"], kq, (0, cur_index, 0, 0)),
                "v": jax.lax.dynamic_update_slice(
                    cache["v"], vq, (0, cur_index, 0, 0)),
                "k_scale": jax.lax.dynamic_update_slice(
                    cache["k_scale"], ks, (0, cur_index, 0)),
                "v_scale": jax.lax.dynamic_update_slice(
                    cache["v_scale"], vs, (0, cur_index, 0)),
            }
            k_all = (new_cache["k"].astype(jnp.float32)
                     * new_cache["k_scale"][..., None]).astype(x.dtype)
            v_all = (new_cache["v"].astype(jnp.float32)
                     * new_cache["v_scale"][..., None]).astype(x.dtype)
        else:
            k_all = jax.lax.dynamic_update_slice(
                cache["k"], k_new.astype(cache["k"].dtype),
                (0, cur_index, 0, 0))
            v_all = jax.lax.dynamic_update_slice(
                cache["v"], v_new.astype(cache["v"].dtype),
                (0, cur_index, 0, 0))
            new_cache = {"k": k_all, "v": v_all}
        T = k_all.shape[1]
        k_pos = jnp.arange(T)
        valid = k_pos <= cur_index
        if window is not None:
            valid = valid & (cur_index - k_pos < window)
        bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
    else:
        if qk_norm:
            q = rmsnorm(params["q_norm"], q)
        k_all, v_all = cross_kv["k"], cross_kv["v"]
        new_cache = cache
        bias = jnp.zeros((k_all.shape[1],), jnp.float32)

    group = n_heads // n_kv
    qg = q.reshape(B, 1, n_kv, group, d_head)
    scores = jnp.einsum("bsngh,btnh->bngst", qg, k_all.astype(q.dtype),
                        preferred_element_type=jnp.float32)
    scores = scores * (d_head ** -0.5) + bias
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bngst,btnh->bsngh", probs, v_all.astype(x.dtype),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    ctx = ctx.reshape(B, 1, n_heads * d_head)
    out = jnp.matmul(ctx, params["wo"]["kernel"].astype(x.dtype),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, new_cache


def precompute_cross_kv(params, enc: jnp.ndarray, *, n_kv: int, d_head: int,
                        qk_norm: bool = False):
    """Encoder K/V for cross-attention, computed once per request."""
    k = _proj(params["wk"], enc, n_kv, d_head)
    v = _proj(params["wv"], enc, n_kv, d_head)
    if qk_norm:
        k = rmsnorm(params["k_norm"], k)
    return {"k": k, "v": v}
