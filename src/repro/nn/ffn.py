"""Feed-forward variants: SwiGLU / GeGLU (gated), squared-ReLU (Nemotron-4),
GELU (Whisper), and RWKV channel-mix."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.module import dense, dense_init

ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
}


def ffn_init(key, d_model: int, d_ff: int, gated: bool, param_dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {
        "wi": dense_init(ks[0], d_model, d_ff, param_dtype),
        "wd": dense_init(ks[1], d_ff, d_model, param_dtype),
    }
    if gated:
        p["wg"] = dense_init(ks[2], d_model, d_ff, param_dtype)
    return p


def ffn_apply(params, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    """Gated if a 'wg' kernel is present: wd(act(wg x) * (wi x)); else
    wd(act(wi x))."""
    h = dense(params["wi"], x)
    if "wg" in params:
        h = ACTS[act](dense(params["wg"], x)) * h
    else:
        h = ACTS[act](h)
    return dense(params["wd"], h)


def rwkv_channel_mix_init(key, d_model: int, d_ff: int, param_dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    return {
        "wk": dense_init(ks[0], d_model, d_ff, param_dtype),
        "wv": dense_init(ks[1], d_ff, d_model, param_dtype),
        "wr": dense_init(ks[2], d_model, d_model, param_dtype),
        "mix_k": jnp.full((d_model,), 0.5, param_dtype),
        "mix_r": jnp.full((d_model,), 0.5, param_dtype),
    }


def rwkv_channel_mix(params, x: jnp.ndarray, x_prev: jnp.ndarray) -> jnp.ndarray:
    """RWKV channel mix: token-shift interpolation + squared-ReLU key net,
    sigmoid receptance gate (Peng et al., arXiv:2404.05892)."""
    mk = params["mix_k"].astype(x.dtype)
    mr = params["mix_r"].astype(x.dtype)
    xk = x * mk + x_prev * (1 - mk)
    xr = x * mr + x_prev * (1 - mr)
    k = jnp.square(jax.nn.relu(dense(params["wk"], xk)))
    return jax.nn.sigmoid(dense(params["wr"], xr)) * dense(params["wv"], k)
