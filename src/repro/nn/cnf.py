"""Continuous normalizing flows (FFJORD variant) — paper Sec. 4.2.

State is the pytree (z, logp). Dynamics:

    dz/ds    = f_theta(s, z)
    dlogp/ds = -tr(df/dz)(s, z)

Exact trace via one jvp per dimension (cheap for the paper's 2-D densities);
Hutchinson estimator available for higher dimensions. The flow maps base
N(0, I) at s=0 to data at s=1 ("sampling direction"); density evaluation
integrates the reversed field.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.nn.module import mlp_apply, mlp_init


def cnf_mlp_init(key, dim: int = 2, hidden=(128, 128, 128),
                 param_dtype=jnp.float32):
    """Paper C.3: three-layer MLP of hidden dims 128,128,128; input [z, s]."""
    return mlp_init(key, (dim + 1, *hidden, dim), param_dtype)


def cnf_field(params) -> Callable:
    def f(s, z):
        s_col = jnp.broadcast_to(jnp.asarray(s, z.dtype), z[..., :1].shape)
        return mlp_apply(params, jnp.concatenate([z, s_col], -1),
                         act=jnp.tanh)
    return f


def exact_trace_dynamics(params) -> Callable:
    """VectorField over (z, logp) with exact divergence (per-dim jvp)."""
    f = cnf_field(params)

    def aug(s, state):
        z, logp = state
        dz = f(s, z)
        dim = z.shape[-1]
        tr = jnp.zeros(z.shape[:-1], z.dtype)
        for i in range(dim):
            e = jnp.zeros_like(z).at[..., i].set(1.0)
            _, jv = jax.jvp(lambda zz: f(s, zz), (z,), (e,))
            tr = tr + jv[..., i]
        return (dz, -tr)

    return aug


def hutchinson_dynamics(params, key, n_samples: int = 1) -> Callable:
    """Stochastic trace estimator (Rademacher) for high-dim CNFs."""
    f = cnf_field(params)
    eps = None

    def aug(s, state):
        z, logp = state
        dz = f(s, z)
        ks = jax.random.fold_in(key, 0)
        tr = jnp.zeros(z.shape[:-1], z.dtype)
        for i in range(n_samples):
            e = jax.random.rademacher(
                jax.random.fold_in(ks, i), z.shape, dtype=z.dtype)
            _, jv = jax.jvp(lambda zz: f(s, zz), (z,), (e,))
            tr = tr + jnp.sum(jv * e, axis=-1)
        return (dz, -tr / n_samples)

    return aug


def reversed_field(aug: Callable) -> Callable:
    """Density direction: integrate x -> base by reversing depth."""
    def rev(s, state):
        dz, dlogp = aug(1.0 - s, state)
        return (jax.tree_util.tree_map(lambda t: -t, dz), -dlogp)
    return rev


def base_log_prob(z: jnp.ndarray) -> jnp.ndarray:
    return -0.5 * jnp.sum(z * z, -1) - 0.5 * z.shape[-1] * jnp.log(2 * jnp.pi)
