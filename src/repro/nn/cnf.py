"""Continuous normalizing flows (FFJORD variant) — paper Sec. 4.2.

State is the pytree (z, logp). Dynamics:

    dz/ds    = f_theta(s, z)
    dlogp/ds = -tr(df/dz)(s, z)

Exact trace via one jvp per dimension (cheap for the paper's 2-D densities);
Hutchinson estimator available for higher dimensions. The flow maps base
N(0, I) at s=0 to data at s=1 ("sampling direction"); density evaluation
integrates the reversed field.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import FixedGrid, as_integrator
from repro.nn.module import mlp_apply, mlp_init


def cnf_mlp_init(key, dim: int = 2, hidden=(128, 128, 128),
                 param_dtype=jnp.float32):
    """Paper C.3: three-layer MLP of hidden dims 128,128,128; input [z, s]."""
    return mlp_init(key, (dim + 1, *hidden, dim), param_dtype)


def cnf_field(params) -> Callable:
    def f(s, z):
        s_col = jnp.broadcast_to(jnp.asarray(s, z.dtype), z[..., :1].shape)
        return mlp_apply(params, jnp.concatenate([z, s_col], -1),
                         act=jnp.tanh)
    return f


def exact_trace_dynamics(params) -> Callable:
    """VectorField over (z, logp) with exact divergence (per-dim jvp)."""
    f = cnf_field(params)

    def aug(s, state):
        z, logp = state
        dz = f(s, z)
        dim = z.shape[-1]
        tr = jnp.zeros(z.shape[:-1], z.dtype)
        for i in range(dim):
            e = jnp.zeros_like(z).at[..., i].set(1.0)
            _, jv = jax.jvp(lambda zz: f(s, zz), (z,), (e,))
            tr = tr + jv[..., i]
        return (dz, -tr)

    return aug


def hutchinson_dynamics(params, key, n_samples: int = 1) -> Callable:
    """Stochastic trace estimator (Rademacher) for high-dim CNFs."""
    f = cnf_field(params)
    eps = None

    def aug(s, state):
        z, logp = state
        dz = f(s, z)
        ks = jax.random.fold_in(key, 0)
        tr = jnp.zeros(z.shape[:-1], z.dtype)
        for i in range(n_samples):
            e = jax.random.rademacher(
                jax.random.fold_in(ks, i), z.shape, dtype=z.dtype)
            _, jv = jax.jvp(lambda zz: f(s, zz), (z,), (e,))
            tr = tr + jnp.sum(jv * e, axis=-1)
        return (dz, -tr / n_samples)

    return aug


def reversed_field(aug: Callable) -> Callable:
    """Density direction: integrate x -> base by reversing depth."""
    def rev(s, state):
        dz, dlogp = aug(1.0 - s, state)
        return (jax.tree_util.tree_map(lambda t: -t, dz), -dlogp)
    return rev


def base_log_prob(z: jnp.ndarray) -> jnp.ndarray:
    return -0.5 * jnp.sum(z * z, -1) - 0.5 * z.shape[-1] * jnp.log(2 * jnp.pi)


# ------------------------------------------- integration entry points ----
# All CNF solves route through the unified Integrator engine; ``solver``
# accepts an Integrator / HyperSolver / Tableau / name (hypersolver
# corrections ride along inside the Integrator, paper Sec. 4.2).

def cnf_sample(params, z0: jnp.ndarray, K: int = 1, solver="heun",
               return_traj: bool = False):
    """Map base draws ``z0 ~ N(0, I)`` to data space with K solver steps.

    Returns the terminal ``(x, dlogp)`` state (or the dense trajectory).
    With a trained 2nd-order hypersolver inside ``solver`` this is the
    paper's 2-NFE sampling result."""
    integ = as_integrator(solver)
    aug = exact_trace_dynamics(params)
    state0 = (z0, jnp.zeros(z0.shape[:-1], z0.dtype))
    return integ.solve(aug, state0, FixedGrid.over(0.0, 1.0, K),
                       return_traj=return_traj)


def cnf_log_prob(params, x: jnp.ndarray, K: int = 8, solver="rk4"):
    """log p(x) by integrating the reversed augmented field data -> base."""
    integ = as_integrator(solver)
    rev = reversed_field(exact_trace_dynamics(params))
    state0 = (x, jnp.zeros(x.shape[:-1], x.dtype))
    zT, dlogp = integ.solve(rev, state0, FixedGrid.over(0.0, 1.0, K),
                            return_traj=False)
    return base_log_prob(zT) - dlogp
