"""Pure-JAX neural-network substrate (no flax/optax dependency).

Every layer is a pair of functions: ``init_*(key, ...) -> params`` (nested
dicts of arrays) and a pure ``apply``. Sharding is attached by name-path
rules in ``repro.distributed.sharding`` so this package stays mesh-agnostic.
"""
from repro.nn.module import (  # noqa: F401
    dense_init, dense, rmsnorm_init, rmsnorm, layernorm_init, layernorm,
    embedding_init, embedding_lookup, mlp_init, mlp_apply, truncated_normal_init,
)
