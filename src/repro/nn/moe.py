"""Mixture-of-Experts with GShard/Switch-style capacity dispatch.

TPU-idiomatic dense dispatch: tokens are routed to (expert, capacity-slot)
one-hot tensors and moved with einsums — XLA lowers the expert axis to
all-to-all when experts are sharded over the 'model' mesh axis (EP). No
CSR/MegaBlocks grouped GEMM (GPU mechanism); capacity einsum is the TPU
equivalent (see DESIGN.md §3).

Supports top-1 (Switch; llama4-maverick) through top-8 (OLMoE) routing,
optional shared expert (llama4), aux load-balancing loss, and router z-loss.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.nn.module import dense_init, truncated_normal_init


class MoEOutput(NamedTuple):
    y: jnp.ndarray
    aux_loss: jnp.ndarray
    router_z_loss: jnp.ndarray
    fraction_dropped: jnp.ndarray


def moe_init(key, d_model: int, d_ff: int, n_experts: int, gated: bool = True,
             param_dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    scale = d_model ** -0.5
    p = {
        "router": dense_init(ks[0], d_model, n_experts, param_dtype),
        # stacked expert weights: leading E axis shards over 'model' (EP)
        "wi": truncated_normal_init(ks[1], (n_experts, d_model, d_ff), scale,
                                    param_dtype),
        "wd": truncated_normal_init(ks[2], (n_experts, d_ff, d_model),
                                    d_ff ** -0.5, param_dtype),
    }
    if gated:
        p["wg"] = truncated_normal_init(ks[3], (n_experts, d_model, d_ff),
                                        scale, param_dtype)
    return p


def moe_apply(
    params,
    x: jnp.ndarray,
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    act: str = "silu",
    renorm_gates: bool = True,
) -> MoEOutput:
    """x: (B, S, d) -> MoEOutput with y: (B, S, d).

    Routing: softmax over experts, take top-k, per-expert capacity
    C = ceil(top_k * T * capacity_factor / E); overflow tokens are dropped
    (their contribution is zero for that expert slot).
    """
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    E = n_experts

    logits = jnp.matmul(xt, params["router"]["kernel"].astype(xt.dtype),
                        preferred_element_type=jnp.float32)  # (T, E) fp32
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)  # (T, k)
    if renorm_gates:
        gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    capacity = int(max(1, -(-top_k * T * capacity_factor // E)))

    # one-hot over experts per routing slot: (T, k, E)
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)
    # position of each (token, slot) within its expert queue
    # cumulative count over flattened (slot-major) order for fairness
    flat = onehot.transpose(1, 0, 2).reshape(top_k * T, E)  # slot-major
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat)  # (kT, E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1)  # (kT,)
    keep = pos < capacity
    pos = jnp.where(keep, pos, 0).astype(jnp.int32)
    flat_keep = flat * keep[:, None]

    # dispatch tensor (kT, E, C)
    cap_onehot = jax.nn.one_hot(pos, capacity, dtype=jnp.float32)
    dispatch = flat_keep[:, :, None] * cap_onehot[:, None, :]
    gates_flat = gate_vals.T.reshape(top_k * T)  # slot-major to match
    combine = dispatch * gates_flat[:, None, None]

    # fold slot axis back onto tokens: (T, E, C)
    dispatch_t = dispatch.reshape(top_k, T, E, capacity).sum(0)
    combine_t = combine.reshape(top_k, T, E, capacity).sum(0)

    # expert ingest: (E, C, d)
    xin = jnp.einsum("tec,td->ecd", dispatch_t.astype(xt.dtype), xt,
                     preferred_element_type=jnp.float32).astype(xt.dtype)
    h = jnp.einsum("ecd,edf->ecf", xin, params["wi"].astype(xt.dtype),
                   preferred_element_type=jnp.float32).astype(xt.dtype)
    if "wg" in params:
        from repro.nn.ffn import ACTS
        g = jnp.einsum("ecd,edf->ecf", xin, params["wg"].astype(xt.dtype),
                       preferred_element_type=jnp.float32).astype(xt.dtype)
        h = ACTS[act](g) * h
    else:
        from repro.nn.ffn import ACTS
        h = ACTS[act](h)
    yout = jnp.einsum("ecf,efd->ecd", h, params["wd"].astype(xt.dtype),
                      preferred_element_type=jnp.float32).astype(xt.dtype)
    y = jnp.einsum("tec,ecd->td", combine_t.astype(xt.dtype), yout,
                   preferred_element_type=jnp.float32).astype(x.dtype)

    # Switch aux load-balance loss: E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)                      # mean router prob per e
    ce = jnp.mean(onehot.sum(1), axis=0)              # fraction routed per e
    aux = E * jnp.sum(me * ce) / top_k
    zl = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = 1.0 - jnp.sum(dispatch_t) / (T * top_k)
    return MoEOutput(y=y.reshape(B, S, d), aux_loss=aux, router_z_loss=zl,
                     fraction_dropped=dropped)


def moe_apply_sorted(
    params,
    x: jnp.ndarray,
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    act: str = "silu",
    renorm_gates: bool = True,
    int8_dispatch: bool = False,
) -> MoEOutput:
    """Sort-based dispatch: argsort tokens by expert, gather into (E, C, d)
    buffers, grouped GEMM, scatter-add back.

    The einsum dispatch above is O(T * E * C * d) = O(T^2) since capacity
    C grows with T — fine for the small-T decode path, ruinous for 1M-token
    training steps. Sorting replaces the one-hot matmuls with O(kT log kT)
    sort + O(kT d) gathers, leaving only the real expert FLOPs
    2 E C d f (= 2 k cf T d f). This is the default for train/prefill.
    """
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    E, k = n_experts, top_k

    logits = jnp.matmul(xt, params["router"]["kernel"].astype(xt.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)        # (T, k)
    if renorm_gates:
        gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    capacity = int(max(1, -(-k * T * capacity_factor // E)))

    flat_e = expert_idx.reshape(-1)                        # (kT,) slot-major? token-major
    flat_g = gate_vals.reshape(-1)
    token_of_slot = jnp.repeat(jnp.arange(T), k)

    order = jnp.argsort(flat_e, stable=True)               # group by expert
    e_sorted = flat_e[order]
    t_sorted = token_of_slot[order]
    g_sorted = flat_g[order]

    # position within expert group = rank - first_rank_of_expert
    counts = jnp.bincount(flat_e, length=E)                # (E,)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(k * T) - starts[e_sorted]
    keep = pos < capacity
    dest = jnp.where(keep, e_sorted * capacity + pos, E * capacity)

    # ingest buffer (E*C + 1 overflow row; all dropped slots write there,
    # the row is never read)
    if int8_dispatch:
        # §Perf optimization: the dispatch buffer is what crosses the EP
        # all-to-all — quantize it to int8 with per-token scales (2x less
        # interconnect traffic than bf16; error-feedback unnecessary since
        # quantization precedes the expert GEMM, not the gradient path).
        scale = jnp.max(jnp.abs(xt.astype(jnp.float32)), axis=-1,
                        keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        xq = jnp.clip(jnp.round(xt.astype(jnp.float32) / scale),
                      -127, 127).astype(jnp.int8)
        buf = jnp.zeros((E * capacity + 1, d), jnp.int8)
        buf = buf.at[dest].set(xq[t_sorted])
        sbuf = jnp.zeros((E * capacity + 1, 1), jnp.float32)
        sbuf = sbuf.at[dest].set(scale[t_sorted])
        xin = (buf[:-1].astype(jnp.float32) * sbuf[:-1]).astype(
            xt.dtype).reshape(E, capacity, d)
    else:
        buf = jnp.zeros((E * capacity + 1, d), xt.dtype)
        buf = buf.at[dest].set(xt[t_sorted])
        xin = buf[:-1].reshape(E, capacity, d)

    h = jnp.einsum("ecd,edf->ecf", xin, params["wi"].astype(xt.dtype),
                   preferred_element_type=jnp.float32).astype(xt.dtype)
    from repro.nn.ffn import ACTS
    if "wg" in params:
        g = jnp.einsum("ecd,edf->ecf", xin, params["wg"].astype(xt.dtype),
                       preferred_element_type=jnp.float32).astype(xt.dtype)
        h = ACTS[act](g) * h
    else:
        h = ACTS[act](h)
    yout = jnp.einsum("ecf,efd->ecd", h, params["wd"].astype(xt.dtype),
                      preferred_element_type=jnp.float32).astype(xt.dtype)

    # combine: gather each kept slot's expert output, weight, scatter-add
    flat_out = yout.reshape(E * capacity, d)
    slot_y = jnp.where(keep[:, None], flat_out[jnp.minimum(dest,
                                                           E * capacity - 1)],
                       0.0)
    y = jnp.zeros((T, d), jnp.float32).at[t_sorted].add(
        slot_y.astype(jnp.float32) * g_sorted[:, None])

    me = jnp.mean(probs, axis=0)
    # Switch aux loss: E * sum_e (tokens routed fraction) * (mean prob)
    frac = counts.astype(jnp.float32) / (T * k)
    aux = E * jnp.sum(frac * me)
    zl = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = 1.0 - jnp.sum(keep.astype(jnp.float32)) / (T * k)
    return MoEOutput(y=y.astype(x.dtype).reshape(B, S, d), aux_loss=aux,
                     router_z_loss=zl, fraction_dropped=dropped)


def moe_apply_reference(params, x, *, n_experts: int, top_k: int,
                        act: str = "silu", renorm_gates: bool = True):
    """Loop-over-experts oracle with infinite capacity (for tests)."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = jnp.matmul(xt, params["router"]["kernel"].astype(xt.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)
    if renorm_gates:
        gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)
    from repro.nn.ffn import ACTS
    y = jnp.zeros_like(xt, dtype=jnp.float32)
    for e in range(n_experts):
        h = xt @ params["wi"][e].astype(xt.dtype)
        if "wg" in params:
            h = ACTS[act](xt @ params["wg"][e].astype(xt.dtype)) * h
        else:
            h = ACTS[act](h)
        he = (h @ params["wd"][e].astype(xt.dtype)).astype(jnp.float32)
        w_e = jnp.sum(jnp.where(expert_idx == e, gate_vals, 0.0), axis=-1)
        y = y + w_e[:, None] * he
    return y.astype(x.dtype).reshape(B, S, d)
