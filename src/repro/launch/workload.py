"""Arrival traces + latency accounting for the serving loops.

Seeded, reproducible streaming workloads (Poisson and bursty arrival
processes over heterogeneous difficulty mixes) and replay drivers that
run the SAME trace through the drain-the-queue engine
(``launch/engine.py``) and the in-flight scheduler
(``launch/scheduler.py``), on the same virtual clock — whichever cost
oracle the loop carries (``launch/oracle.py``; sequential vector-field
evaluations by default) — producing comparable per-request records:

    queue wait  = arrival -> the solve that serves it starts
    latency     = arrival -> outputs ready
    waste       = slot/sample depth-steps computed for frozen or empty rows

``benchmarks/bench_scheduler.py`` is the head-to-head harness over these
drivers; ``latency_stats`` is the summary both report (p50/p99 latency,
throughput, occupancy, masked-step waste), tagged with the producing
clock's ``cost_unit`` so BENCH rows from different oracles are never
compared by accident.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------- traces ----

@dataclasses.dataclass(frozen=True)
class Arrival:
    """One request arrival: time on the virtual clock + its input.
    ``deadline`` is ABSOLUTE on the same clock (the trace builders stamp
    ``t + deadline_slack``); None = no deadline."""

    t: float
    x: np.ndarray
    deadline: Optional[float] = None


def heterogeneous_requests(n: int, d: int, *, easy_frac: float = 0.5,
                           easy_loc: float = -2.0, hard_loc: float = 3.0,
                           scale: float = 0.05, seed: int = 0,
                           interleave: bool = True) -> np.ndarray:
    """The repo's standard toy difficulty mix: request rows whose mean
    drives a softplus stiffness, so `easy_loc` rows integrate in the
    smallest buckets and `hard_loc` rows need the finest mesh (the same
    construction tests/test_engine.py uses). ``interleave`` shuffles the
    two classes together so arrival order carries a realistic mix."""
    rng = np.random.RandomState(seed)
    n_easy = int(round(n * easy_frac))
    xs = np.concatenate([
        rng.randn(n_easy, d) * scale + easy_loc,
        rng.randn(n - n_easy, d) * scale + hard_loc,
    ]).astype(np.float32)
    if interleave:
        rng.shuffle(xs)
    return xs


def drifting_requests(n: int, d: int, *, phases: int = 3, seed: int = 0,
                      easy_frac0: float = 0.8, easy_frac1: float = 0.2,
                      hard_loc0: float = 2.0, hard_loc1: float = 3.5,
                      scale: float = 0.05) -> np.ndarray:
    """A NON-stationary difficulty mix: the trace is split into ``phases``
    contiguous blocks whose easy fraction slides from ``easy_frac0`` to
    ``easy_frac1`` and whose hard-class location from ``hard_loc0`` to
    ``hard_loc1``. Early traffic looks nothing like late traffic — the
    drift the online refinery exists for (an offline-trained g only ever
    saw phase 0; benchmarks/bench_refinery.py serves this mix and lets
    the ledger re-fit g on what is actually arriving)."""
    rng = np.random.RandomState(seed)
    blocks = []
    edges = np.linspace(0, n, phases + 1).astype(int)
    for p in range(phases):
        m = int(edges[p + 1] - edges[p])
        if m == 0:
            continue
        u = p / max(phases - 1, 1)
        blocks.append(heterogeneous_requests(
            m, d,
            easy_frac=float(easy_frac0 + (easy_frac1 - easy_frac0) * u),
            hard_loc=float(hard_loc0 + (hard_loc1 - hard_loc0) * u),
            scale=scale, seed=int(rng.randint(1 << 30)), interleave=True))
    return np.concatenate(blocks).astype(np.float32)


def poisson_trace(xs: np.ndarray, rate: float, *, seed: int = 0,
                  t0: float = 0.0,
                  deadline_slack: Optional[float] = None) -> List[Arrival]:
    """Poisson arrival process: exponential inter-arrival gaps at ``rate``
    requests per virtual cost unit, one arrival per row of ``xs``.
    ``deadline_slack`` stamps each arrival's absolute deadline at
    ``t + slack`` (None = no deadlines)."""
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    rng = np.random.RandomState(seed)
    gaps = rng.exponential(1.0 / rate, size=len(xs))
    ts = t0 + np.cumsum(gaps)
    return [Arrival(t=float(t), x=np.asarray(x),
                    deadline=None if deadline_slack is None
                    else float(t) + deadline_slack)
            for t, x in zip(ts, xs)]


def bursty_trace(xs: np.ndarray, *, burst: int = 4, gap: float = 20.0,
                 within: float = 0.0, seed: int = 0, t0: float = 0.0,
                 deadline_slack: Optional[float] = None) -> List[Arrival]:
    """Bursty arrivals: groups of ``burst`` requests landing (near-)
    simultaneously, bursts separated by ``gap`` cost units (+- 25%
    jitter). ``within`` spreads a burst's members by that many units;
    ``deadline_slack`` stamps absolute deadlines at ``t + slack``."""
    rng = np.random.RandomState(seed)
    arrivals: List[Arrival] = []
    t = t0
    for lo in range(0, len(xs), burst):
        chunk = xs[lo:lo + burst]
        offs = np.sort(rng.uniform(0.0, within, size=len(chunk))) \
            if within > 0 else np.zeros(len(chunk))
        for off, x in zip(offs, chunk):
            arrivals.append(Arrival(
                t=float(t + off), x=np.asarray(x),
                deadline=None if deadline_slack is None
                else float(t + off) + deadline_slack))
        t += gap * float(rng.uniform(0.75, 1.25))
    return arrivals


# ------------------------------------------------------------- accounting ----

@dataclasses.dataclass(frozen=True)
class RequestRecord:
    """Loop-agnostic per-request ledger entry (both replay drivers emit
    these, so the comparison is apples-to-apples)."""

    uid: int
    t_submit: float
    t_admit: float           # when the solve serving it started
    t_done: float
    K: int
    nfe: int
    outputs: np.ndarray      # None for shed / queue-expired requests
    status: str = "ok"       # terminal status (engine.STATUSES)

    @property
    def queue_wait(self) -> float:
        return self.t_admit - self.t_submit

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit


@dataclasses.dataclass(frozen=True)
class TraceReport:
    """One trace replay: per-request records + aggregate work accounting.

    ``occupied_steps`` counts slot/sample-steps that belonged to an
    admitted request at segment start (the in-flight scheduler's pool
    utilization; for the drain engine every scanned row was admitted, so
    it equals ``total_steps``). ``cost_unit`` names the clock that priced
    ``total_cost``/``probe_cost`` and every timestamp in ``records`` —
    step COUNTS (useful/total/occupied) are clock-independent."""

    records: Tuple[RequestRecord, ...]
    total_cost: float        # oracle units spent, arrivals -> drained
    probe_cost: float
    useful_steps: int        # sample-steps that advanced a live request
    total_steps: int         # sample-steps computed (incl. frozen/empty)
    makespan: float          # first arrival -> last completion
    # slot-steps owned by an admitted request; None = "built without
    # in-flight slot accounting", i.e. drain semantics: every scanned row
    # was an admitted request, so occupancy derives to 1.0 (the old
    # default of 0 silently reported 0.0 for such reports — bug fixed in
    # the cost-oracle PR, pinned by tests/test_scheduler.py)
    occupied_steps: Optional[int] = None
    cost_unit: str = "sequential_evals"

    @property
    def waste_steps(self) -> int:
        return self.total_steps - self.useful_steps

    @property
    def occupancy(self) -> float:
        """Fraction of computed slot-steps owned by an admitted request;
        1.0 by construction for drain reports (``occupied_steps=None``)."""
        occ = (self.total_steps if self.occupied_steps is None
               else self.occupied_steps)
        return occ / self.total_steps if self.total_steps else 0.0


def latency_stats(report: TraceReport) -> Dict[str, float]:
    """The summary row both serving loops report: latency/queue-wait
    percentiles, throughput, and masked-step waste. An empty replay
    (zero-request trace) yields a zero summary, not a crash."""
    if not report.records:
        return {"requests": 0, "p50_latency": 0.0, "p99_latency": 0.0,
                "mean_latency": 0.0, "p50_queue_wait": 0.0,
                "p99_queue_wait": 0.0, "mean_nfe": 0.0, "throughput": 0.0,
                "total_cost": round(report.total_cost, 1),
                "probe_cost": round(report.probe_cost, 1),
                "useful_steps": 0, "waste_steps": 0, "waste_frac": 0.0,
                "occupancy": 0.0, "cost_unit": report.cost_unit}
    lat = np.asarray([r.latency for r in report.records])
    wait = np.asarray([r.queue_wait for r in report.records])
    nfe = np.asarray([r.nfe for r in report.records])
    n = len(report.records)
    waste_frac = (report.waste_steps / report.total_steps
                  if report.total_steps else 0.0)
    return {
        "requests": n,
        "p50_latency": round(float(np.percentile(lat, 50)), 3),
        "p99_latency": round(float(np.percentile(lat, 99)), 3),
        "mean_latency": round(float(lat.mean()), 3),
        "p50_queue_wait": round(float(np.percentile(wait, 50)), 3),
        "p99_queue_wait": round(float(np.percentile(wait, 99)), 3),
        "mean_nfe": round(float(nfe.mean()), 3),
        "throughput": round(n / report.makespan, 4) if report.makespan
        else float("inf"),
        "total_cost": round(report.total_cost, 1),
        "probe_cost": round(report.probe_cost, 1),
        "useful_steps": int(report.useful_steps),
        "waste_steps": int(report.waste_steps),
        "waste_frac": round(waste_frac, 4),
        "occupancy": round(report.occupancy, 4),
        "cost_unit": report.cost_unit,
    }


def status_counts(report: TraceReport) -> Dict[str, int]:
    """Terminal-status histogram over a replay's records — the chaos
    bench's accounting row. Keyed by the live ``engine.STATUSES`` enum
    (every key present, zero or not), NOT folded into ``latency_stats``:
    that summary's keys are pinned by committed BENCH artifacts."""
    from repro.launch.engine import STATUSES

    counts = {s: 0 for s in STATUSES}
    for r in report.records:
        counts[r.status] += 1
    return counts


def ok_records(report: TraceReport) -> TraceReport:
    """The report restricted to requests that produced real outputs
    (``ok``/``retried``/``escalated`` — an escalated request completed
    on the K-bucket ladder after its flow eval failed, so its outputs
    are as real as a retried one's) — latency percentiles over shed or
    evicted requests (t_done == t_submit, or a truncated solve) would
    flatter the very loop that failed them."""
    keep = tuple(r for r in report.records
                 if r.status in ("ok", "retried", "escalated"))
    return dataclasses.replace(report, records=keep)


# ---------------------------------------------------------------- replays ----

def replay_engine(engine, trace: Sequence[Arrival], *,
                  on_tick=None, should_admit=None) -> TraceReport:
    """Drive a ``MultiRateEngine`` through an arrival trace with drain
    semantics: whenever the loop turns and work is queued, ``step()``
    serves EVERYTHING queued to completion (new arrivals wait out the
    drain). Request i's service start is the drain start; its completion
    lands at the drain's per-batch finish offset (engine.StepReport).

    ``on_tick(engine)``, if given, runs after every drain step — the
    cooperative slot the online refinery trains in
    (``launch/refinery.py::Refinery.tick``); it must not touch the
    engine's queue or pools (the loops own those). ``should_admit()``
    returning False stops admission for good: remaining arrivals are
    dropped unsubmitted, already-queued work drains to completion — the
    graceful-shutdown contract (serve.py SIGTERM/SIGINT)."""
    trace = sorted(trace, key=lambda a: a.t)
    now = 0.0
    i = 0
    t_submit: Dict[int, float] = {}
    records: List[RequestRecord] = []
    total_cost = probe_cost = 0.0
    useful = total = 0
    while i < len(trace) or len(engine):
        if should_admit is not None and not should_admit():
            i = len(trace)          # drain what's in; admit nothing more
            if not len(engine):
                break
        if not len(engine):
            now = max(now, trace[i].t)          # idle-jump to next arrival
        while i < len(trace) and trace[i].t <= now \
                and engine.can_submit():
            uid = engine.submit(trace[i].x, deadline=trace[i].deadline)
            t_submit[uid] = trace[i].t
            i += 1
        t_drain = now
        done = engine.step(now=now)
        rep = engine.last_report
        now += rep.cost
        total_cost += rep.cost
        probe_cost += rep.probe_cost
        useful += rep.useful_steps
        total += rep.total_steps
        for c in done:
            records.append(RequestRecord(
                uid=c.uid, t_submit=t_submit.pop(c.uid), t_admit=t_drain,
                t_done=t_drain + rep.finish_offset[c.uid], K=c.K, nfe=c.nfe,
                outputs=c.outputs, status=c.status))
        if on_tick is not None:
            on_tick(engine)
    t0 = trace[0].t if trace else 0.0
    t_end = max((r.t_done for r in records), default=t0)
    # every scanned row of a drain was an admitted request, so the
    # engine's occupancy is total_steps by construction
    return TraceReport(records=tuple(records), total_cost=total_cost,
                       probe_cost=probe_cost, useful_steps=useful,
                       total_steps=total, makespan=t_end - t0,
                       occupied_steps=total,
                       cost_unit=getattr(getattr(engine, "oracle", None),
                                         "unit", "sequential_evals"))


def replay_scheduler(sched, trace: Sequence[Arrival], *,
                     on_tick=None, should_admit=None) -> TraceReport:
    """Drive an ``InflightScheduler`` through the same arrival trace:
    arrivals are submitted the moment the virtual clock passes them, and
    each ``step()`` admits + advances one segment — requests overlap
    in-flight instead of waiting out a drain.

    ``on_tick(sched)``, if given, runs BETWEEN scheduler ticks — after a
    segment retires, before the next admission. This is where the online
    refinery trains and (between segments) hot-swaps g
    (``launch/refinery.py``): cooperative, same thread, never inside the
    compiled path. It must not submit or retire requests itself.
    ``should_admit()`` returning False stops admission for good:
    remaining arrivals are dropped unsubmitted and the in-flight slots
    flush to completion — the graceful-shutdown contract (serve.py
    SIGTERM/SIGINT)."""
    trace = sorted(trace, key=lambda a: a.t)
    i = 0
    records: List[RequestRecord] = []
    while i < len(trace) or sched.pending:
        if should_admit is not None and not should_admit():
            i = len(trace)          # drain what's in; admit nothing more
            if not sched.pending:
                break
        while i < len(trace) and trace[i].t <= sched.now \
                and sched.can_submit():
            sched.submit(trace[i].x, t=trace[i].t,
                         deadline=trace[i].deadline)
            i += 1
        if not sched.pending:
            sched.advance_to(trace[i].t)
            continue
        for c in sched.step():
            records.append(RequestRecord(
                uid=c.uid, t_submit=c.t_submit, t_admit=c.t_admit,
                t_done=c.t_done, K=c.K, nfe=c.nfe, outputs=c.outputs,
                status=c.status))
        if on_tick is not None:
            on_tick(sched)
    t0 = trace[0].t if trace else 0.0
    t_end = max((r.t_done for r in records), default=t0)
    return TraceReport(
        records=tuple(records), total_cost=sched.total_cost,
        probe_cost=sched.total_probe_cost,
        useful_steps=sched.total_useful_steps,
        total_steps=sched.total_slot_steps, makespan=t_end - t0,
        occupied_steps=sched.total_occupied_steps,
        cost_unit=getattr(getattr(sched, "oracle", None), "unit",
                          "sequential_evals"))


# ------------------------------------------------------------ toy servable ----

def toy_classifier(solver: str = "euler", fused: bool = True, *,
                   d: int = 32, n_classes: int = 10):
    """Deterministic toy servable classifier shared by the scheduler bench
    (``benchmarks/bench_scheduler.py``) and the knob autotuner
    (``launch/autotune.py``): stiffness (difficulty) is driven by the
    input mean through a softplus, the readout is a fixed seeded linear
    head — heavy enough to have a real pareto, light enough to replay
    hundreds of requests in seconds."""
    import jax
    import jax.numpy as jnp

    from repro.core import Integrator, get_tableau
    from repro.launch.engine import DepthModel

    W = np.asarray(jax.random.normal(jax.random.PRNGKey(7),
                                     (d, n_classes)) / np.sqrt(d))

    def field_of(x):
        k = jax.nn.softplus(jnp.mean(x, axis=-1, keepdims=True))
        return lambda s, z: -z * k

    g = None
    if solver.startswith("hyper_"):
        # toy low-order defect model, enough to exercise the residual
        # controller + fused correction path end to end
        g = lambda eps, s, z, dz: 0.3 * z + 0.1 * dz
    base = solver[len("hyper_"):] if solver.startswith("hyper_") else solver
    return DepthModel(
        embed=lambda x: x + 0.0,
        field_of=field_of,
        readout=lambda x, zT: zT @ jnp.asarray(W),
        integ=Integrator(tableau=get_tableau(base), g=g, fused=fused),
    )


def toy_refinable_classifier(base: str = "euler", fused: bool = True, *,
                             d: int = 32, n_classes: int = 10,
                             hidden: int = 8, seed: int = 11):
    """``toy_classifier``'s PARAMETRIC twin: the same stiff decay field
    and seeded linear head, but the correction is an element-wise MLP
    ``g_apply(gp, eps, s, z, dz)`` over features ``[z, dz, s, eps]``
    whose params ride the serving cells as traced inputs — the model the
    refinery tests/bench train, shadow-score, and hot-swap.

    The output layer is ZERO-initialized, so fresh params make g vanish
    exactly: a cold hyper-euler serve of this model is bitwise the base
    euler serve, and every later improvement is attributable to the
    ledger fit.

    Unlike ``toy_classifier``, the decay here is ANISOTROPIC (a fixed
    per-feature stiffness profile scales the row's difficulty): a
    row-uniform decay would leave the readout argmax invariant to any
    integration error, and agreement could never distinguish a refined
    correction from a frozen one."""
    import jax
    import jax.numpy as jnp

    from repro.core import Integrator, get_tableau
    from repro.launch.engine import DepthModel

    W = np.asarray(jax.random.normal(jax.random.PRNGKey(7),
                                     (d, n_classes)) / np.sqrt(d))
    w_feat = jnp.asarray(np.linspace(0.4, 1.6, d), jnp.float32)

    def field_of(x):
        k = jax.nn.softplus(jnp.mean(x, axis=-1, keepdims=True))
        return lambda s, z: -z * (k * w_feat)

    k1, = jax.random.split(jax.random.PRNGKey(seed), 1)
    g_params = {
        "w1": jnp.asarray(jax.random.normal(k1, (4, hidden)) * 0.5),
        "b1": jnp.zeros((hidden,)),
        "w2": jnp.zeros((hidden, 1)),
        "b2": jnp.zeros((1,)),
    }

    def g_apply(gp, eps, s, z, dz):
        # broadcast the (possibly per-sample) scalars up to z's shape:
        # serving cells call with z (B, d) / eps (B,), the ledger loss
        # vmaps per row with z (d,) / eps scalar — both land here
        up = lambda a: jnp.broadcast_to(
            jnp.reshape(jnp.asarray(a, z.dtype),
                        jnp.shape(a) + (1,) * (z.ndim - jnp.ndim(a))),
            z.shape)
        feats = jnp.stack([z, dz, up(s), up(eps)], axis=-1)
        h = jnp.tanh(feats @ gp["w1"] + gp["b1"])
        return (h @ gp["w2"])[..., 0] + gp["b2"][0]

    return DepthModel(
        embed=lambda x: x + 0.0,
        field_of=field_of,
        readout=lambda x, zT: zT @ jnp.asarray(W),
        integ=Integrator(tableau=get_tableau(base), fused=fused),
        g_apply=g_apply,
        g_params=g_params,
    )


def toy_flow_classifier(base: str = "euler", fused: bool = True, *,
                        d: int = 32, n_classes: int = 10,
                        hidden: int = 8, seed: int = 11,
                        flow_seed: int = 23):
    """``toy_refinable_classifier`` plus a K=0 FLOW HEAD: the same
    parametric correction g, and a second element-wise MLP wrapped by
    ``core.flowhead.make_flow_apply`` into a one-eval solution operator
    ``F(fp, eps, s, z, dz)`` whose params also ride the cells as traced
    inputs — the model the three-tier router serves and the refinery
    can hot-swap at ``param_site="flow"``.

    Both nets are ZERO-initialized at the output, so a cold serve makes
    g vanish exactly AND makes F exactly one full-span Euler step —
    every later agreement gain is attributable to the ledger fit."""
    import jax
    import jax.numpy as jnp

    from repro.core.flowhead import make_flow_apply

    model = toy_refinable_classifier(base, fused, d=d,
                                     n_classes=n_classes, hidden=hidden,
                                     seed=seed)
    k1, = jax.random.split(jax.random.PRNGKey(flow_seed), 1)
    flow_params = {
        "w1": jnp.asarray(jax.random.normal(k1, (4, hidden)) * 0.5),
        "b1": jnp.zeros((hidden,)),
        "w2": jnp.zeros((hidden, 1)),
        "b2": jnp.zeros((1,)),
    }

    def net(fp, eps, s, z, dz):
        # same broadcast contract as the toy g_apply: serving cells call
        # with batched rows, the ledger loss vmaps per row
        up = lambda a: jnp.broadcast_to(
            jnp.reshape(jnp.asarray(a, z.dtype),
                        jnp.shape(a) + (1,) * (z.ndim - jnp.ndim(a))),
            z.shape)
        feats = jnp.stack([z, dz, up(s), up(eps)], axis=-1)
        h = jnp.tanh(feats @ fp["w1"] + fp["b1"])
        return (h @ fp["w2"])[..., 0] + fp["b2"][0]

    return dataclasses.replace(
        model,
        flow_apply=make_flow_apply(net, order=model.integ.order),
        flow_params=flow_params,
    )
