"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis is an
    outer data-parallel axis crossing DCN (only gradient all-reduce)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for CPU sharding-semantics tests (run in a subprocess with
    a forced host device count — never in the main test process)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_serving_mesh(n_devices: int):
    """Pure data-parallel serving mesh: ``n_devices`` chips on one 'data'
    axis — the axis the in-flight slot pool shards over (there is no model
    axis at inference; the depth scan is local per slot). This is what
    ``launch/serve.py --mesh N`` builds."""
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    if n_devices > jax.device_count():
        raise ValueError(
            f"--mesh {n_devices} asks for more devices than visible "
            f"({jax.device_count()}); on CPU force virtual devices with "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    return jax.make_mesh((n_devices,), ("data",))


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch dimension shards over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def mesh_context(mesh):
    """Ambient-mesh context manager across jax versions: ``jax.set_mesh``
    where it exists (jax >= 0.5), else the Mesh's own context manager."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def sharded_solve(integ, f, z0, grid, *, mesh, **solve_kwargs):
    """Run ``Integrator.solve`` data-parallel over ``mesh``: the leading
    batch axis of the state (and of a batched ``grid.eps``) shards over the
    mesh's data axis ('data', the 'pod' outer axis being gradient-only),
    the depth scan stays local to each shard — the runtime-eps fused kernel
    looks its per-row step size up from prefetched SMEM, so batch rows
    share nothing and the solve emits no collectives.

    Thin policy layer over ``integ.solve(mesh=...)``: picks the batch axis
    from the mesh and checks divisibility up front (shard_map's own error
    is about block shapes, not requests). The slot-axis sibling for the
    in-flight scheduler's segment solve is ``sharded_segment`` below."""
    import jax.numpy as jnp
    axis = "data"
    B = jax.tree_util.tree_leaves(z0)[0].shape[0]
    n = mesh.shape[axis]
    if B % n:
        raise ValueError(
            f"batch {B} does not divide the '{axis}' mesh axis ({n}); pad "
            "or re-bucket the request batch (launch/engine.py max_batch)")
    if jnp.ndim(grid.eps) not in (0, 1):
        raise ValueError(f"grid.eps must be scalar or (B,), got "
                         f"ndim={jnp.ndim(grid.eps)}")
    return integ.solve(f, z0, grid, mesh=mesh, batch_axis=axis,
                       **solve_kwargs)


def sharded_segment(integ, field_of, xs, carry, seg, *, mesh, s0=0.0,
                    slot_axis: str = "data"):
    """Slot-axis-sharded segment advance WITH per-slot conditioning: the
    multi-device twin of ``Integrator.solve_segment(mesh=)`` for fields
    that condition on the request input (``field_of(x)`` closures —
    launch/engine.py DepthModel adapters).

    ``Integrator.solve_segment(mesh=)`` shards the SegmentCarry rows but
    treats whatever ``f`` closes over as replicated — correct for model
    params, wrong for per-slot conditioning (a field closed over the FULL
    ``xs`` rows would see B conditioning rows against B/n state rows
    inside a shard). This helper threads ``xs`` through the same
    ``shard_map``, so ``field_of`` is rebuilt per shard from exactly its
    slots' conditioning rows. Returns ``(carry', finished)`` like
    ``solve_segment``; everything stays slot-major and collective-free.

    Thin wrapper over the one shard_map plumbing in
    ``Integrator._solve_segment_sharded`` (shared with
    ``solve_segment(mesh=)``, so the divisibility policy — a remedy-
    naming error up front, like ``sharded_solve`` — and the spec layout
    cannot diverge between the two entry points)."""
    return integ._solve_segment_sharded(
        None, carry, seg, s0, mesh, slot_axis, field_of=field_of,
        cond=xs)


def sharded_segment_cell(integ, field_of, seg, *, mesh, s0=0.0,
                         slot_axis: str = "data", donate: bool = True,
                         g_apply=None):
    """The donated jit compilation of ``sharded_segment``: one
    ``(xs, z, k, Ks, eps, fs) -> (z', fs', meta)`` cell per
    ``(shape, seg, mesh)`` with the pool-sized carry buffers (z, fs)
    donated, exactly like the single-device ``Integrator.segment_cell``
    — sharding changes which device owns which slot rows, never the
    donation contract or the stacked ``[k'; finished]`` retire meta. The
    serving loop (launch/scheduler.py) calls the two interchangeably.
    ``g_apply`` appends the hot-swappable correction-params operand
    (replicated across the mesh), exactly as on the single-device cell."""
    return integ.segment_cell(field_of, seg, s0=s0, mesh=mesh,
                              slot_axis=slot_axis, donate=donate,
                              g_apply=g_apply)
