"""Scheduler-knob autotuning against the roofline cost oracle.

The tuner half of the ROADMAP's roofline item. The sequential-eval clock
prices pool WIDTH at zero (batch width is the axis an accelerator
parallelizes), so under it the trivially optimal scheduler wants an
infinitely wide slot pool — there is nothing to tune. The roofline
oracle (``launch/oracle.py::RooflineOracle``) prices a ``(shape, seg,
slots)`` segment in predicted device-us where weight reads amortize
SUBLINEARLY across rows, which turns ``seg`` / ``slots`` / the bucket
set into a real tradeoff:

  * wider pool: more capacity per segment, but every segment is fatter —
    worth it exactly while queueing dominates the tail;
  * smaller ``seg``: faster admission and retirement (smaller latency
    quantum), same per-useful-step price;
  * finer bucket grid: less snap-up overshoot (``snap_to_buckets`` only
    rounds K UP, so the controller's quality floor is preserved), less
    masked waste, shorter busy periods.

Each candidate is scored by REPLAYING one seeded Poisson trace through
``InflightScheduler`` under the oracle clock (the standard toy servable
from ``launch/workload.py``; the ORACLE carries the priced
architecture), reading p99 latency off the shared ledger
(``latency_stats``), and hillclimbed with
``roofline/hillclimb.py::hypothesis_loop`` — CONFIRMED knob changes are
kept, the rest refuted in the log. Verdicts persist to
``artifacts/tuned/<cell>.json`` next to the BENCH files;
``benchmarks/bench_scheduler.py`` emits the same verdicts as BENCH rows
and ``benchmarks/run.py --check`` fails when the two drift apart.

    PYTHONPATH=src python -m repro.launch.autotune [--budget small]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

from repro.configs import get
from repro.launch.engine import EngineConfig
from repro.launch.oracle import RooflineOracle
from repro.launch.scheduler import InflightScheduler
from repro.launch.workload import (
    heterogeneous_requests, latency_stats, poisson_trace, replay_scheduler,
    toy_classifier,
)
from repro.roofline.hillclimb import hypothesis_loop

TUNED_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "tuned")

# the serving cells the tuner tracks: one priced architecture per decode
# context — short-context and long-context decode sit at different points
# on the HBM roof, so their tuned knobs may legitimately differ
TUNE_CELLS = (
    {"cell": "qwen3_8b_decode4k", "arch": "qwen3_8b", "ctx": 4096},
    {"cell": "qwen3_8b_decode32k", "arch": "qwen3_8b", "ctx": 32768},
)

DEFAULT_BASE = {"seg": 2, "slots": 8, "buckets": (2, 4, 8, 16)}

DEFAULT_STEPS = [
    ("slots 8->16",
     "the old clock priced rows at zero; the roofline cell amortizes the "
     "per-group weight read across rows, so doubling the pool costs <2x "
     "per segment — under queueing load the extra capacity should cut "
     "p99 by more than the fatter segment adds",
     {"slots": 16}),
    ("slots 16->32",
     "same argument again — expected to refute once the pool stops being "
     "the bottleneck: every segment still gets fatter, but nothing "
     "queues long enough to buy it back",
     {"slots": 32}),
    ("seg 2->1",
     "halve the admission/retirement quantum: a finished slot refills "
     "after stages*1 steps instead of stages*2, and a newcomer waits at "
     "most one short segment — per-useful-step price unchanged, tail "
     "wait down",
     {"seg": 1}),
    ("buckets +(3,6,12)",
     "finer snap grid: K snap-up overshoot shrinks (snap_to_buckets "
     "only rounds UP, so the controller's quality floor is preserved), "
     "masked-step waste drops, busy periods shorten",
     {"buckets": (2, 3, 4, 6, 8, 12, 16)}),
]

_BUDGET_N = {"tiny": 16, "small": 48, "full": 128}


def make_objective(oracle: RooflineOracle, trace, *, solver: str = "euler",
                   max_batch: int = 8, tol: float = 5e-3):
    """Score one knob dict by a full trace replay on the oracle clock:
    (p99 latency in oracle units, summary info for the hillclimb log)."""

    def evaluate(kw):
        ecfg = EngineConfig(buckets=tuple(kw["buckets"]), tol=tol,
                            max_batch=max_batch, solver=solver,
                            fused=False)
        sched = InflightScheduler(toy_classifier(solver, fused=False),
                                  ecfg, slots=int(kw["slots"]),
                                  seg=int(kw["seg"]), oracle=oracle)
        stats = latency_stats(replay_scheduler(sched, trace))
        info = {"p99_latency": stats["p99_latency"],
                "p99_queue_wait": stats["p99_queue_wait"],
                "waste_frac": stats["waste_frac"],
                "occupancy": stats["occupancy"]}
        return stats["p99_latency"], info

    return evaluate


def autotune_cell(spec: Dict, *, budget: str = "small", seed: int = 3,
                  load: float = 1.0, base: Optional[Dict] = None,
                  steps=None) -> Dict:
    """Hillclimb (seg, slots, buckets) for one serving cell. ``load`` is
    the arrival rate in requests per base-pool field-eval time — 1.0
    runs the base pool past capacity so queueing dominates the tail
    (the regime where the knobs matter)."""
    n = _BUDGET_N.get(budget, _BUDGET_N["small"])
    base = dict(base or DEFAULT_BASE)
    oracle = RooflineOracle(get(spec["arch"]), ctx=spec["ctx"])
    # arrival rate converts from per-field-eval to per-oracle-unit so the
    # workload stresses every cell equally regardless of its step price
    rate = load / oracle.step_time(base["slots"])
    xs = heterogeneous_requests(n, 32, seed=seed)
    trace = poisson_trace(xs, rate=rate, seed=seed + 100)
    evaluate = make_objective(oracle, trace)
    best_kw, best_score, log = hypothesis_loop(
        evaluate, steps or DEFAULT_STEPS, base)
    return {
        "bench": "scheduler", "mode": "tuner", "cell": spec["cell"],
        "arch": spec["arch"], "ctx": spec["ctx"],
        "cost_unit": oracle.unit, "objective": "p99_latency",
        "trace": f"poisson_seed{seed}", "requests": n, "load": load,
        "base": {"seg": base["seg"], "slots": base["slots"],
                 "buckets": list(base["buckets"])},
        "chosen": {"seg": int(best_kw["seg"]),
                   "slots": int(best_kw["slots"]),
                   "buckets": list(best_kw["buckets"])},
        "p99_base": log[0]["score"], "p99_tuned": best_score,
        "confirmed": [r["change"] for r in log[1:]
                      if r["verdict"] == "CONFIRMED"],
        "log": log,
    }


def tuned_path(cell: str, out_dir: str = TUNED_DIR) -> str:
    return os.path.join(out_dir, f"{cell}.json")


def save_tuned(result: Dict, out_dir: str = TUNED_DIR) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = tuned_path(result["cell"], out_dir)
    with open(path, "w") as fh:
        json.dump(result, fh, indent=1, default=str)
    return path


def load_tuned(cell: str, out_dir: str = TUNED_DIR) -> Optional[Dict]:
    path = tuned_path(cell, out_dir)
    if not os.path.exists(path):
        return None
    with open(path) as fh:
        return json.load(fh)


def autotune_cells(budget: str = "small",
                   out_dir: str = TUNED_DIR) -> List[Dict]:
    """The sweep the tier-2 cron runs: every tracked cell, persisted."""
    results = []
    for spec in TUNE_CELLS:
        res = autotune_cell(spec, budget=budget)
        save_tuned(res, out_dir)
        results.append(res)
    return results


def main():
    ap = argparse.ArgumentParser(
        description="autotune scheduler knobs against the roofline oracle")
    ap.add_argument("--budget", default="small",
                    choices=sorted(_BUDGET_N))
    ap.add_argument("--out", default=TUNED_DIR)
    args = ap.parse_args()
    for res in autotune_cells(args.budget, args.out):
        print(f"== {res['cell']} (ctx={res['ctx']}, {res['cost_unit']}) ==")
        for row in res["log"]:
            if row["change"] == "baseline":
                print(f"  baseline: p99={row['p99_latency']} "
                      f"occ={row['occupancy']}")
            else:
                print(f"  [{row['iter']}] {row['change']}: "
                      f"{row['score_before']} -> {row['score_after']} "
                      f"({row['gain']}) {row['verdict']}")
        print(f"  chosen: {res['chosen']}  "
              f"p99 {res['p99_base']} -> {res['p99_tuned']}")
        print(f"  wrote {tuned_path(res['cell'], args.out)}")


if __name__ == "__main__":
    main()
