"""Pluggable serving cost oracles: what a probe, a pool segment, and a
drain solve cost on the virtual clock.

Both serving loops (``launch/engine.py``'s drain ``MultiRateEngine`` and
``launch/scheduler.py``'s in-flight ``InflightScheduler``) stamp
completions and ledgers through ONE of these oracles instead of inlining
cost arithmetic:

  * ``SequentialEvalOracle`` — the repo's original virtual clock, kept as
    the default so every BENCH baseline stays comparable: one cost unit
    per SEQUENTIAL vector-field evaluation (a K-step scan of an s-stage
    tableau costs ``s*K``, a probe costs its ``probe_nfe``). Batch width
    is FREE on this clock — it is the axis an accelerator parallelizes —
    which is exactly the proxy's blind spot: under it an infinitely wide
    slot pool is costless.
  * ``RooflineOracle`` — the same three events priced in predicted
    device-MICROseconds via the analytic roofline model
    (``roofline/costmodel.py::cell_cost``): one vector-field evaluation
    (= one depth group's forward) of a ``width``-row pool is a decode
    roofline cell at ``depth_fraction = 1/n_groups`` (weights and caches
    of the other groups never load), taking the dominant of
    compute/HBM/collective time with no overlap assumed. Width is no
    longer free — weight reads amortize SUBLINEARLY across rows — so
    packing/seg/slot decisions become a real tradeoff the scheduler-knob
    autotuner (``launch/autotune.py``) can optimize.

The oracle's ``unit`` tag rides into every ``TraceReport`` /
``latency_stats`` row (``cost_unit``), so BENCH files are explicit about
which clock produced which section. Only time-like fields change units
(cost, latency, queue wait, throughput); step COUNTS (useful/total/waste
slot-steps, occupancy) are clock-independent.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Protocol, Tuple, runtime_checkable

from repro.configs import ArchConfig, ShapeSpec
from repro.roofline.costmodel import Mesh2D, cell_cost

#: Unit tag for REAL-clock measurements (``time.perf_counter``, in
#: microseconds) — what ``benchmarks/bench_wallclock.py`` stamps on its
#: serving rows. Deliberately distinct from the virtual-clock units above
#: it in a BENCH file: ``sequential_evals`` and ``device_us`` are
#: *predictions* an oracle priced, ``wall_us`` is what the host actually
#: measured. The predicted-vs-measured section of BENCH_wallclock.json
#: joins a ``device_us`` prediction against a ``wall_us`` measurement
#: per tick — rows in the two units must be ratio'd, never summed.
WALLCLOCK_UNIT = "wall_us"


@runtime_checkable
class CostOracle(Protocol):
    """What a serving loop asks its clock. ``shape`` is the per-request
    input shape (a pool/batch cell key); ``width`` the number of rows the
    priced program runs over; ``stages`` the tableau's stage count."""

    unit: str

    def probe_cost(self, shape: Tuple[int, ...], width: int,
                   probe_nfe: int) -> float:
        """One admission probe over ``width`` rows (``probe_nfe`` field
        evaluations)."""
        ...

    def segment_cost(self, shape: Tuple[int, ...], seg: int, slots: int,
                     stages: int) -> float:
        """One ``seg``-step advance of a ``slots``-row slot pool."""
        ...

    def solve_cost(self, shape: Tuple[int, ...], k_max: int, width: int,
                   stages: int) -> float:
        """One drain batch of ``width`` rows scanned to ``k_max``."""
        ...

    def flow_cost(self, shape: Tuple[int, ...], width: int) -> float:
        """One K=0 flow-tier evaluation (core/flowhead.py) over ``width``
        rows: a single net eval, no solver steps."""
        ...


@dataclasses.dataclass(frozen=True)
class SequentialEvalOracle:
    """The original sequential-field-eval clock (see ``engine.StepReport``):
    cost counts sequential vector-field evaluations, batch-width free.
    The DEFAULT oracle — both serving loops construct it when none is
    passed, so the refactor is a pure relabel of the old inline
    arithmetic (pinned bit-for-bit in tests/test_scheduler.py)."""

    unit: str = "sequential_evals"

    def probe_cost(self, shape, width: int, probe_nfe: int) -> float:
        return float(probe_nfe)

    def segment_cost(self, shape, seg: int, slots: int,
                     stages: int) -> float:
        return float(stages * seg)

    def solve_cost(self, shape, k_max: int, width: int,
                   stages: int) -> float:
        return float(stages * k_max)

    def flow_cost(self, shape, width: int) -> float:
        # one correction-net eval ~ one field eval on this clock; the
        # flow tier's whole pitch is that this is its TOTAL solve cost
        return 1.0


class RooflineOracle:
    """Price serving events in predicted device-us via ``cell_cost``.

    ``cfg`` is the arch whose depth field is being served (the serve CLI
    passes its ``--arch``); ``ctx`` the decode context length of the
    priced cell; ``mesh`` the roofline mesh (default: one device);
    ``n_groups`` the number of depth groups one field evaluation covers
    (default: ``models/lm.py::group_layout``). ``step_time`` memoizes per
    pool width — the scheduler prices every segment of a (shape, seg,
    slots) cell from one cached cell evaluation."""

    unit = "device_us"

    def __init__(self, cfg: ArchConfig, *, ctx: int = 4096,
                 mesh: Optional[Mesh2D] = None,
                 n_groups: Optional[int] = None):
        if n_groups is None:
            from repro.models.lm import group_layout
            _, n_groups, _ = group_layout(cfg)
        self.cfg = cfg
        self.ctx = int(ctx)
        self.mesh = mesh or Mesh2D(1, 1, 1)
        self.n_groups = max(int(n_groups), 1)
        self._step_us: Dict[int, float] = {}

    def step_time(self, width: int) -> float:
        """Predicted device-us of ONE vector-field evaluation over
        ``width`` rows: the dominant roofline term of a decode cell at
        ``depth_fraction = 1/n_groups`` (no overlap assumed). Increasing
        in width but sublinear — the per-group weight read is shared by
        every row, which is what makes wider pools worth paying for."""
        width = max(int(width), 1)
        if width not in self._step_us:
            spec = ShapeSpec(name=f"oracle_decode{self.ctx}_b{width}",
                             kind="decode", seq_len=self.ctx,
                             global_batch=width)
            t = cell_cost(self.cfg, spec, self.mesh,
                          depth_fraction=1.0 / self.n_groups)
            self._step_us[width] = 1e6 * max(
                t.t_compute, t.t_memory, t.t_collective)
        return self._step_us[width]

    def probe_cost(self, shape, width: int, probe_nfe: int) -> float:
        return probe_nfe * self.step_time(width)

    def segment_cost(self, shape, seg: int, slots: int,
                     stages: int) -> float:
        return stages * seg * self.step_time(slots)

    def solve_cost(self, shape, k_max: int, width: int,
                   stages: int) -> float:
        return stages * k_max * self.step_time(width)

    def flow_cost(self, shape, width: int) -> float:
        # the flow net is eval-shaped (rank-r MLP ~ one depth group's
        # cost envelope), so price it as one field evaluation
        return self.step_time(width)


def make_oracle(name: str, cfg: Optional[ArchConfig] = None, *,
                ctx: int = 4096) -> CostOracle:
    """CLI-facing factory (``launch/serve.py --cost-oracle``)."""
    if name == "sequential":
        return SequentialEvalOracle()
    if name == "roofline":
        if cfg is None:
            raise ValueError(
                "the roofline oracle prices a specific architecture: "
                "pass the served ArchConfig")
        return RooflineOracle(cfg, ctx=ctx)
    raise ValueError(f"unknown cost oracle {name!r} "
                     "(expected 'sequential' or 'roofline')")
