"""Serving CLI — batching/eps policy lives in ``launch/engine.py``; this
module only parses flags and reports.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_4b --reduced \
        --batch 4 --prompt-len 16 --gen 32 [--solver hyper_euler --nfe 4] \
        [--g-ckpt /path/to/g --g-rank 32] [--multirate --tol 1e-2 \
         --buckets 2,4,8]

solver=discrete (default): standard full-depth cached decode.
solver=euler|heun|...|hyper_* : continuous-depth scoring. Fixed-K serving
with --nfe K, or error-controlled multi-rate serving with --multirate: a
cheap per-request probe assigns each request a mesh-length bucket and
same-shape requests pack into mixed-K batches solved in one masked
multi-rate pass — with --fused the whole per-step update (per-sample eps,
correction, freeze mask) is a single runtime-eps Pallas kernel pass, for
every bucket mix (see launch/engine.py). ``hyper_*`` solvers apply a
trained hypersolver correction loaded via --g-ckpt (HyperEuler etc.).
Reports per-request NFE and argmax agreement vs the full-depth forward.
--flow-ckpt + --flow-threshold add the K=0 flow tier on top of
--multirate: requests whose probe error sits below threshold*tol are
served by a learned solution operator in ONE net eval (core/flowhead.py);
non-finite flow evals escalate back into the K-bucket ladder
(status="escalated").

--inflight swaps the drain-the-queue engine for the continuous-batching
slot-pool scheduler (launch/scheduler.py): --slots slots advance --seg
depth steps per scheduling round, finished requests retire and refill
between segments. --arrival-trace poisson|bursty replays a seeded
streaming arrival trace (--arrival-rate requests per cost unit) through
the scheduler and reports p50/p99 latency + queue wait + masked-step
waste (launch/workload.py); ``none`` submits the whole batch at once.
--mesh N shards the slot pool over N devices ('data' axis, --slots
global rows split row-wise; launch/mesh.py::make_serving_mesh) — one
admission queue, per-device sub-pools, no collectives. --overlap swaps
the synchronous tick for the pipelined one (host bookkeeping overlaps
the in-flight device segment; uid-for-uid identical completions), and
--profile-dir saves a jax.profiler trace of the serving loop.

Request hardening (--inflight only; docs/serving.md "Failure
semantics"): --deadline gives every request that much oracle-clock
slack before it is dropped/evicted status="deadline"; --queue-cap
bounds the admission queue, with --overload-policy picking what an
over-cap submit does (shed terminally / degrade one bucket coarser /
block the caller). Diverged solves are quarantined on device and
retried once at a finer bucket before returning best-effort.

--refine attaches the online refinery (launch/refinery.py): serving
captures per-segment residual samples into a bounded ledger
(--capture-rate, --ledger-cap), a background trainer fits a candidate
correction between scheduler ticks (--refine-steps per tick,
checkpointed to --refine-dir), and every --shadow-every candidate steps
a shadow scorer replays a held-out trace and hot-swaps the candidate in
ONLY on non-regression — no retrace, no pool drain. --progress-every N
prints a live line every N ticks (hardening counters + refinery state).
SIGTERM/SIGINT drain gracefully: admission stops, in-flight slots flush,
the ledger (--ledger-out) and any pending candidate checkpoint land on
disk before exit.

Full flag reference with worked examples: docs/serving.md.
"""
from __future__ import annotations

import argparse
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.launch.engine import (
    EngineConfig, MultiRateEngine, greedy_generate, lm_depth_model,
    load_flow_params, load_g_params,
)
from repro.models.lm import discrete_nfe, group_layout, init_lm, lm_forward


def _profiled(profile_dir):
    """``jax.profiler.trace`` around the serving loop when --profile-dir
    is set (a no-op context otherwise): the saved timeline shows host
    phases against device segments, which is how overlap regressions are
    diagnosed (docs/serving.md)."""
    import contextlib
    if not profile_dir:
        return contextlib.nullcontext()
    return jax.profiler.trace(profile_dir)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--solver", default="discrete")
    ap.add_argument("--nfe", type=int, default=0,
                    help="fixed mesh length K (ignored with --multirate)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--g-ckpt", default=None,
                    help="CheckpointManager dir of a trained LM hypersolver "
                         "correction (enables hyper_* solvers)")
    ap.add_argument("--g-rank", type=int, default=32,
                    help="rank of the g_omega checkpoint being restored")
    ap.add_argument("--flow-ckpt", default=None,
                    help="CheckpointManager dir of a trained K=0 flow head "
                         "(core/flowhead.py); requires --flow-threshold")
    ap.add_argument("--flow-rank", type=int, default=64,
                    help="rank of the flow-head checkpoint being restored")
    ap.add_argument("--flow-threshold", type=float, default=0.0,
                    help="route requests whose probe error is below this "
                         "fraction of --tol to the K=0 flow tier (one net "
                         "eval, no solver; --multirate only). 0 disables "
                         "the tier; flow evals that come back non-finite "
                         "escalate into the K-bucket ladder "
                         "(status='escalated')")
    ap.add_argument("--multirate", action="store_true",
                    help="error-controlled per-request step sizes "
                         "(launch/engine.py) instead of one fixed K")
    ap.add_argument("--tol", type=float, default=1e-2,
                    help="probe local-error tolerance for --multirate")
    ap.add_argument("--buckets", default="2,4,8",
                    help="comma-separated serving K buckets for --multirate")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--fused", action="store_true",
                    help="route batch solves through the runtime-eps "
                         "Pallas kernel (any bucket mix fuses)")
    ap.add_argument("--inflight", action="store_true",
                    help="serve through the in-flight slot-pool scheduler "
                         "(launch/scheduler.py) instead of the drain engine")
    ap.add_argument("--seg", type=int, default=2,
                    help="depth steps per scheduling segment (--inflight)")
    ap.add_argument("--slots", type=int, default=4,
                    help="slot-pool width per request shape (--inflight)")
    ap.add_argument("--arrival-trace", default="none",
                    choices=["none", "poisson", "bursty"],
                    help="replay a seeded streaming arrival trace through "
                         "the scheduler (--inflight only)")
    ap.add_argument("--arrival-rate", type=float, default=0.25,
                    help="poisson arrival rate / bursty burst pacing, in "
                         "requests per virtual cost unit")
    ap.add_argument("--mesh", type=int, default=0,
                    help="shard the slot pool over N devices (--inflight "
                         "only): --slots is the GLOBAL pool width and must "
                         "be a multiple of N; on CPU force virtual devices "
                         "with XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N")
    ap.add_argument("--cost-oracle", default="sequential",
                    choices=["sequential", "roofline"],
                    help="virtual-clock pricing (launch/oracle.py): "
                         "'sequential' counts sequential field evals "
                         "(batch-width free, the BENCH baseline unit); "
                         "'roofline' prices probes/segments/solves of the "
                         "served --arch in predicted device-us via the "
                         "analytic roofline model (roofline/costmodel.py)")
    ap.add_argument("--overlap", action="store_true",
                    help="pipelined in-flight loop (--inflight only): "
                         "dispatch segment N+1 while segment N's retire "
                         "metadata is still in flight (JAX async dispatch "
                         "+ donated carries); completions are uid-for-uid "
                         "identical to the synchronous loop")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="per-request deadline SLACK on the oracle clock "
                         "(--inflight only): a request not finished "
                         "within this many cost units of its arrival is "
                         "dropped/evicted with status='deadline'; 0 = "
                         "no deadlines")
    ap.add_argument("--queue-cap", type=int, default=0,
                    help="bound the admission queue at this many waiting "
                         "requests (--inflight only); 0 = unbounded")
    ap.add_argument("--overload-policy", default="shed",
                    choices=["shed", "degrade", "block"],
                    help="what an over-cap submit does (--queue-cap): "
                         "'shed' refuses terminally (status='shed'), "
                         "'degrade' admits one K-bucket coarser under "
                         "pressure, 'block' raises to the caller")
    ap.add_argument("--profile-dir", default=None,
                    help="wrap the serving loop in jax.profiler.trace and "
                         "save the trace here (inspect with TensorBoard/"
                         "Perfetto) — wall-clock regressions become "
                         "diagnosable from the saved timeline")
    ap.add_argument("--refine", action="store_true",
                    help="attach the online refinery (--inflight only): "
                         "capture serving-time residuals into a ledger, "
                         "fit a candidate correction between scheduler "
                         "ticks, shadow-score it on a held-out trace and "
                         "hot-swap it in only on non-regression "
                         "(launch/refinery.py)")
    ap.add_argument("--refine-dir", default=None,
                    help="CheckpointManager directory for async candidate "
                         "checkpoints (--refine); restorable via --g-ckpt "
                         "on a later run")
    ap.add_argument("--capture-rate", type=float, default=1.0,
                    help="fraction of capture events the residual ledger "
                         "keeps (--refine); 0 disables capture entirely")
    ap.add_argument("--ledger-cap", type=int, default=512,
                    help="residual-ledger reservoir capacity in samples "
                         "(--refine)")
    ap.add_argument("--refine-steps", type=int, default=2,
                    help="candidate fit steps per scheduler tick "
                         "(--refine): the cooperative training budget "
                         "interleaved between segments")
    ap.add_argument("--shadow-every", type=int, default=50,
                    help="candidate steps between shadow-gate evaluations "
                         "(--refine)")
    ap.add_argument("--ledger-out", default=None,
                    help="flush the residual ledger to this .npz on exit "
                         "or graceful drain (--refine)")
    ap.add_argument("--progress-every", type=int, default=0,
                    help="print a live progress line every N scheduler "
                         "ticks (--inflight): hardening counters "
                         "(quarantined/deadline/requeued/shed) plus "
                         "refinery state under --refine; 0 = off")
    args = ap.parse_args()
    if args.mesh and not args.inflight:
        # same policy as --g-ckpt: a silently ignored flag would let a
        # run labeled multi-device report single-device numbers
        raise SystemExit("--mesh shards the in-flight slot pool; pass "
                         "--inflight with it (the drain engine has no "
                         "slot pool to shard)")
    if args.overlap and not args.inflight:
        # same policy: a run labeled overlapped must not silently report
        # drain-engine numbers
        raise SystemExit("--overlap pipelines the in-flight segment loop; "
                         "pass --inflight with it (the drain engine has "
                         "no segment loop to overlap)")
    if (args.deadline or args.queue_cap) and not args.inflight:
        # same policy: a run labeled deadline-bounded or overload-capped
        # must not silently report unbounded drain numbers
        raise SystemExit("--deadline/--queue-cap harden the in-flight "
                         "scheduler's admission; pass --inflight with "
                         "them")
    if args.overload_policy != "shed" and not args.queue_cap:
        raise SystemExit(f"--overload-policy {args.overload_policy} is "
                         "meaningless without --queue-cap (an unbounded "
                         "queue never overloads)")
    if args.refine and not args.inflight:
        # same policy as --mesh/--overlap: the refinery trains BETWEEN
        # scheduler ticks; the drain engine has no tick to interleave
        raise SystemExit("--refine interleaves with the in-flight "
                         "scheduler's ticks; pass --inflight with it")
    if args.refine and args.solver == "discrete":
        raise SystemExit("--refine fits a hypersolver correction; pass a "
                         "continuous --solver (e.g. euler/hyper_euler)")
    if not args.refine and (
            args.refine_dir or args.ledger_out
            or args.capture_rate != 1.0 or args.ledger_cap != 512
            or args.refine_steps != 2 or args.shadow_every != 50):
        raise SystemExit("--refine-dir/--capture-rate/--ledger-cap/"
                         "--refine-steps/--shadow-every/--ledger-out "
                         "tune the online refinery; pass --refine with "
                         "them (a silently ignored knob would mislabel "
                         "the run)")
    if args.progress_every and not args.inflight:
        raise SystemExit("--progress-every reports the in-flight "
                         "scheduler's tick counters; pass --inflight "
                         "with it")
    if args.flow_threshold and not args.multirate:
        # the flow tier routes off the admission probe's difficulty
        # estimate; fixed-K serving never probes
        raise SystemExit("--flow-threshold routes off the multi-rate "
                         "admission probe; pass --multirate with it "
                         "(fixed-K serving has no probe to route from)")
    if args.flow_threshold and not args.flow_ckpt:
        raise SystemExit("--flow-threshold needs --flow-ckpt (a trained "
                         "flow head): a fresh zero-init head is exactly "
                         "one full-span Euler step, which would mislabel "
                         "the K=0 tier's numbers")
    if args.flow_ckpt and not args.flow_threshold:
        # same policy as --g-ckpt/--mesh: a silently ignored checkpoint
        # would let a run labeled flow-tiered report ladder-only numbers
        raise SystemExit("--flow-ckpt is only read by the flow tier; "
                         "pass --flow-threshold > 0 with it")

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)

    if args.solver == "discrete":
        t0 = time.time()
        with _profiled(args.profile_dir):
            toks = greedy_generate(params, cfg, prompt, args.gen)
        dt = time.time() - t0
        print(f"[discrete] {args.batch}x{args.gen} tokens in {dt:.2f}s "
              f"({args.batch * args.gen / dt:.1f} tok/s), "
              f"NFE/token = {discrete_nfe(cfg)} groups")
        print("sample:", np.asarray(toks[0, :16]))
        return

    # continuous-depth scoring comparison at reduced NFE
    _, n_groups, _ = group_layout(cfg)
    g_params = None
    if args.g_ckpt:
        g_params = load_g_params(args.g_ckpt, cfg, rank=args.g_rank)
    if args.solver.startswith("hyper_") and g_params is None \
            and not args.refine:
        raise SystemExit(f"--solver {args.solver} needs --g-ckpt "
                         "(a trained correction checkpoint) — or "
                         "--refine to fit one from live traffic, "
                         "starting at a zero correction")

    flow_params = None
    if args.flow_ckpt:
        flow_params = load_flow_params(args.flow_ckpt, cfg,
                                       rank=args.flow_rank)

    buckets = tuple(int(b) for b in args.buckets.split(","))
    K_fixed = args.nfe or max(1, n_groups // 2)
    ecfg = EngineConfig(
        buckets=buckets if args.multirate else (K_fixed,),
        tol=args.tol,
        max_batch=args.max_batch,
        solver=args.solver,
        controller="auto" if args.multirate else "fixed",
        fixed_K=K_fixed,
        fused=args.fused,
        flow_threshold=args.flow_threshold,
    )
    model = lm_depth_model(params, cfg, solver=args.solver,
                           g_params=g_params, fused=args.fused,
                           refinable=args.refine, rank=args.g_rank,
                           flow_params=flow_params)
    # the roofline clock prices the SERVED arch at the prompt's context;
    # reported latency/wait switch to its unit (device-us) with it
    from repro.launch.oracle import make_oracle
    oracle = make_oracle(args.cost_oracle, cfg, ctx=args.prompt_len)

    full, _ = lm_forward(params, cfg, prompt)
    full_top = np.asarray(jnp.argmax(full, -1))

    if args.inflight:
        from repro.launch.scheduler import InflightScheduler
        from repro.launch.workload import (
            Arrival, bursty_trace, latency_stats, poisson_trace,
            replay_scheduler,
        )

        if args.arrival_trace != "none" and args.arrival_rate <= 0:
            raise SystemExit("--arrival-rate must be > 0 for "
                             f"--arrival-trace {args.arrival_trace}")
        mesh = None
        if args.mesh:
            from repro.launch.mesh import make_serving_mesh
            mesh = make_serving_mesh(args.mesh)

        ledger = refinery = None
        if args.refine:
            from repro.launch.refinery import (
                Refinery, RefineryConfig, ResidualLedger,
            )
            ledger = ResidualLedger(model, capacity=args.ledger_cap,
                                    capture_rate=args.capture_rate,
                                    seed=args.seed)
        sched = InflightScheduler(model, ecfg, slots=args.slots,
                                  seg=args.seg, mesh=mesh, oracle=oracle,
                                  overlap=args.overlap,
                                  deadline=args.deadline or None,
                                  queue_cap=args.queue_cap or None,
                                  overload_policy=args.overload_policy,
                                  ledger=ledger)
        if args.refine:
            # held-out seeded prompts the live trace never serves: the
            # shadow gate's replay set
            shadow = np.asarray(jax.random.randint(
                jax.random.PRNGKey(args.seed + 1000),
                (max(2, min(args.max_batch, 4)), args.prompt_len),
                0, cfg.vocab))
            refinery = Refinery(
                model, ledger,
                RefineryConfig(steps_per_tick=args.refine_steps,
                               shadow_every=args.shadow_every,
                               min_fill=min(32, args.ledger_cap),
                               ref_K=max(n_groups, max(buckets)),
                               seed=args.seed),
                ecfg=ecfg, shadow_xs=shadow, ckpt_dir=args.refine_dir)

        # graceful drain: first SIGTERM/SIGINT stops admission and lets
        # the in-flight slots flush; the ledger + any pending candidate
        # checkpoint land on disk below before the process exits
        draining = [False]

        def _on_signal(signum, frame):
            if draining[0]:
                raise KeyboardInterrupt  # second signal: give up the drain
            draining[0] = True
            print(f"[serve] caught signal {signum}: admission stopped, "
                  "draining in-flight slots")

        for sig in (signal.SIGTERM, signal.SIGINT):
            signal.signal(sig, _on_signal)

        ticks = [0]

        def on_tick(s):
            ticks[0] += 1
            if refinery is not None:
                refinery.tick([s])
            if args.progress_every \
                    and ticks[0] % args.progress_every == 0:
                parts = [f"t={s.now:.1f}", f"ticks={s.ticks}",
                         f"inflight={len(s)}",
                         f"quarantined={s.total_quarantined}",
                         f"deadline_evicted={s.total_deadline_evicted}",
                         f"requeued={s.total_requeued}",
                         f"shed={s.total_shed}"]
                if args.flow_threshold:
                    parts += [f"flow={s.total_flow_served}",
                              f"escalated={s.total_escalated}"]
                if refinery is not None:
                    st = refinery.status()
                    parts += [
                        f"ledger={st['ledger_fill']}/{ledger.capacity}",
                        f"cand_step={st['candidate_step']}",
                        f"promotions={st['promotions']}",
                        f"last_promotion={st['last_promotion']}"]
                print("[progress] " + " ".join(parts), flush=True)

        xs = np.asarray(prompt)
        t0 = time.time()
        with _profiled(args.profile_dir):
            if args.arrival_trace == "none" and refinery is None \
                    and not args.progress_every:
                results = sched.run(xs)
            else:
                if args.arrival_trace == "none":
                    # batch submit, replayed tick-by-tick so on_tick
                    # (refinery slice + progress line) still runs
                    trace = [Arrival(t=0.0, x=row) for row in xs]
                else:
                    trace = poisson_trace(xs, rate=args.arrival_rate,
                                          seed=args.seed) \
                        if args.arrival_trace == "poisson" else \
                        bursty_trace(xs, burst=args.slots,
                                     gap=args.slots / args.arrival_rate,
                                     seed=args.seed)
                report = replay_scheduler(
                    sched, trace, on_tick=on_tick,
                    should_admit=lambda: not draining[0])
                # records join back to prompt rows by uid (arrival order)
                results = sorted(report.records, key=lambda r: r.uid)
                if args.arrival_trace != "none":
                    print(f"[inflight {args.arrival_trace}] "
                          f"{latency_stats(report)}")
        dt = time.time() - t0
        if draining[0]:
            print(f"[serve] drained: {len(results)} completions flushed, "
                  f"{len(xs) - len(results)} arrivals never admitted")
        if refinery is not None:
            refinery.flush()   # pending async candidate checkpoint
            print(f"[refinery] {refinery.status()}")
        if ledger is not None and args.ledger_out:
            n_rows = ledger.flush(args.ledger_out)
            print(f"[ledger] flushed {n_rows} residual rows -> "
                  f"{args.ledger_out}")
        # shed/expired requests carry no outputs — agreement is over the
        # requests actually served (their status says why the rest
        # are not)
        # uid is submission order = prompt-row order, which survives a
        # partial (drained) run where enumerate order would not
        agree = {r.uid: float(np.mean(np.argmax(r.outputs, -1)
                                      == full_top[r.uid - 1]))
                 for r in results if r.outputs is not None}
        nfes = [r.nfe for r in results if r.outputs is not None]
        mode = "multirate" if args.multirate else f"K={K_fixed}"
        print(f"[{args.solver} {mode} inflight slots={args.slots} "
              f"seg={args.seg}] scored {len(agree)}/{args.batch} of "
              f"{args.batch}x{args.prompt_len} in "
              f"{dt:.2f}s; mean NFE {np.mean(nfes):.2f}/{n_groups} "
              f"(probe {sched.probe_nfe}); mean argmax agreement vs full "
              f"depth: {np.mean(list(agree.values())):.3f}")
        for r in results:
            # both record types (InflightCompleted / RequestRecord) stamp
            # queue_wait, latency, and status
            a = f"{agree[r.uid]:.3f}" if r.uid in agree else "-"
            print(f"  req {r.uid}: K={r.K} nfe={r.nfe} agree={a} "
                  f"wait={r.queue_wait:.1f} lat={r.latency:.1f} "
                  f"status={r.status}")
        return

    engine = MultiRateEngine(model, ecfg, oracle=oracle)
    t0 = time.time()
    with _profiled(args.profile_dir):
        results = engine.run(np.asarray(prompt))
    dt = time.time() - t0
    agree = [float(np.mean(np.argmax(r.outputs, -1) == full_top[i]))
             for i, r in enumerate(results)]
    nfes = [r.nfe for r in results]
    mode = "multirate" if args.multirate else f"K={K_fixed}"
    print(f"[{args.solver} {mode}] scored {args.batch}x{args.prompt_len} "
          f"in {dt:.2f}s; mean NFE {np.mean(nfes):.2f}/{n_groups} "
          f"(probe {engine.probe_nfe}); mean argmax agreement vs full "
          f"depth: {np.mean(agree):.3f}")
    for r, a in zip(results, agree):
        print(f"  req {r.uid}: K={r.K} nfe={r.nfe} "
              f"err_probe={r.err_probe:.3e} agree={a:.3f} "
              f"fused={r.fused_kernel}")


if __name__ == "__main__":
    main()
