"""Batched serving driver with selectable depth solver — where the paper's
technique meets the serving stack.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_4b --reduced \
        --batch 4 --prompt-len 16 --gen 32 [--solver hyper_euler --nfe 4]

solver=discrete (default): standard full-depth cached decode.
solver=euler|heun|... with --nfe K: continuous-depth inference
(models/cdepth.py) — per-token depth integration in K steps; with a trained
hypersolver checkpoint (--g-ckpt), the correction term is applied
(HyperEuler). Reports tokens/s and NFE per token.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get
from repro.models.lm import (
    group_layout, init_lm, init_lm_cache, lm_decode_step, lm_forward,
    lm_prefill,
)

def greedy_generate(params, cfg, prompt, gen_len: int, jit_step=None):
    """Standard cached decode; prompt: (B, P) int32. Prefill is a single
    batched forward (one compiled scan over the prompt, models/lm.py),
    then token-by-token greedy decode."""
    B, P = prompt.shape
    caches = init_lm_cache(cfg, B, P + gen_len)
    step = jit_step or jax.jit(
        lambda p, t, c, i: lm_decode_step(p, cfg, t, c, i))
    prefill = jax.jit(lambda p, toks, c: lm_prefill(p, cfg, toks, c))
    logits, caches = prefill(params, prompt, caches)
    out = [jnp.argmax(logits, -1).astype(jnp.int32)]
    for t in range(P, P + gen_len - 1):
        logits, caches = step(params, out[-1], caches,
                              jnp.asarray(t, jnp.int32))
        out.append(jnp.argmax(logits, -1).astype(jnp.int32))
    return jnp.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--solver", default="discrete")
    ap.add_argument("--nfe", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)

    if args.solver == "discrete":
        t0 = time.time()
        toks = greedy_generate(params, cfg, prompt, args.gen)
        dt = time.time() - t0
        _, n_groups, _ = group_layout(cfg)
        print(f"[discrete] {args.batch}x{args.gen} tokens in {dt:.2f}s "
              f"({args.batch * args.gen / dt:.1f} tok/s), "
              f"NFE/token = {n_groups} groups")
        print("sample:", np.asarray(toks[0, :16]))
    else:
        # continuous-depth scoring comparison at reduced NFE
        from repro.models.cdepth import lm_forward_cdepth
        _, n_groups, _ = group_layout(cfg)
        K = args.nfe or max(1, n_groups // 2)
        full, _ = lm_forward(params, cfg, prompt)
        t0 = time.time()
        approx = lm_forward_cdepth(params, cfg, prompt, K=K,
                                   solver=args.solver)
        dt = time.time() - t0
        agree = float(jnp.mean(jnp.argmax(full, -1) == jnp.argmax(approx, -1)))
        print(f"[{args.solver} K={K}] scored {args.batch}x{args.prompt_len} "
              f"in {dt:.2f}s; NFE {K}/{n_groups}; "
              f"argmax agreement vs full depth: {agree:.3f}")


if __name__ == "__main__":
    main()
