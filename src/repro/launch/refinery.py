"""Online refinery: closed-loop hypersolver refinement from live traffic.

The paper trains the correction g once, offline, by fitting the local
truncation residual (Sec. 3.2, Eq. 6 — ``core/residual.py``); serving
then throws that exact signal away on every probe and solve. This module
closes the loop, in three pieces layered beside (never inside) the
serving loops:

1. **Residual ledger** (``ResidualLedger``) — both serving loops
   optionally capture per-segment ``(s, z, eps, dz, R)`` residual samples
   from the states their cells already materialize: the in-flight
   scheduler from interior healthy slot rows at retire time, the drain
   engine from probe states at admission. ``R`` is the Eq. 6 residual
   computed on-device against a finer reference step (two half-steps of
   RK4), so fitting later needs neither the vector field nor a
   trajectory. The buffer is a bounded, seeded reservoir (algorithm R)
   behind an explicit ``capture_rate`` gate; the hot path pays at most
   ONE extra readout per retire, the capture never mutates serving state
   and is never priced by the cost oracle — capture-enabled completions
   stay uid-for-uid bitwise identical to capture-disabled ones
   (pinned in tests/test_refinery.py, benched in bench_refinery.py).

2. **Background trainer** (``Refinery.train_tick``) — a cooperative step
   budget interleaved BETWEEN scheduler ticks (no threads touch the
   compiled path): sample a ledger batch, run the shared
   ``core/train.py::make_fit_step`` over
   ``core/residual.py::ledger_fitting_loss``, and checkpoint candidate
   params via ``checkpoint/manager.py`` async save.

3. **Shadow scorer + promotion gate** (``Refinery.maybe_promote``) —
   replay a held-out seeded request set through a SHADOW engine (its own
   pools; the live loops are never drained), scoring candidate-vs-current
   g on agreement against a fine frozen reference and on held-out
   residual norm. Promotion only on non-regression; a promoted candidate
   hot-swaps into the running engines/schedulers between segments
   (``hot_swap_g`` — params are traced cell INPUTS, so the swap compiles
   nothing), and ``check_promoted`` re-scores post-promotion and swaps
   the previous params back on regression.

The params-are-inputs invariant this rests on lives in
``Integrator.segment_cell(g_apply=)`` and the two loops' probe/solve
cells; ``docs/architecture.md`` ("the refinery layer") is the prose
twin.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.integrate import (
    Integrator, _bcast, rk_stages, tree_axpy, tree_lincomb,
)
from repro.core.residual import flow_fitting_loss, ledger_fitting_loss
from repro.core.tableaus import get as get_tableau
from repro.core.train import make_fit_step
from repro.optim import adamw
from repro.optim.schedules import cosine_annealing

__all__ = ["ResidualLedger", "Refinery", "RefineryConfig"]


# --------------------------------------------------------------- the ledger ----

class ResidualLedger:
    """Bounded, seeded-reservoir host buffer of serving-time residual
    samples, plus the jitted capture cells that produce them.

    One sample is ``(s, eps, z, dz, R)`` for a single request row: the
    state ``z`` at depth ``s``, its step size, the field eval
    ``dz = f(s, z)``, and the Eq. 6 local truncation residual
    ``R = [z_ref(s+eps) - z - eps*psi] / eps^{p+1}`` with ``z_ref`` a
    two-half-step RK4 reference — exactly the target the paper fits g
    to, measured on the traffic actually being served.

    Capture cost discipline: one jitted call per capture event, full
    batch/pool width (so the cell set is bounded: one per (shape, width)
    — callers pad to pow2 widths), gated by ``capture_rate`` on the
    ledger's own seeded RNG. Nothing here touches serving state, the
    serving RNG, or the cost oracle's clock.

    ``holdout_every``: every Nth kept sample is diverted to a held-out
    split the trainer never samples — the shadow scorer's residual-norm
    metric (``holdout_batch``)."""

    def __init__(self, model, capacity: int = 512,
                 capture_rate: float = 1.0, seed: int = 0,
                 holdout_every: int = 8):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if not (0.0 <= capture_rate <= 1.0):
            raise ValueError(
                f"capture_rate must be in [0, 1], got {capture_rate}")
        self.model = model
        self.capacity = int(capacity)
        self.capture_rate = float(capture_rate)
        self.holdout_every = int(holdout_every)
        self._rng = np.random.RandomState(seed)
        self._samples: List[Tuple] = []      # (s, eps, z, dz, R) rows
        self._holdout: List[Tuple] = []
        self.seen = 0                        # kept rows ever offered
        self.captures = 0                    # capture events that fired
        self._cells: Dict[Tuple, Any] = {}

    # ------------------------------------------------------------- state ----
    @property
    def fill(self) -> int:
        return len(self._samples)

    @property
    def holdout_fill(self) -> int:
        return len(self._holdout)

    # ------------------------------------------------------ capture cells ----
    def _cell(self, shape: Tuple[int, ...], dtype, width: int):
        """One jitted ``(xs, z, s, eps) -> (dz, R)`` capture cell per
        (request shape, row width): base-tableau stage evals + psi, a
        finer RK4 reference (two half steps), and the Eq. 6 residual —
        all row-wise, nothing mutated."""
        key = (tuple(shape), str(dtype), int(width))
        if key not in self._cells:
            m = self.model
            tab = m.integ.tableau
            ref_tab = get_tableau("rk4")
            p1 = tab.order + 1

            @jax.jit
            def cap(xs, z, s, eps):
                f = m.field_of(xs)
                stages = rk_stages(f, tab, s, eps, z)
                dz = stages[0]
                psi = tree_lincomb(tab.b, stages)

                def fine(s_, h_, z_):
                    st = rk_stages(f, ref_tab, s_, h_, z_)
                    return tree_axpy(h_, tree_lincomb(ref_tab.b, st), z_)

                h2 = eps * 0.5
                z_ref = fine(s + h2, h2, fine(s, h2, z))
                # R = [z_ref - z - eps*psi] / eps^{p+1}  (paper Eq. 6,
                # with the finer step standing in for the ground truth)
                R = jax.tree_util.tree_map(
                    lambda zr, zz, ps: (zr - zz - _bcast(eps, zz) * ps)
                    / _bcast(eps ** p1, zz),
                    z_ref, z, psi)
                return dz, R

            self._cells[key] = cap
        return self._cells[key]

    # ----------------------------------------------------------- capture ----
    def _fires(self) -> bool:
        if self.capture_rate <= 0.0:
            return False
        if self.capture_rate >= 1.0:
            return True
        return bool(self._rng.random_sample() < self.capture_rate)

    def _offer(self, sample: Tuple) -> None:
        """Reservoir-add one kept sample (algorithm R), diverting every
        ``holdout_every``-th to the held-out split (cyclic overwrite once
        that split is at capacity)."""
        self.seen += 1
        if self.holdout_every and self.seen % self.holdout_every == 0:
            if len(self._holdout) < self.capacity:
                self._holdout.append(sample)
            else:
                self._holdout[self.seen % self.capacity] = sample
            return
        if len(self._samples) < self.capacity:
            self._samples.append(sample)
        else:
            j = int(self._rng.randint(0, self.seen))
            if j < self.capacity:
                self._samples[j] = sample

    def capture(self, xs, z, s, eps, keep=None) -> int:
        """Capture residual rows from a materialized request batch (the
        drain engine's admission hook). ``xs`` is the (B, ...) input
        batch, ``z`` the matching state pytree, ``s``/``eps`` (B,) float
        rows; ``keep`` masks rows in (quarantine-bound rows out). Pads to
        a pow2 row width so the capture-cell set stays bounded. Returns
        the number of rows offered to the reservoir."""
        if not self._fires():
            return 0
        xs = jnp.asarray(xs)
        B = xs.shape[0]
        if B == 0:
            return 0
        w = 1 << max(B - 1, 0).bit_length()
        if w != B:
            pad = jnp.arange(w) % B
            xs = xs[pad]
            z = jax.tree_util.tree_map(lambda l: l[pad], z)
            s = np.asarray(s)[np.arange(w) % B]
            eps = np.asarray(eps)[np.arange(w) % B]
        mask = np.ones(B, bool) if keep is None else \
            np.asarray(keep, bool).copy()
        cell = self._cell(tuple(xs.shape[1:]), xs.dtype, w)
        dz, R = cell(xs, z, jnp.asarray(s, jnp.float32),
                     jnp.asarray(eps, jnp.float32))
        return self._ingest(np.asarray(s), np.asarray(eps), z, dz, R,
                            np.flatnonzero(mask))

    def capture_pool(self, pool, rows: np.ndarray) -> int:
        """Capture residual rows from an in-flight slot pool (the
        scheduler's retire hook): one full-pool-width jitted readout of
        ``(dz, R)`` at each live row's current ``s = s0 + k*eps``, then a
        host-side gather of just ``rows``. The pool's resident buffers
        are READ (gathers enqueued before the next donating launch),
        never written."""
        if len(rows) == 0 or not self._fires():
            return 0
        s0 = self.model.span[0]
        s = (s0 + pool.k.astype(np.float64)
             * pool.eps.astype(np.float64)).astype(np.float32)
        cell = self._cell(tuple(pool.shape), pool.xs.dtype,
                          int(pool.k.shape[0]))
        dz, R = cell(pool._xs_dev, pool.z, jnp.asarray(s),
                     jnp.asarray(pool.eps, jnp.float32))
        return self._ingest(s, pool.eps, pool.z, dz, R, rows)

    def _ingest(self, s, eps, z, dz, R, rows) -> int:
        """Materialize the captured rows, drop non-finite ones (a row can
        go non-finite inside the reference step before the quarantine
        layer sees it), and offer the rest to the reservoir."""
        self.captures += 1
        z_h = jax.tree_util.tree_map(np.asarray, z)
        dz_h = jax.tree_util.tree_map(np.asarray, dz)
        R_h = jax.tree_util.tree_map(np.asarray, R)
        offered = 0
        for i in rows:
            i = int(i)
            row = lambda t: jax.tree_util.tree_map(lambda l: l[i], t)
            Ri = row(R_h)
            if not all(np.isfinite(l).all()
                       for l in jax.tree_util.tree_leaves(Ri)):
                continue
            self._offer((np.float32(s[i]), np.float32(eps[i]),
                         row(z_h), row(dz_h), Ri))
            offered += 1
        return offered

    # ---------------------------------------------------------- batching ----
    @staticmethod
    def _stack(samples: Sequence[Tuple]) -> Dict[str, Any]:
        s = np.asarray([t[0] for t in samples], np.float32)
        eps = np.asarray([t[1] for t in samples], np.float32)
        stack = lambda col: jax.tree_util.tree_map(
            lambda *ls: np.stack(ls), *[t[col] for t in samples])
        return {"s": s, "eps": eps, "z": stack(2), "dz": stack(3),
                "R": stack(4)}

    def sample_batch(self, n: int, rng: np.random.RandomState
                     ) -> Dict[str, Any]:
        """Stacked training batch of ``n`` reservoir samples, drawn with
        replacement (so the batch width — and the fit-step compilation —
        is constant from the first usable fill onward)."""
        if not self._samples:
            raise ValueError("empty ledger: nothing captured yet")
        idx = rng.randint(0, len(self._samples), size=n)
        return self._stack([self._samples[i] for i in idx])

    def holdout_batch(self, n: int) -> Optional[Dict[str, Any]]:
        """Deterministic fixed-width batch from the held-out split (rows
        cycled to width ``n`` so the eval cell compiles once); None until
        anything is held out."""
        if not self._holdout:
            return None
        return self._stack([self._holdout[i % len(self._holdout)]
                            for i in range(n)])

    # ------------------------------------------------------------- flush ----
    def flush(self, path: str) -> int:
        """Persist the ledger (train + holdout splits) as an .npz — the
        graceful-drain hook (serve.py SIGTERM/SIGINT): captured residuals
        survive the pre-emption for the next refinery run. Returns the
        number of rows written."""
        rows = self._samples + self._holdout
        if not rows:
            np.savez(path, s=np.zeros((0,), np.float32),
                     eps=np.zeros((0,), np.float32), n_train=0)
            return 0
        cols = self._stack(rows)
        flat = {"s": cols["s"], "eps": cols["eps"],
                "n_train": len(self._samples)}
        for name in ("z", "dz", "R"):
            for i, leaf in enumerate(
                    jax.tree_util.tree_leaves(cols[name])):
                flat[f"{name}_{i}"] = leaf
        np.savez(path, **flat)
        return len(rows)


# -------------------------------------------------------------- the trainer ----

@dataclasses.dataclass(frozen=True)
class RefineryConfig:
    """Knobs for the cooperative background trainer + promotion gate."""

    steps_per_tick: int = 2       # fit steps per scheduler tick
    batch_size: int = 32          # ledger rows per fit step
    min_fill: int = 32            # ledger fill before training starts
    lr: float = 3e-3              # AdamW peak lr (cosine to lr_min)
    lr_min: float = 1e-4
    weight_decay: float = 1e-6
    grad_clip: float = 10.0
    total_steps: int = 1000       # cosine horizon for the candidate
    ckpt_every: int = 50          # candidate steps between async saves
    shadow_every: int = 100       # candidate steps between shadow scores
    agreement_margin: float = 0.0  # allowed agreement slack at the gate
    resid_margin: float = 0.0     # allowed residual-norm slack at the gate
    holdout_rows: int = 64        # fixed eval width over the holdout split
    ref_K: int = 64               # fine frozen-reference mesh length
    seed: int = 0


class Refinery:
    """The closed loop: ledger batches -> candidate g -> shadow score ->
    promotion gate -> hot-swap (with rollback). Cooperative by
    construction — every method runs on the caller's thread between
    scheduler ticks; only the checkpoint write rides the
    CheckpointManager's async saver thread, which never touches jax.

    ``model`` must be parametric (``g_apply``/``g_params``);
    ``shadow_xs`` is the held-out seeded request set replayed by the
    shadow scorer (reuse launch/workload.py generators with a reserved
    seed). ``targets`` passed to ``tick``/``maybe_promote`` are live
    ``MultiRateEngine``/``InflightScheduler`` instances — promotion
    hot-swaps them between segments; their slot pools are never drained.
    """

    def __init__(self, model, ledger: ResidualLedger,
                 cfg: Optional[RefineryConfig] = None, *,
                 ecfg=None, shadow_xs=None, ckpt_dir: Optional[str] = None,
                 score_fn: Optional[Callable] = None,
                 param_site: str = "g"):
        from repro.launch.engine import EngineConfig, MultiRateEngine
        if param_site not in ("g", "flow"):
            raise ValueError(
                f"param_site={param_site!r}: expected 'g' (refine the "
                "hypersolver correction) or 'flow' (refine the K=0 flow "
                "head, core/flowhead.py)")
        if param_site == "g" and model.g_apply is None:
            raise ValueError(
                "Refinery needs a parametric model (DepthModel.g_apply/"
                "g_params): a closure g cannot hot-swap without retraces")
        if param_site == "flow" and model.flow_apply is None:
            raise ValueError(
                "Refinery(param_site='flow') needs a model with a flow "
                "head (DepthModel.flow_apply/flow_params)")
        self.model = model
        self.ledger = ledger
        self.cfg = cfg or RefineryConfig()
        self.param_site = param_site
        self._rng = np.random.RandomState(self.cfg.seed)

        # candidate/current params: current is what serving runs; the
        # candidate trains ahead of it on ledger batches
        as_dev = lambda t: jax.tree_util.tree_map(jnp.asarray, t)
        self.current = as_dev(model.g_params if param_site == "g"
                              else model.flow_params)
        self.candidate = self.current
        self.steps = 0                      # candidate fit steps taken
        self.last_loss: Optional[float] = None
        self.last_promotion: Optional[int] = None
        self.last_verdict: Optional[Dict] = None
        self.promotions = 0
        self.rejections = 0
        self.rollbacks = 0
        self._prev: Optional[Tuple[Any, Dict]] = None   # rollback handle
        self._current_score: Optional[Dict] = None

        opt = adamw(
            cosine_annealing(self.cfg.lr, self.cfg.lr_min,
                             self.cfg.total_steps),
            weight_decay=self.cfg.weight_decay)
        self._opt_state = opt.init(self.candidate)

        if param_site == "g":
            ga = model.g_apply

            def loss_fn(gp, s, eps, z, dz, R):
                g = lambda e, s_, z_, dz_: ga(gp, e, s_, z_, dz_)
                return ledger_fitting_loss(g, s, eps, z, dz, R)
        else:
            # the flow head fits the SAME ledger rows: for a structured
            # F = z + eps*dz + eps^{p+1}*net, flow_fitting_loss reduces
            # exactly to ledger_fitting_loss on the inner net — one
            # residual stream trains both tiers. relative=True because
            # the router only hands the flow tier confidently-easy rows:
            # the live ledger is difficulty-mixed, and the raw objective
            # would trade easy-row accuracy for hard-row magnitudes
            fa = model.flow_apply
            order = model.integ.order

            def loss_fn(fp, s, eps, z, dz, R):
                flow = lambda e, s_, z_, dz_: fa(fp, e, s_, z_, dz_)
                return flow_fitting_loss(flow, s, eps, z, dz, R,
                                         order=order, relative=True)

        self._fit_step = make_fit_step(loss_fn, opt, self.cfg.grad_clip)
        self._eval_loss = jax.jit(loss_fn)

        # shadow scorer: its OWN engine instance over the same model and
        # policy — candidate params score on cells that take gp as a
        # traced input, so scoring N candidates compiles once. At
        # param_site="flow" the engine is replaced by a dedicated
        # full-span flow cell (the K=0 tier has no mesh to serve).
        self._shadow_xs = None if shadow_xs is None else np.asarray(
            shadow_xs)
        self._score_fn = score_fn or self._argmax_agreement
        self._shadow_engine = None
        self._flow_score_fn = None
        self._ref_out = None
        if self._shadow_xs is not None:
            if param_site == "g":
                self._shadow_engine = MultiRateEngine(
                    model, ecfg or EngineConfig())
            else:
                self._flow_score_fn = self._flow_cell()
            self._ref_out = np.asarray(
                self._reference_cell()(jnp.asarray(self._shadow_xs)))

        self._ckpt = None
        if ckpt_dir is not None:
            from repro.checkpoint import CheckpointManager
            self._ckpt = CheckpointManager(ckpt_dir, keep=3,
                                           async_save=True)

    # ---------------------------------------------------------- training ----
    def train_tick(self) -> Optional[float]:
        """One cooperative training slice: up to ``steps_per_tick`` fit
        steps over ledger batches (no-op below ``min_fill``), candidate
        checkpointed asynchronously every ``ckpt_every`` steps. Returns
        the last batch loss, or None if the ledger is not ready."""
        if self.ledger.fill < max(self.cfg.min_fill, 1):
            return None
        loss = None
        for _ in range(self.cfg.steps_per_tick):
            b = self.ledger.sample_batch(self.cfg.batch_size, self._rng)
            self.candidate, self._opt_state, l = self._fit_step(
                self.candidate, self._opt_state, self.steps,
                b["s"], b["eps"], b["z"], b["dz"], b["R"])
            self.steps += 1
            loss = float(l)
            if self._ckpt is not None \
                    and self.steps % self.cfg.ckpt_every == 0:
                self._ckpt.save(self.steps, self.candidate)
        self.last_loss = loss
        return loss

    # ----------------------------------------------------------- scoring ----
    def _reference_cell(self):
        """Fine frozen reference for shadow agreement: the BASE tableau
        (no correction) at ``ref_K`` steps — the same ground-truth proxy
        the offline benches use."""
        m = self.model
        K = int(self.cfg.ref_K)
        ref = Integrator(tableau=m.integ.tableau)

        @jax.jit
        def run(xs):
            z0 = m.embed(xs)
            Ks = jnp.full((xs.shape[0],), K, jnp.int32)
            zT = ref.solve_multirate(m.field_of(xs), z0, m.span, Ks, K)
            return m.readout(xs, zT)

        return run

    def _flow_cell(self):
        """Shadow scorer for ``param_site="flow"``: the candidate flow
        params serve the held-out set as the K=0 tier would — one
        full-span F eval off ``(z0, dz0)`` plus readout — and score
        agreement against the same fine frozen reference. Params ride as
        a traced input, so scoring N candidates compiles once."""
        m = self.model
        h, s0 = m.span[1] - m.span[0], m.span[0]
        fa = m.flow_apply

        @jax.jit
        def run(xs, fp):
            z0 = m.embed(xs)
            dz0 = m.field_of(xs)(s0, z0)
            return m.readout(xs, fa(fp, h, s0, z0, dz0))

        return run

    @staticmethod
    def _argmax_agreement(outs: np.ndarray, ref: np.ndarray) -> float:
        """Default agreement: fraction of matching argmax over the last
        output axis (classification-style readouts; pass ``score_fn``
        for anything else)."""
        return float((np.argmax(outs, -1) == np.argmax(ref, -1)).mean())

    def shadow_score(self, gp) -> Dict[str, float]:
        """Score params on the held-out trace: agreement vs the fine
        frozen reference, mean NFE, and held-out residual-norm loss.
        Runs on the refinery's own shadow engine — live pools untouched."""
        out: Dict[str, float] = {}
        if self._shadow_engine is not None:
            self._shadow_engine.hot_swap_g(gp)
            recs = self._shadow_engine.run(self._shadow_xs)
            recs = sorted(recs, key=lambda c: c.uid)
            outs = np.stack([c.outputs for c in recs])
            out["agreement"] = self._score_fn(outs, self._ref_out)
            out["mean_nfe"] = float(np.mean([c.nfe for c in recs]))
        elif self._flow_score_fn is not None:
            outs = np.asarray(self._flow_score_fn(
                jnp.asarray(self._shadow_xs), gp))
            out["agreement"] = self._score_fn(outs, self._ref_out)
        hb = self.ledger.holdout_batch(self.cfg.holdout_rows)
        if hb is not None:
            out["resid"] = float(self._eval_loss(
                gp, hb["s"], hb["eps"], hb["z"], hb["dz"], hb["R"]))
        return out

    def _non_regression(self, cand: Dict, cur: Dict) -> bool:
        """The promotion gate: candidate must not regress on any metric
        both scores carry (agreement within ``agreement_margin``,
        held-out residual within ``resid_margin``)."""
        ok = True
        if "agreement" in cand and "agreement" in cur:
            ok &= cand["agreement"] >= cur["agreement"] \
                - self.cfg.agreement_margin
        if "resid" in cand and "resid" in cur:
            ok &= cand["resid"] <= cur["resid"] + self.cfg.resid_margin
        return bool(ok)

    def _swap(self, target, params) -> None:
        """Hot-swap ``params`` into a live serving loop at this
        refinery's param site — ``hot_swap_g`` or ``hot_swap_flow``,
        both zero-retrace by the params-are-inputs invariant."""
        if self.param_site == "g":
            target.hot_swap_g(params)
        else:
            target.hot_swap_flow(params)

    # ---------------------------------------------------- promote / roll ----
    def maybe_promote(self, targets: Sequence = ()) -> Dict:
        """Shadow-score the candidate against the serving params and
        hot-swap it into every target ONLY on non-regression. The
        rejected candidate keeps training — nothing it computed is ever
        observable in serving outputs. Returns the verdict dict
        (bench_refinery.py records these).

        Both sides are scored FRESH each gate: the held-out residual
        split keeps growing between gates, and judging the candidate on
        today's holdout against a current score cached on yesterday's
        would bias the comparison either way."""
        cand = self.shadow_score(self.candidate)
        cur = self.shadow_score(self.current)
        self._current_score = cur
        promoted = self._non_regression(cand, cur)
        self.last_verdict = {
            "step": self.steps, "promoted": promoted,
            "candidate": cand, "current": cur,
        }
        if promoted:
            self._prev = (self.current, cur)
            self.current = self.candidate
            self._current_score = cand
            for t in targets:
                self._swap(t, self.current)
            self.promotions += 1
            self.last_promotion = self.steps
        else:
            self.rejections += 1
        return self.last_verdict

    def check_promoted(self, targets: Sequence = ()) -> Optional[bool]:
        """Post-promotion guard: re-score the PROMOTED params (the
        held-out residual split keeps growing, so the score can drift
        after promotion) and roll the previous params back into every
        target if they now regress below the pre-promotion params.
        BOTH sides re-score on today's holdout — comparing a fresh
        promoted score against the stale pre-promotion baseline would
        fire rollbacks on holdout growth alone. None if there is
        nothing to check, else whether a rollback fired."""
        if self._prev is None:
            return None
        score = self.shadow_score(self.current)
        prev_params, _ = self._prev
        prev_score = self.shadow_score(prev_params)
        if self._non_regression(score, prev_score):
            self._current_score = score
            return False
        for t in targets:
            self._swap(t, prev_params)
        self.current = prev_params
        self._current_score = prev_score
        self._prev = None
        self.rollbacks += 1
        return True

    # -------------------------------------------------------- tick / misc ----
    def tick(self, targets: Sequence = ()) -> None:
        """The between-scheduler-ticks slice serve.py drives: train a
        little, and every ``shadow_every`` candidate steps run the
        shadow gate (then the post-promotion guard on the next gate)."""
        before = self.steps
        self.train_tick()
        crossed = (self.steps // self.cfg.shadow_every
                   > before // self.cfg.shadow_every)
        if crossed and self.steps > 0:
            self.check_promoted(targets)
            self.maybe_promote(targets)

    def flush(self) -> None:
        """Block until any pending async candidate checkpoint is on disk
        (the graceful-drain hook; ledger flushing is the caller's call —
        it needs a path)."""
        if self._ckpt is not None:
            self._ckpt.wait()

    def status(self) -> Dict[str, Any]:
        """One-line state for the serve.py live progress line."""
        return {
            "ledger_fill": self.ledger.fill,
            "ledger_seen": self.ledger.seen,
            "candidate_step": self.steps,
            "last_loss": self.last_loss,
            "last_promotion": self.last_promotion,
            "promotions": self.promotions,
            "rejections": self.rejections,
            "rollbacks": self.rollbacks,
        }
