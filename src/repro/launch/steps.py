"""pjit-able train / prefill / serve step builders + ShapeDtypeStruct input
specs for every (arch x shape) cell.

These are shared by the dry-run (AOT lower+compile on the production mesh)
and the real training/serving loops. Conventions:

  * train cell   = one optimizer step (microbatched grad accumulation,
    remat policy per config), donated params/opt-state.
  * prefill cell = full-sequence forward scoring pass (logits) — the
    compute-bound half of serving. (Cache-materializing prefill is a
    documented simplification; see EXPERIMENTS.md §Dry-run.)
  * decode cell  = one cached token step (serve_step): embed -> stacked
    per-group cache updates -> logits.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ArchConfig, ShapeSpec
from repro.distributed import sharding as shd
from repro.launch.mesh import batch_axes
from repro.models import encdec as whisper
from repro.models.lm import (
    dtype_of, init_lm, init_lm_cache, lm_decode_step, lm_forward, lm_loss,
)
from repro.optim import Optimizer, adamw, apply_updates, clip_by_global_norm
from repro.optim.schedules import linear_warmup_cosine


@dataclasses.dataclass(frozen=True)
class StepSettings:
    microbatches: int = 1
    remat: str = "dots"            # none | dots | full
    zero_opt: bool = True          # ZeRO-1 opt-state sharding
    seq_shard: bool = False        # SP: shard residual seq over 'model'
    fsdp: bool = False             # params data+model sharded (>= ~100B)
    grad_clip: float = 1.0
    lr: float = 3e-4
    moment_dtype: str = "float32"  # float32 | bfloat16
    acc_dtype: str = "float32"     # grad-accumulator dtype (bf16 >= ~340B)


def make_optimizer(s: StepSettings) -> Optimizer:
    return adamw(linear_warmup_cosine(s.lr, s.lr * 0.1, 200, 10_000),
                 weight_decay=0.1,
                 moment_dtype=dtype_of(s.moment_dtype))


# -------------------------------------------------------------- specs ----

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def abstract_params(cfg: ArchConfig):
    init = (whisper.init_encdec if cfg.is_encdec else init_lm)
    return jax.eval_shape(partial(init, cfg=cfg), jax.random.PRNGKey(0))


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    B, S = shape.global_batch, shape.seq_len
    dt = dtype_of(cfg.dtype)
    if cfg.is_encdec:
        L = cfg.max_target_len
        if shape.kind == "train":
            return {"frames": _sds((B, S, cfg.d_model), dt),
                    "tokens": _sds((B, L), jnp.int32),
                    "targets": _sds((B, L), jnp.int32)}
        if shape.kind == "prefill":
            return {"frames": _sds((B, S, cfg.d_model), dt),
                    "tokens": _sds((B, L), jnp.int32)}
        # decode: cross KV over S encoder frames, self cache of max_target
        return {
            "token": _sds((B,), jnp.int32),
            "caches": {
                "self": {
                    "k": _sds((cfg.dec_layers, B, L, cfg.n_kv, cfg.d_head), dt),
                    "v": _sds((cfg.dec_layers, B, L, cfg.n_kv, cfg.d_head), dt),
                },
                "cross": {
                    "k": _sds((cfg.dec_layers, B, S, cfg.n_kv, cfg.d_head), dt),
                    "v": _sds((cfg.dec_layers, B, S, cfg.n_kv, cfg.d_head), dt),
                },
            },
            "cur_index": _sds((), jnp.int32),
        }
    # decoder-only families
    fe = None
    if cfg.frontend == "patches":
        fe = _sds((B, cfg.n_frontend_tokens, cfg.d_model), dt)
    if shape.kind == "train":
        out = {"tokens": _sds((B, S), jnp.int32),
               "targets": _sds((B, S), jnp.int32)}
        if fe is not None:
            out["frontend"] = fe
        return out
    if shape.kind == "prefill":
        out = {"tokens": _sds((B, S), jnp.int32)}
        if fe is not None:
            out["frontend"] = fe
        return out
    caches = jax.eval_shape(partial(init_lm_cache, cfg, B, S))
    return {"token": _sds((B,), jnp.int32), "caches": caches,
            "cur_index": _sds((), jnp.int32)}


# ---------------------------------------------------------- shardings ----

def _batch_spec(mesh, B: int, extra_dims: int) -> P:
    ba = batch_axes(mesh)
    n = 1
    for a in ba:
        n *= mesh.shape[a]
    first = ba if B % n == 0 else None
    return P(first, *([None] * extra_dims))


def data_shardings(mesh, cfg: ArchConfig, specs) -> Any:
    """Shardings for the input_specs tree."""
    def one(path, leaf):
        ps = shd.path_str(path)
        B = leaf.shape[0] if leaf.ndim else 1
        if ps in ("tokens", "targets", "token"):
            return NamedSharding(mesh, _batch_spec(mesh, B, leaf.ndim - 1))
        if ps in ("frames", "frontend"):
            return NamedSharding(mesh, _batch_spec(mesh, B, leaf.ndim - 1))
        if ps == "cur_index":
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, cache_pspec(mesh, ps, leaf))
    return jax.tree_util.tree_map_with_path(one, specs)


def cache_pspec(mesh, path: str, leaf) -> P:
    """Cache sharding: batch over data axes when divisible, else the
    longest non-head axis; head/width axes over 'model'."""
    ba = batch_axes(mesh)
    n_b = 1
    for a in ba:
        n_b *= mesh.shape[a]
    n_m = mesh.shape["model"]
    shape = leaf.shape
    spec = [None] * leaf.ndim

    def try_axis(i, axes, size_needed):
        if spec[i] is None and shape[i] % size_needed == 0 \
                and shape[i] >= size_needed:
            spec[i] = axes
            return True
        return False

    if path.endswith("/k") or path.endswith("/v"):
        # (G?, B, S, KV, hd): model on KV if divisible else hd else S
        kv_i, hd_i = leaf.ndim - 2, leaf.ndim - 1
        s_i, b_i = leaf.ndim - 3, leaf.ndim - 4
        (try_axis(kv_i, "model", n_m) or try_axis(hd_i, "model", n_m)
         or try_axis(s_i, "model", n_m))
        (try_axis(b_i, ba, n_b) or try_axis(s_i, ba, n_b))
        return P(*spec)
    if path.endswith("_scale"):
        # int8 KV scales (G?, B, S, KV)
        kv_i, s_i, b_i = leaf.ndim - 1, leaf.ndim - 2, leaf.ndim - 3
        (try_axis(kv_i, "model", n_m) or try_axis(s_i, "model", n_m))
        (try_axis(b_i, ba, n_b) or try_axis(s_i, ba, n_b))
        return P(*spec)
    nd = leaf.ndim  # tail-layer caches lack the leading group axis
    if path.endswith("/S"):          # (G?, B, H, hd, hd)
        try_axis(nd - 3, "model", n_m)
        try_axis(nd - 4, ba, n_b)
        return P(*spec)
    if path.endswith("x_tmix") or path.endswith("x_cmix"):  # (G?, B, d)
        try_axis(nd - 1, "model", n_m)
        try_axis(nd - 2, ba, n_b)
        return P(*spec)
    if path.endswith("/conv"):       # (G?, B, 3, W)
        try_axis(nd - 1, "model", n_m)
        try_axis(nd - 3, ba, n_b)
        return P(*spec)
    if path.endswith("/h"):          # (G?, B, W)
        try_axis(nd - 1, "model", n_m)
        try_axis(nd - 2, ba, n_b)
        return P(*spec)
    return P(*spec)


# --------------------------------------------------------------- steps ----

def split_microbatches(batch: Dict[str, jnp.ndarray], m: int):
    return jax.tree_util.tree_map(
        lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), batch)


def make_train_step(cfg: ArchConfig, settings: StepSettings, mesh):
    """Returns (jit_step, abstract trees + shardings) for one optimizer
    update with microbatched gradient accumulation."""
    opt = make_optimizer(settings)

    def loss_fn(p, mb):
        if cfg.is_encdec:
            return whisper.encdec_loss(p, cfg, mb["frames"], mb["tokens"],
                                       mb["targets"], remat=settings.remat)
        return lm_loss(p, cfg, mb["tokens"], mb["targets"],
                       frontend=mb.get("frontend"), remat=settings.remat)

    a_params0 = abstract_params(cfg)
    g_sh = shd.grad_shardings(mesh, a_params0, zero=settings.zero_opt)

    def constrain_grads(g):
        # ZeRO-2: keep the fp32 accumulator reduce-scattered over 'data'
        # (an un-sharded fp32 replica of a 340B model is 85 GiB/device).
        # NamedShardings carry their mesh -> no ambient mesh ctx needed.
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, g, g_sh)

    def train_step(params, opt_state, step, batch):
        m = settings.microbatches
        if m == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            grads = constrain_grads(grads)
        else:
            mbs = split_microbatches(batch, m)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, met), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                g = constrain_grads(g)  # reduce-scatter before accumulate
                g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), met

            acc_dt = dtype_of(settings.acc_dtype)
            g0 = constrain_grads(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), params))
            (grads, loss), mets = jax.lax.scan(acc, (g0, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / m, grads)
            loss = loss / m
            metrics = jax.tree_util.tree_map(lambda a: jnp.mean(a, 0), mets)
        grads, gnorm = clip_by_global_norm(grads, settings.grad_clip)
        updates, opt_state = opt.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics

    a_params = abstract_params(cfg)
    a_opt = jax.eval_shape(opt.init, a_params)
    p_sh = (shd.grad_shardings(mesh, a_params, zero=True)
            if settings.fsdp else shd.param_shardings(mesh, a_params))
    o_sh = shd.opt_state_shardings(mesh, a_opt, zero=settings.zero_opt)
    jit_step = jax.jit(
        train_step,
        in_shardings=(p_sh, o_sh, NamedSharding(mesh, P()), None),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
    )
    return jit_step, opt, (a_params, a_opt, p_sh, o_sh)


def _param_sh(cfg, settings, mesh):
    a_params = abstract_params(cfg)
    p_sh = (shd.grad_shardings(mesh, a_params, zero=True)
            if settings.fsdp else shd.param_shardings(mesh, a_params))
    return a_params, p_sh


def make_prefill_step(cfg: ArchConfig, settings: StepSettings, mesh):
    def prefill(params, batch):
        if cfg.is_encdec:
            enc = whisper.encode(params, cfg, batch["frames"],
                                 remat=settings.remat)
            return whisper.decode_train(params, cfg, enc, batch["tokens"],
                                        remat=settings.remat)
        logits, _ = lm_forward(params, cfg, batch["tokens"],
                               frontend=batch.get("frontend"),
                               remat=settings.remat)
        return logits

    a_params, p_sh = _param_sh(cfg, settings, mesh)
    return jax.jit(prefill, in_shardings=(p_sh, None)), (a_params, p_sh)


def make_serve_step(cfg: ArchConfig, mesh, settings: StepSettings = None):
    settings = settings or StepSettings()

    def serve(params, token, caches, cur_index):
        if cfg.is_encdec:
            return whisper.encdec_decode_step(params, cfg, token, caches,
                                              cur_index)
        return lm_decode_step(params, cfg, token, caches, cur_index)

    a_params, p_sh = _param_sh(cfg, settings, mesh)
    return jax.jit(serve, in_shardings=(p_sh, None, None, None),
                   donate_argnums=(2,)), (a_params, p_sh)
