"""In-flight depth-continuous batching: a slot-pool scheduler over the
resumable segment solve.

The slot/segment model, against ``engine.py``'s drain loop
==========================================================

``MultiRateEngine.step()`` is a batch job: it drains the whole queue,
probes, packs by bucket, and solves each batch TO COMPLETION before any
new request gets a look. Under streaming traffic that shape loses twice:

  * **queue wait** — a request arriving just after a drain starts waits
    out the entire drain (worst case: every batch of it), even if a slot's
    worth of work would have served it immediately;
  * **masked-step waste** — a K=2 request packed next to a K=16 request
    rides the scan to k_max frozen, burning kernel passes on rows that
    finished 14 steps ago.

This module is the depth-axis analog of token-level continuous batching
from LLM serving (Orca/vLLM): where those schedulers admit and retire
sequences between *decode steps*, ``InflightScheduler`` admits and retires
requests between *depth segments* of the ODE solve. The pieces:

  * A fixed **slot pool** per request (shape, dtype) cell: ``slots`` rows
    of a resumable
    ``SegmentCarry`` (core/integrate.py) — per-slot state z, step counter
    k, target mesh length Ks, step size eps, and the admission probe's
    first stage. ``Ks == 0`` marks an empty slot; occupancy is DATA, not
    shape, so one ``(shape, seg)`` jit cell (one fused-kernel trace)
    serves every admission/refill pattern with zero recompiles.
  * A **segment** is ``seg`` masked multi-rate depth steps of the whole
    pool (``Integrator.solve_segment``) — the same fused kernel pass the
    drain engine uses, just chunked. A slot is finished exactly when
    ``k >= Ks``, which is the freeze mask the kernel already takes as a
    scalar-prefetch row.
  * Between segments, finished slots **retire** (readout -> completion
    record) and **refill** from the queue: admission probes the newcomers
    batch (padded to the pool width so the probe stays one jit cell),
    reusing the controller policy from ``launch/engine.py``
    (``make_controller`` + ``snap_to_buckets``), and scatters their rows
    into the free slots. A K=2 request admitted next to a half-done K=16
    request exits after its own ~K/seg segments instead of waiting out
    the batch.

Multi-device slot pools
-----------------------

Passing ``mesh=`` shards the SLOT axis over the mesh's data axis via
``shard_map`` (``Integrator.solve_segment(mesh=)`` /
``launch/mesh.py::sharded_segment``), the way ``Integrator.solve(mesh=)``
shards the batch axis: each device owns ``slots / n_devices`` rows of the
carry, the depth scan stays local, and no collective is ever emitted —
slots share nothing. Admission remains ONE global FIFO queue feeding the
global pool width; retire/refill between segments operates on the
gathered ``k``/``Ks`` host rows exactly as on one device. Because
occupancy is still data, one ``(shape, seg, mesh)`` jit cell (one
fused-kernel trace) serves every refill pattern per device. On the
virtual clock a segment's cost is batch-width-free, so sharding buys
capacity: n devices hold n-fold the slots at the same sequential cost
per tick.

Virtual-cost clock
------------------

The scheduler keeps a virtual clock (``self.now``) priced by a pluggable
cost oracle (``launch/oracle.py``). The default ``SequentialEvalOracle``
is the same unit as ``engine.StepReport``: SEQUENTIAL vector-field
evaluations (batch-width free — the axis an accelerator parallelizes),
where one segment costs ``tableau.stages * seg`` and an admission probe
costs the controller's ``probe_nfe``; ``RooflineOracle`` prices the same
events in predicted device-us via the analytic roofline model, making
pool width a real cost axis. Completions are stamped at the end of the
tick that retired them with only THEIR pool's probe + segment cost —
pools are concurrent cells (the PR-5 sharding semantics), so one pool's
segment never inflates another pool's latency, while ``total_cost``
still sums every pool's work as a resource ledger.
``launch/workload.py`` replays identical arrival traces against this
clock and the drain engine's, producing comparable queue wait / latency
/ waste numbers.

Choosing ``seg``: small ``seg`` = fast admission and low masked waste but
more per-segment host round-trips; large ``seg`` degenerates toward the
drain loop (``seg >= max bucket`` is exactly a drain with extra steps).
``seg`` of 2-4 with ``slots ~ max_batch`` is the useful regime.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.controllers import FixedController
from repro.core.integrate import SegmentCarry
from repro.launch.engine import (
    DepthModel, EngineConfig, Request, make_controller, prepare_model,
    probe_net_nfe, snap_to_buckets,
)
from repro.launch.oracle import CostOracle, SequentialEvalOracle


@dataclasses.dataclass(frozen=True)
class InflightCompleted:
    """Per-request completion record with the latency decomposition the
    drain engine cannot express: queue wait (submit -> slot admission) and
    service (admission -> retirement), in virtual cost units."""

    uid: int
    outputs: np.ndarray
    K: int                        # snapped mesh length actually integrated
    nfe: int                      # probe (net of reuse) + stages * K
    err_probe: float
    fused_kernel: bool
    t_submit: float
    t_admit: float
    t_done: float
    segments: int                 # pool segments this request rode

    @property
    def queue_wait(self) -> float:
        return self.t_admit - self.t_submit

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit


@dataclasses.dataclass(frozen=True)
class TickReport:
    """One scheduling round: admissions + at most one segment per pool."""

    cost: float = 0.0             # sequential evals this tick
    probe_cost: float = 0.0
    admitted: int = 0
    retired: int = 0
    useful_steps: int = 0         # slot-steps that advanced a live request
    total_steps: int = 0          # slots * seg over pools that ran
    occupied_steps: int = 0       # occupied-slot-steps (live at segment start)

    @property
    def waste_steps(self) -> int:
        """Slot-steps computed for frozen or empty rows."""
        return self.total_steps - self.useful_steps


class _SlotPool:
    """Fixed-width slot pool for one request shape: device-side carry
    (z / first_stage pytrees) + host-side bookkeeping rows (k, Ks, eps,
    uid, timestamps). All jit cells are pool-width, so occupancy never
    respecializes anything."""

    def __init__(self, sched: "InflightScheduler", shape: Tuple[int, ...],
                 dtype: np.dtype):
        self.sched = sched
        self.shape = shape
        n = sched.slots
        self.uid = np.full((n,), -1, np.int64)        # -1 = empty slot
        self.k = np.zeros((n,), np.int32)
        self.Ks = np.zeros((n,), np.int32)
        self.eps = np.ones((n,), np.float32)
        self.err = np.zeros((n,), np.float32)
        self.t_submit = np.zeros((n,), np.float64)
        self.t_admit = np.zeros((n,), np.float64)
        self.segments = np.zeros((n,), np.int32)
        self.xs = np.zeros((n,) + shape, dtype)
        self._xs_dev = None     # device mirror of xs, refreshed on admit
        self.z: Any = None                            # device pytree or None
        self.fs: Any = None                           # probe dz rows or None
        self._probe_fn = None
        self._embed_fn = None
        self._segment_fn = None
        self._readout_fn = None

    # ------------------------------------------------------- jit cells ----
    def _cells(self):
        m, integ = self.sched.model, self.sched.model.integ
        ctrl, seg = self.sched.controller, self.sched.seg
        s0 = m.span[0]

        if self._probe_fn is None:
            @jax.jit
            def probe(xs):
                z0 = m.embed(xs)
                p = ctrl.select(integ, m.field_of(xs), z0, m.span)
                return p.K, p.err, z0, p.dz0

            @jax.jit
            def embed(xs):
                return m.embed(xs)

            mesh = self.sched.mesh
            if mesh is None:
                @jax.jit
                def segment(xs, z, k, Ks, eps, fs):
                    carry = SegmentCarry(z, k, Ks, eps, fs)
                    carry, fin = integ.solve_segment(
                        m.field_of(xs), carry, seg, s0=s0)
                    return carry.z, carry.k, fin
            else:
                # multi-device pool: the carry AND the per-slot
                # conditioning rows shard over the mesh's slot axis; the
                # depth scan stays local per shard (sharded_segment), so
                # this is still ONE (shape, seg, mesh) jit cell — one
                # fused-kernel trace — across every refill pattern.
                from repro.launch.mesh import sharded_segment

                @jax.jit
                def segment(xs, z, k, Ks, eps, fs):
                    carry = SegmentCarry(z, k, Ks, eps, fs)
                    carry, fin = sharded_segment(
                        integ, m.field_of, xs, carry, seg, mesh=mesh,
                        s0=s0, slot_axis=self.sched.slot_axis)
                    return carry.z, carry.k, fin

            @jax.jit
            def readout(xs, z):
                return m.readout(xs, z)

            self._probe_fn, self._embed_fn = probe, embed
            self._segment_fn, self._readout_fn = segment, readout
        return (self._probe_fn, self._embed_fn, self._segment_fn,
                self._readout_fn)

    # ------------------------------------------------------- occupancy ----
    @property
    def free(self) -> np.ndarray:
        return np.flatnonzero(self.uid < 0)

    @property
    def occupied(self) -> np.ndarray:
        return self.uid >= 0

    def busy(self) -> bool:
        return bool((self.uid >= 0).any())

    # ------------------------------------------------------- admission ----
    def admit(self, reqs: List[Request], submit_t: Dict[int, float],
              now: float) -> float:
        """Probe ``reqs`` (padded to pool width: one probe jit cell per
        shape) and scatter them into free slots. Returns the probe cost."""
        probe_fn, embed_fn, _, _ = self._cells()
        sched = self.sched
        idx = self.free[:len(reqs)]
        assert len(idx) == len(reqs), "caller admits at most `free` requests"
        n_pad = sched.slots - len(reqs)
        xs_new = np.stack([r.x for r in reqs])
        assert xs_new.dtype == self.xs.dtype, (xs_new.dtype, self.xs.dtype)
        xs_pad = np.concatenate(
            [xs_new, np.repeat(xs_new[:1], n_pad, axis=0)]) \
            if n_pad else xs_new

        fixed = isinstance(sched.controller, FixedController)
        if fixed:
            z0 = embed_fn(jnp.asarray(xs_pad))
            dz0 = None
            Ks_raw = np.full((len(reqs),), sched.controller.K, np.int32)
            errs = np.zeros((len(reqs),), np.float32)
            probe_cost = 0.0
        else:
            Ks_dev, err_dev, z0, dz0 = probe_fn(jnp.asarray(xs_pad))
            Ks_raw = np.asarray(Ks_dev)[:len(reqs)]
            errs = np.asarray(err_dev)[:len(reqs)]
            # the probe is padded to pool width, so the oracle prices a
            # pool-width program regardless of how many rows refilled
            probe_cost = sched.oracle.probe_cost(
                self.shape, sched.slots,
                getattr(sched.controller, "probe_nfe", 0))
        Ks = snap_to_buckets(Ks_raw, sched.ecfg.buckets)

        # scatter: host rows directly, device pytrees leaf-wise. On the
        # pool's first admission the padded probe output IS the pool state.
        jidx = jnp.asarray(idx)
        take_rows = lambda t: jax.tree_util.tree_map(
            lambda l: l[:len(reqs)], t)
        if self.z is None:
            scatter = lambda _, new: jax.tree_util.tree_map(
                lambda l: jnp.asarray(l), new)
            self.z = scatter(None, z0)
            self.fs = None if dz0 is None else scatter(None, dz0)
        else:
            upd = lambda old, new: jax.tree_util.tree_map(
                lambda o, nl: o.at[jidx].set(nl), old, take_rows(new))
            self.z = upd(self.z, z0)
            if self.fs is not None:
                self.fs = upd(self.fs, dz0)
        span = sched.model.span
        for j, i in enumerate(idx):
            r = reqs[j]
            self.uid[i] = r.uid
            self.k[i] = 0
            self.Ks[i] = int(Ks[j])
            self.eps[i] = (span[1] - span[0]) / float(Ks[j])
            self.err[i] = float(errs[j])
            self.t_submit[i] = submit_t.pop(r.uid)
            self.t_admit[i] = now
            self.segments[i] = 0
            self.xs[i] = r.x
        # device mirror of xs: scatter only the refilled rows (a full
        # re-upload per admission would put the big operand back on the
        # host->device path every tick under steady streaming traffic)
        if self._xs_dev is None:
            self._xs_dev = jnp.asarray(self.xs)
        else:
            self._xs_dev = self._xs_dev.at[jidx].set(jnp.asarray(xs_new))
        return probe_cost

    # --------------------------------------------------------- segment ----
    def run_segment(self, now_done: float) -> Tuple[List[InflightCompleted],
                                                    int, int]:
        """One ``seg``-step advance of the whole pool; retire finished
        slots. Returns (completions, useful_steps, occupied_slots)."""
        _, _, segment_fn, readout_fn = self._cells()
        sched = self.sched
        k_old = self.k.copy()
        assert self._xs_dev is not None  # a busy pool has admitted
        z, k_dev, fin = segment_fn(
            self._xs_dev, self.z, jnp.asarray(self.k),
            jnp.asarray(self.Ks), jnp.asarray(self.eps), self.fs)
        self.z = z
        self.k = np.array(k_dev)  # np.asarray of a jax array is read-only
        occ = self.occupied
        self.segments[occ] += 1
        useful = int((self.k - k_old)[occ].sum())
        finished = occ & np.asarray(fin)
        done: List[InflightCompleted] = []
        if finished.any():
            outs = np.asarray(readout_fn(self._xs_dev, self.z))
            fused = sched.model.integ.fused_available(z=self.z)
            for i in np.flatnonzero(finished):
                K = int(self.Ks[i])
                done.append(InflightCompleted(
                    uid=int(self.uid[i]), outputs=outs[i], K=K,
                    nfe=sched.probe_nfe + sched.stages * K,
                    err_probe=float(self.err[i]), fused_kernel=fused,
                    t_submit=float(self.t_submit[i]),
                    t_admit=float(self.t_admit[i]), t_done=now_done,
                    segments=int(self.segments[i])))
                self.uid[i] = -1          # retire: slot becomes refillable
                self.Ks[i] = 0            # Ks==0 keeps the row frozen
                self.eps[i] = 1.0
                self.k[i] = 0
        return done, useful, int(occ.sum())


class InflightScheduler:
    """Continuous-batching serving loop: submit as traffic arrives, call
    ``step()`` repeatedly; each step admits into free slots and advances
    every busy pool by one segment. See the module docstring for the
    slot/segment model and the virtual-cost clock.

    ``mesh`` grows the pool past one chip: ``slots`` is the GLOBAL pool
    width, sharded row-wise over the mesh's ``slot_axis`` (per-device
    sub-pools of ``slots / axis_size`` rows) while admission stays one
    global FIFO queue. Between segments, retire/refill operates on the
    gathered per-slot ``k``/``Ks`` rows exactly as on one device — slot
    state is data, so the host never needs to know which device holds
    which slot — and the probe path is unchanged (one pool-width probe
    cell on the default device). ``slots`` must be a multiple of the
    axis size; checked here with a remedy-naming error."""

    def __init__(self, model: DepthModel,
                 engine_cfg: Optional[EngineConfig] = None,
                 *, slots: int = 4, seg: int = 2, mesh=None,
                 slot_axis: str = "data",
                 oracle: Optional[CostOracle] = None):
        engine_cfg = engine_cfg or EngineConfig()
        model = prepare_model(model, engine_cfg)
        if seg < 1:
            raise ValueError(f"seg must be >= 1, got {seg}")
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if mesh is not None:
            n = mesh.shape[slot_axis]
            if slots % n:
                raise ValueError(
                    f"slots={slots} does not divide the '{slot_axis}' "
                    f"mesh axis ({n}); the pool shards row-wise — size "
                    "slots as a multiple of the axis (e.g. "
                    f"slots={n * max(1, slots // n)})")
        self.mesh = mesh
        self.slot_axis = slot_axis
        self.model = model
        self.ecfg = engine_cfg
        self.slots = int(slots)
        self.seg = int(seg)
        self.controller = make_controller(model.integ, engine_cfg)
        self.oracle: CostOracle = oracle or SequentialEvalOracle()
        self.stages = model.integ.tableau.stages
        self.now = 0.0
        self.ticks = 0
        self.total_cost = 0.0
        self.total_probe_cost = 0.0
        self.total_useful_steps = 0
        self.total_slot_steps = 0
        self.total_occupied_steps = 0
        self.last_report = TickReport()
        self._queue: deque = deque()
        self._submit_t: Dict[int, float] = {}
        self._uid = 0
        self._pools: Dict[Tuple, _SlotPool] = {}

    # ----------------------------------------------------------- queue ----
    @property
    def probe_nfe(self) -> int:
        """Per-request probe cost net of the reused first stage (same
        accounting as MultiRateEngine.probe_nfe)."""
        return probe_net_nfe(self.controller)

    def submit(self, x, t: Optional[float] = None) -> int:
        """Queue a request. ``t`` is its arrival time on the virtual
        clock, defaulting to now; a past ``t`` records the true arrival
        of a request the caller is admitting late (the replay driver's
        normal case — queue wait starts at ``t``). A FUTURE ``t`` is
        only meaningful when the scheduler is idle, where the clock
        idle-jumps forward to it; with work pending it is refused,
        because jumping the clock mid-flight would bill every in-flight
        request for time no segment ran — ``step()`` until ``now >= t``
        instead (as ``launch/workload.py::replay_scheduler`` does)."""
        t = self.now if t is None else float(t)
        if t > self.now:
            if self.pending:
                raise ValueError(
                    f"submit at t={t} > now={self.now} with "
                    f"{self.pending} requests pending: advancing the "
                    "clock mid-flight would misattribute latency; "
                    "step() until now >= t, then submit")
            self.advance_to(t)
        self._uid += 1
        self._queue.append(Request(uid=self._uid, x=np.asarray(x)))
        self._submit_t[self._uid] = t
        return self._uid

    def advance_to(self, t: float) -> None:
        """Idle-jump the virtual clock forward (never backward). Refused
        while work is pending, for the same reason ``submit`` refuses a
        future ``t`` then: the jump would bill every in-flight request
        for time no segment ran."""
        if float(t) > self.now and self.pending:
            raise ValueError(
                f"advance_to(t={t}) > now={self.now} with {self.pending} "
                "requests pending: the clock only idle-jumps; step() "
                "until now >= t instead")
        self.now = max(self.now, float(t))

    @property
    def pending(self) -> int:
        """Requests not yet completed: queued + in flight."""
        inflight = sum(int(p.occupied.sum()) for p in self._pools.values())
        return len(self._queue) + inflight

    def __len__(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------ tick ----
    def step(self) -> List[InflightCompleted]:
        """One scheduling round: (1) refill free slots from the queue
        (probe-on-admission), (2) advance every busy pool by one segment,
        (3) retire finished slots. Advances the virtual clock by the
        tick's summed cost (the resource ledger); completions are stamped
        at end-of-tick with only THEIR pool's probe + segment cost —
        pools are concurrent cells, so per-request latency must not
        depend on ``(shape, dtype)`` key insertion order (it used to:
        the pre-oracle clock accumulated segment cost across pools in
        dict-iteration order, billing later-iterated pools for every
        earlier pool's segment; pinned in tests/test_scheduler.py)."""
        cost = 0.0
        probe_cost = 0.0
        admitted = 0
        pool_probe: Dict[Tuple, float] = {}
        # -- admission: FIFO per (shape, dtype) pool; a full pool does not
        #    block other pools' admissions (head-of-line blocking stays
        #    within a cell).
        if self._queue:
            batches: Dict[Tuple, List[Request]] = {}
            budget: Dict[Tuple, int] = {}
            leftover: deque = deque()
            while self._queue:
                r = self._queue.popleft()
                # pools key on (shape, dtype): same-shape requests of a
                # different dtype must not silently cast into a pool's
                # storage (the jit-cell retrace boundary, made explicit)
                key = (r.x.shape, r.x.dtype.str)
                if key not in self._pools:
                    self._pools[key] = _SlotPool(self, r.x.shape,
                                                 r.x.dtype)
                if key not in budget:
                    budget[key] = len(self._pools[key].free)
                if budget[key] > 0:
                    budget[key] -= 1
                    batches.setdefault(key, []).append(r)
                else:
                    leftover.append(r)
            self._queue = leftover
            for key, batch in batches.items():
                # every pool's probe starts at tick start (concurrent
                # cells) — t_admit no longer absorbs other pools' probes
                pc = self._pools[key].admit(batch, self._submit_t,
                                            self.now)
                pool_probe[key] = pc
                probe_cost += pc
                admitted += len(batch)
        cost += probe_cost
        # -- segments
        done: List[InflightCompleted] = []
        useful = total = occupied = retired = 0
        for key, pool in self._pools.items():
            if not pool.busy():
                continue
            seg_cost = self.oracle.segment_cost(pool.shape, self.seg,
                                                self.slots, self.stages)
            cost += seg_cost
            d, u, occ = pool.run_segment(
                self.now + pool_probe.get(key, 0.0) + seg_cost)
            done.extend(d)
            retired += len(d)
            useful += u
            total += self.slots * self.seg
            occupied += occ * self.seg
        self.now += cost
        self.ticks += 1
        self.total_cost += cost
        self.total_probe_cost += probe_cost
        self.total_useful_steps += useful
        self.total_slot_steps += total
        self.total_occupied_steps += occupied
        self.last_report = TickReport(
            cost=cost, probe_cost=probe_cost, admitted=admitted,
            retired=retired, useful_steps=useful, total_steps=total,
            occupied_steps=occupied)
        return done

    # ----------------------------------------------------- convenience ----
    def run(self, xs) -> List[InflightCompleted]:
        """Submit a batch at the current instant and drive to completion,
        returning results ordered by submission (uid join)."""
        uids = [self.submit(x) for x in np.asarray(xs)]
        results: Dict[int, InflightCompleted] = {}
        while self.pending:
            for c in self.step():
                results[c.uid] = c
        return [results[u] for u in uids]
