"""In-flight depth-continuous batching: a slot-pool scheduler over the
resumable segment solve.

The slot/segment model, against ``engine.py``'s drain loop
==========================================================

``MultiRateEngine.step()`` is a batch job: it drains the whole queue,
probes, packs by bucket, and solves each batch TO COMPLETION before any
new request gets a look. Under streaming traffic that shape loses twice:

  * **queue wait** — a request arriving just after a drain starts waits
    out the entire drain (worst case: every batch of it), even if a slot's
    worth of work would have served it immediately;
  * **masked-step waste** — a K=2 request packed next to a K=16 request
    rides the scan to k_max frozen, burning kernel passes on rows that
    finished 14 steps ago.

This module is the depth-axis analog of token-level continuous batching
from LLM serving (Orca/vLLM): where those schedulers admit and retire
sequences between *decode steps*, ``InflightScheduler`` admits and retires
requests between *depth segments* of the ODE solve. The pieces:

  * A fixed **slot pool** per request (shape, dtype) cell: ``slots`` rows
    of a resumable
    ``SegmentCarry`` (core/integrate.py) — per-slot state z, step counter
    k, target mesh length Ks, step size eps, and the admission probe's
    first stage. ``Ks == 0`` marks an empty slot; occupancy is DATA, not
    shape, so one ``(shape, seg)`` jit cell (one fused-kernel trace)
    serves every admission/refill pattern with zero recompiles.
  * A **segment** is ``seg`` masked multi-rate depth steps of the whole
    pool (``Integrator.solve_segment``) — the same fused kernel pass the
    drain engine uses, just chunked. A slot is finished exactly when
    ``k >= Ks``, which is the freeze mask the kernel already takes as a
    scalar-prefetch row.
  * Between segments, finished slots **retire** (readout -> completion
    record) and **refill** from the queue: admission probes the newcomers
    batch (padded to the pool width so the probe stays one jit cell),
    reusing the controller policy from ``launch/engine.py``
    (``make_controller`` + ``snap_to_buckets``), and scatters their rows
    into the free slots. A K=2 request admitted next to a half-done K=16
    request exits after its own ~K/seg segments instead of waiting out
    the batch.

Multi-device slot pools
-----------------------

Passing ``mesh=`` shards the SLOT axis over the mesh's data axis via
``shard_map`` (``Integrator.solve_segment(mesh=)`` /
``launch/mesh.py::sharded_segment``), the way ``Integrator.solve(mesh=)``
shards the batch axis: each device owns ``slots / n_devices`` rows of the
carry, the depth scan stays local, and no collective is ever emitted —
slots share nothing. Admission remains ONE global FIFO queue feeding the
global pool width; retire/refill between segments operates on the
gathered ``k``/``Ks`` host rows exactly as on one device. Because
occupancy is still data, one ``(shape, seg, mesh)`` jit cell (one
fused-kernel trace) serves every refill pattern per device. On the
virtual clock a segment's cost is batch-width-free, so sharding buys
capacity: n devices hold n-fold the slots at the same sequential cost
per tick.

Virtual-cost clock
------------------

The scheduler keeps a virtual clock (``self.now``) priced by a pluggable
cost oracle (``launch/oracle.py``). The default ``SequentialEvalOracle``
is the same unit as ``engine.StepReport``: SEQUENTIAL vector-field
evaluations (batch-width free — the axis an accelerator parallelizes),
where one segment costs ``tableau.stages * seg`` and an admission probe
costs the controller's ``probe_nfe``; ``RooflineOracle`` prices the same
events in predicted device-us via the analytic roofline model, making
pool width a real cost axis. Completions are stamped at the end of the
tick that retired them with only THEIR pool's probe + segment cost —
pools are concurrent cells (the PR-5 sharding semantics), so one pool's
segment never inflates another pool's latency, while ``total_cost``
still sums every pool's work as a resource ledger.
``launch/workload.py`` replays identical arrival traces against this
clock and the drain engine's, producing comparable queue wait / latency
/ waste numbers.

Choosing ``seg``: small ``seg`` = fast admission and low masked waste but
more per-segment host round-trips; large ``seg`` degenerates toward the
drain loop (``seg >= max bucket`` is exactly a drain with extra steps).
``seg`` of 2-4 with ``slots ~ max_batch`` is the useful regime.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.controllers import FixedController, TierRouter
from repro.core.integrate import SegmentCarry
from repro.distributed.fault import FaultInjector, RetryPolicy
from repro.launch.engine import (
    STATUSES, DepthModel, EngineConfig, QueueFull, Request,
    bound_integrator, make_controller, next_bucket_above, prepare_model,
    probe_net_nfe, screen_probe_errors, snap_to_buckets, validate_g_swap,
)
from repro.launch.oracle import CostOracle, SequentialEvalOracle

__all__ = ["InflightScheduler", "InflightCompleted", "TickReport",
           "STATUSES", "QueueFull", "RetryPolicy", "FaultInjector"]


@dataclasses.dataclass(frozen=True)
class InflightCompleted:
    """Per-request terminal record with the latency decomposition the
    drain engine cannot express: queue wait (submit -> slot admission) and
    service (admission -> retirement), in virtual cost units.

    ``status`` is the request's terminal disposition (engine.STATUSES;
    docs/serving.md "Failure semantics"): ``ok``/``retried`` carry real
    outputs, ``diverged``/``deadline`` carry the best-effort partial
    readout (or None if the request expired while still queued), and
    ``shed`` carries None — the overload policy refused it at admission.
    ``t_admit`` is the LAST admission (a retried request re-queues and
    re-admits); ``queue_wait`` therefore spans original submission to
    final admission."""

    uid: int
    outputs: np.ndarray
    K: int                        # snapped mesh length actually integrated
    nfe: int                      # probe (net of reuse) + stages * steps,
    #                               summed over every attempt
    err_probe: float
    fused_kernel: bool
    t_submit: float
    t_admit: float
    t_done: float
    segments: int                 # pool segments this request rode
    status: str = "ok"            # terminal status (engine.STATUSES)

    @property
    def queue_wait(self) -> float:
        return self.t_admit - self.t_submit

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit


@dataclasses.dataclass(frozen=True)
class TickReport:
    """One scheduling round: admissions + at most one segment per pool."""

    cost: float = 0.0             # sequential evals this tick
    probe_cost: float = 0.0
    admitted: int = 0
    retired: int = 0              # terminal records surfaced this tick
    useful_steps: int = 0         # slot-steps that advanced a live request
    total_steps: int = 0          # slots * seg over pools that ran
    occupied_steps: int = 0       # occupied-slot-steps (live at segment start)
    quarantined: int = 0          # slots force-retired non-finite this tick
    deadline_evicted: int = 0     # slots/queued requests evicted past deadline
    requeued: int = 0             # failed slots re-queued by the retry ladder
    shed: int = 0                 # admission refusals surfaced this tick
    probe_nonfinite: int = 0      # non-finite probe errors seen at admission
    flow_served: int = 0          # requests completed on the K=0 flow tier
    escalated: int = 0            # flow failures requeued to the K ladder

    @property
    def waste_steps(self) -> int:
        """Slot-steps computed for frozen or empty rows."""
        return self.total_steps - self.useful_steps


@dataclasses.dataclass
class _PendingSegment:
    """An in-flight segment: the async ``[k'; finished; nonfinite]``
    meta future plus the host snapshots needed to account it when it
    retires."""

    meta: Any                     # (3, B) int32 device future
    k_old: np.ndarray             # k rows at launch
    occ: np.ndarray               # occupancy at launch (bool row)
    t_done: float                 # virtual completion stamp for retires


@dataclasses.dataclass
class _FlowBatch:
    """K=0 flow-tier rows staged at admission. ``outs`` stays an async
    device future until ``finalize_retired`` (same deferral contract as
    ``_RetireBatch``); host rows are snapshots of the admitted requests —
    flow rows never touch a slot, so there is nothing to free. ``xs``
    keeps the ORIGINAL request inputs (never the chaos-poisoned probe
    copies) so an escalation requeues clean data."""

    n: int                        # real rows (outs may be pow2-padded)
    outs: Any                     # flow readout rows, device future
    t_done: float                 # admission probe + flow eval, this pool
    uid: np.ndarray
    err: np.ndarray
    t_submit: np.ndarray
    t_admit: float
    deadline: np.ndarray          # np.inf = none
    attempts: np.ndarray
    xs: np.ndarray


@dataclasses.dataclass
class _RetireBatch:
    """Retiring rows staged for materialization. ``outs`` stays an async
    device future until ``finalize_retired`` — the overlap loop
    materializes AFTER dispatching the next segment, so even the readout
    transfer hides behind device work. Host rows are SNAPSHOTS, because
    admission may refill the slots before the batch is finalized."""

    idx: np.ndarray
    outs: Any                     # readout rows, device future
    t_done: float
    fused: bool
    uid: np.ndarray
    K: np.ndarray
    k_done: np.ndarray            # depth steps actually taken (== K for ok)
    err: np.ndarray
    t_submit: np.ndarray
    t_admit: np.ndarray
    segments: np.ndarray
    status: List[str]             # terminal status per row


@dataclasses.dataclass(frozen=True)
class _RetireStats:
    """Per-pool retirement accounting for one segment."""

    retired: int = 0              # rows staged terminal (any status)
    useful: int = 0
    occupied: int = 0
    quarantined: int = 0
    deadline_evicted: int = 0
    requeued: int = 0


class _SlotPool:
    """Fixed-width slot pool for one request shape: device-side carry
    (z / first_stage pytrees) + host-side bookkeeping rows (k, Ks, eps,
    uid, timestamps). All segment jit cells are pool-width, so occupancy
    never respecializes anything; the finished-row readout cells are
    pow2-gated (see ``_readout_finished``)."""

    def __init__(self, sched: "InflightScheduler", shape: Tuple[int, ...],
                 dtype: np.dtype):
        self.sched = sched
        self.shape = shape
        n = sched.slots
        self.uid = np.full((n,), -1, np.int64)        # -1 = empty slot
        self.k = np.zeros((n,), np.int32)
        self.Ks = np.zeros((n,), np.int32)
        self.eps = np.ones((n,), np.float32)
        self.err = np.zeros((n,), np.float32)
        self.t_submit = np.zeros((n,), np.float64)
        self.t_admit = np.zeros((n,), np.float64)
        self.segments = np.zeros((n,), np.int32)
        self.deadline = np.full((n,), np.inf, np.float64)
        self.attempts = np.zeros((n,), np.int32)
        self.escalated = np.zeros((n,), bool)   # flow-escalation provenance
        self.xs = np.zeros((n,) + shape, dtype)
        self._xs_dev = None     # device mirror of xs, refreshed on admit
        self.z: Any = None                            # device pytree or None
        self.fs: Any = None                           # probe dz rows or None
        self._pending: Optional[_PendingSegment] = None
        self._staged: List[_RetireBatch] = []
        self._staged_flow: List[_FlowBatch] = []
        self.flow_retired_last = 0   # flow terminals in the last finalize
        self._readout_widths: set = set()   # pow2 readout cells traced
        self._probe_fn = None
        self._embed_fn = None
        self._segment_fn = None
        self._readout_fn = None
        self._flow_fn = None

    # ------------------------------------------------------- jit cells ----
    def _cells(self):
        m, integ = self.sched.model, self.sched.model.integ
        ctrl, seg = self.sched.controller, self.sched.seg
        s0 = m.span[0]

        if self._probe_fn is None:
            parametric = m.g_apply is not None

            @jax.jit
            def probe(xs, *gps):
                # on a parametric model the correction params ride as a
                # traced operand (gps = (gp,)) — the residual controller
                # consumes g in the probe, so the probe cell must be
                # swap-stable too (no retrace on hot_swap_g)
                ig = bound_integrator(m, gps[0]) if parametric else integ
                z0 = m.embed(xs)
                p = ctrl.select(ig, m.field_of(xs), z0, m.span)
                return p.K, p.err, z0, p.dz0

            @jax.jit
            def embed(xs):
                return m.embed(xs)

            # the segment cell donates the pool-sized carry buffers
            # (z, fs) — Integrator.segment_cell documents the aliasing
            # contract launch_segment/retire_pending are built around.
            # With a mesh, the carry AND the per-slot conditioning rows
            # shard over the mesh's slot axis and the depth scan stays
            # local per shard; either way this is ONE
            # (shape, seg[, mesh]) jit cell — one fused-kernel trace —
            # across every refill pattern. A parametric g appends its
            # params as a trailing traced (non-donated) operand: the
            # params-are-inputs invariant that makes hot_swap_g free.
            mesh = self.sched.mesh
            donate = self.sched.donate
            g_apply = m.g_apply
            if mesh is None:
                segment = integ.segment_cell(m.field_of, seg, s0=s0,
                                             donate=donate,
                                             g_apply=g_apply)
            else:
                from repro.launch.mesh import sharded_segment_cell
                segment = sharded_segment_cell(
                    integ, m.field_of, seg, mesh=mesh, s0=s0,
                    slot_axis=self.sched.slot_axis, donate=donate,
                    g_apply=g_apply)

            @jax.jit
            def readout(xs, z):
                return m.readout(xs, z)

            if m.flow_apply is not None:
                h, fs0 = m.span[1] - m.span[0], m.span[0]

                @jax.jit
                def flow(xs, z0, dz0, *fps):
                    # the K=0 tier: one flow-head eval + readout over the
                    # admission probe's already-materialized (z0, dz0);
                    # flow params ride as a traced trailing operand (the
                    # params-are-inputs invariant, same as g). Widths are
                    # pow2-gated by the caller like _readout_finished.
                    return m.readout(xs, m.flow_apply(fps[0], h, fs0,
                                                      z0, dz0))

                self._flow_fn = flow

            self._probe_fn, self._embed_fn = probe, embed
            self._segment_fn, self._readout_fn = segment, readout
        return (self._probe_fn, self._embed_fn, self._segment_fn,
                self._readout_fn)

    # ------------------------------------------------------- occupancy ----
    @property
    def free(self) -> np.ndarray:
        return np.flatnonzero(self.uid < 0)

    @property
    def occupied(self) -> np.ndarray:
        return self.uid >= 0

    def busy(self) -> bool:
        return bool((self.uid >= 0).any())

    # ------------------------------------------------------- admission ----
    def admit(self, reqs: List[Request], submit_t: Dict[int, float],
              now: float, degrade: bool = False) -> Tuple[float, int]:
        """Probe ``reqs`` (padded to pool width: one probe jit cell per
        shape) and scatter them into free slots. Returns (probe cost,
        non-finite probe count). ``degrade`` caps every admission one
        bucket coarser (the overload policy's pressure response)."""
        probe_fn, embed_fn, _, _ = self._cells()
        sched = self.sched
        idx = self.free[:len(reqs)]
        assert len(idx) == len(reqs), "caller admits at most `free` requests"
        n_pad = sched.slots - len(reqs)
        rows = [r.x for r in reqs]
        if sched.fault_injector is not None:
            # chaos hook: poisoned rows feed the probe and the device
            # mirror; self.xs keeps the ORIGINAL input, so a retry of a
            # transiently-poisoned request re-admits clean data
            rows = [sched.fault_injector.corrupt_admission(
                r.uid, r.attempts, x) for r, x in zip(reqs, rows)]
        xs_new = np.stack(rows)
        assert xs_new.dtype == self.xs.dtype, (xs_new.dtype, self.xs.dtype)
        xs_pad = np.concatenate(
            [xs_new, np.repeat(xs_new[:1], n_pad, axis=0)]) \
            if n_pad else xs_new

        fixed = isinstance(sched.controller, FixedController)
        probe_nonfinite = 0
        if fixed:
            z0 = embed_fn(jnp.asarray(xs_pad))
            dz0 = None
            Ks_raw = np.full((len(reqs),), sched.controller.K, np.int32)
            errs = np.zeros((len(reqs),), np.float32)
            probe_cost = 0.0
        else:
            Ks_dev, err_dev, z0, dz0 = probe_fn(jnp.asarray(xs_pad),
                                                *sched._g_args())
            Ks_raw = np.asarray(Ks_dev)[:len(reqs)]
            errs = np.asarray(err_dev)[:len(reqs)]
            # the silent k_max clamp in mesh_for_tolerance becomes an
            # observable signal here (one-time warning + TickReport
            # counter); the request itself is the quarantine layer's job
            probe_nonfinite = screen_probe_errors(errs)
            # the probe is padded to pool width, so the oracle prices a
            # pool-width program regardless of how many rows refilled
            probe_cost = sched.oracle.probe_cost(
                self.shape, sched.slots,
                getattr(sched.controller, "probe_nfe", 0))
        Ks = snap_to_buckets(Ks_raw, sched.ecfg.buckets)
        if degrade:
            # graceful degradation: serve one bucket coarser than asked
            # while the queue is over pressure — agreement trades off
            # measurably, nothing is refused
            b = np.asarray(sorted(sched.ecfg.buckets), np.int32)
            Ks = b[np.maximum(np.searchsorted(b, Ks) - 1, 0)]
        # retry-ladder escalation: a re-queued request never re-serves
        # below its K_floor (the next-finer bucket than the failed one)
        floors = np.asarray([r.K_floor for r in reqs], np.int32)
        Ks = np.maximum(Ks, floors)

        # K=0 flow tier (core/flowhead.py): probe-easy rows never touch
        # a slot — one flow-head eval off the probe's (z0, dz0), staged
        # async and materialized in finalize_retired. The remaining rows
        # (and the padded probe outputs) are subset so every line below
        # runs exactly as if only they had been admitted; with the tier
        # disabled (router is None) this block never executes and
        # admission is bitwise identical to pre-flow.
        if sched.router is not None and not fixed:
            flow_sel = np.asarray(sched.router.flow_mask(
                errs, sched.ecfg.tol, floors))
            if flow_sel.any():
                flow_cost = sched.oracle.flow_cost(
                    self.shape, int(flow_sel.sum()))
                sched._flow_cost_tick += flow_cost
                self._stage_flow(reqs, flow_sel, xs_new, z0, dz0, errs,
                                 submit_t, now,
                                 t_done=now + probe_cost + flow_cost)
                keep = np.flatnonzero(~flow_sel)
                reqs = [reqs[i] for i in keep]
                xs_new = xs_new[keep]
                Ks, errs = Ks[keep], errs[keep]
                idx = idx[:len(reqs)]
                if not len(reqs):
                    return probe_cost, probe_nonfinite
                # remap the PADDED probe outputs so rows 0..len(reqs)-1
                # are the kept rows (take_rows and the first-admission
                # full-pool shortcut below both rely on that layout)
                pad_pos = jnp.asarray(np.concatenate(
                    [keep, np.full(sched.slots - len(keep), keep[0])]))
                remap = lambda t: jax.tree_util.tree_map(
                    lambda l: l[pad_pos], t)
                z0 = remap(z0)
                dz0 = None if dz0 is None else remap(dz0)

        # scatter: host rows directly, device pytrees leaf-wise. On the
        # pool's first admission the padded probe output IS the pool state.
        jidx = jnp.asarray(idx)
        take_rows = lambda t: jax.tree_util.tree_map(
            lambda l: l[:len(reqs)], t)
        if self.z is None:
            scatter = lambda _, new: jax.tree_util.tree_map(
                lambda l: jnp.asarray(l), new)
            self.z = scatter(None, z0)
            self.fs = None if dz0 is None else scatter(None, dz0)
        else:
            upd = lambda old, new: jax.tree_util.tree_map(
                lambda o, nl: o.at[jidx].set(nl), old, take_rows(new))
            self.z = upd(self.z, z0)
            if self.fs is not None:
                self.fs = upd(self.fs, dz0)
        span = sched.model.span
        for j, i in enumerate(idx):
            r = reqs[j]
            self.uid[i] = r.uid
            self.k[i] = 0
            self.Ks[i] = int(Ks[j])
            self.eps[i] = (span[1] - span[0]) / float(Ks[j])
            self.err[i] = float(errs[j])
            self.t_submit[i] = submit_t.pop(r.uid)
            self.t_admit[i] = now
            self.segments[i] = 0
            self.deadline[i] = np.inf if r.deadline is None else r.deadline
            self.attempts[i] = r.attempts
            self.escalated[i] = r.escalated
            self.xs[i] = r.x
        # device mirror of xs: scatter only the refilled rows (a full
        # re-upload per admission would put the big operand back on the
        # host->device path every tick under steady streaming traffic)
        if self._xs_dev is None:
            self._xs_dev = jnp.asarray(self.xs)
        else:
            self._xs_dev = self._xs_dev.at[jidx].set(jnp.asarray(xs_new))
        return probe_cost, probe_nonfinite

    def _stage_flow(self, reqs: List[Request], flow_sel: np.ndarray,
                    xs_new: np.ndarray, z0, dz0, errs: np.ndarray,
                    submit_t: Dict[int, float], now: float,
                    t_done: float) -> None:
        """Dispatch the flow-tier rows' K=0 eval (async device future,
        pow2-padded gather like ``_readout_finished``) and stage the
        batch for ``finalize_retired``. Rows are gathered from the
        PADDED probe outputs, so this is purely a read of state the
        probe already materialized — no extra probe, no slot."""
        sched = self.sched
        fidx = np.flatnonzero(flow_sel)
        w = min(1 << (len(fidx) - 1).bit_length(), sched.slots)
        pad = fidx if w == len(fidx) else np.concatenate(
            [fidx, np.repeat(fidx[:1], w - len(fidx))])
        jf = jnp.asarray(pad)
        gather = lambda t: jax.tree_util.tree_map(lambda l: l[jf], t)
        outs = self._flow_fn(jnp.asarray(xs_new[pad]), gather(z0),
                             gather(dz0), *sched._flow_args())
        rs = [reqs[i] for i in fidx]
        self._staged_flow.append(_FlowBatch(
            n=len(fidx), outs=outs, t_done=t_done,
            uid=np.asarray([r.uid for r in rs], np.int64),
            err=errs[fidx].copy(),
            t_submit=np.asarray([submit_t.pop(r.uid) for r in rs],
                                np.float64),
            t_admit=now,
            deadline=np.asarray(
                [np.inf if r.deadline is None else r.deadline
                 for r in rs], np.float64),
            attempts=np.asarray([r.attempts for r in rs], np.int32),
            xs=np.stack([r.x for r in rs])))

    # --------------------------------------------------------- segment ----
    def launch_segment(self, t_done: float) -> None:
        """Dispatch one ``seg``-step advance of the pool WITHOUT reading
        anything back: JAX async dispatch returns futures immediately,
        so the device chews on the segment while the host does whatever
        comes next. The donated carry buffers (z, fs) are consumed by
        the call — the returned futures become the pool's next resident
        buffers, and any read of the OLD state (readout gathers, refill
        scatters) must already be enqueued, which the retire -> admit ->
        launch tick order guarantees. The one blocking transfer (the
        stacked retire meta) is deferred to ``retire_pending``."""
        _, _, segment_fn, _ = self._cells()
        assert self._pending is None, "one in-flight segment per pool"
        assert self._xs_dev is not None  # a busy pool has admitted
        k_old = self.k.copy()
        occ = self.occupied.copy()
        z, fs, meta = segment_fn(
            self._xs_dev, self.z, jnp.asarray(self.k),
            jnp.asarray(self.Ks), jnp.asarray(self.eps), self.fs,
            *self.sched._g_args())
        self.z, self.fs = z, fs
        self._pending = _PendingSegment(meta=meta, k_old=k_old, occ=occ,
                                        t_done=t_done)

    def retire_pending(self) -> _RetireStats:
        """Block on the pending segment's stacked ``[k'; finished;
        nonfinite]`` meta — still ONE batched device->host transfer per
        segment — stage terminal rows for retirement (gated readout
        enqueued async), requeue retryable failures, and free their
        slots. Returns per-pool ``_RetireStats``; the staged completions
        materialize later in ``finalize_retired``.

        Precedence: quarantine beats finished (a non-finite row's
        finished flag is meaningless — NaN froze or compared its way
        past Ks), finished beats deadline (a request that FINISHED by
        the time the segment retired completes ``ok`` even if its stamp
        lands past the deadline — eviction is only for rows that would
        keep burning segments they can no longer use)."""
        p = self._pending
        assert p is not None, "retire_pending without a pending segment"
        self._pending = None
        sched = self.sched
        meta = np.array(p.meta)   # the one blocking transfer per segment
        self.k = meta[0]
        occ = p.occ
        self.segments[occ] += 1
        useful = int((self.k - p.k_old)[occ].sum())
        fin_row = meta[1] != 0
        if sched.fault_injector is not None:
            # chaos hook: lose completion signals. Keyed per (uid,
            # segment count), so a dropped flag is re-drawn next segment
            # and the request still terminates — zero-hang for p < 1.
            fin_row = sched.fault_injector.drop_retire_flags(
                self.uid, self.segments, fin_row)
        nonfin = occ & (meta[2] != 0)
        finished = occ & fin_row & ~nonfin
        expired = occ & ~nonfin & ~finished & (self.deadline < p.t_done)

        if sched.ledger is not None:
            # residual-ledger capture (launch/refinery.py): interior,
            # healthy rows only — quarantined and deadline-evicted rows
            # are excluded (the STATUSES gate), finished rows sit at the
            # span end where no further step starts. ONE extra readout
            # per retire, rate-gated inside the ledger, never priced by
            # the cost oracle, and purely a READ of the resident state
            # (enqueued before the next donating launch) — so capture
            # on/off completions stay uid-for-uid bitwise identical.
            live = occ & ~nonfin & ~fin_row & ~expired \
                & (self.k < self.Ks)
            sched.ledger.capture_pool(self, np.flatnonzero(live))

        idx: List[int] = [int(i) for i in np.flatnonzero(finished)]
        status = ["ok" if self.attempts[i] == 0 else
                  ("escalated" if self.escalated[i] else "retried")
                  for i in idx]
        requeued = 0
        for i in np.flatnonzero(nonfin | expired):
            st = "diverged" if nonfin[i] else "deadline"
            # escalate one bucket finer; at the top bucket (where a
            # poisoned PROBE lands every corrupted request, since
            # mesh_for_tolerance clamps non-finite k to k_max) retry at
            # the same bucket — a transient fault deserves one clean
            # re-run, still bounded by the RetryPolicy
            nxt = next_bucket_above(int(self.Ks[i]), sched.ecfg.buckets) \
                or int(self.Ks[i])
            if sched.retry.should_retry(st, int(self.attempts[i])):
                self._requeue_slot(int(i), nxt)
                requeued += 1
            else:
                idx.append(int(i))
                status.append(st)
        retired = 0
        if idx:
            retired = self._stage_retire(np.asarray(idx, np.int64),
                                         p.t_done, status)
        return _RetireStats(
            retired=retired, useful=useful, occupied=int(occ.sum()),
            quarantined=int(nonfin.sum()),
            deadline_evicted=int(expired.sum()), requeued=requeued)

    def _requeue_slot(self, i: int, K_floor: int) -> None:
        """Send slot ``i`` back through the retry ladder: the request
        re-enters the FRONT of the queue (so both tick variants admit it
        at the very next ``_admit_tick`` — the sync/overlap parity
        contract) with its K_floor escalated one bucket, and the failed
        attempt's work charged to the scheduler's ``_nfe_extra`` ledger.
        The slot frees without a readout — nothing terminal happened."""
        sched = self.sched
        uid = int(self.uid[i])
        sched._nfe_extra[uid] = sched._nfe_extra.get(uid, 0) \
            + sched.probe_nfe + sched.stages * int(self.k[i])
        sched._submit_t[uid] = float(self.t_submit[i])
        deadline = float(self.deadline[i])
        sched._queue.appendleft(Request(
            uid=uid, x=self.xs[i].copy(),
            deadline=deadline if np.isfinite(deadline) else None,
            attempts=int(self.attempts[i]) + 1, K_floor=K_floor,
            escalated=bool(self.escalated[i])))
        self.uid[i] = -1
        self.Ks[i] = 0
        self.eps[i] = 1.0
        self.k[i] = 0
        self.deadline[i] = np.inf

    def _stage_retire(self, idx: np.ndarray, t_done: float,
                      status: List[str]) -> int:
        """Retire the slots ``idx``: enqueue the rows' readout (async;
        force-retired rows get the same gated readout — their partial
        state IS the best-effort answer), snapshot their host rows, and
        mark them refillable."""
        outs = self._readout_finished(idx)
        self._staged.append(_RetireBatch(
            idx=idx, outs=outs, t_done=t_done,
            fused=self.sched.model.integ.fused_available(z=self.z),
            uid=self.uid[idx].copy(), K=self.Ks[idx].copy(),
            k_done=self.k[idx].copy(),
            err=self.err[idx].copy(), t_submit=self.t_submit[idx].copy(),
            t_admit=self.t_admit[idx].copy(),
            segments=self.segments[idx].copy(), status=list(status)))
        self.uid[idx] = -1            # retire: slot becomes refillable
        self.Ks[idx] = 0              # Ks==0 keeps the row frozen
        self.eps[idx] = 1.0
        self.k[idx] = 0
        self.deadline[idx] = np.inf
        return len(idx)

    def _readout_finished(self, idx: np.ndarray):
        """Readout of ONLY the finished rows (it used to recompute the
        whole pool — including empty ``Ks == 0`` rows — whenever any
        single slot finished). Gather widths are padded to the next
        power of two, capped at the pool width, so the readout jit cells
        are ``(shape, width <= slots)``: a lone finishing slot pays a
        width-1 readout, and the cell count stays log2(slots). Returns
        the device future — materialization is ``finalize_retired``'s
        job."""
        _, _, _, readout_fn = self._cells()
        w = min(1 << (len(idx) - 1).bit_length(), self.sched.slots)
        pad = idx if w == len(idx) else np.concatenate(
            [idx, np.repeat(idx[:1], w - len(idx))])
        self._readout_widths.add(int(w))
        jidx = jnp.asarray(pad)
        z_rows = jax.tree_util.tree_map(lambda l: l[jidx], self.z)
        return readout_fn(self._xs_dev[jidx], z_rows)

    def finalize_retired(self) -> List[InflightCompleted]:
        """Materialize staged completions — the only place readout rows
        cross to the host. The overlap loop calls this AFTER dispatching
        the next segments, so the transfer rides behind device work; the
        sync loop calls it immediately."""
        sched = self.sched
        done: List[InflightCompleted] = []
        self.flow_retired_last = 0
        for fb in self._staged_flow:
            outs = np.asarray(fb.outs)
            for j in range(fb.n):
                uid = int(fb.uid[j])
                attempts = int(fb.attempts[j])
                row = outs[j]
                if sched.fault_injector is not None:
                    # chaos hook: a poisoned FLOW eval (the only fault
                    # that can reach this tier — admission-poisoned
                    # inputs fail the probe's finite screen and are
                    # never flow-routed)
                    row = sched.fault_injector.corrupt_flow_eval(
                        uid, attempts, row)
                if np.isfinite(row).all():
                    # flow_mask bars K_floor > 0, so attempts == 0 here
                    self.flow_retired_last += 1
                    sched._flow_tick += 1
                    sched.total_flow_served += 1
                    done.append(InflightCompleted(
                        uid=uid, outputs=row, K=0,
                        nfe=sched.nfe_flow + sched._nfe_extra.pop(uid, 0),
                        err_probe=float(fb.err[j]), fused_kernel=False,
                        t_submit=float(fb.t_submit[j]),
                        t_admit=fb.t_admit, t_done=fb.t_done,
                        segments=0, status="ok"))
                    continue
                if sched.retry.should_retry("diverged", attempts):
                    # escalation: bill the flow attempt's nfe, requeue
                    # into the K-bucket ladder at the coarsest bucket
                    # (the front of the queue, like _requeue_slot — the
                    # sync/overlap parity contract); K_floor > 0 also
                    # bars re-routing to flow
                    sched._nfe_extra[uid] = \
                        sched._nfe_extra.get(uid, 0) + sched.nfe_flow
                    sched._submit_t[uid] = float(fb.t_submit[j])
                    dl = float(fb.deadline[j])
                    sched._queue.appendleft(Request(
                        uid=uid, x=fb.xs[j].copy(),
                        deadline=dl if np.isfinite(dl) else None,
                        attempts=attempts + 1,
                        K_floor=min(sched.ecfg.buckets),
                        escalated=True))
                    sched._esc_tick += 1
                    sched.total_escalated += 1
                    continue
                self.flow_retired_last += 1
                done.append(InflightCompleted(
                    uid=uid, outputs=row, K=0,
                    nfe=sched.nfe_flow + sched._nfe_extra.pop(uid, 0),
                    err_probe=float(fb.err[j]), fused_kernel=False,
                    t_submit=float(fb.t_submit[j]), t_admit=fb.t_admit,
                    t_done=fb.t_done, segments=0, status="diverged"))
        self._staged_flow = []
        for b in self._staged:
            outs = np.asarray(b.outs)
            for j in range(len(b.idx)):
                uid = int(b.uid[j])
                # nfe bills the depth steps actually TAKEN (k_done == K
                # for ok rows, fewer for evictions) plus every failed
                # attempt's probe + steps from the _nfe_extra ledger
                done.append(InflightCompleted(
                    uid=uid, outputs=outs[j], K=int(b.K[j]),
                    nfe=sched.probe_nfe + sched.stages * int(b.k_done[j])
                    + sched._nfe_extra.pop(uid, 0),
                    err_probe=float(b.err[j]), fused_kernel=b.fused,
                    t_submit=float(b.t_submit[j]),
                    t_admit=float(b.t_admit[j]), t_done=b.t_done,
                    segments=int(b.segments[j]), status=b.status[j]))
        self._staged = []
        return done

    def run_segment(self, now_done: float) -> Tuple[List[InflightCompleted],
                                                    _RetireStats]:
        """The SYNCHRONOUS segment: one ``seg``-step advance of the whole
        pool, finished slots retired before returning. Exactly
        ``launch_segment`` + ``retire_pending`` + ``finalize_retired``
        with zero lag — the overlap loop runs the same three phases one
        segment apart, which is why its completions are uid-for-uid
        identical to this path (pinned in tests/test_scheduler.py).
        Returns (completions, per-pool retire stats)."""
        self.launch_segment(now_done)
        stats = self.retire_pending()
        return self.finalize_retired(), stats


class InflightScheduler:
    """Continuous-batching serving loop: submit as traffic arrives, call
    ``step()`` repeatedly; each step admits into free slots and advances
    every busy pool by one segment. See the module docstring for the
    slot/segment model and the virtual-cost clock.

    ``mesh`` grows the pool past one chip: ``slots`` is the GLOBAL pool
    width, sharded row-wise over the mesh's ``slot_axis`` (per-device
    sub-pools of ``slots / axis_size`` rows) while admission stays one
    global FIFO queue. Between segments, retire/refill operates on the
    gathered per-slot ``k``/``Ks`` rows exactly as on one device — slot
    state is data, so the host never needs to know which device holds
    which slot — and the probe path is unchanged (one pool-width probe
    cell on the default device). ``slots`` must be a multiple of the
    axis size; checked here with a remedy-naming error.

    ``overlap=True`` swaps the synchronous tick for the pipelined one
    (serve.py ``--overlap``): segment N+1 is dispatched while segment
    N's retire metadata is still in flight, so host-side bookkeeping
    overlaps device compute (see ``_step_overlap``). Completions,
    virtual-clock stamps, and ledger totals are identical to the
    synchronous loop — the sync path is kept as the oracle the overlap
    path is pinned against."""

    def __init__(self, model: DepthModel,
                 engine_cfg: Optional[EngineConfig] = None,
                 *, slots: int = 4, seg: int = 2, mesh=None,
                 slot_axis: str = "data",
                 oracle: Optional[CostOracle] = None,
                 overlap: bool = False,
                 donate: Optional[bool] = None,
                 queue_cap: Optional[int] = None,
                 overload_policy: str = "shed",
                 deadline: Optional[float] = None,
                 retry: Optional[RetryPolicy] = None,
                 fault_injector: Optional[FaultInjector] = None,
                 ledger=None):
        engine_cfg = engine_cfg or EngineConfig()
        if overload_policy not in ("shed", "degrade", "block"):
            raise ValueError(
                f"overload_policy={overload_policy!r}: expected 'shed' "
                "(refuse with status='shed'), 'degrade' (admit one "
                "bucket coarser under pressure), or 'block' (raise "
                "QueueFull; caller backs off)")
        if queue_cap is not None and queue_cap < 1:
            raise ValueError(f"queue_cap must be >= 1, got {queue_cap} "
                             "(a zero-width queue can never admit)")
        model = prepare_model(model, engine_cfg)
        if seg < 1:
            raise ValueError(f"seg must be >= 1, got {seg}")
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if mesh is not None:
            n = mesh.shape[slot_axis]
            if slots % n:
                raise ValueError(
                    f"slots={slots} does not divide the '{slot_axis}' "
                    f"mesh axis ({n}); the pool shards row-wise — size "
                    "slots as a multiple of the axis (e.g. "
                    f"slots={n * max(1, slots // n)})")
        self.mesh = mesh
        self.slot_axis = slot_axis
        self.model = model
        self.ecfg = engine_cfg
        self.slots = int(slots)
        self.seg = int(seg)
        # controller policy decides off the BOUND integrator (a
        # parametric g counts as a correction for controller="auto");
        # the pool cells re-bind g over the traced gp operand themselves
        self.controller = make_controller(bound_integrator(model),
                                          engine_cfg)
        # hot-swappable correction params: host-held, appended to every
        # parametric probe/segment cell call — hot_swap_g replaces them
        # between segments with zero retraces and no pool drain
        self.g_params = None if model.g_apply is None else \
            jax.tree_util.tree_map(jnp.asarray, model.g_params)
        # K=0 flow tier (core/flowhead.py): hot-swappable like g, routed
        # by the TierRouter off the admission probe's difficulty estimate
        self.flow_params = None if model.flow_apply is None else \
            jax.tree_util.tree_map(jnp.asarray, model.flow_params)
        self.router = TierRouter(flow_threshold=engine_cfg.flow_threshold) \
            if engine_cfg.flow_threshold > 0 else None
        self.ledger = ledger   # optional ResidualLedger (launch/refinery)
        self.overlap = bool(overlap)
        # Donating the carry buffers halves pool memory on accelerators,
        # where XLA aliases them in place without giving up async
        # dispatch. The CPU client (jaxlib 0.4.x) runs donated
        # computations SYNCHRONOUSLY — dispatch blocks until the segment
        # finishes, which would serialize the overlap pipeline at launch
        # — so the auto default keeps donation off on CPU; pass
        # donate=True to force it (the aliasing contract itself compiles
        # and verifies on every backend — tests/test_scheduler.py).
        if donate is None:
            donate = jax.default_backend() != "cpu"
        self.donate = bool(donate)
        self.oracle: CostOracle = oracle or SequentialEvalOracle()
        self.stages = model.integ.tableau.stages
        self.now = 0.0
        self.ticks = 0
        self.dispatches = 0
        self.total_cost = 0.0
        self.total_probe_cost = 0.0
        self.total_useful_steps = 0
        self.total_slot_steps = 0
        self.total_occupied_steps = 0
        # cumulative hardening counters (per-tick twins live in
        # TickReport): what the serve CLI's live progress line reports
        self.total_quarantined = 0
        self.total_deadline_evicted = 0
        self.total_requeued = 0
        self.total_shed = 0
        self.total_flow_served = 0
        self.total_escalated = 0
        # per-tick flow accounting, accrued inside pool.admit/finalize
        # (reset at the top of each tick variant)
        self._flow_tick = 0
        self._esc_tick = 0
        self._flow_cost_tick = 0.0
        self.last_report = TickReport()
        self.queue_cap = None if queue_cap is None else int(queue_cap)
        self.overload_policy = overload_policy
        self.default_deadline = deadline  # relative slack, applied at submit
        self.retry = retry or RetryPolicy()
        self.fault_injector = fault_injector
        self._queue: deque = deque()
        self._submit_t: Dict[int, float] = {}
        self._uid = 0
        self._pools: Dict[Tuple, _SlotPool] = {}
        self._shed: List[InflightCompleted] = []   # terminal, pre-admission
        self._nfe_extra: Dict[int, int] = {}       # failed attempts' work

    # ----------------------------------------------------------- queue ----
    @property
    def probe_nfe(self) -> int:
        """Per-request probe cost net of the reused first stage (same
        accounting as MultiRateEngine.probe_nfe)."""
        return probe_net_nfe(self.controller)

    @property
    def nfe_flow(self) -> int:
        """NFE billed to a flow-tier completion: the raw probe evals
        plus ZERO solver steps. ``probe_nfe`` nets out the reused first
        stage, but on the flow tier that stage is consumed by the flow
        combine's ``eps*dz`` term rather than a solver, so it is billed
        back (+1). Same accounting as MultiRateEngine.nfe_flow."""
        return self.probe_nfe + 1

    def _flow_args(self) -> Tuple:
        """Trailing flow-cell operands, the flow twin of ``_g_args``."""
        return () if self.model.flow_apply is None else (self.flow_params,)

    def _g_args(self) -> Tuple:
        """Trailing cell operands for the hot-swappable correction:
        ``(g_params,)`` on a parametric model, ``()`` otherwise. Read at
        CALL time, so a hot_swap_g is visible from the very next
        launched segment."""
        return () if self.model.g_apply is None else (self.g_params,)

    def hot_swap_g(self, gp):
        """Install new correction params BETWEEN segments: the pool
        cells take them as traced inputs (same treedef/shapes/dtypes
        enforced by ``validate_g_swap``), so the swap compiles nothing,
        drains nothing, and every segment launched after this call —
        including refills of slots admitted under the old params —
        integrates with the new g. Under ``overlap=True`` the one
        in-flight segment finishes on the old params (it was dispatched
        with them); the swap is visible from the next launch. Returns
        the previous params — the refinery's rollback handle."""
        if self.model.g_apply is None:
            raise ValueError(
                "hot_swap_g on a non-parametric model: build the "
                "DepthModel with g_apply/g_params (params-are-inputs) "
                "to make the correction swappable")
        gp = jax.tree_util.tree_map(jnp.asarray, gp)
        validate_g_swap(self.g_params, gp)
        old, self.g_params = self.g_params, gp
        return old

    def hot_swap_flow(self, fp):
        """Install new flow-head params between ticks — identical
        contract to ``hot_swap_g`` (zero retraces, no drain; the params
        are traced operands read at flow-cell CALL time). Returns the
        previous params as the rollback handle."""
        if self.model.flow_apply is None:
            raise ValueError(
                "hot_swap_flow on a model without a flow head: build "
                "the DepthModel with flow_apply/flow_params to make the "
                "K=0 tier swappable")
        fp = jax.tree_util.tree_map(jnp.asarray, fp)
        validate_g_swap(self.flow_params, fp, label="hot_swap_flow")
        old, self.flow_params = self.flow_params, fp
        return old

    def can_submit(self) -> bool:
        """False exactly when the next ``submit`` would raise QueueFull:
        the bounded queue is at cap under ``overload_policy='block'``.
        (``shed`` always accepts — and may refuse terminally; ``degrade``
        always admits, one bucket coarser under pressure.)"""
        return not (self.queue_cap is not None
                    and self.overload_policy == "block"
                    and len(self._queue) >= self.queue_cap)

    def submit(self, x, t: Optional[float] = None,
               deadline: Optional[float] = None) -> int:
        """Queue a request. ``t`` is its arrival time on the virtual
        clock, defaulting to now; a past ``t`` records the true arrival
        of a request the caller is admitting late (the replay driver's
        normal case — queue wait starts at ``t``). A FUTURE ``t`` is
        only meaningful when the scheduler is idle, where the clock
        idle-jumps forward to it; with work pending it is refused,
        because jumping the clock mid-flight would bill every in-flight
        request for time no segment ran — ``step()`` until ``now >= t``
        instead (as ``launch/workload.py::replay_scheduler`` does).

        ``deadline`` is ABSOLUTE on the virtual clock (defaulting to
        ``t + self.default_deadline`` when the scheduler has a default
        slack); a request past its deadline is dropped from the queue or
        evicted from its slot with ``status="deadline"``. Over a full
        bounded queue: ``shed`` returns a uid whose terminal
        ``status="shed"`` record surfaces from the next ``step()``;
        ``block`` raises ``QueueFull`` (probe with ``can_submit``)."""
        t = self.now if t is None else float(t)
        if t > self.now:
            if self.pending:
                raise ValueError(
                    f"submit at t={t} > now={self.now} with "
                    f"{self.pending} requests pending: advancing the "
                    "clock mid-flight would misattribute latency; "
                    "step() until now >= t, then submit")
            self.advance_to(t)
        if deadline is None and self.default_deadline is not None:
            deadline = t + float(self.default_deadline)
        at_cap = self.queue_cap is not None \
            and len(self._queue) >= self.queue_cap
        if at_cap and self.overload_policy == "block":
            raise QueueFull(
                f"admission queue at cap ({self.queue_cap}) under "
                "overload_policy='block'; back off and resubmit "
                "(can_submit() is the non-raising probe)")
        self._uid += 1
        if at_cap and self.overload_policy == "shed":
            # terminal refusal: no slot, no probe, no outputs — the
            # record surfaces from the next step() like any completion
            self._shed.append(InflightCompleted(
                uid=self._uid, outputs=None, K=0, nfe=0, err_probe=0.0,
                fused_kernel=False, t_submit=t, t_admit=t, t_done=t,
                segments=0, status="shed"))
            return self._uid
        self._queue.append(Request(uid=self._uid, x=np.asarray(x),
                                   deadline=deadline))
        self._submit_t[self._uid] = t
        return self._uid

    def advance_to(self, t: float) -> None:
        """Idle-jump the virtual clock forward (never backward). Refused
        while work is pending, for the same reason ``submit`` refuses a
        future ``t`` then: the jump would bill every in-flight request
        for time no segment ran."""
        if float(t) > self.now and self.pending:
            raise ValueError(
                f"advance_to(t={t}) > now={self.now} with {self.pending} "
                "requests pending: the clock only idle-jumps; step() "
                "until now >= t instead")
        self.now = max(self.now, float(t))

    @property
    def pending(self) -> int:
        """Requests not yet surfaced: queued + in flight + terminal
        records (shed refusals) awaiting the next ``step()``."""
        inflight = sum(int(p.occupied.sum()) for p in self._pools.values())
        return len(self._queue) + inflight + len(self._shed)

    def __len__(self) -> int:
        return len(self._queue)

    # ------------------------------------------------------------ tick ----
    def step(self) -> List[InflightCompleted]:
        """One scheduling round. The synchronous tick (default) admits,
        advances every busy pool by one segment, and retires — blocking
        on each pool's result before moving on. ``overlap=True`` runs
        the pipelined tick instead: retire the PREVIOUS tick's segments,
        admit into the freed slots, dispatch the next segments, and only
        then materialize outputs — so host bookkeeping overlaps device
        compute. Both paths admit identical request->slot assignments
        and stamp identical virtual-clock times; only wall-clock
        behavior differs."""
        return self._step_overlap() if self.overlap else self._step_sync()

    def _admit_tick(self) -> Tuple[float, int, Dict[Tuple, float],
                                   List[InflightCompleted], int]:
        """Refill free slots from the FIFO queue (probe-on-admission).
        Shared verbatim by the sync and overlap ticks, so the two loops
        admit identical request->slot assignments tick for tick — the
        root of the uid-for-uid parity contract. Requests already past
        their deadline drop here, terminal, without costing a probe.
        Returns (probe_cost, admitted, per-pool probe cost, dropped
        terminal records, non-finite probe count)."""
        probe_cost = 0.0
        admitted = 0
        probe_nonfinite = 0
        pool_probe: Dict[Tuple, float] = {}
        dropped: List[InflightCompleted] = []
        # degrade pressure is measured once at tick start, so every
        # admission this tick sees the same policy decision
        degrade = (self.overload_policy == "degrade"
                   and self.queue_cap is not None
                   and len(self._queue) > self.queue_cap)
        # -- admission: FIFO per (shape, dtype) pool; a full pool does not
        #    block other pools' admissions (head-of-line blocking stays
        #    within a cell).
        if self._queue:
            batches: Dict[Tuple, List[Request]] = {}
            budget: Dict[Tuple, int] = {}
            leftover: deque = deque()
            while self._queue:
                r = self._queue.popleft()
                if r.deadline is not None and r.deadline < self.now:
                    # expired while queued: terminal, no slot ever held.
                    # nfe surfaces any failed-attempt work (a retry that
                    # expired waiting for its re-admission).
                    dropped.append(InflightCompleted(
                        uid=r.uid, outputs=None, K=0,
                        nfe=self._nfe_extra.pop(r.uid, 0), err_probe=0.0,
                        fused_kernel=False,
                        t_submit=self._submit_t.pop(r.uid),
                        t_admit=self.now, t_done=self.now,
                        segments=0, status="deadline"))
                    continue
                # pools key on (shape, dtype): same-shape requests of a
                # different dtype must not silently cast into a pool's
                # storage (the jit-cell retrace boundary, made explicit)
                key = (r.x.shape, r.x.dtype.str)
                if key not in self._pools:
                    self._pools[key] = _SlotPool(self, r.x.shape,
                                                 r.x.dtype)
                if key not in budget:
                    budget[key] = len(self._pools[key].free)
                if budget[key] > 0:
                    budget[key] -= 1
                    batches.setdefault(key, []).append(r)
                else:
                    leftover.append(r)
            self._queue = leftover
            for key, batch in batches.items():
                # every pool's probe starts at tick start (concurrent
                # cells) — t_admit no longer absorbs other pools' probes
                pc, n_bad = self._pools[key].admit(
                    batch, self._submit_t, self.now, degrade=degrade)
                pool_probe[key] = pc
                probe_cost += pc
                probe_nonfinite += n_bad
                admitted += len(batch)
        return probe_cost, admitted, pool_probe, dropped, probe_nonfinite

    def _finish_tick(self, *, cost, probe_cost, admitted, retired,
                     useful, total, occupied, quarantined=0,
                     deadline_evicted=0, requeued=0, shed=0,
                     probe_nonfinite=0, flow_served=0,
                     escalated=0) -> None:
        """Advance the virtual clock and the resource ledgers — the one
        accounting epilogue both tick variants share."""
        self.now += cost
        self.ticks += 1
        self.total_cost += cost
        self.total_probe_cost += probe_cost
        self.total_useful_steps += useful
        self.total_slot_steps += total
        self.total_occupied_steps += occupied
        self.total_quarantined += quarantined
        self.total_deadline_evicted += deadline_evicted
        self.total_requeued += requeued
        self.total_shed += shed
        self.last_report = TickReport(
            cost=cost, probe_cost=probe_cost, admitted=admitted,
            retired=retired, useful_steps=useful, total_steps=total,
            occupied_steps=occupied, quarantined=quarantined,
            deadline_evicted=deadline_evicted, requeued=requeued,
            shed=shed, probe_nonfinite=probe_nonfinite,
            flow_served=flow_served, escalated=escalated)

    def _step_sync(self) -> List[InflightCompleted]:
        """The synchronous tick: (1) refill free slots from the queue
        (probe-on-admission), (2) advance every busy pool by one segment,
        (3) retire finished slots. Advances the virtual clock by the
        tick's summed cost (the resource ledger); completions are stamped
        at end-of-tick with only THEIR pool's probe + segment cost —
        pools are concurrent cells, so per-request latency must not
        depend on ``(shape, dtype)`` key insertion order (it used to:
        the pre-oracle clock accumulated segment cost across pools in
        dict-iteration order, billing later-iterated pools for every
        earlier pool's segment; pinned in tests/test_scheduler.py)."""
        done: List[InflightCompleted] = list(self._shed)
        shed = len(done)
        self._shed = []
        self._flow_tick = self._esc_tick = 0
        self._flow_cost_tick = 0.0
        probe_cost, admitted, pool_probe, dropped, probe_nonfinite = \
            self._admit_tick()
        done.extend(dropped)
        cost = probe_cost + self._flow_cost_tick
        # -- segments
        useful = total = occupied = retired = 0
        quarantined = evicted = requeued = 0
        for key, pool in self._pools.items():
            if not pool.busy():
                continue
            seg_cost = self.oracle.segment_cost(pool.shape, self.seg,
                                                self.slots, self.stages)
            if self.fault_injector is not None:
                # virtual straggler: keyed on the DISPATCH sequence, not
                # the tick counter — the overlap loop burns a retire-only
                # flush tick whenever the pool drains, so tick counters
                # drift across loops while the dispatch sequence stays
                # identical (and with it the fault schedule)
                seg_cost = self.fault_injector.inflate_segment_cost(
                    self.dispatches, seg_cost)
            self.dispatches += 1
            cost += seg_cost
            d, st = pool.run_segment(
                self.now + pool_probe.get(key, 0.0) + seg_cost)
            done.extend(d)
            retired += len(d)
            useful += st.useful
            total += self.slots * self.seg
            occupied += st.occupied * self.seg
            quarantined += st.quarantined
            evicted += st.deadline_evicted
            requeued += st.requeued
        # flow-only admissions leave their pool non-busy (flow rows
        # never occupy slots), so run_segment never fires for them —
        # drain any pool still holding staged flow batches here or the
        # tick would silently strand (and hang) those requests
        for pool in self._pools.values():
            if pool._staged_flow:
                d = pool.finalize_retired()
                done.extend(d)
                retired += len(d)
        self._finish_tick(cost=cost, probe_cost=probe_cost,
                          admitted=admitted,
                          retired=retired + shed + len(dropped),
                          useful=useful, total=total, occupied=occupied,
                          quarantined=quarantined,
                          deadline_evicted=evicted + len(dropped),
                          requeued=requeued, shed=shed,
                          probe_nonfinite=probe_nonfinite,
                          flow_served=self._flow_tick,
                          escalated=self._esc_tick)
        return done

    def _step_overlap(self) -> List[InflightCompleted]:
        """The pipelined tick: launch segment N+1 with a one-segment-
        lagged retire, so the device never idles through host
        bookkeeping and the host never idles through a segment. Order:

          1. **retire** every pool's PENDING segment (launched last
             tick): block on its stacked ``[k'; finished]`` meta — by
             now the device has had a full host-phase head start on it —
             stage finished rows (readout gather enqueued async), free
             their slots;
          2. **admit** into the freed slots (``_admit_tick``, shared
             with the sync path — identical request->slot assignments);
          3. **launch** the next segment of every busy pool — async
             dispatch returns immediately, the donated carry buffers
             swap roles (in-flight vs resident), and every line of host
             work after this point overlaps device compute;
          4. **materialize** the staged completions — even the readout
             device->host transfer rides behind the just-dispatched
             segments.

        Per-tick attribution differs from the sync loop (a segment's
        useful/occupied steps and its retires land one tick later in
        ``TickReport``), but per-request completions, virtual-clock
        stamps, and end-of-run ledger totals are identical — pinned
        uid-for-uid in tests/test_scheduler.py."""
        done: List[InflightCompleted] = list(self._shed)
        shed = len(done)
        self._shed = []
        self._flow_tick = self._esc_tick = 0
        self._flow_cost_tick = 0.0
        useful = total = occupied = retired = 0
        quarantined = evicted = requeued = 0
        for pool in self._pools.values():
            if pool._pending is not None:
                st = pool.retire_pending()
                retired += st.retired
                useful += st.useful
                total += self.slots * self.seg
                occupied += st.occupied * self.seg
                quarantined += st.quarantined
                evicted += st.deadline_evicted
                requeued += st.requeued
        probe_cost, admitted, pool_probe, dropped, probe_nonfinite = \
            self._admit_tick()
        done.extend(dropped)
        cost = probe_cost + self._flow_cost_tick
        for key, pool in self._pools.items():
            if not pool.busy():
                continue
            seg_cost = self.oracle.segment_cost(pool.shape, self.seg,
                                                self.slots, self.stages)
            if self.fault_injector is not None:
                # keyed on the dispatch sequence (see _step_sync)
                seg_cost = self.fault_injector.inflate_segment_cost(
                    self.dispatches, seg_cost)
            self.dispatches += 1
            cost += seg_cost
            pool.launch_segment(self.now + pool_probe.get(key, 0.0)
                                + seg_cost)
        for pool in self._pools.values():
            done.extend(pool.finalize_retired())
            # staged-segment retire stats (st.retired above) never see
            # flow rows — they retire straight out of finalize
            retired += pool.flow_retired_last
        self._finish_tick(cost=cost, probe_cost=probe_cost,
                          admitted=admitted,
                          retired=retired + shed + len(dropped),
                          useful=useful, total=total, occupied=occupied,
                          quarantined=quarantined,
                          deadline_evicted=evicted + len(dropped),
                          requeued=requeued, shed=shed,
                          probe_nonfinite=probe_nonfinite,
                          flow_served=self._flow_tick,
                          escalated=self._esc_tick)
        return done

    # ----------------------------------------------------- convenience ----
    def run(self, xs) -> List[InflightCompleted]:
        """Submit a batch at the current instant and drive to completion,
        returning results ordered by submission (uid join)."""
        uids = [self.submit(x) for x in np.asarray(xs)]
        results: Dict[int, InflightCompleted] = {}
        while self.pending:
            for c in self.step():
                results[c.uid] = c
        return [results[u] for u in uids]
