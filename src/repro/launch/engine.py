"""Multi-rate serving engine: error-controlled per-request step sizes.

This is where the repo's batching/eps policy lives (launch/serve.py is the
CLI over it). The loop the paper's pareto pitch implies, end to end:

    submit(x) -> request queue
        -> probe: one cheap depth-field step per request
           (core/controllers.py picks a per-sample mesh length K; the
           probe's dz = f(s0, z0) is kept and reused as stage 0 of the
           solve, so probing costs one fewer NFE than it evaluates)
        -> bucket snap: clamp K to the configured serving buckets — a
           PACKING POLICY (bounds masked-step waste and the number of
           (shape, k_max) jit cells), NOT a kernel-eligibility rule
        -> pack same-shape requests into batches, sorted by K so batches
           stay as K-pure as the traffic allows (leftovers mix freely)
        -> drive each batch through ONE masked multi-rate solve
           (``Integrator.solve_multirate``): per-sample eps and the
           mesh-length row are TRACED operands of the runtime-eps fused
           kernel, so a mixed-K batch runs fused end to end and a given
           (shape, k_max) cell never recompiles across bucket mixes
        -> Completed{outputs, K, nfe, err_probe} per request

Hot (easy) requests integrate in 2-4 NFEs; hard ones get 8-16. Per-request
NFE accounting includes the probe cost (minus the reused stage), so
reported pareto points are honest.

The engine is generic over a ``DepthModel`` adapter (embed -> field ->
readout); ``lm_depth_model`` serves the continuous-depth LM
(models/cdepth.py) and ``node_depth_model`` any ``NeuralODE`` (the paper's
image classifiers).

This drain loop is the BATCH-JOB serving shape: ``step()`` probes, packs,
and solves everything queued to completion before admitting new work. The
streaming shape — depth-continuous batching, where finished slots retire
and refill *between segments* of the solve — lives in
``launch/scheduler.py`` (InflightScheduler over
``Integrator.solve_segment``), reusing this module's controller policy
(``make_controller``), bucket snap, and ``DepthModel`` adapters; identical
arrival traces replay against both via ``launch/workload.py``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ArchConfig
from repro.core.controllers import (
    EmbeddedErrorController, FixedController, HypersolverResidualController,
    TierRouter,
)
from repro.core.integrate import Integrator, OneTimeWarning
from repro.models.cdepth import lm_g_init, lm_integrator
from repro.models.lm import init_lm_cache, lm_decode_step, lm_prefill


# ----------------------------------------------------------- discrete path ----

def greedy_generate(params, cfg, prompt, gen_len: int, jit_step=None):
    """Standard cached decode; prompt: (B, P) int32. Prefill is a single
    batched forward (one compiled scan over the prompt, models/lm.py),
    then token-by-token greedy decode."""
    B, P = prompt.shape
    caches = init_lm_cache(cfg, B, P + gen_len)
    step = jit_step or jax.jit(
        lambda p, t, c, i: lm_decode_step(p, cfg, t, c, i))
    prefill = jax.jit(lambda p, toks, c: lm_prefill(p, cfg, toks, c))
    logits, caches = prefill(params, prompt, caches)
    out = [jnp.argmax(logits, -1).astype(jnp.int32)]
    for t in range(P, P + gen_len - 1):
        logits, caches = step(params, out[-1], caches,
                              jnp.asarray(t, jnp.int32))
        out.append(jnp.argmax(logits, -1).astype(jnp.int32))
    return jnp.stack(out, axis=1)


# -------------------------------------------------------------- g loading ----

def load_g_params(path: str, cfg: ArchConfig, rank: int = 32):
    """Restore a trained LM hypersolver correction from a CheckpointManager
    directory (the --g-ckpt CLI flag)."""
    cm = CheckpointManager(path)
    step = cm.latest_step()
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {path!r}")
    template = lm_g_init(jax.random.PRNGKey(0), cfg, rank=rank,
                         param_dtype=jnp.float32)
    return cm.restore(step, jax.eval_shape(lambda: template))


def load_flow_params(path: str, cfg: ArchConfig, rank: int = 64):
    """Restore a trained LM flow head (core/flowhead.py) from a
    CheckpointManager directory (the --flow-ckpt CLI flag)."""
    from repro.models.cdepth import lm_flow_init
    cm = CheckpointManager(path)
    step = cm.latest_step()
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {path!r}")
    template = lm_flow_init(jax.random.PRNGKey(0), cfg, rank=rank,
                            param_dtype=jnp.float32)
    return cm.restore(step, jax.eval_shape(lambda: template))


# ---------------------------------------------------------- model adapters ----

@dataclasses.dataclass(frozen=True)
class DepthModel:
    """What the engine needs to serve a continuous-depth model.

    ``embed(x)`` lifts a request batch to the ODE state z0; ``field_of(x)``
    closes the vector field over any conditioning; ``readout(x, zT)`` maps
    the terminal state to outputs (logits). ``integ`` is the serving
    Integrator (base tableau + optional correction g).

    Two ways to carry a hypersolver correction:

    * **closure** — ``integ.g`` closes over its parameters. Zero extra
      plumbing, but the params are constants of every jit cell: swapping
      them forces a retrace of every compiled probe/segment/solve.
    * **parametric** — ``g_apply(gp, eps, s, z, dz)`` plus an initial
      ``g_params`` pytree. The serving loops then thread ``gp`` through
      their jit cells as a TRACED, non-donated input, so replacing it
      with a pytree of identical treedef/shapes/dtypes
      (``hot_swap_g``) reuses every compilation — the params-are-inputs
      invariant the online refinery's no-retrace hot-swap rests on
      (launch/refinery.py; docs/architecture.md "the refinery layer").
      ``integ.g`` must be None on this path.

    ``flow_apply``/``flow_params`` carry the OPTIONAL K=0 flow tier
    (core/flowhead.py): ``flow_apply(fp, eps, s, z, dz) -> z(s + eps)``
    is a learned solution operator — one eval, no solver — that the
    serving loops route probe-easy requests to when
    ``EngineConfig.flow_threshold > 0``. Flow params follow the same
    params-are-inputs contract as g (traced cell operands,
    ``hot_swap_flow``)."""

    embed: Callable[[Any], Any]
    field_of: Callable[[Any], Callable]
    readout: Callable[[Any, Any], Any]
    integ: Integrator
    span: Tuple[float, float] = (0.0, 1.0)
    g_apply: Optional[Callable] = None   # g_apply(gp, eps, s, z, dz)
    g_params: Any = None                 # initial swappable params
    flow_apply: Optional[Callable] = None  # flow_apply(fp, eps, s, z, dz)
    flow_params: Any = None              # initial swappable flow params


def bound_integrator(model: DepthModel, gp=None) -> Integrator:
    """``model.integ`` with the parametric correction bound over ``gp``
    (defaulting to the model's initial params). Used wherever an
    Integrator-with-g is needed OUTSIDE a serving jit cell — controller
    policy checks, shadow scoring, offline evaluation. Inside the cells
    the loops bind g themselves so ``gp`` stays a traced operand."""
    if model.g_apply is None:
        return model.integ
    ga = model.g_apply
    if gp is None:
        gp = model.g_params
    return dataclasses.replace(
        model.integ, g=lambda e, s, z, dz: ga(gp, e, s, z, dz))


def validate_g_swap(current, new, label: str = "hot_swap_g") -> None:
    """Refuse a hot-swap that would retrace: the incoming params must
    match the resident pytree leaf for leaf (treedef, shapes, dtypes) —
    the exact condition under which jit reuses the compiled cells that
    took ``current`` as an input. Shared by the engine's and scheduler's
    ``hot_swap_g`` AND ``hot_swap_flow`` (``label`` names the caller in
    the error)."""
    t_cur, d_cur = jax.tree_util.tree_flatten(current)
    t_new, d_new = jax.tree_util.tree_flatten(new)
    if d_cur != d_new:
        raise ValueError(
            f"{label}: params treedef mismatch ({d_new} vs resident "
            f"{d_cur}) — a swap must preserve the pytree structure or "
            "every serving cell would retrace")
    for i, (c, n) in enumerate(zip(t_cur, t_new)):
        cs, cd = jnp.shape(c), jnp.asarray(c).dtype
        ns, nd = jnp.shape(n), jnp.asarray(n).dtype
        if cs != ns or cd != nd:
            raise ValueError(
                f"{label}: leaf {i} is {ns}/{nd}, resident is "
                f"{cs}/{cd} — shapes and dtypes must match exactly "
                "(the no-retrace contract)")


def lm_depth_model(params, cfg: ArchConfig, solver: str = "euler",
                   g_params: Any = None, fused: bool = False, *,
                   refinable: bool = False, rank: int = 32,
                   flow_params: Any = None) -> DepthModel:
    """The unified LM's depth ODE (models/cdepth.py) as a servable model.

    ``refinable=True`` carries the correction on the PARAMETRIC path
    (``g_apply``/``g_params`` as traced cell inputs) instead of baking
    it into ``integ.g`` — required for the online refinery's no-retrace
    hot-swap. Without a trained ``g_params`` it starts from a fresh
    zero-readout init (g == 0 exactly, pure base solver) that the
    refinery then fits from live traffic.

    ``flow_params`` (a trained ``lm_flow_init``-shaped pytree, e.g. from
    ``load_flow_params``) attaches the K=0 flow tier: the model carries
    ``flow_apply``/``flow_params`` on the same parametric contract, and
    the serving loops route probe-easy requests to it when
    ``EngineConfig.flow_threshold > 0``."""
    from repro.models.cdepth import (
        apply_tail, depth_field, lm_flow_apply, lm_g_apply,
    )
    from repro.models.lm import _embed

    f = depth_field(params, cfg)
    kw = {}
    if refinable:
        base = solver[len("hyper_"):] if solver.startswith("hyper_") \
            else solver
        if g_params is None:
            g_params = lm_g_init(jax.random.PRNGKey(0), cfg, rank=rank,
                                 param_dtype=jnp.float32)
        integ = lm_integrator(base, None, fused=fused)
        kw = dict(
            g_apply=lambda gp, eps, s, z, dz:
                lm_g_apply(gp, eps, s, None, z, dz),
            g_params=g_params)
    else:
        integ = lm_integrator(solver, g_params, fused=fused)
    if flow_params is not None:
        order = integ.order
        kw.update(
            flow_apply=lambda fp, eps, s, z, dz:
                lm_flow_apply(fp, eps, s, z, dz, order=order),
            flow_params=flow_params)
    return DepthModel(
        embed=lambda toks: _embed(params, cfg, toks),
        field_of=lambda toks: f,
        readout=lambda toks, h: apply_tail(params, cfg, h),
        integ=integ,
        **kw,
    )


def node_depth_model(node, params, solver: str = "euler",
                     g_apply: Any = None, g_params: Any = None,
                     fused: bool = False) -> DepthModel:
    """Any ``NeuralODE`` (core/neural_ode.py) as a servable model — e.g.
    the paper's image classifiers (models/conv_node.py). ``solver`` may
    carry a ``hyper_`` prefix (requires g_apply/g_params). ``g_apply`` gets
    x=None: conditioning-dependent corrections need a custom adapter."""
    from repro.core.train import make_integrator

    if solver.startswith("hyper_"):
        if g_apply is None:
            raise ValueError(
                f"solver {solver!r} needs a correction: pass g_apply/"
                "g_params (a hyper solver silently downgraded to its base "
                "would misreport benchmark numbers)")
        base = solver[len("hyper_"):]
    else:
        base = solver
    return DepthModel(
        embed=lambda x: node.hx_apply(params, x),
        field_of=lambda x: node.field(params, x),
        readout=lambda x, zT: node.hy_apply(params, zT),
        integ=make_integrator(base, g_apply, g_params, None, fused=fused),
        span=tuple(node.s_span),
    )


# ------------------------------------------------------------ bucket policy ----

# bucket-overflow snap latch: one shared OneTimeWarning mechanism with
# the fused-fallback warning (tests re-arm both per test via conftest)
_snap_overflow = OneTimeWarning()


def reset_snap_overflow_warning() -> None:
    """Re-arm the one-time bucket-overflow RuntimeWarning (test isolation)."""
    _snap_overflow.reset()


# non-finite probe latch: a NaN/Inf probe error used to select k_max
# SILENTLY (core/controllers.py::mesh_for_tolerance clamps inside jit,
# where it cannot warn) — both serving loops now screen the materialized
# error row host-side, warn once, and thread the count into
# StepReport/TickReport.probe_nonfinite; the request itself is handled
# by the quarantine layer (its state is non-finite from step one, so the
# segment cell's nonfinite meta row force-retires it).
_probe_nonfinite = OneTimeWarning()


def reset_probe_nonfinite_warning() -> None:
    """Re-arm the one-time non-finite-probe RuntimeWarning (test
    isolation)."""
    _probe_nonfinite.reset()


def screen_probe_errors(errs: np.ndarray) -> int:
    """Count non-finite probe errors in a materialized error row and
    warn once. ``mesh_for_tolerance`` already routes such requests to
    ``k_max`` (the conservative mesh), but inside jit it cannot signal —
    this host-side screen is where the silent clamp becomes observable.
    Shared by MultiRateEngine.step and the scheduler's admission."""
    n_bad = int((~np.isfinite(np.asarray(errs))).sum())
    if n_bad:
        _probe_nonfinite.warn(
            f"non-finite probe error for {n_bad} request(s): the probe "
            "step itself blew up, so the controller assigned k_max (the "
            "finest mesh). The solve is likely to diverge too — the "
            "non-finite quarantine will force-retire it with "
            "status='diverged'.", stacklevel=3)
    return n_bad


def next_bucket_above(K: int, buckets: Sequence[int]) -> Optional[int]:
    """The finest configured bucket strictly greater than ``K`` — the
    retry ladder's escalation rule (a diverged K-bucket solve retries at
    the next-finer mesh). None when ``K`` is already the top bucket."""
    for b in sorted(buckets):
        if b > K:
            return int(b)
    return None


def snap_to_buckets(Ks: np.ndarray, buckets: Sequence[int]) -> np.ndarray:
    """Smallest configured bucket >= K (largest bucket when K overshoots,
    with a one-time warning — that clamp integrates COARSER than asked).

    Snapping up, never down — except at the top: a request is only ever
    integrated at least as finely as its controller asked for, unless its
    K exceeds ``buckets[-1]`` entirely, in which case it clamps down to
    ``buckets[-1]`` (the warning latch flags the quality degradation once).
    Since the runtime-eps kernel fuses any K mix, snapping exists purely to
    bound masked-step waste and the set of (shape, k_max) jit cells — not
    to make batches kernel-eligible."""
    buckets = np.asarray(sorted(buckets), np.int32)
    Ks = np.asarray(Ks, np.int32)
    if Ks.size and int(Ks.max()) > int(buckets[-1]):
        _snap_overflow.warn(
            f"snap_to_buckets: probed K={int(Ks.max())} exceeds the "
            f"largest configured bucket {int(buckets[-1])}; clamping down "
            "to it. The request will integrate more coarsely than its "
            "controller asked for — widen the bucket set (or raise tol) "
            "if this is steady-state traffic.", stacklevel=3)
    idx = np.searchsorted(buckets, Ks, side="left")
    return buckets[np.minimum(idx, len(buckets) - 1)]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Batching/eps policy knobs for the multi-rate engine."""

    buckets: Tuple[int, ...] = (2, 4, 8, 16)
    tol: float = 1e-2             # target local-error tolerance for probes
    max_batch: int = 8            # max requests packed into one bucket batch
    solver: str = "euler"         # base tableau; "hyper_*" pairs it with g
    controller: str = "auto"      # auto | residual | embedded | fixed
    fixed_K: int = 0              # mesh length when controller == "fixed"
    fused: bool = False           # route batch solves through the kernel
    #                               (runtime-eps: any K mix fuses)
    flow_threshold: float = 0.0   # K=0 flow tier confidence fraction:
    #                               route iff probe err <= this * tol
    #                               (0 disables the tier entirely)

    def __post_init__(self):
        assert self.buckets == tuple(sorted(self.buckets)), self.buckets
        if not (0.0 <= self.flow_threshold <= 1.0):
            raise ValueError(
                f"flow_threshold={self.flow_threshold}: expected a "
                "confidence fraction in [0, 1] (core/controllers.py::"
                "TierRouter) — the flow tier only serves requests whose "
                "probe error is confidently below tol")


def prepare_model(model: DepthModel, ecfg: "EngineConfig") -> DepthModel:
    """Shared serving-loop model vetting: promote the integrator onto the
    fused kernel path when the config asks for it, and refuse a hyper_*
    solver with no correction bound (a silent downgrade to the base
    tableau would misreport every NFE/agreement number downstream). Both
    MultiRateEngine and InflightScheduler construct through here, so the
    two loops cannot drift on eligibility policy."""
    if ecfg.fused and not model.integ.fused:
        model = dataclasses.replace(
            model, integ=dataclasses.replace(model.integ, fused=True))
    if model.g_apply is not None and model.integ.g is not None:
        raise ValueError(
            "DepthModel carries BOTH a closure correction (integ.g) and "
            "a parametric one (g_apply); pick one — a cell binding both "
            "would apply g twice")
    if ecfg.solver.startswith("hyper_") and model.integ.g is None \
            and model.g_apply is None:
        raise ValueError(
            f"solver {ecfg.solver!r} needs a correction: build the "
            "DepthModel with g_params (serve CLI: --g-ckpt)")
    if ecfg.flow_threshold > 0:
        if model.flow_apply is None:
            raise ValueError(
                f"flow_threshold={ecfg.flow_threshold} routes easy "
                "requests to the K=0 flow tier, but the DepthModel "
                "carries no flow head: build it with flow_apply/"
                "flow_params (serve CLI: --flow-ckpt)")
        if ecfg.controller == "fixed":
            raise ValueError(
                "flow_threshold > 0 needs a probing controller — the "
                "flow tier routes off the admission probe's difficulty "
                "estimate, which controller='fixed' never computes")
    return model


def make_controller(integ: Integrator, ecfg: "EngineConfig"):
    """Controller selection shared by the drain engine and the in-flight
    scheduler (launch/scheduler.py): same knobs -> same per-request K
    policy, so the two serving loops are comparable request-for-request."""
    kind = ecfg.controller
    if kind == "auto":
        kind = "residual" if integ.g is not None else "embedded"
    k_min, k_max = min(ecfg.buckets), max(ecfg.buckets)
    if kind == "fixed":
        K = ecfg.fixed_K or k_max
        assert K <= k_max, (
            f"fixed_K={K} exceeds the largest bucket {k_max}; "
            "snap_to_buckets never snaps down — widen buckets")
        return FixedController(K=K)
    if kind == "residual":
        return HypersolverResidualController(
            tol=ecfg.tol, k_min=k_min, k_max=k_max)
    if kind == "embedded":
        return EmbeddedErrorController(
            tol=ecfg.tol, k_min=k_min, k_max=k_max)
    raise ValueError(f"unknown controller {kind!r}")


def probe_net_nfe(controller) -> int:
    """Per-request probe cost net of the reused first stage (the probe's
    dz0 substitutes for stage 0 of the solve, so one eval is free)."""
    raw = getattr(controller, "probe_nfe", 0)
    return max(raw - 1, 0) if raw else 0


@dataclasses.dataclass(frozen=True)
class StepReport:
    """Virtual-cost accounting for one engine drain, priced by the
    engine's cost oracle (``launch/oracle.py``). Under the default
    ``SequentialEvalOracle`` the unit is SEQUENTIAL vector-field
    evaluations (the unit a batch-parallel accelerator serializes on):
    a K-step scan of an s-stage tableau costs s*K regardless of batch
    width, a probe costs its probe_nfe. The trace replayer
    (launch/workload.py) uses this to compare the drain loop and the
    in-flight scheduler on identical arrival traces.

    ``finish_offset`` maps uid -> cost offset (from drain start) at which
    its batch's solve completed — requests in the first bucket batch of a
    drain finish before the last batch does."""

    cost: float = 0.0                 # total sequential evals this drain
    probe_cost: float = 0.0           # sequential evals spent probing
    useful_steps: int = 0             # sum of per-sample K over served rows
    total_steps: int = 0              # sum of batch_rows * k_max over batches
    batches: int = 0
    probe_nonfinite: int = 0          # non-finite probe errors this drain
    finish_offset: Dict[int, float] = dataclasses.field(default_factory=dict)
    flow_served: int = 0              # requests completed on the K=0 tier
    escalated: int = 0                # flow failures requeued to the ladder

    @property
    def waste_steps(self) -> int:
        """Masked sample-steps: rows scanned past their own K_i."""
        return self.total_steps - self.useful_steps


# terminal request statuses both serving loops stamp (docs/serving.md
# "Failure semantics" carries the operator-facing glossary; the docs
# gate in tests/test_docs.py asserts against THIS tuple):
#   ok        — completed its mesh, first attempt
#   retried   — completed after >= 1 quarantine/eviction retry
#   diverged  — non-finite state, retry ladder exhausted (best-effort
#               outputs: the poisoned partial readout)
#   deadline  — evicted past its deadline (best-effort partial readout,
#               or none if it expired while still queued)
#   shed      — refused at admission by the overload policy (no outputs)
#   escalated — completed on the K-bucket ladder after its K=0 flow-tier
#               eval came back non-finite (real outputs; the flow
#               attempt's nfe is billed into the record)
STATUSES = ("ok", "retried", "diverged", "deadline", "shed", "escalated")


class QueueFull(RuntimeError):
    """Bounded admission queue is full under overload_policy='block'.
    Callers back off and resubmit (``can_submit()`` is the non-raising
    probe; launch/workload.py's replay drivers defer the arrival)."""


@dataclasses.dataclass(frozen=True)
class Request:
    uid: int
    x: np.ndarray                 # one request's input (no batch axis)
    deadline: Optional[float] = None  # oracle-clock deadline (None = none)
    attempts: int = 0             # completed (failed) serve attempts so far
    K_floor: int = 0              # retry ladder: minimum bucket on re-probe
    escalated: bool = False       # a failed K=0 flow eval sent it here


@dataclasses.dataclass(frozen=True)
class Completed:
    uid: int
    outputs: np.ndarray           # readout of the terminal state (e.g. logits)
    K: int                        # bucket mesh length actually used
    nfe: int                      # per-request NFE, probe included
    err_probe: float              # controller's local-error estimate
    fused_kernel: bool            # Pallas fused path in play for the solve
    status: str = "ok"            # terminal status (STATUSES)


class MultiRateEngine:
    """Request-queue engine serving continuous-depth models at per-request
    rates. Heavy lifting is jitted and cached per request shape for probes
    and per (shape, K) for bucket solves, so a steady-state traffic mix
    compiles once per cell."""

    def __init__(self, model: DepthModel, engine_cfg: EngineConfig,
                 oracle=None, *, queue_cap: Optional[int] = None,
                 overload_policy: str = "shed", retry=None,
                 fault_injector=None, ledger=None):
        from repro.distributed.fault import RetryPolicy
        from repro.launch.oracle import SequentialEvalOracle
        if overload_policy not in ("shed", "degrade", "block"):
            raise ValueError(f"unknown overload_policy {overload_policy!r} "
                             "(shed | degrade | block)")
        if queue_cap is not None and queue_cap < 1:
            raise ValueError(f"queue_cap must be >= 1, got {queue_cap} "
                             "(a zero-width queue can never admit)")
        self.model = prepare_model(model, engine_cfg)
        self.ecfg = engine_cfg
        # controller policy decides off the BOUND integrator (a parametric
        # g counts as a correction for controller="auto"); the cells
        # re-bind g over the traced gp operand themselves
        self.controller = make_controller(
            bound_integrator(self.model), self.ecfg)
        # hot-swappable correction params: host-held, passed into every
        # parametric jit cell at CALL time — hot_swap_g replaces them
        # between drains with zero retraces (validate_g_swap)
        self.g_params = None if self.model.g_apply is None else \
            jax.tree_util.tree_map(jnp.asarray, self.model.g_params)
        # the K=0 flow tier's swappable params + router policy; None/None
        # when the tier is disabled (flow_threshold == 0), in which case
        # NO flow code runs — the bitwise-parity guarantee vs pre-flow
        self.flow_params = None if self.model.flow_apply is None else \
            jax.tree_util.tree_map(jnp.asarray, self.model.flow_params)
        self.router = TierRouter(
            flow_threshold=engine_cfg.flow_threshold) \
            if engine_cfg.flow_threshold > 0 else None
        self.ledger = ledger   # optional ResidualLedger (launch/refinery)
        self.oracle = oracle or SequentialEvalOracle()
        self.queue_cap = queue_cap
        self.overload_policy = overload_policy
        self.retry = retry or RetryPolicy()
        self.fault_injector = fault_injector
        self._queue: deque = deque()
        self._uid = 0
        self._shed: List[Completed] = []
        self._nfe_extra: Dict[int, int] = {}   # failed attempts' NFE per uid
        self._probe_fns: Dict[Tuple, Any] = {}
        self._solve_fns: Dict[Tuple, Any] = {}
        self._embed_fns: Dict[Tuple, Any] = {}
        self._flow_fns: Dict[Tuple, Any] = {}
        self.last_report = StepReport()

    # ---------------------------------------------------------- policy ----
    @property
    def probe_nfe(self) -> int:
        """Probe cost per request, net of the reused first stage."""
        return probe_net_nfe(self.controller)

    def fused_in_play(self, z0=None) -> bool:
        """Kernel eligibility is K-independent now (runtime-eps kernel):
        only the integrator's fused flag and the state dtypes matter —
        pass the embedded state (or its eval_shape) to vet the latter."""
        return self.model.integ.fused_available(z=z0)

    def nfe_of(self, K: int) -> int:
        """Per-request NFE for a bucket-K solve, probe included (the solve
        reuses the probe's first stage, so one eval is not double-counted)."""
        return self.probe_nfe + self.model.integ.tableau.stages * K

    @property
    def nfe_flow(self) -> int:
        """Per-request NFE on the K=0 flow tier: the probe's RAW field
        evals, nothing else. ``probe_nfe`` nets out the stage the solve
        reuses; on the flow tier that same stage is consumed by the flow
        combine's ``eps*dz`` term, so it is billed back here (+1) and the
        total is probe evals + ZERO solver steps — strictly below
        ``nfe_of(k_min)`` for every controller."""
        return self.probe_nfe + 1

    def probe(self, xs):
        """Probe a request batch without serving it: returns (raw per-
        sample K before bucket snapping, per-sample error estimate)."""
        xs = np.asarray(xs)
        Ks, errs, _, _ = self._probe_fn(xs.shape[1:])(
            jnp.asarray(xs), *self._g_args())
        return np.asarray(Ks), np.asarray(errs)

    # ----------------------------------------------------------- queue ----
    def can_submit(self) -> bool:
        """False iff the bounded queue is full under ``block`` (the only
        policy that pushes backpressure to the caller; ``shed`` admits
        the submit and refuses the request, ``degrade`` admits and caps
        K under pressure)."""
        return not (self.queue_cap is not None
                    and self.overload_policy == "block"
                    and len(self._queue) >= self.queue_cap)

    def submit(self, x, deadline: Optional[float] = None) -> int:
        """Queue a request. ``deadline`` is an absolute time on the
        replay clock (``step(now=...)``); requests past it at drain
        start are dropped with ``status="deadline"``. A full bounded
        queue sheds (terminal ``status="shed"``, surfaced by the next
        ``step()``) or raises ``QueueFull`` under ``block``."""
        if self.queue_cap is not None \
                and len(self._queue) >= self.queue_cap:
            if self.overload_policy == "block":
                raise QueueFull(
                    f"admission queue at cap {self.queue_cap} under "
                    "overload_policy='block'; poll can_submit() and "
                    "resubmit")
            if self.overload_policy == "shed":
                self._uid += 1
                self._shed.append(Completed(
                    uid=self._uid, outputs=None, K=0, nfe=0,
                    err_probe=0.0, fused_kernel=False, status="shed"))
                return self._uid
            # degrade: admit past the cap; the drain caps K one bucket
            # down while the queue stays over pressure (see step())
        self._uid += 1
        self._queue.append(Request(uid=self._uid, x=np.asarray(x),
                                   deadline=deadline))
        return self._uid

    def __len__(self) -> int:
        # shed records count until a step() surfaces them, so drive
        # loops (run / replay_engine) never exit with terminals unread
        return len(self._queue) + len(self._shed)

    # ------------------------------------------------------- jit cells ----
    def _g_args(self) -> Tuple:
        """The trailing cell operands for the hot-swappable correction:
        ``(g_params,)`` on a parametric model, ``()`` otherwise. Read at
        CALL time so a hot_swap_g lands on the very next drain."""
        return () if self.model.g_apply is None else (self.g_params,)

    def _probe_fn(self, shape):
        if shape not in self._probe_fns:
            m, ctrl = self.model, self.controller
            parametric = m.g_apply is not None

            @jax.jit
            def probe(x, *gps):
                # parametric g rides as a traced operand (gps = (gp,)),
                # so swapped params reuse this compilation
                integ = bound_integrator(m, gps[0]) if parametric \
                    else m.integ
                z0 = m.embed(x)
                p = ctrl.select(integ, m.field_of(x), z0, m.span)
                return p.K, p.err, z0, p.dz0

            self._probe_fns[shape] = probe
        return self._probe_fns[shape]

    def _embed_fn(self, shape):
        """Embed-only cell for the ledger-capture path under a fixed
        controller (no probe, so no z0 to reuse). Capture-only state:
        never fed to the solve, never priced by the oracle."""
        if shape not in self._embed_fns:
            self._embed_fns[shape] = jax.jit(self.model.embed)
        return self._embed_fns[shape]

    def _flow_args(self) -> Tuple:
        """Trailing cell operands for the hot-swappable flow head:
        ``(flow_params,)`` when the model carries one, ``()`` otherwise.
        Read at CALL time so a hot_swap_flow lands on the next drain."""
        return () if self.model.flow_apply is None else (self.flow_params,)

    def _flow_fn(self, shape):
        """The K=0 tier's jit cell: one flow-head eval + readout over the
        probe's already-materialized (z0, dz0). Variable-width like the
        drain's solve batches; flow params ride as a traced trailing
        operand (the params-are-inputs invariant, same as g)."""
        if shape not in self._flow_fns:
            m = self.model
            h = m.span[1] - m.span[0]
            s0 = m.span[0]

            @jax.jit
            def flow(x, z0, dz0, *fps):
                return m.readout(x, m.flow_apply(fps[0], h, s0, z0, dz0))

            self._flow_fns[shape] = flow
        return self._flow_fns[shape]

    def _solve_fn(self, shape, k_max: int):
        key = (shape, k_max)
        if key not in self._solve_fns:
            m = self.model
            parametric = m.g_apply is not None

            @jax.jit
            def solve(x, z0, dz0, Ks, *gps):
                # z0/dz0 come from the probe cell (embed + first stage are
                # not recomputed); the fixed path passes z0=None and
                # embeds here. Ks is a TRACED (B,) row: sample i runs its
                # own eps_i = span / Ks[i] mesh and freezes after Ks[i]
                # steps, so one (shape, k_max) compilation serves every
                # bucket mix and every step size the controller emits.
                # gps, when present, is the hot-swappable correction
                # params pytree — traced, so swaps never retrace.
                integ = bound_integrator(m, gps[0]) if parametric \
                    else m.integ
                if z0 is None:
                    z0 = m.embed(x)
                zT = integ.solve_multirate(
                    m.field_of(x), z0, m.span, Ks, k_max, first_stage=dz0)
                return m.readout(x, zT)

            self._solve_fns[key] = solve
        return self._solve_fns[key]

    # --------------------------------------------------------- hot swap ----
    def hot_swap_g(self, gp):
        """Install new correction params between drains: every cached
        probe/solve cell takes them as a traced input, so the swap
        compiles NOTHING and the next ``step()`` serves with the new g.
        Returns the previous params (the refinery's rollback handle).
        Raises ValueError if the incoming pytree would retrace."""
        if self.model.g_apply is None:
            raise ValueError(
                "hot_swap_g on a non-parametric model: build the "
                "DepthModel with g_apply/g_params (params-are-inputs) "
                "to make the correction swappable")
        gp = jax.tree_util.tree_map(jnp.asarray, gp)
        validate_g_swap(self.g_params, gp)
        old, self.g_params = self.g_params, gp
        return old

    def hot_swap_flow(self, fp):
        """Install new flow-head params between drains — the flow twin of
        ``hot_swap_g``, same zero-retrace contract (the flow cell takes
        them as a traced input). Returns the previous params."""
        if self.model.flow_apply is None:
            raise ValueError(
                "hot_swap_flow on a model with no flow head: build the "
                "DepthModel with flow_apply/flow_params (core/flowhead."
                "py) to make the K=0 tier swappable")
        fp = jax.tree_util.tree_map(jnp.asarray, fp)
        validate_g_swap(self.flow_params, fp, label="hot_swap_flow")
        old, self.flow_params = self.flow_params, fp
        return old

    # ------------------------------------------------------------ serve ----
    def step(self, now: float = 0.0) -> List[Completed]:
        """Drain the queue once: probe, bucket, pack, solve. Returns the
        completed requests (order not guaranteed — uid is the join key).
        ``self.last_report`` carries this drain's virtual-cost accounting
        (StepReport) for the trace replayer in launch/workload.py.

        ``now`` is the replay clock at drain start: requests already
        past their deadline drop terminally (``status="deadline"``)
        before any probe is spent on them. Rows whose outputs come back
        non-finite either retry (re-queued at the next-finer bucket,
        served by the NEXT drain, bounded by the RetryPolicy) or return
        best-effort with ``status="diverged"``."""
        done: List[Completed] = list(self._shed)   # surface shed refusals
        self._shed = []
        if not self._queue:
            self.last_report = StepReport(
                finish_offset={c.uid: 0.0 for c in done})
            return done
        stages = self.model.integ.tableau.stages
        cost = probe_cost = 0.0
        useful = total = batches = probe_nonfinite = 0
        flow_served = escalated = 0
        finish_offset: Dict[int, float] = {c.uid: 0.0 for c in done}
        # degrade pressure is measured once per drain, at its start
        degrade = (self.queue_cap is not None
                   and self.overload_policy == "degrade"
                   and len(self._queue) > self.queue_cap)
        pending: List[Request] = []
        while self._queue:
            r = self._queue.popleft()
            if r.deadline is not None and r.deadline < now:
                finish_offset[r.uid] = 0.0
                done.append(Completed(
                    uid=r.uid, outputs=None, K=0,
                    nfe=self._nfe_extra.pop(r.uid, 0), err_probe=0.0,
                    fused_kernel=False, status="deadline"))
                continue
            pending.append(r)
        if not pending:
            self.last_report = StepReport(finish_offset=finish_offset)
            return done
        # group by request shape — each shape is its own jit cell
        by_shape: Dict[Tuple, List[Request]] = {}
        for r in pending:
            by_shape.setdefault(r.x.shape, []).append(r)

        for shape, reqs in by_shape.items():
            rows = [r.x for r in reqs]
            if self.fault_injector is not None:
                rows = [self.fault_injector.corrupt_admission(
                    r.uid, r.attempts, x) for r, x in zip(reqs, rows)]
            xs = np.stack(rows)
            if isinstance(self.controller, FixedController):
                Ks_raw = np.full((len(reqs),), self.controller.K, np.int32)
                errs = np.zeros((len(reqs),), np.float32)
                z0 = dz0 = None
            else:
                Ks_dev, err_dev, z0, dz0 = self._probe_fn(shape)(
                    jnp.asarray(xs), *self._g_args())
                Ks_raw = np.asarray(Ks_dev)
                errs = np.asarray(err_dev)
                probe_nonfinite += screen_probe_errors(errs)
                p = self.oracle.probe_cost(
                    shape, len(reqs),
                    getattr(self.controller, "probe_nfe", 0))
                probe_cost += p
                cost += p
            Ks = snap_to_buckets(Ks_raw, self.ecfg.buckets)
            if degrade:
                # graceful degradation: every admission in an over-
                # pressure drain serves one bucket coarser than asked —
                # agreement trades off measurably, nothing is refused
                b = np.asarray(sorted(self.ecfg.buckets), np.int32)
                Ks = b[np.maximum(np.searchsorted(b, Ks) - 1, 0)]
            # retry-ladder escalation: a re-queued request never serves
            # below its K_floor (the next-finer bucket than the one that
            # failed)
            floors = np.asarray([r.K_floor for r in reqs], np.int32)
            Ks = np.maximum(Ks, floors)

            if self.ledger is not None:
                # residual-ledger capture (launch/refinery.py): one extra
                # readout per drain, computed from the probe states the
                # cells already materialized at the eps each request will
                # actually integrate at (the fixed path has no probe, so
                # capture embeds its own copy). Rows with a non-finite
                # probe (quarantine-bound) are excluded; capture reads
                # state, never mutates it, and is never priced by the
                # cost oracle — so capture-enabled completions stay
                # bitwise identical to capture-disabled ones.
                span = self.model.span
                z_cap = z0 if z0 is not None else \
                    self._embed_fn(shape)(jnp.asarray(xs))
                self.ledger.capture(
                    jnp.asarray(xs), z_cap,
                    np.full(len(reqs), span[0], np.float32),
                    ((span[1] - span[0])
                     / Ks.astype(np.float64)).astype(np.float32),
                    keep=np.isfinite(errs))

            # mixed-K packing: sort by K so batches stay as K-pure as the
            # traffic allows (bucket purity bounds masked-step waste), then
            # fill batches of <= max_batch straight through — a batch mixing
            # buckets still solves fused, scanning to its largest K.
            take = lambda tree, sel: None if tree is None else \
                jax.tree_util.tree_map(lambda l: l[sel], tree)
            # vet the actual state dtypes so Completed.fused_kernel is
            # honest; the fixed path has no probe z0, so eval_shape the
            # embedding (dtypes only, no compute)
            z_like = z0 if z0 is not None else jax.eval_shape(
                self.model.embed,
                jax.ShapeDtypeStruct(xs.shape, xs.dtype))
            fused = self.fused_in_play(z_like)

            # K=0 flow tier (core/flowhead.py): requests whose probe
            # error sits confidently below tol skip the ladder entirely
            # — one flow-head eval + readout, zero solver steps. Tier is
            # a PACKING decision like the buckets: flow rows route to
            # their own per-shape jit cell and are excluded from the
            # pack loop below; nothing about the ladder cells changes.
            # With the tier disabled (router is None) this whole block
            # is a no-op and the drain is bitwise identical to pre-flow.
            flow_sel = np.zeros(len(reqs), bool)
            if self.router is not None and z0 is not None:
                flow_sel = np.asarray(self.router.flow_mask(
                    errs, self.ecfg.tol, floors))
            fidx = np.flatnonzero(flow_sel)
            if len(fidx):
                f_out = np.asarray(self._flow_fn(shape)(
                    jnp.asarray(xs[fidx]), take(z0, fidx),
                    take(dz0, fidx), *self._flow_args()))
                cost += self.oracle.flow_cost(shape, len(fidx))
                for j, i in enumerate(fidx):
                    r = reqs[i]
                    row = f_out[j]
                    if self.fault_injector is not None:
                        row = self.fault_injector.corrupt_flow_eval(
                            r.uid, r.attempts, row)
                    if not np.isfinite(row).all():
                        # escalation path: the no-solver answer failed —
                        # bill the flow attempt and requeue into the
                        # K-bucket ladder at the coarsest bucket (the
                        # next drain re-probes; K_floor > 0 also bars
                        # re-routing to flow), bounded by the RetryPolicy
                        if self.retry.should_retry(
                                "diverged", r.attempts):
                            self._nfe_extra[r.uid] = (
                                self._nfe_extra.get(r.uid, 0)
                                + self.nfe_flow)
                            self._queue.append(dataclasses.replace(
                                r, attempts=r.attempts + 1,
                                K_floor=min(self.ecfg.buckets),
                                escalated=True))
                            escalated += 1
                            continue
                        finish_offset[r.uid] = cost
                        done.append(Completed(
                            uid=r.uid, outputs=row, K=0,
                            nfe=self.nfe_flow
                            + self._nfe_extra.pop(r.uid, 0),
                            err_probe=float(errs[i]),
                            fused_kernel=False, status="diverged"))
                        continue
                    # flow_mask bars K_floor > 0, so attempts == 0 here
                    finish_offset[r.uid] = cost
                    flow_served += 1
                    done.append(Completed(
                        uid=r.uid, outputs=row, K=0,
                        nfe=self.nfe_flow
                        + self._nfe_extra.pop(r.uid, 0),
                        err_probe=float(errs[i]), fused_kernel=False,
                        status="ok"))

            order = np.argsort(Ks, kind="stable")
            order = order[~flow_sel[order]]
            for lo in range(0, len(order), self.ecfg.max_batch):
                sel = order[lo:lo + self.ecfg.max_batch]
                k_max = int(Ks[sel].max())
                outputs = np.asarray(
                    self._solve_fn(shape, k_max)(
                        jnp.asarray(xs[sel]), take(z0, sel),
                        take(dz0, sel), jnp.asarray(Ks[sel], jnp.int32),
                        *self._g_args()))
                cost += self.oracle.solve_cost(shape, k_max, len(sel),
                                               stages)
                useful += int(Ks[sel].sum())
                total += len(sel) * k_max
                batches += 1
                # row-wise non-finite screen on the ALREADY-materialized
                # outputs (no extra device transfer): diverged rows
                # climb the retry ladder or return best-effort
                finite = np.isfinite(
                    outputs.reshape(len(sel), -1)).all(axis=1)
                for j, i in enumerate(sel):
                    r, K = reqs[i], int(Ks[i])
                    if not finite[j]:
                        # next-finer bucket; at the top (where a poisoned
                        # probe's k_max clamp lands) one clean re-run at
                        # the same bucket, bounded by the RetryPolicy
                        nxt = next_bucket_above(K, self.ecfg.buckets) or K
                        if self.retry.should_retry(
                                "diverged", r.attempts):
                            self._nfe_extra[r.uid] = (
                                self._nfe_extra.get(r.uid, 0)
                                + self.nfe_of(K))
                            self._queue.append(dataclasses.replace(
                                r, attempts=r.attempts + 1, K_floor=nxt))
                            continue     # served by the next drain
                        status = "diverged"
                    else:
                        status = "ok" if r.attempts == 0 else (
                            "escalated" if r.escalated else "retried")
                    finish_offset[r.uid] = cost
                    done.append(Completed(
                        uid=r.uid, outputs=outputs[j], K=K,
                        nfe=self.nfe_of(K)
                        + self._nfe_extra.pop(r.uid, 0),
                        err_probe=float(errs[i]), fused_kernel=fused,
                        status=status))
        self.last_report = StepReport(
            cost=cost, probe_cost=probe_cost, useful_steps=useful,
            total_steps=total, batches=batches,
            probe_nonfinite=probe_nonfinite, finish_offset=finish_offset,
            flow_served=flow_served, escalated=escalated)
        return done

    def run(self, xs) -> List[Completed]:
        """Convenience: submit a batch (leading axis = requests) and drain
        to completion, returning results ordered by submission. Loops
        until every uid is terminal — a retried request drains again,
        bounded by the RetryPolicy, so this always terminates."""
        uids = [self.submit(x) for x in np.asarray(xs)]
        results: Dict[int, Completed] = {}
        while len(self):
            for c in self.step():
                results[c.uid] = c
        return [results[u] for u in uids]
