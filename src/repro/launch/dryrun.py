import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.

"""Multi-pod dry-run: AOT lower + compile every (arch x shape) cell on the
production meshes, and record memory/cost/collective analysis for the
roofline (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_8b --shape train_4k \
      [--multi-pod] [--seq-shard] [--remat full] [--microbatches 4]
"""
import argparse
import json
import re
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, cell_is_applicable, get
from repro.distributed import sharding as shd
from repro.launch.mesh import batch_axes, make_production_mesh, mesh_context
from repro.launch.steps import (
    StepSettings, data_shardings, input_specs, make_prefill_step,
    make_serve_step, make_train_step,
)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")

# Per-arch training-step settings (microbatching + remat sized for HBM).
TRAIN_SETTINGS = {
    "nemotron_4_340b": StepSettings(microbatches=16, remat="full",
                                    seq_shard=True, fsdp=True,
                                    moment_dtype="bfloat16",
                                    acc_dtype="bfloat16"),
    "llama4_maverick_400b_a17b": StepSettings(microbatches=8, remat="full",
                                              seq_shard=True, fsdp=True,
                                              moment_dtype="bfloat16",
                                              acc_dtype="bfloat16"),
    "mistral_nemo_12b": StepSettings(microbatches=4, remat="full"),
    "qwen3_8b": StepSettings(microbatches=4, remat="full"),
    "whisper_base": StepSettings(microbatches=1, remat="dots"),
    "_default": StepSettings(microbatches=4, remat="full"),
}

_COLL_RE = re.compile(
    r"(\S+?)\s*=\s*(\S+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.I)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def collective_bytes(hlo_text: str):
    """Sum output bytes of every collective op in optimized HLO, per class.

    Convention: bytes counted are the (per-participating-device) op output
    — a consistent proxy for link traffic across collective kinds.
    """
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3).lower()
        if "-done(" in line:  # avoid double-count of async pairs
            continue
        ty = m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(ty):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] += nbytes
        counts[kind] += 1
    return out, counts


def _attach(specs, shardings):
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        specs, shardings)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             settings: StepSettings = None, verbose: bool = True,
             mesh=None):
    cfg = get(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_is_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "SKIP", "reason": reason}
    settings = settings or TRAIN_SETTINGS.get(arch, TRAIN_SETTINGS["_default"])
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh_context(mesh):
        if settings.seq_shard and shape.kind != "decode":
            shd.set_activation_sharding(batch_axes(mesh), seq_axis="model")
        else:
            shd.set_activation_sharding(batch_axes(mesh))
        # q-chunked exact attention for long sequences: bounds the score
        # buffer to (B, H, 512, S) — XLA-level flash analogue (nn/attention)
        from repro.nn.attention import set_attention_chunking
        if shape.kind != "decode" and shape.seq_len >= 4096:
            set_attention_chunking(512)
        if settings.fsdp:
            shd.set_param_resharding(mesh)
        try:
            specs = input_specs(cfg, shape)
            d_sh = data_shardings(mesh, cfg, specs)
            specs = _attach(specs, d_sh)
            if shape.kind == "train":
                step, opt, (a_p, a_o, p_sh, o_sh) = make_train_step(
                    cfg, settings, mesh)
                a_params = _attach(a_p, p_sh)
                a_opt = _attach(a_o, o_sh)
                step0 = jax.ShapeDtypeStruct((), jnp.int32,
                                             sharding=NamedSharding(mesh, P()))
                lowered = step.lower(a_params, a_opt, step0, specs)
            elif shape.kind == "prefill":
                step, (a_p, p_sh) = make_prefill_step(cfg, settings, mesh)
                lowered = step.lower(_attach(a_p, p_sh), specs)
            else:
                step, (a_p, p_sh) = make_serve_step(cfg, mesh, settings)
                lowered = step.lower(_attach(a_p, p_sh), specs["token"],
                                     specs["caches"], specs["cur_index"])
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
            coll, coll_counts = collective_bytes(hlo)
            res = {
                "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "OK",
                "compile_s": round(time.time() - t0, 1),
                "settings": dataclass_dict(settings),
                "n_devices": int(mesh.size),
                "flops": float(cost.get("flops", -1.0)),
                "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
                "memory": {
                    "argument_bytes": int(mem.argument_size_in_bytes),
                    "output_bytes": int(mem.output_size_in_bytes),
                    "temp_bytes": int(mem.temp_size_in_bytes),
                    "generated_code_bytes": int(
                        mem.generated_code_size_in_bytes),
                    "alias_bytes": int(mem.alias_size_in_bytes),
                },
                "collective_bytes": coll,
                "collective_counts": coll_counts,
            }
            if verbose:
                print(f"[OK] {arch} x {shape_name} "
                      f"({'2x16x16' if multi_pod else '16x16'}) "
                      f"compile={res['compile_s']}s "
                      f"flops={res['flops']:.3e} "
                      f"temp/dev={mem.temp_size_in_bytes / 2**30:.2f}GiB "
                      f"coll={sum(coll.values()) / 2**30:.2f}GiB")
            return res
        except Exception as e:
            traceback.print_exc()
            return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                    "status": "FAIL", "error": f"{type(e).__name__}: {e}"}
        finally:
            shd.clear_activation_sharding()
            shd.clear_param_resharding()
            set_attention_chunking(None)


def dataclass_dict(s: StepSettings):
    import dataclasses
    return dataclasses.asdict(s)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--seq-shard", action="store_true", default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--int8-dispatch", action="store_true")
    ap.add_argument("--ep-data", action="store_true")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    from repro.models.lm import set_perf_options
    from repro.distributed.sharding import set_ep_axis
    if args.int8_dispatch:
        set_perf_options(int8_dispatch=True)
    if args.kv_int8:
        set_perf_options(kv_int8=True)
    if args.ep_data:
        set_ep_axis("data")
    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) \
        else [args.multi_pod]

    results = []
    mesh_cache = {}
    for mp in meshes:
        if mp not in mesh_cache:
            mesh_cache[mp] = make_production_mesh(multi_pod=mp)
        for arch in archs:
            for shape in shapes:
                settings = TRAIN_SETTINGS.get(arch,
                                              TRAIN_SETTINGS["_default"])
                overrides = {}
                if args.seq_shard is not None:
                    overrides["seq_shard"] = args.seq_shard
                if args.remat:
                    overrides["remat"] = args.remat
                if args.microbatches:
                    overrides["microbatches"] = args.microbatches
                if overrides:
                    import dataclasses
                    settings = dataclasses.replace(settings, **overrides)
                res = run_cell(arch, shape, mp, settings,
                               mesh=mesh_cache[mp])
                results.append(res)
                tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}" + \
                    (f"__{args.tag}" if args.tag else "")
                with open(os.path.join(ARTIFACT_DIR, tag + ".json"), "w") as f:
                    json.dump(res, f, indent=1)

    # only --all owns summary.json (single-cell reruns must not clobber)
    default_name = "summary.json" if args.all else "summary_partial.json"
    out = args.out or os.path.join(ARTIFACT_DIR, default_name)
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "OK" for r in results)
    n_skip = sum(r["status"] == "SKIP" for r in results)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"\n== dry-run: {n_ok} OK, {n_skip} SKIP (documented), "
          f"{n_fail} FAIL ==")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
