"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3_4b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1 [--resume]

Loop skeleton (the piece that matters at 1000 nodes):
  restore-latest -> data skip-ahead -> step loop under a watchdog ->
  periodic async checkpoints -> on failure: bounded restore-and-retry.
Works on CPU with reduced configs; the same code drives the production
mesh when devices exist (mesh/microbatching/sharding all flow from
launch/steps.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get
from repro.data import ShardedLoader, token_batches
from repro.distributed.fault import (
    FailureInjector, StepFailure, StepWatchdog, WatchdogConfig,
)
from repro.launch.mesh import mesh_context
from repro.launch.steps import StepSettings, make_train_step
from repro.models.lm import init_lm
from repro.models import encdec as whisper

log = logging.getLogger("repro.train")


def train_loop(
    cfg,
    settings: StepSettings,
    mesh,
    steps: int,
    batch_iter,
    ckpt: Optional[CheckpointManager] = None,
    ckpt_every: int = 25,
    injector: Optional[FailureInjector] = None,
    watchdog: Optional[StepWatchdog] = None,
    seed: int = 0,
):
    """Returns (params, opt_state, history). Restartable: if ``ckpt`` has a
    latest step, resumes from it (params, opt state, step index)."""
    step_fn, opt, (a_params, a_opt, p_sh, o_sh) = make_train_step(
        cfg, settings, mesh)
    watchdog = watchdog or StepWatchdog(WatchdogConfig())

    start = 0
    params = opt_state = None
    if ckpt is not None:
        latest = ckpt.latest_step()
        if latest is not None:
            state = ckpt.restore(latest, {"params": a_params, "opt": a_opt},
                                 {"params": p_sh, "opt": o_sh})
            params, opt_state = state["params"], state["opt"]
            start = latest
            log.info("resumed from step %d", latest)
    if params is None:
        init = (whisper.init_encdec if cfg.is_encdec else init_lm)
        with mesh_context(mesh):
            params = jax.jit(
                lambda k: init(k, cfg), out_shardings=p_sh
            )(jax.random.PRNGKey(seed))
            opt_state = jax.jit(opt.init, out_shardings=o_sh)(params)

    history = []
    it = iter(batch_iter)
    # data skip-ahead keeps the stream aligned with the resumed step
    for _ in range(start):
        next(it)

    step = start
    while step < steps:
        batch = next(it)
        try:
            if injector is not None:
                injector.maybe_fail(step)
            # the watchdog owns the NaN screen (WatchdogConfig.
            # nan_is_failure): loss_of names the scalar to vet
            params, opt_state, metrics = watchdog.run(
                step_fn, params, opt_state, jnp.asarray(step, jnp.int32),
                batch, loss_of=lambda out: out[2]["loss"])
            loss = float(metrics["loss"])
        except StepFailure as e:
            log.warning("step %d failed: %s", step, e)
            if ckpt is None or not watchdog.record_failure():
                raise
            latest = ckpt.latest_step()
            if latest is None:
                raise StepFailure("no checkpoint to restore from") from e
            state = ckpt.restore(latest, {"params": a_params, "opt": a_opt},
                                 {"params": p_sh, "opt": o_sh})
            params, opt_state = state["params"], state["opt"]
            # rewind the data stream to the restored step
            it = iter(batch_iter)
            for _ in range(latest):
                next(it)
            step = latest
            continue
        history.append({"step": step, "loss": loss,
                        "grad_norm": float(metrics["grad_norm"])})
        step += 1
        if ckpt is not None and (step % ckpt_every == 0 or step == steps):
            ckpt.save(step, {"params": params, "opt": opt_state})
    if ckpt is not None:
        ckpt.wait()
    return params, opt_state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = jax.make_mesh((1, 1), ("data", "model")) \
        if jax.device_count() == 1 else None
    assert mesh is not None, "production launch requires a real device mesh"
    settings = StepSettings(microbatches=args.microbatches, remat="none",
                            lr=args.lr, zero_opt=False)

    batches = ({"tokens": t, "targets": y}
               for t, y in token_batches(cfg.vocab, args.batch, args.seq))
    ckpt = CheckpointManager(args.ckpt_dir, keep=3, async_save=True) \
        if args.ckpt_dir else None

    t0 = time.time()
    params, _, hist = train_loop(cfg, settings, mesh, args.steps,
                                 batches, ckpt, args.ckpt_every)
    for h in hist[::args.log_every] + hist[-1:]:
        print(f"step {h['step']:5d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.3f}")
    print(f"total {time.time() - t0:.1f}s; final loss {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
