from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adamw,
    sgd,
    apply_updates,
    global_norm,
    clip_by_global_norm,
)
from repro.optim.schedules import (  # noqa: F401
    constant,
    cosine_annealing,
    linear_warmup_cosine,
)
