"""Learning-rate schedules (pure functions of the integer step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_annealing(lr_max: float, lr_min: float, total_steps: int):
    """Cosine anneal lr_max -> lr_min over total_steps (paper Sec. C.2)."""

    def sched(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return lr_min + 0.5 * (lr_max - lr_min) * (1.0 + jnp.cos(jnp.pi * t))

    return sched


def linear_warmup_cosine(lr_max: float, lr_min: float, warmup: int, total: int):
    def sched(step):
        warm = lr_max * jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = lr_min + 0.5 * (lr_max - lr_min) * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)

    return sched
