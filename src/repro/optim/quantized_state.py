"""8-bit block-quantized Adam moments (Dettmers-style blockwise absmax).

For very large configs (nemotron-4-340b) fp32 m+v is ~2.7 TB; int8 moments
with per-256-block fp32 scales cut optimizer-state memory 4x at negligible
update error (tested against fp32 AdamW).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer

BLOCK = 256


class QTensor(NamedTuple):
    q: jnp.ndarray       # int8 payload, padded flat
    scale: jnp.ndarray   # fp32 per-block absmax
    # static metadata lives in the pytree structure via aux dict


def quantize_blockwise(x: jnp.ndarray):
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return QTensor(q=q, scale=scale)


def dequantize_blockwise(qt: QTensor, shape, dtype=jnp.float32):
    flat = (qt.q.astype(jnp.float32) * qt.scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


class Adam8bitState(NamedTuple):
    mu: Any
    nu: Any


def adamw8bit(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
              weight_decay: float = 0.0) -> Optimizer:
    sched = lr if callable(lr) else (lambda _s: jnp.asarray(lr, jnp.float32))

    def init(params):
        z = lambda p: quantize_blockwise(jnp.zeros(p.shape, jnp.float32))
        return Adam8bitState(
            mu=jax.tree_util.tree_map(z, params),
            nu=jax.tree_util.tree_map(z, params),
        )

    def update(grads, state: Adam8bitState, params, step):
        step = jnp.asarray(step, jnp.float32) + 1.0
        lr_t = sched(step)
        bc1 = 1.0 - b1 ** step
        bc2 = 1.0 - b2 ** step

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_p = jax.tree_util.tree_leaves(params)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)

        upds, new_m, new_v = [], [], []
        for g, p, mq, vq in zip(flat_g, flat_p, flat_m, flat_v):
            g32 = g.astype(jnp.float32)
            m = b1 * dequantize_blockwise(mq, g.shape) + (1 - b1) * g32
            v = b2 * dequantize_blockwise(vq, g.shape) + (1 - b2) * g32 * g32
            m_hat = m / bc1
            v_hat = v / bc2
            d = m_hat / (jnp.sqrt(v_hat) + eps)
            if weight_decay:
                d = d + weight_decay * p.astype(jnp.float32)
            upds.append(-lr_t * d)
            new_m.append(quantize_blockwise(m))
            new_v.append(quantize_blockwise(v))

        updates = jax.tree_util.tree_unflatten(treedef, upds)
        return updates, Adam8bitState(
            mu=jax.tree_util.tree_unflatten(treedef, new_m),
            nu=jax.tree_util.tree_unflatten(treedef, new_v),
        )

    return Optimizer(init=init, update=update)
