"""Optimizers as (init, update) pairs over parameter pytrees.

AdamW (decoupled weight decay, Loshchilov & Hutter 2017) is the paper's
hypersolver-training optimizer; Adam (wd=0) its model-training optimizer.
State dtype is configurable: fp32 moments by default, int8 block-quantized
moments for very large models (see optim/quantized_state.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Params = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]


class Optimizer(NamedTuple):
    init: Callable[[Params], Any]
    update: Callable[..., Any]  # (grads, state, params, step) -> (updates, state)


def global_norm(tree: Params) -> jnp.ndarray:
    leaves = [jnp.sum(l.astype(jnp.float32) ** 2)
              for l in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree: Params, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree_util.tree_map(lambda l: l * scale, tree), norm


def apply_updates(params: Params, updates: Params) -> Params:
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)), params, updates
    )


class AdamState(NamedTuple):
    mu: Params
    nu: Params


def adamw(
    lr: Schedule | float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    moment_dtype: jnp.dtype = jnp.float32,
) -> Optimizer:
    sched = lr if callable(lr) else (lambda _s: jnp.asarray(lr, jnp.float32))

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return AdamState(
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
        )

    def update(grads, state: AdamState, params, step):
        step = jnp.asarray(step, jnp.float32) + 1.0
        lr_t = sched(step)

        def upd_mu(g, m):
            return (b1 * m.astype(jnp.float32)
                    + (1 - b1) * g.astype(jnp.float32)).astype(moment_dtype)

        def upd_nu(g, v):
            g32 = g.astype(jnp.float32)
            return (b2 * v.astype(jnp.float32)
                    + (1 - b2) * g32 * g32).astype(moment_dtype)

        mu = jax.tree_util.tree_map(upd_mu, grads, state.mu)
        nu = jax.tree_util.tree_map(upd_nu, grads, state.nu)
        bc1 = 1.0 - b1 ** step
        bc2 = 1.0 - b2 ** step

        def upd(p, m, v):
            m_hat = m.astype(jnp.float32) / bc1
            v_hat = v.astype(jnp.float32) / bc2
            step_dir = m_hat / (jnp.sqrt(v_hat) + eps)
            if weight_decay:
                step_dir = step_dir + weight_decay * p.astype(jnp.float32)
            return (-lr_t * step_dir).astype(jnp.float32)

        updates = jax.tree_util.tree_map(upd, params, mu, nu)
        return updates, AdamState(mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


class SgdState(NamedTuple):
    momentum: Optional[Params]


def sgd(lr: Schedule | float, momentum: float = 0.0) -> Optimizer:
    sched = lr if callable(lr) else (lambda _s: jnp.asarray(lr, jnp.float32))

    def init(params):
        if momentum == 0.0:
            return SgdState(momentum=None)
        return SgdState(
            momentum=jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
        )

    def update(grads, state: SgdState, params, step):
        lr_t = sched(jnp.asarray(step, jnp.float32))
        if momentum == 0.0:
            upd = jax.tree_util.tree_map(
                lambda g: -lr_t * g.astype(jnp.float32), grads
            )
            return upd, state
        buf = jax.tree_util.tree_map(
            lambda b, g: momentum * b + g.astype(jnp.float32),
            state.momentum, grads,
        )
        upd = jax.tree_util.tree_map(lambda b: -lr_t * b, buf)
        return upd, SgdState(momentum=buf)

    return Optimizer(init=init, update=update)
