"""int8 gradient compression with error feedback (1-bit-Adam lineage).

Two pieces:
  * ``compress_with_feedback`` — blockwise int8 quantization of gradients
    with an error-feedback accumulator, applied before the optimizer. The
    residual re-enters the next step, so the scheme is unbiased in the
    long run (convergence tests in tests/test_optim.py).
  * ``compressed_allreduce_mean`` — a shard_map collective that
    quantizes -> all_gathers int8 payloads + fp32 scales -> dequantizes and
    means locally: 4x less DP gradient traffic than an fp32 all-reduce
    (exercised on a forced-multi-device CPU subprocess in tests).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.optim.quantized_state import dequantize_blockwise, quantize_blockwise


def compress_with_feedback(grads: Any, error_fb: Any) -> Tuple[Any, Any]:
    """Quantize (g + e) to int8 blocks; carry the quantization residual."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        qt = quantize_blockwise(g32)
        g_hat = dequantize_blockwise(qt, g.shape)
        return g_hat.astype(g.dtype), g32 - g_hat

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(error_fb)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    g_hats = jax.tree_util.tree_unflatten(tdef, [o[0] for o in outs])
    new_e = jax.tree_util.tree_unflatten(tdef, [o[1] for o in outs])
    return g_hats, new_e


def init_error_feedback(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_allreduce_mean(x: jnp.ndarray, mesh, axis: str = "data"):
    """Mean over `axis` with int8 payloads (shard_map manual collective)."""
    n = mesh.shape[axis]

    def body(xl):
        qt = quantize_blockwise(xl)
        qs = jax.lax.all_gather(qt.q, axis)          # (n, blocks, BLOCK) int8
        ss = jax.lax.all_gather(qt.scale, axis)      # (n, blocks, 1) fp32
        deq = qs.astype(jnp.float32) * ss            # (n, blocks, BLOCK)
        total = jnp.sum(deq, axis=0).reshape(-1)
        m = 1
        for s in xl.shape:
            m *= s
        return (total[:m] / n).reshape(xl.shape).astype(xl.dtype)

    fn = jax.shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                       check_vma=False)
    return fn(x)
