"""Deterministic synthetic data pipelines (container is offline).

* token streams: structured Zipf-ish next-token-predictable sequences for
  LM training drivers (a learnable Markov-like process so loss decreases);
* 2-D densities for CNFs: pinwheel / rings / checkerboard / circles
  (the paper's own procedural densities, Sec. 4.2 + Grathwohl et al.);
* synthetic image classification: class-conditional stroke/blob renders in
  MNIST-like (28x28x1) and CIFAR-like (32x32x3) formats — documented
  substitution for the unavailable natural-image sets (DESIGN.md §7).
"""
from __future__ import annotations

from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------- tokens ----

def token_batches(vocab: int, batch: int, seq_len: int, seed: int = 0,
                  order: int = 2) -> Iterator[Tuple[jnp.ndarray, jnp.ndarray]]:
    """Learnable synthetic LM stream: order-2 Markov chain over a reduced
    alphabet embedded in [0, vocab). Deterministic given seed."""
    rng = np.random.default_rng(seed)
    alpha = min(vocab, 512)
    trans = rng.dirichlet(np.full(alpha, 0.05), size=(alpha, alpha))
    cum = np.cumsum(trans, axis=-1)
    while True:
        toks = np.zeros((batch, seq_len + 1), np.int64)
        toks[:, 0] = rng.integers(0, alpha, batch)
        toks[:, 1] = rng.integers(0, alpha, batch)
        u = rng.random((batch, seq_len + 1))
        for t in range(2, seq_len + 1):
            c = cum[toks[:, t - 2], toks[:, t - 1]]
            toks[:, t] = (u[:, t, None] < c).argmax(-1)
        yield (jnp.asarray(toks[:, :-1], jnp.int32),
               jnp.asarray(toks[:, 1:], jnp.int32))


# ----------------------------------------------------------- densities ----

def _pinwheel(rng, n):
    radial_std, tangential_std, num_classes, rate = 0.3, 0.1, 5, 0.25
    rads = np.linspace(0, 2 * np.pi, num_classes, endpoint=False)
    feats = rng.standard_normal((n, 2)) * np.array([radial_std,
                                                    tangential_std])
    feats[:, 0] += 1.0
    labels = rng.integers(0, num_classes, n)
    angles = rads[labels] + rate * np.exp(feats[:, 0])
    rot = np.stack([np.cos(angles), -np.sin(angles),
                    np.sin(angles), np.cos(angles)], -1).reshape(n, 2, 2)
    return 2.0 * np.einsum("ni,nij->nj", feats, rot)


def _rings(rng, n):
    n_per = n // 3 + 1
    pts = []
    for r in (1.0, 2.0, 3.0):
        t = rng.random(n_per) * 2 * np.pi
        pts.append(np.stack([r * np.cos(t), r * np.sin(t)], -1))
    x = np.concatenate(pts)[:n]
    return x + 0.08 * rng.standard_normal((n, 2))


def _checkerboard(rng, n):
    x1 = rng.random(n) * 4 - 2
    x2_ = rng.random(n) - rng.integers(0, 2, n) * 2
    x2 = x2_ + np.floor(x1) % 2
    return np.stack([x1, x2], -1) * 2


def _circles(rng, n):
    """Paper's 'modified, more challenging circles': two annuli connected
    by three curves."""
    n_ring = int(n * 0.8)
    n_arm = n - n_ring
    pts = []
    for r in (1.0, 2.5):
        t = rng.random(n_ring // 2 + 1) * 2 * np.pi
        pts.append(np.stack([r * np.cos(t), r * np.sin(t)], -1))
    ring = np.concatenate(pts)[:n_ring]
    a = rng.integers(0, 3, n_arm)
    base = a * 2 * np.pi / 3
    rr = 1.0 + 1.5 * rng.random(n_arm)
    curve = base + 0.4 * (rr - 1.0)
    arm = np.stack([rr * np.cos(curve), rr * np.sin(curve)], -1)
    x = np.concatenate([ring, arm])
    return x + 0.05 * rng.standard_normal(x.shape)


DENSITIES = {
    "pinwheel": _pinwheel,
    "rings": _rings,
    "checkerboard": _checkerboard,
    "circles": _circles,
}


def density_sampler(name: str, batch: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    fn = DENSITIES[name]
    while True:
        yield jnp.asarray(fn(rng, batch), jnp.float32)


# -------------------------------------------------------------- images ----

def synthetic_images(kind: str, n: int, seed: int = 0):
    """Class-conditional procedural images. kind: 'mnist28' | 'cifar32'.
    Ten classes; each class = deterministic arrangement of oriented strokes
    and blobs + noise. Returns (images NHWC float32 in [0,1], labels)."""
    rng = np.random.default_rng(seed)
    if kind == "mnist28":
        H = W = 28
        C = 1
    elif kind == "cifar32":
        H = W = 32
        C = 3
    else:
        raise ValueError(kind)
    ys = rng.integers(0, 10, n)
    yy, xx = np.mgrid[0:H, 0:W].astype(np.float32)
    imgs = np.zeros((n, H, W, C), np.float32)
    for i in range(n):
        c = ys[i]
        img = np.zeros((H, W), np.float32)
        # class-dependent strokes: k-th class gets k%3+1 bars at angle ~c
        for j in range(c % 3 + 1):
            ang = (c * 36.0 + j * 50.0) * np.pi / 180.0
            cx = H / 2 + rng.normal(0, 1.5)
            cy = W / 2 + rng.normal(0, 1.5)
            d = np.abs(np.cos(ang) * (xx - cx) + np.sin(ang) * (yy - cy))
            img += np.exp(-(d ** 2) / 4.0)
        # class-dependent blob ring
        r0 = 4.0 + (c % 5) * 2.0
        rr = np.sqrt((xx - W / 2) ** 2 + (yy - H / 2) ** 2)
        img += 0.7 * np.exp(-((rr - r0) ** 2) / 3.0) * ((c >= 5) * 1.0)
        img += 0.08 * rng.standard_normal((H, W))
        img = np.clip(img / max(img.max(), 1e-6), 0, 1)
        if C == 1:
            imgs[i, ..., 0] = img
        else:
            phase = np.array([1.0, 0.8 + 0.04 * c, 0.6 + 0.04 * c])
            imgs[i] = img[..., None] * phase[None, None]
    return jnp.asarray(imgs), jnp.asarray(ys, jnp.int32)
