"""Sharded, prefetching data loader.

Host-side iterator -> device arrays with the global-batch NamedSharding.
On a real multi-host pod each process feeds only its addressable shard
(``jax.make_array_from_process_local_data``); in this single-process
container the full batch is placed with ``jax.device_put`` under the same
sharding, which is semantically identical for SPMD. A background thread
keeps ``prefetch`` batches in flight so host data prep overlaps device
compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Iterator, Optional

import jax


class ShardedLoader:
    def __init__(self, it: Iterator[Any], sharding=None, prefetch: int = 2):
        self._it = it
        self._sharding = sharding
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _place(self, batch):
        if self._sharding is None:
            return batch
        return jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), batch, self._sharding)

    def _fill(self):
        try:
            for batch in self._it:
                self._q.put(self._place(batch))
        except BaseException as e:  # surfaced on next __next__
            self._err = e
        finally:
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item
