from repro.data.synthetic import (  # noqa: F401
    token_batches, density_sampler, synthetic_images, DENSITIES,
)
from repro.data.loader import ShardedLoader  # noqa: F401
