"""Name-path sharding rules: DP / TP / EP / SP without touching model code.

``param_pspec(path, ndim)`` maps a parameter's tree path to a
PartitionSpec; stacked layer params (leading group axis) get a None
prepended automatically. ``zero_spec`` additionally shards optimizer
moments over the 'data' axis (ZeRO-1). Activation constraints are applied
through the module-level hooks ``constrain`` (no-ops outside a mesh
context, so unit tests are unaffected).
"""
from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# (regex on 'a/b/c' path, spec for the UNSTACKED param). Order matters.
_RULES: Tuple[Tuple[str, Tuple], ...] = (
    (r"embed/table$", ("model", None)),          # vocab sharding
    (r"head/kernel$", (None, "model")),
    (r"patch_proj/kernel$", (None, "model")),
    (r"pos_embed$", (None, None)),
    (r"enc_pos$", (None, None)),
    (r"dec_pos$", (None, None)),
    # attention
    (r"(attn|self_attn|cross_attn)/w[qkv]/kernel$", (None, "model")),
    (r"(attn|self_attn|cross_attn)/wo/kernel$", ("model", None)),
    (r"(q_norm|k_norm)/scale$", (None,)),
    # dense ffn
    (r"ffn/w[ig]/kernel$", (None, "model")),
    (r"ffn/wd/kernel$", ("model", None)),
    (r"shared/w[ig]/kernel$", (None, "model")),
    (r"shared/wd/kernel$", ("model", None)),
    # MoE: expert-parallel over 'model'
    (r"moe/router/kernel$", (None, None)),
    (r"moe/w[igd]$", ("model", None, None)),
    # RWKV6
    (r"tmix/w[rkvg]/kernel$", (None, "model")),
    (r"tmix/wo/kernel$", ("model", None)),
    (r"tmix/(mu_x|u|w0)$", ("model",)),
    (r"tmix/mu$", (None, "model")),
    (r"tmix/lora_a1$", (None, None)),
    (r"tmix/lora_a2$", (None, None, "model")),
    (r"tmix/w_lora1$", (None, None)),
    (r"tmix/w_lora2$", (None, "model")),
    (r"tmix/gn_(scale|bias)$", ("model", None)),
    (r"cmix/w[k]/kernel$", (None, "model")),
    (r"cmix/wv/kernel$", ("model", None)),
    (r"cmix/wr/kernel$", (None, "model")),
    (r"cmix/mix_[kr]$", ("model",)),
    # Griffin / RG-LRU (recurrence width sharded over 'model')
    (r"griffin/in_(rec|gate)/kernel$", (None, "model")),
    (r"griffin/out/kernel$", ("model", None)),
    (r"griffin/conv/w$", (None, "model")),
    (r"griffin/conv/b$", ("model",)),
    (r"rglru/w[ax]/kernel$", (None, "model")),
    (r"rglru/(ba|bx|lam)$", ("model",)),
    # norms & anything 1-D: replicate
    (r"(ln1|ln2|ln_x|ln_f|ln_enc|ln_dec)/(scale|bias)$", (None,)),
)


def path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(k.name)
        else:
            parts.append(str(k))
    return "/".join(parts)


# Expert-parallel placement: 'model' (default, Switch/GShard style: the
# all-to-all shares the TP axis) or 'data' (DeepSpeed-MoE style: expert
# weights live on the DP axis — no FSDP weight all-gather for experts,
# dispatch a2a crosses the data axis instead). §Perf hillclimb B.
_EP = {"axis": "model"}


def set_ep_axis(axis: str) -> None:
    assert axis in ("model", "data")
    _EP["axis"] = axis


def param_pspec(path: str, ndim: int) -> P:
    if _EP["axis"] == "data" and re.search(r"moe/w[igd]$", path):
        # wi/wg: (E, d, f) -> E over data, f over model;
        # wd:    (E, f, d) -> E over data, f over model
        spec = ("data", None, "model") if not path.endswith("wd") \
            else ("data", "model", None)
        if ndim > 3:
            spec = (None,) * (ndim - 3) + spec
        return P(*spec)
    spec: Optional[Tuple] = None
    for pat, sp in _RULES:
        if re.search(pat, path):
            spec = sp
            break
    if spec is None:
        spec = (None,) * ndim  # replicate unknowns (safe default)
    if len(spec) < ndim:  # stacked group/layer leading axes
        spec = (None,) * (ndim - len(spec)) + tuple(spec)
    assert len(spec) == ndim, (path, spec, ndim)
    return P(*spec)


def sanitize_spec(mesh, spec: P, shape) -> P:
    """Drop mesh axes from dims they don't divide (e.g. whisper's 51865
    vocab on a 16-way model axis -> replicate that dim)."""
    out = []
    for ax, dim in zip(spec, shape):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        out.append(ax if dim % n == 0 else None)
    return P(*out)


def param_shardings(mesh, abstract_params):
    """NamedSharding tree for an abstract (eval_shape) param tree."""
    def one(path, leaf):
        spec = param_pspec(path_str(path), leaf.ndim)
        return NamedSharding(mesh, sanitize_spec(mesh, spec, leaf.shape))
    return jax.tree_util.tree_map_with_path(one, abstract_params)


_STACKED_RE = re.compile(r"^(groups|enc_blocks|dec_blocks)/")


def _stack_skip(path: str) -> int:
    """Parameters under a scanned stack have a leading layer axis that
    lax.scan slices each iteration — it must stay UNSHARDED, otherwise XLA
    hoists a full all-gather of the stacked tensor out of the loop."""
    return 1 if _STACKED_RE.search(path) else 0


def zero_pspec(path: str, shape: Tuple[int, ...], data_size: int,
               skip: int | None = None) -> P:
    """ZeRO/FSDP: param spec plus 'data' sharding on the first eligible
    dim (unsharded, divisible) — skipping the scanned stack axis."""
    base = list(param_pspec(path, len(shape)))
    skip = _stack_skip(path) if skip is None else skip

    def _used(ax):
        return [ax] if isinstance(ax, str) else list(ax or ())

    in_use = {a for ax in base for a in _used(ax)}
    if "data" in in_use:          # e.g. EP-over-data expert weights
        return P(*base)
    for i in range(skip, len(shape)):
        ax, dim = base[i], shape[i]
        if ax is None and dim % data_size == 0 and dim >= data_size:
            base[i] = "data"
            break
    return P(*base)


def grad_shardings(mesh, abstract_params, zero: bool = True):
    """Sharding for gradient accumulators: param spec + 'data' sharding of
    the first divisible unsharded dim (ZeRO-2: grads live reduce-scattered
    across the data axis during accumulation)."""
    data_size = mesh.shape.get("data", 1)

    def one(path, leaf):
        ps = path_str(path)
        spec = zero_pspec(ps, leaf.shape, data_size) if zero \
            else param_pspec(ps, leaf.ndim)
        return NamedSharding(mesh, sanitize_spec(mesh, spec, leaf.shape))
    return jax.tree_util.tree_map_with_path(one, abstract_params)


def opt_state_shardings(mesh, abstract_opt_state, zero: bool = True):
    data_size = mesh.shape.get("data", 1)

    def one(path, leaf):
        ps = path_str(path)
        # strip AdamState prefix (mu/..., nu/..., index keys) for matching
        ps = re.sub(r"^(mu|nu|momentum|[01])/", "", ps)
        if not zero:
            spec = param_pspec(ps, leaf.ndim)
        else:
            spec = zero_pspec(ps, leaf.shape, data_size)
        return NamedSharding(mesh, sanitize_spec(mesh, spec, leaf.shape))
    return jax.tree_util.tree_map_with_path(one, abstract_opt_state)


# ------------------------------------------------ activation constraints ----

_ACT: dict = {"enabled": False, "batch": ("data",), "seq": None}
_PARAM_RESHARD: dict = {"enabled": False, "mesh": None}


def set_param_resharding(mesh) -> None:
    """FSDP mode: inside the layer scan, constrain the per-iteration param
    slice to its TP-only spec. The data-axis all-gather then happens on ONE
    group's weights per step inside the loop (and its transpose is a
    per-group reduce-scatter of grads), instead of XLA hoisting a full
    all-gather of the stacked weights out of the loop."""
    _PARAM_RESHARD["enabled"] = True
    _PARAM_RESHARD["mesh"] = mesh


def clear_param_resharding() -> None:
    _PARAM_RESHARD["enabled"] = False
    _PARAM_RESHARD["mesh"] = None


def constrain_group_params(gp):
    """FSDP in-loop resharding with a custom VJP:

    forward : constrain each param slice to its TP-only spec -> the 'data'
              all-gather of ONE group's weights happens inside the loop;
    backward: cast the weight cotangent to the PARAM dtype (bf16) and
              constrain it to the FSDP grad spec -> the backward scan's
              stacked ys buffer is bf16 and reduce-scattered over 'data'
              instead of an fp32 full replica (85 GiB/device at 340B).
    """
    if not _PARAM_RESHARD["enabled"]:
        return gp
    mesh = _PARAM_RESHARD["mesh"]
    data_size = mesh.shape.get("data", 1)

    flat, tdef = jax.tree_util.tree_flatten_with_path(gp)
    paths = [path_str(p) for p, _ in flat]
    leaves = [l for _, l in flat]
    fwd_specs = [sanitize_spec(mesh, param_pspec(p, l.ndim), l.shape)
                 for p, l in zip(paths, leaves)]
    # cotangent spec: TP spec + 'data' on the first eligible dim (the
    # slice has no stack axis, so skip=0)
    bwd_specs = [sanitize_spec(mesh, zero_pspec(p, l.shape, data_size,
                                                skip=0), l.shape)
                 for p, l in zip(paths, leaves)]
    dtypes = [l.dtype for l in leaves]

    @jax.custom_vjp
    def reshard(*ls):
        return tuple(jax.lax.with_sharding_constraint(l, s)
                     for l, s in zip(ls, fwd_specs))

    def fwd(*ls):
        return reshard(*ls), None

    def bwd(_, dls):
        return tuple(
            jax.lax.with_sharding_constraint(d.astype(dt), s)
            for d, dt, s in zip(dls, dtypes, bwd_specs))

    reshard.defvjp(fwd, bwd)
    return jax.tree_util.tree_unflatten(tdef, reshard(*leaves))


def set_activation_sharding(batch_axes: Sequence[str],
                            seq_axis: Optional[str] = None):
    """Enable with_sharding_constraint hooks inside model code.
    seq_axis='model' activates sequence partitioning (SP) of the residual
    stream between blocks."""
    _ACT["enabled"] = True
    _ACT["batch"] = tuple(batch_axes)
    _ACT["seq"] = seq_axis


def clear_activation_sharding():
    _ACT["enabled"] = False
    _ACT["seq"] = None


def constrain(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    """kind: 'residual' (B,S,d) | 'logits' (B,S,V) | 'batch' (B, ...)."""
    if not _ACT["enabled"]:
        return x
    b = tuple(_ACT["batch"]) if len(_ACT["batch"]) > 1 else _ACT["batch"][0]
    if kind == "residual":
        spec = P(b, _ACT["seq"], None)
    elif kind == "logits":
        spec = P(b, None, "model")
    elif kind == "batch":
        spec = P(b, *([None] * (x.ndim - 1)))
    else:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
