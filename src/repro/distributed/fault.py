"""Fault-tolerance utilities: step watchdog (straggler detection), retry
policy, and simulated-failure injection for tests and the chaos bench.

On a real multi-pod deployment the failure signals come from the runtime
(pre-emption notices, ICI link errors, heartbeat timeouts); in this
container we implement the *control logic* — deadline monitoring, bounded
restart-from-checkpoint retries, and exclusion notes — and inject failures
synthetically to exercise it end to end (tests/test_fault_tolerance.py).

Two bounded-retry mechanisms live here and share one idea — a failure is
retried at most N times before the caller gets the best-effort answer:

  * ``StepWatchdog`` + ``WatchdogConfig`` guard TRAINING steps (walltime
    deadline, NaN screening, restart-from-checkpoint budget);
  * ``RetryPolicy`` guards SERVING requests (launch/scheduler.py /
    launch/engine.py): a request whose solve diverged is retried once at
    a finer mesh bucket before being returned ``status="diverged"``.

``FaultInjector`` is the serving-side chaos source (seeded, hash-keyed —
deterministic per (seed, uid) regardless of loop interleaving, so the
sync and overlap ticks see IDENTICAL faults); ``FailureInjector`` is the
training-side one (raise at given steps).
"""
from __future__ import annotations

import dataclasses
import hashlib
import logging
import math
import time
from typing import Callable, Optional, Tuple

import numpy as np

log = logging.getLogger("repro.fault")


class StepFailure(RuntimeError):
    """A training step failed (device loss, NaN blow-up, injected fault)."""


@dataclasses.dataclass
class WatchdogConfig:
    step_deadline_s: float = 600.0     # straggler threshold
    max_restarts: int = 3              # per incident window
    nan_is_failure: bool = True
    # close the incident window on the first clean step after a failure:
    # the restart budget then bounds CONSECUTIVE failures (a fleet that
    # hits one transient per day never exhausts it), instead of the
    # lifetime total. Default False: the historical budget-for-the-whole-
    # run accounting, which tests/test_fault_tolerance.py pins.
    reset_on_success: bool = False


class StepWatchdog:
    """Wraps step execution: walltime deadline + NaN screening + restart
    accounting. Synchronous SPMD means a straggler shows up as a slow step
    everywhere; the mitigation at fleet scale is restart-without-the-bad-
    host from the last checkpoint, which maps onto restore() here.

    ``run(fn, *args, loss_of=...)`` owns the NaN screen: when
    ``cfg.nan_is_failure`` and ``loss_of(out)`` is non-finite, it raises
    ``StepFailure`` itself. (It used to leave the screen to callers —
    ``nan_is_failure`` sat in the config while every call site
    re-implemented the check ad hoc; launch/train.py was the one caller
    that remembered.)"""

    def __init__(self, cfg: WatchdogConfig):
        self.cfg = cfg
        self.restarts = 0
        self.step_times: list = []

    def run(self, fn: Callable, *args,
            loss_of: Optional[Callable] = None):
        t0 = time.time()
        out = fn(*args)
        dt = time.time() - t0
        self.step_times.append(dt)
        if dt > self.cfg.step_deadline_s:
            log.warning("step exceeded deadline: %.1fs > %.1fs (straggler?)",
                        dt, self.cfg.step_deadline_s)
        if loss_of is not None and self.cfg.nan_is_failure:
            loss = float(loss_of(out))
            if not math.isfinite(loss):
                raise StepFailure(f"non-finite loss: {loss}")
        if self.cfg.reset_on_success and self.restarts:
            log.info("clean step after %d restart(s): incident window "
                     "closed", self.restarts)
            self.restarts = 0
        return out

    def record_failure(self) -> bool:
        """Returns True if a restart is allowed."""
        self.restarts += 1
        if self.restarts > self.cfg.max_restarts:
            log.error("restart budget exhausted (%d)", self.restarts)
            return False
        log.warning("restart %d/%d", self.restarts, self.cfg.max_restarts)
        return True


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded per-request retry ladder for the serving loops — the
    request-level analog of the watchdog's restart budget.

    A request whose slot is force-retired (non-finite quarantine, or a
    deadline eviction when opted in) is re-queued at the NEXT-FINER mesh
    bucket (``K_floor`` escalation in launch/scheduler.py) — or, when
    its failed K was already the finest bucket, re-run once at that same
    bucket (a transient fault deserves one clean pass) — at most
    ``max_retries`` times; after that the caller gets the best-effort
    partial readout with a terminal status. Deadline evictions are not
    retried by
    default: a finer mesh cannot un-miss a deadline (add ``"deadline"``
    to ``retry_statuses`` to opt in anyway)."""

    max_retries: int = 1
    retry_statuses: Tuple[str, ...] = ("diverged",)

    def should_retry(self, status: str, attempts: int) -> bool:
        return status in self.retry_statuses and attempts < self.max_retries


def _hash01(*keys) -> float:
    """Deterministic [0, 1) hash of the key tuple — stable across
    processes and call order (unlike ``random`` state or ``hash()``),
    so the sync and overlap loops draw identical fault decisions.

    blake2b, not crc32: CRC is a linear code, so key tuples that differ
    only in a trailing integer (consecutive uids) land in a narrow band
    and a small poison fraction can silently select nothing."""
    digest = hashlib.blake2b(repr(keys).encode(), digest_size=8).digest()
    return int.from_bytes(digest, "big") / 2.0 ** 64


@dataclasses.dataclass
class FaultInjector:
    """Seeded serving-chaos source (benchmarks/bench_faults.py): every
    decision is a pure function of ``(seed, site, uid-or-tick[, n])``
    via ``_hash01``, never of call order — the sync and overlap ticks
    (and re-runs) see bitwise-identical fault schedules.

    Three fault sites, all host-side (no extra device transfer):

      * ``corrupt_admission`` — NaN-poison a fraction of request inputs
        at admission; the poisoned solve diverges and the segment cell's
        quarantine flag force-retires it. ``nan_transient=True`` poisons
        only attempt 0, so a retried request runs clean (exercising the
        ``retried`` terminal status); ``False`` poisons every attempt
        (exercising ``diverged``).
      * ``drop_retire_flags`` — suppress finished flags with probability
        ``drop_flag_p`` per (uid, segment) BEFORE the scheduler reads
        them (a lost completion signal). Keyed on the slot's segment
        count, so a dropped flag is re-drawn next segment and the
        request still terminates (zero-hang) for any ``p < 1``.
      * ``inflate_segment_cost`` — multiply a fraction of dispatched
        segments' cost by ``straggle_factor`` (virtual stragglers on the
        oracle clock), pushing in-flight requests past their deadlines.
        Keyed on the scheduler's dispatch-sequence counter, NOT its tick
        counter: the overlap loop burns retire-only flush ticks at pool
        drain, so tick counters drift across loops while the dispatch
        sequence stays identical.
      * ``corrupt_flow_eval`` — NaN-poison a fraction of K=0 flow-tier
        outputs (``flow_nan_frac``), exercising the escalation path:
        the serving loops screen the flow row host-side and requeue the
        request into the K-bucket ladder (terminal ``escalated``). A
        SEPARATE site from ``corrupt_admission`` because an admission-
        poisoned input already fails the probe's finite screen and is
        never flow-routed — only a fault in the flow eval itself can
        exercise escalation. Honors ``nan_transient`` the same way.
    """

    seed: int = 0
    nan_uid_frac: float = 0.0
    nan_transient: bool = True
    drop_flag_p: float = 0.0
    straggle_tick_frac: float = 0.0
    straggle_factor: float = 4.0
    flow_nan_frac: float = 0.0

    def corrupt_admission(self, uid: int, attempts: int,
                          x: np.ndarray) -> np.ndarray:
        if self.nan_uid_frac <= 0.0:
            return x
        if self.nan_transient and attempts > 0:
            return x
        if _hash01(self.seed, "nan", int(uid)) < self.nan_uid_frac:
            x = np.array(x, copy=True)
            x.reshape(-1)[0] = np.nan
        return x

    def corrupt_flow_eval(self, uid: int, attempts: int,
                          out_row: np.ndarray) -> np.ndarray:
        if self.flow_nan_frac <= 0.0:
            return out_row
        if self.nan_transient and attempts > 0:
            return out_row
        if _hash01(self.seed, "flow", int(uid)) < self.flow_nan_frac:
            out_row = np.array(out_row, copy=True)
            out_row.reshape(-1)[0] = np.nan
        return out_row

    def drop_retire_flags(self, uids: np.ndarray, segments: np.ndarray,
                          finished: np.ndarray) -> np.ndarray:
        if self.drop_flag_p <= 0.0:
            return finished
        out = finished.copy()
        for i in np.flatnonzero(finished):
            if _hash01(self.seed, "flag", int(uids[i]),
                       int(segments[i])) < self.drop_flag_p:
                out[i] = False
        return out

    def inflate_segment_cost(self, seq: int, cost: float) -> float:
        if self.straggle_tick_frac <= 0.0:
            return cost
        if _hash01(self.seed, "straggle", int(seq)) \
                < self.straggle_tick_frac:
            return cost * self.straggle_factor
        return cost


class FailureInjector:
    """Deterministic failure injection for tests: raise at given steps."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise StepFailure(f"injected failure at step {step}")
