"""Fault-tolerance utilities: step watchdog (straggler detection), retry
policy, and simulated-failure injection for tests.

On a real multi-pod deployment the failure signals come from the runtime
(pre-emption notices, ICI link errors, heartbeat timeouts); in this
container we implement the *control logic* — deadline monitoring, bounded
restart-from-checkpoint retries, and exclusion notes — and inject failures
synthetically to exercise it end to end (tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable, Optional

log = logging.getLogger("repro.fault")


class StepFailure(RuntimeError):
    """A training step failed (device loss, NaN blow-up, injected fault)."""


@dataclasses.dataclass
class WatchdogConfig:
    step_deadline_s: float = 600.0     # straggler threshold
    max_restarts: int = 3              # per incident window
    nan_is_failure: bool = True


class StepWatchdog:
    """Wraps step execution: walltime deadline + NaN screening + restart
    accounting. Synchronous SPMD means a straggler shows up as a slow step
    everywhere; the mitigation at fleet scale is restart-without-the-bad-
    host from the last checkpoint, which maps onto restore() here."""

    def __init__(self, cfg: WatchdogConfig):
        self.cfg = cfg
        self.restarts = 0
        self.step_times: list = []

    def run(self, fn: Callable, *args):
        t0 = time.time()
        out = fn(*args)
        dt = time.time() - t0
        self.step_times.append(dt)
        if dt > self.cfg.step_deadline_s:
            log.warning("step exceeded deadline: %.1fs > %.1fs (straggler?)",
                        dt, self.cfg.step_deadline_s)
        return out

    def record_failure(self) -> bool:
        """Returns True if a restart is allowed."""
        self.restarts += 1
        if self.restarts > self.cfg.max_restarts:
            log.error("restart budget exhausted (%d)", self.restarts)
            return False
        log.warning("restart %d/%d", self.restarts, self.cfg.max_restarts)
        return True


class FailureInjector:
    """Deterministic failure injection for tests: raise at given steps."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise StepFailure(f"injected failure at step {step}")
