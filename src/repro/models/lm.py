"""Unified causal LM covering the dense / MoE / RWKV6 / Griffin-hybrid
families behind one interface.

Layer stacks are expressed as a repeating block *pattern* (e.g. llama4 =
[dense, moe], recurrentgemma = [rec, rec, attn]); groups of the pattern are
parameter-stacked on a leading axis and applied with ``lax.scan`` so HLO
size is O(1) in depth — essential for the 96-layer dry-run compiles. A
remainder of ``n_layers mod len(pattern)`` becomes explicit tail layers.

Decode maintains per-group caches (KV for attention — rotating buffer under
a sliding window so long_500k is O(window); recurrent states for RWKV/LRU).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.distributed.sharding import constrain
from repro.distributed.sharding import constrain_group_params as shd_constrain_group
from repro.nn.attention import apply_rope, mha
from repro.nn.attention import attention_init, mha_decode
from repro.nn.ffn import ffn_apply, ffn_init, rwkv_channel_mix, rwkv_channel_mix_init
from repro.nn.moe import moe_apply, moe_apply_sorted, moe_init
from repro.nn.module import (
    dense_init, embedding_init, rmsnorm, rmsnorm_init, truncated_normal_init,
)
from repro.nn.rglru import (
    causal_conv1d, griffin_recurrent_apply, griffin_recurrent_init,
    rglru_decode_step,
)
from repro.nn.rwkv6 import rwkv6_init, rwkv6_time_mix

Params = Any


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# Runtime performance options (set by the launcher, not by model code):
#   int8_dispatch — quantize the MoE EP all-to-all payload (§Perf A)
#   kv_int8      — int8 KV cache with per-token-head scales (§Perf C)
PERF_OPT = {"int8_dispatch": False, "kv_int8": False}


def set_perf_options(**kw):
    for k, v in kw.items():
        assert k in PERF_OPT, k
        PERF_OPT[k] = v


# ----------------------------------------------------------- patterns ----

def block_pattern(cfg: ArchConfig) -> Tuple[str, ...]:
    if cfg.rwkv_heads:
        return ("rwkv",)
    if cfg.pattern_attn_every:
        return ("rec",) * (cfg.pattern_attn_every - 1) + ("attn",)
    if cfg.n_experts:
        if cfg.moe_every == 1:
            return ("moe",)
        return ("dense",) * (cfg.moe_every - 1) + ("moe",)
    return ("dense",)


def group_layout(cfg: ArchConfig) -> Tuple[Tuple[str, ...], int, int]:
    pattern = block_pattern(cfg)
    n_groups, tail = divmod(cfg.n_layers, len(pattern))
    return pattern, n_groups, tail


def discrete_nfe(cfg: ArchConfig) -> int:
    """Depth-ODE NFE equivalent of the discrete full-depth forward: one
    vector-field (= block-group) evaluation per group. The serving pareto
    (launch/engine.py, benchmarks/bench_serve.py) reports continuous-depth
    NFE against this baseline."""
    _, n_groups, _ = group_layout(cfg)
    return n_groups


# ------------------------------------------------------------- blocks ----

def block_init(key, cfg: ArchConfig, kind: str) -> Params:
    pd = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    if kind in ("dense", "attn"):
        p = {
            "ln1": rmsnorm_init(d, pd),
            "attn": attention_init(ks[0], d, cfg.n_heads, cfg.n_kv, cfg.d_head,
                                   qk_norm=cfg.qk_norm, param_dtype=pd),
            "ln2": rmsnorm_init(d, pd),
            "ffn": ffn_init(ks[1], d, cfg.d_ff, cfg.gated_ffn, pd),
        }
        return p
    if kind == "moe":
        p = {
            "ln1": rmsnorm_init(d, pd),
            "attn": attention_init(ks[0], d, cfg.n_heads, cfg.n_kv, cfg.d_head,
                                   qk_norm=cfg.qk_norm, param_dtype=pd),
            "ln2": rmsnorm_init(d, pd),
            "moe": moe_init(ks[1], d, cfg.d_ff_expert or cfg.d_ff,
                            cfg.n_experts, gated=cfg.gated_ffn, param_dtype=pd),
        }
        if cfg.shared_expert:
            p["shared"] = ffn_init(ks[2], d, cfg.d_ff, cfg.gated_ffn, pd)
        return p
    if kind == "rwkv":
        return {
            "ln1": rmsnorm_init(d, pd),
            "tmix": rwkv6_init(ks[0], d, cfg.rwkv_heads, cfg.lora_rank, pd),
            "ln2": rmsnorm_init(d, pd),
            "cmix": rwkv_channel_mix_init(ks[1], d, cfg.d_ff, pd),
        }
    if kind == "rec":
        return {
            "ln1": rmsnorm_init(d, pd),
            "griffin": griffin_recurrent_init(ks[0], d, cfg.lru_width, pd),
            "ln2": rmsnorm_init(d, pd),
            "ffn": ffn_init(ks[1], d, cfg.d_ff, cfg.gated_ffn, pd),
        }
    raise ValueError(kind)


def _attn_kwargs(cfg: ArchConfig, kind: str) -> Dict:
    window = cfg.local_window if (kind == "attn" and cfg.pattern_attn_every) \
        else cfg.window
    return dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_head=cfg.d_head,
                rope_theta=cfg.rope_theta, window=window,
                qk_norm=cfg.qk_norm, use_rope=(cfg.pos == "rope"))


def block_apply(p: Params, cfg: ArchConfig, kind: str, h: jnp.ndarray,
                aux: Dict[str, jnp.ndarray]):
    """Full-sequence (train / prefill) block application."""
    if kind in ("dense", "attn", "moe"):
        h = h + mha(p["attn"], rmsnorm(p["ln1"], h), **_attn_kwargs(cfg, kind))
        xn = rmsnorm(p["ln2"], h)
        if kind == "moe":
            # sort-based dispatch: the einsum dispatch is O(T^2) (capacity
            # grows with T) — see nn/moe.py::moe_apply_sorted
            out = moe_apply_sorted(
                p["moe"], xn, n_experts=cfg.n_experts, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor, act=cfg.act,
                int8_dispatch=PERF_OPT["int8_dispatch"])
            y = out.y
            if "shared" in p:
                y = y + ffn_apply(p["shared"], xn, act=cfg.act)
            aux = {
                "moe_aux": aux["moe_aux"] + out.aux_loss,
                "moe_z": aux["moe_z"] + out.router_z_loss,
                "moe_dropped": jnp.maximum(aux["moe_dropped"],
                                           out.fraction_dropped),
            }
            h = h + y
        else:
            h = h + ffn_apply(p["ffn"], xn, act=cfg.act)
        return h, aux
    if kind == "rwkv":
        tm, _ = rwkv6_time_mix(p["tmix"], rmsnorm(p["ln1"], h), cfg.rwkv_heads)
        h = h + tm
        xn = rmsnorm(p["ln2"], h)
        x_prev = jnp.concatenate(
            [jnp.zeros_like(xn[:, :1]), xn[:, :-1]], axis=1)
        h = h + rwkv_channel_mix(p["cmix"], xn, x_prev)
        return h, aux
    if kind == "rec":
        y, _ = griffin_recurrent_apply(p["griffin"], rmsnorm(p["ln1"], h))
        h = h + y
        h = h + ffn_apply(p["ffn"], rmsnorm(p["ln2"], h), act=cfg.act)
        return h, aux
    raise ValueError(kind)


# ------------------------------------------------------------ caches ----

def block_cache_init(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                     dtype) -> Params:
    d = cfg.d_model
    if kind in ("dense", "attn", "moe"):
        window = cfg.local_window if (kind == "attn" and cfg.pattern_attn_every) \
            else cfg.window
        buf = min(max_len, window) if window else max_len
        from repro.nn.attention import init_cache
        return init_cache(batch, buf, cfg.n_kv, cfg.d_head, dtype,
                          kv_int8=(PERF_OPT["kv_int8"] and window is None))
    if kind == "rwkv":
        hd = d // cfg.rwkv_heads
        return {
            "x_tmix": jnp.zeros((batch, d), dtype),
            "S": jnp.zeros((batch, cfg.rwkv_heads, hd, hd), jnp.float32),
            "x_cmix": jnp.zeros((batch, d), dtype),
        }
    if kind == "rec":
        return {
            "conv": jnp.zeros((batch, 3, cfg.lru_width), dtype),
            "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
        }
    raise ValueError(kind)


def _rotating_decode_attn(p, cfg: ArchConfig, kind: str, h, cache, cur_index):
    """Decode attention with a rotating buffer when windowed (O(window) for
    long_500k); plain indexed cache otherwise. RoPE is applied at write time
    with absolute positions (rotation-safe: scores depend on pos deltas)."""
    kwargs = _attn_kwargs(cfg, kind)
    window = kwargs["window"]
    buf = cache["k"].shape[1]
    if window is None:
        out, new_cache = mha_decode(p["attn"], h, cache, cur_index, **kwargs)
        return out, new_cache
    # rotating window cache
    from repro.nn.attention import _proj, NEG_INF
    B = h.shape[0]
    q = _proj(p["attn"]["wq"], h, cfg.n_heads, cfg.d_head)
    k_new = _proj(p["attn"]["wk"], h, cfg.n_kv, cfg.d_head)
    v_new = _proj(p["attn"]["wv"], h, cfg.n_kv, cfg.d_head)
    if cfg.qk_norm:
        q = rmsnorm(p["attn"]["q_norm"], q)
        k_new = rmsnorm(p["attn"]["k_norm"], k_new)
    pos = jnp.asarray(cur_index)[None]
    if kwargs["use_rope"]:
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)
    slot = jnp.mod(cur_index, buf)
    k_all = jax.lax.dynamic_update_slice(
        cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
    v_all = jax.lax.dynamic_update_slice(
        cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
    # slot i holds absolute position: valid iff abs_pos > cur - window
    idx = jnp.arange(buf)
    # absolute position of each slot given we just wrote cur at slot
    abs_pos = cur_index - jnp.mod(slot - idx, buf)
    valid = (abs_pos >= 0) & (cur_index - abs_pos < window)
    bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
    group = cfg.n_heads // cfg.n_kv
    qg = q.reshape(B, 1, cfg.n_kv, group, cfg.d_head)
    scores = jnp.einsum("bsngh,btnh->bngst", qg, k_all.astype(q.dtype),
                        preferred_element_type=jnp.float32)
    scores = scores * (cfg.d_head ** -0.5) + bias
    probs = jax.nn.softmax(scores, axis=-1).astype(h.dtype)
    ctx = jnp.einsum("bngst,btnh->bsngh", probs, v_all.astype(h.dtype),
                     preferred_element_type=jnp.float32).astype(h.dtype)
    ctx = ctx.reshape(B, 1, cfg.n_heads * cfg.d_head)
    out = jnp.matmul(ctx, p["attn"]["wo"]["kernel"].astype(h.dtype),
                     preferred_element_type=jnp.float32).astype(h.dtype)
    return out, {"k": k_all, "v": v_all}


def block_decode(p: Params, cfg: ArchConfig, kind: str, h: jnp.ndarray,
                 cache: Params, cur_index):
    """Single-token decode. h: (B, 1, d). Returns (h, new_cache)."""
    if kind in ("dense", "attn", "moe"):
        a, new_cache = _rotating_decode_attn(p, cfg, kind,
                                             rmsnorm(p["ln1"], h), cache,
                                             cur_index)
        h = h + a
        xn = rmsnorm(p["ln2"], h)
        if kind == "moe":
            out = moe_apply(p["moe"], xn, n_experts=cfg.n_experts,
                            top_k=cfg.top_k,
                            capacity_factor=max(cfg.capacity_factor, 2.0),
                            act=cfg.act)
            y = out.y
            if "shared" in p:
                y = y + ffn_apply(p["shared"], xn, act=cfg.act)
            h = h + y
        else:
            h = h + ffn_apply(p["ffn"], xn, act=cfg.act)
        return h, new_cache
    if kind == "rwkv":
        from repro.nn.rwkv6 import rwkv6_decode_step
        xn = rmsnorm(p["ln1"], h)[:, 0]
        tm, (x_tmix, S) = rwkv6_decode_step(
            p["tmix"], xn, (cache["x_tmix"].astype(xn.dtype), cache["S"]),
            cfg.rwkv_heads)
        h = h + tm[:, None]
        xn2 = rmsnorm(p["ln2"], h)[:, 0]
        cm = rwkv_channel_mix(p["cmix"], xn2[:, None],
                              cache["x_cmix"][:, None].astype(xn2.dtype))
        h = h + cm
        return h, {"x_tmix": x_tmix.astype(cache["x_tmix"].dtype), "S": S,
                   "x_cmix": xn2.astype(cache["x_cmix"].dtype)}
    if kind == "rec":
        from repro.nn.module import dense
        xn = rmsnorm(p["ln1"], h)
        gp = p["griffin"]
        u = dense(gp["in_rec"], xn)
        g = jax.nn.gelu(dense(gp["in_gate"], xn), approximate=True)
        u, conv_carry = causal_conv1d(gp["conv"], u,
                                      cache["conv"].astype(u.dtype))
        y_t, h_state = rglru_decode_step(gp["rglru"], u[:, 0], cache["h"])
        y = dense(gp["out"], (y_t[:, None] * g))
        h = h + y
        h = h + ffn_apply(p["ffn"], rmsnorm(p["ln2"], h), act=cfg.act)
        return h, {"conv": conv_carry.astype(cache["conv"].dtype),
                   "h": h_state}
    raise ValueError(kind)


# -------------------------------------------------------------- model ----

def init_lm(key, cfg: ArchConfig) -> Params:
    pd = dtype_of(cfg.param_dtype)
    pattern, n_groups, tail = group_layout(cfg)
    k_embed, k_groups, k_tail, k_head, k_pos = jax.random.split(key, 5)

    def init_group(k):
        ks = jax.random.split(k, len(pattern))
        return {f"b{i}": block_init(ks[i], cfg, kind)
                for i, kind in enumerate(pattern)}

    group_keys = jax.random.split(k_groups, n_groups)
    groups = jax.vmap(init_group)(group_keys)

    params = {
        "embed": embedding_init(k_embed, cfg.vocab, cfg.d_model, pd),
        "groups": groups,
        "ln_f": rmsnorm_init(cfg.d_model, pd),
    }
    tail_keys = jax.random.split(k_tail, max(tail, 1))
    params["tail"] = {f"t{i}": block_init(tail_keys[i], cfg, pattern[i])
                      for i in range(tail)}
    if not cfg.tie_embeddings:
        params["head"] = dense_init(k_head, cfg.d_model, cfg.vocab, pd)
    if cfg.pos == "learned":
        params["pos_embed"] = truncated_normal_init(
            k_pos, (8192, cfg.d_model), 0.02, pd)
    if cfg.frontend == "patches":
        params["patch_proj"] = dense_init(k_pos, cfg.d_model, cfg.d_model, pd)
    return params


def _embed(params, cfg: ArchConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    dt = dtype_of(cfg.dtype)
    h = jnp.take(params["embed"]["table"], tokens, axis=0).astype(dt)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, dt)
    return h


def _readout(params, cfg: ArchConfig, h: jnp.ndarray) -> jnp.ndarray:
    h = rmsnorm(params["ln_f"], h)
    if cfg.tie_embeddings:
        w = params["embed"]["table"].astype(h.dtype)
        return jnp.matmul(h, w.T, preferred_element_type=jnp.float32)
    return jnp.matmul(h, params["head"]["kernel"].astype(h.dtype),
                      preferred_element_type=jnp.float32)


ZERO_AUX = lambda: {"moe_aux": jnp.zeros((), jnp.float32),
                    "moe_z": jnp.zeros((), jnp.float32),
                    "moe_dropped": jnp.zeros((), jnp.float32)}


def lm_forward(params, cfg: ArchConfig, tokens: jnp.ndarray,
               frontend: Optional[jnp.ndarray] = None,
               remat: str = "none"):
    """tokens: (B, S) int32. ``frontend``: precomputed modality embeddings
    (B, N, d) prepended to the text sequence (paligemma patches).
    Returns (logits fp32 (B, S_total, V), aux dict)."""
    pattern, n_groups, tail = group_layout(cfg)
    h = _embed(params, cfg, tokens)
    if frontend is not None:
        from repro.nn.module import dense
        fe = dense(params["patch_proj"], frontend.astype(h.dtype))
        h = jnp.concatenate([fe, h], axis=1)
    if cfg.pos == "learned":
        S = h.shape[1]
        h = h + params["pos_embed"][:S].astype(h.dtype)

    h = constrain(h, "residual")

    def group_fn(h, gp):
        gp = shd_constrain_group(gp)  # FSDP: per-group all-gather in-loop
        aux = ZERO_AUX()
        for i, kind in enumerate(pattern):
            h, aux = block_apply(gp[f"b{i}"], cfg, kind, h, aux)
        return constrain(h, "residual"), aux

    if remat == "full":
        group_fn = jax.checkpoint(group_fn)
    elif remat == "dots":
        group_fn = jax.checkpoint(
            group_fn, policy=jax.checkpoint_policies.checkpoint_dots)

    h, auxs = jax.lax.scan(group_fn, h, params["groups"])
    aux = jax.tree_util.tree_map(lambda a: jnp.sum(a, 0), auxs)
    for i in range(tail):
        h, aux = block_apply(params["tail"][f"t{i}"], cfg, pattern[i], h, aux)
    logits = constrain(_readout(params, cfg, h), "logits")
    return logits, aux


def lm_loss(params, cfg: ArchConfig, tokens: jnp.ndarray,
            targets: jnp.ndarray, frontend: Optional[jnp.ndarray] = None,
            remat: str = "none", moe_aux_weight: float = 0.01,
            moe_z_weight: float = 1e-3):
    logits, aux = lm_forward(params, cfg, tokens, frontend, remat)
    if frontend is not None:
        logits = logits[:, -tokens.shape[1]:]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    loss = ce
    if cfg.n_experts:
        loss = loss + moe_aux_weight * aux["moe_aux"] + \
            moe_z_weight * aux["moe_z"]
    metrics = {"ce": ce, **aux}
    return loss, metrics


def init_lm_cache(cfg: ArchConfig, batch: int, max_len: int,
                  dtype=None) -> Params:
    """Stacked decode caches: leading axis = group index."""
    dtype = dtype or dtype_of(cfg.dtype)
    pattern, n_groups, tail = group_layout(cfg)

    def one_group():
        return {f"b{i}": block_cache_init(cfg, kind, batch, max_len, dtype)
                for i, kind in enumerate(pattern)}

    g = one_group()
    stacked = jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l[None], (n_groups,) + l.shape).copy(), g)
    tail_caches = {f"t{i}": block_cache_init(cfg, pattern[i], batch, max_len,
                                             dtype)
                   for i in range(tail)}
    return {"groups": stacked, "tail": tail_caches}


def lm_decode_step(params, cfg: ArchConfig, token: jnp.ndarray, caches,
                   cur_index):
    """One decode step. token: (B,) int32; cur_index: scalar int32 position.
    Returns (logits (B, V) fp32, new_caches)."""
    pattern, n_groups, tail = group_layout(cfg)
    h = _embed(params, cfg, token[:, None])
    if cfg.pos == "learned":
        h = h + jax.lax.dynamic_slice_in_dim(
            params["pos_embed"], cur_index, 1, axis=0).astype(h.dtype)[None]

    def group_fn(h, gp_cache):
        gp, gc = gp_cache
        new_gc = {}
        for i, kind in enumerate(pattern):
            h, new_gc[f"b{i}"] = block_decode(gp[f"b{i}"], cfg, kind, h,
                                              gc[f"b{i}"], cur_index)
        return h, new_gc

    h, new_group_caches = jax.lax.scan(
        group_fn, h, (params["groups"], caches["groups"]))
    new_tail = {}
    for i in range(tail):
        h, new_tail[f"t{i}"] = block_decode(
            params["tail"][f"t{i}"], cfg, pattern[i], h, caches["tail"][f"t{i}"],
            cur_index)
    logits = _readout(params, cfg, h)[:, 0]
    return logits, {"groups": new_group_caches, "tail": new_tail}


def lm_prefill(params, cfg: ArchConfig, prompt: jnp.ndarray, caches,
               start_index=0):
    """Populate decode caches for a whole prompt in ONE compiled forward.

    prompt: (B, P) int32. Scans ``lm_decode_step`` over the position axis
    inside a single XLA computation — batched over B and O(1) dispatches in
    P, versus the P Python-loop dispatches of token-by-token prefill.
    Returns (logits of the last position (B, V) fp32, new_caches).
    """
    P = prompt.shape[1]

    def body(caches, inp):
        tok, idx = inp
        logits, caches = lm_decode_step(params, cfg, tok, caches, idx)
        return caches, logits

    idxs = start_index + jnp.arange(P, dtype=jnp.int32)
    caches, logits = jax.lax.scan(body, caches, (prompt.T, idxs))
    return logits[-1], caches


def count_params(params) -> int:
    return sum(int(l.size) for l in jax.tree_util.tree_leaves(params))
