"""Whisper-style encoder-decoder backbone (conv/mel frontend is a stub:
inputs are precomputed frame embeddings (B, T, d) per the assignment).

Pre-LN LayerNorm blocks (as in Whisper), learned positional embeddings,
bidirectional encoder, causal decoder with cross-attention. Both stacks are
parameter-stacked and scanned.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models.lm import dtype_of
from repro.nn.attention import (
    attention_init, init_cache, mha, mha_decode, precompute_cross_kv,
)
from repro.nn.ffn import ffn_apply, ffn_init
from repro.nn.module import (
    dense_init, embedding_init, layernorm, layernorm_init,
    truncated_normal_init,
)

MAX_FRAMES = 1 << 16  # learned position table ceiling for stress shapes


def _enc_block_init(key, cfg: ArchConfig, pd):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": layernorm_init(cfg.d_model, pd),
        "attn": attention_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv,
                               cfg.d_head, param_dtype=pd),
        "ln2": layernorm_init(cfg.d_model, pd),
        "ffn": ffn_init(k2, cfg.d_model, cfg.d_ff, cfg.gated_ffn, pd),
    }


def _dec_block_init(key, cfg: ArchConfig, pd):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": layernorm_init(cfg.d_model, pd),
        "self_attn": attention_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv,
                                    cfg.d_head, param_dtype=pd),
        "ln_x": layernorm_init(cfg.d_model, pd),
        "cross_attn": attention_init(k2, cfg.d_model, cfg.n_heads, cfg.n_kv,
                                     cfg.d_head, param_dtype=pd),
        "ln2": layernorm_init(cfg.d_model, pd),
        "ffn": ffn_init(k3, cfg.d_model, cfg.d_ff, cfg.gated_ffn, pd),
    }


def init_encdec(key, cfg: ArchConfig):
    pd = dtype_of(cfg.param_dtype)
    ke, kd, kt, kp1, kp2 = jax.random.split(key, 5)
    enc_keys = jax.random.split(ke, cfg.enc_layers)
    dec_keys = jax.random.split(kd, cfg.dec_layers)
    return {
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg, pd))(enc_keys),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(k, cfg, pd))(dec_keys),
        "embed": embedding_init(kt, cfg.vocab, cfg.d_model, pd),
        "enc_pos": truncated_normal_init(kp1, (MAX_FRAMES, cfg.d_model), 0.02,
                                         pd),
        "dec_pos": truncated_normal_init(kp2, (cfg.max_target_len * 64,
                                               cfg.d_model), 0.02, pd),
        "ln_enc": layernorm_init(cfg.d_model, pd),
        "ln_dec": layernorm_init(cfg.d_model, pd),
    }


def _attn_kw(cfg: ArchConfig):
    return dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_head=cfg.d_head,
                use_rope=False)


def encode(params, cfg: ArchConfig, frames: jnp.ndarray,
           remat: str = "none") -> jnp.ndarray:
    """frames: (B, T, d) stub embeddings -> encoder states (B, T, d)."""
    dt = dtype_of(cfg.dtype)
    T = frames.shape[1]
    h = frames.astype(dt) + params["enc_pos"][:T].astype(dt)

    def body(h, bp):
        h = h + mha(bp["attn"], layernorm(bp["ln1"], h), causal=False,
                    **_attn_kw(cfg))
        h = h + ffn_apply(bp["ffn"], layernorm(bp["ln2"], h), act=cfg.act)
        return h, None

    if remat != "none":
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["enc_blocks"])
    return layernorm(params["ln_enc"], h)


def decode_train(params, cfg: ArchConfig, enc: jnp.ndarray,
                 tokens: jnp.ndarray, remat: str = "none") -> jnp.ndarray:
    """Teacher-forced decoder. tokens: (B, L). Returns fp32 logits (B, L, V)."""
    dt = dtype_of(cfg.dtype)
    L = tokens.shape[1]
    h = jnp.take(params["embed"]["table"], tokens, axis=0).astype(dt)
    h = h + params["dec_pos"][:L].astype(dt)

    def body(h, bp):
        h = h + mha(bp["self_attn"], layernorm(bp["ln1"], h), causal=True,
                    **_attn_kw(cfg))
        h = h + mha(bp["cross_attn"], layernorm(bp["ln_x"], h), kv_x=enc,
                    causal=False, **_attn_kw(cfg))
        h = h + ffn_apply(bp["ffn"], layernorm(bp["ln2"], h), act=cfg.act)
        return h, None

    if remat != "none":
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["dec_blocks"])
    h = layernorm(params["ln_dec"], h)
    return jnp.matmul(h, params["embed"]["table"].astype(h.dtype).T,
                      preferred_element_type=jnp.float32)


def encdec_loss(params, cfg: ArchConfig, frames, tokens, targets,
                remat: str = "none"):
    enc = encode(params, cfg, frames, remat)
    logits = decode_train(params, cfg, enc, tokens, remat).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce, {"ce": ce}


def init_dec_cache(params, cfg: ArchConfig, enc: jnp.ndarray, batch: int,
                   max_len: int):
    """Self-attn KV caches (stacked over layers) + precomputed cross K/V."""
    dt = dtype_of(cfg.dtype)
    self_kv = {
        "k": jnp.zeros((cfg.dec_layers, batch, max_len, cfg.n_kv, cfg.d_head),
                       dt),
        "v": jnp.zeros((cfg.dec_layers, batch, max_len, cfg.n_kv, cfg.d_head),
                       dt),
    }
    cross = jax.vmap(
        lambda bp: precompute_cross_kv(bp["cross_attn"], enc, n_kv=cfg.n_kv,
                                       d_head=cfg.d_head)
    )(params["dec_blocks"])
    return {"self": self_kv, "cross": cross}


def encdec_decode_step(params, cfg: ArchConfig, token: jnp.ndarray, caches,
                       cur_index):
    """One decoder token. token: (B,). Returns (logits (B,V), new caches)."""
    dt = dtype_of(cfg.dtype)
    h = jnp.take(params["embed"]["table"], token[:, None], axis=0).astype(dt)
    h = h + jax.lax.dynamic_slice_in_dim(
        params["dec_pos"], cur_index, 1, axis=0).astype(dt)[None]

    def body(h, xs):
        bp, kv, cross = xs
        cache = {"k": kv["k"], "v": kv["v"]}
        a, cache = mha_decode(bp["self_attn"], layernorm(bp["ln1"], h), cache,
                              cur_index, **_attn_kw(cfg))
        h = h + a
        c, _ = mha_decode(bp["cross_attn"], layernorm(bp["ln_x"], h), {},
                          cur_index, cross_kv=cross, **_attn_kw(cfg))
        h = h + c
        h = h + ffn_apply(bp["ffn"], layernorm(bp["ln2"], h), act=cfg.act)
        return h, cache

    h, new_self = jax.lax.scan(
        body, h, (params["dec_blocks"], caches["self"], caches["cross"]))
    h = layernorm(params["ln_dec"], h)
    logits = jnp.matmul(h, params["embed"]["table"].astype(h.dtype).T,
                        preferred_element_type=jnp.float32)[:, 0]
    return logits, {"self": new_self, "cross": caches["cross"]}
