"""Continuous-depth mode for the unified LM — the paper's technique at
framework scale (DESIGN.md §4).

A pre-norm residual stack is read as the Euler discretization of a depth
ODE with piecewise-constant parameters theta(s) (paper Eq. 1 allows
s-dependent parameters):

    f(s, h) = n_groups * (group_apply(theta(floor(s * n_groups)), h) - h)

Euler with K = n_groups steps reproduces the discrete network EXACTLY
(tested); K < n_groups trades NFE (~ layer evaluations) for accuracy, and a
HyperEuler correction g_omega — trained by residual fitting against the
full-depth trajectory (the LM analogue of the paper's dopri5 ground truth)
— recovers most of the lost accuracy. This transplants the paper's CNF
result (2-NFE sampling) to LM inference.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.core import FixedGrid, Integrator, SolveStats, get_tableau, with_initial
from repro.core.residual import combined_loss
from repro.models.lm import (
    ZERO_AUX, _embed, _readout, block_apply, dtype_of, group_layout,
)
from repro.nn.module import truncated_normal_init


def _group_apply(params, cfg: ArchConfig, gp, h):
    pattern, _, _ = group_layout(cfg)
    aux = ZERO_AUX()
    for i, kind in enumerate(pattern):
        h, aux = block_apply(gp[f"b{i}"], cfg, kind, h, aux)
    return h


def depth_field(params, cfg: ArchConfig):
    """VectorField f(s, h) over the residual stream (full sequence).

    ``s`` may be a scalar or a per-sample ``(B,)`` row (multi-rate solves,
    core/integrate.py ``solve_multirate``): group selection is by depth, so
    per-sample depths gather per-sample group weights via a vmap over the
    batch axis (each sample keeps its singleton batch dim so the block
    stack sees its native (B, S, d) rank)."""
    _, n_groups, _ = group_layout(cfg)

    def f(s, h):
        if jnp.ndim(s):
            return jax.vmap(lambda si, hi: f(si, hi[None])[0])(
                jnp.reshape(s, (-1,)), h)
        idx = jnp.clip(jnp.floor(s * n_groups).astype(jnp.int32), 0,
                       n_groups - 1)
        gp = jax.tree_util.tree_map(lambda p: p[idx], params["groups"])
        h_out = _group_apply(params, cfg, gp, h)
        return (n_groups * (h_out - h)).astype(h.dtype)

    return f


def discrete_depth_trajectory(params, cfg: ArchConfig, tokens: jnp.ndarray,
                              frontend: Optional[jnp.ndarray] = None):
    """Residual-stream states at every group boundary — the 'exact'
    solution checkpoints for hypersolver fitting (paper Sec. 3.2; ground
    truth here is the deployed full-depth network itself).

    Deliberately NOT an Integrator solve: Euler at K = n_groups matches
    this walk only up to the eps*(n*(h_out-h)) recombination rounding,
    and in a bf16 residual stream that per-step ulp noise is the same
    order as the residuals g_omega fits. Ground truth must emit the
    group outputs bit-exactly; only the trajectory stacking is shared
    with the engine. Returns (n_groups+1, B, S, d).
    """
    h0 = _embed(params, cfg, tokens)
    if frontend is not None:
        from repro.nn.module import dense
        fe = dense(params["patch_proj"], frontend.astype(h0.dtype))
        h0 = jnp.concatenate([fe, h0], axis=1)

    def body(h, gp):
        h_out = _group_apply(params, cfg, gp, h)
        return h_out, h_out

    _, traj = jax.lax.scan(body, h0, params["groups"])
    return with_initial(h0, traj)


# --------------------------------------------------- g_omega for the LM ----

def lm_g_init(key, cfg: ArchConfig, rank: int = 64, n_fourier: int = 8,
              param_dtype=None):
    pd = param_dtype or dtype_of(cfg.param_dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        "w_h": truncated_normal_init(k1, (d, rank), d ** -0.5, pd),
        "w_dh": truncated_normal_init(k2, (d, rank), d ** -0.5, pd),
        "w_s": truncated_normal_init(k3, (2 * n_fourier + 1, rank), 0.3, pd),
        # zero-init readout: correction starts at exactly 0 (pure base solver)
        "w_out": jnp.zeros((rank, d), pd),
    }


def _fourier(s, n: int, dtype):
    """Fourier depth features; ``s`` may be a scalar (fixed-K serving) or
    per-sample batched ``(B,)`` (multi-rate meshes and the refinery's
    residual-capture cell hand every row its own depth)."""
    s = jnp.asarray(s, jnp.float32)
    ks = jnp.arange(1, n + 1, dtype=jnp.float32)
    ang = 2 * jnp.pi * ks * s[..., None]            # (..., n)
    feats = jnp.concatenate([jnp.sin(ang), jnp.cos(ang), s[..., None]],
                            axis=-1)                # (..., 2n + 1)
    return feats.reshape(s.shape + (2 * n + 1,)).astype(dtype)


def lm_g_apply(gp, eps, s, x, h, dh):
    """Correction net: rank-r MLP over (h, dh, s). MAC cost 3*d*r per token
    — negligible next to the ~12 d^2 block cost (paper Sec. 6 overhead)."""
    del eps, x
    nf = (gp["w_s"].shape[0] - 1) // 2  # w_s: (2*n_fourier + 1, rank)
    sf = _fourier(s, nf, h.dtype) @ gp["w_s"].astype(h.dtype)
    if jnp.ndim(s):
        # batched depth row: align sf's leading sample axis with h's by
        # inserting singleton token axes — (B, r) -> (B, 1..., r)
        sf = jnp.reshape(
            sf, sf.shape[:-1] + (1,) * (h.ndim - sf.ndim) + sf.shape[-1:])
    pre = (h @ gp["w_h"].astype(h.dtype)
           + dh.astype(h.dtype) @ gp["w_dh"].astype(h.dtype) + sf)
    return (jnp.tanh(pre) @ gp["w_out"].astype(h.dtype)).astype(h.dtype)


# ----------------------------------------------- flow head for the LM ----

def lm_flow_init(key, cfg: ArchConfig, rank: int = 64, n_fourier: int = 8,
                 param_dtype=None):
    """Flow-net params for the K=0 tier (core/flowhead.py): the SAME
    rank-r architecture as g_omega — flow and correction fit the same
    eps^{p+1}-scaled residual target, so one net family serves both
    sites. Zero-init readout means the flow starts as EXACTLY one
    full-span Euler step."""
    return lm_g_init(key, cfg, rank=rank, n_fourier=n_fourier,
                     param_dtype=param_dtype)


def lm_flow_apply(fp, eps, s, z, dz, order: int = 1):
    """LM solution operator F(z(s)) -> z(s+eps) — ``make_flow_apply``
    over the ``lm_g_apply`` net (DepthModel.flow_apply signature)."""
    from repro.core.flowhead import flow_combine

    return flow_combine(eps, z, dz, lm_g_apply(fp, eps, s, None, z, dz),
                        order=order)


# ----------------------------------------------------------- inference ----

def bind_lm_g(g_params):
    """Close LM g_omega over its params to the core Correction signature."""
    return lambda eps, s, z, dz: lm_g_apply(g_params, eps, s, None, z, dz)


def lm_integrator(solver: str = "euler", g_params: Any = None,
                  fused: bool = False) -> Integrator:
    """The serving Integrator for the LM depth ODE. ``solver`` may carry a
    ``hyper_`` prefix (``hyper_euler`` == euler base + correction, which
    then requires ``g_params`` — a hyper solver silently downgraded to its
    base would misreport agreement/benchmark numbers)."""
    if solver.startswith("hyper_"):
        if g_params is None:
            raise ValueError(
                f"solver {solver!r} needs a trained correction: pass "
                "g_params (serve CLI: --g-ckpt)")
        base = solver[len("hyper_"):]
    else:
        base = solver
    g = bind_lm_g(g_params) if g_params is not None else None
    return Integrator(tableau=get_tableau(base), g=g, fused=fused)


def apply_tail(params, cfg: ArchConfig, h):
    """The discrete tail layers + readout shared by every LM serving path
    (full-K scoring, the engine's readout, and reference solves)."""
    pattern, _, tail = group_layout(cfg)
    aux = ZERO_AUX()
    for i in range(tail):
        h, aux = block_apply(params["tail"][f"t{i}"], cfg, pattern[i], h, aux)
    return _readout(params, cfg, h)


def lm_forward_cdepth(params, cfg: ArchConfig, tokens: jnp.ndarray, K: int,
                      solver: str = "euler", g_params: Any = None,
                      frontend: Optional[jnp.ndarray] = None,
                      with_stats: bool = False):
    """Full-sequence scoring with a K-step (hyper)solved depth integration.

    K == n_groups with solver='euler', g=None reproduces lm_forward exactly
    (up to tail layers, which are always applied discretely).

    ``with_stats=True`` additionally returns per-sample ``SolveStats`` (NFE
    = stages * K for every row of the batch; the multi-rate engine in
    launch/engine.py adds its probe cost on top).
    """
    pattern, n_groups, tail = group_layout(cfg)
    h = _embed(params, cfg, tokens)
    if frontend is not None:
        from repro.nn.module import dense
        fe = dense(params["patch_proj"], frontend.astype(h.dtype))
        h = jnp.concatenate([fe, h], axis=1)
    f = depth_field(params, cfg)
    integ = lm_integrator(solver, g_params)
    grid = FixedGrid.over(0.0, 1.0, K)
    h = integ.solve(f, h, grid, return_traj=False)
    logits = apply_tail(params, cfg, h)
    if not with_stats:
        return logits
    B = tokens.shape[0]
    stats = SolveStats(
        nfe=jnp.full((B,), integ.tableau.stages * K, jnp.int32),
        K=jnp.full((B,), K, jnp.int32),
        err_probe=jnp.zeros((B,), jnp.float32),
        probe_nfe=0,
    )
    return logits, stats


def depth_probe(params, cfg: ArchConfig, tokens: jnp.ndarray, controller,
                solver: str = "euler", g_params: Any = None,
                frontend: Optional[jnp.ndarray] = None):
    """Cheap per-request error probe over the LM depth ODE.

    Embeds the prompt and lets ``controller`` (core/controllers.py) pick a
    per-sample mesh length from one probe step of the depth field. Returns
    a ``Probe`` (K, err, nfe, dz0) — the serving engine snaps K to its
    buckets and reuses dz0 as the solve's first stage."""
    h = _embed(params, cfg, tokens)
    if frontend is not None:
        from repro.nn.module import dense
        fe = dense(params["patch_proj"], frontend.astype(h.dtype))
        h = jnp.concatenate([fe, h], axis=1)
    f = depth_field(params, cfg)
    integ = lm_integrator(solver, g_params)
    return controller.select(integ, f, h, (0.0, 1.0))


def cdepth_residual_loss(params, g_params, cfg: ArchConfig,
                         tokens: jnp.ndarray, K: int,
                         base_solver: str = "euler"):
    """Residual-fitting loss for the LM hypersolver at mesh length K.

    Ground truth = full-depth discrete trajectory subsampled at the K-mesh
    (requires n_groups % K == 0).
    """
    _, n_groups, _ = group_layout(cfg)
    assert n_groups % K == 0, (n_groups, K)
    stride = n_groups // K
    traj_full = discrete_depth_trajectory(params, cfg, tokens)
    traj = traj_full[::stride]  # (K+1, B, S, d)
    f = depth_field(params, cfg)
    g = lambda eps, s, z, dz: lm_g_apply(g_params, eps, s, None, z, dz)
    integ = Integrator(tableau=get_tableau(base_solver), g=g)
    grid = FixedGrid.over(0.0, 1.0, K)
    return combined_loss(integ, f, traj, grid, residual_weight=1.0)
