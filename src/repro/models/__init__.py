from repro.models.lm import (  # noqa: F401
    block_pattern, init_lm, lm_forward, lm_loss, init_lm_cache, lm_decode_step,
)
