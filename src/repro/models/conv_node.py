"""The paper's image-classification Neural ODEs (App. C.2), in JAX.

MNIST-family (28x28x1):
    aug: conv(1->12, k3)
    f:   DepthCat -> conv(13->64, k3) -> tanh -> DepthCat ->
         conv(65->12, k3)                       (channels 12-64-12, paper)
    head: conv(12->1, k3) -> flatten -> linear(784->10)
    g (HyperEuler): conv(25->64, k3) -> PReLU -> conv(64->12, k3)
         (input = [z(12), dz(12), s(1)] = 25 channels, paper)

CIFAR-family (32x32x3):
    aug: conv(3->5, k3) (concat -> 8 channels)
    f:   DepthCat -> conv(9->64) -> GN -> tanh -> DepthCat ->
         conv(65->64) -> GN -> tanh -> conv(64->8)
    head: conv(8->1) -> flatten -> linear(1024->10)
    g:   conv(17->64, k5) -> PReLU -> conv(64->32, k5) -> PReLU ->
         conv(32->8, k3)

GroupNorm replaces the paper's BatchNorm inside f (running-stat BN is
ill-defined along continuous depth; documented in DESIGN.md §7).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.integrate import Integrator
from repro.core.neural_ode import NeuralODE
from repro.nn.conv_blocks import (
    conv2d, conv2d_init, depth_cat, groupnorm, groupnorm_init, prelu,
    prelu_init,
)
from repro.nn.module import dense_init


def conv_macs(h, w, cin, cout, k):
    return h * w * cin * cout * k * k


def init_mnist_node(key):
    ks = jax.random.split(key, 6)
    params = {
        "aug": conv2d_init(ks[0], 1, 12, 3),
        "f1": conv2d_init(ks[1], 13, 64, 3),
        "f2": conv2d_init(ks[2], 65, 12, 3),
        "head_conv": conv2d_init(ks[3], 12, 1, 3),
        "head_lin": dense_init(ks[4], 28 * 28, 10),
    }
    return params


def mnist_f_apply(params, s, x, z):
    h = depth_cat(z, s)
    h = jnp.tanh(conv2d(params["f1"], h))
    h = depth_cat(h, s)
    return conv2d(params["f2"], h)


def mnist_hx(params, x):
    return conv2d(params["aug"], x)


def mnist_hy(params, z):
    h = conv2d(params["head_conv"], z)
    return h.reshape(h.shape[0], -1) @ params["head_lin"]["kernel"]


def mnist_node(key) -> Tuple[NeuralODE, dict]:
    params = init_mnist_node(key)
    node = NeuralODE(f_apply=mnist_f_apply, hx_apply=mnist_hx,
                     hy_apply=mnist_hy, s_span=(0.0, 1.0))
    return node, params


def mnist_f_macs(hw: int = 28) -> int:
    return conv_macs(hw, hw, 13, 64, 3) + conv_macs(hw, hw, 65, 12, 3)


def init_mnist_hyper(key):
    k1, k2 = jax.random.split(key)
    p = {
        "c1": conv2d_init(k1, 25, 64, 3),
        "a1": prelu_init(64),
        "c2": conv2d_init(k2, 64, 12, 3),
    }
    p["c2"]["w"] = jnp.zeros_like(p["c2"]["w"])  # start at g == 0
    return p


def mnist_g_apply(gp, eps, s, x, z, dz):
    h = depth_cat(jnp.concatenate([z, dz], axis=-1), s)  # 25 channels
    h = prelu(gp["a1"], conv2d(gp["c1"], h))
    return conv2d(gp["c2"], h)


def mnist_g_macs(hw: int = 28) -> int:
    return conv_macs(hw, hw, 25, 64, 3) + conv_macs(hw, hw, 64, 12, 3)


def mnist_integrator(gp=None, x=None, base="euler",
                     fused: bool = False) -> Integrator:
    """Unified-engine solver for the MNIST-family Neural ODE: plain base
    tableau when ``gp`` is None, HyperEuler-style correction otherwise."""
    from repro.core.train import make_integrator
    return make_integrator(base, mnist_g_apply if gp is not None else None,
                           gp, x, fused=fused)


# ------------------------------------------------------------- CIFAR ----

def init_cifar_node(key):
    ks = jax.random.split(key, 7)
    return {
        "aug": conv2d_init(ks[0], 3, 5, 3),
        "f1": conv2d_init(ks[1], 9, 64, 3),
        "gn1": groupnorm_init(64),
        "f2": conv2d_init(ks[2], 65, 64, 3),
        "gn2": groupnorm_init(64),
        "f3": conv2d_init(ks[3], 64, 8, 3),
        "head_conv": conv2d_init(ks[4], 8, 1, 3),
        "head_lin": dense_init(ks[5], 32 * 32, 10),
    }


def cifar_f_apply(params, s, x, z):
    h = depth_cat(z, s)
    h = jnp.tanh(groupnorm(params["gn1"], conv2d(params["f1"], h)))
    h = depth_cat(h, s)
    h = jnp.tanh(groupnorm(params["gn2"], conv2d(params["f2"], h)))
    return conv2d(params["f3"], h)


def cifar_hx(params, x):
    return jnp.concatenate([x, conv2d(params["aug"], x)], axis=-1)


def cifar_hy(params, z):
    h = conv2d(params["head_conv"], z)
    return h.reshape(h.shape[0], -1) @ params["head_lin"]["kernel"]


def cifar_node(key) -> Tuple[NeuralODE, dict]:
    params = init_cifar_node(key)
    node = NeuralODE(f_apply=cifar_f_apply, hx_apply=cifar_hx,
                     hy_apply=cifar_hy, s_span=(0.0, 1.0))
    return node, params


def init_cifar_hyper(key):
    ks = jax.random.split(key, 3)
    p = {
        "c1": conv2d_init(ks[0], 17, 64, 5),
        "a1": prelu_init(64),
        "c2": conv2d_init(ks[1], 64, 32, 5),
        "a2": prelu_init(32),
        "c3": conv2d_init(ks[2], 32, 8, 3),
    }
    p["c3"]["w"] = jnp.zeros_like(p["c3"]["w"])
    return p


def cifar_g_apply(gp, eps, s, x, z, dz):
    h = depth_cat(jnp.concatenate([z, dz], axis=-1), s)  # 17 channels
    h = prelu(gp["a1"], conv2d(gp["c1"], h))
    h = prelu(gp["a2"], conv2d(gp["c2"], h))
    return conv2d(gp["c3"], h)


def cifar_integrator(gp=None, x=None, base="euler",
                     fused: bool = False) -> Integrator:
    """Unified-engine solver for the CIFAR-family Neural ODE."""
    from repro.core.train import make_integrator
    return make_integrator(base, cifar_g_apply if gp is not None else None,
                           gp, x, fused=fused)
