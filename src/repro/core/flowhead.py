"""FlowHead — a learned solution operator as the serving ladder's K=0 tier.

*Neural Flows* (Biloš et al., PAPERS.md) takes the paper's bet — a cheap
learned corrector buys solver accuracy — to its limit: replace the solver
entirely with a learned map z(s1) = F(z(s0)). One network eval, zero
integration steps. This module keeps that operator INSIDE the hypersolver
parameterization instead of learning a free-form F:

    F(fp, eps, s, z, dz) = z + eps * dz + eps^{p+1} * net(fp, eps, s, z, dz)

i.e. one full-span explicit-Euler step plus an eps^{p+1}-scaled learned
correction — exactly the hypersolver update shape (paper Eq. 3) with the
whole span as the single step. Three properties fall out:

  * **zero-init == Euler.** With ``net == 0`` (the zero-readout init every
    correction net here uses), F is EXACTLY one full-span Euler step — the
    flow tier degrades to the cheapest classical answer, never garbage.
  * **same fitting data as g.** Rearranging the Eq.-6 residual definition,
    the true solution satisfies ``z(s+eps) = z + eps*dz + eps^{p+1} * R``,
    so fitting F to z(s+eps) on the refinery ledger's captured
    ``(s, eps, z, dz, R)`` rows reduces to fitting ``net`` to R — the SAME
    ``ledger_fitting_loss`` target the hypersolver g trains on
    (``core/residual.py::flow_fitting_loss`` is the scaled restatement).
  * **same swap machinery as g.** ``net`` has the g_apply signature
    ``(params, eps, s, z, dz)``, so flow params ride the serving cells as
    traced inputs and hot-swap with the zero-retrace ``hot_swap_g``
    validation path (``launch/engine.py::hot_swap_flow``).

``launch/engine.py`` / ``launch/scheduler.py`` route admission-probe-easy
requests here (the ``flow``/``hyper``/``high-K`` three-tier ladder,
``core/controllers.py::TierRouter``); ``launch/refinery.py`` refits the
flow head online off the same residual ledger (``param_site="flow"``).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

Pytree = Any

# net(params, eps, s, z, dz) -> correction pytree like z — the g_apply
# signature (launch/engine.py::DepthModel), so any correction net (toy MLP,
# models/cdepth.py::lm_g_apply adapter) doubles as a flow net.
FlowNet = Callable[..., Any]

__all__ = ["make_flow_apply", "flow_combine"]


def flow_combine(eps, z: Pytree, dz: Pytree, corr: Pytree,
                 order: int = 1) -> Pytree:
    """``z + eps*dz + eps^{order+1}*corr`` — the hypersolver update shape
    (paper Eq. 3) applied once over the full span. Leaf-wise, so z/dz/corr
    may be arbitrary matching pytrees; ``eps`` is a scalar (the span)."""
    scale = eps ** (order + 1)
    return jax.tree_util.tree_map(
        lambda zl, dzl, cl: zl + eps * dzl
        + jnp.asarray(scale, dtype=zl.dtype) * cl.astype(zl.dtype),
        z, dz, corr)


def make_flow_apply(net: FlowNet, order: int = 1) -> Callable:
    """Wrap a correction net into the solution-operator signature
    ``flow_apply(fp, eps, s, z, dz) -> z(s + eps)`` that ``DepthModel``
    carries (``flow_apply``/``flow_params``). ``order`` is the base
    solver's order p; the net's output is scaled by eps^{p+1}, matching
    the residual target it is fitted to (core/residual.py)."""

    def flow_apply(fp, eps, s, z, dz):
        return flow_combine(eps, z, dz, net(fp, eps, s, z, dz), order=order)

    return flow_apply
