"""Hypersolver training harness (paper Sec. 3.2 + Appendix C.2).

Two-phase protocol, as in the paper: (1) stabilize by pretraining on the
trajectories of a single batch for ``pretrain_iters``; (2) swap the batch
every ``swap_every`` iterations so g_omega generalizes across initial
conditions. Ground truth is dopri5 at tight tolerances; residual fitting
requires no task supervision.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hypersolver import HyperSolver
from repro.core.integrate import Integrator
from repro.core.neural_ode import NeuralODE
from repro.core.residual import combined_loss, flow_fitting_loss
from repro.core.solvers import FixedGrid
from repro.core.tableaus import Tableau, get as get_tableau
from repro.optim import Optimizer, adamw, clip_by_global_norm, apply_updates
from repro.optim.schedules import cosine_annealing

# g_apply(g_params, eps, s, x, z, dz) -> correction pytree like z
GApply = Callable[..., Any]


@dataclasses.dataclass
class HypersolverTrainConfig:
    base_solver: str = "euler"
    K: int = 10                   # mesh length (paper: K=10 image cls, K=1 CNF)
    iters: int = 1000
    pretrain_iters: int = 10      # phase-1 single-batch iterations
    swap_every: int = 10          # paper: swap batch every 10 iters (100 for CNF)
    lr: float = 1e-2              # paper C.2: AdamW lr=1e-2
    lr_min: float = 5e-4          # cosine anneal floor (paper: 5e-4)
    weight_decay: float = 1e-6
    grad_clip: float = 10.0
    atol: float = 1e-4            # dopri5 gt tolerances (paper: 1e-4 img, 1e-5 CNF)
    rtol: float = 1e-4
    residual_weight: float = 1.0
    trajectory_weight: float = 0.0


def bind_g(g_apply: GApply, g_params, x) -> Callable:
    """Close g over (params, x) to the core Correction signature."""
    return lambda eps, s, z, dz: g_apply(g_params, eps, s, x, z, dz)


def make_hypersolver(base: str | Tableau, g_apply: GApply, g_params, x) -> HyperSolver:
    tab = base if isinstance(base, Tableau) else get_tableau(base)
    return HyperSolver(tableau=tab, g=bind_g(g_apply, g_params, x))


def make_integrator(base: str | Tableau, g_apply: GApply = None, g_params=None,
                    x=None, fused: bool = False) -> Integrator:
    """Unified-engine twin of ``make_hypersolver``: an Integrator over the
    base tableau, with g bound over (params, x) when a correction is given."""
    tab = base if isinstance(base, Tableau) else get_tableau(base)
    g = bind_g(g_apply, g_params, x) if g_apply is not None else None
    return Integrator(tableau=tab, g=g, fused=fused)


def make_fit_step(loss_fn: Callable, opt: Optimizer, grad_clip: float):
    """The one jitted optimizer step every g-fitting loop shares:
    ``fit_step(gp, opt_state, step, *batch) -> (gp, opt_state, loss)``
    running value_and_grad -> global-norm clip -> update -> apply.
    ``train_hypersolver`` (offline) and the online refinery
    (launch/refinery.py) both build their loops on this, so the two
    training paths cannot drift on optimizer mechanics."""

    @jax.jit
    def fit_step(gp, opt_state, step, *batch):
        loss, grads = jax.value_and_grad(loss_fn)(gp, *batch)
        grads, _ = clip_by_global_norm(grads, grad_clip)
        updates, opt_state = opt.update(grads, opt_state, gp, step)
        gp = apply_updates(gp, updates)
        return gp, opt_state, loss

    return fit_step


@dataclasses.dataclass
class FlowTrainConfig:
    """Offline FlowHead fitting knobs (core/flowhead.py). Defaults match
    the refinery's online fit (launch/refinery.py::RefineryConfig) so the
    two flow-training paths share one optimizer regime."""

    iters: int = 400
    batch_size: int = 64
    lr: float = 3e-3
    lr_min: float = 1e-4
    weight_decay: float = 1e-6
    grad_clip: float = 10.0
    order: int = 1                # base solver order p (eps^{p+1} scaling)
    relative: bool = True         # per-sample ||R||-normalized objective:
    #   the router only hands the flow tier CONFIDENTLY EASY rows, so the
    #   head must not trade easy-row accuracy for hard-row magnitudes
    #   (see core/residual.py::flow_fitting_loss)
    seed: int = 0


def train_flowhead(
    flow_apply: Callable,
    flow_params: Any,
    ledger: Any,
    cfg: Optional[FlowTrainConfig] = None,
    log_every: int = 0,
    logger: Optional[Callable[[int, float], None]] = None,
):
    """Fit a flow head on residual-ledger rows — the SAME reservoir the
    hypersolver g trains on (``ledger`` is any source with the
    ``ResidualLedger.sample_batch(n, rng) -> {"s","eps","z","dz","R"}``
    contract, launch/refinery.py). Built on ``make_fit_step`` over
    ``core/residual.py::flow_fitting_loss``, so offline flow fitting,
    offline g fitting, and the online refinery cannot drift on optimizer
    mechanics. Returns (flow_params, losses list)."""
    cfg = cfg or FlowTrainConfig()
    opt: Optimizer = adamw(
        cosine_annealing(cfg.lr, cfg.lr_min, cfg.iters),
        weight_decay=cfg.weight_decay,
    )
    opt_state = opt.init(flow_params)

    def loss_fn(fp, s, eps, z, dz, R):
        flow = lambda e, si, zi, dzi: flow_apply(fp, e, si, zi, dzi)
        return flow_fitting_loss(flow, s, eps, z, dz, R, order=cfg.order,
                                 relative=cfg.relative)

    fit_step = make_fit_step(loss_fn, opt, cfg.grad_clip)
    rng = np.random.RandomState(cfg.seed)
    losses = []
    for it in range(cfg.iters):
        b = ledger.sample_batch(cfg.batch_size, rng)
        if b is None:
            raise ValueError(
                "train_flowhead: ledger has no capacity to sample from "
                "(fill it via live capture or ResidualLedger.capture "
                "before fitting)")
        flow_params, opt_state, loss = fit_step(
            flow_params, opt_state, it,
            b["s"], b["eps"], b["z"], b["dz"], b["R"])
        losses.append(float(loss))
        if log_every and logger and it % log_every == 0:
            logger(it, float(loss))
    return flow_params, losses


def train_hypersolver(
    node: NeuralODE,
    model_params: Any,
    g_apply: GApply,
    g_params: Any,
    batches: Iterator[Any],
    cfg: HypersolverTrainConfig,
    log_every: int = 0,
    logger: Optional[Callable[[int, float], None]] = None,
):
    """Fit g_omega by residual (and/or trajectory) fitting. Returns
    (g_params, losses list)."""
    tab = get_tableau(cfg.base_solver)
    opt: Optimizer = adamw(
        cosine_annealing(cfg.lr, cfg.lr_min, cfg.iters),
        weight_decay=cfg.weight_decay,
    )
    opt_state = opt.init(g_params)

    @jax.jit
    def reference(x):
        traj, grid, nfe = node.reference_trajectory(
            model_params, x, cfg.K, atol=cfg.atol, rtol=cfg.rtol
        )
        return traj

    grid = FixedGrid.over(node.s_span[0], node.s_span[1], cfg.K)

    def loss_fn(gp, x, traj):
        hs = make_hypersolver(tab, g_apply, gp, x)
        f = node.field(model_params, x)
        return combined_loss(
            hs, f, traj, grid,
            residual_weight=cfg.residual_weight,
            trajectory_weight=cfg.trajectory_weight,
        )

    fit_step = make_fit_step(loss_fn, opt, cfg.grad_clip)

    losses = []
    x = next(batches)
    traj = reference(x)
    for it in range(cfg.iters):
        swap = (it >= cfg.pretrain_iters) and (it % cfg.swap_every == 0)
        if swap:
            x = next(batches)
            traj = reference(x)
        g_params, opt_state, loss = fit_step(g_params, opt_state, it, x, traj)
        losses.append(float(loss))
        if log_every and logger and it % log_every == 0:
            logger(it, float(loss))
    return g_params, losses
