# The paper's primary contribution: hypersolvers for continuous-depth models.
from repro.core.tableaus import (  # noqa: F401
    Tableau, EULER, MIDPOINT, HEUN, RALSTON, RK4, RK38, RK3_KUTTA, DOPRI5,
    alpha_family, get as get_tableau,
)
from repro.core.integrate import (  # noqa: F401
    Integrator, SegmentCarry, SolveStats, as_integrator, depth_like,
    make_segment_carry, rk_stages, with_initial,
)
from repro.core.solvers import (  # noqa: F401
    FixedGrid, odeint_fixed, rk_psi, local_error, tree_axpy, tree_lincomb,
)
from repro.core.controllers import (  # noqa: F401
    EmbeddedErrorController, FixedController, HypersolverResidualController,
    TierRouter, embedded_step, error_ratio, per_sample_norm, step_factor,
)
from repro.core.flowhead import flow_combine, make_flow_apply  # noqa: F401
from repro.core.adaptive import (  # noqa: F401
    odeint_dopri5, odeint_dopri5_batched,
)
from repro.core.hypersolver import HyperSolver, make as make_solver  # noqa: F401
from repro.core.residual import (  # noqa: F401
    solver_residual, residual_fitting_loss, trajectory_fitting_loss,
    combined_loss, flow_fitting_loss,
)
from repro.core.neural_ode import NeuralODE  # noqa: F401
from repro.core.train import (  # noqa: F401
    FlowTrainConfig, HypersolverTrainConfig, train_flowhead,
    train_hypersolver, make_hypersolver, make_integrator, bind_g,
)
