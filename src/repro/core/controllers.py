"""Step controllers — the error-control layer between solvers and serving.

The paper's pitch is pareto efficiency (fewest NFEs for a target error),
but a fixed mesh spends the same K on every request. A ``StepController``
closes the loop: from a *cheap local-error probe* it picks a per-sample
mesh length K, so easy requests integrate in 2-4 NFEs while hard ones get
8-16. Three instances:

  * ``FixedController``      — the status quo: constant K, no probe.
  * ``EmbeddedErrorController`` — classical embedded-pair estimation
    (paper Sec. 2): one probe step of a tableau with ``b_err`` weights;
    the |b - b_err|-weighted stage combination estimates the local
    truncation error.  ``odeint_dopri5`` (core/adaptive.py) is the DOPRI5
    accept/reject instance of the *same* ``embedded_step`` /
    ``error_ratio`` / ``step_factor`` code path.
  * ``HypersolverResidualController`` — the hypersolver's own correction
    magnitude ||g|| as a *free* error proxy: g_omega is trained to fit the
    eps^{p+1}-scaled local defect R_k (paper Eq. 6), so
    ``||g|| * eps^{p+1}`` estimates the base solver's local truncation
    error at the cost of a single vector-field evaluation.

All controllers share one selection rule: with a one-full-span probe error
``e ~ C * h^{q+1}`` and global error over K steps accumulating as
``K * C * (h/K)^{q+1} = e / K^q``, the smallest mesh meeting ``tol`` is

    K = ceil((e / tol)^{1/q})         (clipped to [k_min, k_max]).

``Integrator.solve(..., controller=...)`` (core/integrate.py) consumes a
controller and emits per-sample NFE counts; ``launch/engine.py`` uses the
same selection to bucket requests for multi-rate batched serving.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.tableaus import HEUN, Tableau, get as get_tableau

Pytree = Any


class Probe(NamedTuple):
    """Result of a controller's ``select``: per-sample mesh lengths plus the
    evidence. ``dz0 = f(s0, z0)`` is the probe's first stage — identical to
    stage 0 of the subsequent solve, so solvers reuse it
    (``Integrator.solve(first_stage=...)``) and the probe costs one fewer
    NFE than it evaluates. None when the controller did not probe."""

    K: jnp.ndarray          # (B,) int32 selected mesh lengths
    err: jnp.ndarray        # (B,) float32 local-error estimate (0 = no probe)
    nfe: int                # vector-field evals the probe spent, per sample
    dz0: Optional[Pytree]   # f(s0, z0), reusable as the solve's first stage

# Classical step-size controller constants (shared with core/adaptive.py).
SAFETY = 0.9
MIN_FACTOR = 0.2
MAX_FACTOR = 5.0


# ----------------------------------------------- shared embedded-error path ----

def embedded_step(f, tab: Tableau, s, eps, z: Pytree):
    """One step of an embedded RK pair: returns ``(z_hi, err, stages)``.

    ``z_hi`` is the higher-order update (weights ``b``); ``err`` is the
    leaf-wise local-error estimate ``eps * sum_j (b_j - b_err_j) r_j`` —
    the difference between the pair's two solutions. This is THE embedded
    estimator: ``odeint_dopri5`` and ``EmbeddedErrorController`` both call
    it (DOPRI5 and HEUN instances respectively).
    """
    from repro.core.integrate import rk_stages, tree_axpy, tree_lincomb

    if tab.b_err is None:
        raise ValueError(f"tableau {tab.name!r} has no embedded b_err weights")
    stages = rk_stages(f, tab, s, eps, z)
    z_hi = tree_axpy(eps, tree_lincomb(tab.b, stages), z)
    err_w = tuple(b - be for b, be in zip(tab.b, tab.b_err))
    err = jax.tree_util.tree_map(lambda l: eps * l, tree_lincomb(err_w, stages))
    return z_hi, err, stages


def error_ratio(z: Pytree, z_new: Pytree, err: Pytree, atol, rtol):
    """RMS of err / (atol + rtol * max(|z|, |z_new|)); accept iff <= 1."""

    def leafwise(zl, znl, el):
        tol = atol + rtol * jnp.maximum(jnp.abs(zl), jnp.abs(znl))
        return jnp.mean((el.astype(jnp.float32) / tol.astype(jnp.float32)) ** 2)

    parts = jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(leafwise, z, z_new, err))
    return jnp.sqrt(sum(parts) / len(parts))


def step_factor(ratio, order: int):
    """Classical safety-clamped step-size multiplier ratio^{-1/order}."""
    return jnp.clip(
        SAFETY * (jnp.maximum(ratio, 1e-10) ** (-1.0 / order)),
        MIN_FACTOR, MAX_FACTOR,
    )


def per_sample_norm(tree: Pytree) -> jnp.ndarray:
    """RMS over everything but the leading (batch) axis, averaged across
    leaves — the per-request scalar the serving policy keys on."""
    leaves = jax.tree_util.tree_leaves(tree)
    parts = [
        jnp.mean(l.astype(jnp.float32).reshape(l.shape[0], -1) ** 2, axis=-1)
        for l in leaves
    ]
    return jnp.sqrt(sum(parts) / len(parts))


def mesh_for_tolerance(err, tol: float, q: int, k_min: int, k_max: int):
    """K = ceil((err/tol)^{1/q}) clipped — the shared selection rule.

    A non-finite probe error (the probe step itself blew up) means the
    request is as hard as they come: it gets k_max, never the smallest
    bucket a NaN would otherwise select through the int cast."""
    e = jnp.maximum(jnp.asarray(err, jnp.float32), 1e-30)
    k = jnp.ceil((e / tol) ** (1.0 / q))
    k = jnp.where(jnp.isfinite(k), k, float(k_max))
    return jnp.clip(k, k_min, k_max).astype(jnp.int32)


# ------------------------------------------------------------- controllers ----

@dataclasses.dataclass(frozen=True)
class FixedController:
    """Constant mesh length for every sample (no probe, probe_nfe = 0)."""

    K: int

    k_min: int = dataclasses.field(init=False, default=1)

    @property
    def k_max(self) -> int:
        return self.K

    def select(self, integ, f, z0: Pytree, span: Tuple[float, float]) -> Probe:
        B = jax.tree_util.tree_leaves(z0)[0].shape[0]
        Ks = jnp.full((B,), self.K, jnp.int32)
        return Probe(Ks, jnp.zeros((B,), jnp.float32), 0, None)


@dataclasses.dataclass(frozen=True)
class EmbeddedErrorController:
    """Per-sample K from one embedded-pair probe step over the full span.

    ``probe`` is any tableau with ``b_err`` (default HEUN, whose embedded
    Euler pair costs 2 NFEs; DOPRI5 gives a 5(4) estimate for 7). The
    error exponent q is the serving integrator's order — the rate at which
    its global error decays under mesh refinement.
    """

    tol: float = 1e-2
    k_min: int = 1
    k_max: int = 16
    probe: Tableau = HEUN

    def __post_init__(self):
        if isinstance(self.probe, str):
            object.__setattr__(self, "probe", get_tableau(self.probe))
        if self.probe.b_err is None:
            raise ValueError(
                f"probe tableau {self.probe.name!r} has no b_err weights")

    @property
    def probe_nfe(self) -> int:
        return self.probe.stages

    def select(self, integ, f, z0: Pytree, span: Tuple[float, float]) -> Probe:
        s0, s1 = span
        h = s1 - s0
        _, err, stages = embedded_step(f, self.probe, s0, h, z0)
        e = per_sample_norm(err)
        # K is sized for the SERVING integrator: its order governs how the
        # error decays with mesh refinement (for the default HEUN probe
        # serving euler, integ.order == probe embedded order anyway).
        q = max(integ.order, 1)
        Ks = mesh_for_tolerance(e, self.tol, q, self.k_min, self.k_max)
        return Probe(Ks, e, self.probe.stages, stages[0])


@dataclasses.dataclass(frozen=True)
class HypersolverResidualController:
    """Per-sample K from the learned correction magnitude ||g||.

    g_omega fits the eps^{p+1}-scaled residual (paper Eq. 6), so the local
    defect of one full-span base step is ~ ||g(h, s0, z0, dz)|| * h^{p+1}
    — an error estimate whose only cost is the dz = f(s0, z0) evaluation
    the subsequent solve needs anyway (1 probe NFE; g itself is the
    paper's Sec. 6 negligible overhead).
    """

    tol: float = 1e-2
    k_min: int = 1
    k_max: int = 16

    probe_nfe: int = dataclasses.field(init=False, default=1)

    def select(self, integ, f, z0: Pytree, span: Tuple[float, float]) -> Probe:
        if integ.g is None:
            raise ValueError(
                "HypersolverResidualController needs an Integrator with a "
                "correction g; use EmbeddedErrorController for base solvers")
        s0, s1 = span
        h = s1 - s0
        dz = f(s0, z0)
        corr = integ.g(h, s0, z0, dz)
        p = integ.order
        e = per_sample_norm(corr) * (h ** (p + 1))
        Ks = mesh_for_tolerance(e, self.tol, p, self.k_min, self.k_max)
        return Probe(Ks, e, 1, dz)


# ------------------------------------------------------------ tier router ----

@dataclasses.dataclass(frozen=True)
class TierRouter:
    """The three-way serving-ladder policy layered ON TOP of a probing
    step controller: snap each request's difficulty estimate to a tier —

      * ``flow``   — probe error confidently below tolerance
        (``err <= flow_threshold * tol``): serve with the K=0 learned
        solution operator (core/flowhead.py), ONE net eval, no solver;
      * ``hyper``  — easy-to-medium (``K <= hyper_k_max`` after bucket
        snap): hypersolver at a small mesh;
      * ``high-K`` — everything else: the fine buckets.

    ``flow_threshold`` is a CONFIDENCE margin, not a second tolerance:
    the probe error estimates one full-span base step's defect, and the
    flow head is exactly that step plus a correction fitted to cancel
    it, so routing demands the estimate sit well inside ``tol`` before
    trusting the no-solver answer. Requests on the escalation path
    (``K_floor > 0`` from the retry ladder) are never flow-eligible —
    a request the flow already failed must not loop back to it. Tier is
    a PACKING decision like the K-buckets (launch/engine.py): it picks
    which jit cell serves a row, and never respecializes any cell.
    """

    flow_threshold: float = 0.25   # route to flow iff err <= this * tol
    hyper_k_max: int = 4           # hyper/high-K boundary (reporting tier)

    def __post_init__(self):
        if not (0.0 <= self.flow_threshold <= 1.0):
            raise ValueError(
                f"flow_threshold={self.flow_threshold}: expected a "
                "confidence fraction in [0, 1] — the flow tier serves "
                "requests whose probe error is confidently BELOW "
                "tolerance, so a threshold above 1 would route requests "
                "the probe already flagged as failing")

    def flow_mask(self, err, tol: float, k_floor) -> jnp.ndarray:
        """(B,) bool: rows to serve on the K=0 flow tier. Non-finite
        probe errors (the probe itself blew up) and escalated requests
        (``k_floor > 0``) are excluded unconditionally."""
        err = jnp.asarray(err, jnp.float32)
        k_floor = jnp.asarray(k_floor, jnp.int32)
        return (jnp.isfinite(err)
                & (err <= self.flow_threshold * tol)
                & (k_floor == 0))

    def tier_of(self, K) -> jnp.ndarray:
        """Reporting tier for a snapped bucket row: 1 = hyper
        (``K <= hyper_k_max``), 2 = high-K. Flow rows (tier 0) never
        reach the bucket ladder, so they are assigned by ``flow_mask``,
        not here."""
        K = jnp.asarray(K, jnp.int32)
        return jnp.where(K <= self.hyper_k_max, 1, 2).astype(jnp.int32)


StepController = Any  # FixedController | EmbeddedErrorController | ...
