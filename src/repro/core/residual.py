"""Hypersolver training losses (paper Sec. 3.2).

Residual fitting targets the *local* truncation error (Theorem 1); trajectory
fitting targets the *global* truncation error. Both operate on ground-truth
trajectories {z(s_k)} produced by a tightly-tolerated adaptive solver.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.integrate import (
    Integrator,
    Pytree,
    VectorField,
    rk_psi,
    tree_axpy,
)
from repro.core.solvers import FixedGrid
from repro.core.tableaus import Tableau


def _tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree_util.tree_map(lambda x, y: x - y, a, b)


def _tree_l2(t: Pytree) -> jnp.ndarray:
    leaves = [jnp.sum(l.astype(jnp.float32) ** 2)
              for l in jax.tree_util.tree_leaves(t)]
    return jnp.sqrt(sum(leaves) + 1e-24)


def _index(traj: Pytree, k) -> Pytree:
    return jax.tree_util.tree_map(lambda l: l[k], traj)


def solver_residual(
    f: VectorField, tab: Tableau, s, eps, z_k: Pytree, z_k1: Pytree
):
    """R(s_k, z(s_k), z(s_{k+1})) = [z(s_{k+1}) - z(s_k) - eps psi] / eps^{p+1}.

    (paper Eq. 6). Also returns dz = f(s_k, z_k) for reuse by g_omega.
    """
    psi, stages = rk_psi(f, tab, s, eps, z_k)
    pred = tree_axpy(eps, psi, z_k)
    resid = jax.tree_util.tree_map(
        lambda a, b: (a - b) / (eps ** (tab.order + 1)), z_k1, pred
    )
    return resid, stages[0]


def residual_fitting_loss(
    hs: Integrator, f: VectorField, traj: Pytree, grid: FixedGrid
) -> jnp.ndarray:
    """ell = (1/K) sum_k || R_k - g(eps, s_k, z(s_k)) ||_2  (paper Sec. 3.2).

    ``traj`` has a leading mesh axis of length K+1; it is treated as ground
    truth (gradients are stopped through it and through f's stage evals, as
    in the paper's reference implementation which detaches f evaluations).
    """
    assert hs.g is not None
    traj = jax.lax.stop_gradient(traj)
    s_knots = grid.s0 + grid.eps * jnp.arange(grid.K)

    def per_k(k, s):
        z_k = _index(traj, k)
        z_k1 = _index(traj, k + 1)
        resid, dz = solver_residual(f, hs.tableau, s, grid.eps, z_k, z_k1)
        resid = jax.lax.stop_gradient(resid)
        dz = jax.lax.stop_gradient(dz)
        pred = hs.g(grid.eps, s, z_k, dz)
        return _tree_l2(_tree_sub(resid, pred))

    ks = jnp.arange(grid.K)
    losses = jax.vmap(per_k)(ks, s_knots)
    return jnp.mean(losses)


def ledger_fitting_loss(
    g: Callable, s: jnp.ndarray, eps: jnp.ndarray, z: Pytree, dz: Pytree,
    R: Pytree
) -> jnp.ndarray:
    """The serving-ledger form of ``residual_fitting_loss``: same Eq. 6
    target, but over a batch of captured residual SAMPLES instead of a
    dense ground-truth trajectory.

    The online refinery (launch/refinery.py) records per-request
    ``(s_i, eps_i, z_i, dz_i, R_i)`` rows at serve time, where ``R_i`` is
    the local truncation residual against a finer reference step — so
    fitting needs neither the vector field nor a trajectory here:

        ell = (1/N) sum_i || R_i - g(eps_i, s_i, z_i, dz_i) ||_2

    ``s``/``eps`` are (N,) rows; ``z``/``dz``/``R`` are pytrees whose
    leaves carry a leading sample axis. ``R``/``dz`` are data (captured
    under stop_gradient semantics by construction); only g's parameters
    see gradients."""
    R = jax.lax.stop_gradient(R)
    dz = jax.lax.stop_gradient(dz)
    z = jax.lax.stop_gradient(z)

    def per_i(si, epsi, zi, dzi, Ri):
        pred = g(epsi, si, zi, dzi)
        return _tree_l2(_tree_sub(Ri, pred))

    losses = jax.vmap(per_i)(s, eps, z, dz, R)
    return jnp.mean(losses)


def flow_fitting_loss(
    flow: Callable, s: jnp.ndarray, eps: jnp.ndarray, z: Pytree, dz: Pytree,
    R: Pytree, order: int = 1, relative: bool = False
) -> jnp.ndarray:
    """Fit a FlowHead solution operator (core/flowhead.py) on the SAME
    ledger rows the hypersolver g trains on. By the Eq.-6 residual
    definition the true step target is reconstructable from a captured
    sample without re-evaluating the vector field:

        z(s_i + eps_i) = z_i + eps_i * dz_i + eps_i^{p+1} * R_i

    so the loss is the eps^{p+1}-normalized step-prediction error

        ell = (1/N) sum_i || z(s_i+eps_i) - F(eps_i, s_i, z_i, dz_i) ||_2
                    / eps_i^{p+1}

    — for the structured ``make_flow_apply`` head this is EXACTLY
    ``ledger_fitting_loss`` of its net (the Euler part cancels), so the
    flow tier and the g tier fit the same target off the same reservoir.
    ``flow(eps, s, z, dz)`` is the params-bound operator; normalization
    keeps the objective O(1) so one lr/clip config serves both sites.

    ``relative=True`` additionally normalizes each sample by its residual
    magnitude ``1 + ||R_i||``. On a mixed-difficulty ledger the raw
    objective is dominated by the hardest rows (their residuals can sit
    orders of magnitude above the easy ones), and the fitted head trades
    easy-row accuracy away to chase them — exactly backwards for the K=0
    tier, which the router only ever hands the CONFIDENTLY EASY rows.
    Relative fitting is the deployment-matched objective; the default
    ``False`` keeps the exact ledger_fitting_loss equivalence above."""
    R = jax.lax.stop_gradient(R)
    dz = jax.lax.stop_gradient(dz)
    z = jax.lax.stop_gradient(z)

    def per_i(si, epsi, zi, dzi, Ri):
        scale = epsi ** (order + 1)
        target = jax.tree_util.tree_map(
            lambda zl, dzl, Rl: zl + epsi * dzl + scale * Rl, zi, dzi, Ri)
        pred = flow(epsi, si, zi, dzi)
        ell = _tree_l2(_tree_sub(target, pred)) / scale
        if relative:
            ell = ell / (1.0 + _tree_l2(Ri))
        return ell

    losses = jax.vmap(per_i)(s, eps, z, dz, R)
    return jnp.mean(losses)


def trajectory_fitting_loss(
    hs: Integrator, f: VectorField, traj: Pytree, grid: FixedGrid
) -> jnp.ndarray:
    """L = sum_k || z(s_k) - z_k ||_2 with z_k the unrolled hypersolve."""
    assert hs.g is not None
    traj = jax.lax.stop_gradient(traj)
    z0 = _index(traj, 0)

    def body(z, inp):
        k, s = inp
        z_next, _, _ = hs.step(f, s, grid.eps, z)
        target = _index(traj, k + 1)
        return z_next, _tree_l2(_tree_sub(target, z_next))

    ks = jnp.arange(grid.K)
    s_knots = grid.s0 + grid.eps * jnp.arange(grid.K)
    _, losses = jax.lax.scan(body, z0, (ks, s_knots))
    return jnp.sum(losses)


def combined_loss(
    hs: Integrator,
    f: VectorField,
    traj: Pytree,
    grid: FixedGrid,
    residual_weight: float = 1.0,
    trajectory_weight: float = 0.0,
) -> jnp.ndarray:
    """Residual and trajectory fitting 'can be combined into a single loss
    term, depending on the application' (paper Sec. 3.2)."""
    loss = jnp.asarray(0.0, jnp.float32)
    if residual_weight:
        loss = loss + residual_weight * residual_fitting_loss(hs, f, traj, grid)
    if trajectory_weight:
        loss = loss + trajectory_weight * trajectory_fitting_loss(hs, f, traj, grid)
    return loss
