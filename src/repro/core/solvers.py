"""Fixed-step explicit ODE solvers over pytree states (paper Eq. 2-3).

A vector field is any callable ``f(s, z) -> dz`` where ``z`` is an arbitrary
pytree (conditioning inputs ``x`` are closed over, matching paper Eq. 1 where
f depends on (s, x, z)). All linear algebra is done leaf-wise with
``jax.tree_util`` so states like a CNF's ``(z, logp)`` tuple work unchanged.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.tableaus import Tableau

Pytree = Any
VectorField = Callable[[jnp.ndarray, Pytree], Pytree]


def tree_axpy(a, x: Pytree, y: Pytree) -> Pytree:
    """y + a * x, leaf-wise."""
    return jax.tree_util.tree_map(lambda xi, yi: yi + a * xi, x, y)


def tree_lincomb(coeffs: Sequence[float], trees: Sequence[Pytree]) -> Pytree:
    """sum_j coeffs[j] * trees[j], leaf-wise (skips exact-zero coeffs)."""
    terms = [(c, t) for c, t in zip(coeffs, trees) if c != 0.0]
    if not terms:
        return jax.tree_util.tree_map(jnp.zeros_like, trees[0])
    out = jax.tree_util.tree_map(lambda l: terms[0][0] * l, terms[0][1])
    for c, t in terms[1:]:
        out = tree_axpy(c, t, out)
    return out


def rk_psi(f: VectorField, tab: Tableau, s, eps, z: Pytree):
    """Compute the RK update map psi and all stage evaluations r_i (Eq. 3).

    Returns (psi, stages). ``stages[0] == f(s, z)`` which hypersolvers reuse
    as a free input to g_omega.
    """
    stages = []
    for i in range(tab.stages):
        if i == 0:
            zi = z
        else:
            incr = tree_lincomb(tab.a[i], stages)
            zi = tree_axpy(eps, incr, z)
        stages.append(f(s + tab.c[i] * eps, zi))
    psi = tree_lincomb(tab.b, stages)
    return psi, stages


class FixedGrid(NamedTuple):
    """Uniform depth mesh s_k = s0 + k * eps, k = 0..K (paper Sec. 2)."""

    s0: float
    eps: float
    K: int

    @property
    def s_span(self) -> jnp.ndarray:
        return self.s0 + self.eps * jnp.arange(self.K + 1)

    @classmethod
    def over(cls, s0: float, s1: float, K: int) -> "FixedGrid":
        return cls(s0=s0, eps=(s1 - s0) / K, K=K)


def odeint_fixed(
    f: VectorField,
    z0: Pytree,
    grid: FixedGrid,
    tab: Tableau,
    return_traj: bool = True,
):
    """Integrate z' = f(s, z) on a fixed grid with an explicit RK method.

    Returns the full trajectory stacked on a leading axis of length K+1
    (including z0) if ``return_traj``, else just the terminal state. Uses
    ``lax.scan`` so the unrolled HLO is O(1) in K.
    """

    def step(z, s):
        psi, _ = rk_psi(f, tab, s, grid.eps, z)
        z_next = tree_axpy(grid.eps, psi, z)
        return z_next, (z_next if return_traj else None)

    s_knots = grid.s0 + grid.eps * jnp.arange(grid.K)
    zT, ys = jax.lax.scan(step, z0, s_knots)
    if not return_traj:
        return zT
    return jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a[None], b], axis=0), z0, ys
    )


def local_error(
    f: VectorField, tab: Tableau, s, eps, z_true: Pytree, z_true_next: Pytree
):
    """Local truncation error e_k = ||z(s_{k+1}) - z(s_k) - eps psi|| (Sec. 2)."""
    psi, _ = rk_psi(f, tab, s, eps, z_true)
    pred = tree_axpy(eps, psi, z_true)
    diff = jax.tree_util.tree_map(lambda a, b: a - b, z_true_next, pred)
    leaves = [jnp.sum(l.astype(jnp.float32) ** 2) for l in jax.tree_util.tree_leaves(diff)]
    return jnp.sqrt(sum(leaves))


def nfe_per_step(tab: Tableau) -> int:
    """Number of vector-field evaluations per solver step (= p for RK-p)."""
    return tab.stages
