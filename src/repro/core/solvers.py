"""Fixed-grid solver API over pytree states (paper Eq. 2-3).

The actual integration engine lives in ``repro.core.integrate`` — this
module keeps the mesh definition (``FixedGrid``) and thin, stable wrappers
(``odeint_fixed``) so numerical code reads like the paper. A vector field
is any callable ``f(s, z) -> dz`` where ``z`` is an arbitrary pytree
(conditioning inputs ``x`` are closed over, matching paper Eq. 1 where f
depends on (s, x, z)).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.integrate import (  # noqa: F401 — re-exported leaf algebra
    Integrator,
    Pytree,
    VectorField,
    rk_psi,
    rk_stages,
    tree_axpy,
    tree_lincomb,
    with_initial,
)
from repro.core.tableaus import Tableau


class FixedGrid(NamedTuple):
    """Uniform depth mesh s_k = s0 + k * eps, k = 0..K (paper Sec. 2).

    ``eps`` may be a scalar or an array with a leading batch axis
    (per-sample step sizes for multi-rate serving — the Integrator
    broadcasts it leaf-wise against the state).
    """

    s0: float
    eps: Any
    K: int

    @property
    def s_span(self) -> jnp.ndarray:
        ks = jnp.arange(self.K + 1)
        if jnp.ndim(self.eps) == 0:
            return self.s0 + self.eps * ks
        # batched eps: (K+1, *eps.shape) mesh, one column per sample
        return self.s0 + jnp.tensordot(ks, jnp.asarray(self.eps), axes=0)

    @classmethod
    def over(cls, s0: float, s1: float, K: int) -> "FixedGrid":
        return cls(s0=s0, eps=(s1 - s0) / K, K=K)

    @classmethod
    def over_batched(cls, s0: float, s1, K: int) -> "FixedGrid":
        """Per-sample spans: ``s1`` an array -> eps with a batch axis."""
        return cls(s0=s0, eps=(jnp.asarray(s1) - s0) / K, K=K)


def odeint_fixed(
    f: VectorField,
    z0: Pytree,
    grid: FixedGrid,
    tab: Tableau,
    return_traj: bool = True,
):
    """Integrate z' = f(s, z) on a fixed grid with an explicit RK method.

    Thin wrapper over ``Integrator(tab).solve`` — returns the trajectory
    stacked on a leading axis of length K+1 (including z0) if
    ``return_traj``, else just the terminal state.
    """
    return Integrator(tableau=tab).solve(f, z0, grid,
                                         return_traj=return_traj)


def local_error(
    f: VectorField, tab: Tableau, s, eps, z_true: Pytree, z_true_next: Pytree
):
    """Local truncation error e_k = ||z(s_{k+1}) - z(s_k) - eps psi|| (Sec. 2)."""
    psi, _ = rk_psi(f, tab, s, eps, z_true)
    pred = tree_axpy(eps, psi, z_true)
    diff = jax.tree_util.tree_map(lambda a, b: a - b, z_true_next, pred)
    leaves = [jnp.sum(l.astype(jnp.float32) ** 2) for l in jax.tree_util.tree_leaves(diff)]
    return jnp.sqrt(sum(leaves))


def nfe_per_step(tab: Tableau) -> int:
    """Number of vector-field evaluations per solver step (= p for RK-p)."""
    return tab.stages
