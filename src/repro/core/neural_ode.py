"""General Neural ODE formulation (paper Eq. 1, Massaroli et al. 2020b):

    z' = f_theta(s, x, z),  z(0) = h_x(x),  y_hat(s) = h_y(z(s))

``h_x`` / ``h_y`` are kept linear maps (paper Sec. 2) to avoid collapsing the
dynamics. This module is functional: parameters are explicit pytrees, and the
three maps are ``apply(params, ...)`` callables, so it composes with pjit.
All integration routes through the unified ``Integrator`` engine
(core/integrate.py); ``solver`` arguments accept an Integrator, a
HyperSolver, a Tableau, or a tableau name.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.core.adaptive import odeint_dopri5
from repro.core.integrate import Integrator, as_integrator
from repro.core.solvers import FixedGrid
from repro.core.tableaus import Tableau

Params = Any
Apply = Callable[..., Any]


@dataclasses.dataclass(frozen=True)
class NeuralODE:
    """Functional Neural ODE. ``f_apply(params, s, x, z) -> dz``.

    ``hx_apply(params, x) -> z0`` and ``hy_apply(params, z) -> y`` are the
    linear input/output maps; identity lambdas are valid.
    """

    f_apply: Apply
    hx_apply: Apply
    hy_apply: Apply
    s_span: tuple = (0.0, 1.0)

    def field(self, params: Params, x: Any) -> Callable:
        """Close f over (params, x): the VectorField handed to solvers."""
        return lambda s, z: self.f_apply(params, s, x, z)

    def grid(self, K: int) -> FixedGrid:
        return FixedGrid.over(self.s_span[0], self.s_span[1], K)

    def solve(
        self,
        params: Params,
        x: Any,
        solver,
        K: int,
        return_traj: bool = False,
        checkpoint: bool = False,
    ):
        integ = as_integrator(solver)
        f = self.field(params, x)
        z0 = self.hx_apply(params, x)
        return integ.solve(f, z0, self.grid(K), return_traj=return_traj,
                           checkpoint=checkpoint)

    def forward(self, params: Params, x: Any, solver, K: int):
        """y_hat(S) = h_y(z(S)) (paper Sec. 2)."""
        zT = self.solve(params, x, solver, K, return_traj=False)
        return self.hy_apply(params, zT)

    def reference_trajectory(
        self,
        params: Params,
        x: Any,
        K: int,
        atol: float = 1e-5,
        rtol: float = 1e-5,
    ):
        """Ground-truth mesh checkpoints {z(s_k)} via dopri5 (paper Sec. 3.2)."""
        grid = self.grid(K)
        f = self.field(params, x)
        z0 = self.hx_apply(params, x)
        traj, nfe = odeint_dopri5(f, z0, grid, atol=atol, rtol=rtol)
        return jax.lax.stop_gradient(traj), grid, nfe

    def forward_fixed(
        self, params: Params, x: Any, tab: Tableau, K: int
    ):
        """Plain fixed-step baseline forward (no hypersolver)."""
        return self.forward(params, x, as_integrator(tab), K)
