"""General Neural ODE formulation (paper Eq. 1, Massaroli et al. 2020b):

    z' = f_theta(s, x, z),  z(0) = h_x(x),  y_hat(s) = h_y(z(s))

``h_x`` / ``h_y`` are kept linear maps (paper Sec. 2) to avoid collapsing the
dynamics. This module is functional: parameters are explicit pytrees, and the
three maps are ``apply(params, ...)`` callables, so it composes with pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.adaptive import odeint_dopri5
from repro.core.hypersolver import HyperSolver
from repro.core.solvers import FixedGrid, odeint_fixed
from repro.core.tableaus import Tableau

Params = Any
Apply = Callable[..., Any]


@dataclasses.dataclass(frozen=True)
class NeuralODE:
    """Functional Neural ODE. ``f_apply(params, s, x, z) -> dz``.

    ``hx_apply(params, x) -> z0`` and ``hy_apply(params, z) -> y`` are the
    linear input/output maps; identity lambdas are valid.
    """

    f_apply: Apply
    hx_apply: Apply
    hy_apply: Apply
    s_span: tuple = (0.0, 1.0)

    def field(self, params: Params, x: Any) -> Callable:
        """Close f over (params, x): the VectorField handed to solvers."""
        return lambda s, z: self.f_apply(params, s, x, z)

    def solve(
        self,
        params: Params,
        x: Any,
        solver: HyperSolver,
        K: int,
        return_traj: bool = False,
    ):
        grid = FixedGrid.over(self.s_span[0], self.s_span[1], K)
        f = self.field(params, x)
        z0 = self.hx_apply(params, x)
        out = solver.odeint(f, z0, grid, return_traj=return_traj)
        return out

    def forward(self, params: Params, x: Any, solver: HyperSolver, K: int):
        """y_hat(S) = h_y(z(S)) (paper Sec. 2)."""
        zT = self.solve(params, x, solver, K, return_traj=False)
        return self.hy_apply(params, zT)

    def reference_trajectory(
        self,
        params: Params,
        x: Any,
        K: int,
        atol: float = 1e-5,
        rtol: float = 1e-5,
    ):
        """Ground-truth mesh checkpoints {z(s_k)} via dopri5 (paper Sec. 3.2)."""
        grid = FixedGrid.over(self.s_span[0], self.s_span[1], K)
        f = self.field(params, x)
        z0 = self.hx_apply(params, x)
        traj, nfe = odeint_dopri5(f, z0, grid, atol=atol, rtol=rtol)
        return jax.lax.stop_gradient(traj), grid, nfe

    def forward_fixed(
        self, params: Params, x: Any, tab: Tableau, K: int
    ):
        """Plain fixed-step baseline forward (no hypersolver)."""
        grid = FixedGrid.over(self.s_span[0], self.s_span[1], K)
        f = self.field(params, x)
        z0 = self.hx_apply(params, x)
        zT = odeint_fixed(f, z0, grid, tab, return_traj=False)
        return self.hy_apply(params, zT)
