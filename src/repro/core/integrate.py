"""Unified batched integration engine — every solve in the repo goes here.

``Integrator`` subsumes the three integration paths the codebase grew
(``solvers.odeint_fixed``, ``HyperSolver.odeint`` and the per-model scan
loops): one scan-native engine that

  * works on arbitrary pytree states (a CNF's ``(z, logp)`` tuple, the LM
    residual stream, image feature maps) — all linear algebra is leaf-wise;
  * composes with ``jax.jit`` / ``jax.vmap`` / ``jax.grad`` — the mesh walk
    is a single ``lax.scan`` whose unrolled HLO is O(1) in K;
  * supports *batched step sizes*: ``grid.eps`` may be an array with a
    leading batch axis (per-sample eps for multi-rate serving — each row of
    the batch integrates its own mesh), broadcast leaf-wise against the
    state;
  * emits the dense trajectory (leading axis K+1, including z0) or the
    terminal state only;
  * optionally rematerializes each step under reverse-mode AD
    (``checkpoint=True``) so trajectories of long meshes backprop in O(K)
    memory instead of O(K * stages);
  * routes the update through the fused Pallas ``hyper_step`` kernel
    (``fused=True``): the b-weighted stage combination, the eps^{p+1}
    correction term, AND the multi-rate freeze mask collapse into one
    memory pass per leaf, for every base tableau — the update is
    memory-bound, so this is the serving hot path. Step sizes are RUNTIME
    kernel operands (scalar-prefetch SMEM rows, kernels/hyper_step): a
    Python float, a traced scalar, and a per-sample ``(B,)`` eps row all
    hit the same compiled kernel, so multi-rate serving never recompiles
    per step size and never falls off the fused path. The only surviving
    fallback is odd state dtypes (see ``fused_available``);
  * integrates under a step controller (``controller=``,
    core/controllers.py): a cheap probe picks a per-sample mesh length,
    the probe's first stage is reused, and the solve reports per-sample
    NFE counts (``SolveStats``) — the error-control layer multi-rate
    serving (launch/engine.py) builds on. ``solve_multirate`` is the same
    masked scan with externally supplied per-sample mesh lengths (the
    serving engine packs mixed-K batches straight into it);
  * runs data-parallel under a device mesh (``solve(mesh=...)``): the
    leading batch axis shards over the mesh's data axis via ``shard_map``
    and the depth scan stays local — batch rows share nothing (the
    runtime-eps kernel looks its coefficients up per row), so the mesh
    walk needs no cross-device communication.

The hypersolver update implemented for tableau psi and correction g
(paper Eq. 3 + Eq. 5, Poli et al. 2020):

    z_{k+1} = z_k + eps * sum_j b_j r_j + eps^{p+1} * g(eps, s_k, z_k, r_0)

Controller/engine architecture (error-controlled multi-rate serving)::

    core/tableaus.py      Tableau (+ b_err embedded weights)
          |
    core/controllers.py   embedded_step / error_ratio / step_factor
          |                 FixedController | EmbeddedErrorController |
          |                 HypersolverResidualController
          |                       | per-sample K from a cheap probe
    core/integrate.py     Integrator.solve(..., controller=) -> (z, SolveStats)
          |                 masked multi-rate scan (fused in-kernel mask),
          |                 per-sample NFE counts; solve_multirate(Ks=...)
          |                 is the serving entry; solve(mesh=...) shards
          |                 the batch axis (launch/mesh.py debug/prod mesh)
          |\
          | core/adaptive.py   odeint_dopri5 = DOPRI5 accept/reject instance
          |                    of the same embedded-error path (+ vmap batch)
    launch/engine.py      MultiRateEngine: probe -> bucket snap (packing
          |                 policy only) -> mixed-K batch packing ->
          |                 per-sample-eps fused solves
    launch/scheduler.py   InflightScheduler: slot-pool continuous batching
          |                 over ``solve_segment`` (resumable SegmentCarry,
          |                 admit/retire between segments); mesh= shards
          |                 the SLOT axis (launch/mesh.py sharded_segment)
    launch/serve.py       CLI only (arch/solver/--g-ckpt/--inflight/--mesh)

User-facing docs: README.md (quickstart), docs/architecture.md (this
diagram in prose), docs/serving.md (operator guide).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core.tableaus import Tableau, get as get_tableau

Pytree = Any
VectorField = Callable[[jnp.ndarray, Pytree], Pytree]
# g(eps, s, z, dz) -> correction pytree shaped like z; dz = f(s, z) is the
# first RK stage, passed for free reuse (paper feeds g the concat [z, dz, s]).
Correction = Callable[[Any, Any, Pytree, Pytree], Pytree]


# ------------------------------------------------------ leaf-wise algebra ----

def _bcast(a, leaf: jnp.ndarray):
    """Right-pad a batched scalar coefficient with singleton axes so it
    broadcasts against ``leaf`` from the leading (batch) axis."""
    if isinstance(a, (int, float)):
        return a
    nd = jnp.ndim(a)
    if nd == 0:
        return a
    return jnp.reshape(a, jnp.shape(a) + (1,) * (leaf.ndim - nd))


def tree_axpy(a, x: Pytree, y: Pytree) -> Pytree:
    """y + a * x, leaf-wise; ``a`` may be scalar or batched (leading axis)."""
    return jax.tree_util.tree_map(lambda xi, yi: yi + _bcast(a, yi) * xi, x, y)


def tree_lincomb(coeffs: Sequence[float], trees: Sequence[Pytree]) -> Pytree:
    """sum_j coeffs[j] * trees[j], leaf-wise (skips exact-zero coeffs)."""
    terms = [(c, t) for c, t in zip(coeffs, trees) if c != 0.0]
    if not terms:
        return jax.tree_util.tree_map(jnp.zeros_like, trees[0])
    out = jax.tree_util.tree_map(lambda l: terms[0][0] * l, terms[0][1])
    for c, t in terms[1:]:
        out = tree_axpy(c, t, out)
    return out


def depth_like(s, z: jnp.ndarray) -> jnp.ndarray:
    """Broadcast a depth coordinate ``s`` — scalar, or per-sample (B,) when
    integrating with batched step sizes — to ``z[..., :1]``'s shape, the
    layout fields use to concatenate depth as an extra channel."""
    s = jnp.asarray(s, z.dtype)
    if s.ndim:
        s = s.reshape(s.shape + (1,) * (z.ndim - s.ndim))
    return jnp.broadcast_to(s, z[..., :1].shape)


def with_initial(z0: Pytree, traj: Pytree) -> Pytree:
    """Prepend the initial state to a scanned trajectory, leaf-wise."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a[None], b], axis=0), z0, traj
    )


def rk_stages(f: VectorField, tab: Tableau, s, eps, z: Pytree,
              first_stage: Optional[Pytree] = None):
    """All stage evaluations r_i of an explicit tableau (paper Eq. 3).

    ``stages[0] == f(s, z)``, which hypersolvers reuse as a free input to
    g_omega. ``eps`` may be batched (leading axis). A precomputed
    ``first_stage`` (e.g. a controller probe's dz, core/controllers.py)
    substitutes for stage 0, saving one vector-field evaluation."""
    stages = []
    for i in range(tab.stages):
        if i == 0:
            if first_stage is not None:
                stages.append(first_stage)
                continue
            zi = z
        else:
            zi = tree_axpy(eps, tree_lincomb(tab.a[i], stages), z)
        stages.append(f(s + tab.c[i] * eps, zi))
    return stages


def rk_psi(f: VectorField, tab: Tableau, s, eps, z: Pytree):
    """(psi, stages) where psi = sum_j b_j r_j is the RK update map."""
    stages = rk_stages(f, tab, s, eps, z)
    return tree_lincomb(tab.b, stages), stages


# Storage dtypes the runtime-eps Pallas kernel takes. Since eps became a
# runtime operand (scalar-prefetch SMEM row), step sizes can no longer
# disqualify the fused path — odd state dtypes are the only fallback left.
_FUSED_DTYPES = frozenset(("float32", "bfloat16", "float16"))


def _fusable(z: Pytree) -> bool:
    """True iff every state leaf has a dtype the fused kernel stores.
    Dtype-less leaves (Python scalars) take the jnp fallback, which
    promotes them; the kernel needs real arrays."""
    return all(hasattr(l, "dtype") and l.dtype.name in _FUSED_DTYPES
               for l in jax.tree_util.tree_leaves(z))


class OneTimeWarning:
    """Resettable one-time RuntimeWarning latch.

    A process-global module bool made warning assertions test-order-
    dependent (whichever test tripped a warning first swallowed everyone
    else's). Each warn-once site holds an instance and exposes a reset
    function that the autouse fixture in tests/conftest.py re-arms per
    test. Instances: the fused-fallback warning below, and the bucket-
    overflow snap warning in launch/engine.py."""

    __slots__ = ("warned",)

    def __init__(self) -> None:
        self.warned = False

    def warn(self, message: str, stacklevel: int = 4) -> None:
        if not self.warned:
            warnings.warn(message, RuntimeWarning, stacklevel=stacklevel)
            self.warned = True

    def reset(self) -> None:
        self.warned = False


_fused_fallback = OneTimeWarning()


def reset_fused_fallback_warning() -> None:
    """Re-arm the one-time fused-fallback RuntimeWarning (test isolation)."""
    _fused_fallback.reset()


def _nonfinite_rows(z: Pytree, like: jnp.ndarray) -> jnp.ndarray:
    """Per-slot non-finite flag: True where any inexact element of a
    slot's state row is NaN/Inf, reduced over every non-slot axis of
    every inexact leaf. Plain jnp reductions — no extra kernel trace —
    and row-wise, so it composes with the slot-sharded segment (each
    row's flag depends only on that row's data; no collective).
    ``like`` supplies the (B,) shape/backing for stateless pools."""
    flags = [jnp.any(~jnp.isfinite(l), axis=tuple(range(1, jnp.ndim(l))))
             for l in jax.tree_util.tree_leaves(z)
             if jnp.issubdtype(jnp.asarray(l).dtype, jnp.inexact)]
    if not flags:
        return jnp.zeros_like(like, dtype=bool)
    bad = flags[0]
    for f in flags[1:]:
        bad = bad | f
    return bad


class SegmentCarry(NamedTuple):
    """Resumable per-slot state of a segmented multi-rate solve.

    One row per *slot* (leading axis B on every array/leaf). A slot is a
    request mid-integration: ``z`` its current state, ``k`` the next depth
    step it will take, ``Ks`` its target mesh length, ``eps`` its step
    size. ``first_stage`` optionally carries the admission probe's
    ``dz0 = f(s0, z0)`` rows, substituted as stage 0 exactly while a slot
    is still at ``k == 0`` — the same probe reuse ``solve_multirate`` gets
    via its ``first_stage=`` argument, so segment-wise serving loses no
    NFE accounting honesty.

    The carry is a plain pytree: it jits, donates, and scatters (slot
    refill is a leaf-wise ``.at[idx].set``). ``Integrator.segment_cell``
    is the donation-ready compilation: ``z`` and ``first_stage`` (the two
    pool-sized buffers) are donated into the segment and alias in place
    as its outputs — a caller holding the carry owns at most TWO logical
    buffers per pool, the in-flight one (dead to the host once the
    segment is dispatched) and the resident one (the previous segment's
    outputs, which retire/refill scatter into). A retired/empty slot is
    encoded as ``Ks == 0``: ``k < Ks`` is then always False, so the fused
    freeze mask keeps its rows inert at zero bookkeeping cost —
    occupancy is data, never a shape, which is what keeps one
    ``(shape, seg)`` compilation serving every admission pattern.

    Every field is SLOT-MAJOR (leading axis B), deliberately: the carry
    splits row-wise over a device mesh (``solve_segment(mesh=)`` /
    ``launch/mesh.py::sharded_segment``), so a multi-device slot pool is
    the same pytree sharded — nothing in the layout distinguishes one
    chip's pool from a shard of a bigger one.
    """

    z: Pytree                       # per-slot state, leading slot axis B
    k: jnp.ndarray                  # (B,) int32 — next depth-step index
    Ks: jnp.ndarray                 # (B,) int32 — target mesh lengths (0 = empty)
    eps: jnp.ndarray                # (B,) — per-slot step sizes
    first_stage: Optional[Pytree]   # probe dz0 rows, used only at k == 0


def make_segment_carry(z0: Pytree, Ks, span, *,
                       first_stage: Optional[Pytree] = None) -> SegmentCarry:
    """Fresh carry for a slot batch: every slot at ``k = 0`` with
    ``eps_i = (s1 - s0) / Ks[i]`` — the identical arithmetic of
    ``solve_multirate``, so a segment-driven solve is step-for-step the
    same mesh. ``Ks[i] == 0`` marks an empty slot (eps set to 1.0 so no
    inf/NaN rides along in the frozen rows)."""
    s0, s1 = span
    Ks = jnp.asarray(Ks, jnp.int32)
    eps = jnp.asarray(s1 - s0) / jnp.maximum(Ks, 1)
    eps = jnp.where(Ks > 0, eps, jnp.ones_like(eps))
    return SegmentCarry(z=z0, k=jnp.zeros_like(Ks), Ks=Ks, eps=eps,
                        first_stage=first_stage)


@dataclasses.dataclass(frozen=True)
class SolveStats:
    """Per-sample accounting from a controller-driven solve.

    ``nfe`` includes the controller's probe cost; ``K`` is the per-sample
    mesh length the controller selected; ``err_probe`` its local-error
    estimate (0 for FixedController)."""

    nfe: jnp.ndarray        # (B,) int32 — vector-field evals incl. probe
    K: jnp.ndarray          # (B,) int32 — selected mesh lengths
    err_probe: jnp.ndarray  # (B,) float32 — probe local-error estimate
    probe_nfe: int          # per-sample probe cost included in ``nfe``


# ------------------------------------------------------------- the engine ----

@dataclasses.dataclass(frozen=True)
class Integrator:
    """A base explicit-RK tableau, optionally paired with a hypersolver
    correction ``g`` of matching order (paper Sec. 3) and a fused Pallas
    update path.

    ``fused=True`` collapses the whole per-step state update — the
    b-weighted stage combination, the eps^{p+1} correction, and the
    multi-rate freeze mask — into a single Pallas kernel pass per leaf
    (kernels/hyper_step): one read of each stage and one write of the state
    instead of ``stages + 3`` passes. The kernel takes eps at RUNTIME
    (scalar-prefetch SMEM rows), so scalar, traced, and per-sample batched
    step sizes all fuse through one compilation; only odd state dtypes
    (outside ``_FUSED_DTYPES``) fall back to the jnp path.
    """

    tableau: Tableau
    g: Optional[Correction] = None
    fused: bool = False

    @property
    def order(self) -> int:
        return self.tableau.order

    @property
    def name(self) -> str:
        base = self.tableau.name
        return f"hyper_{base}" if self.g is not None else base

    def with_tableau(self, tab: Union[str, Tableau]) -> "Integrator":
        """Swap the base tableau, keeping g (paper Sec. 4.1: an alpha-family
        hypersolver evaluated under sibling tableaus without finetuning)."""
        tab = get_tableau(tab) if isinstance(tab, str) else tab
        return dataclasses.replace(self, tableau=tab)

    def nfe(self, K: int) -> int:
        """Vector-field evaluations over K steps (g counted separately as
        overhead, paper Sec. 6)."""
        return self.tableau.stages * K

    def fused_available(self, eps=None, z: Optional[Pytree] = None) -> bool:
        """True iff the fused Pallas kernel path will actually run — the
        structured twin of the one-time fallback warning, for serving
        configs to assert the kernel is in play. ``eps`` is accepted for
        interface stability but no longer gates anything: the runtime-eps
        kernel fuses scalar, traced, AND per-sample batched step sizes.
        Pass the state (``z=``) to also vet its dtypes."""
        del eps  # runtime operand now — any step-size pattern fuses
        return self.fused and (z is None or _fusable(z))

    # ------------------------------------------------------------- step ----
    def step(self, f: VectorField, s, eps, z: Pytree,
             first_stage: Optional[Pytree] = None,
             active: Optional[jnp.ndarray] = None):
        """One (hyper)solved step. Returns (z_next, psi, dz).

        ``eps`` may be a Python float, a traced scalar, or a per-sample
        ``(B,)`` row (then all state leaves carry the leading batch axis).
        ``active`` is an optional ``(B,)`` mask row: inactive samples keep
        ``z`` (the multi-rate freeze) — applied inside the fused kernel at
        zero extra memory passes, or as a trailing leaf-wise ``where`` on
        the jnp path.

        ``psi`` (the b-weighted stage combination) is lazy: on the fused
        path the kernel already produced the combined update, so psi is
        returned as ``None`` rather than re-running ``stages`` leaf-wise
        passes nobody consumes — the serving hot loop only uses z_next.

        ``first_stage`` substitutes a precomputed f(s, z) for stage 0
        (probe reuse — see core/controllers.py)."""
        tab = self.tableau
        stages = rk_stages(f, tab, s, eps, z, first_stage=first_stage)
        dz = stages[0]
        corr = self.g(eps, s, z, dz) if self.g is not None else None
        use_kernel = self.fused and _fusable(z)
        if self.fused and not use_kernel:
            _fused_fallback.warn(
                "Integrator(fused=True): state dtypes outside the kernel "
                f"set {sorted(_FUSED_DTYPES)}; falling back to the "
                "leaf-wise jnp update path for this solve.")
        if use_kernel:
            from repro.kernels.hyper_step.ops import fused_rk_update
            # zero-b stages never reach the kernel: each operand costs a
            # full HBM read per step, the whole traffic the fusion saves
            live = tuple((bj, r) for bj, r in zip(tab.b, stages)
                         if bj != 0.0)
            b_live = tuple(bj for bj, _ in live)
            n_live = len(live)
            eps_op = eps if isinstance(eps, (int, float)) \
                else jnp.asarray(eps)
            z_next = jax.tree_util.tree_map(
                lambda zl, *rest: fused_rk_update(
                    zl, rest[:n_live],
                    rest[n_live] if corr is not None else None,
                    eps_op, b_live, tab.order, active=active),
                z, *(r for _, r in live),
                *((corr,) if corr is not None else ()))
            psi = None  # fused kernel already combined the stages
        else:
            psi = tree_lincomb(tab.b, stages)
            z_next = tree_axpy(eps, psi, z)
            if corr is not None:
                p1 = self.order + 1
                ceps = eps ** p1 if isinstance(eps, (int, float)) \
                    else jnp.asarray(eps) ** p1
                z_next = tree_axpy(ceps, corr, z_next)
            if active is not None:
                z_next = jax.tree_util.tree_map(
                    lambda a, b_: jnp.where(_bcast(active, b_), a, b_),
                    z_next, z)
        return z_next, psi, dz

    # ------------------------------------------------------------ solve ----
    def solve(
        self,
        f: VectorField,
        z0: Pytree,
        grid,
        *,
        return_traj: bool = True,
        checkpoint: bool = False,
        controller=None,
        first_stage: Optional[Pytree] = None,
        mesh=None,
        batch_axis: str = "data",
    ):
        """Integrate z' = f(s, z) over ``grid`` (a FixedGrid; ``grid.eps``
        may carry a leading batch axis for per-sample step sizes, in which
        case ``f`` receives a batched ``s`` — use ``depth_like`` to lift it
        leaf-wise; ``jax.vmap`` over (z0, eps) is the fully general path).

        Returns the dense trajectory stacked on a leading axis of length
        K+1 (including z0) when ``return_traj``, else the terminal state.
        ``checkpoint=True`` rematerializes each step under reverse-mode AD.

        With a ``controller`` (core/controllers.py), ``grid`` supplies only
        the span [s0, s0 + eps*K] (scalar eps required): the controller
        probes z0, picks a per-sample mesh length K_i, and the solve runs a
        masked multi-rate scan — sample i integrates at eps_i = span/K_i
        and freezes after K_i steps. Returns ``(result, SolveStats)`` with
        per-sample NFE counts (probe included, minus the reused first
        stage). The scan length is the controller's ``k_max``, so pack
        similar-difficulty samples together (launch/engine.py's bucketing)
        to avoid masked-step waste.

        ``first_stage`` is a precomputed f(s0, z0) (a probe's dz) reused as
        stage 0 of the first step — one NFE saved per solve.

        ``mesh`` shards the solve data-parallel: the leading batch axis of
        every state leaf (and a batched ``grid.eps``) is sharded over the
        mesh's ``batch_axis`` via ``shard_map`` and the depth scan runs
        local to each shard — batch rows share nothing, so no collective
        is ever emitted. The batch size must divide the axis size.
        """
        if mesh is not None:
            return self._solve_sharded(
                f, z0, grid, mesh, batch_axis, return_traj=return_traj,
                checkpoint=checkpoint, controller=controller,
                first_stage=first_stage)
        eps = grid.eps
        if controller is not None:
            return self._solve_controlled(f, z0, grid, controller,
                                          return_traj, checkpoint)

        def body(z, k):
            z_next, _, _ = self.step(f, grid.s0 + k * eps, eps, z)
            return z_next, (z_next if return_traj else None)

        if checkpoint:
            body = jax.checkpoint(body)
        if first_stage is None:
            zT, ys = jax.lax.scan(body, z0, jnp.arange(grid.K))
            if not return_traj:
                return zT
            return with_initial(z0, ys)
        # step 0 unrolled to consume the probe's stage; scan the rest
        z1, _, _ = self.step(f, grid.s0, eps, z0, first_stage=first_stage)
        zT, ys = jax.lax.scan(body, z1, jnp.arange(1, grid.K))
        if not return_traj:
            return zT
        return with_initial(z0, with_initial(z1, ys))

    def solve_multirate(self, f, z0: Pytree, span, Ks, k_max: int, *,
                        first_stage: Optional[Pytree] = None,
                        return_traj: bool = False,
                        checkpoint: bool = False):
        """Masked multi-rate solve over externally supplied per-sample mesh
        lengths: sample i integrates ``span`` in ``Ks[i]`` uniform steps
        (eps_i = (s1 - s0) / Ks[i]); the scan runs ``k_max`` steps and
        freezes sample i once ``k >= Ks[i]``. All z0 leaves must share a
        leading batch axis matching ``Ks``.

        This is the serving engine's entry point (launch/engine.py packs a
        mixed-K request batch straight into one call — ``Ks`` is a traced
        operand, so one compilation per (shape, k_max) serves every bucket
        mix). On the fused path the whole masked update
        ``where(k < K_i, z + eps_i*psi + eps_i^{p+1}*g, z)`` is ONE kernel
        memory pass per leaf; unfused it is ``stages + 3`` jnp passes
        (lincomb + axpy + correction axpy + freeze where).

        ``k_max`` must cover every ``Ks[i]`` — a sample whose mesh is
        longer than the scan would silently stop mid-span (checked here
        when Ks is concrete; traced callers own the invariant, as the
        engine does with ``k_max = Ks.max()``)."""
        s0, s1 = span
        Ks = jnp.asarray(Ks, jnp.int32)
        try:
            ks_hi = int(jnp.max(Ks))
        except jax.errors.ConcretizationTypeError:
            ks_hi = None
        if ks_hi is not None and ks_hi > int(k_max):
            raise ValueError(
                f"k_max={int(k_max)} truncates samples with K up to "
                f"{ks_hi}: their scan would stop mid-span")
        eps = jnp.asarray(s1 - s0) / Ks  # (B,) per-sample step sizes

        def body(z, k):
            z_next, _, _ = self.step(f, s0 + k * eps, eps, z,
                                     active=(k < Ks))
            return z_next, (z_next if return_traj else None)

        if checkpoint:
            body = jax.checkpoint(body)
        # step 0 is always active (K_i >= 1) and can reuse a probe's dz0
        # — f(s0, z0) does not depend on eps, so it is shared by every
        # sample regardless of its selected rate.
        z1, _, _ = self.step(f, s0, eps, z0, first_stage=first_stage)
        zT, ys = jax.lax.scan(body, z1, jnp.arange(1, int(k_max)))
        if not return_traj:
            return zT
        return with_initial(z0, with_initial(z1, ys))

    def solve_segment(self, f, carry: SegmentCarry, seg: int, *,
                      s0=0.0, mesh=None, slot_axis: str = "data"):
        """Advance every slot of ``carry`` by ``seg`` depth steps and
        return ``(carry', finished)`` — the resumable core of in-flight
        continuous batching (launch/scheduler.py).

        Each slot walks its own mesh: slot i steps at ``eps_i`` from
        ``s = s0 + k_i * eps_i`` and freezes (state AND counter) once
        ``k_i >= Ks_i`` — the same masked update ``solve_multirate``
        scans, so driving a batch to completion segment-by-segment is
        step-for-step identical to one ``solve_multirate`` call with the
        same ``Ks`` row. The payoff is resumability: between segments a
        caller may retire finished slots and scatter fresh requests into
        them (a new z row, ``k = 0``, a new ``Ks``/``eps``), and because
        occupancy/refill are carried as data, ONE ``(shape, seg)``
        compilation — one kernel trace on the fused path — serves every
        admission pattern with zero recompiles.

        ``finished`` is ``k >= Ks`` after the segment: True for slots
        that completed their mesh during (or before) this segment,
        including empty ``Ks == 0`` slots — callers keep their own
        occupancy mask to tell a retired slot from a fresh completion.

        ``seg`` is a static Python int (the scan length, part of the jit
        cell); ``s0`` is the shared span origin. A slot admitted with a
        probe ``first_stage`` row consumes it on its ``k == 0`` step
        only; the blend costs no extra vector-field evaluation (the
        batch-wide ``f`` call is the one ``step`` would make anyway).

        ``mesh`` shards the SLOT axis the way ``solve(mesh=)`` shards the
        batch axis: every ``SegmentCarry`` field is slot-major, so the
        carry splits row-wise over the mesh's ``slot_axis`` via
        ``shard_map`` and the ``seg``-step depth scan runs local to each
        shard — slots share nothing (occupancy, freeze masks, and step
        sizes are all per-row data), so no collective is ever emitted and
        one ``(shape, seg, mesh)`` compilation (one fused-kernel trace)
        still serves every refill pattern. The slot count must divide the
        axis size. ``f`` must be slot-local: anything it closes over
        (model params) is replicated; per-slot conditioning must shard
        WITH the carry — use ``launch/mesh.py::sharded_segment``, which
        threads the conditioning rows through the same shard_map."""
        if mesh is not None:
            return self._solve_segment_sharded(f, carry, seg, s0, mesh,
                                               slot_axis)
        z, k, Ks, eps, fs = carry
        k = jnp.asarray(k, jnp.int32)
        Ks = jnp.asarray(Ks, jnp.int32)

        def body(zk, _):
            zc, kc = zk
            active = kc < Ks
            s = s0 + kc * eps
            if fs is None:
                dz0 = None
            else:
                # fresh slots (k == 0) substitute their probe's dz row for
                # stage 0 — identical values to f(s0, z) there, reused so
                # the probe's accounting (one eval saved) stays honest.
                dz = f(s, zc)
                fresh = kc == 0
                dz0 = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(_bcast(fresh, b), a, b), fs, dz)
            z_next, _, _ = self.step(f, s, eps, zc, first_stage=dz0,
                                     active=active)
            return (z_next, jnp.where(active, kc + 1, kc)), None

        (z, k), _ = jax.lax.scan(body, (z, k), None, length=int(seg))
        return SegmentCarry(z, k, Ks, eps, fs), k >= Ks

    def _solve_segment_sharded(self, f, carry, seg, s0, mesh, slot_axis,
                               *, field_of=None, cond=None):
        """Slot-parallel segment advance: shard every carry field over
        ``slot_axis`` and run the local ``solve_segment`` per shard. Only
        the fields the segment mutates (z, k) cross back through the
        shard_map boundary — Ks/eps/first_stage pass through unchanged.

        With ``field_of``/``cond`` (launch/mesh.py::sharded_segment),
        the per-slot conditioning rows ``cond`` shard WITH the carry and
        each shard's field is rebuilt as ``field_of(cond_local)``; ``f``
        is ignored. Both entry points share this one plumbing so the
        divisibility policy and the spec layout cannot diverge."""
        from jax.experimental.shard_map import shard_map
        P = jax.sharding.PartitionSpec
        tmap = jax.tree_util.tree_map
        bspec = P(slot_axis)
        n = mesh.shape[slot_axis]
        B = carry.k.shape[0]
        if B % n:
            raise ValueError(
                f"slot count {B} does not divide the '{slot_axis}' mesh "
                f"axis ({n}); size the pool as a multiple of the axis "
                "(launch/scheduler.py slots=)")
        z, k, Ks, eps, fs = carry
        threaded = cond is not None
        args = ([cond] if threaded else []) + [z, k, Ks, eps]
        in_specs = ([bspec] if threaded else []) + \
            [tmap(lambda _: bspec, z), bspec, bspec, bspec]
        if fs is not None:
            args.append(fs)
            in_specs.append(tmap(lambda _: bspec, fs))

        def body(*ops):
            if threaded:
                cond_, *ops = ops
            z_, k_, Ks_, eps_, *fs_ = ops
            local = SegmentCarry(z_, k_, Ks_, eps_,
                                 fs_[0] if fs_ else None)
            f_local = field_of(cond_) if threaded else f
            out, fin = self.solve_segment(f_local, local, seg, s0=s0)
            return out.z, out.k, fin

        out_specs = (tmap(lambda _: bspec, z), bspec, bspec)
        z2, k2, fin = shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                                out_specs=out_specs, check_rep=False)(*args)
        return SegmentCarry(z2, k2, Ks, eps, fs), fin

    def segment_cell(self, field_of, seg: int, *, s0=0.0, mesh=None,
                     slot_axis: str = "data", donate: bool = True,
                     g_apply=None):
        """The serving-loop compilation of ``solve_segment``: one jitted
        ``(xs, z, k, Ks, eps, fs) -> (z', fs', meta)`` cell per
        ``(shape, seg[, mesh])``, with the carry buffers DONATED.

        The donation contract (``donate_argnums``): the big per-slot
        buffers — ``z`` and the ``fs`` probe rows — are consumed by the
        call, and XLA aliases them in place as the output buffers, so
        slot state never round-trips HBM between segments. The caller
        must treat the inputs as dead the instant the cell is dispatched
        (``Array has been deleted`` on any later use) and rebind the
        returned ``(z', fs')`` as the pool's resident state; any read of
        the OLD buffers (a finished-row readout gather, a refill
        scatter) must be enqueued BEFORE the donating call. ``k``, ``Ks``
        and ``eps`` are (B,) bookkeeping rows — too small to be worth
        aliasing, and ``Ks``/``eps`` persist host-side across segments —
        so they are passed by value.

        ``meta`` is the stacked ``(3, B)`` int32
        ``[k'; finished; nonfinite]`` rows: retiring a segment costs ONE
        device->host transfer, and because jit dispatch is async the
        caller can hold ``meta`` as a future and read it a full segment
        later (the overlap loop in launch/scheduler.py). The third row
        is the per-slot non-finite quarantine flag (``_nonfinite_rows``
        over the post-segment state): a diverging slot is detected
        inside the compiled cell — no extra transfer, no extra kernel
        trace, and row-wise so it shards with the carry — and the
        scheduler force-retires it with ``status="diverged"`` instead
        of recycling poisoned state. ``fs'`` is the first_stage passthrough —
        ``solve_segment`` never mutates it, so the donated input aliases
        straight to the output; when the pool runs probeless (``fs is
        None``) the slot contributes no donated buffer and the cell
        degrades gracefully.

        ``field_of`` builds the slot-local vector field from the per-slot
        conditioning rows ``xs`` (the launch/engine.py ``DepthModel``
        adapter shape); under ``mesh=`` the rows thread through the same
        shard_map as the carry (``_solve_segment_sharded``).

        ``g_apply`` turns the correction into a HOT-SWAPPABLE operand:
        instead of baking g's parameters into the closure (a constant of
        the compiled cell — swapping them would force a retrace), the
        cell takes an extra trailing ``gp`` pytree and binds
        ``g = g_apply(gp, eps, s, z, dz)`` inside the trace. ``gp`` is a
        traced, NON-donated input, so replacing it between segments with
        a pytree of identical treedef/shapes/dtypes reuses the same
        compilation — the params-are-inputs invariant the online refinery
        (launch/refinery.py) rests on. Any closure ``self.g`` is ignored
        on this path. The non-parametric signature and its donation
        contract are unchanged."""

        def _advance(integ, xs, z, k, Ks, eps, fs):
            carry = SegmentCarry(z, jnp.asarray(k, jnp.int32),
                                 jnp.asarray(Ks, jnp.int32), eps, fs)
            if mesh is None:
                out, fin = integ.solve_segment(field_of(xs), carry, seg,
                                               s0=s0)
            else:
                out, fin = integ._solve_segment_sharded(
                    None, carry, seg, s0, mesh, slot_axis,
                    field_of=field_of, cond=xs)
            bad = _nonfinite_rows(out.z, like=fin)
            meta = jnp.stack([out.k.astype(jnp.int32),
                              fin.astype(jnp.int32),
                              bad.astype(jnp.int32)])
            return out.z, out.first_stage, meta

        if g_apply is None:
            def run(xs, z, k, Ks, eps, fs):
                return _advance(self, xs, z, k, Ks, eps, fs)
        else:
            def run(xs, z, k, Ks, eps, fs, gp):
                bound = dataclasses.replace(
                    self,
                    g=lambda e, s, zz, dzz: g_apply(gp, e, s, zz, dzz))
                return _advance(bound, xs, z, k, Ks, eps, fs)

        return jax.jit(run, donate_argnums=(1, 5) if donate else ())

    def _solve_controlled(self, f, z0, grid, controller, return_traj,
                          checkpoint):
        """Probe, pick per-sample mesh lengths, run the masked multi-rate
        scan, and account per-sample NFE."""
        assert jnp.ndim(grid.eps) == 0, (
            "controller-driven solve derives per-sample eps itself; pass a "
            "scalar-eps grid defining the span")
        s0 = grid.s0
        s1 = s0 + grid.eps * grid.K
        probe = controller.select(self, f, z0, (s0, s1))
        result = self.solve_multirate(
            f, z0, (s0, s1), probe.K, int(controller.k_max),
            first_stage=probe.dz0, return_traj=return_traj,
            checkpoint=checkpoint)
        reused = 1 if probe.dz0 is not None else 0
        stats = SolveStats(
            nfe=(probe.nfe - reused
                 + self.tableau.stages * probe.K).astype(jnp.int32),
            K=probe.K,
            err_probe=jnp.asarray(probe.err, jnp.float32),
            probe_nfe=int(probe.nfe),
        )
        return result, stats

    def _solve_sharded(self, f, z0, grid, mesh, batch_axis, *, return_traj,
                       checkpoint, controller, first_stage):
        """Data-parallel solve: shard the leading batch axis over
        ``batch_axis``, depth scan local to each shard. Batch rows share
        nothing — the runtime-eps kernel looks its per-row coefficients up
        from prefetched SMEM — so the body emits no collectives and the
        wrapper is pure bookkeeping (specs in, specs out)."""
        from jax.experimental.shard_map import shard_map
        P = jax.sharding.PartitionSpec
        tmap = jax.tree_util.tree_map
        bspec = P(batch_axis)
        eps_batched = jnp.ndim(grid.eps) > 0
        args = [z0, jnp.asarray(grid.eps)]
        in_specs = [tmap(lambda _: bspec, z0),
                    bspec if eps_batched else P()]
        if first_stage is not None:
            args.append(first_stage)
            in_specs.append(tmap(lambda _: bspec, first_stage))

        def body(z0_, eps_, *fs_):
            out = self.solve(
                f, z0_, grid._replace(eps=eps_), return_traj=return_traj,
                checkpoint=checkpoint, controller=controller,
                first_stage=fs_[0] if fs_ else None)
            if controller is not None:
                res, st = out
                # SolveStats is not a pytree (static probe_nfe member):
                # ship its arrays through the shard_map boundary and
                # rebuild outside.
                return res, (st.nfe, st.K, st.err_probe)
            return out

        res_spec = P(None, batch_axis) if return_traj else bspec
        out_specs = (res_spec, (bspec, bspec, bspec)) \
            if controller is not None else res_spec
        out = shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                        out_specs=out_specs, check_rep=False)(*args)
        if controller is None:
            return out
        res, (nfe, K, err) = out
        return res, SolveStats(
            nfe=nfe, K=K, err_probe=err,
            probe_nfe=int(getattr(controller, "probe_nfe", 0)))


def as_integrator(
    solver, g: Optional[Correction] = None, fused: bool = False
) -> Integrator:
    """Coerce a tableau name / Tableau / Integrator / HyperSolver-like
    object (anything with .tableau/.g/.fused) into an Integrator."""
    if isinstance(solver, Integrator):
        return solver
    if isinstance(solver, str):
        return Integrator(tableau=get_tableau(solver), g=g, fused=fused)
    if isinstance(solver, Tableau):
        return Integrator(tableau=solver, g=g, fused=fused)
    if hasattr(solver, "tableau"):
        return Integrator(tableau=solver.tableau,
                          g=getattr(solver, "g", g),
                          fused=getattr(solver, "fused", fused))
    raise TypeError(f"cannot build an Integrator from {solver!r}")
