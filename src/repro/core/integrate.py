"""Unified batched integration engine — every solve in the repo goes here.

``Integrator`` subsumes the three integration paths the codebase grew
(``solvers.odeint_fixed``, ``HyperSolver.odeint`` and the per-model scan
loops): one scan-native engine that

  * works on arbitrary pytree states (a CNF's ``(z, logp)`` tuple, the LM
    residual stream, image feature maps) — all linear algebra is leaf-wise;
  * composes with ``jax.jit`` / ``jax.vmap`` / ``jax.grad`` — the mesh walk
    is a single ``lax.scan`` whose unrolled HLO is O(1) in K;
  * supports *batched step sizes*: ``grid.eps`` may be an array with a
    leading batch axis (per-sample eps for multi-rate serving — each row of
    the batch integrates its own mesh), broadcast leaf-wise against the
    state;
  * emits the dense trajectory (leading axis K+1, including z0) or the
    terminal state only;
  * optionally rematerializes each step under reverse-mode AD
    (``checkpoint=True``) so trajectories of long meshes backprop in O(K)
    memory instead of O(K * stages);
  * routes the update through the fused Pallas ``hyper_step`` kernel
    (``fused=True``): the b-weighted stage combination AND the eps^{p+1}
    correction term collapse into one memory pass per leaf, for every base
    tableau — the update is memory-bound, so this is the serving hot path;
  * integrates under a step controller (``controller=``,
    core/controllers.py): a cheap probe picks a per-sample mesh length,
    the probe's first stage is reused, and the solve reports per-sample
    NFE counts (``SolveStats``) — the error-control layer multi-rate
    serving (launch/engine.py) builds on.

The hypersolver update implemented for tableau psi and correction g
(paper Eq. 3 + Eq. 5, Poli et al. 2020):

    z_{k+1} = z_k + eps * sum_j b_j r_j + eps^{p+1} * g(eps, s_k, z_k, r_0)

Controller/engine architecture (error-controlled multi-rate serving)::

    core/tableaus.py      Tableau (+ b_err embedded weights)
          |
    core/controllers.py   embedded_step / error_ratio / step_factor
          |                 FixedController | EmbeddedErrorController |
          |                 HypersolverResidualController
          |                       | per-sample K from a cheap probe
    core/integrate.py     Integrator.solve(..., controller=) -> (z, SolveStats)
          |                 masked multi-rate scan, per-sample NFE counts
          |\
          | core/adaptive.py   odeint_dopri5 = DOPRI5 accept/reject instance
          |                    of the same embedded-error path (+ vmap batch)
    launch/engine.py      MultiRateEngine: probe -> eps-bucket assignment ->
          |                 same-bucket batch packing -> scalar-eps solves
    launch/serve.py       CLI only (arch/solver/--g-ckpt flags)
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.core.tableaus import Tableau, get as get_tableau

Pytree = Any
VectorField = Callable[[jnp.ndarray, Pytree], Pytree]
# g(eps, s, z, dz) -> correction pytree shaped like z; dz = f(s, z) is the
# first RK stage, passed for free reuse (paper feeds g the concat [z, dz, s]).
Correction = Callable[[Any, Any, Pytree, Pytree], Pytree]


# ------------------------------------------------------ leaf-wise algebra ----

def _bcast(a, leaf: jnp.ndarray):
    """Right-pad a batched scalar coefficient with singleton axes so it
    broadcasts against ``leaf`` from the leading (batch) axis."""
    if isinstance(a, (int, float)):
        return a
    nd = jnp.ndim(a)
    if nd == 0:
        return a
    return jnp.reshape(a, jnp.shape(a) + (1,) * (leaf.ndim - nd))


def tree_axpy(a, x: Pytree, y: Pytree) -> Pytree:
    """y + a * x, leaf-wise; ``a`` may be scalar or batched (leading axis)."""
    return jax.tree_util.tree_map(lambda xi, yi: yi + _bcast(a, yi) * xi, x, y)


def tree_lincomb(coeffs: Sequence[float], trees: Sequence[Pytree]) -> Pytree:
    """sum_j coeffs[j] * trees[j], leaf-wise (skips exact-zero coeffs)."""
    terms = [(c, t) for c, t in zip(coeffs, trees) if c != 0.0]
    if not terms:
        return jax.tree_util.tree_map(jnp.zeros_like, trees[0])
    out = jax.tree_util.tree_map(lambda l: terms[0][0] * l, terms[0][1])
    for c, t in terms[1:]:
        out = tree_axpy(c, t, out)
    return out


def depth_like(s, z: jnp.ndarray) -> jnp.ndarray:
    """Broadcast a depth coordinate ``s`` — scalar, or per-sample (B,) when
    integrating with batched step sizes — to ``z[..., :1]``'s shape, the
    layout fields use to concatenate depth as an extra channel."""
    s = jnp.asarray(s, z.dtype)
    if s.ndim:
        s = s.reshape(s.shape + (1,) * (z.ndim - s.ndim))
    return jnp.broadcast_to(s, z[..., :1].shape)


def with_initial(z0: Pytree, traj: Pytree) -> Pytree:
    """Prepend the initial state to a scanned trajectory, leaf-wise."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a[None], b], axis=0), z0, traj
    )


def rk_stages(f: VectorField, tab: Tableau, s, eps, z: Pytree,
              first_stage: Optional[Pytree] = None):
    """All stage evaluations r_i of an explicit tableau (paper Eq. 3).

    ``stages[0] == f(s, z)``, which hypersolvers reuse as a free input to
    g_omega. ``eps`` may be batched (leading axis). A precomputed
    ``first_stage`` (e.g. a controller probe's dz, core/controllers.py)
    substitutes for stage 0, saving one vector-field evaluation."""
    stages = []
    for i in range(tab.stages):
        if i == 0:
            if first_stage is not None:
                stages.append(first_stage)
                continue
            zi = z
        else:
            zi = tree_axpy(eps, tree_lincomb(tab.a[i], stages), z)
        stages.append(f(s + tab.c[i] * eps, zi))
    return stages


def rk_psi(f: VectorField, tab: Tableau, s, eps, z: Pytree):
    """(psi, stages) where psi = sum_j b_j r_j is the RK update map."""
    stages = rk_stages(f, tab, s, eps, z)
    return tree_lincomb(tab.b, stages), stages


def _static_eps(eps) -> Optional[float]:
    """eps as a Python float when it is concrete and scalar, else None
    (batched or traced eps cannot be baked into a Pallas kernel)."""
    if isinstance(eps, (int, float)):
        return float(eps)
    try:
        if jnp.ndim(eps) == 0:
            return float(eps)
    except (TypeError, jax.errors.ConcretizationTypeError):
        pass
    return None


_fused_fallback_warned = False


def _warn_fused_fallback() -> None:
    """One-time process-wide warning when fused=True cannot use the kernel.

    Serving configs key off this (or ``Integrator.fused_available``) to know
    the Pallas hyper_step kernel is NOT in play — e.g. a multi-rate batch
    with per-sample eps must be split into scalar-eps buckets to fuse."""
    global _fused_fallback_warned
    if not _fused_fallback_warned:
        warnings.warn(
            "Integrator(fused=True): eps is batched or traced, so the fused "
            "Pallas hyper_step kernel cannot be specialized; falling back to "
            "the leaf-wise jnp update path. Use a concrete scalar eps (one "
            "bucket per step size) to keep the kernel in play.",
            RuntimeWarning, stacklevel=3)
        _fused_fallback_warned = True


@dataclasses.dataclass(frozen=True)
class SolveStats:
    """Per-sample accounting from a controller-driven solve.

    ``nfe`` includes the controller's probe cost; ``K`` is the per-sample
    mesh length the controller selected; ``err_probe`` its local-error
    estimate (0 for FixedController)."""

    nfe: jnp.ndarray        # (B,) int32 — vector-field evals incl. probe
    K: jnp.ndarray          # (B,) int32 — selected mesh lengths
    err_probe: jnp.ndarray  # (B,) float32 — probe local-error estimate
    probe_nfe: int          # per-sample probe cost included in ``nfe``


# ------------------------------------------------------------- the engine ----

@dataclasses.dataclass(frozen=True)
class Integrator:
    """A base explicit-RK tableau, optionally paired with a hypersolver
    correction ``g`` of matching order (paper Sec. 3) and a fused Pallas
    update path.

    ``fused=True`` collapses the whole per-step state update — the
    b-weighted stage combination plus the eps^{p+1} correction — into a
    single Pallas kernel pass per leaf (kernels/hyper_step): one read of
    each stage and one write of the state instead of ``stages + 2`` passes.
    Falls back to the jnp path when eps is batched/traced (the kernel bakes
    eps statically).
    """

    tableau: Tableau
    g: Optional[Correction] = None
    fused: bool = False

    @property
    def order(self) -> int:
        return self.tableau.order

    @property
    def name(self) -> str:
        base = self.tableau.name
        return f"hyper_{base}" if self.g is not None else base

    def with_tableau(self, tab: Union[str, Tableau]) -> "Integrator":
        """Swap the base tableau, keeping g (paper Sec. 4.1: an alpha-family
        hypersolver evaluated under sibling tableaus without finetuning)."""
        tab = get_tableau(tab) if isinstance(tab, str) else tab
        return dataclasses.replace(self, tableau=tab)

    def nfe(self, K: int) -> int:
        """Vector-field evaluations over K steps (g counted separately as
        overhead, paper Sec. 6)."""
        return self.tableau.stages * K

    def fused_available(self, eps) -> bool:
        """True iff the fused Pallas kernel path will actually run for this
        eps — the structured twin of the one-time fallback warning, for
        serving configs to assert the kernel is in play."""
        return self.fused and _static_eps(eps) is not None

    # ------------------------------------------------------------- step ----
    def step(self, f: VectorField, s, eps, z: Pytree,
             first_stage: Optional[Pytree] = None):
        """One (hyper)solved step. Returns (z_next, psi, dz).

        ``psi`` (the b-weighted stage combination) is lazy: on the fused
        path the kernel already produced the combined update, so psi is
        returned as ``None`` rather than re-running ``stages`` leaf-wise
        passes nobody consumes — the serving hot loop only uses z_next.

        ``first_stage`` substitutes a precomputed f(s, z) for stage 0
        (probe reuse — see core/controllers.py)."""
        tab = self.tableau
        stages = rk_stages(f, tab, s, eps, z, first_stage=first_stage)
        dz = stages[0]
        corr = self.g(eps, s, z, dz) if self.g is not None else None
        eps_f = _static_eps(eps) if self.fused else None
        if self.fused and eps_f is None:
            _warn_fused_fallback()
        if eps_f is not None:
            from repro.kernels.hyper_step.ops import fused_rk_update
            # zero-b stages never reach the kernel: each operand costs a
            # full HBM read per step, the whole traffic the fusion saves
            live = tuple((bj, r) for bj, r in zip(tab.b, stages)
                         if bj != 0.0)
            b_live = tuple(bj for bj, _ in live)
            n_live = len(live)
            z_next = jax.tree_util.tree_map(
                lambda zl, *rest: fused_rk_update(
                    zl, rest[:n_live],
                    rest[n_live] if corr is not None else None,
                    eps_f, b_live, tab.order),
                z, *(r for _, r in live),
                *((corr,) if corr is not None else ()))
            psi = None  # fused kernel already combined the stages
        else:
            psi = tree_lincomb(tab.b, stages)
            z_next = tree_axpy(eps, psi, z)
            if corr is not None:
                p1 = self.order + 1
                ceps = eps ** p1 if isinstance(eps, (int, float)) \
                    else jnp.asarray(eps) ** p1
                z_next = tree_axpy(ceps, corr, z_next)
        return z_next, psi, dz

    # ------------------------------------------------------------ solve ----
    def solve(
        self,
        f: VectorField,
        z0: Pytree,
        grid,
        *,
        return_traj: bool = True,
        checkpoint: bool = False,
        controller=None,
        first_stage: Optional[Pytree] = None,
    ):
        """Integrate z' = f(s, z) over ``grid`` (a FixedGrid; ``grid.eps``
        may carry a leading batch axis for per-sample step sizes, in which
        case ``f`` receives a batched ``s`` — use ``depth_like`` to lift it
        leaf-wise; ``jax.vmap`` over (z0, eps) is the fully general path).

        Returns the dense trajectory stacked on a leading axis of length
        K+1 (including z0) when ``return_traj``, else the terminal state.
        ``checkpoint=True`` rematerializes each step under reverse-mode AD.

        With a ``controller`` (core/controllers.py), ``grid`` supplies only
        the span [s0, s0 + eps*K] (scalar eps required): the controller
        probes z0, picks a per-sample mesh length K_i, and the solve runs a
        masked multi-rate scan — sample i integrates at eps_i = span/K_i
        and freezes after K_i steps. Returns ``(result, SolveStats)`` with
        per-sample NFE counts (probe included, minus the reused first
        stage). The scan length is the controller's ``k_max``, so pack
        similar-difficulty samples together (launch/engine.py's bucketing)
        to avoid masked-step waste.

        ``first_stage`` is a precomputed f(s0, z0) (a probe's dz) reused as
        stage 0 of the first step — one NFE saved per solve.
        """
        eps = grid.eps
        if controller is not None:
            return self._solve_controlled(f, z0, grid, controller,
                                          return_traj, checkpoint)

        def body(z, k):
            z_next, _, _ = self.step(f, grid.s0 + k * eps, eps, z)
            return z_next, (z_next if return_traj else None)

        if checkpoint:
            body = jax.checkpoint(body)
        if first_stage is None:
            zT, ys = jax.lax.scan(body, z0, jnp.arange(grid.K))
            if not return_traj:
                return zT
            return with_initial(z0, ys)
        # step 0 unrolled to consume the probe's stage; scan the rest
        z1, _, _ = self.step(f, grid.s0, eps, z0, first_stage=first_stage)
        zT, ys = jax.lax.scan(body, z1, jnp.arange(1, grid.K))
        if not return_traj:
            return zT
        return with_initial(z0, with_initial(z1, ys))

    def _solve_controlled(self, f, z0, grid, controller, return_traj,
                          checkpoint):
        """Masked multi-rate scan over per-sample meshes chosen by the
        controller. All z0 leaves must share a leading batch axis."""
        assert jnp.ndim(grid.eps) == 0, (
            "controller-driven solve derives per-sample eps itself; pass a "
            "scalar-eps grid defining the span")
        s0 = grid.s0
        s1 = s0 + grid.eps * grid.K
        probe = controller.select(self, f, z0, (s0, s1))
        Ks = probe.K
        eps = jnp.asarray(s1 - s0) / Ks  # (B,) per-sample step sizes

        def body(z, k):
            s = s0 + k * eps
            z_next, _, _ = self.step(f, s, eps, z)
            active = k < Ks
            z_next = jax.tree_util.tree_map(
                lambda a, b: jnp.where(_bcast(active, b), a, b), z_next, z)
            return z_next, (z_next if return_traj else None)

        if checkpoint:
            body = jax.checkpoint(body)
        # step 0 is always active (K_i >= 1) and can reuse the probe's dz0
        # — f(s0, z0) does not depend on eps, so it is shared by every
        # sample regardless of its selected rate.
        z1, _, _ = self.step(f, s0, eps, z0, first_stage=probe.dz0)
        zT, ys = jax.lax.scan(body, z1, jnp.arange(1, int(controller.k_max)))
        reused = 1 if probe.dz0 is not None else 0
        stats = SolveStats(
            nfe=(probe.nfe - reused
                 + self.tableau.stages * Ks).astype(jnp.int32),
            K=Ks,
            err_probe=jnp.asarray(probe.err, jnp.float32),
            probe_nfe=int(probe.nfe),
        )
        if not return_traj:
            return zT, stats
        return with_initial(z0, with_initial(z1, ys)), stats


def as_integrator(
    solver, g: Optional[Correction] = None, fused: bool = False
) -> Integrator:
    """Coerce a tableau name / Tableau / Integrator / HyperSolver-like
    object (anything with .tableau/.g/.fused) into an Integrator."""
    if isinstance(solver, Integrator):
        return solver
    if isinstance(solver, str):
        return Integrator(tableau=get_tableau(solver), g=g, fused=fused)
    if isinstance(solver, Tableau):
        return Integrator(tableau=solver, g=g, fused=fused)
    if hasattr(solver, "tableau"):
        return Integrator(tableau=solver.tableau,
                          g=getattr(solver, "g", g),
                          fused=getattr(solver, "fused", fused))
    raise TypeError(f"cannot build an Integrator from {solver!r}")
