"""Adaptive-step Dormand-Prince 5(4) — the paper's ground-truth solver.

Used to generate "exact" solution checkpoints z(s_k) at mesh points for
hypersolver training (paper Sec. 3.2: "practically obtained through an
adaptive-step solver set up with low tolerances").

The embedded-error machinery is NOT private to this module: one step of
the pair, the error ratio, and the safety-clamped step factor live in
``core/controllers.py`` (``embedded_step`` / ``error_ratio`` /
``step_factor``) and are shared with the serving-time
``EmbeddedErrorController`` — ``odeint_dopri5`` is simply the DOPRI5
accept/reject instance of that code path, run per mesh segment under
``lax.while_loop``. ``odeint_dopri5_batched`` vmaps the whole solve over
a leading batch axis so every sample adapts its own step sequence (and
reports its own NFE) in one compiled call — the batched ground-truth path
for multi-rate serving targets.

Not differentiated through (trainers ``stop_gradient`` its outputs,
matching the paper's ``.detach()``).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.controllers import embedded_step, error_ratio, step_factor
from repro.core.solvers import FixedGrid, Pytree, VectorField, with_initial
from repro.core.tableaus import DOPRI5


class _SegState(NamedTuple):
    s: jnp.ndarray
    z: Any
    eps: jnp.ndarray
    nfe: jnp.ndarray


def _dopri5_stages(f: VectorField, s, eps, z):
    """(z5, err): one DOPRI5 pair step via the shared embedded-error path."""
    z5, err, _ = embedded_step(f, DOPRI5, s, eps, z)
    return z5, err


def _integrate_segment(f, z0, s0, s1, eps0, atol, rtol, max_steps):
    """Adaptively integrate from s0 to s1, returning (z(s1), last_eps, nfe)."""

    def cond(st: _SegState):
        return (st.s < s1 - 1e-12) & (st.nfe < max_steps * 6)

    def body(st: _SegState):
        eps = jnp.minimum(st.eps, s1 - st.s)
        z_new, err = _dopri5_stages(f, st.s, eps, st.z)
        ratio = error_ratio(st.z, z_new, err, atol, rtol)
        accept = ratio <= 1.0
        new_eps = jnp.clip(eps * step_factor(ratio, DOPRI5.order),
                           1e-8, s1 - s0)
        z_out = jax.tree_util.tree_map(
            lambda a, b: jnp.where(accept, a, b), z_new, st.z
        )
        s_out = jnp.where(accept, st.s + eps, st.s)
        return _SegState(s=s_out, z=z_out, eps=new_eps, nfe=st.nfe + 6)

    init = _SegState(
        s=jnp.asarray(s0, jnp.float32),
        z=z0,
        eps=jnp.asarray(eps0, jnp.float32),
        nfe=jnp.asarray(0, jnp.int32),
    )
    out = jax.lax.while_loop(cond, body, init)
    return out.z, out.eps, out.nfe


def odeint_dopri5(
    f: VectorField,
    z0: Pytree,
    grid: FixedGrid,
    atol: float = 1e-5,
    rtol: float = 1e-5,
    max_steps_per_segment: int = 1000,
):
    """Solve the IVP, emitting the solution at every mesh point of ``grid``.

    Returns (trajectory with leading axis K+1, total NFE). The trajectory is
    the hypersolver training target {(s_k, z(s_k))} of paper Sec. 3.2.
    """

    def seg(carry, s_pair):
        z, eps = carry
        s_a, s_b = s_pair
        z_b, eps_out, nfe = _integrate_segment(
            f, z, s_a, s_b, eps, atol, rtol, max_steps_per_segment
        )
        return (z_b, eps_out), (z_b, nfe)

    s_span = grid.s_span
    pairs = jnp.stack([s_span[:-1], s_span[1:]], axis=1)
    (_, _), (traj, nfes) = jax.lax.scan(
        seg, (z0, jnp.asarray(grid.eps, jnp.float32)), pairs
    )
    return with_initial(z0, traj), jnp.sum(nfes)


def odeint_dopri5_batched(
    f: VectorField,
    z0: Pytree,
    grid: FixedGrid,
    atol: float = 1e-5,
    rtol: float = 1e-5,
    max_steps_per_segment: int = 1000,
):
    """``odeint_dopri5`` vmapped over a leading batch axis of ``z0``.

    Each sample runs its OWN accept/reject step sequence (the while_loop is
    masked under vmap, not lock-stepped), so stiff rows take more internal
    steps than easy rows — and the returned per-sample NFE vector exposes
    exactly that, the signal multi-rate serving buckets on.

    ``f`` is called with per-sample (unbatched) states, as under
    ``jax.vmap``. Returns (trajectory with leading axes (B, K+1), nfe (B,)).
    """

    def solve_one(z0_i):
        return odeint_dopri5(f, z0_i, grid, atol=atol, rtol=rtol,
                             max_steps_per_segment=max_steps_per_segment)

    return jax.vmap(solve_one)(z0)
