"""Adaptive-step Dormand-Prince 5(4) — the paper's ground-truth solver.

Used to generate "exact" solution checkpoints z(s_k) at mesh points for
hypersolver training (paper Sec. 3.2: "practically obtained through an
adaptive-step solver set up with low tolerances"). Implemented with
``lax.while_loop`` per mesh segment; not differentiated through (trainers
``stop_gradient`` its outputs, matching the paper's ``.detach()``).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.solvers import FixedGrid, Pytree, VectorField, tree_axpy, tree_lincomb
from repro.core.tableaus import DOPRI5

_SAFETY = 0.9
_MIN_FACTOR = 0.2
_MAX_FACTOR = 5.0


def _flat_rms(tree: Pytree) -> jnp.ndarray:
    leaves = [jnp.mean(l.astype(jnp.float32) ** 2) for l in jax.tree_util.tree_leaves(tree)]
    n = len(leaves)
    return jnp.sqrt(sum(leaves) / n)


def _error_ratio(z, z_new, err, atol, rtol):
    def leafwise(zl, znl, el):
        tol = atol + rtol * jnp.maximum(jnp.abs(zl), jnp.abs(znl))
        return jnp.mean((el.astype(jnp.float32) / tol.astype(jnp.float32)) ** 2)

    parts = jax.tree_util.tree_leaves(jax.tree_util.tree_map(leafwise, z, z_new, err))
    return jnp.sqrt(sum(parts) / len(parts))


class _SegState(NamedTuple):
    s: jnp.ndarray
    z: Any
    eps: jnp.ndarray
    nfe: jnp.ndarray


def _dopri5_stages(f: VectorField, s, eps, z):
    tab = DOPRI5
    stages = []
    for i in range(tab.stages):
        if i == 0:
            zi = z
        else:
            zi = tree_axpy(eps, tree_lincomb(tab.a[i], stages), z)
        stages.append(f(s + tab.c[i] * eps, zi))
    z5 = tree_axpy(eps, tree_lincomb(tab.b, stages), z)
    err_w = tuple(b - be for b, be in zip(tab.b, tab.b_err))
    err = jax.tree_util.tree_map(lambda l: eps * l, tree_lincomb(err_w, stages))
    return z5, err


def _integrate_segment(f, z0, s0, s1, eps0, atol, rtol, max_steps):
    """Adaptively integrate from s0 to s1, returning (z(s1), last_eps, nfe)."""

    def cond(st: _SegState):
        return (st.s < s1 - 1e-12) & (st.nfe < max_steps * 6)

    def body(st: _SegState):
        eps = jnp.minimum(st.eps, s1 - st.s)
        z_new, err = _dopri5_stages(f, st.s, eps, st.z)
        ratio = _error_ratio(st.z, z_new, err, atol, rtol)
        accept = ratio <= 1.0
        factor = jnp.clip(
            _SAFETY * (jnp.maximum(ratio, 1e-10) ** -0.2), _MIN_FACTOR, _MAX_FACTOR
        )
        new_eps = jnp.clip(eps * factor, 1e-8, s1 - s0)
        z_out = jax.tree_util.tree_map(
            lambda a, b: jnp.where(accept, a, b), z_new, st.z
        )
        s_out = jnp.where(accept, st.s + eps, st.s)
        return _SegState(s=s_out, z=z_out, eps=new_eps, nfe=st.nfe + 6)

    init = _SegState(
        s=jnp.asarray(s0, jnp.float32),
        z=z0,
        eps=jnp.asarray(eps0, jnp.float32),
        nfe=jnp.asarray(0, jnp.int32),
    )
    out = jax.lax.while_loop(cond, body, init)
    return out.z, out.eps, out.nfe


def odeint_dopri5(
    f: VectorField,
    z0: Pytree,
    grid: FixedGrid,
    atol: float = 1e-5,
    rtol: float = 1e-5,
    max_steps_per_segment: int = 1000,
):
    """Solve the IVP, emitting the solution at every mesh point of ``grid``.

    Returns (trajectory with leading axis K+1, total NFE). The trajectory is
    the hypersolver training target {(s_k, z(s_k))} of paper Sec. 3.2.
    """

    def seg(carry, s_pair):
        z, eps = carry
        s_a, s_b = s_pair
        z_b, eps_out, nfe = _integrate_segment(
            f, z, s_a, s_b, eps, atol, rtol, max_steps_per_segment
        )
        return (z_b, eps_out), (z_b, nfe)

    s_span = grid.s_span
    pairs = jnp.stack([s_span[:-1], s_span[1:]], axis=1)
    (_, _), (traj, nfes) = jax.lax.scan(
        seg, (z0, jnp.asarray(grid.eps, jnp.float32)), pairs
    )
    full = jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a[None], b], axis=0), z0, traj
    )
    return full, jnp.sum(nfes)
