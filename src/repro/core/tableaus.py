"""Butcher tableaus for explicit Runge-Kutta methods.

A tableau fully characterizes an explicit RK method (paper Eq. 3 / Fig. 5):

    r_i = f(s_k + c_i eps, z_k + eps * sum_j a_ij r_j)      j < i
    psi = sum_j b_j r_j

``order`` is the classical order p of the method; the hypersolver correction
term is scaled by eps^{p+1} (paper Eq. 5).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Tableau:
    name: str
    a: Tuple[Tuple[float, ...], ...]  # strictly lower-triangular stage matrix
    b: Tuple[float, ...]
    c: Tuple[float, ...]
    order: int
    # Embedded lower-order weights for adaptive methods (None for fixed-step).
    b_err: Tuple[float, ...] | None = None

    @property
    def stages(self) -> int:
        return len(self.b)

    def validate(self) -> None:
        """Consistency conditions: sum(b) == 1 and c_i == sum_j a_ij."""
        assert abs(sum(self.b) - 1.0) < 1e-12, self.name
        for i in range(self.stages):
            row = self.a[i]
            assert len(row) == i, (self.name, i)
            assert abs(self.c[i] - sum(row)) < 1e-12, (self.name, i)


EULER = Tableau(name="euler", a=((),), b=(1.0,), c=(0.0,), order=1)

MIDPOINT = Tableau(
    name="midpoint", a=((), (0.5,)), b=(0.0, 1.0), c=(0.0, 0.5), order=2
)

# b_err = plain Euler: the classical Heun-Euler 2(1) embedded pair — the
# cheapest embedded local-error estimate (2 NFEs), used by the serving
# probe (core/controllers.py::EmbeddedErrorController).
HEUN = Tableau(name="heun", a=((), (1.0,)), b=(0.5, 0.5), c=(0.0, 1.0),
               order=2, b_err=(1.0, 0.0))

RALSTON = Tableau(
    name="ralston",
    a=((), (2.0 / 3.0,)),
    b=(0.25, 0.75),
    c=(0.0, 2.0 / 3.0),
    order=2,
)

RK4 = Tableau(
    name="rk4",
    a=((), (0.5,), (0.0, 0.5), (0.0, 0.0, 1.0)),
    b=(1.0 / 6.0, 1.0 / 3.0, 1.0 / 3.0, 1.0 / 6.0),
    c=(0.0, 0.5, 0.5, 1.0),
    order=4,
)

RK38 = Tableau(
    name="rk38",
    a=((), (1.0 / 3.0,), (-1.0 / 3.0, 1.0), (1.0, -1.0, 1.0)),
    b=(1.0 / 8.0, 3.0 / 8.0, 3.0 / 8.0, 1.0 / 8.0),
    c=(0.0, 1.0 / 3.0, 2.0 / 3.0, 1.0),
    order=4,
)

RK3_KUTTA = Tableau(
    name="rk3",
    a=((), (0.5,), (-1.0, 2.0)),
    b=(1.0 / 6.0, 2.0 / 3.0, 1.0 / 6.0),
    c=(0.0, 0.5, 1.0),
    order=3,
)

# Dormand-Prince 5(4): the paper's ground-truth/reference solver (dopri5).
DOPRI5 = Tableau(
    name="dopri5",
    a=(
        (),
        (1.0 / 5.0,),
        (3.0 / 40.0, 9.0 / 40.0),
        (44.0 / 45.0, -56.0 / 15.0, 32.0 / 9.0),
        (19372.0 / 6561.0, -25360.0 / 2187.0, 64448.0 / 6561.0, -212.0 / 729.0),
        (9017.0 / 3168.0, -355.0 / 33.0, 46732.0 / 5247.0, 49.0 / 176.0,
         -5103.0 / 18656.0),
        (35.0 / 384.0, 0.0, 500.0 / 1113.0, 125.0 / 192.0, -2187.0 / 6784.0,
         11.0 / 84.0),
    ),
    b=(35.0 / 384.0, 0.0, 500.0 / 1113.0, 125.0 / 192.0, -2187.0 / 6784.0,
       11.0 / 84.0, 0.0),
    b_err=(5179.0 / 57600.0, 0.0, 7571.0 / 16695.0, 393.0 / 640.0,
           -92097.0 / 339200.0, 187.0 / 2100.0, 1.0 / 40.0),
    c=(0.0, 1.0 / 5.0, 3.0 / 10.0, 4.0 / 5.0, 8.0 / 9.0, 1.0, 1.0),
    order=5,
)


def alpha_family(alpha: float) -> Tableau:
    """General 2nd-order explicit family (paper Fig. 5 right).

    c = (0, alpha); a21 = alpha; b = (1 - 1/(2 alpha), 1/(2 alpha)).
    alpha = 0.5 recovers midpoint, alpha = 1.0 recovers Heun,
    alpha = 2/3 recovers Ralston.
    """
    if alpha <= 0:
        raise ValueError("alpha must be positive")
    inv = 1.0 / (2.0 * alpha)
    return Tableau(
        name=f"alpha_{alpha:g}",
        a=((), (float(alpha),)),
        b=(1.0 - inv, inv),
        c=(0.0, float(alpha)),
        order=2,
    )


REGISTRY = {
    t.name: t
    for t in (EULER, MIDPOINT, HEUN, RALSTON, RK3_KUTTA, RK4, RK38, DOPRI5)
}


def get(name: str) -> Tableau:
    if name.startswith("alpha_"):
        return alpha_family(float(name.split("_", 1)[1]))
    if name not in REGISTRY:
        raise KeyError(f"unknown tableau {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def _selfcheck() -> None:
    for t in REGISTRY.values():
        t.validate()
    for al in np.linspace(0.1, 1.0, 7):
        alpha_family(float(al)).validate()


_selfcheck()
