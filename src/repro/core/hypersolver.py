"""Hypersolvers (paper Sec. 3): base explicit solver + learned residual net.

General p-th order Hypersolved update (paper Eq. 5):

    z_{k+1} = z_k + eps * psi(s_k, x, z_k) + eps^{p+1} * g_omega(eps, s_k, x, z_k)

``HyperSolver`` is a thin alias over the unified ``Integrator`` engine
(core/integrate.py) kept for paper-facing call sites: ``g_omega`` is any
callable ``g(eps, s, z, dz) -> pytree like z`` where ``dz = f(s, z)`` is
the first RK stage — passed in for free reuse, matching the paper's
reference implementation which feeds ``g`` the concatenation
``[z, dx, ds]``. Conditioning inputs ``x`` are closed over inside both
``f`` and ``g`` (as in paper Eq. 1).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.integrate import Correction, Integrator, Pytree, VectorField
from repro.core.solvers import FixedGrid
from repro.core.tableaus import get as get_tableau


@dataclasses.dataclass(frozen=True)
class HyperSolver(Integrator):
    """A base tableau paired with a correction network of matching order.

    ``fused=True`` routes the whole update — b-weighted stage combination
    plus correction plus the multi-rate freeze mask — through the Pallas
    fused_rk_update kernel (kernels/hyper_step): one read/write of the
    state per step instead of ``stages + 3``, for ANY step-size pattern
    (eps is a runtime scalar-prefetch operand) — the update itself is
    memory-bound, so the fusion is the whole win on TPU (interpret-mode
    on CPU)."""

    def odeint(self, f: VectorField, z0: Pytree, grid: FixedGrid,
               return_traj: bool = True):
        """Integrate with the unified engine over the fixed mesh."""
        return self.solve(f, z0, grid, return_traj=return_traj)


def make(base: str, g: Optional[Correction] = None) -> HyperSolver:
    return HyperSolver(tableau=get_tableau(base), g=g)
