"""Hypersolvers (paper Sec. 3): base explicit solver + learned residual net.

General p-th order Hypersolved update (paper Eq. 5):

    z_{k+1} = z_k + eps * psi(s_k, x, z_k) + eps^{p+1} * g_omega(eps, s_k, x, z_k)

``g_omega`` here is any callable ``g(eps, s, z, dz) -> pytree like z`` where
``dz = f(s, z)`` is the first RK stage — passed in for free reuse, matching
the paper's reference implementation which feeds ``g`` the concatenation
``[z, dx, ds]``. Conditioning inputs ``x`` are closed over inside both ``f``
and ``g`` (as in paper Eq. 1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.solvers import (
    FixedGrid,
    Pytree,
    VectorField,
    rk_psi,
    tree_axpy,
)
from repro.core.tableaus import Tableau, get as get_tableau

Correction = Callable[[jnp.ndarray, jnp.ndarray, Pytree, Pytree], Pytree]


@dataclasses.dataclass(frozen=True)
class HyperSolver:
    """A base tableau paired with a correction network of matching order.

    ``fused=True`` routes the update z + eps*psi + eps^{p+1}*g through the
    Pallas hyper_step kernel (kernels/hyper_step): one read/write of the
    state instead of three — the update itself is memory-bound, so the
    fusion is the whole win on TPU (interpret-mode on CPU)."""

    tableau: Tableau
    g: Optional[Correction]  # None => plain base solver (g == 0)
    fused: bool = False

    @property
    def order(self) -> int:
        return self.tableau.order

    @property
    def name(self) -> str:
        base = self.tableau.name
        return f"hyper_{base}" if self.g is not None else base

    def with_tableau(self, tab: Tableau) -> "HyperSolver":
        """Swap the base solver, keeping g (paper Sec. 4.1 alpha-family
        generalization: a HyperMidpoint evaluated under other 2nd-order
        tableaus without finetuning)."""
        return dataclasses.replace(self, tableau=tab)

    def step(self, f: VectorField, s, eps, z: Pytree):
        """One hypersolved step; returns (z_next, psi, dz)."""
        psi, stages = rk_psi(f, self.tableau, s, eps, z)
        dz = stages[0]
        if self.g is not None:
            corr = self.g(eps, s, z, dz)
            if self.fused:
                from repro.kernels.hyper_step.ops import hyper_step
                z_next = jax.tree_util.tree_map(
                    lambda zz, pp, gg: hyper_step(zz, pp, gg, float(eps),
                                                  self.order),
                    z, psi, corr)
            else:
                z_next = tree_axpy(eps, psi, z)
                z_next = tree_axpy(eps ** (self.order + 1), corr, z_next)
        else:
            z_next = tree_axpy(eps, psi, z)
        return z_next, psi, dz

    def odeint(self, f: VectorField, z0: Pytree, grid: FixedGrid,
               return_traj: bool = True):
        """Integrate with lax.scan over the fixed mesh."""

        def body(z, s):
            z_next, _, _ = self.step(f, s, grid.eps, z)
            return z_next, (z_next if return_traj else None)

        s_knots = grid.s0 + grid.eps * jnp.arange(grid.K)
        zT, ys = jax.lax.scan(body, z0, s_knots)
        if not return_traj:
            return zT
        return jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a[None], b], axis=0), z0, ys
        )

    def nfe(self, K: int) -> int:
        """Vector-field evaluations for K steps — O(pK), the g_omega
        evaluation is counted separately as overhead (paper Sec. 6)."""
        return self.tableau.stages * K


def make(base: str, g: Optional[Correction] = None) -> HyperSolver:
    return HyperSolver(tableau=get_tableau(base), g=g)
