"""PaliGemma-3B [arXiv:2407.07726; hf:google/paligemma-3b].

Gemma-2B LM decoder backbone: 18L, d_model 2048, 8 heads (MQA kv=1,
d_head 256), GeGLU d_ff 16384, vocab 257216, embeddings scaled by sqrt(d)
and tied. SigLIP vision frontend is a STUB: input_specs() provides
precomputed patch embeddings (B, 256, d_model).
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="paligemma_3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv=1,
    d_head=256,
    d_ff=16384,
    vocab=257216,
    act="gelu",
    gated_ffn=True,
    rope_theta=1e4,
    tie_embeddings=True,
    embed_scale=True,
    frontend="patches",
    n_frontend_tokens=256,
    source="arXiv:2407.07726",
)
