"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427; hf:google/recurrentgemma-2b].

26L, d_model 2560, pattern (RG-LRU, RG-LRU, local-attn) — 1 attention per
2 recurrent blocks; MQA 10 heads kv=1 d_head 256, local window 2048,
GeGLU d_ff 7680, lru_width 2560, vocab 256000, embeddings scaled.
Sub-quadratic: runs the long_500k cell.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma_2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv=1,
    d_head=256,
    d_ff=7680,
    vocab=256000,
    act="gelu",
    gated_ffn=True,
    rope_theta=1e4,
    tie_embeddings=True,
    embed_scale=True,
    lru_width=2560,
    pattern_attn_every=3,
    local_window=2048,
    source="arXiv:2402.19427",
)
