"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L, d_model 5120, 40 heads (GQA kv=8), dense d_ff 8192 (x2 interleave),
MoE 128 experts top-1 + shared expert on alternating layers (interleaved
dense/MoE gives ~400B total / ~17B active — see DESIGN.md provenance note).
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="llama4_maverick_400b_a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv=8,
    d_head=128,
    d_ff=8192,
    vocab=202048,
    act="silu",
    gated_ffn=True,
    rope_theta=5e5,
    n_experts=128,
    top_k=1,
    moe_every=2,
    shared_expert=True,
    d_ff_expert=8192,
    capacity_factor=1.25,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (dims per assignment)",
)
