"""Architecture + shape configuration registry.

Every assigned architecture has a ``src/repro/configs/<id>.py`` exporting
``CONFIG`` with the exact public dimensions; ``get(name)`` loads it.
``SHAPES`` carries the assigned input-shape set (same for all LM archs).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    d_ff: int
    vocab: int
    # attention / ffn details
    act: str = "silu"
    gated_ffn: bool = True
    qk_norm: bool = False
    rope_theta: float = 1e4
    window: Optional[int] = None
    tie_embeddings: bool = False
    embed_scale: bool = False          # gemma: embed * sqrt(d)
    pos: str = "rope"                  # rope | learned | none
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1                 # MoE block every N layers (llama4: 2)
    shared_expert: bool = False
    d_ff_expert: Optional[int] = None
    capacity_factor: float = 1.25
    # RWKV
    rwkv_heads: int = 0
    lora_rank: int = 32
    # Griffin / recurrentgemma
    lru_width: int = 0
    pattern_attn_every: int = 0        # 3 => [rec, rec, attn] repeating
    local_window: int = 2048
    # encoder-decoder (whisper)
    enc_layers: int = 0
    dec_layers: int = 0
    max_target_len: int = 512
    # modality frontend stub
    frontend: Optional[str] = None     # patches | frames
    n_frontend_tokens: int = 0
    # dtypes
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # notes for DESIGN.md provenance
    source: str = ""

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports O(S) or O(window) decode at 500k ctx."""
        return self.family in ("ssm", "hybrid")

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    def reduced(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        pat = self.pattern_attn_every
        n_layers = (2 * pat if pat else (4 if self.moe_every > 1 else 2))
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv=min(self.n_kv, 2) if self.n_kv < self.n_heads else 4,
            d_head=16,
            d_ff=128,
            d_ff_expert=32 if self.n_experts else None,
            vocab=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            rwkv_heads=4 if self.rwkv_heads else 0,
            lora_rank=4,
            lru_width=64 if self.lru_width else 0,
            local_window=8 if self.pattern_attn_every else 2048,
            window=self.window and 8,
            enc_layers=2 if self.enc_layers else 0,
            dec_layers=2 if self.dec_layers else 0,
            max_target_len=16,
            n_frontend_tokens=8 if self.frontend else 0,
            dtype="float32",
            param_dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

ARCH_IDS = (
    "llama4_maverick_400b_a17b",
    "olmoe_1b_7b",
    "nemotron_4_340b",
    "qwen3_4b",
    "qwen3_8b",
    "mistral_nemo_12b",
    "paligemma_3b",
    "rwkv6_1p6b",
    "recurrentgemma_2b",
    "whisper_base",
)

_ALIASES = {
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "nemotron-4-340b": "nemotron_4_340b",
    "qwen3-4b": "qwen3_4b",
    "qwen3-8b": "qwen3_8b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "paligemma-3b": "paligemma_3b",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "whisper-base": "whisper_base",
}


def get(name: str) -> ArchConfig:
    mod_name = _ALIASES.get(name, name.replace("-", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def cell_is_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether an (arch x shape) dry-run cell runs, with skip reason."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full softmax attention is O(S^2); 512k-token KV "
                       "exceeds HBM — documented skip (DESIGN.md §5)")
    if shape.kind == "decode" and cfg.family == "audio" \
            and shape.name == "long_500k":
        return False, "whisper encoder is full-attention"
    return True, ""
