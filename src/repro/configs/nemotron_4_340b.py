"""Nemotron-4 340B [arXiv:2402.16819; unverified].

96L, d_model 18432, 96 heads (GQA kv=8), d_ff 73728 with squared-ReLU
(non-gated), vocab 256000.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="nemotron_4_340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv=8,
    d_head=192,
    d_ff=73728,
    vocab=256000,
    act="relu2",
    gated_ffn=False,
    rope_theta=1e4,
    source="arXiv:2402.16819",
)
