"""Mistral-Nemo 12B [hf:mistralai/Mistral-Nemo-Base-2407].

40L, d_model 5120, 32 heads (GQA kv=8, d_head 128), d_ff 14336,
128k context (rope theta 1e6), vocab 131072 (Tekken).
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="mistral_nemo_12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv=8,
    d_head=128,
    d_ff=14336,
    vocab=131072,
    act="silu",
    gated_ffn=True,
    rope_theta=1e6,
    source="hf:mistralai/Mistral-Nemo-Base-2407",
)
