"""Qwen3-8B [hf:Qwen/Qwen3-8B].

36L, d_model 4096, 32 heads (GQA kv=8, d_head 128), d_ff 12288, qk-norm.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="qwen3_8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_head=128,
    d_ff=12288,
    vocab=151936,
    act="silu",
    gated_ffn=True,
    qk_norm=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B",
)
