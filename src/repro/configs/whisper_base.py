"""Whisper-base [arXiv:2212.04356; unverified].

Encoder-decoder, 6L each, d_model 512, 8 heads (MHA), d_ff 2048 (GELU),
vocab 51865, learned positions. Conv/mel frontend is a STUB: input_specs()
provides precomputed frame embeddings (B, T, d_model).
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="whisper_base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv=8,
    d_head=64,
    d_ff=2048,
    vocab=51865,
    act="gelu",
    gated_ffn=False,
    pos="learned",
    enc_layers=6,
    dec_layers=6,
    max_target_len=512,
    frontend="frames",
    source="arXiv:2212.04356",
)
