"""Qwen3-4B [hf:Qwen/Qwen3-4B].

36L, d_model 2560, 32 heads (GQA kv=8, d_head 128), d_ff 9728, qk-norm,
tied embeddings, vocab 151936.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="qwen3_4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv=8,
    d_head=128,
    d_ff=9728,
    vocab=151936,
    act="silu",
    gated_ffn=True,
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
    source="hf:Qwen/Qwen3-4B",
)
