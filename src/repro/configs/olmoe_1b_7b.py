"""OLMoE-1B-7B [arXiv:2409.02060; hf:allenai/OLMoE-1B-7B].

16L, d_model 2048, 16 heads (kv=16, i.e. MHA), 64 experts top-8 with
per-expert d_ff 1024, qk-norm, every layer MoE.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="olmoe_1b_7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_head=128,
    d_ff=1024,
    vocab=50304,
    act="silu",
    gated_ffn=True,
    qk_norm=True,
    rope_theta=1e4,
    n_experts=64,
    top_k=8,
    moe_every=1,
    d_ff_expert=1024,
    capacity_factor=1.25,
    source="arXiv:2409.02060",
)
