"""RWKV-6 'Finch' 1.6B [arXiv:2404.05892; unverified].

24L, d_model 2048, attention-free (32 WKV heads of dim 64), channel-mix
d_ff 7168, vocab 65536. Sub-quadratic: runs the long_500k cell.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6_1p6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,        # WKV heads (d_head 64)
    n_kv=32,
    d_head=64,
    d_ff=7168,
    vocab=65536,
    act="relu2",
    gated_ffn=False,
    pos="none",
    rwkv_heads=32,
    lora_rank=32,
    source="arXiv:2404.05892",
)
