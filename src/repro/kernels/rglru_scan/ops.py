"""Public RG-LRU scan wrapper: padding (a=1, b=0 pass-through) + interpret
auto-detect."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import on_cpu
from repro.kernels.rglru_scan.rglru_scan import BW, CHUNK, rglru_scan_btw


@partial(jax.jit, static_argnames=("chunk", "bw", "interpret"))
def rglru_scan(a: jnp.ndarray, b: jnp.ndarray, *, chunk: int = CHUNK,
               bw: int = BW, interpret: bool | None = None):
    """a, b: (B, T, W) -> fp32 (B, T, W) recurrence outputs."""
    interpret = on_cpu() if interpret is None else interpret
    B, T, W = a.shape
    T_pad = (-(-T // chunk)) * chunk
    W_pad = (-(-W // bw)) * bw
    ap = jnp.pad(a, ((0, 0), (0, T_pad - T), (0, W_pad - W)),
                 constant_values=1.0)
    bp = jnp.pad(b, ((0, 0), (0, T_pad - T), (0, W_pad - W)))
    h = rglru_scan_btw(ap, bp, chunk=chunk, bw=bw, interpret=interpret)
    return h[:, :T, :W]
