"""Chunked gated-linear-recurrence kernel (RG-LRU inner scan):

    h_t = a_t * h_{t-1} + b_t        (elementwise over width)

Grid: (B, W/bw parallel, T/CHUNK sequential); fp32 carry (1, bw) in VMEM
scratch across the chunk axis. The unrolled-by-8 inner loop gives the VPU
longer dependency-free runs per 128-lane vector (the recurrence itself is
a strict serial chain per lane — the parallelism is the 128-wide lane
axis and the (B, W/bw) grid, NOT time; see DESIGN.md §3 for why the
associative-scan form is used at training time and this kernel at
long-context decode/prefill time where its O(1) state memory wins).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 256
BW = 128


def _kernel(a_ref, b_ref, o_ref, h_ref, *, chunk: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0].astype(jnp.float32)   # (chunk, bw)
    b = b_ref[0].astype(jnp.float32)

    def step(t, carry):
        h, out = carry
        a_t = jax.lax.dynamic_slice_in_dim(a, t, 1, 0)
        b_t = jax.lax.dynamic_slice_in_dim(b, t, 1, 0)
        h = a_t * h + b_t
        out = jax.lax.dynamic_update_slice_in_dim(out, h, t, 0)
        return h, out

    h0 = h_ref[...]
    out0 = jnp.zeros_like(a)
    h_fin, out = jax.lax.fori_loop(0, chunk, step, (h0, out0))
    h_ref[...] = h_fin
    o_ref[0] = out.astype(o_ref.dtype)


def rglru_scan_btw(a: jnp.ndarray, b: jnp.ndarray, *, chunk: int = CHUNK,
                   bw: int = BW, interpret: bool = False):
    """a, b: (B, T, W) -> h: (B, T, W) fp32. T % chunk == 0, W % bw == 0."""
    B, T, W = a.shape
    assert T % chunk == 0 and W % bw == 0, (T, W)
    grid = (B, W // bw, T // chunk)
    spec = pl.BlockSpec((1, chunk, bw), lambda i, j, c: (i, c, j))
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, T, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
        if not interpret else None,
        interpret=interpret,
    )(a, b)
