"""Oracle: sequential linear recurrence h_t = a_t h_{t-1} + b_t."""
import jax
import jax.numpy as jnp


def rglru_scan_ref(a, b):
    """a, b: (B, T, W) -> (B, T, W) fp32."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)

    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h

    h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    _, hs = jax.lax.scan(step, h0, (jnp.moveaxis(a, 1, 0),
                                    jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)
