"""Pallas TPU kernels for the compute hot-spots (DESIGN.md §3).

Each kernel directory holds:
  <name>.py — pl.pallas_call + BlockSpec VMEM tiling (the TPU target)
  ops.py    — jit'd public wrapper (auto-interpret on CPU)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels: hyper_step (fused hypersolver update — the paper's Eq. 5 inner
loop), flash_attention (blocked causal GQA), rwkv6_scan (chunked WKV6
recurrence), rglru_scan (chunked gated linear recurrence).
"""


def on_cpu() -> bool:
    import jax
    return jax.default_backend() == "cpu"
