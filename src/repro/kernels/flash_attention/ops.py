"""Public flash-attention wrapper: (B, S, H, hd) layout in/out (matching
nn/attention.py), sequence padding to block multiples, CPU auto-interpret.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import on_cpu
from repro.kernels.flash_attention.flash_attention import (
    DEFAULT_BK, DEFAULT_BQ, flash_attention_bhsd,
)


@partial(jax.jit,
         static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int | None = None,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool | None = None) -> jnp.ndarray:
    """q: (B, S, H, hd); k, v: (B, S, KV, hd) -> (B, S, H, hd)."""
    interpret = on_cpu() if interpret is None else interpret
    B, S, H, hd = q.shape
    blk = max(bq, bk)
    S_pad = -(-S // blk) * blk
    pad = S_pad - S

    def prep(x):  # (B, S, n, hd) -> (B, n, S_pad, hd)
        x = jnp.moveaxis(x, 1, 2)
        if pad:
            x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return x

    o = flash_attention_bhsd(prep(q), prep(k), prep(v), causal=causal,
                             window=window, bq=bq, bk=bk, seq_k=S,
                             interpret=interpret)
    o = jnp.moveaxis(o, 1, 2)
    return o[:, :S] if pad else o
