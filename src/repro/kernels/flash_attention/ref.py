"""Dense softmax-attention oracle (fp32) for the flash kernel."""
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=None):
    """q: (B, H, S, hd); k, v: (B, KV, S, hd)."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    group = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, group, S, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bngsh,bnth->bngst", qf, kf) * hd ** -0.5
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    ok = jnp.ones((S, S), bool)
    if causal:
        ok = ok & (k_pos <= q_pos)
    if window is not None:
        ok = ok & (q_pos - k_pos < window)
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngst,bnth->bngsh", p, vf)
    return o.reshape(B, H, S, hd).astype(q.dtype)
