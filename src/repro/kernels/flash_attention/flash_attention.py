"""Blocked causal GQA attention with online softmax (FlashAttention
adapted to the TPU memory hierarchy).

Grid: (batch*kv_head, q_group, nq) — one program per (bh pair, q block);
the kv loop runs inside the kernel over ``pl.ds`` dynamic slices of the
kv panel resident in VMEM. Blocks are MXU-aligned (bq = bk = 128,
d_head <= 256 lanes). Online softmax carries (m, l, acc) in fp32.

Causality: kv blocks strictly above the diagonal are never visited — the
fori upper bound is derived from the q block index — so the kernel does
~(S/bk)^2/2 block-dots instead of masking a dense S^2. This is the same
2x win FlashAttention gets on GPU, realized through the loop bound rather
than warp predication (HARDWARE ADAPTATION, DESIGN.md §3). A sliding
window additionally raises the loop LOWER bound, making local attention
O(S * window).

VMEM budget: the kv panel is (S, d_head) per program — fine to S ~ 8k at
d_head 128; longer sequences run under a sequence-sharded layout (SP)
where the per-shard S stays bounded.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def flash_attention_bhsd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         *, causal: bool = True, window: int | None = None,
                         bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                         seq_k: int | None = None,
                         interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, S, hd); k, v: (B, KV, S, hd) with H % KV == 0.
    Returns (B, H, S, hd). S must divide by the block sizes (ops.py pads).
    """
    B, H, S, hd = q.shape
    KV = k.shape[1]
    assert H % KV == 0 and S % bq == 0 and S % bk == 0, (H, KV, S, bq, bk)
    group = H // KV
    scale = hd ** -0.5
    seq_k = S if seq_k is None else seq_k  # true (unpadded) kv length
    grid = (B * KV, group, S // bq)

    q_spec = pl.BlockSpec(
        (1, 1, bq, hd),
        lambda bh, g, i: (bh // KV, (bh % KV) * group + g, i, 0))
    kv_spec = pl.BlockSpec(
        (1, 1, S, hd), lambda bh, g, i: (bh // KV, bh % KV, 0, 0))

    def kernel(q_ref, k_ref, v_ref, o_ref):
        iq = pl.program_id(2)
        q_ = q_ref[0, 0].astype(jnp.float32) * scale     # (bq, hd)

        def body(j, carry):
            acc, m, l = carry
            kk = k_ref[0, 0, pl.ds(j * bk, bk), :].astype(jnp.float32)
            vv = v_ref[0, 0, pl.ds(j * bk, bk), :].astype(jnp.float32)
            s = jax.lax.dot_general(q_, kk, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
            q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            ok = k_pos < seq_k
            if causal:
                ok = ok & (k_pos <= q_pos)
            if window is not None:
                ok = ok & (q_pos - k_pos < window)
            s = jnp.where(ok, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[:, None])
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[:, None] + jax.lax.dot_general(
                p, vv, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            return acc_new, m_new, l_new

        hi = (jax.lax.div(iq * jnp.int32(bq) + jnp.int32(bq + bk - 1),
                          jnp.int32(bk))
              if causal else jnp.int32(S // bk))
        lo = (jnp.maximum(jnp.int32(0),
                          jax.lax.div(iq * jnp.int32(bq)
                                      - jnp.int32(window - 1),
                                      jnp.int32(bk)))
              if window is not None else jnp.int32(0))
        acc0 = jnp.zeros((bq, hd), jnp.float32)
        m0 = jnp.full((bq,), NEG_INF, jnp.float32)
        l0 = jnp.zeros((bq,), jnp.float32)
        acc, m, l = jax.lax.fori_loop(lo, hi, body, (acc0, m0, l0))
        o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(
            o_ref.dtype)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
