"""Pure-jnp oracle for the fused hypersolver update."""
import jax.numpy as jnp


def hyper_step_ref(z, psi, g, eps: float, order: int):
    z32 = z.astype(jnp.float32)
    out = z32 + eps * psi.astype(jnp.float32) \
        + (eps ** (order + 1)) * g.astype(jnp.float32)
    return out.astype(z.dtype)
