"""Pure-jnp oracles for the fused hypersolver update kernels."""
import jax.numpy as jnp


def hyper_step_ref(z, psi, g, eps: float, order: int):
    z32 = z.astype(jnp.float32)
    out = z32 + eps * psi.astype(jnp.float32) \
        + (eps ** (order + 1)) * g.astype(jnp.float32)
    return out.astype(z.dtype)


def fused_rk_update_ref(z, stages, g, eps: float, b, order: int):
    out = z.astype(jnp.float32)
    for bj, r in zip(b, stages):
        if bj != 0.0:
            out = out + (eps * bj) * r.astype(jnp.float32)
    if g is not None:
        out = out + (eps ** (order + 1)) * g.astype(jnp.float32)
    return out.astype(z.dtype)
