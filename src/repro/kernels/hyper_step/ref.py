"""Pure-jnp oracles for the fused hypersolver update kernels."""
import jax.numpy as jnp


def _coef(a, leaf):
    """Right-pad a scalar-or-(B,) coefficient to broadcast against leaf."""
    a = jnp.asarray(a, jnp.float32)
    if a.ndim:
        a = a.reshape(a.shape + (1,) * (leaf.ndim - a.ndim))
    return a


def hyper_step_ref(z, psi, g, eps, order: int):
    z32 = z.astype(jnp.float32)
    out = z32 + _coef(eps, z) * psi.astype(jnp.float32) \
        + (_coef(eps, z) ** (order + 1)) * g.astype(jnp.float32)
    return out.astype(z.dtype)


def fused_rk_update_ref(z, stages, g, eps, b, order: int, active=None):
    """Runtime-eps oracle: eps scalar or per-sample (B,) row; ``active`` an
    optional (B,) mask row freezing inactive samples at z."""
    z32 = z.astype(jnp.float32)
    out = z32
    e = _coef(eps, z)
    for bj, r in zip(b, stages):
        if bj != 0.0:
            out = out + (e * bj) * r.astype(jnp.float32)
    if g is not None:
        out = out + (e ** (order + 1)) * g.astype(jnp.float32)
    if active is not None:
        out = jnp.where(_coef(active, z) != 0, out, z32)
    return out.astype(z.dtype)
