"""Fused hypersolver update (paper Eq. 3 + Eq. 5):

    z_{k+1} = z_k + eps * sum_j b_j r_j + eps^{p+1} * g

One kernel pass fuses the b-weighted stage combination of ANY explicit
tableau with the eps^{p+1} correction: the state and each stage are read
once and the new state written once, instead of the ``stages + 2`` HBM
round-trips of the unfused leaf-wise adds. The update is purely
memory-bound, so this traffic reduction is the whole optimization on TPU
(interpret mode on CPU). Tiles are (ROWS, 128) fp32/bf16 VMEM blocks,
128-lane aligned for the VPU; accumulation is fp32 regardless of the
storage dtype.

``hyper_step_2d`` (the original final-axpy fusion, psi precombined) is the
single-stage special case b = (1.0,).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 256
LANES = 128


def _rk_kernel(*refs, eps: float, b: Tuple[float, ...], order: int,
               with_g: bool):
    """refs = (z, r_0..r_{S-1}, [g], out). Stage count is static, so the
    combination loop fully unrolls into VPU fma chains."""
    z_ref, o_ref = refs[0], refs[-1]
    stage_refs = refs[1:1 + len(b)]
    out = z_ref[...].astype(jnp.float32)
    for bj, r_ref in zip(b, stage_refs):
        if bj != 0.0:
            out += (eps * bj) * r_ref[...].astype(jnp.float32)
    if with_g:
        g_ref = refs[1 + len(b)]
        out += (eps ** (order + 1)) * g_ref[...].astype(jnp.float32)
    o_ref[...] = out.astype(o_ref.dtype)


def rk_update_2d(z: jnp.ndarray, stages: Sequence[jnp.ndarray],
                 g: Optional[jnp.ndarray], eps: float,
                 b: Tuple[float, ...], order: int,
                 interpret: bool = False):
    """z, stages[j], g: (N, 128k) 2-D views; returns z_next of z.dtype."""
    assert len(stages) == len(b), (len(stages), b)
    n, d = z.shape
    assert d % LANES == 0 and n % ROWS == 0, (n, d)
    grid = (n // ROWS, d // LANES)
    spec = pl.BlockSpec((ROWS, LANES), lambda i, j: (i, j))
    operands = [z, *stages] + ([g] if g is not None else [])
    return pl.pallas_call(
        functools.partial(_rk_kernel, eps=float(eps), b=tuple(b),
                          order=int(order), with_g=g is not None),
        grid=grid,
        in_specs=[spec] * len(operands),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(z.shape, z.dtype),
        interpret=interpret,
    )(*operands)


def hyper_step_2d(z: jnp.ndarray, psi: jnp.ndarray, g: jnp.ndarray,
                  eps: float, order: int, interpret: bool = False):
    """Single-stage case: z + eps*psi + eps^{p+1}*g (psi precombined)."""
    return rk_update_2d(z, (psi,), g, eps, (1.0,), order,
                        interpret=interpret)
