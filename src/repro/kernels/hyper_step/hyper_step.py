"""Fused hypersolver update (paper Eq. 5):

    z_{k+1} = z_k + eps * psi + eps^{p+1} * g

Three reads + one write of the residual stream instead of the 3x traffic
of unfused adds — the update is purely memory-bound, so fusion is the
whole optimization. Tiles are (ROWS, 128) fp32/bf16 VMEM blocks, 128-lane
aligned for the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 256
LANES = 128


def _kernel(z_ref, psi_ref, g_ref, o_ref, *, eps: float, order: int):
    z = z_ref[...].astype(jnp.float32)
    psi = psi_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    out = z + eps * psi + (eps ** (order + 1)) * g
    o_ref[...] = out.astype(o_ref.dtype)


def hyper_step_2d(z: jnp.ndarray, psi: jnp.ndarray, g: jnp.ndarray,
                  eps: float, order: int, interpret: bool = False):
    """z, psi, g: (N, 128k) 2-D views; returns z_next of z.dtype."""
    n, d = z.shape
    assert d % LANES == 0 and n % ROWS == 0, (n, d)
    grid = (n // ROWS, d // LANES)
    spec = pl.BlockSpec((ROWS, LANES), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_kernel, eps=float(eps), order=int(order)),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(z.shape, z.dtype),
        interpret=interpret,
    )(z, psi, g)
