"""Fused hypersolver update with RUNTIME step sizes (paper Eq. 3 + Eq. 5):

    z_{k+1}[i] = where(active[i],
                       z_k[i] + eps[i] * sum_j b_j r_j[i]
                              + eps[i]^{p+1} * g[i],
                       z_k[i])

One kernel pass fuses the b-weighted stage combination of ANY explicit
tableau with the eps^{p+1} correction AND the multi-rate freeze mask: the
state and each stage are read once and the new state written once, instead
of the ``stages + 3`` HBM round-trips of the unfused leaf-wise
lincomb/axpy/axpy/where sequence. The update is purely memory-bound, so
this traffic reduction is the whole optimization on TPU (interpret mode on
CPU).

Step sizes are *runtime operands*, not compile-time constants: the
per-sample ``eps`` row, its derived ``eps^{p+1}`` correction scale, and the
``active`` mask row ride in SMEM via ``pltpu.PrefetchScalarGridSpec`` and
are looked up per batch row with a scalar read — so one compiled kernel
serves every step size (scalar, traced, per-sample multi-rate) with no
respecialization.

Layout is batch-major: each sample's flattened state is a ``(R, 128)``
lane-aligned plane and the operands stack to ``(B, R, 128)``. Tiles are
``(1, BR, 128)`` VMEM blocks — rows of one tile belong to a single sample,
so samples share nothing but the prefetch lookup, which is what makes the
kernel trivially shardable over the batch axis (launch/mesh.py).
Accumulation is fp32 regardless of the storage dtype.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

SUBLANES = 8        # fp32 sublane quantum: R is padded to a multiple of this
LANES = 128
MAX_BLOCK_ROWS = 256  # VMEM block rows per tile (1 x 256 x 128 fp32 = 128 KiB)


def _rk_kernel(eps_ref, epsp_ref, act_ref, *refs,
               b: Tuple[float, ...], with_g: bool):
    """refs = (z, r_0..r_{S-1}, [g], out); eps/epsp/act are SMEM prefetch
    rows indexed by the batch grid coordinate. The stage count is static,
    so the combination loop fully unrolls into VPU fma chains; the step
    size is a runtime scalar broadcast into them."""
    z_ref, o_ref = refs[0], refs[-1]
    stage_refs = refs[1:1 + len(b)]
    i = pl.program_id(0)                      # batch row of this tile
    eps = eps_ref[i]
    z32 = z_ref[...].astype(jnp.float32)
    out = z32
    for bj, r_ref in zip(b, stage_refs):
        if bj != 0.0:
            out += (eps * bj) * r_ref[...].astype(jnp.float32)
    if with_g:
        g_ref = refs[1 + len(b)]
        out += epsp_ref[i] * g_ref[...].astype(jnp.float32)
    out = jnp.where(act_ref[i] != 0, out, z32)
    o_ref[...] = out.astype(o_ref.dtype)


def rk_update_batched(z: jnp.ndarray, stages: Sequence[jnp.ndarray],
                      g: Optional[jnp.ndarray],
                      eps_row: jnp.ndarray, epsp_row: jnp.ndarray,
                      active_row: jnp.ndarray, b: Tuple[float, ...],
                      interpret: bool = False):
    """z, stages[j], g: (B, R, 128) batch-major views; eps_row, epsp_row:
    (B,) float32; active_row: (B,) int32. Returns z_next of z.dtype."""
    assert len(stages) == len(b), (len(stages), b)
    B, R, L = z.shape
    assert L == LANES and R % SUBLANES == 0, (B, R, L)
    br = min(R, MAX_BLOCK_ROWS)
    assert R % br == 0, (R, br)
    operands = [z, *stages] + ([g] if g is not None else [])
    # index maps under PrefetchScalarGridSpec receive the prefetch refs as
    # trailing args; the data tiling ignores them (values, not indices).
    spec = pl.BlockSpec((1, br, LANES), lambda i, j, *_: (i, j, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, R // br),
        in_specs=[spec] * len(operands),
        out_specs=spec,
    )
    return pl.pallas_call(
        functools.partial(_rk_kernel, b=tuple(b), with_g=g is not None),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(z.shape, z.dtype),
        interpret=interpret,
    )(eps_row, epsp_row, active_row, *operands)
