from repro.kernels.hyper_step.ops import hyper_step  # noqa: F401
