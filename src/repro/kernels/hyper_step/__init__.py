from repro.kernels.hyper_step.ops import (  # noqa: F401
    fused_rk_update, hyper_step,
)
