"""Public wrappers: arbitrary-shape pytree-leaf updates with padding to the
(ROWS, 128) tile grid; auto-interpret on CPU.

``fused_rk_update`` is the general entry point used by the core
``Integrator`` engine: one kernel pass for the b-weighted stage combination
of any explicit tableau plus the optional eps^{p+1} hypersolver correction.
``hyper_step`` (psi precombined, single stage) is kept for callers of the
original final-axpy fusion.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import on_cpu
from repro.kernels.hyper_step.hyper_step import (
    LANES, ROWS, hyper_step_2d, rk_update_2d,
)


def _tile_shape(n: int) -> Tuple[int, int]:
    cols = LANES
    rows = -(-n // cols)
    rows += (-rows) % ROWS
    return rows, cols


def _flat(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    x = x.reshape(-1)
    return jnp.pad(x, (0, rows * cols - x.size)).reshape(rows, cols)


@partial(jax.jit,
         static_argnames=("eps", "b", "order", "interpret"))
def fused_rk_update(z: jnp.ndarray, stages: Sequence[jnp.ndarray],
                    g: Optional[jnp.ndarray], eps: float,
                    b: Tuple[float, ...], order: int = 1,
                    interpret: bool | None = None):
    """Fused z + eps*sum_j b[j]*stages[j] + eps^{order+1}*g over any-shaped
    arrays (g may be None for a plain base-solver step)."""
    interpret = on_cpu() if interpret is None else interpret
    shape, n = z.shape, z.size
    rows, cols = _tile_shape(n)
    out = rk_update_2d(
        _flat(z, rows, cols),
        tuple(_flat(r, rows, cols) for r in stages),
        _flat(g, rows, cols) if g is not None else None,
        eps, tuple(b), order, interpret=interpret)
    return out.reshape(-1)[:n].reshape(shape)


@partial(jax.jit, static_argnames=("eps", "order", "interpret"))
def hyper_step(z: jnp.ndarray, psi: jnp.ndarray, g: jnp.ndarray,
               eps: float, order: int = 1, interpret: bool | None = None):
    """Fused z + eps*psi + eps^{order+1}*g over any-shaped arrays."""
    interpret = on_cpu() if interpret is None else interpret
    shape, n = z.shape, z.size
    rows, cols = _tile_shape(n)
    out = hyper_step_2d(_flat(z, rows, cols), _flat(psi, rows, cols),
                        _flat(g, rows, cols), eps, order,
                        interpret=interpret)
    return out.reshape(-1)[:n].reshape(shape)
