"""Public wrapper: arbitrary-shape pytree-leaf update with padding to the
(ROWS, 128) tile grid; auto-interpret on CPU."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import on_cpu
from repro.kernels.hyper_step.hyper_step import LANES, ROWS, hyper_step_2d


@partial(jax.jit, static_argnames=("eps", "order", "interpret"))
def hyper_step(z: jnp.ndarray, psi: jnp.ndarray, g: jnp.ndarray,
               eps: float, order: int = 1, interpret: bool | None = None):
    """Fused z + eps*psi + eps^{order+1}*g over any-shaped arrays."""
    interpret = on_cpu() if interpret is None else interpret
    shape = z.shape
    n = z.size
    cols = LANES
    rows = -(-n // cols)
    pad_rows = (-rows) % ROWS
    total = (rows + pad_rows) * cols

    def flat(x):
        x = x.reshape(-1)
        return jnp.pad(x, (0, total - n)).reshape(rows + pad_rows, cols)

    out = hyper_step_2d(flat(z), flat(psi), flat(g), eps, order,
                        interpret=interpret)
    return out.reshape(-1)[:n].reshape(shape)
